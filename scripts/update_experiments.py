"""Splice the §Roofline table and §Perf log into EXPERIMENTS.md from
results/dryrun and results/perf JSONs."""

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline_report import load, markdown_table  # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")


def perf_log() -> str:
    out = []
    cells = {
        "starcoder2-prefill": ("starcoder2-7b x prefill_32k x 16x16",
                               "worst roofline fraction with a structural "
                               "cause (36 heads don't divide TP=16)"),
        "dsv3-train": ("deepseek-v3-671b x train_4k x 2x16x16",
                       "most collective-bound AND most representative of "
                       "the paper's technique (EP all-to-all over the "
                       "torus + cross-pod gradient sync)"),
        "mistral-train": ("mistral-large-123b x train_4k x 16x16",
                          "largest dense model; balanced compute/memory/"
                          "collective profile, richest trade-off space"),
    }
    for cell, (title, why) in cells.items():
        path = os.path.join(ROOT, "results", "perf", cell + ".json")
        if not os.path.exists(path):
            continue
        rows = json.load(open(path))
        out.append(f"### {title}\n\nSelected because: {why}.\n")
        out.append("| variant | hypothesis | compute_s | memory_s | "
                   "collective_s | roofline frac | peak GB | verdict |")
        out.append("|---|---|---|---|---|---|---|---|")
        base = None
        for r in rows:
            if "error" in r:
                out.append(f"| {r['variant']} | {r['hypothesis'][:70]} | "
                           f"ERROR | | | | | {r['error'][:40]} |")
                continue
            rr = r["roofline"]
            if base is None:
                base = rr
                verdict = "baseline"
            else:
                d = (base["step_bound_s"] - rr["step_bound_s"]) \
                    / base["step_bound_s"]
                verdict = f"{'+' if d >= 0 else ''}{100*d:.0f}% step bound"
            out.append(
                f"| {r['variant']} | {r['hypothesis'][:80]} | "
                f"{rr['compute_s']:.2f} | {rr['memory_s']:.2f} | "
                f"{rr['collective_s']:.2f} | {rr['roofline_fraction']:.3f} | "
                f"{r['memory']['peak_gb']:.1f} | {verdict} |")
            p = r.get("pallas_attention_projection")
            if p:
                out.append(
                    f"| &nbsp;&nbsp;+pallas-attn (projected) | fused flash "
                    f"kernel keeps score tensors in VMEM: attn HBM "
                    f"{p['attn_block_gb']:.0f}->{p['fused_gb']:.0f} GB | "
                    f"{rr['compute_s']:.2f} | {p['memory_s']:.2f} | "
                    f"{rr['collective_s']:.2f} | "
                    f"{p['roofline_fraction']:.3f} | — | projection |")
        out.append("")
    return "\n".join(out)


def main():
    exp = open(os.path.join(ROOT, "EXPERIMENTS.md")).read()
    cells = load(os.path.join(ROOT, "results", "dryrun"))
    table = markdown_table(cells)
    head, _, _ = exp.partition("<!-- ROOFLINE_TABLE -->")
    new = (head + "<!-- ROOFLINE_TABLE -->\n\n" + table + "\n\n"
           + PERF_PREAMBLE + "\n<!-- PERF_LOG -->\n\n" + perf_log() + "\n")
    open(os.path.join(ROOT, "EXPERIMENTS.md"), "w").write(new)
    print("EXPERIMENTS.md updated")


PERF_PREAMBLE = """## §Perf (hillclimb log)

Method per DESIGN.md: baseline every cell (table above), hillclimb the
THREE selected cells with explicit hypothesis -> change -> re-lower ->
confirm/refute cycles (driver: `benchmarks/hillclimb.py`; raw JSON in
`results/perf/`). The paper-faithful BASELINE rows and the beyond-paper
optimized rows are both recorded; `+pallas-attn (projected)` rows give the
analytically projected effect of running the validated Pallas flash
kernels in place of the XLA-scan attention (score tensors stay in VMEM) —
a projection, since Pallas TPU kernels cannot execute on the CPU dry-run
host.

Cross-cutting findings already folded into every baseline (see §Dry-run):
activation sharding hints (16x), FlashAttention custom-VJP (100x memory),
int8 optimizer states, microbatching policy. Refuted-and-rolled-back:
Megatron-style sequence sharding via hints (GSPMD dropped head sharding
after the per-layer gather -> 7x flops); ZeRO-3 weight-gather hints for
deepseek-v3 (re-gathers on every remat pass: collectives +10%).
"""


if __name__ == "__main__":
    main()
