from repro.data.pipeline import SyntheticTokens, Prefetcher, make_batch_specs

__all__ = ["SyntheticTokens", "Prefetcher", "make_batch_specs"]
