"""Deterministic sharded data pipeline.

Design target (1000+ nodes, DESIGN.md §5): NO coordinator state — every
batch is a pure function of (seed, step), so any host can materialize its
shard independently, restarts resume exactly, and elastic re-meshing needs
no data re-partitioning. This is also what makes failure-replay testing
exact (runtime/fault.py).
"""

from __future__ import annotations

import queue
import threading

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import ArchConfig


class SyntheticTokens:
    """Markov-ish synthetic LM tokens: learnable structure (not uniform
    noise) so example training shows a real loss curve."""

    def __init__(self, cfg: ArchConfig, batch: int, seq: int, seed: int = 0):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed

    def _tokens(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        v = self.cfg.vocab_size
        # token t+1 = (a * t + drift) % v on easy positions, noise elsewhere
        base = rng.integers(0, v, size=(self.batch, 1))
        mult = 31
        idx = np.arange(self.seq)
        toks = (base + mult * idx) % v
        noise_mask = rng.random((self.batch, self.seq)) < 0.15
        noise = rng.integers(0, v, size=(self.batch, self.seq))
        return np.where(noise_mask, noise, toks).astype(np.int32)

    def batch_at(self, step: int) -> dict:
        toks = self._tokens(step)
        out = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
        cfg = self.cfg
        rng = np.random.default_rng((self.seed, step, 7))
        if cfg.vision is not None:
            out["patches"] = jnp.asarray(rng.standard_normal(
                (self.batch, cfg.vision.n_patches, cfg.d_model),
                dtype=np.float32))
        if cfg.encdec is not None:
            out["frames"] = jnp.asarray(rng.standard_normal(
                (self.batch, cfg.encdec.encoder_seq, cfg.d_model),
                dtype=np.float32))
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_batch_specs(cfg: ArchConfig, pctx) -> dict:
    from repro.parallel.sharding import batch_specs
    from repro.config import ShapeConfig
    return batch_specs(cfg, ShapeConfig("train", 0, 0, "train"), pctx)


def shard_batch(batch: dict, pctx) -> dict:
    specs = {"tokens": P(pctx.dp_axes, None), "labels": P(pctx.dp_axes, None),
             "patches": P(pctx.dp_axes, None, None),
             "frames": P(pctx.dp_axes, None, None)}
    return {k: jax.device_put(v, NamedSharding(pctx.mesh, specs[k]))
            for k, v in batch.items()}


class Prefetcher:
    """Depth-k background prefetch (host->device overlap)."""

    def __init__(self, it, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = iter(it)
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
