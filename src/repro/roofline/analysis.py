"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), in seconds per step:

  compute    = HLO_FLOPs_per_device / peak_bf16_flops
  memory     = HLO_bytes_per_device / hbm_bw
  collective = collective_bytes_per_device / (ici_links x ici_link_bw)
               [+ cross-pod bytes / dcn_bw on the multi-pod mesh]

``cost_analysis()`` supplies FLOPs/bytes (already per-device under SPMD);
collective bytes are NOT in cost_analysis, so we parse the optimized HLO
text and sum operand sizes of every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute op. Ops whose replica
groups cross the pod axis are charged to DCN on the multi-pod mesh.
"""

from __future__ import annotations

import re

from repro.roofline.hw import V5E

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in (optimized) HLO text.

    Works on the op's full line: `%out = TYPE[dims] op-name(%a, %b, ...)`.
    We count the OUTPUT tuple/array bytes per op — a uniform proxy for the
    data a chip injects into the fabric for that op (operand lists repeat
    shapes; outputs are unambiguous in text form).
    """
    out: dict = {k: 0 for k in _COLLECTIVES}
    out["ops"] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", ls)
        if not m:
            continue
        op = m.group(2)
        # normalize fusion/start-done variants: all-reduce-start etc.
        base = None
        for k in _COLLECTIVES:
            if op == k or op.startswith(k + "-"):
                base = k
                break
        if base is None:
            continue
        shapes_txt = m.group(1)
        byts = sum(_shape_bytes(sm) for sm in _SHAPE_RE.finditer(shapes_txt))
        out[base] += byts
        out["ops"][base] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(flops: float, bytes_hbm: float, coll_bytes: float,
                   *, cross_pod_bytes: float = 0.0, hw=V5E) -> dict:
    compute_s = flops / hw.peak_bf16_flops
    memory_s = bytes_hbm / hw.hbm_bw
    ici_s = coll_bytes / (hw.ici_links * hw.ici_link_bw)
    dcn_s = cross_pod_bytes / hw.dcn_bw
    collective_s = ici_s + dcn_s
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s, "ici_s": ici_s, "dcn_s": dcn_s}
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["bottleneck"] = dom
    total = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["step_bound_s"] = total
    terms["roofline_fraction"] = compute_s / total if total > 0 else 0.0
    return terms


def model_flops_per_step(meta: dict, shape_kind: str, tokens: int) -> float:
    """MODEL_FLOPS: 6*N*D for dense training (fwd+bwd), 2*N*D inference;
    N = active params (MoE uses activated experts only)."""
    n = meta.get("active_params_b", 0.0) * 1e9
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n * tokens


def lm_serve_step_cost(cfg, *, n_decode: float, decode_kv: float,
                       n_prefill: float = 0.0, prefill_kv: float = 0.0,
                       dtype_bytes: int = 2) -> dict:
    """Closed-form cost of ONE continuous-batching serving step for an
    :class:`~repro.config.ArchConfig` — the config-derived twin of what
    :mod:`repro.roofline.hlo_cost` measures on compiled HLO, cheap enough
    to evaluate per simulated step for any config (compiling a real
    deepseek-7b decode graph to read its HLO would dwarf the simulation).

    A step advances ``n_decode`` in-flight requests by one token (KV
    context ``decode_kv`` each, the batch mean) and pushes ``n_prefill``
    new prompt tokens through (on top of ``prefill_kv`` already-cached
    tokens; causal attention is charged at the mean context
    ``prefill_kv + n_prefill/2``).  FLOPs use the 2*N-per-token rule of
    :func:`model_flops_per_step` plus the KV-length-dependent attention
    term that rule omits; HBM bytes charge one weight sweep per step
    (shared by every token in the batch — the continuous-batching
    economy) plus KV reads/writes.  Returned collective payloads are
    whole-model totals; tensor-parallel sharding (the /nranks) is the
    caller's concern (:mod:`repro.serve.sim`).
    """
    P = float(cfg.param_count())
    L, hd = cfg.n_layers, cfg.resolved_head_dim
    kv_tok = L * 2.0 * cfg.n_kv_heads * hd * dtype_bytes  # bytes/token
    attn_fl_tok = 4.0 * L * cfg.n_heads * hd              # flops/token/ctx
    nd, npf = float(n_decode), float(n_prefill)
    tokens = nd + npf
    pf_ctx = prefill_kv + npf / 2.0
    flops = (nd * (2.0 * P + attn_fl_tok * decode_kv)
             + npf * (2.0 * P + attn_fl_tok * pf_ctx))
    hbm = 0.0
    if tokens > 0:
        hbm += P * dtype_bytes                       # one weight sweep
        hbm += nd * decode_kv * kv_tok               # decode KV reads
        hbm += npf * pf_ctx * kv_tok                 # prefill KV reads
        hbm += tokens * kv_tok                       # KV writes
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        # per-token activation gather payload (one hidden vector each)
        "act_bytes": tokens * cfg.d_model * dtype_bytes,
        # KV shards migrated for the newly-prefilled tokens
        "kv_bytes": npf * kv_tok,
        "kv_bytes_per_token": kv_tok,
    }


def lm_train_step_cost(cfg, *, seq_len: int, batch: int,
                       dtype_bytes: int = 2,
                       grad_dtype_bytes: int = 2) -> dict:
    """Closed-form cost of ONE data-parallel training step for an
    :class:`~repro.config.ArchConfig` — the train-side twin of
    :func:`lm_serve_step_cost`, and the analytic cross-anchor for the
    synthetic-HLO estimate (:func:`repro.roofline.hlo_cost.synth_train_hlo`
    through the same while-rollup cost model real dry-run artifacts use).

    FLOPs follow the 6N rule split as 2N forward + 4N backward per token
    (N = active params; MoE charges top-k + shared experts only) plus the
    context-dependent attention term that rule omits, charged at the mean
    causal context ``seq_len/2`` forward and twice that backward.  HBM
    bytes charge one weight sweep forward, two backward (read weights,
    write gradients) and one optimizer pass over master weights;
    ``grad_bytes`` is the full data-parallel gradient volume one rank
    contributes to the sync — bucketing/sharding is the caller's concern
    (:mod:`repro.train.cosim`).
    """
    Na = float(cfg.active_param_count())
    P = float(cfg.param_count())
    L, hd = cfg.n_layers, cfg.resolved_head_dim
    tokens = float(seq_len) * float(batch)
    attn_fl_tok = 4.0 * L * cfg.n_heads * hd      # flops/token/ctx-token
    fwd = tokens * (2.0 * Na + attn_fl_tok * seq_len / 2.0)
    bwd = 2.0 * fwd
    act_tok = cfg.n_layers * cfg.d_model * dtype_bytes
    return {
        "tokens": tokens,
        "fwd_flops": fwd,
        "bwd_flops": bwd,
        "flops": fwd + bwd,
        "grad_bytes": P * grad_dtype_bytes,
        "param_bytes": P * dtype_bytes,
        "hbm_bytes": 4.0 * P * dtype_bytes + 2.0 * tokens * act_tok,
        "act_bytes_per_token": act_tok,
    }


def serve_step_calibration(cfg, *, measured_step_us: float,
                           n_decode: float, decode_kv: float,
                           n_prefill: float = 0.0, prefill_kv: float = 0.0,
                           dtype_bytes: int = 2,
                           rate_flops_per_us: float,
                           bw_bytes_per_us: float,
                           overhead_us: float = 0.0) -> dict:
    """Measured-vs-predicted anchor for :func:`lm_serve_step_cost`: fold a
    measured per-step time (e.g. ``launch/serve.py``'s wall-clock over
    engine steps) back onto the roofline prediction for the same step
    state and report the ratio — the single calibration constant that
    would make the closed form match the measurement
    (``BENCH_serve.json``'s ``calibration`` row)."""
    c = lm_serve_step_cost(cfg, n_decode=n_decode, decode_kv=decode_kv,
                           n_prefill=n_prefill, prefill_kv=prefill_kv,
                           dtype_bytes=dtype_bytes)
    predicted = overhead_us + max(c["flops"] / rate_flops_per_us,
                                  c["hbm_bytes"] / bw_bytes_per_us)
    return {
        "measured_step_us": float(measured_step_us),
        "predicted_step_us": float(predicted),
        "measured_over_predicted": float(measured_step_us) / predicted,
        "predicted_flops": c["flops"],
        "predicted_hbm_bytes": c["hbm_bytes"],
    }


def roofline_from_compiled(compiled, meta: dict, hw=V5E) -> dict:
    """Roofline terms from the compiled artifact.

    XLA's aggregate ``cost_analysis()`` counts while-loop bodies ONCE
    (verified; see EXPERIMENTS.md §Dry-run), so scan-over-layers models
    under-report by ~n_layers. We therefore use the HLO-text cost model
    with known_trip_count rollup (repro.roofline.hlo_cost) as the primary
    source, and record raw cost_analysis for comparison.
    """
    from repro.roofline.hlo_cost import analyze_hlo
    ca = compiled.cost_analysis() or {}
    raw_flops = float(ca.get("flops", 0.0))
    raw_bytes = float(ca.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    h = analyze_hlo(hlo)
    flops, byts, coll = h["flops"], h["bytes"], h["collectives"]
    multi = meta.get("mesh", "").startswith("2x")
    # cross-pod traffic: on the multi-pod mesh the gradient all-reduce over
    # the pod axis moves the FSDP-sharded gradient once across DCN
    cross = coll["all-reduce"] * 0.5 / 16.0 if multi else 0.0
    from repro.config import SHAPES
    shape = SHAPES[meta["shape"]]
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind != "decode" else shape.global_batch)
    n_dev = 512 if multi else 256
    terms = roofline_terms(flops, byts, coll["total"],
                           cross_pod_bytes=cross, hw=hw)
    model_fl = model_flops_per_step(meta, shape.kind, tokens) / n_dev
    return {
        "hlo_cost": {"flops": flops, "bytes": byts},
        "cost_analysis_raw": {"flops": raw_flops,
                              "bytes_accessed": raw_bytes,
                              "note": "while bodies counted once by XLA"},
        "collectives": coll,
        "roofline": terms,
        "model_flops_per_device": model_fl,
        "useful_flops_ratio": (model_fl / flops) if flops else 0.0,
    }
