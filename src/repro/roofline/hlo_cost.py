"""Static cost analyzer for optimized HLO text with while-loop rollup.

Motivation (verified experimentally, see EXPERIMENTS.md §Dry-run): XLA's
``compiled.cost_analysis()`` counts a while-loop body ONCE, regardless of
trip count — scan-over-layers models therefore under-report FLOPs by ~L and
collective bytes are similarly wrong. The optimized HLO text, however,
annotates every while op with ``backend_config={"known_trip_count":...}``,
so an exact rollup is possible:

  cost(computation) = own ops
                    + Σ while ops: trip x (cost(body) + cost(cond))
                    + Σ fusion ops: flops(called comp)   [bytes counted at
                      the fusion call site: operands + outputs once]

Per-op model:
* dot: 2 x out_elems x contraction_size (operand/result types resolved via
  a per-computation symbol table)
* convolution: 2 x out_elems x prod(window dims)
* collectives (incl. -start variants): output bytes, by category
* bytes: every op writes its outputs once; dot/conv/fusion/gather/scatter/
  custom-call additionally read their operands (elementwise ops inside
  fusions live in registers and are not charged)
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "c64": 8, "c128": 16,
    # 'pred' intentionally 0: the CPU backend materializes broadcast
    # iota-compare masks that Mosaic/TPU fuses into consumers — counting
    # them would charge the TPU roofline for phantom HBM traffic.
    "pred": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.+?)\s+"
                    r"([\w\-]+)\((.*)$")
_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\((.*?)\)\s*->")


def _shape_dims(txt: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(txt):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out

def _bytes_of(txt: str) -> int:
    total = 0
    for dt, dims in _shape_dims(txt):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _elems_of_first(txt: str) -> tuple[list[int], int]:
    sd = _shape_dims(txt)
    if not sd:
        return [], 0
    dims = sd[0][1]
    n = 1
    for d in dims:
        n *= d
    return dims, n


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_ops: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in COLLECTIVES})

    def add(self, other: "Cost", times: float = 1.0, bytes_too: bool = True):
        self.flops += other.flops * times
        if bytes_too:
            self.bytes += other.bytes * times
        for k in COLLECTIVES:
            self.coll[k] += other.coll[k] * times
            self.coll_ops[k] += other.coll_ops[k] * times


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations = self._split(hlo_text)
        self._memo: dict[str, Cost] = {}
        self.entry = next((n for n, c in self.computations.items()
                           if c["entry"]), None)

    # ------------------------------------------------------------ parsing
    @staticmethod
    def _split(text: str) -> dict:
        comps: dict = {}
        cur = None
        for line in text.splitlines():
            if cur is None:
                m = _HDR_RE.match(line.strip()) if line.rstrip().endswith("{") \
                    else None
                if m:
                    cur = m.group(1)
                    comps[cur] = {"entry": line.startswith("ENTRY"),
                                  "params": m.group(2), "lines": []}
                continue
            if line.startswith("}"):
                cur = None
                continue
            comps[cur]["lines"].append(line)
        return comps

    @staticmethod
    def _symbols(comp: dict) -> dict:
        """name -> type text (for params and op results)."""
        table = {}
        # params: "name: TYPE, name2: TYPE2" (types may be tuples)
        for m in re.finditer(r"([\w.\-]+)\s*:\s*((?:\([^)]*\)|[a-z0-9]+"
                             r"\[[0-9,]*\](?:\{[^}]*\})?))",
                             comp["params"]):
            table["%" + m.group(1)] = m.group(2)
        for line in comp["lines"]:
            m = _OP_RE.match(line)
            if m:
                table[m.group(1)] = m.group(2)
        return table

    # --------------------------------------------------------------- costs
    def cost(self, name: str | None = None) -> Cost:
        name = name or self.entry
        if name in self._memo:
            return self._memo[name]
        comp = self.computations.get(name)
        total = Cost()
        self._memo[name] = total
        if comp is None:
            return total
        table = self._symbols(comp)
        for line in comp["lines"]:
            m = _OP_RE.match(line)
            if not m:
                continue
            _, out_type, op, rest = m.groups()
            out_bytes = _bytes_of(out_type)
            # ---- flops
            if op == "dot":
                out_dims, out_elems = _elems_of_first(out_type)
                contract = self._dot_contraction(line, rest, table)
                total.flops += 2.0 * out_elems * contract
                total.bytes += out_bytes + self._operand_bytes(rest, table)
            elif op == "convolution":
                _, out_elems = _elems_of_first(out_type)
                win = re.search(r"window=\{size=([\dx]+)", line)
                k = 1
                if win:
                    for d in win.group(1).split("x"):
                        k *= int(d)
                total.flops += 2.0 * out_elems * k
                total.bytes += out_bytes + self._operand_bytes(rest, table)
            elif op == "fusion":
                # Heuristics for a TPU-proxy HBM model (see see module doc +
                # EXPERIMENTS.md §Roofline methodology):
                # * dynamic-update-slice fusions alias in place: traffic is
                #   ~2x the update slice (read-modify-write), not the buffer;
                # * pure layout fusions (copy/transpose/convert/bitcast) are
                #   CPU-backend artifacts — TPU fuses them into consumers;
                # * slice/copy fusions read only ~output-sized windows of
                #   big operands -> cap operands at output size;
                # * reduce fusions genuinely read full operands.
                name_l = m.group(1)
                ops_b = self._operand_list_bytes(rest, table)
                if "dynamic-update-slice" in line or "dynamic_update" in line:
                    nonscalar = [b for b in ops_b if b > 256]
                    upd = min(nonscalar) if nonscalar else out_bytes
                    total.bytes += 2 * min(upd, out_bytes)
                elif re.fullmatch(r"%?[_.\d]*(copy|transpose|convert|bitcast"
                                  r"|reshape)[_.\w]*(copy|transpose|convert"
                                  r"|bitcast|reshape|fusion|[_.\d])*",
                                  name_l):
                    pass  # pure layout plumbing: fused away on TPU
                elif "reduce" in name_l:
                    total.bytes += out_bytes + sum(ops_b)
                else:
                    total.bytes += out_bytes + sum(min(b, out_bytes)
                                                   for b in ops_b)
            elif op in ("copy", "transpose", "convert", "reshape",
                        "broadcast", "iota"):
                pass  # layout/manifest ops: fused on TPU
            elif op == "custom-call":
                total.bytes += out_bytes + self._operand_bytes(rest, table)
            elif op in ("dynamic-slice", "gather"):
                # reads only the sliced/gathered rows, not the whole operand
                total.bytes += 2 * out_bytes
            elif op in ("dynamic-update-slice", "scatter"):
                # in-place aliased update: read+write of the update region
                upd = self._operand_bytes(rest, table, skip_first=True)
                total.bytes += 2 * min(upd, out_bytes)
            elif op == "while":
                body = re.search(r"body=(%[\w.\-]+)", line)
                trip = 1
                bc = re.search(r'known_trip_count[^0-9]*(\d+)', line)
                if bc:
                    trip = int(bc.group(1))
                if body:
                    total.add(self.cost(body.group(1)), times=trip)
                cond = re.search(r"condition=(%[\w.\-]+)", line)
                if cond:
                    total.add(self.cost(cond.group(1)), times=trip)
            elif any(op == k or op.startswith(k + "-") for k in COLLECTIVES):
                base = next(k for k in COLLECTIVES
                            if op == k or op.startswith(k + "-"))
                if op.endswith("-done"):
                    continue
                total.coll[base] += _bytes_of(out_type)
                total.coll_ops[base] += 1
                total.bytes += out_bytes
            elif op in ("tuple", "get-tuple-element", "parameter", "bitcast",
                        "constant", "after-all", "partition-id",
                        "replica-id"):
                pass  # aliasing / metadata ops move no HBM bytes
            else:
                total.bytes += out_bytes
            # roll up flops of called fusions (their dots, if any)
            cm = re.search(r"calls=(%[\w.\-]+)", line)
            if cm and op == "fusion":
                total.add(self.cost(cm.group(1)), bytes_too=False)
        return total

    def _dot_contraction(self, line: str, rest: str, table: dict) -> int:
        lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        ops = re.findall(r"%[\w.\-]+", rest.split("),")[0])
        if not lc or not ops:
            return 1
        lhs_type = table.get(ops[0], "")
        dims, _ = _elems_of_first(lhs_type)
        contract = 1
        for idx in lc.group(1).split(","):
            if idx and int(idx) < len(dims):
                contract *= dims[int(idx)]
        return contract

    @staticmethod
    def _operand_bytes(rest: str, table: dict, skip_first: bool = False
                       ) -> int:
        args = rest.split("),")[0]
        total = 0
        for i, nm in enumerate(re.findall(r"%[\w.\-]+", args)):
            if skip_first and i == 0:
                continue
            total += _bytes_of(table.get(nm, ""))
        return total

    @staticmethod
    def _operand_list_bytes(rest: str, table: dict) -> list[int]:
        args = rest.split("),")[0]
        return [_bytes_of(table.get(nm, ""))
                for nm in re.findall(r"%[\w.\-]+", args)]


def analyze_hlo(hlo_text: str) -> dict:
    model = HloCostModel(hlo_text)
    c = model.cost()
    coll_total = sum(c.coll.values())
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collectives": {**{k: c.coll[k] for k in COLLECTIVES},
                        "ops": dict(c.coll_ops), "total": coll_total},
    }


# ------------------------------------------------- fused-attention projection
def _trip_multipliers(model: HloCostModel) -> dict:
    """Total execution multiplier of every computation, walking from ENTRY
    through while bodies (x trip count) and fusion calls (x1)."""
    mult: dict[str, float] = {}

    def walk(name: str, m: float):
        mult[name] = mult.get(name, 0.0) + m
        comp = model.computations.get(name)
        if comp is None:
            return
        for line in comp["lines"]:
            wm = re.search(r"condition=(%[\w.\-]+), body=(%[\w.\-]+)", line)
            if wm:
                t = re.search(r"known_trip_count[^0-9]*(\d+)", line)
                trip = int(t.group(1)) if t else 1
                walk(wm.group(2), m * trip)
                continue
            cm = re.search(r"calls=(%[\w.\-]+)", line)
            if cm:
                walk(cm.group(1), m)

    walk(model.entry, 1.0)
    return mult


# -------------------------------------------------- synthetic train modules
def synth_train_hlo(cfg, *, seq_len: int, batch: int = 1,
                    microbatches: int = 1, dtype: str = "bf16") -> str:
    """Parser-compatible HLO text of one *forward* training step for an
    :class:`~repro.config.ArchConfig` — the compute anchor the train
    co-sim (:mod:`repro.train.cosim`) feeds through :func:`analyze_hlo`
    instead of compiling a multi-hundred-B-parameter graph to read its
    text.  The module is shaped like a real scan-over-layers lowering:

    * an outer ``while`` over microbatches (``known_trip_count``),
    * nested ``while`` loops over the dense and MoE layer stacks,
    * per-layer ``dot`` ops sized from the config (attention projections,
      full-context score/value matmuls, gated MLP or top-k + shared
      experts), and the LM head per microbatch,
    * one ``all-reduce`` of the f32 gradient at ENTRY (the DP sync whose
      bucketing the co-sim searches over).

    The nested loops are exactly what ``cost_analysis()`` mis-counts and
    the while-rollup (:class:`HloCostModel`, :func:`_trip_multipliers`)
    exists to fix, so this generator doubles as their test surface.
    Backward cost is the caller's multiplier (the standard 2x forward).
    """
    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.resolved_head_dim
    T = max(1, (seq_len * batch) // max(1, microbatches))
    qh, kvh = cfg.n_heads * hd, 2 * cfg.n_kv_heads * hd
    up_mult = 2 if getattr(cfg, "mlp_gated", True) else 1
    state = f"(s32[], {dtype}[{T},{d}])"
    lines: list[str] = []

    def attn(tag: str) -> list[str]:
        return [
            f"  %{tag}.wq = {dtype}[{d},{qh}]{{1,0}} constant(0)",
            f"  %{tag}.wkv = {dtype}[{d},{kvh}]{{1,0}} constant(0)",
            f"  %{tag}.wo = {dtype}[{qh},{d}]{{1,0}} constant(0)",
            f"  %{tag}.q = {dtype}[{T},{qh}]{{1,0}} dot(%x, %{tag}.wq), "
            f"lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}",
            f"  %{tag}.kv = {dtype}[{T},{kvh}]{{1,0}} dot(%x, %{tag}.wkv), "
            f"lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}",
            # per-head score/value matmuls, heads folded into the rows:
            # [H*T, hd] x [hd, T] charges the full 2*H*T*T*hd like XLA's
            # unfused lowering (causal masking discards, not skips, work)
            f"  %{tag}.qh = {dtype}[{cfg.n_heads * T},{hd}]{{1,0}} "
            f"reshape(%{tag}.q)",
            f"  %{tag}.kt = {dtype}[{hd},{T}]{{1,0}} reshape(%{tag}.kv)",
            f"  %{tag}.s = {dtype}[{cfg.n_heads * T},{T}]{{1,0}} "
            f"dot(%{tag}.qh, %{tag}.kt), "
            f"lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}",
            f"  %{tag}.vt = {dtype}[{T},{hd}]{{1,0}} reshape(%{tag}.kv)",
            f"  %{tag}.av = {dtype}[{cfg.n_heads * T},{hd}]{{1,0}} "
            f"dot(%{tag}.s, %{tag}.vt), "
            f"lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}",
            f"  %{tag}.ctx = {dtype}[{T},{qh}]{{1,0}} reshape(%{tag}.av)",
            f"  %{tag}.o = {dtype}[{T},{d}]{{1,0}} dot(%{tag}.ctx, "
            f"%{tag}.wo), lhs_contracting_dims={{1}}, "
            f"rhs_contracting_dims={{0}}",
        ]

    def mlp(tag: str, width: int, rows: int = 0) -> list[str]:
        """Gated MLP dots at hidden ``width``; ``rows`` > 0 folds a
        top-k token replication into the row dimension (MoE routing)."""
        R = rows or T
        out = []
        if rows:
            out.append(f"  %{tag}.xr = {dtype}[{R},{d}]{{1,0}} "
                       f"reshape(%x)")
        src = f"%{tag}.xr" if rows else "%x"
        out += [
            f"  %{tag}.wu = {dtype}[{d},{up_mult * width}]{{1,0}} "
            f"constant(0)",
            f"  %{tag}.wd = {dtype}[{width},{d}]{{1,0}} constant(0)",
            f"  %{tag}.up = {dtype}[{R},{up_mult * width}]{{1,0}} "
            f"dot({src}, %{tag}.wu), lhs_contracting_dims={{1}}, "
            f"rhs_contracting_dims={{0}}",
            f"  %{tag}.h = {dtype}[{R},{width}]{{1,0}} reshape(%{tag}.up)",
            f"  %{tag}.dn = {dtype}[{R},{d}]{{1,0}} dot(%{tag}.h, "
            f"%{tag}.wd), lhs_contracting_dims={{1}}, "
            f"rhs_contracting_dims={{0}}",
        ]
        return out

    def layer_comp(name: str, body_mid: list[str]) -> None:
        lines.extend([
            f"%{name} (p: {state}) -> {state} {{",
            f"  %p = {state} parameter(0)",
            f"  %i = s32[] get-tuple-element(%p), index=0",
            f"  %x = {dtype}[{T},{d}]{{1,0}} get-tuple-element(%p), index=1",
            *body_mid,
            f"  ROOT %out = {state} tuple(%i, %x)",
            "}", "",
            f"%{name}.cond (pc: {state}) -> pred[] {{",
            f"  %pc = {state} parameter(0)",
            f"  %ic = s32[] get-tuple-element(%pc), index=0",
            f"  %lim = s32[] constant(0)",
            f"  ROOT %lt = pred[] compare(%ic, %lim), direction=LT",
            "}", "",
        ])

    n_dense = cfg.n_dense_layers if cfg.moe is not None else L
    if n_dense:
        layer_comp("dense_body", attn("a") + mlp("m", cfg.d_ff))
    moe_layers = L - n_dense
    if cfg.moe is not None and moe_layers:
        m = cfg.moe
        body = attn("a") + [
            f"  %r.wg = {dtype}[{d},{m.n_experts}]{{1,0}} constant(0)",
            f"  %r.gate = {dtype}[{T},{m.n_experts}]{{1,0}} dot(%x, "
            f"%r.wg), lhs_contracting_dims={{1}}, "
            f"rhs_contracting_dims={{0}}",
        ] + mlp("e", m.d_expert, rows=m.top_k * T)
        if m.n_shared_experts:
            body += mlp("s", m.n_shared_experts * m.d_shared)
        layer_comp("moe_body", body)

    def while_line(out: str, name: str, trip: int) -> str:
        return (f"  %{out} = {state} while(%init), "
                f"condition=%{name}.cond, body=%{name}, "
                f'backend_config={{"known_trip_count":{{"n":"{trip}"}}}}')

    mb_mid = [f"  %init = {state} tuple(%i, %x)"]
    if n_dense:
        mb_mid.append(while_line("dense", "dense_body", n_dense))
    if cfg.moe is not None and moe_layers:
        mb_mid.append(while_line("moe", "moe_body", moe_layers))
    mb_mid += [
        f"  %wv = {dtype}[{d},{cfg.vocab_size}]{{1,0}} constant(0)",
        f"  %logits = {dtype}[{T},{cfg.vocab_size}]{{1,0}} dot(%x, %wv), "
        f"lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}",
    ]
    layer_comp("mb_body", mb_mid)

    P = int(cfg.param_count())
    lines.extend([
        f"ENTRY %train_step.{cfg.name if hasattr(cfg, 'name') else 'lm'} "
        f"(x0: {dtype}[{T},{d}]) -> {state} {{",
        f"  %x0 = {dtype}[{T},{d}]{{1,0}} parameter(0)",
        f"  %z = s32[] constant(0)",
        f"  %init = {state} tuple(%z, %x0)",
        f"  %mb = {state} while(%init), condition=%mb_body.cond, "
        f"body=%mb_body, "
        f'backend_config={{"known_trip_count":{{"n":"{microbatches}"}}}}',
        f"  %grads = f32[{P}]{{0}} constant(0)",
        f"  %gsync = f32[{P}]{{0}} all-reduce(%grads), to_apply=%mb_body",
        f"  ROOT %res = {state} get-tuple-element(%mb), index=0",
        "}",
    ])
    return "\n".join(lines)


def flash_block_report(hlo_text: str) -> dict:
    """Identify flash-attention block bodies (innermost while bodies that
    contain an `exponential` fusion plus >=2 dots) and report:

    * ``block_bytes``: their total rolled-up HBM traffic under this XLA
      lowering (score/probability tensors round-trip per block);
    * ``fused_bytes``: the projected traffic if the block ran as a fused
      Pallas kernel — only the non-score dot operands (q/k/v/dout tiles)
      and non-score outputs stream from HBM; everything (qc x kc)-shaped
      stays in VMEM.

    Used by the §Perf 'pallas-attention (projected)' variants.
    """
    model = HloCostModel(hlo_text)
    model.cost()
    mult = _trip_multipliers(model)
    block_bytes = 0.0
    fused_bytes = 0.0
    for name, comp in model.computations.items():
        if name not in mult:
            continue
        text = "\n".join(comp["lines"])
        n_dots = len(re.findall(r"\bdot\(", text))
        if n_dots < 2 or "exponential" not in text:
            continue
        if re.search(r"condition=", text):
            continue  # not innermost
        table = model._symbols(comp)
        own = 0.0
        fused = 0.0
        # square-chunk (score) shapes to exclude from the fused stream
        for line in comp["lines"]:
            mm = _OP_RE.match(line)
            if not mm:
                continue
            _, out_type, op, rest = mm.groups()
            if op in ("tuple", "get-tuple-element", "parameter", "constant",
                      "bitcast", "copy", "transpose", "convert", "reshape",
                      "broadcast", "iota"):
                continue
            dims, _ = _elems_of_first(out_type)
            score_like = len(dims) >= 2 and dims[-1] == dims[-2] >= 256
            ob = _bytes_of(out_type)
            if op == "dot":
                own += ob + model._operand_bytes(rest, table)
                for nm_ in re.findall(r"%[\w.\-]+",
                                      rest.split("),")[0]):
                    t = table.get(nm_, "")
                    d2, _ = _elems_of_first(t)
                    if not (len(d2) >= 2 and d2[-1] == d2[-2] >= 256):
                        fused += _bytes_of(t)
                if not score_like:
                    fused += ob
            elif op == "fusion":
                ops_b = model._operand_list_bytes(rest, table)
                own += ob + sum(min(b, ob) for b in ops_b)
                if not score_like:
                    fused += ob
            else:
                own += ob
                if not score_like:
                    fused += ob
        block_bytes += own * mult[name]
        fused_bytes += fused * mult[name]
    return {"block_bytes": block_bytes, "fused_bytes": fused_bytes,
            "savings_bytes": block_bytes - fused_bytes}
