"""Target hardware constants (TPU v5e) for roofline terms and CommPolicy.

The container is CPU-only; these constants describe the TARGET chip per the
assignment: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class HwSpec:
    name: str
    peak_bf16_flops: float      # FLOP/s per chip
    hbm_bw: float               # bytes/s per chip
    hbm_bytes: float            # capacity per chip
    ici_link_bw: float          # bytes/s per ICI link direction
    ici_links: int              # links per chip on the 2-D torus
    dcn_bw: float               # cross-pod bytes/s per chip
    vmem_bytes: float = 128 * 2 ** 20
    mxu_tile: int = 128


V5E = HwSpec(
    name="tpu-v5e",
    peak_bf16_flops=197e12,
    hbm_bw=819e9,
    hbm_bytes=16 * 2 ** 30,
    ici_link_bw=50e9,
    ici_links=4,
    dcn_bw=6.25e9,   # ~50 Gb/s effective per-chip cross-pod budget
)
