"""Configuration system: architecture, parallelism and run configs.

Every assigned architecture provides a ``src/repro/configs/<id>.py`` with an
``ARCH`` constant built from these dataclasses; reduced smoke variants are
derived with :func:`reduced`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden size
    n_shared_experts: int = 0
    d_shared: int = 0              # shared-expert FFN hidden size
    capacity_factor: float = 1.25
    router_softmax: bool = True    # softmax routing (vs sigmoid)
    #: int8-quantize the all-to-all dispatch payloads (per-slot scales) —
    #: the DeepSeek-V3 fp8-dispatch trick, halving EP wire bytes
    a2a_quant: bool = False


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2/V3 Multi-head Latent Attention dims (arXiv:2412.19437)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD (arXiv:2405.21060)."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_encoder_layers: int = 12
    encoder_seq: int = 1500        # whisper: 30s of audio at 50 Hz
    frontend: str = "stub"         # precomputed frame embeddings per spec


@dataclasses.dataclass(frozen=True)
class VisionStubConfig:
    n_patches: int = 256           # precomputed patch embeddings per spec


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    rope_theta: float = 10000.0
    attn_bias: bool = False
    mlp_bias: bool = False
    mlp_act: str = "silu"          # silu | gelu
    mlp_gated: bool = True
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    tie_embeddings: bool = False
    pos_embedding: str = "rope"    # rope | learned | none
    moe: Optional[MoEConfig] = None
    n_dense_layers: int = 0        # leading dense layers before MoE stack
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0     # zamba2: shared attn block period
    encdec: Optional[EncDecConfig] = None
    vision: Optional[VisionStubConfig] = None
    mtp_depth: int = 0             # DeepSeek-V3 multi-token prediction
    dtype: str = "bfloat16"
    # attention lowering: chunk sizes for the XLA flash path
    q_chunk: int = 1024
    kv_chunk: int = 1024
    #: pad query heads up to this count so they divide the TP axis (extra
    #: heads are zero-initialized AND output-masked -> bit-exact math and
    #: zero gradients; a recorded §Perf optimization, off by default)
    pad_heads_to: Optional[int] = None
    # sub-quadratic? (decides long_500k applicability)
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, v = self.d_model, self.vocab_size
        n = v * d  # embeddings
        if not self.tie_embeddings:
            n += d * v  # output head
        hd = self.resolved_head_dim

        def attn_params() -> int:
            if self.mla is not None:
                m = self.mla
                qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                p = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                p += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim
                                                      + m.v_head_dim)
                p += self.n_heads * m.v_head_dim * d
                return p
            p = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
            p += self.n_heads * hd * d
            return p

        def mlp_params(ff: int) -> int:
            mats = 3 if self.mlp_gated else 2
            return mats * d * ff

        def ssm_params() -> int:
            s = self.ssm
            d_in = s.expand * d
            nh = d_in // s.head_dim
            conv_ch = d_in + 2 * s.n_groups * s.d_state
            p = d * (2 * d_in + 2 * s.n_groups * s.d_state + nh)  # in_proj
            p += conv_ch * s.d_conv  # depthwise conv
            p += 2 * nh              # A_log, D
            p += nh                  # dt_bias
            p += d_in                # gated norm
            p += d_in * d            # out_proj
            return p

        if self.family in ("dense", "vlm"):
            n += self.n_layers * (attn_params() + mlp_params(self.d_ff))
        elif self.family == "audio":
            enc = self.encdec.n_encoder_layers
            n += enc * (attn_params() + mlp_params(self.d_ff))
            # decoder: self-attn + cross-attn + mlp
            n += self.n_layers * (2 * attn_params() + mlp_params(self.d_ff))
        elif self.family == "moe":
            m = self.moe
            n += self.n_dense_layers * (attn_params() + mlp_params(self.d_ff))
            moe_layers = self.n_layers - self.n_dense_layers
            per = attn_params() + m.n_experts * mlp_params(m.d_expert)
            per += d * m.n_experts  # router
            if m.n_shared_experts:
                per += m.n_shared_experts * (3 if self.mlp_gated else 2) * d * m.d_shared
            n += moe_layers * per
        elif self.family == "ssm":
            n += self.n_layers * ssm_params()
        elif self.family == "hybrid":
            n += self.n_layers * ssm_params()
            # one shared attention+MLP block (weights shared across uses)
            n += attn_params() + mlp_params(self.d_ff)
        if self.mtp_depth:
            n += self.mtp_depth * (attn_params() + (
                self.moe.n_experts * 3 * d * self.moe.d_expert + d * self.moe.n_experts
                if self.moe else mlp_params(self.d_ff)))
        return int(n)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        total = self.param_count()
        moe_layers = self.n_layers - self.n_dense_layers + self.mtp_depth
        mats = 3 if self.mlp_gated else 2
        inactive = moe_layers * (m.n_experts - m.top_k) * mats * self.d_model * m.d_expert
        return int(total - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


#: the four assigned input shapes (identical for every LM arch)
SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_runnable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch x shape) dry-run cell runs, per the assignment rules."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, "skipped(full-attention arch; long_500k needs sub-quadratic)"
    return True, ""


def reduced(arch: ArchConfig, **overrides) -> ArchConfig:
    """Tiny same-family variant for CPU smoke tests."""
    kw: dict = dict(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, q_chunk=64, kv_chunk=64,
    )
    if arch.moe is not None:
        kw["moe"] = MoEConfig(
            n_experts=4, top_k=2, d_expert=32,
            n_shared_experts=arch.moe.n_shared_experts,
            d_shared=32 if arch.moe.n_shared_experts else 0)
        kw["n_dense_layers"] = min(arch.n_dense_layers, 1)
    if arch.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)
    if arch.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                              n_groups=1, chunk=32)
        kw["n_kv_heads"] = 4
    if arch.hybrid_attn_every:
        kw["hybrid_attn_every"] = 2
        kw["n_layers"] = 4
    if arch.encdec is not None:
        kw["encdec"] = EncDecConfig(n_encoder_layers=2, encoder_seq=16)
    if arch.vision is not None:
        kw["vision"] = VisionStubConfig(n_patches=8)
    if arch.mtp_depth:
        kw["mtp_depth"] = 1
    kw.update(overrides)
    return dataclasses.replace(arch, **kw)
