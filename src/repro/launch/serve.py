"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Drives the slot-based continuous-batching engine with synthetic requests
and reports per-request latency in *engine steps* (submit -> done) — the
same quantity the simulated lane (``repro.serve.sim`` +
``benchmarks/serve_sweep.py``) reports in simulated microseconds, so the
real engine and the simulator publish comparable distributions.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import reduced
from repro.configs import ALL_ARCHS, EXTRA_ARCHS, get
from repro.models import build_model
from repro.serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="exanest-lm-100m",
                    choices=ALL_ARCHS + EXTRA_ARCHS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--window", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0,
                    help="RNG seed for the synthetic requests "
                         "(deterministic token streams per seed)")
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, slots=args.slots, window=args.window)
    rng = np.random.default_rng(args.seed)
    rids = [eng.submit(
        list(rng.integers(0, cfg.vocab_size, size=args.prompt_len)),
        max_new_tokens=args.max_new)
        for _ in range(args.requests)]
    t0 = time.perf_counter()
    steps = eng.run_until_idle(max_steps=10000)
    dt = time.perf_counter() - t0
    done = sum(eng.result(r) is not None for r in rids)
    toks = sum(len(eng.result(r) or []) for r in rids)
    print(f"served {done}/{args.requests} requests, {toks} tokens in "
          f"{steps} engine steps, {dt:.2f}s ({toks/max(dt,1e-9):.1f} tok/s)")
    stats = eng.request_steps()
    if stats:
        lat = np.sort(np.array([d - s for s, d in stats.values()],
                               dtype=np.float64))
        print(f"latency (submit->done, engine steps): "
              f"p50={np.quantile(lat, 0.5):.0f} "
              f"p90={np.quantile(lat, 0.9):.0f} "
              f"p99={np.quantile(lat, 0.99):.0f} max={lat.max():.0f}")
        for rid in sorted(stats)[:8]:
            s, d = stats[rid]
            print(f"  request {rid}: submit@{s} done@{d} "
                  f"({d - s} steps, {len(eng.result(rid) or [])} tokens)")


if __name__ == "__main__":
    main()
