"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On real TPU pods this process runs per-host under the standard JAX
distributed bootstrap; on CPU it drives the reduced config end-to-end (the
same step function the dry-run lowers at full scale).
"""

from __future__ import annotations

import argparse
import os

import jax

from repro.config import reduced
from repro.configs import ALL_ARCHS, EXTRA_ARCHS, get
from repro.data.pipeline import SyntheticTokens
from repro.models import build_model
from repro.runtime.fault import StragglerMonitor, run_with_recovery
from repro.train.loop import Trainer
from repro.train.optimizer import AdamWConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="exanest-lm-100m",
                    choices=ALL_ARCHS + EXTRA_ARCHS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--ckpt-dir", default="results/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--quantize-opt", action="store_true")
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                          decay_steps=args.steps,
                          quantize_states=args.quantize_opt)
    trainer = Trainer(model, opt_cfg)
    state = trainer.init_state(jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg, batch=args.batch, seq=args.seq)
    step_fn = trainer.make_step()
    mon = StragglerMonitor()

    def one_step(st, i):
        st, metrics = step_fn(st, data.batch_at(i))
        if i % 10 == 0:
            print(f"step {i} loss {float(metrics['loss']):.4f}")
        return st

    os.makedirs(args.ckpt_dir, exist_ok=True)
    state, log = run_with_recovery(state, one_step, args.steps,
                                   ckpt_dir=args.ckpt_dir,
                                   ckpt_every=args.ckpt_every, straggler=mon)
    print(f"done: {args.steps} steps, straggles={log['straggles']}")


if __name__ == "__main__":
    main()
