import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For one (arch x shape x mesh) cell: build the step function the real
launcher uses, ``jax.jit(...).lower(**specs).compile()`` it against
ShapeDtypeStruct stand-ins (no allocation), and record:

* ``memory_analysis()``  — per-device bytes (proves it fits),
* ``cost_analysis()``    — per-device FLOPs / bytes accessed,
* collective bytes      — parsed from the optimized HLO text, split by op
  kind (all-reduce / all-gather / reduce-scatter / all-to-all /
  collective-permute),
* the roofline terms (repro.roofline.analysis).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-v3-671b \
      --shape train_4k --mesh multi --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

NOTE: the XLA_FLAGS line above must execute before ANY jax import — jax
locks the device count on first init. Only this entry point sets it;
tests/benches see the real single device.
"""

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.config import SHAPES, ArchConfig, ShapeConfig, cell_runnable
from repro.configs import ALL_ARCHS, get
from repro.launch.mesh import make_parallel_ctx, make_production_mesh
from repro.models import build_model
from repro.parallel.sharding import (batch_specs, cache_specs, param_specs,
                                     opt_state_specs)
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.loop import make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P


def input_specs(cfg: ArchConfig, shape: ShapeConfig, model) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
    B, S = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.vision is not None:
            batch["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.vision.n_patches, cfg.d_model), f32)
        if cfg.encdec is not None:
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encdec.encoder_seq, cfg.d_model), f32)
        return batch
    # decode: one new token against a seq_len cache
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    return {"cache": cache,
            "batch": {"token": jax.ShapeDtypeStruct((B,), i32),
                      "pos": jax.ShapeDtypeStruct((), i32)}}


def _sharding_tree(spec_tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _opt_config(cfg: ArchConfig) -> AdamWConfig:
    # int8 optimizer states once f32 m/v would not fit a 256-chip pod
    quantize = cfg.param_count() * 10 > 256 * 12e9
    return AdamWConfig(quantize_states=quantize)


def _train_policy(cfg: ArchConfig, shape: ShapeConfig, pctx) -> dict:
    """Microbatch count + grad-accumulation dtype so remat-saved layer
    inputs fit HBM: act ~= tokens/dev * d_model * n_layers * 2B / mb."""
    import math
    dp = pctx.dp_size
    tokens_dev = shape.global_batch * shape.seq_len // dp
    act = tokens_dev * cfg.d_model * cfg.n_layers * 2
    mb = 1
    max_mb = max(1, shape.global_batch // dp)
    while act / mb > 4e9 and mb * 2 <= min(max_mb, 16):
        mb *= 2
    opt_cfg = _opt_config(cfg)
    accum = jnp.bfloat16 if opt_cfg.quantize_states else jnp.float32
    return {"microbatches": mb, "accum_dtype": accum, "opt_cfg": opt_cfg}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               extra_flags: dict | None = None):
    """Returns (lowered, meta). Separated for the perf-iteration loop.

    ``extra_flags`` (hillclimb knobs): ``cfg_overrides`` (dataclasses.replace
    on the ArchConfig, e.g. {"pad_heads_to": 48, "q_chunk": 2048}),
    ``seq_shard``, ``train_policy`` overrides.
    """
    cfg = get(arch)
    if extra_flags and extra_flags.get("cfg_overrides"):
        import dataclasses as _dc0
        cfg = _dc0.replace(cfg, **extra_flags["cfg_overrides"])
    shape = SHAPES[shape_name]
    ok, why = cell_runnable(cfg, shape)
    if not ok:
        return None, {"skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    pctx = make_parallel_ctx(mesh)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = _sharding_tree(param_specs(params, cfg, pctx), mesh)
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "params_b": cfg.param_count() / 1e9,
            "active_params_b": cfg.active_param_count() / 1e9}

    with mesh:
        if shape.kind == "train":
            import dataclasses as _dc
            # NOTE: seq_shard (Megatron-SP via sharding hints) is OFF by
            # default — measured as a REGRESSION here: GSPMD drops the
            # head sharding after the per-layer seq all-gather and
            # replicates attention (7x flops). See EXPERIMENTS.md SPerf.
            pctx_t = _dc.replace(
                pctx,
                seq_shard=bool((extra_flags or {}).get("seq_shard", False)),
                gather_weights=bool((extra_flags or {}).get(
                    "gather_weights", False)))
            pol = _train_policy(cfg, shape, pctx_t)
            if extra_flags:
                pol.update(extra_flags.get("train_policy", {}))
            opt_cfg = pol["opt_cfg"]
            opt = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params)
            oshard = _sharding_tree(opt_state_specs(opt, params, cfg, pctx),
                                    mesh)
            batch = input_specs(cfg, shape, model)
            bshard = _sharding_tree(batch_specs(cfg, shape, pctx), mesh)
            step = make_train_step(model, opt_cfg, pctx_t,
                                   microbatches=pol["microbatches"],
                                   accum_dtype=pol["accum_dtype"])
            fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params, opt, batch)
            meta["opt_quantized"] = opt_cfg.quantize_states
            meta["microbatches"] = pol["microbatches"]
            meta["accum_dtype"] = str(pol["accum_dtype"].__name__)
            meta["seq_shard"] = pctx_t.seq_shard
        elif shape.kind == "prefill":
            import dataclasses as _dc
            pctx_p = _dc.replace(
                pctx,
                seq_shard=bool((extra_flags or {}).get("seq_shard", False)),
                gather_weights=bool((extra_flags or {}).get(
                    "gather_weights", False)))
            batch = input_specs(cfg, shape, model)
            bshard = _sharding_tree(batch_specs(cfg, shape, pctx), mesh)

            def prefill(p, b):
                return model.prefill(p, b, pctx_p)

            fn = jax.jit(prefill, in_shardings=(pshard, bshard))
            lowered = fn.lower(params, batch)
        else:  # decode
            specs = input_specs(cfg, shape, model)
            cshard = _sharding_tree(
                cache_specs(specs["cache"], cfg, shape, pctx), mesh)
            bshard = _sharding_tree(batch_specs(cfg, shape, pctx), mesh)

            def decode(p, c, b):
                return model.decode_step(p, c, b, pctx)

            fn = jax.jit(decode, in_shardings=(pshard, cshard, bshard),
                         donate_argnums=(1,))
            lowered = fn.lower(params, specs["cache"], specs["batch"])
    return lowered, meta


def analyze(lowered, meta: dict, hlo_sink: dict | None = None) -> dict:
    from repro.roofline.analysis import roofline_from_compiled
    t0 = time.time()
    compiled = lowered.compile()
    if hlo_sink is not None:
        hlo_sink["hlo"] = compiled.as_text()
    meta["compile_s"] = round(time.time() - t0, 1)
    ma = compiled.memory_analysis()
    meta["memory"] = {
        "argument_gb": ma.argument_size_in_bytes / 2 ** 30,
        "output_gb": ma.output_size_in_bytes / 2 ** 30,
        "temp_gb": ma.temp_size_in_bytes / 2 ** 30,
        "alias_gb": ma.alias_size_in_bytes / 2 ** 30,
        "peak_gb": (ma.argument_size_in_bytes + ma.output_size_in_bytes +
                    ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2 ** 30,
    }
    meta.update(roofline_from_compiled(compiled, meta))
    return meta


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    lowered, meta = lower_cell(arch, shape_name, multi_pod)
    if lowered is None:
        return meta
    return analyze(lowered, meta)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS + ["exanest-lm-100m"])
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = ALL_ARCHS if args.all else [args.arch]
    shapes = list(SHAPES) if args.all else ([args.shape] if args.shape
                                            else list(SHAPES))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch in archs:
        for shp in shapes:
            for mp in meshes:
                cells.append((arch, shp, mp))

    for arch, shp, mp in cells:
        tag = f"{arch}__{shp}__{'multi' if mp else 'single'}"
        out_path = os.path.join(args.out, tag + ".json")
        if os.path.exists(out_path):
            print(f"[skip existing] {tag}")
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        try:
            res = run_cell(arch, shp, mp)
        except Exception as e:  # noqa: BLE001 — record the failure
            res = {"arch": arch, "shape": shp,
                   "mesh": "2x16x16" if mp else "16x16",
                   "error": f"{type(e).__name__}: {e}"}
            print(f"  FAILED: {res['error']}", file=sys.stderr)
        with open(out_path, "w") as f:
            json.dump(res, f, indent=1, default=str)
        print(f"  -> {out_path}")


if __name__ == "__main__":
    main()
