"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run driver
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
jax import and only then builds the mesh.

Axes: single-pod (16, 16) = ("data", "model"); multi-pod (2, 16, 16) =
("pod", "data", "model"). The "pod" axis is the slow/cross-pod dimension —
the target of the hierarchical (accelerator-style) collectives.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]
              ) -> jax.sharding.Mesh:
    """Arbitrary mesh for tests/examples (e.g. (2,2,2) on 8 host devices)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_parallel_ctx(mesh: jax.sharding.Mesh):
    from repro.parallel.ctx import ParallelCtx
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return ParallelCtx(mesh=mesh, dp_axes=dp, tp_axis="model")
