from repro.models.transformer import LM, SSMLM, HybridLM, EncDecLM, build_model

__all__ = ["LM", "SSMLM", "HybridLM", "EncDecLM", "build_model"]
