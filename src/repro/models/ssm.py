"""Mamba-2 SSD (state-space duality) block — arXiv:2405.21060.

Train/prefill uses the chunked dual form: quadratic attention-like math
inside each chunk (MXU-friendly (c x c) blocks) plus a linear recurrence
over chunk states. Decode is the O(1)-state recurrent update — the reason
mamba2/zamba2 run the ``long_500k`` shape that full-attention archs skip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models.layers import dense_init, dtype_of, rmsnorm_gated


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, nh, conv_ch


def init_mamba2(key, cfg: ArchConfig) -> dict:
    """The canonical fused ``in_proj`` is split into per-role projections
    (z/x/B/C/dt) — a pure column partition of the same matrix — so each
    output dim shards cleanly on the TP mesh axis instead of slicing across
    shard boundaries (see parallel/sharding.py)."""
    s, d_in, nh, conv_ch = _dims(cfg)
    dt = dtype_of(cfg)
    d = cfg.d_model
    gn = s.n_groups * s.d_state
    ks = jax.random.split(key, 9)
    return {
        "wz": dense_init(ks[0], (d, d_in), dt),
        "wx": dense_init(ks[1], (d, d_in), dt),
        "wB": dense_init(ks[2], (d, gn), dt),
        "wC": dense_init(ks[3], (d, gn), dt),
        "wdt": dense_init(ks[4], (d, nh), dt),
        "conv_x": dense_init(ks[5], (s.d_conv, d_in), dt, scale=0.3),
        "conv_B": dense_init(ks[6], (s.d_conv, gn), dt, scale=0.3),
        "conv_C": dense_init(ks[7], (s.d_conv, gn), dt, scale=0.3),
        "conv_bx": jnp.zeros((d_in,), dt),
        "conv_bB": jnp.zeros((gn,), dt),
        "conv_bC": jnp.zeros((gn,), dt),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[8], (d_in, d), dt),
    }


def _causal_conv(xBC, conv_w, conv_b, prev=None):
    """Depthwise causal conv along seq. xBC: (B,L,C); conv_w: (W,C).
    ``prev``: (B,W-1,C) left context (decode/streaming)."""
    W = conv_w.shape[0]
    if prev is None:
        prev = jnp.zeros(xBC.shape[:1] + (W - 1,) + xBC.shape[2:], xBC.dtype)
    xp = jnp.concatenate([prev, xBC], axis=1)
    out = sum(xp[:, i:i + xBC.shape[1]] * conv_w[i] for i in range(W))
    return jax.nn.silu(out + conv_b), xp[:, -(W - 1):]


def _project(p, x, cfg, conv_prev=None):
    """Input projections + causal depthwise convs on x/B/C (§4 of the
    Mamba-2 paper: conv on the xBC block). Returns (z, xi, B, C, dt_raw,
    conv_state)."""
    z = x @ p["wz"]
    xc = x @ p["wx"]
    Bc = x @ p["wB"]
    Cc = x @ p["wC"]
    dtr = x @ p["wdt"]
    prev = (None, None, None) if conv_prev is None else conv_prev
    xc, sx = _causal_conv(xc, p["conv_x"], p["conv_bx"], prev[0])
    Bc, sB = _causal_conv(Bc, p["conv_B"], p["conv_bB"], prev[1])
    Cc, sC = _causal_conv(Cc, p["conv_C"], p["conv_bC"], prev[2])
    return z, xc, Bc, Cc, dtr, (sx, sB, sC)


def _segsum(x):
    """x: (..., T) -> (..., T, T) with out[i, j] = sum_{k=j+1..i} x[k] for
    j <= i (0 on the diagonal), -inf above the diagonal."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, ss, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD dual form.

    x: (b,l,h,p) inputs; dt: (b,l,h) f32 (post-softplus); A: (h,) f32 (<0);
    B, C: (b,l,g,n). Returns (y: (b,l,h,p), final_state: (b,h,p,n))."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    l_orig = l
    if l % chunk != 0:
        # pad with dt=0 steps: dA=0 (no decay) and no input contribution,
        # so the final state is exact and padded outputs are dropped.
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l += pad
    nc = l // chunk
    rep = h // g

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    dA = dtc * A  # (b,nc,c,h)

    # within-chunk (quadratic) term
    L = jnp.exp(_segsum(jnp.moveaxis(dA, -1, -2)))        # (b,nc,h,c,c)
    CB = jnp.einsum("bzcgn,bzsgn->bzgcs", Cc, Bc,
                    preferred_element_type=jnp.float32)   # (b,nc,g,c,s)
    if rep > 1:
        CB = jnp.repeat(CB, rep, axis=2)                  # (b,nc,h,c,s)
    M = CB * jnp.where(jnp.isfinite(L), L, 0.0)
    xdt = xc.astype(jnp.float32) * dtc[..., None]
    y_diag = jnp.einsum("bzhcs,bzshp->bzchp", M.astype(x.dtype),
                        xdt.astype(x.dtype),
                        preferred_element_type=jnp.float32)

    # chunk states
    dA_cum = jnp.cumsum(dA, axis=2)                       # (b,nc,c,h)
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum) # (b,nc,c,h)
    states = jnp.einsum("bzcgn,bzch,bzchp->bzhpn",
                        Bc.astype(x.dtype), decay_states.astype(x.dtype),
                        xdt.astype(x.dtype),
                        preferred_element_type=jnp.float32)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])            # (b,nc,h)

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry  # emit the state *entering* the chunk

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)         # (b,nc,h,p,n)

    # contribution of the entering state to each position
    state_decay = jnp.exp(dA_cum)                         # (b,nc,c,h)
    y_off = jnp.einsum("bzcgn,bzch,bzhpn->bzchp",
                       Cc.astype(x.dtype), state_decay.astype(x.dtype),
                       prev_states.astype(x.dtype),
                       preferred_element_type=jnp.float32)
    y = (y_diag + y_off).reshape(b, l, h, p)
    return y[:, :l_orig], final


def mamba2_forward(p: dict, x: jnp.ndarray, cfg: ArchConfig
                   ) -> tuple[jnp.ndarray, dict]:
    """Full-sequence SSD. x: (B,L,d). Returns (y, state) where state =
    {conv: (B,W-1,C), ssm: (B,h,p,n)} for streaming continuation."""
    s, d_in, nh, conv_ch = _dims(cfg)
    z, xc, Bc, Cc, dtr, conv_state = _project(p, x, cfg)
    xi = xc.reshape(x.shape[0], x.shape[1], nh, s.head_dim)
    B_ = Bc.reshape(x.shape[0], x.shape[1], s.n_groups, s.d_state)
    C_ = Cc.reshape(x.shape[0], x.shape[1], s.n_groups, s.d_state)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, ssm_state = ssd_chunked(xi, dt, A, B_, C_, s.chunk)
    y = y + xi.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(x.shape[0], x.shape[1], d_in).astype(x.dtype)
    y = rmsnorm_gated(p["norm_scale"], y, z)
    return y @ p["out_proj"], {"conv": conv_state, "ssm": ssm_state}


def mamba2_decode(p: dict, x: jnp.ndarray, cfg: ArchConfig, state: dict
                  ) -> tuple[jnp.ndarray, dict]:
    """Single-token recurrent update. x: (B,1,d)."""
    s, d_in, nh, conv_ch = _dims(cfg)
    B1 = x.shape[0]
    z, xc, Bc, Cc, dtr, conv_state = _project(p, x, cfg,
                                              conv_prev=state["conv"])
    xi = xc.reshape(B1, nh, s.head_dim)
    B_ = Bc.reshape(B1, s.n_groups, s.d_state)
    C_ = Cc.reshape(B1, s.n_groups, s.d_state)
    rep = nh // s.n_groups
    B_ = jnp.repeat(B_, rep, axis=1)   # (B,h,n)
    C_ = jnp.repeat(C_, rep, axis=1)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,h)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                   # (B,h)
    xdt = xi.astype(jnp.float32) * dt[..., None]           # (B,h,p)
    new_state = state["ssm"] * dA[..., None, None] + \
        jnp.einsum("bhn,bhp->bhpn", B_.astype(jnp.float32), xdt)
    y = jnp.einsum("bhn,bhpn->bhp", C_.astype(jnp.float32), new_state)
    y = y + xi.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(B1, 1, d_in).astype(x.dtype)
    y = rmsnorm_gated(p["norm_scale"], y, z)
    return y @ p["out_proj"], {"conv": conv_state, "ssm": new_state}
