"""Attention: GQA (+RoPE) and DeepSeek MLA, with chunked-flash lowering.

Three execution paths:

* ``flash_attention`` — double-chunked online-softmax attention in pure jnp
  (``lax.scan`` over query and KV chunks). This is the default lowering used
  by the dry-run: memory is bounded by chunk size, so 32k-token prefill
  compiles without an S x S score tensor. The Pallas TPU kernel in
  ``repro.kernels.flash_decode`` is the hot-spot implementation for decode.
* decode attention — one new token against a (possibly sharded) KV cache:
  plain einsums over the cache; XLA turns the softmax reductions over a
  sharded sequence axis into the matching collectives.
* MLA — latent-compressed attention (arXiv:2412.19437): expanded form for
  train/prefill, *absorbed* form for decode so the cache stays in the
  compressed (kv_lora + rope) layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models.layers import apply_norm, apply_rope, dense_init, dtype_of, init_norm

NEG_INF = -1e30


# ---------------------------------------------------------------- flash (jnp)
def flash_attention(q, k, v, *, causal: bool = True, q_chunk: int = 1024,
                    kv_chunk: int = 1024) -> jnp.ndarray:
    """q: (B,Sq,H,dk); k: (B,Skv,K,dk); v: (B,Skv,K,dv); H % K == 0.

    Double-chunked online-softmax attention with a FlashAttention-style
    custom VJP: forward saves only (q, k, v, out, lse); backward recomputes
    probabilities blockwise — without this, scan residuals make a 32k
    backward cost hundreds of GB of activations."""
    B, Sq, H, dk = q.shape
    _, Skv, K, dv = v.shape
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Skv)
    # pad ragged sequence lengths up to chunk multiples (padded keys are
    # masked out; padded query rows are dropped at the end)
    Sq_p = -(-Sq // qc) * qc
    Skv_p = -(-Skv // kc) * kc
    kv_valid = Skv
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    if Skv_p != Skv:
        k = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
    out = _flash(q, k, v, causal, qc, kc, kv_valid)
    return out[:, :Sq]


def _block_mask(s, i, j, qc, kc, causal, kv_valid):
    kpos = j * kc + jnp.arange(kc)
    if causal:
        qpos = i * qc + jnp.arange(qc)
        mask = (qpos[:, None] >= kpos[None, :]) & (kpos[None, :] < kv_valid)
        return jnp.where(mask[None, None, None], s, NEG_INF)
    return jnp.where((kpos < kv_valid)[None, None, None, None], s, NEG_INF)


def _flash_fwd_impl(q, k, v, causal, qc, kc, kv_valid):
    B, Sq, H, dk = q.shape
    _, Skv, K, dv = v.shape
    rep = H // K
    nq, nk = Sq // qc, Skv // kc
    scale = dk ** -0.5
    qr = jnp.moveaxis(q.reshape(B, nq, qc, K, rep, dk), 1, 0)
    kr = jnp.moveaxis(k.reshape(B, nk, kc, K, dk), 1, 0)
    vr = jnp.moveaxis(v.reshape(B, nk, kc, K, dv), 1, 0)

    def q_step(_, qi):
        q_i, i = qi

        def kv_step(carry, kj):
            m, l, acc = carry
            k_j, v_j, j = kj
            # bf16 operands into the MXU, f32 accumulation (TPU-native)
            s = jnp.einsum("bqgrh,bkgh->bgrqk", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            s = _block_mask(s, i, j, qc, kc, causal, kv_valid)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bgrqk,bkgd->bgrqd", p.astype(q_i.dtype), v_j,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, rep, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, rep, qc), jnp.float32)
        a0 = jnp.zeros((B, K, rep, qc, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (kr, vr, jnp.arange(nk)))
        out_i = acc / jnp.maximum(l, 1e-30)[..., None]
        lse_i = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out_i.astype(q.dtype), lse_i)

    _, (outs, lses) = jax.lax.scan(q_step, None, (qr, jnp.arange(nq)))
    # outs: (nq, B, K, rep, qc, dv) -> (B, Sq, H, dv)
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 4, 2, 3, 5)
    out = out.reshape(B, Sq, H, dv)
    # lse: (nq, B, K, rep, qc) -> (B, K, rep, Sq)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, K, rep, Sq)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, qc, kc, kv_valid):
    return _flash_fwd_impl(q, k, v, causal, qc, kc, kv_valid)[0]


def _flash_fwd(q, k, v, causal, qc, kc, kv_valid):
    out, lse = _flash_fwd_impl(q, k, v, causal, qc, kc, kv_valid)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, qc, kc, kv_valid, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, dk = q.shape
    _, Skv, K, dv = v.shape
    rep = H // K
    nq, nk = Sq // qc, Skv // kc
    scale = dk ** -0.5
    # D = rowsum(dout * out): (B, K, rep, Sq)
    D = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    D = jnp.moveaxis(D.reshape(B, Sq, K, rep), 1, 3)         # (B,K,rep,Sq)
    qr = jnp.moveaxis(q.reshape(B, nq, qc, K, rep, dk), 1, 0)
    dor = jnp.moveaxis(dout.reshape(B, nq, qc, K, rep, dv), 1, 0)
    Dr = jnp.moveaxis(D.reshape(B, K, rep, nq, qc), 3, 0)    # (nq,B,K,rep,qc)
    lser = jnp.moveaxis(lse.reshape(B, K, rep, nq, qc), 3, 0)
    kr = jnp.moveaxis(k.reshape(B, nk, kc, K, dk), 1, 0)
    vr = jnp.moveaxis(v.reshape(B, nk, kc, K, dv), 1, 0)

    def kv_step(dq_acc, kj):
        k_j, v_j, j = kj

        def q_step(carry, qi):
            dk_j, dv_j = carry
            q_i, do_i, D_i, lse_i, i = qi
            s = jnp.einsum("bqgrh,bkgh->bgrqk", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            s = _block_mask(s, i, j, qc, kc, causal, kv_valid)
            p = jnp.exp(s - lse_i[..., None])                # (B,K,rep,qc,kc)
            p_c = p.astype(q_i.dtype)
            dv_j += jnp.einsum("bgrqk,bqgrd->bkgd", p_c, do_i,
                               preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqgrd,bkgd->bgrqk", do_i, v_j,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - D_i[..., None]) * scale
            ds_c = ds.astype(q_i.dtype)
            dk_j += jnp.einsum("bgrqk,bqgrh->bkgh", ds_c, q_i,
                               preferred_element_type=jnp.float32)
            dq_i = jnp.einsum("bgrqk,bkgh->bqgrh", ds_c, k_j,
                              preferred_element_type=jnp.float32)
            return (dk_j, dv_j), dq_i

        zk = jnp.zeros((B, kc, K, dk), jnp.float32)
        zv = jnp.zeros((B, kc, K, dv), jnp.float32)
        (dk_j, dv_j), dq_parts = jax.lax.scan(
            q_step, (zk, zv), (qr, dor, Dr, lser, jnp.arange(nq)))
        return dq_acc + dq_parts, (dk_j, dv_j)

    dq0 = jnp.zeros((nq, B, qc, K, rep, dk), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_step, dq0,
                                  (kr, vr, jnp.arange(nk)))
    dq = jnp.moveaxis(dq, 0, 1).reshape(B, Sq, H, dk).astype(q.dtype)
    dk_ = jnp.moveaxis(dks, 0, 1).reshape(B, Skv, K, dk).astype(k.dtype)
    dv_ = jnp.moveaxis(dvs, 0, 1).reshape(B, Skv, K, dv).astype(v.dtype)
    return dq, dk_, dv_


_flash.defvjp(_flash_fwd, _flash_bwd)


def _pos_vec(pos, B):
    """Normalize a scalar or (B,) position into a (B,) int32 vector."""
    p = jnp.asarray(pos, jnp.int32)
    return jnp.broadcast_to(jnp.atleast_1d(p), (B,))


def decode_attention(q, k_cache, v_cache, pos) -> jnp.ndarray:
    """q: (B,1,H,dk); caches: (B,S,K,d*); attend to positions <= pos
    (scalar or per-row vector — continuous batching uses per-slot pos)."""
    B, _, H, dk = q.shape
    _, S, K, dv = v_cache.shape
    rep = H // K
    qg = q.reshape(B, K, rep, dk).astype(jnp.float32)
    s = jnp.einsum("bgrh,bkgh->bgrk", qg, k_cache.astype(jnp.float32))
    s = s * (dk ** -0.5)
    mask = jnp.arange(S)[None, :] <= _pos_vec(pos, B)[:, None]  # (B,S)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrk,bkgd->bgrd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, dv).astype(q.dtype)



def _qkv_hint(t, pctx):
    """Constrain (B, S, H, hd) attention tensors: batch over DP, heads over
    TP (when divisible). Like the residual-stream hint, this pins the
    sharding of the flash scan xs/carries — without it GSPMD replicates the
    head dim inside the while loops (measured: no win from head padding
    until this hint exists)."""
    if pctx is None:
        return t
    import math
    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = math.prod(pctx.mesh.shape[a] for a in pctx.dp_axes)
    b_ax = pctx.dp_axes if t.shape[0] % dp == 0 else None
    tp_n = pctx.mesh.shape[pctx.tp_axis]
    h_ax = pctx.tp_axis if t.shape[2] % tp_n == 0 else None
    return jax.lax.with_sharding_constraint(
        t, NamedSharding(pctx.mesh, P(b_ax, None, h_ax, None)))


# ------------------------------------------------------------------------ GQA
def _padded_heads(cfg: ArchConfig) -> int:
    if cfg.pad_heads_to is not None and cfg.pad_heads_to > cfg.n_heads:
        return cfg.pad_heads_to
    return cfg.n_heads


def init_gqa(key, cfg: ArchConfig, d: int) -> dict:
    dt = dtype_of(cfg)
    hd = cfg.resolved_head_dim
    Hp = _padded_heads(cfg)
    ks = jax.random.split(key, 4)
    wq = dense_init(ks[0], (d, Hp, hd), dt, scale=d ** -0.5)
    wo = dense_init(ks[3], (Hp, hd, d), dt, scale=(Hp * hd) ** -0.5)
    if Hp != cfg.n_heads:
        # zero the padding heads; gqa outputs are additionally head-masked,
        # so training keeps them at zero (bit-exact vs the unpadded arch)
        wq = wq.at[:, cfg.n_heads:, :].set(0)
        wo = wo.at[cfg.n_heads:].set(0)
    p = {
        "wq": wq,
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads, hd), dt, scale=d ** -0.5),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads, hd), dt, scale=d ** -0.5),
        "wo": wo,
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((Hp, hd), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), dt)
    return p


def _head_mask(cfg: ArchConfig, out):
    Hp = _padded_heads(cfg)
    if Hp == cfg.n_heads:
        return out
    mask = (jnp.arange(Hp) < cfg.n_heads).astype(out.dtype)
    return out * mask[None, None, :, None]


def gqa_qkv(p: dict, x: jnp.ndarray, cfg: ArchConfig, positions) -> tuple:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.attn_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attention(p: dict, x: jnp.ndarray, cfg: ArchConfig, *, positions,
                  causal: bool = True, pctx=None) -> tuple[jnp.ndarray, dict]:
    """Full-sequence (train/prefill) GQA. Returns (out, cache).

    When q heads shard over TP but kv heads do not, flash's (K, rep) head
    grouping would break the sharding (e.g. 48 -> (4,12) has no 16-way
    split): repeat KV to MHA so every head tensor shards cleanly — the
    repeated-KV bytes are TP-sharded, so per-chip KV actually shrinks."""
    tp = pctx.mesh.shape[pctx.tp_axis] if pctx is not None else 1
    q, k, v = gqa_qkv(p, x, cfg, positions)
    Hp = q.shape[2]
    K = k.shape[2]
    mha_ize = (Hp % K != 0) or (tp > 1 and Hp % tp == 0 and K % tp != 0)
    if mha_ize:
        k = jnp.repeat(k, -(-Hp // K), axis=2)[:, :, :Hp]
        v = jnp.repeat(v, -(-Hp // K), axis=2)[:, :, :Hp]
    q, k, v = (_qkv_hint(t, pctx) for t in (q, k, v))
    out = flash_attention(q, k, v, causal=causal,
                          q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    out = _head_mask(cfg, out)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, {"k": k, "v": v}


def gqa_decode(p: dict, x: jnp.ndarray, cfg: ArchConfig, cache: dict,
               pos) -> tuple[jnp.ndarray, dict]:
    """x: (B,1,d); cache k/v: (B,S,K,hd); writes the new KV at ``pos``
    (scalar or per-row (B,) for slot-based continuous batching)."""
    B = x.shape[0]
    pos_b = _pos_vec(pos, B)
    q, k_new, v_new = gqa_qkv(p, x, cfg, pos_b[:, None])
    rows = jnp.arange(B)
    k = cache["k"].at[rows, pos_b].set(k_new[:, 0].astype(cache["k"].dtype))
    v = cache["v"].at[rows, pos_b].set(v_new[:, 0].astype(cache["v"].dtype))
    Hp = q.shape[2]
    ka, va = k, v
    if Hp % k.shape[2] != 0:
        ka = jnp.repeat(k, -(-Hp // k.shape[2]), axis=2)[:, :, :Hp]
        va = jnp.repeat(v, -(-Hp // v.shape[2]), axis=2)[:, :, :Hp]
    out = decode_attention(q, ka, va, pos_b)
    out = _head_mask(cfg, out)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, {"k": k, "v": v}


# ------------------------------------------------------- cross attention (whisper)
def init_cross_attention(key, cfg: ArchConfig, d: int) -> dict:
    return init_gqa(key, cfg, d)


def cross_attention(p: dict, x: jnp.ndarray, cfg: ArchConfig,
                    kv: tuple[jnp.ndarray, jnp.ndarray]) -> jnp.ndarray:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.attn_bias:
        q = q + p["bq"]
    k, v = kv
    out = flash_attention(q, k, v, causal=False,
                          q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_kv(p: dict, enc: jnp.ndarray, cfg: ArchConfig) -> tuple:
    k = jnp.einsum("bsd,dhk->bshk", enc, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc, p["wv"])
    if cfg.attn_bias:
        k, v = k + p["bk"], v + p["bv"]
    return k, v


# ------------------------------------------------------------------------ MLA
def init_mla(key, cfg: ArchConfig, d: int) -> dict:
    m = cfg.mla
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 6)
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), dt),
        "q_norm": init_norm(cfg, m.q_lora_rank),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, cfg.n_heads, qk), dt),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dt),
        "kv_norm": init_norm(cfg, m.kv_lora_rank),
        "wkv_b": dense_init(ks[3], (m.kv_lora_rank, cfg.n_heads,
                                    m.qk_nope_head_dim + m.v_head_dim), dt),
        "wo": dense_init(ks[4], (cfg.n_heads, m.v_head_dim, d), dt,
                         scale=(cfg.n_heads * m.v_head_dim) ** -0.5),
    }


def _mla_q(p, x, cfg, positions):
    m = cfg.mla
    q_lat = apply_norm(p["q_norm"], x @ p["wq_a"], cfg)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["wq_b"])
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, x, cfg, positions):
    m = cfg.mla
    kv = x @ p["wkv_a"]
    c_kv = apply_norm(p["kv_norm"], kv[..., :m.kv_lora_rank], cfg)
    k_rope = apply_rope(kv[..., None, m.kv_lora_rank:], positions,
                        cfg.rope_theta)  # (B,S,1,rope)
    return c_kv, k_rope


def mla_attention(p: dict, x: jnp.ndarray, cfg: ArchConfig, *,
                  positions, pctx=None) -> tuple[jnp.ndarray, dict]:
    """Expanded-form MLA for train/prefill; cache stays compressed."""
    m = cfg.mla
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_latent(p, x, cfg, positions)
    kv = jnp.einsum("bsr,rhk->bshk", c_kv, p["wkv_b"])
    k_nope = kv[..., :m.qk_nope_head_dim]
    v = kv[..., m.qk_nope_head_dim:]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, k_nope.shape[:3] +
                                          (m.qk_rope_head_dim,))], axis=-1)
    q, k, v = (_qkv_hint(t, pctx) for t in (q, k, v))
    out = flash_attention(q, k, v, causal=True, q_chunk=cfg.q_chunk,
                          kv_chunk=cfg.kv_chunk)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, {"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}


def mla_decode(p: dict, x: jnp.ndarray, cfg: ArchConfig, cache: dict,
               pos) -> tuple[jnp.ndarray, dict]:
    """Absorbed-form decode: the per-token cache is (kv_lora + rope) wide,
    so a 32k cache is ~576 values/token instead of H*(192+128)."""
    m = cfg.mla
    B = x.shape[0]
    pos_b = _pos_vec(pos, B)
    positions = pos_b[:, None]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)          # (B,1,H,*)
    c_new, kr_new = _mla_latent(p, x, cfg, positions)
    rows = jnp.arange(B)
    c_kv = cache["c_kv"].at[rows, pos_b].set(
        c_new[:, 0].astype(cache["c_kv"].dtype))
    k_rope = cache["k_rope"].at[rows, pos_b].set(
        kr_new[:, 0, 0, :].astype(cache["k_rope"].dtype))
    w_uk = p["wkv_b"][..., :m.qk_nope_head_dim]            # (r,H,nope)
    w_uv = p["wkv_b"][..., m.qk_nope_head_dim:]            # (r,H,v)
    # absorb W_UK into q: q_c (B,H,r)
    q_c = jnp.einsum("bshk,rhk->bhr", q_nope.astype(jnp.float32),
                     w_uk.astype(jnp.float32))
    s = jnp.einsum("bhr,bkr->bhk", q_c, c_kv.astype(jnp.float32))
    s += jnp.einsum("bshk,bmk->bhm", q_rope.astype(jnp.float32),
                    k_rope.astype(jnp.float32))
    s *= (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    mask = jnp.arange(c_kv.shape[1])[None, :] <= pos_b[:, None]
    s = jnp.where(mask[:, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhk,bkr->bhr", pr, c_kv.astype(jnp.float32))
    o = jnp.einsum("bhr,rhv->bhv", o_c, w_uv.astype(jnp.float32))
    out = jnp.einsum("bhv,hvd->bd", o.astype(x.dtype), p["wo"])[:, None, :]
    return out, {"c_kv": c_kv, "k_rope": k_rope}
