"""Mixture-of-Experts FFN with expert-parallel (EP) dispatch.

The paper's interconnect exists to make exactly this pattern cheap: many
endpoints exchanging medium-size messages over a torus (§4.1-4.4). On TPU we
express the dispatch as an ``all_to_all`` inside ``shard_map`` — the ExaNet
analog of RDMA'ing token blocks between QFDBs.

Axis layout (matches tokens being DP-sharded over ``data``):
* **EP over `data`**: experts are sharded along the same axis that shards
  tokens, so each shard dispatches only ITS tokens (no duplicated compute),
  via all_to_all over `data` (within each pod replica group);
* **TP over `model`**: each expert's FFN hidden dim is column-sharded; one
  psum over `model` combines the partial w_out contraction at the end.

Two-level capacity buffers keep every shape static:
1. route: top-k over a replicated router;
2. pack per-destination-shard capacity buffers (scatter by running index);
3. ``all_to_all`` tokens + metadata to expert shards;
4. pack again into per-local-expert buffers; batched expert GEMMs
   (E_local, C, d) x (E_local, d, f_shard) — MXU-shaped, no one-hot
   dispatch einsum (for 256 experts that would dwarf the expert FLOPs);
5. ``all_to_all`` back, combine with routing weights, psum the TP partials.

Tokens that overflow a capacity buffer are dropped (classic capacity-factor
semantics); ``capacity_factor`` controls the overhead.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ArchConfig
from repro.models.layers import dense_init, dtype_of


def init_moe(key, cfg: ArchConfig, d: int) -> dict:
    m = cfg.moe
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, m.n_experts), jnp.float32, scale=0.02),
        "w_gate": dense_init(ks[1], (m.n_experts, d, m.d_expert), dt,
                             scale=d ** -0.5),
        "w_up": dense_init(ks[2], (m.n_experts, d, m.d_expert), dt,
                           scale=d ** -0.5),
        "w_out": dense_init(ks[3], (m.n_experts, m.d_expert, d), dt,
                            scale=m.d_expert ** -0.5),
    }
    if m.n_shared_experts:
        ff = m.d_shared * m.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kk[0], (d, ff), dt),
            "w_up": dense_init(kk[1], (d, ff), dt),
            "w_out": dense_init(kk[2], (ff, d), dt),
        }
    return p



@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _qa2a(x, axis):
    """int8-quantized all_to_all (per-slot scales) with a quantized adjoint:
    both the dispatch and its gradient cross the wire in int8 + f32 scales
    (DeepSeek-V3 fp8-dispatch analog). x: (ep, cap, d)."""
    return _qa2a_fwd(x, axis)[0]


def _qa2a_impl(x, axis):
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                                keepdims=True) / 127.0, 1e-20)
    q8 = jnp.round(x.astype(jnp.float32) / scale).astype(jnp.int8)
    q8 = jax.lax.all_to_all(q8, axis, 0, 0, tiled=False)
    q8 = q8.reshape(x.shape)
    s = jax.lax.all_to_all(scale.astype(jnp.float32), axis, 0, 0,
                           tiled=False).reshape(x.shape[:-1] + (1,))
    return (q8.astype(x.dtype) * s.astype(x.dtype)).astype(x.dtype)


def _qa2a_fwd(x, axis):
    return _qa2a_impl(x, axis), None


def _qa2a_bwd(axis, _, g):
    return (_qa2a_impl(g, axis).astype(g.dtype),)


_qa2a.defvjp(_qa2a_fwd, _qa2a_bwd)


def _pack(dest, n_dest, capacity, payload):
    """Scatter ``payload`` rows into (n_dest, capacity, ...) buffers by
    running index within each destination. Returns (buffers, pos, valid)."""
    oh = jax.nn.one_hot(dest, n_dest, dtype=jnp.int32)        # (T, D)
    pos = (jnp.cumsum(oh, axis=0) - oh)[jnp.arange(dest.shape[0]), dest]
    valid = pos < capacity
    pos_c = jnp.where(valid, pos, capacity - 1)
    buf = jnp.zeros((n_dest, capacity) + payload.shape[1:], payload.dtype)
    upd = jnp.where(valid[:, None] if payload.ndim == 2 else valid,
                    payload, 0).astype(payload.dtype)
    buf = buf.at[dest, pos_c].add(upd, mode="drop")
    return buf, pos, valid


def _expert_ffn(w_gate, w_up, w_out, x, cfg: ArchConfig):
    """x: (E_l, C, d) -> (E_l, C, d) batched over local experts; the hidden
    dim may be a TP shard (partial contributions combined by the caller)."""
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    g = jnp.einsum("ecd,edf->ecf", x, w_gate)
    u = jnp.einsum("ecd,edf->ecf", x, w_up)
    return jnp.einsum("ecf,efd->ecd", act(g) * u, w_out)


def _moe_body(x, router_w, w_gate, w_up, w_out, cfg: ArchConfig,
              ep_axis: str | None, tp_axis: str | None):
    """Local view: x (T_l, d) — this shard's tokens; expert weights are the
    LOCAL (E_l, d, f_l) shard. ep_axis=None means all experts local."""
    m = cfg.moe
    T, d = x.shape
    E_l = w_gate.shape[0]
    ep = m.n_experts // E_l
    k = m.top_k

    logits = (x @ router_w.astype(x.dtype)).astype(jnp.float32)  # (T, E)
    vals, ids = jax.lax.top_k(logits, k)                         # (T, k)
    if m.router_softmax:
        w = jax.nn.softmax(vals, axis=-1)
    else:
        w = jax.nn.sigmoid(vals)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)

    flat_ids = ids.reshape(-1)                                   # (T*k,)
    flat_src = jnp.repeat(jnp.arange(T), k)
    dest_shard = flat_ids // E_l
    cap_send = max(1, math.ceil(T * k / ep * m.capacity_factor))
    payload = jnp.take(x, flat_src, axis=0)                      # (T*k, d)
    send, pos_send, valid_send = _pack(dest_shard, ep, cap_send, payload)
    meta = flat_ids % E_l                                        # local expert id
    send_meta, _, _ = _pack(dest_shard, ep, cap_send,
                            jnp.where(valid_send, meta + 1, 0))  # 0 == empty

    if ep_axis is not None and ep > 1:
        if m.a2a_quant:
            # int8 dispatch with per-slot scales (DeepSeek-V3 fp8-dispatch
            # analog): ~2x less wire bytes both ways; the custom VJP keeps
            # the gradient's all_to_all (round() alone would zero it out)
            send = _qa2a(send, ep_axis)
        else:
            send = jax.lax.all_to_all(send, ep_axis, 0, 0, tiled=False)
            send = send.reshape((ep, cap_send, d))
        send_meta = jax.lax.all_to_all(send_meta, ep_axis, 0, 0, tiled=False)
        send_meta = send_meta.reshape((ep, cap_send))

    # destination side: group received slots by local expert; empty wire
    # slots go to a trash bucket so they never consume expert capacity
    recv = send.reshape(ep * cap_send, d)
    recv_meta = send_meta.reshape(ep * cap_send)
    has_tok = recv_meta > 0
    local_e = jnp.where(has_tok, recv_meta - 1, E_l)
    # per-LOCAL-expert capacity: each local expert receives its global load
    # (T_local * ep sources * k / E experts == T_local * k / E_local)
    cap_e = max(1, math.ceil(T * k / E_l
                             * m.capacity_factor * m.capacity_factor))
    ebuf, pos_e, valid_e = _pack(local_e, E_l + 1, cap_e,
                                 jnp.where(has_tok[:, None], recv, 0))
    valid_e = valid_e & has_tok
    y_e = _expert_ffn(w_gate, w_up, w_out, ebuf[:E_l].astype(x.dtype), cfg)
    # un-pack back into the (ep, cap_send) wire layout
    flat_pos = jnp.where(valid_e, local_e * cap_e + pos_e, E_l * cap_e)
    back = jnp.take(y_e.reshape(E_l * cap_e, d), flat_pos, axis=0,
                    mode="fill", fill_value=0)
    back = back.reshape(ep, cap_send, d)

    if ep_axis is not None and ep > 1:
        if m.a2a_quant:
            back = _qa2a(back.astype(x.dtype), ep_axis)
        else:
            back = jax.lax.all_to_all(back, ep_axis, 0, 0, tiled=False)
            back = back.reshape((ep, cap_send, d))

    # combine at the source: gather each (t, j) contribution
    flat_idx = dest_shard * cap_send + jnp.where(valid_send, pos_send, 0)
    contrib = jnp.take(back.reshape(ep * cap_send, d), flat_idx, axis=0)
    contrib = jnp.where(valid_send[:, None], contrib, 0)
    contrib = contrib * w.reshape(-1)[:, None].astype(contrib.dtype)
    y = jax.ops.segment_sum(contrib, flat_src, num_segments=T)
    if tp_axis is not None:
        # combine the TP-partial w_out contractions
        y = jax.lax.psum(y, tp_axis)
    return y.astype(x.dtype)


def apply_moe(p: dict, x: jnp.ndarray, cfg: ArchConfig, pctx=None) -> jnp.ndarray:
    """x: (B, S, d). With a ParallelCtx: EP over 'data', TP over 'model'."""
    m = cfg.moe
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    ep_axis = "data"
    use_ep = (pctx is not None and ep_axis in pctx.mesh.axis_names
              and pctx.mesh.shape[ep_axis] > 1
              and m.n_experts % pctx.mesh.shape[ep_axis] == 0
              and (B * S) % pctx.dp_size == 0)
    if use_ep:
        tp_axis = (pctx.tp_axis if m.d_expert % pctx.tp_size == 0
                   and pctx.tp_size > 1 else None)
        f_spec = tp_axis
        body = functools.partial(_moe_body, cfg=cfg, ep_axis=ep_axis,
                                 tp_axis=tp_axis)
        fn = jax.shard_map(
            body, mesh=pctx.mesh,
            in_specs=(P(pctx.dp_axes, None), P(None, None),
                      P(ep_axis, None, f_spec), P(ep_axis, None, f_spec),
                      P(ep_axis, f_spec, None)),
            out_specs=P(pctx.dp_axes, None))
        y = fn(xt, p["router"], p["w_gate"], p["w_up"], p["w_out"])
    else:
        y = _moe_body(xt, p["router"], p["w_gate"], p["w_up"], p["w_out"],
                      cfg, None, None)
    y = y.reshape(B, S, d)
    if m.n_shared_experts:
        sh = p["shared"]
        act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
        y = y + (act(x @ sh["w_gate"]) * (x @ sh["w_up"])) @ sh["w_out"]
    return y
