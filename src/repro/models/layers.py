"""Core layers: norms, RoPE, MLPs, embeddings — pure functions over pytrees.

Parameters are nested dicts of ``jnp.ndarray``; init functions mirror apply
functions. Everything is ``jax.eval_shape``-safe so the dry-run can build
full-size parameter ShapeDtypeStructs without allocation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ArchConfig


def dtype_of(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ----------------------------------------------------------------- init utils
def dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------- norms
def init_norm(cfg: ArchConfig, d: int) -> dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p: dict, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * p["scale"] + p["bias"]
    else:
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"]
    return y.astype(x.dtype)


def rmsnorm_gated(scale: jnp.ndarray, x: jnp.ndarray, gate: jnp.ndarray) -> jnp.ndarray:
    """Mamba-2 gated RMSNorm: norm(x * silu(gate)) * scale."""
    xf = (x * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)).astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + 1e-6) * scale).astype(x.dtype)


# ----------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------------ MLP
def init_mlp(key, cfg: ArchConfig, d: int, ff: int) -> dict:
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    p = {"w_out": dense_init(ks[2], (ff, d), dt)}
    if cfg.mlp_gated:
        p["w_gate"] = dense_init(ks[0], (d, ff), dt)
        p["w_up"] = dense_init(ks[1], (d, ff), dt)
    else:
        p["w_up"] = dense_init(ks[1], (d, ff), dt)
    if cfg.mlp_bias:
        p["b_up"] = jnp.zeros((ff,), dt)
        p["b_out"] = jnp.zeros((d,), dt)
    return p


def apply_mlp(p: dict, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
    up = x @ p["w_up"]
    if cfg.mlp_bias:
        up = up + p["b_up"]
    if cfg.mlp_gated:
        h = act(x @ p["w_gate"]) * up
    else:
        h = act(up)
    out = h @ p["w_out"]
    if cfg.mlp_bias:
        out = out + p["b_out"]
    return out


# ----------------------------------------------------------------- embeddings
def init_embedding(key, cfg: ArchConfig) -> dict:
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    p = {"tokens": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), dt, scale=0.02)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dt)
    if cfg.pos_embedding == "learned":
        # sized generously so the assigned decode shapes lower mechanically
        p["positions"] = dense_init(ks[2], (32768 + 8, cfg.d_model), dt, scale=0.02)
    return p


def embed_tokens(p: dict, tokens: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    return jnp.take(p["tokens"], tokens, axis=0)


def logits(p: dict, h: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    w = p["tokens"].T if cfg.tie_embeddings else p["head"]
    return (h @ w).astype(jnp.float32)


def softmax_xent(logits_: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Stable cross entropy; logits (..., V) f32, labels int (...)."""
    m = jnp.max(logits_, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits_ - m), axis=-1))
    gold = jnp.take_along_axis(logits_, labels[..., None], axis=-1)[..., 0]
    return lse - gold


def lm_loss(p: dict, h: jnp.ndarray, targets: jnp.ndarray, cfg: ArchConfig,
            *, chunk: int = 1024) -> jnp.ndarray:
    """Mean next-token cross entropy with the head projection fused inside a
    sequence-chunk scan, so the (B, S, V) logits tensor is never
    materialized (V up to 256k at S=4096 would be ~GBs of f32 per device).

    h: (B, T, d) hidden states aligned with ``targets`` (B, T) — caller has
    already applied the shift. Pads T to a chunk multiple internally.
    """
    w = p["tokens"].T if cfg.tie_embeddings else p["head"]
    B, T, d = h.shape
    # adaptive chunk: cap the transient (B, c, V) f32 logits at ~1 GB
    # (matters for replicated vocabs: 151k x f32 x B is ~10 GB at c=1024)
    c = max(64, min(chunk, (1 << 30) // max(1, cfg.vocab_size * 4 * B)))
    c = min(c, T)
    Tp = -(-T // c) * c
    if Tp != T:
        h = jnp.pad(h, ((0, 0), (0, Tp - T), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, Tp - T)),
                          constant_values=-1)
    hr = jnp.moveaxis(h.reshape(B, Tp // c, c, d), 1, 0)
    tr = jnp.moveaxis(targets.reshape(B, Tp // c, c), 1, 0)

    @jax.checkpoint
    def step(carry, xs):
        hc, tc = xs
        lg = (hc @ w).astype(jnp.float32)
        valid = tc >= 0
        ls = softmax_xent(lg, jnp.maximum(tc, 0))
        return (carry[0] + jnp.sum(jnp.where(valid, ls, 0.0)),
                carry[1] + jnp.sum(valid)), None

    (tot, n), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros((), jnp.int32)),
                               (hr, tr))
    return tot / jnp.maximum(n, 1)
