"""Unified model definitions for the 10 assigned architectures.

Families:
* ``LM``        — decoder-only dense / MoE / VLM (stub patch frontend);
                  optional MLA attention and DeepSeek-V3 MTP head.
* ``SSMLM``     — Mamba-2 (attention-free).
* ``HybridLM``  — Zamba2-style: Mamba-2 stack with a single *shared*
                  attention+MLP block applied every ``hybrid_attn_every``
                  layers (one weight copy, several KV caches).
* ``EncDecLM``  — Whisper-style encoder-decoder with a stub conv frontend
                  (``frames`` arrive as precomputed embeddings per the
                  assignment spec).

All stacks scan over stacked layer parameters (compact HLO at 61+ layers)
with ``jax.checkpoint`` on the block body (remat).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (apply_mlp, apply_norm, dtype_of,
                                 embed_tokens, init_embedding, init_mlp,
                                 init_norm, lm_loss, logits, softmax_xent)


def _hint(x, pctx):
    """Sharding constraint on residual-stream activations (B, S, d).

    * batch over the DP axes — keeps scan/while carries DP-sharded (without
      it GSPMD may replicate the batch inside loop bodies: observed 16x
      flops/memory on the 16x16 mesh);
    * optionally (pctx.seq_shard) sequence over the TP axis — Megatron-style
      sequence parallelism: remat-saved layer inputs shrink by the TP size
      at the cost of one all-gather per layer entry."""
    if pctx is None:
        return x
    import math
    dp = math.prod(pctx.mesh.shape[a] for a in pctx.dp_axes)
    if x.shape[0] % dp != 0:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    seq_ax = None
    if (pctx.seq_shard and x.ndim >= 3
            and x.shape[1] % pctx.mesh.shape[pctx.tp_axis] == 0
            and x.shape[1] > 1):
        seq_ax = pctx.tp_axis
    spec = P(pctx.dp_axes, seq_ax, *([None] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(pctx.mesh, spec))


# ------------------------------------------------------------------ blocks
def init_block(key, cfg: ArchConfig, kind: str) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict = {}
    if kind in ("dense", "moe", "encoder", "decoder"):
        p["ln1"] = init_norm(cfg, d)
        p["attn"] = (attn.init_mla(ks[0], cfg, d) if cfg.mla is not None
                     else attn.init_gqa(ks[0], cfg, d))
        p["ln2"] = init_norm(cfg, d)
        if kind == "moe":
            p["ffn"] = moe_lib.init_moe(ks[1], cfg, d)
        else:
            p["ffn"] = init_mlp(ks[1], cfg, d, cfg.d_ff)
        if kind == "decoder":
            p["ln_x"] = init_norm(cfg, d)
            p["xattn"] = attn.init_cross_attention(ks[2], cfg, d)
    elif kind == "ssm":
        p["ln1"] = init_norm(cfg, d)
        p["ssm"] = ssm_lib.init_mamba2(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p


def _ffn(p, h, cfg, kind, pctx):
    if kind == "moe":
        return moe_lib.apply_moe(p["ffn"], h, cfg, pctx)
    return apply_mlp(p["ffn"], h, cfg)


def _unfsdp(p: dict, cfg, pctx, kind: str):
    """ZeRO-3 semantics for the dense weights of one layer: constrain them
    to be replicated over the FSDP ('data') axis inside the scan body, so
    GSPMD all-gathers the weight shards (0.1-1 GB/layer) instead of
    psum-ing full activations over 'data' (measured: 1.9 TB/step/device of
    activation all-reduce on deepseek-v3 without this). Expert weights are
    excluded — their EP layout is consumed directly by shard_map."""
    if pctx is None or not pctx.gather_weights \
            or "data" not in pctx.mesh.axis_names:
        return p
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel.sharding import param_specs

    def strip(spec):
        parts = []
        for ax in tuple(spec):
            if ax == "data":
                parts.append(None)
            elif isinstance(ax, (tuple, list)):
                kept = tuple(a for a in ax if a != "data")
                parts.append(kept if kept else None)
            else:
                parts.append(ax)
        return P(*parts)

    specs = param_specs(p, cfg, pctx)

    def one(path, leaf, spec):
        if leaf.ndim == 3 and leaf.shape[0] == (cfg.moe.n_experts
                                                if cfg.moe else -1):
            return leaf  # EP expert stacks stay in shard_map layout
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(pctx.mesh, strip(spec)))

    return jax.tree_util.tree_map_with_path(
        lambda path, l, s: one(path, l, s), p, specs)


def block_forward(p: dict, x, cfg: ArchConfig, kind: str, *, positions,
                  pctx=None, causal=True, cross=None):
    """Full-sequence block. Returns (x, cache)."""
    p = _unfsdp(p, cfg, pctx, kind)
    x = _hint(x, pctx)
    h = apply_norm(p["ln1"], x, cfg)
    if kind == "ssm":
        y, state = ssm_lib.mamba2_forward(p["ssm"], h, cfg)
        return _hint(x + y, pctx), state
    if cfg.mla is not None:
        y, cache = attn.mla_attention(p["attn"], h, cfg, positions=positions,
                                      pctx=pctx)
    else:
        y, cache = attn.gqa_attention(p["attn"], h, cfg, positions=positions,
                                      causal=causal, pctx=pctx)
    x = x + y
    if kind == "decoder":
        hx = apply_norm(p["ln_x"], x, cfg)
        kv = attn.cross_kv(p["xattn"], cross, cfg)
        x = x + attn.cross_attention(p["xattn"], hx, cfg, kv)
    h2 = apply_norm(p["ln2"], x, cfg)
    x = x + _ffn(p, h2, cfg, kind, pctx)
    # exit hint: the scan carry (== the remat-saved layer input of the NEXT
    # block) keeps the (dp[, seq-sharded]) layout
    return _hint(x, pctx), cache


def block_decode(p: dict, x, cfg: ArchConfig, kind: str, *, cache, pos,
                 pctx=None, cross_kv=None):
    x = _hint(x, pctx)
    h = apply_norm(p["ln1"], x, cfg)
    if kind == "ssm":
        y, state = ssm_lib.mamba2_decode(p["ssm"], h, cfg, cache)
        return x + y, state
    if cfg.mla is not None:
        y, cache = attn.mla_decode(p["attn"], h, cfg, cache, pos)
    else:
        y, cache = attn.gqa_decode(p["attn"], h, cfg, cache, pos)
    x = x + y
    if kind == "decoder":
        hx = apply_norm(p["ln_x"], x, cfg)
        q = jnp.einsum("bsd,dhk->bshk", hx, p["xattn"]["wq"])
        if cfg.attn_bias:
            q = q + p["xattn"]["bq"]
        o = attn.decode_attention(q, cross_kv[0], cross_kv[1],
                                  cross_kv[0].shape[1] - 1)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["xattn"]["wo"])
    h2 = apply_norm(p["ln2"], x, cfg)
    x = x + _ffn(p, h2, cfg, kind, pctx)
    return x, cache


# ------------------------------------------------------------ stacked scans
def init_stack(key, cfg: ArchConfig, kind: str, n: int):
    if n == 0:
        return None
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_block(k, cfg, kind))(keys)


def stack_forward(stack, x, cfg, kind, *, positions, pctx=None, causal=True,
                  cross=None):
    """Scan a stacked block over the layer dimension; returns (x, caches)."""
    body = functools.partial(block_forward, cfg=cfg, kind=kind,
                             positions=positions, pctx=pctx, causal=causal,
                             cross=cross)

    def step(carry, layer_p):
        out, cache = jax.checkpoint(
            lambda c, lp: body(lp, c))(carry, layer_p)
        return out, cache

    return jax.lax.scan(step, x, stack)


def stack_decode(stack, x, cfg, kind, *, caches, pos, pctx=None,
                 cross_kv=None):
    def step(carry, xs):
        layer_p, cache, ck = xs
        out, new_cache = block_decode(layer_p, carry, cfg, kind, cache=cache,
                                      pos=pos, pctx=pctx, cross_kv=ck)
        return out, new_cache

    if cross_kv is None:
        n = jax.tree_util.tree_leaves(stack)[0].shape[0]
        cross_kv = (jnp.zeros((n,)),) * 2  # unused placeholder, scanned over
    return jax.lax.scan(step, x, (stack, caches, cross_kv))


# ------------------------------------------------------------------ LM model
@dataclasses.dataclass(frozen=True)
class LM:
    """Decoder-only LM: dense, MoE (w/ optional MLA + MTP), VLM."""
    cfg: ArchConfig

    # -------- init
    def init(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        n_dense = cfg.n_layers if cfg.moe is None else cfg.n_dense_layers
        n_moe = 0 if cfg.moe is None else cfg.n_layers - cfg.n_dense_layers
        p = {
            "embed": init_embedding(ks[0], cfg),
            "dense_stack": init_stack(ks[1], cfg, "dense", n_dense),
            "moe_stack": init_stack(ks[2], cfg, "moe", n_moe),
            "final_norm": init_norm(cfg, cfg.d_model),
        }
        if cfg.mtp_depth:
            from repro.models.layers import dense_init
            p["mtp"] = {
                "proj": dense_init(ks[3], (2 * cfg.d_model, cfg.d_model),
                                   dtype_of(cfg)),
                "block": init_block(ks[4], cfg,
                                    "moe" if cfg.moe is not None else "dense"),
                "ln_h": init_norm(cfg, cfg.d_model),
                "ln_e": init_norm(cfg, cfg.d_model),
            }
        return p

    # -------- shared trunk
    def _inputs(self, params, batch):
        cfg = self.cfg
        x = embed_tokens(params["embed"], batch["tokens"], cfg)
        if cfg.vision is not None:
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        if cfg.pos_embedding == "learned":
            x = x + jnp.take(params["embed"]["positions"],
                             jnp.arange(S), axis=0)
        return x, positions

    def _trunk(self, params, x, positions, pctx):
        cfg = self.cfg
        caches = {}
        if params["dense_stack"] is not None:
            x, caches["dense"] = stack_forward(
                params["dense_stack"], x, cfg, "dense", positions=positions,
                pctx=pctx)
        if params["moe_stack"] is not None:
            x, caches["moe"] = stack_forward(
                params["moe_stack"], x, cfg, "moe", positions=positions,
                pctx=pctx)
        return apply_norm(params["final_norm"], x, cfg), caches

    # -------- train
    def loss_fn(self, params, batch, pctx=None):
        cfg = self.cfg
        x, positions = self._inputs(params, batch)
        h, _ = self._trunk(params, x, positions, pctx)
        if cfg.vision is not None:
            h = h[:, cfg.vision.n_patches:, :]
        labels = batch["labels"]
        total = lm_loss(params["embed"], h[:, :-1], labels[:, 1:], cfg)
        if cfg.mtp_depth:
            total = total + 0.3 * self._mtp_loss(params, h, batch, pctx)
        return total

    def _mtp_loss(self, params, h, batch, pctx):
        """DeepSeek-V3 MTP (depth 1): predict token t+2 from h_t combined
        with the embedding of token t+1."""
        cfg = self.cfg
        mtp = params["mtp"]
        if cfg.vision is not None:
            h = h[:, cfg.vision.n_patches:, :]
        tok = batch["tokens"]
        e_next = embed_tokens(params["embed"], tok[:, 1:], cfg)
        hh = apply_norm(mtp["ln_h"], h[:, :-1], cfg)
        ee = apply_norm(mtp["ln_e"], e_next, cfg)
        z = jnp.concatenate([hh, ee], axis=-1) @ mtp["proj"]
        B, S = z.shape[0], z.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        z, _ = block_forward(mtp["block"], z, cfg,
                             "moe" if cfg.moe is not None else "dense",
                             positions=positions, pctx=pctx)
        labels = batch["labels"]
        return lm_loss(params["embed"], z[:, :-1], labels[:, 2:], cfg)

    # -------- serving
    def prefill(self, params, batch, pctx=None):
        cfg = self.cfg
        x, positions = self._inputs(params, batch)
        h, caches = self._trunk(params, x, positions, pctx)
        lg = logits(params["embed"], h[:, -1:, :], cfg)
        return lg, caches

    def decode_step(self, params, caches, batch, pctx=None):
        cfg = self.cfg
        tok = batch["token"][:, None]
        pos = batch["pos"]
        x = embed_tokens(params["embed"], tok, cfg)
        if cfg.pos_embedding == "learned":
            pos_b = jnp.broadcast_to(jnp.atleast_1d(
                jnp.asarray(pos, jnp.int32)), (x.shape[0],))
            x = x + jnp.take(params["embed"]["positions"], pos_b,
                             axis=0)[:, None, :]
        new_caches = {}
        if params["dense_stack"] is not None:
            x, new_caches["dense"] = stack_decode(
                params["dense_stack"], x, cfg, "dense",
                caches=caches["dense"], pos=pos, pctx=pctx)
        if params["moe_stack"] is not None:
            x, new_caches["moe"] = stack_decode(
                params["moe_stack"], x, cfg, "moe", caches=caches["moe"],
                pos=pos, pctx=pctx)
        h = apply_norm(params["final_norm"], x, cfg)
        lg = logits(params["embed"], h, cfg)
        return lg, new_caches

    def init_cache(self, batch_size: int, seq_len: int):
        """Zero KV caches shaped for a ``seq_len`` window."""
        cfg = self.cfg
        dt = dtype_of(cfg)
        n_dense = cfg.n_layers if cfg.moe is None else cfg.n_dense_layers
        n_moe = 0 if cfg.moe is None else cfg.n_layers - cfg.n_dense_layers

        def kv(n):
            if n == 0:
                return None
            if cfg.mla is not None:
                m = cfg.mla
                return {"c_kv": jnp.zeros((n, batch_size, seq_len,
                                           m.kv_lora_rank), dt),
                        "k_rope": jnp.zeros((n, batch_size, seq_len,
                                             m.qk_rope_head_dim), dt)}
            hd = cfg.resolved_head_dim
            return {"k": jnp.zeros((n, batch_size, seq_len, cfg.n_kv_heads,
                                    hd), dt),
                    "v": jnp.zeros((n, batch_size, seq_len, cfg.n_kv_heads,
                                    hd), dt)}

        out = {}
        if n_dense:
            out["dense"] = kv(n_dense)
        if n_moe:
            out["moe"] = kv(n_moe)
        return out


# ------------------------------------------------------------------ SSM model
@dataclasses.dataclass(frozen=True)
class SSMLM:
    cfg: ArchConfig

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 3)
        return {
            "embed": init_embedding(ks[0], cfg),
            "stack": init_stack(ks[1], cfg, "ssm", cfg.n_layers),
            "final_norm": init_norm(cfg, cfg.d_model),
        }

    def loss_fn(self, params, batch, pctx=None):
        cfg = self.cfg
        x = embed_tokens(params["embed"], batch["tokens"], cfg)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x, _ = stack_forward(params["stack"], x, cfg, "ssm",
                             positions=positions, pctx=pctx)
        h = apply_norm(params["final_norm"], x, cfg)
        return lm_loss(params["embed"], h[:, :-1], batch["labels"][:, 1:],
                       cfg)

    def prefill(self, params, batch, pctx=None):
        cfg = self.cfg
        x = embed_tokens(params["embed"], batch["tokens"], cfg)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x, states = stack_forward(params["stack"], x, cfg, "ssm",
                                  positions=positions, pctx=pctx)
        h = apply_norm(params["final_norm"], x, cfg)
        return logits(params["embed"], h[:, -1:, :], cfg), states

    def decode_step(self, params, states, batch, pctx=None):
        cfg = self.cfg
        x = embed_tokens(params["embed"], batch["token"][:, None], cfg)
        x, new_states = stack_decode(params["stack"], x, cfg, "ssm",
                                     caches=states, pos=batch["pos"],
                                     pctx=pctx)
        h = apply_norm(params["final_norm"], x, cfg)
        return logits(params["embed"], h, cfg), new_states

    def init_cache(self, batch_size: int, seq_len: int):
        cfg = self.cfg
        s, d_in, nh, conv_ch = ssm_lib._dims(cfg)
        gn = s.n_groups * s.d_state
        L = cfg.n_layers
        dt = dtype_of(cfg)
        W = s.d_conv - 1
        return {"conv": (jnp.zeros((L, batch_size, W, d_in), dt),
                         jnp.zeros((L, batch_size, W, gn), dt),
                         jnp.zeros((L, batch_size, W, gn), dt)),
                "ssm": jnp.zeros((L, batch_size, nh, s.head_dim, s.d_state),
                                 jnp.float32)}


# --------------------------------------------------------------- Hybrid model
@dataclasses.dataclass(frozen=True)
class HybridLM:
    """Zamba2-style: groups of Mamba-2 layers, each group followed by ONE
    shared attention+MLP block (single weight copy)."""
    cfg: ArchConfig

    @property
    def group_size(self) -> int:
        return self.cfg.hybrid_attn_every

    @property
    def n_groups(self) -> int:
        return self.cfg.n_layers // self.group_size

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        keys = jax.random.split(ks[1], self.n_groups)
        stack = jax.vmap(
            lambda k: init_stack(k, cfg, "ssm", self.group_size))(keys)
        return {
            "embed": init_embedding(ks[0], cfg),
            "groups": stack,                     # (G, group_size, ...)
            "shared": init_block(ks[2], cfg, "dense"),
            "final_norm": init_norm(cfg, cfg.d_model),
        }

    def _forward(self, params, x, positions, pctx):
        cfg = self.cfg

        def group_step(carry, group_p):
            h, _ = stack_forward(group_p, carry, cfg, "ssm",
                                 positions=positions, pctx=pctx)
            h, cache = jax.checkpoint(
                lambda hh: block_forward(params["shared"], hh, cfg, "dense",
                                         positions=positions, pctx=pctx))(h)
            return h, cache

        x, attn_caches = jax.lax.scan(group_step, x, params["groups"])
        return x, attn_caches

    def loss_fn(self, params, batch, pctx=None):
        cfg = self.cfg
        x = embed_tokens(params["embed"], batch["tokens"], cfg)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x, _ = self._forward(params, x, positions, pctx)
        h = apply_norm(params["final_norm"], x, cfg)
        return lm_loss(params["embed"], h[:, :-1], batch["labels"][:, 1:],
                       cfg)

    def prefill(self, params, batch, pctx=None):
        cfg = self.cfg
        x = embed_tokens(params["embed"], batch["tokens"], cfg)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

        def group_step(carry, group_p):
            h, ssm_states = stack_forward(group_p, carry, cfg, "ssm",
                                          positions=positions, pctx=pctx)
            h, attn_cache = block_forward(params["shared"], h, cfg, "dense",
                                          positions=positions, pctx=pctx)
            return h, (ssm_states, attn_cache)

        x, (ssm_states, attn_caches) = jax.lax.scan(group_step, x,
                                                    params["groups"])
        h = apply_norm(params["final_norm"], x, cfg)
        return logits(params["embed"], h[:, -1:, :], cfg), \
            {"ssm": ssm_states, "attn": attn_caches}

    def decode_step(self, params, caches, batch, pctx=None):
        cfg = self.cfg
        x = embed_tokens(params["embed"], batch["token"][:, None], cfg)
        pos = batch["pos"]

        def group_step(carry, xs):
            group_p, sstate, acache = xs
            h, new_s = stack_decode(group_p, carry, cfg, "ssm",
                                    caches=sstate, pos=pos, pctx=pctx)
            h, new_a = block_decode(params["shared"], h, cfg, "dense",
                                    cache=acache, pos=pos, pctx=pctx)
            return h, (new_s, new_a)

        x, (new_ssm, new_attn) = jax.lax.scan(
            group_step, x, (params["groups"], caches["ssm"], caches["attn"]))
        h = apply_norm(params["final_norm"], x, cfg)
        return logits(params["embed"], h, cfg), \
            {"ssm": new_ssm, "attn": new_attn}

    def init_cache(self, batch_size: int, seq_len: int):
        cfg = self.cfg
        s, d_in, nh, conv_ch = ssm_lib._dims(cfg)
        gn = s.n_groups * s.d_state
        G, gs = self.n_groups, self.group_size
        hd = cfg.resolved_head_dim
        dt = dtype_of(cfg)
        W = s.d_conv - 1
        return {
            "ssm": {"conv": (jnp.zeros((G, gs, batch_size, W, d_in), dt),
                             jnp.zeros((G, gs, batch_size, W, gn), dt),
                             jnp.zeros((G, gs, batch_size, W, gn), dt)),
                    "ssm": jnp.zeros((G, gs, batch_size, nh, s.head_dim,
                                      s.d_state), jnp.float32)},
            "attn": {"k": jnp.zeros((G, batch_size, seq_len, cfg.n_kv_heads,
                                     hd), dt),
                     "v": jnp.zeros((G, batch_size, seq_len, cfg.n_kv_heads,
                                     hd), dt)},
        }


# --------------------------------------------------------------- EncDec model
@dataclasses.dataclass(frozen=True)
class EncDecLM:
    """Whisper-style enc-dec; the conv frontend is a stub (precomputed frame
    embeddings arrive in ``batch['frames']``)."""
    cfg: ArchConfig

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 5)
        return {
            "embed": init_embedding(ks[0], cfg),
            "enc_pos": jax.random.normal(
                ks[3], (cfg.encdec.encoder_seq, cfg.d_model),
                jnp.float32).astype(dtype_of(cfg)) * 0.02,
            "encoder": init_stack(ks[1], cfg, "encoder",
                                  cfg.encdec.n_encoder_layers),
            "enc_norm": init_norm(cfg, cfg.d_model),
            "decoder": init_stack(ks[2], cfg, "decoder", cfg.n_layers),
            "final_norm": init_norm(cfg, cfg.d_model),
        }

    def _encode(self, params, frames, pctx):
        cfg = self.cfg
        x = frames.astype(dtype_of(cfg)) + params["enc_pos"]
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x, _ = stack_forward(params["encoder"], x, cfg, "encoder",
                             positions=positions, pctx=pctx, causal=False)
        return apply_norm(params["enc_norm"], x, cfg)

    def _decode_stack(self, params, tokens, enc, pctx):
        cfg = self.cfg
        x = embed_tokens(params["embed"], tokens, cfg)
        B, S = x.shape[:2]
        if cfg.pos_embedding == "learned":
            x = x + jnp.take(params["embed"]["positions"], jnp.arange(S),
                             axis=0)
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x, caches = stack_forward(params["decoder"], x, cfg, "decoder",
                                  positions=positions, pctx=pctx, cross=enc)
        return apply_norm(params["final_norm"], x, cfg), caches

    def loss_fn(self, params, batch, pctx=None):
        enc = self._encode(params, batch["frames"], pctx)
        # cross-KV is computed per layer inside the scan from `enc`
        h, _ = self._decode_stack(params, batch["tokens"], enc, pctx)
        return lm_loss(params["embed"], h[:, :-1], batch["labels"][:, 1:],
                       self.cfg)

    def prefill(self, params, batch, pctx=None):
        cfg = self.cfg
        enc = self._encode(params, batch["frames"], pctx)
        h, caches = self._decode_stack(params, batch["tokens"], enc, pctx)
        # precompute per-layer cross KV for decode
        def xkv(layer_p):
            return attn.cross_kv(layer_p["xattn"], enc, cfg)
        cross = jax.vmap(xkv, in_axes=0)(params["decoder"])
        lg = logits(params["embed"], h[:, -1:, :], cfg)
        return lg, {"self": caches, "cross": cross}

    def decode_step(self, params, caches, batch, pctx=None):
        cfg = self.cfg
        x = embed_tokens(params["embed"], batch["token"][:, None], cfg)
        pos = batch["pos"]
        if cfg.pos_embedding == "learned":
            pos_b = jnp.broadcast_to(jnp.atleast_1d(
                jnp.asarray(pos, jnp.int32)), (x.shape[0],))
            x = x + jnp.take(params["embed"]["positions"], pos_b,
                             axis=0)[:, None, :]
        x, new_self = stack_decode(params["decoder"], x, cfg, "decoder",
                                   caches=caches["self"], pos=pos, pctx=pctx,
                                   cross_kv=caches["cross"])
        h = apply_norm(params["final_norm"], x, cfg)
        return logits(params["embed"], h, cfg), \
            {"self": new_self, "cross": caches["cross"]}

    def init_cache(self, batch_size: int, seq_len: int):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        dt = dtype_of(cfg)
        L = cfg.n_layers
        return {
            "self": {"k": jnp.zeros((L, batch_size, seq_len, cfg.n_kv_heads,
                                     hd), dt),
                     "v": jnp.zeros((L, batch_size, seq_len, cfg.n_kv_heads,
                                     hd), dt)},
            "cross": (jnp.zeros((L, batch_size, cfg.encdec.encoder_seq,
                                 cfg.n_kv_heads, hd), dt),
                      jnp.zeros((L, batch_size, cfg.encdec.encoder_seq,
                                 cfg.n_kv_heads, hd), dt)),
        }


def build_model(cfg: ArchConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return LM(cfg)
    if cfg.family == "ssm":
        return SSMLM(cfg)
    if cfg.family == "hybrid":
        return HybridLM(cfg)
    if cfg.family == "audio":
        return EncDecLM(cfg)
    raise ValueError(cfg.family)
