"""Simulated LM serving as a first-class Program-IR workload (ROADMAP
item 1): continuous-batching decode/prefill steps emitted as per-rank
``Compute`` + embedded KV/activation ``Collective``\\ s, costed by the
closed-form roofline estimator
(:func:`repro.roofline.analysis.lm_serve_step_cost`) and executed on the
ExaNeSt event engine — congestion, skewed collective entries and
per-rank arrival jitter are simulated, not modeled.

The fast path is the whole point (DESIGN.md §2.7): a continuous-batching
server only ever occupies finitely many *step states* — (decoding slots,
prefilling slots, KV-occupancy bucket) — so an entire load sweep needs
just one :meth:`~repro.core.exanet.mpi.ExanetMPI.run_program_scenarios`
call: every (state x Monte-Carlo-draw) binds as one column of the
compiled artifact (per-column compute skew, per-column collective
payloads via the ``site_scale`` seam, per-rank arrival skew via the
``t0`` axis), and the open-loop traffic replay
(:mod:`repro.serve.traffic`) then walks millions of simulated steps as
table lookups.  The per-step lane — rebind + ``run_program`` per
simulated step — is the baseline the speedup row in ``BENCH_serve.json``
measures against.

Step model
----------
One step advances every decoding slot by one token and pushes one
``prefill_chunk``-sized chunk through every prefilling slot (chunked
prefill: a P-token prompt occupies its slot for ``ceil(P/chunk)`` steps,
its final chunk emitting the first output token).  Per rank (tensor
parallelism over all ``nranks``) the step is::

    Compute(roofline max of flops/rate and bytes/bw, jittered)
    Collective(allgather,  act_bytes / nranks)   # per-token activations
    Collective(alltoall | allgather, kv_bytes / nranks)  # KV-shard moves

The KV-shard exchange is a pairwise ``alltoall`` up to
``alltoall_max_ranks`` and an ``allgather`` beyond it: the XOR-pairwise
schedule is O(nranks) exchange rounds, which a real system would never
run over thousands of ranks for a few migrated shards — and which would
also dominate the compiled replay itself.  Both ops resolve to a single
schedule regardless of payload, so per-column ``site_scale`` bindings
can never flip the probe tape (the hazard ``algo="auto"`` sites have).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.program import Collective, Compute, Program


@dataclasses.dataclass(frozen=True)
class ServeSimSpec:
    """One simulated serving deployment: model config x machine shard."""
    arch: str = "exanest-lm-100m"
    nranks: int = 512
    slots: int = 8                 #: continuous-batching slots per replica
    window: int = 1024             #: KV capacity per slot (tokens)
    prefill_chunk: int = 256       #: prompt tokens per prefill step
    dtype_bytes: int = 2
    #: per-rank A53-class compute roofline: NEON peak (~8 flop/cycle at
    #: 1.5 GHz) and the per-core DDR copy bandwidth of params.HwParams
    core_rate_flops_per_us: float = 12000.0
    mem_bw_bytes_per_us: float = 2000.0
    #: fixed per-step dispatch overhead (kernel launches, batching glue)
    step_overhead_us: float = 25.0
    #: KV-occupancy buckets the step table quantizes decode context into
    kv_buckets: int = 4
    #: per-rank request-dispatch jitter, uniform [0, skew) us (t0 axis)
    arrival_skew_us: float = 2.0
    #: multiplicative per-rank compute noise, uniform 1 +/- jitter
    compute_jitter: float = 0.02
    #: pairwise alltoall is O(nranks) rounds; beyond this the KV-shard
    #: exchange emits as a recursive-doubling allgather instead
    alltoall_max_ranks: int = 128

    def kv_centers(self) -> np.ndarray:
        """Bucket-center KV occupancies (tokens) for the step table."""
        k = max(1, int(self.kv_buckets))
        return (np.arange(k) + 0.5) * (self.window / k)

    def kv_bucket(self, kv_mean: float) -> int:
        k = max(1, int(self.kv_buckets))
        return min(k - 1, max(0, int(kv_mean / self.window * k)))


@dataclasses.dataclass
class StepTable:
    """Batched step-latency table: one row per step state, one column
    per Monte-Carlo draw — the product of ONE ``run_program_scenarios``
    call.  ``cols`` maps a (state, draw) back to its scenario column so
    the per-step lane can rebind the *identical* payload."""
    states: list          #: [(n_decode, n_prefill, kv_bucket), ...]
    mc: int
    us: np.ndarray        #: (n_states, mc) simulated step latency
    index: dict           #: state -> row
    compute_scale: np.ndarray   #: (nranks, N) column compute skew
    site_scale: np.ndarray      #: (n_sites, N) column payload scale
    t0: np.ndarray              #: (nranks, N) column entry clocks

    def col(self, state, j: int) -> int:
        return self.index[state] * self.mc + int(j)

    def lookup(self, nd: int, npf: int, kvb: int, step: int) -> float:
        """Step latency for a replay step: deterministic draw rotation."""
        return float(self.us[self.index[(nd, npf, kvb)], step % self.mc])


class ServeSim:
    """Emit + cost serving-step Programs for one :class:`ServeSimSpec`.

    The simulation instance (base prototype or scaled-torus twin) is
    resolved per rank count through the same
    :meth:`~repro.core.machine.ExanetMachine._mpi_for` tier cache the
    planner and app sweeps use.
    """

    def __init__(self, spec: ServeSimSpec, mpi=None):
        from repro.configs import get
        self.spec = spec
        self.cfg = get(spec.arch)
        if mpi is None:
            from repro.core.exanet.mpi import ExanetMPI
            from repro.core.exanet.params import DEFAULT
            from repro.core.machine import ExanetMachine
            mpi = ExanetMachine(mpi=ExanetMPI(DEFAULT))._mpi_for(spec.nranks)
        self.mpi = mpi
        if spec.nranks & (spec.nranks - 1):
            raise ValueError(
                f"nranks must be a power of two for the allgather/"
                f"alltoall schedules; got {spec.nranks}")
        self._base_state = (max(1, spec.slots), 1,
                            spec.kv_buckets // 2)
        self._base_prog = None

    # ------------------------------------------------------------- costing
    def step_cost(self, nd: float, npf: float, kv_mean: float) -> dict:
        """Whole-model cost of one (nd decode, npf prefill-chunk) step."""
        from repro.roofline.analysis import lm_serve_step_cost
        sp = self.spec
        return lm_serve_step_cost(
            self.cfg, n_decode=nd, decode_kv=kv_mean,
            n_prefill=npf * sp.prefill_chunk,
            prefill_kv=0.0, dtype_bytes=sp.dtype_bytes)

    def rank_compute_us(self, nd: float, npf: float,
                        kv_mean: float) -> float:
        """Per-rank roofline step compute: the tensor-parallel shard of
        the whole-model flops/bytes, whichever roof binds, plus the
        fixed dispatch overhead."""
        sp = self.spec
        c = self.step_cost(nd, npf, kv_mean)
        return sp.step_overhead_us + max(
            c["flops"] / sp.nranks / sp.core_rate_flops_per_us,
            c["hbm_bytes"] / sp.nranks / sp.mem_bw_bytes_per_us)

    def site_bytes(self, nd: float, npf: float, kv_mean: float) -> tuple:
        """(act allgather, kv exchange) per-rank payloads in bytes."""
        c = self.step_cost(nd, npf, kv_mean)
        n = self.spec.nranks
        return (max(1, int(round(c["act_bytes"] / n))),
                max(1, int(round(c["kv_bytes"] / n))) if npf > 0 else 1)

    # ------------------------------------------------------------ emission
    def kv_exchange_op(self) -> tuple:
        """(op, algo) of the KV-shard exchange collective."""
        if self.spec.nranks <= self.spec.alltoall_max_ranks:
            return "alltoall", "pairwise"
        return "allgather", "recursive_doubling"

    def emit_step(self, nd: int, npf: int, kv_mean: float) -> Program:
        """One serving step as a Program: every rank computes its shard
        then enters the activation allgather and the KV-shard exchange.
        Structure is state-independent — only payloads move — so every
        step of every load point binds as a column of ONE artifact."""
        sp = self.spec
        us = self.rank_compute_us(nd, npf, kv_mean)
        act_b, kv_b = self.site_bytes(nd, npf, kv_mean)
        kv_op, kv_algo = self.kv_exchange_op()
        ops = (Compute(us=us),
               Collective(op="allgather", nbytes=act_b,
                          algo="recursive_doubling"),
               Collective(op=kv_op, nbytes=kv_b, algo=kv_algo))
        return Program(tuple(ops for _ in range(sp.nranks)))

    def base_program(self) -> Program:
        """The base binding every scenario column perturbs (all payloads
        strictly positive, so per-column multiplicative scales are
        well-defined)."""
        if self._base_prog is None:
            nd, npf, kvb = self._base_state
            kv = float(self.spec.kv_centers()[kvb])
            self._base_prog = self.emit_step(nd, npf, kv)
        return self._base_prog

    # --------------------------------------------------------- step states
    def step_states(self) -> list:
        """Every (n_decode, n_prefill, kv_bucket) a replay can occupy:
        occupancy up to ``slots``, KV bucketed only where decode reads
        it (pure-prefill states pin bucket 0)."""
        sp = self.spec
        out = []
        for nd in range(sp.slots + 1):
            for npf in range(sp.slots + 1 - nd):
                if nd == 0 and npf == 0:
                    continue
                for kvb in (range(sp.kv_buckets) if nd else (0,)):
                    out.append((nd, npf, kvb))
        return out

    # ------------------------------------------------------------ the table
    def build_table(self, *, mc: int = 3, rng=None, engine=None,
                    check: int = 0, rtol: float = 1e-9) -> StepTable:
        """Cost every step state x Monte-Carlo draw in ONE batched
        scenario replay.  ``check`` forwards to
        :meth:`~repro.core.exanet.mpi.ExanetMPI.run_program_scenarios`
        (sampled columns re-run on the interpreter, <=1e-9 agreement or
        raise)."""
        sp = self.spec
        rng = np.random.default_rng(rng)
        states = self.step_states()
        centers = sp.kv_centers()
        base = self.base_program()
        base_us = self.rank_compute_us(
            self._base_state[0], self._base_state[1],
            float(centers[self._base_state[2]]))
        base_sites = np.array(self.site_bytes(
            self._base_state[0], self._base_state[1],
            float(centers[self._base_state[2]])), dtype=np.float64)
        n_states = len(states)
        N = n_states * mc
        cs = np.empty((sp.nranks, N))
        ss = np.empty((2, N))
        for i, (nd, npf, kvb) in enumerate(states):
            kv = float(centers[kvb])
            cols = slice(i * mc, (i + 1) * mc)
            cs[:, cols] = self.rank_compute_us(nd, npf, kv) / base_us
            a, k = self.site_bytes(nd, npf, kv)
            ss[0, cols] = a / base_sites[0]
            ss[1, cols] = k / base_sites[1]
        if sp.compute_jitter > 0:
            cs *= rng.uniform(1.0 - sp.compute_jitter,
                              1.0 + sp.compute_jitter, cs.shape)
        t0 = rng.uniform(0.0, max(sp.arrival_skew_us, 1e-30),
                         (sp.nranks, N))
        res = self.mpi.run_program_scenarios(
            base, compute_scale=cs, site_scale=ss, t0=t0,
            engine=engine, check=check, rtol=rtol)
        us = np.array([r.latency_us for r in res]).reshape(n_states, mc)
        return StepTable(states=states, mc=mc, us=us,
                         index={s: i for i, s in enumerate(states)},
                         compute_scale=cs, site_scale=ss, t0=t0)

    # ------------------------------------------------------ per-step lane
    def step_time_single(self, table: StepTable, state, j: int, *,
                         backend: str = "auto", engine=None) -> float:
        """The naive lane: rebind the column's exact payload as a fresh
        Program and run it alone — what a per-step simulator pays for
        every simulated step.  Bit-identical inputs to the batched
        column, so lane agreement is pure executor agreement."""
        from repro.core.exanet.program_compiled import (extract_data,
                                                        rebind_program)
        b = table.col(state, j)
        base = self.base_program()
        data = extract_data(base)
        comp = np.array(data[0]) * table.compute_scale[:, b]
        site = np.rint(np.array(data[2], dtype=np.float64)
                       * table.site_scale[:, b]).astype(np.int64)
        prog = rebind_program(base, compute_us=comp, site_nbytes=site)
        return self.mpi.run_program(prog, backend=backend, engine=engine,
                                    t0=table.t0[:, b]).latency_us
