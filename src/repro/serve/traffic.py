"""Open-loop traffic driver for the simulated serving stack.

Arrivals are generated up front (Poisson or an explicit trace) and
*never* throttled by the server — the open-loop discipline tail-latency
measurement requires: at overload the queue grows and per-request
latency diverges, which is exactly the goodput-vs-load knee
``BENCH_serve.json`` reports.  The replay itself is a continuous-batching
loop over slots whose per-step cost comes from a pluggable
``step_time(nd, npf, kvb, step) -> us`` — a :class:`~repro.serve.sim
.StepTable` lookup on the batched lane, a rebind + ``run_program`` call
on the per-step lane — so the two lanes share every line of queueing
logic and lane agreement reduces to executor agreement.

Timestamps per request (all microseconds, simulated): ``arrive`` (enters
the queue), ``admit`` (a slot picks it up, FIFO), ``first`` (first output
token — end of the step that finishes its prefill), ``done`` (last
token).  Latency is ``done - arrive``; TTFT is ``first - arrive``.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np


@dataclasses.dataclass(frozen=True)
class Workload:
    """One open-loop request trace (arrival times sorted ascending)."""
    arrive_us: np.ndarray
    prompt_tokens: np.ndarray
    out_tokens: np.ndarray

    def __post_init__(self):
        n = len(self.arrive_us)
        if len(self.prompt_tokens) != n or len(self.out_tokens) != n:
            raise ValueError("workload arrays disagree on length")
        if n and np.any(np.diff(self.arrive_us) < 0):
            raise ValueError("arrivals must be sorted ascending")
        if n and (np.any(self.prompt_tokens < 1)
                  or np.any(self.out_tokens < 1)):
            raise ValueError("prompt/output token counts must be >= 1")

    @property
    def n(self) -> int:
        return len(self.arrive_us)


def poisson_workload(rate_rps: float, n_requests: int, rng, *,
                     prompt_tokens: int = 128, out_tokens: int = 32,
                     length_jitter: float = 0.5) -> Workload:
    """Poisson arrivals at ``rate_rps`` with geometric-ish length mix:
    prompt/output lengths drawn uniform in ``mean * (1 +/- jitter)``
    (clipped to >= 1), the load mix a serving study sweeps."""
    rng = np.random.default_rng(rng)
    gaps = rng.exponential(1e6 / rate_rps, n_requests)
    arrive = np.cumsum(gaps) - gaps[0] if n_requests else np.zeros(0)

    def lengths(mean: int) -> np.ndarray:
        lo = max(1, int(round(mean * (1.0 - length_jitter))))
        hi = max(lo, int(round(mean * (1.0 + length_jitter))))
        return rng.integers(lo, hi + 1, n_requests)

    return Workload(arrive_us=arrive, prompt_tokens=lengths(prompt_tokens),
                    out_tokens=lengths(out_tokens))


def trace_workload(arrive_us, prompt_tokens, out_tokens) -> Workload:
    """An explicit request trace (replayed as-is, open loop)."""
    return Workload(arrive_us=np.asarray(arrive_us, dtype=np.float64),
                    prompt_tokens=np.asarray(prompt_tokens, dtype=np.int64),
                    out_tokens=np.asarray(out_tokens, dtype=np.int64))


@dataclasses.dataclass
class ReplayResult:
    """Per-request timestamps plus aggregate counters for one replay."""
    arrive_us: np.ndarray
    admit_us: np.ndarray
    first_us: np.ndarray
    done_us: np.ndarray
    n_steps: int
    sim_us: float                 #: completion time of the last request
    tokens_out: int

    @property
    def latency_us(self) -> np.ndarray:
        return self.done_us - self.arrive_us

    @property
    def ttft_us(self) -> np.ndarray:
        return self.first_us - self.arrive_us

    @property
    def queue_us(self) -> np.ndarray:
        return self.admit_us - self.arrive_us


def replay(workload: Workload, *, slots: int, prefill_chunk: int,
           window: int, kv_bucket, step_time) -> ReplayResult:
    """Continuous-batching open-loop replay.

    Each step: ingest arrivals, FIFO-admit into free slots, charge
    ``step_time(n_decode, n_prefill, kv_bucket, step_idx)``, then advance
    every occupied slot — prefilling slots by one ``prefill_chunk``
    (finishing prompts emit their first output token at the end of that
    step), decoding slots by one token.  A request completes after
    ``out_tokens`` outputs or when its KV hits ``window``.  When the
    machine is idle and requests are still due, the clock jumps to the
    next arrival.
    """
    n = workload.n
    arrive = workload.arrive_us
    admit = np.full(n, np.nan)
    first = np.full(n, np.nan)
    done = np.full(n, np.nan)
    queue: deque = deque()
    # slot state: rid, prefill_left, kv, out_left  (rid < 0 == free)
    s_rid = np.full(slots, -1, dtype=np.int64)
    s_pre = np.zeros(slots, dtype=np.int64)
    s_kv = np.zeros(slots, dtype=np.int64)
    s_out = np.zeros(slots, dtype=np.int64)
    t = 0.0
    next_arr = 0
    completed = 0
    n_steps = 0
    tokens_out = 0
    while completed < n:
        while next_arr < n and arrive[next_arr] <= t:
            queue.append(next_arr)
            next_arr += 1
        busy = s_rid >= 0
        if not queue and not busy.any():
            t = float(arrive[next_arr])  # idle: jump to the next arrival
            continue
        for s in np.flatnonzero(~busy):
            if not queue:
                break
            rid = queue.popleft()
            s_rid[s] = rid
            s_pre[s] = workload.prompt_tokens[rid]
            s_kv[s] = 0
            s_out[s] = workload.out_tokens[rid]
            admit[rid] = t
        busy = s_rid >= 0
        pre = busy & (s_pre > 0)
        dec = busy & (s_pre == 0)
        nd, npf = int(dec.sum()), int(pre.sum())
        kvb = kv_bucket(float(s_kv[dec].mean())) if nd else 0
        t += float(step_time(nd, npf, kvb, n_steps))
        n_steps += 1
        for s in np.flatnonzero(pre):
            take = min(prefill_chunk, int(s_pre[s]))
            s_pre[s] -= take
            s_kv[s] += take
            if s_pre[s] == 0:       # final chunk emits the first token
                rid = int(s_rid[s])
                first[rid] = t
                s_out[s] -= 1
                tokens_out += 1
        for s in np.flatnonzero(dec):
            s_kv[s] += 1
            s_out[s] -= 1
            tokens_out += 1
        for s in np.flatnonzero(busy):
            if s_out[s] <= 0 or s_kv[s] >= window:
                rid = int(s_rid[s])
                if np.isnan(first[rid]):
                    first[rid] = t
                done[rid] = t
                s_rid[s] = -1
                completed += 1
    return ReplayResult(arrive_us=arrive, admit_us=admit, first_us=first,
                        done_us=done, n_steps=n_steps,
                        sim_us=float(np.nanmax(done) if n else 0.0),
                        tokens_out=tokens_out)


# ---------------------------------------------------------------- analysis
def quantiles(values, qs=(0.5, 0.9, 0.99, 0.999)) -> dict:
    """Named latency quantiles (``p50``, ``p99``, ``p999``, ...) of a
    sample, plus mean/max — the CDF summary every BENCH_serve row
    carries."""
    v = np.sort(np.asarray(values, dtype=np.float64))
    out = {}
    for q in qs:
        key = ("p%g" % (100 * q)).replace(".", "")
        out[key] = float(np.quantile(v, q)) if v.size else float("nan")
    out["mean"] = float(v.mean()) if v.size else float("nan")
    out["max"] = float(v.max()) if v.size else float("nan")
    return out


def cdf_points(values, n_points: int = 64) -> list:
    """Downsampled empirical CDF as [value, cumulative_fraction] pairs
    (evenly spaced in rank, endpoints included) — enough to plot the
    tail without shipping every sample."""
    v = np.sort(np.asarray(values, dtype=np.float64))
    if not v.size:
        return []
    idx = np.unique(np.linspace(0, v.size - 1,
                                min(n_points, v.size)).astype(np.int64))
    return [[float(v[i]), float((i + 1) / v.size)] for i in idx]


def knee_point(offered_rps, goodput_rps, frac: float = 0.95):
    """The goodput-vs-load knee: the largest offered load still served
    at >= ``frac`` of the offered rate (None when even the lightest
    point saturates).  Past the knee the open-loop queue diverges and
    tail latency is unbounded — the capacity number a serving study
    quotes."""
    best = None
    for off, good in zip(offered_rps, goodput_rps):
        if good >= frac * off and (best is None or off > best):
            best = float(off)
    return best
