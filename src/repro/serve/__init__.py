"""Serving stack: the real jitted engine (:mod:`repro.serve.engine`)
and its simulated twin on the event engine (:mod:`repro.serve.sim`,
:mod:`repro.serve.traffic`).

Engine classes load lazily so the numpy-only simulator side
(``repro.serve.sim`` / ``repro.serve.traffic``) imports without jax.
"""

__all__ = ["ServeEngine", "Request", "ServeSim", "ServeSimSpec",
           "StepTable"]


def __getattr__(name):
    if name in ("ServeEngine", "Request"):
        from repro.serve import engine
        return getattr(engine, name)
    if name in ("ServeSim", "ServeSimSpec", "StepTable"):
        from repro.serve import sim
        return getattr(sim, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
