"""Batched serving engine: slot-based continuous batching.

Serving analog of the paper's converged-traffic goal (§5.3): one compiled
decode step serves many concurrent requests. Requests occupy fixed slots of
a shared KV cache; prefill fills a slot (padded to the window), decode
advances all active slots together; finished slots are recycled without
recompiling (static shapes throughout).

Prefill is batched across admissions: all slots admitted in one ``step()``
teacher-force their prompts together, one jitted ``decode_step`` call per
token *index* (rows whose prompt is shorter sit out behind the same
static shape) — admitting k slots with P-token prompts costs max(P)
dispatches, not k*P.
"""

from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    generated: list[int] = dataclasses.field(default_factory=list)
    #: engine step counter at submit / completion (for latency summaries)
    submit_step: int = 0
    done_step: Optional[int] = None

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return bool(self.generated and self.eos_id is not None
                    and self.generated[-1] == self.eos_id)


class ServeEngine:
    def __init__(self, model, params, *, slots: int = 4, window: int = 256,
                 greedy: bool = True):
        self.model = model
        self.params = params
        self.slots = slots
        self.window = window
        self.greedy = greedy
        self.cache = model.init_cache(slots, window)
        self.pos = np.zeros(slots, np.int32)           # next write position
        self.active: list[Optional[Request]] = [None] * slots
        self._queue: deque[Request] = deque()
        self._rid = itertools.count()
        self._decode = jax.jit(model.decode_step)
        self._results: dict[int, Request] = {}
        self._steps = 0

    # ------------------------------------------------------------- frontend
    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               eos_id: int | None = None) -> int:
        if not prompt:
            raise ValueError("empty prompt: a request needs at least one "
                             "token to condition its first output on")
        r = Request(next(self._rid), list(prompt), max_new_tokens, eos_id,
                    submit_step=self._steps)
        self._queue.append(r)
        return r.rid

    def result(self, rid: int) -> list[int] | None:
        r = self._results.get(rid)
        return list(r.generated) if r is not None else None

    def request_steps(self) -> dict[int, tuple[int, int]]:
        """``rid -> (submit_step, done_step)`` for every completed
        request — the engine-side timestamps the simulator's per-request
        latencies are compared against."""
        return {rid: (r.submit_step, r.done_step)
                for rid, r in self._results.items()}

    # ------------------------------------------------------------- scheduler
    def _admit(self):
        admitted: list[Request] = []
        slots_adm: list[int] = []
        for slot in range(self.slots):
            if self.active[slot] is None and self._queue:
                r = self._queue.popleft()
                self.active[slot] = r
                self.pos[slot] = 0
                admitted.append(r)
                slots_adm.append(slot)
        if not admitted:
            return
        # batched prefill: teacher-force all admitted prompts together,
        # one decode_step dispatch per token index (short prompts finish
        # early and sit out of later calls); leaves each admitted slot's
        # next-token logits in self._pending
        for k in range(max(len(r.prompt) for r in admitted)):
            toks = np.zeros(self.slots, np.int32)
            live = []
            for slot, r in zip(slots_adm, admitted):
                if k < len(r.prompt):
                    toks[slot] = r.prompt[k]
                    live.append(slot)
            self._step_slots(live, toks)

    def _step_slots(self, slots: Sequence[int], toks: np.ndarray):
        """Feed one token into each slot in ``slots`` (``toks`` is the
        full-width token row; other rows carry a harmless filler that is
        overwritten at the same position before those slots advance).
        Records the resulting logits as each stepped slot's pending
        next-token distribution.

        Uses per-row positions so concurrent slots at different depths
        never touch each other's cache rows (continuous batching)."""
        pos = np.maximum(self.pos, 0).astype(np.int32)
        logits, cache = self._decode(
            self.params, self.cache,
            {"token": jnp.asarray(toks), "pos": jnp.asarray(pos)})
        self.cache = cache
        if not hasattr(self, "_pending"):
            self._pending = np.zeros((self.slots,
                                      logits.shape[-1]), np.float32)
        for slot in slots:
            self.pos[slot] += 1
            self._pending[slot] = np.asarray(logits[slot, 0], np.float32)

    def _step_one_slot(self, slot: int, token: int):
        toks = np.zeros(self.slots, np.int32)
        toks[slot] = token
        self._step_slots([slot], toks)

    def step(self) -> int:
        """One engine step: admit + advance every active slot by one token
        (greedy over its pending logits); returns active request count."""
        self._admit()
        act = [s for s in range(self.slots) if self.active[s] is not None]
        if not act:
            return 0
        self._steps += 1
        for slot in act:
            r = self.active[slot]
            nxt = int(np.argmax(self._pending[slot]))
            r.generated.append(nxt)
            if r.done:
                r.done_step = self._steps
                self._results[r.rid] = r
                self.active[slot] = None
                self.pos[slot] = 0
            else:
                self._step_one_slot(slot, nxt)
        return len(act)

    def run_until_idle(self, max_steps: int = 1000) -> int:
        steps = 0
        while (self._queue or any(a is not None for a in self.active)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return steps
