"""Batched serving engine: slot-based continuous batching.

Serving analog of the paper's converged-traffic goal (§5.3): one compiled
decode step serves many concurrent requests. Requests occupy fixed slots of
a shared KV cache; prefill fills a slot (padded to the window), decode
advances all active slots together; finished slots are recycled without
recompiling (static shapes throughout).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    generated: list[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return bool(self.generated and self.eos_id is not None
                    and self.generated[-1] == self.eos_id)


class ServeEngine:
    def __init__(self, model, params, *, slots: int = 4, window: int = 256,
                 greedy: bool = True):
        self.model = model
        self.params = params
        self.slots = slots
        self.window = window
        self.greedy = greedy
        self.cache = model.init_cache(slots, window)
        self.pos = np.zeros(slots, np.int32)           # next write position
        self.active: list[Optional[Request]] = [None] * slots
        self._queue: list[Request] = []
        self._rid = itertools.count()
        self._decode = jax.jit(model.decode_step)
        self._results: dict[int, Request] = {}

    # ------------------------------------------------------------- frontend
    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               eos_id: int | None = None) -> int:
        r = Request(next(self._rid), list(prompt), max_new_tokens, eos_id)
        self._queue.append(r)
        return r.rid

    def result(self, rid: int) -> list[int] | None:
        r = self._results.get(rid)
        return list(r.generated) if r is not None else None

    # ------------------------------------------------------------- scheduler
    def _admit(self):
        for slot in range(self.slots):
            if self.active[slot] is None and self._queue:
                r = self._queue.pop(0)
                self.active[slot] = r
                # prefill the slot by teacher-forcing the prompt through
                # decode steps (slot-local; avoids a second compiled graph);
                # leaves this slot's next-token logits in self._pending
                self.pos[slot] = 0
                for tok in r.prompt:
                    self._step_one_slot(slot, tok)

    def _step_one_slot(self, slot: int, token: int):
        """Feed one token into a slot; records the resulting logits as the
        slot's pending next-token distribution.

        Uses per-row positions so concurrent slots at different depths never
        touch each other's cache rows (continuous batching)."""
        toks = np.zeros(self.slots, np.int32)
        toks[slot] = token
        pos = np.maximum(self.pos, 0).astype(np.int32)
        logits, cache = self._decode(
            self.params, self.cache,
            {"token": jnp.asarray(toks), "pos": jnp.asarray(pos)})
        self.cache = cache
        self.pos[slot] += 1
        if not hasattr(self, "_pending"):
            self._pending = np.zeros((self.slots,
                                      logits.shape[-1]), np.float32)
        self._pending[slot] = np.asarray(logits[slot, 0], np.float32)

    def step(self) -> int:
        """One engine step: admit + advance every active slot by one token
        (greedy over its pending logits); returns active request count."""
        self._admit()
        act = [s for s in range(self.slots) if self.active[s] is not None]
        if not act:
            return 0
        for slot in act:
            r = self.active[slot]
            nxt = int(np.argmax(self._pending[slot]))
            r.generated.append(nxt)
            if r.done:
                self._results[r.rid] = r
                self.active[slot] = None
                self.pos[slot] = 0
            else:
                self._step_one_slot(slot, nxt)
        return len(act)

    def run_until_idle(self, max_steps: int = 1000) -> int:
        steps = 0
        while (self._queue or any(a is not None for a in self.active)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return steps
