"""Sharding rules: parameter/activation PartitionSpecs for the production mesh.

Axis roles (DESIGN.md §5):
* ``data``  — DP batch axis AND FSDP weight axis (dim-0/"d_model" rows of
  every large matrix are sharded here, ZeRO-3 style);
* ``model`` — TP/EP axis (heads, d_ff columns, experts, vocab);
* ``pod``   — pure DP replication across pods (gradients cross pods once
  per step; the hierarchical-allreduce target axis).

GVAS mapping (paper §4.3): a jax.Array with a NamedSharding over this mesh
*is* a Global Virtual Address Space — (mesh coords, local index) plays the
role of (node, rank, VA); see ``repro.core.gvas``.

Head/vocab dims that do not divide the TP axis are replicated (kept exact);
padding them to the axis size is a recorded §Perf optimization, not the
baseline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.config import ArchConfig, ShapeConfig
from repro.parallel.ctx import ParallelCtx


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _axis(name, ok: bool):
    return name if ok else None


def param_spec(path: tuple[str, ...], leaf, cfg: ArchConfig,
               pctx: ParallelCtx) -> P:
    """PartitionSpec for one parameter, keyed on its tree path + shape."""
    tp, fsdp = pctx.tp_axis, "data" if "data" in pctx.mesh.axis_names else None
    tp_n = pctx.mesh.shape[tp]
    fsdp_n = pctx.mesh.shape[fsdp] if fsdp else 1
    name = path[-1]
    shape = leaf.shape
    d = cfg.d_model

    def dspec(dim_size):  # FSDP on a d_model-rows dim
        return _axis(fsdp, _div(dim_size, fsdp_n))

    # ---- embeddings
    if name == "tokens":
        return P(_axis(tp, _div(shape[0], tp_n)), dspec(shape[1]))
    if name == "head":
        return P(dspec(shape[0]), _axis(tp, _div(shape[1], tp_n)))
    if name in ("positions", "enc_pos"):
        return P(None, _axis(tp, _div(shape[-1], tp_n)))

    # ---- attention (GQA + MLA)
    if name in ("wq", "wk", "wv"):
        return P(dspec(shape[0]), _axis(tp, _div(shape[1], tp_n)), None)
    if name == "wo":
        return P(_axis(tp, _div(shape[0], tp_n)), None, dspec(shape[2]))
    if name in ("bq", "bk", "bv"):
        return P(_axis(tp, _div(shape[0], tp_n)), None)
    if name == "wq_a":
        return P(dspec(shape[0]), None)
    if name in ("wq_b", "wkv_b"):
        return P(None, _axis(tp, _div(shape[1], tp_n)), None)
    if name == "wkv_a":
        return P(dspec(shape[0]), None)

    # ---- MoE
    if name == "router":
        return P(dspec(shape[0]), None)
    if len(path) >= 2 and "ffn" in path and name in ("w_gate", "w_up",
                                                     "w_out") and len(shape) == 3:
        # stacked expert weights: EP over 'data' (tokens' own axis, so
        # dispatch is a data-axis all_to_all), TP over 'model' on the
        # expert hidden dim — must match apply_moe's shard_map in_specs
        e_ax = _axis(fsdp, _div(shape[0], fsdp_n))
        if name == "w_out":
            return P(e_ax, _axis(tp, _div(shape[1], tp_n)), None)
        return P(e_ax, None, _axis(tp, _div(shape[2], tp_n)))

    # ---- dense MLP (2-D) incl. shared experts / mtp proj
    if name in ("w_gate", "w_up", "proj") and len(shape) == 2:
        return P(dspec(shape[0]), _axis(tp, _div(shape[1], tp_n)))
    if name == "w_out" and len(shape) == 2:
        return P(_axis(tp, _div(shape[0], tp_n)), dspec(shape[1]))
    if name in ("b_up",):
        return P(_axis(tp, _div(shape[0], tp_n)))
    if name in ("b_out",):
        return P(None)

    # ---- Mamba-2
    if name in ("wz", "wx"):
        return P(dspec(shape[0]), _axis(tp, _div(shape[1], tp_n)))
    if name in ("wB", "wC", "wdt"):
        return P(dspec(shape[0]), _axis(tp, _div(shape[1], tp_n)))
    if name in ("conv_x", "conv_B", "conv_C"):
        return P(None, _axis(tp, _div(shape[1], tp_n)))
    if name in ("conv_bx", "conv_bB", "conv_bC", "norm_scale"):
        return P(_axis(tp, _div(shape[0], tp_n)))
    if name in ("A_log", "D", "dt_bias"):
        return P(_axis(tp, _div(shape[0], tp_n)))
    if name == "out_proj":
        return P(_axis(tp, _div(shape[0], tp_n)), dspec(shape[1]))

    # ---- norms & everything small: replicated
    return P()


def _strip_stack_dims(path, leaf, cfg) -> int:
    """Stacked layer params have 1 (scan) or 2 (hybrid group) leading layer
    dims; specs above address the per-layer shape."""
    parts = [p for p in path]
    n = 0
    if any(k in parts for k in ("dense_stack", "moe_stack", "stack",
                                "encoder", "decoder")):
        n = 1
    if "groups" in parts:
        n = 2
    return n


def _path_names(path) -> tuple[str, ...]:
    out = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            out.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            out.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            out.append(str(k.name))
        else:
            out.append(str(k))
    return tuple(out)


class _FakeLeaf:
    def __init__(self, shape):
        self.shape = shape


def param_specs(params, cfg: ArchConfig, pctx: ParallelCtx):
    """PartitionSpec pytree for a parameter pytree (arrays or
    ShapeDtypeStructs)."""
    def one(path, leaf):
        names = _path_names(path)
        nstack = _strip_stack_dims(names, leaf, cfg)
        inner = param_spec(names, _FakeLeaf(leaf.shape[nstack:]), cfg, pctx)
        return P(*([None] * nstack), *tuple(inner))

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params, cfg: ArchConfig, pctx: ParallelCtx):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(pctx.mesh, s),
        param_specs(params, cfg, pctx),
        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(opt_state, params, cfg: ArchConfig, pctx: ParallelCtx):
    """PartitionSpecs for an AdamW state tree: m/v mirror the param specs
    (int8-quantized states are layout-preserving, so the spec transfers;
    per-block scales drop the last axis' sharding if it no longer
    divides)."""
    pspecs = param_specs(params, cfg, pctx)

    def axis_size(ax) -> int:
        if ax is None:
            return 1
        if isinstance(ax, (tuple, list)):
            n = 1
            for a in ax:
                n *= pctx.mesh.shape[a]
            return n
        return pctx.mesh.shape[ax]

    def one(ps, st):
        if isinstance(st, dict) and set(st) == {"q", "scale"}:
            parts = tuple(ps)
            last = parts[-1] if parts else None
            nblk = st["scale"].shape[-1]
            scale_last = last if nblk % axis_size(last) == 0 else None
            return {"q": P(*parts),
                    "scale": P(*parts[:-1], scale_last) if parts else P()}
        return ps

    is_p = lambda x: isinstance(x, P)
    return {
        "m": jax.tree_util.tree_map(one, pspecs, opt_state["m"], is_leaf=is_p),
        "v": jax.tree_util.tree_map(one, pspecs, opt_state["v"], is_leaf=is_p),
        "step": P(),
    }


# ------------------------------------------------------------- activations
def batch_specs(cfg: ArchConfig, shape: ShapeConfig, pctx: ParallelCtx):
    """PartitionSpecs for the input batch of a given assigned shape."""
    dp = pctx.dp_axes
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": P(dp, None)}
        if shape.kind == "train":
            specs["labels"] = P(dp, None)
        if cfg.vision is not None:
            specs["patches"] = P(dp, None, None)
        if cfg.encdec is not None:
            specs["frames"] = P(dp, None, None)
        return specs
    # decode: batch over dp when divisible, else replicate batch and rely
    # on sequence-sharded caches (long_500k, batch=1)
    dp_size = pctx.dp_size
    b_ax = dp if _div(shape.global_batch, dp_size) else None
    return {"token": P(b_ax), "pos": P()}


def cache_specs(cache, cfg: ArchConfig, shape: ShapeConfig,
                pctx: ParallelCtx):
    """PartitionSpecs for KV caches / SSM states.

    Leaves are identified by their dimension SUFFIX pattern (any number of
    leading layer/group stack dims):
      attention KV      (..., B, S, K, hd)  — B over dp; K over tp if it
                        divides, else hd over tp; S over 'data' when the
                        batch cannot shard (long_500k, B=1)
      MLA latent        (..., B, S, r)      — B over dp, r over tp
      SSM state         (..., B, h, p, n)   — B over dp, heads over tp
      conv state        (..., B, W, ch)     — B over dp, channels over tp
    """
    dp = pctx.dp_axes
    dp_size = pctx.dp_size
    tp = pctx.tp_axis
    tp_n = pctx.mesh.shape[tp]
    B, S = shape.global_batch, shape.seq_len
    batch_sharded = _div(B, dp_size)
    b_ax = dp if batch_sharded else None
    seq_ax = "data" if (not batch_sharded and _div(S, dp_size)) else None

    def spec_for(path, leaf):
        names = _path_names(path)
        s = leaf.shape
        name = names[-1] if names else ""
        in_conv = any(n == "conv" for n in names)
        if name in ("k", "v") or (not in_conv and len(s) >= 4
                                  and s[-3] == S):
            # (..., B, S, K, hd)
            nstack = len(s) - 4
            kv_ax = tp if _div(s[-2], tp_n) else None
            hd_ax = tp if kv_ax is None and _div(s[-1], tp_n) else None
            return P(*([None] * nstack), b_ax, seq_ax, kv_ax, hd_ax)
        if name in ("c_kv", "k_rope"):
            # (..., B, S, r)
            nstack = len(s) - 3
            r_ax = tp if _div(s[-1], tp_n) else None
            return P(*([None] * nstack), b_ax, seq_ax, r_ax)
        if in_conv or (name != "ssm" and len(s) >= 3 and s[-2] <= 8):
            # conv state (..., B, W, ch), W = d_conv-1 (tiny)
            nstack = len(s) - 3
            ch_ax = tp if _div(s[-1], tp_n) else None
            return P(*([None] * nstack), b_ax, None, ch_ax)
        # SSM state (..., B, h, p, n)
        nstack = len(s) - 4
        h_ax = tp if _div(s[-3], tp_n) else None
        return P(*([None] * nstack), b_ax, h_ax, None, None)

    return jax.tree_util.tree_map_with_path(spec_for, cache)
