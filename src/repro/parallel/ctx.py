"""Parallel context threaded through model code (mesh + axis roles)."""

from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    mesh: jax.sharding.Mesh
    dp_axes: tuple[str, ...] = ("data",)   # batch/token axes (DP/FSDP)
    tp_axis: str = "model"                 # tensor/expert-parallel axis
    pp_axis: str | None = None             # optional pipeline axis
    #: ZeRO-3 weight gathering: constrain dense layer weights to be
    #: replicated over 'data' inside scan bodies (all-gather the shards)
    #: instead of letting GSPMD psum activations over 'data'.
    gather_weights: bool = False
    #: Megatron-style sequence parallelism for the residual stream: the
    #: saved (remat) activations crossing layer boundaries are sharded over
    #: the TP axis on the sequence dim (16x less activation memory; one
    #: extra all-gather per layer). Enabled by the dry-run for train/prefill.
    seq_shard: bool = False

    @property
    def dp_size(self) -> int:
        return int(__import__("math").prod(
            self.mesh.shape[a] for a in self.dp_axes))

    @property
    def tp_size(self) -> int:
        return int(self.mesh.shape[self.tp_axis])
