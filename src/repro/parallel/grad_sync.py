"""Gradient synchronization strategies (paper C3/C4 as first-class features).

Under GSPMD the per-step gradient collectives are compiler-inserted; these
strategies exist (a) for the manual shard_map DP path used by tests and the
collectives benchmark, and (b) to expose the policy knobs (bucketing,
hierarchy, compression) whose lowered-collective effects are recorded in
EXPERIMENTS.md §Perf.

* ``flat``         — one psum over all DP axes (software-allreduce analog)
* ``hierarchical`` — reduce-scatter(intra) + psum(inter) + all-gather(intra)
                     (the NI Allreduce accelerator schedule, §4.7)
* ``compressed``   — int8-quantized hierarchical sync with error feedback
                     (gradient compression for the slow cross-pod hop)
* ``auto``         — the CollectivePlanner picks one of the above per
                     bucket by predicted cost on the policy's MachineModel
                     (DESIGN.md §3.5); the choice happens at trace time on
                     static byte counts, so it is jit-compatible

Bucketing: gradients are packed into contiguous buckets sized by
CommPolicy.bucket_bytes — the cell/MTU trade-off of §4.2: small enough to
overlap with backward compute, large enough to amortize alpha.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.comm import CommPolicy


# ------------------------------------------------------------------ buckets
def flatten_to_buckets(tree, bucket_bytes: int):
    """Pack a pytree into f32 1-D buckets; returns (buckets, spec) where
    spec allows exact unpacking."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    spec = [(l.shape, l.dtype) for l in leaves]
    flat = [l.astype(jnp.float32).reshape(-1) for l in leaves]
    big = jnp.concatenate(flat) if flat else jnp.zeros((0,), jnp.float32)
    per = max(bucket_bytes // 4, 1)
    buckets = [big[i:i + per] for i in range(0, big.size, per)]
    return buckets, (treedef, spec)


def unflatten_from_buckets(buckets, spec):
    treedef, shapes = spec
    big = jnp.concatenate(buckets) if buckets else jnp.zeros((0,), jnp.float32)
    leaves, off = [], 0
    for shape, dtype in shapes:
        n = 1
        for s in shape:
            n *= s
        leaves.append(big[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, leaves)


# --------------------------------------------------------------- strategies
def plan_bucket_strategy(policy: CommPolicy, nbytes: int,
                         axis_sizes: tuple[int, ...],
                         allow_lossy: bool = False) -> str:
    """Planner-chosen strategy for one bucket of ``nbytes`` over the given
    DP axis sizes (intra first). Pure host-side: no jax, static ints only,
    so ``strategy="auto"`` stays jit-traceable."""
    intra = axis_sizes[0]
    inter = axis_sizes[-1] if len(axis_sizes) > 1 else 1
    return policy.plan_bucket(nbytes, intra, inter,
                              allow_lossy=allow_lossy).schedule


def sync_gradients(grads, mesh, *, strategy: str = "hierarchical",
                   intra_axis: str = "data", inter_axis: str | None = "pod",
                   policy: CommPolicy | None = None, mean_over: int = 1,
                   allow_lossy: bool = False):
    """All-reduce a gradient pytree across DP axes (manual-DP path).

    ``allow_lossy`` only matters for ``strategy="auto"``: it decides
    whether the planner may pick the int8-compressed sync (whose error
    feedback is the caller's job — see :class:`CompressedSync`)."""
    from repro.core.collectives import flat_allreduce, hierarchical_allreduce
    policy = policy or CommPolicy()
    axes = tuple(a for a in (intra_axis, inter_axis)
                 if a and a in mesh.axis_names and mesh.shape[a] > 1)
    if not axes:
        return grads
    axis_sizes = tuple(int(mesh.shape[a]) for a in axes)
    # DP world size is a host-side int; math.prod avoids the device
    # round-trip a jnp.prod would force on every sync call
    buckets, spec = flatten_to_buckets(
        grads, policy.bucket_bytes(math.prod(axis_sizes)))
    out = []
    for b in buckets:
        strat = strategy
        if strategy == "auto":
            strat = plan_bucket_strategy(
                policy, int(b.size) * b.dtype.itemsize, axis_sizes,
                allow_lossy)
        if strat == "flat" or len(axes) == 1:
            r = flat_allreduce(b, mesh, axes)
        elif strat == "hierarchical":
            r = hierarchical_allreduce(b, mesh, intra_axis=axes[0],
                                       inter_axis=axes[-1])
        elif strat == "compressed":
            r = _compressed_allreduce(b, mesh, axes)
        else:
            raise ValueError(strat)
        out.append(r / mean_over)
    return unflatten_from_buckets(out, spec)


def _compressed_allreduce(b, mesh, axes):
    """int8 + per-bucket scale across the slow axis; exact psum on the fast
    axis. Error feedback is the caller's job (see CompressedSync)."""
    from repro.core.collectives import flat_allreduce, hierarchical_allreduce
    if len(axes) == 1:
        return flat_allreduce(b, mesh, axes)

    intra, inter = axes[0], axes[-1]

    def body(x):
        k = jax.lax.axis_size(intra)
        pad = (-x.shape[0]) % k
        if pad:
            x = jnp.pad(x, (0, pad))
        shard = jax.lax.psum_scatter(x, intra, scatter_dimension=0,
                                     tiled=True)
        # quantize only the slow (cross-pod) hop
        scale = jnp.max(jnp.abs(shard)) / 127.0
        scale = jnp.maximum(scale, 1e-20)
        q = jnp.round(shard / scale).astype(jnp.int8)
        # int16 accumulation halves the cross-pod wire bytes and is exact
        # while sum(|q|) <= 255 * 127 < 2^15; wider inter axes fall back to
        # int32 (the planner's compressed cost model mirrors this cutoff)
        acc = jnp.int16 if jax.lax.axis_size(inter) <= 255 else jnp.int32
        qsum = jax.lax.psum(q.astype(acc), inter)
        ssum = jax.lax.psum(scale, inter) / jax.lax.axis_size(inter)
        shard = qsum.astype(jnp.float32) * ssum
        full = jax.lax.all_gather(shard, intra, axis=0, tiled=True)
        return full[:b.shape[0]] if pad else full

    return jax.shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                         check_vma=False)(b)


# -------------------------------------------------------- program emission
def emit_sync_program(nranks: int, bucket_bytes_list, *,
                      compute_us_per_bucket=0.0, algo: str = "auto",
                      overlap_depth: int = 0):
    """Emit the train-step gradient-sync :class:`repro.core.program.Program`
    of a bucketed backward pass: per bucket, the backward-compute slice
    that produces it, then its allreduce.

    This is the Layer-B tie-in to the workload simulator: run it through
    :meth:`ExanetMPI.run_program` or :meth:`MachineModel.cost_program` and
    the planner's per-bucket schedule choices (``algo="auto"``) plus the
    compute/communication overlap of the bucket pipeline become
    inspectable quantities instead of trace-time guesses.

    ``bucket_bytes_list`` is the per-bucket byte count — e.g.
    ``[b.size * b.dtype.itemsize for b in flatten_to_buckets(grads, n)[0]]``
    — and ``compute_us_per_bucket`` a scalar or per-bucket sequence of the
    backward microseconds preceding each bucket's readiness.
    ``overlap_depth > 0`` emits the allreduces *nonblocking*
    (``Collective(handle=...)``): up to ``overlap_depth`` syncs ride
    behind the following buckets' compute, each drained by a ``Wait``
    that many buckets later, with a final ``Wait()`` at the end — the
    overlap seam the train co-sim (DESIGN.md §2.9) searches over.  Pure
    host-side (no jax): callable from tests and benchmarks without
    devices.
    """
    from repro.core.program import Collective, Compute, Program, Wait
    sizes = [int(b) for b in bucket_bytes_list]
    try:
        per_bucket = [float(c) for c in compute_us_per_bucket]
    except TypeError:
        per_bucket = [float(compute_us_per_bucket)] * len(sizes)
    if len(per_bucket) != len(sizes):
        raise ValueError(f"{len(sizes)} buckets but {len(per_bucket)} "
                         f"compute entries")
    ops = []
    for i, (nb, us) in enumerate(zip(sizes, per_bucket)):
        if us > 0.0:
            ops.append(Compute(us))
        if overlap_depth > 0:
            ops.append(Collective("allreduce", max(nb, 1), algo,
                                  handle=f"g{i}"))
            if i - overlap_depth >= 0:
                ops.append(Wait((f"g{i - overlap_depth}",)))
        else:
            ops.append(Collective("allreduce", max(nb, 1), algo))
    if overlap_depth > 0:
        ops.append(Wait())
    return Program(tuple(tuple(ops) for _ in range(nranks)))


# per-machine memo of cost_sync_program_s: the planner/hillclimb inner
# loops hammer identical (nranks, bucket layout, algo) queries, and each
# miss re-emits, re-probes and re-simulates a whole Program.  Weak keys:
# a machine's entry dies with the machine.
_sync_cost_cache: "weakref.WeakKeyDictionary" = None  # built on first use
_sync_cost_stats = {"hits": 0, "misses": 0}


def sync_cost_cache_info() -> dict:
    """Hit/miss counters of the :func:`cost_sync_program_s` memo."""
    size = 0
    if _sync_cost_cache is not None:
        size = sum(len(v) for v in _sync_cost_cache.values())
    return {**_sync_cost_stats, "size": size}


def clear_sync_cost_cache() -> None:
    global _sync_cost_cache
    _sync_cost_cache = None
    _sync_cost_stats["hits"] = _sync_cost_stats["misses"] = 0


def cost_sync_program_s(machine, nranks: int, bucket_bytes_list, *,
                        compute_us_per_bucket=0.0, algo: str = "auto",
                        overlap_depth: int = 0, fidelity: str = "sim",
                        backend: str = "auto") -> float:
    """Predicted seconds of one bucketed gradient sync on a machine: the
    :func:`emit_sync_program` emission costed through
    :meth:`MachineModel.cost_program`.  At ``sim`` fidelity on the ExaNeSt
    machine, ``backend="auto"`` replays the bucket pipeline as a compiled
    level program (collective sites splice their compiled round programs),
    so sweeping bucket layouts is a batched array workload instead of
    per-bucket event interpretation.  Results are memoized per
    (machine, nranks, bucket tuple, compute tuple, algo, overlap depth,
    fidelity, backend) — see :func:`sync_cost_cache_info`.  Pure
    host-side: no jax, callable from tests and benchmarks without
    devices."""
    import inspect
    import weakref as _weakref
    global _sync_cost_cache
    sizes = tuple(int(b) for b in bucket_bytes_list)
    try:
        comp = tuple(float(c) for c in compute_us_per_bucket)
    except TypeError:
        comp = (float(compute_us_per_bucket),) * len(sizes)
    key = (int(nranks), sizes, comp, algo, int(overlap_depth), fidelity,
           backend)
    if _sync_cost_cache is None:
        _sync_cost_cache = _weakref.WeakKeyDictionary()
    try:
        per_machine = _sync_cost_cache.setdefault(machine, {})
    except TypeError:                 # unhashable/unweakrefable machine
        per_machine = None
    if per_machine is not None and key in per_machine:
        _sync_cost_stats["hits"] += 1
        return per_machine[key]
    _sync_cost_stats["misses"] += 1
    prog = emit_sync_program(nranks, sizes, compute_us_per_bucket=comp,
                             algo=algo, overlap_depth=overlap_depth)
    kw = {"fidelity": fidelity}
    # signature probe, not try/except TypeError: a genuine TypeError from
    # inside a machine's sim path must surface, not trigger a silent
    # backend-less recomputation
    if "backend" in inspect.signature(machine.cost_program).parameters:
        kw["backend"] = backend
    out = machine.cost_program(prog, **kw)
    if per_machine is not None:
        per_machine[key] = out
    return out


class CompressedSync:
    """EF-SGD-style error feedback (Karimireddy et al. 2019): the residual
    of the *local* quantization is carried into the next step, keeping the
    compressed sync unbiased over time."""

    def __init__(self, mesh, **kw):
        self.mesh = mesh
        self.kw = kw
        self.residual = None

    @staticmethod
    def _local_quant(g):
        scale = jnp.maximum(jnp.max(jnp.abs(g)) / 127.0, 1e-20)
        return jnp.round(g.astype(jnp.float32) / scale) * scale

    def __call__(self, grads):
        if self.residual is None:
            self.residual = jax.tree_util.tree_map(
                lambda g: jnp.zeros_like(g, jnp.float32), grads)
        e = jax.tree_util.tree_map(
            lambda g, r: g.astype(jnp.float32) + r, grads, self.residual)
        g_hat = jax.tree_util.tree_map(self._local_quant, e)
        self.residual = jax.tree_util.tree_map(jnp.subtract, e, g_hat)
        return sync_gradients(g_hat, self.mesh, strategy="compressed",
                              **self.kw)
