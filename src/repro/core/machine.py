"""MachineModel: the hardware half of the planner/machine split (DESIGN.md §3.5).

A *machine* answers two questions about a target system, and nothing else:

* :meth:`MachineModel.alpha_beta` — the (alpha seconds, beta bytes/s)
  linearization of one communication *level* (``"intra"`` = the fast axis:
  ICI links on TPU, intra-QFDB GTH links on the prototype; ``"inter"`` = the
  slow axis: cross-pod DCN, inter-QFDB SFP+ links);
* :meth:`MachineModel.cost_s` — predicted wall-clock seconds of one
  collective schedule at a chosen *fidelity* (``"analytic"`` closed-form
  alpha-beta, or ``"sim"`` full event simulation where available).

Machines never inspect a schedule's rounds themselves: analytic costs go
through :func:`repro.core.exanet.schedules.alpha_beta_cost_s`, simulated
costs through the event executor (:meth:`ExanetMPI.run_schedule`).  The
:class:`repro.core.planner.CollectivePlanner` is the only caller that ranks
schedules; consumers (CommPolicy, grad_sync, ExanetMPI) talk to the planner.

Two implementations:

* :class:`ExanetMachine` — the ExaNeSt prototype, backed by the event
  engine's :class:`PathMetrics` and the calibrated :class:`HwParams`.
* :class:`TpuMachine` — the TPU v5e target, backed by ``roofline/hw.py``
  constants with per-axis (ICI vs DCN) alphas and bandwidths.
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar, Protocol, runtime_checkable

from repro.core.exanet.schedules import (COLLECTIVE_SCHEDULES,
                                         CollectiveSchedule,
                                         alpha_beta_cost_s)
from repro.roofline.hw import V5E

INTRA = "intra"
INTER = "inter"


def _analytic_coll_us(nranks: int, alpha_s: float, bw_bytes_per_s: float,
                      accel_params=None):
    """Closed-form cost hook for embedded program collectives: alpha-beta
    cost of the named schedule, or of the cheapest feasible candidate when
    ``algo="auto"`` (the analytic twin of the planner's choice).  The §4.7
    accelerator is already a closed form, so ``algo="accel"`` costs it
    directly when the machine has one (``accel_params``); machines
    without an NI accelerator reject it at either fidelity."""
    def _accel_us(nbytes: int):
        """Closed-form accel cost, or None when this machine has no NI
        accelerator / the rank envelope rules it out."""
        if accel_params is None:
            return None
        from repro.core.exanet.allreduce_accel import (accel_cost_us,
                                                       accel_rank_applicable)
        if not accel_rank_applicable(nranks, accel_params):
            return None
        return accel_cost_us(nbytes, nranks, accel_params)

    def cost_us(op: str, nbytes: int, algo: str) -> float:
        if op == "allreduce" and algo == "accel":
            accel = _accel_us(nbytes)
            if accel is None:
                raise ValueError("no NI allreduce accelerator on this "
                                 "machine (or rank count outside its "
                                 "envelope)")
            return accel
        algos = COLLECTIVE_SCHEDULES.get(op)
        if algos is None:
            raise ValueError(f"unknown collective op {op!r}; options: "
                             f"{sorted(COLLECTIVE_SCHEDULES)}")
        if algo == "auto":
            candidates = list(algos.values())
        else:
            if algo not in algos:
                raise ValueError(f"unknown {op} algo {algo!r}; options: "
                                 f"{sorted(algos) + ['auto']}")
            candidates = [algos[algo]]
        best = None
        for cls in candidates:
            sched = cls()
            if not _schedule_feasible(sched, nranks, nbytes):
                continue
            c = alpha_beta_cost_s(sched, nranks, nbytes, alpha_s=alpha_s,
                                  bw_bytes_per_s=bw_bytes_per_s)
            if best is None or c < best:
                best = c
        if op == "allreduce" and algo == "auto":
            # the analytic twin of the planner's choice considers the
            # §4.7 accelerator too (its closed form needs no alpha-beta)
            accel = _accel_us(nbytes)
            if accel is not None and (best is None or accel * 1e-6 < best):
                best = accel * 1e-6
        if best is None:
            raise ValueError(f"no feasible {op} schedule at "
                             f"nranks={nranks} nbytes={nbytes}")
        return best * 1e6
    return cost_us


def _schedule_feasible(schedule: CollectiveSchedule, nranks: int,
                       nbytes: int) -> bool:
    """A schedule is feasible when its round generator accepts the shape
    (power-of-two constraints, minimum rank counts, QFDB multiples)."""
    if nranks < 2:
        return False
    try:
        # every schedule validates its shape before the first yield, so one
        # round is enough — no need to materialize the whole round list
        next(iter(schedule.rounds(nranks, nbytes)), None)
    except (ValueError, AssertionError):
        return False
    return True


@runtime_checkable
class MachineModel(Protocol):
    """What the planner needs from a target system (and nothing more)."""
    name: str
    levels: tuple[str, ...]

    def alpha_beta(self, level: str = INTRA) -> tuple[float, float]:
        """(alpha seconds, beta bytes/s) of a communication level."""
        ...

    def supports(self, schedule: CollectiveSchedule, nranks: int,
                 nbytes: int) -> bool:
        """Can this machine run this schedule at this shape?"""
        ...

    def cost_s(self, schedule: CollectiveSchedule, nranks: int, nbytes: int,
               *, fidelity: str = "analytic", level: str | None = None
               ) -> float:
        """Predicted seconds for one execution of the schedule."""
        ...

    def cost_program(self, prog, *, fidelity: str = "analytic",
                     level: str | None = None,
                     backend: str = "auto") -> float:
        """Predicted seconds for one execution of a whole
        :class:`repro.core.program.Program` (compute + point-to-point +
        embedded collectives, with whatever overlap the program
        expresses).  ``backend`` selects the sim-fidelity executor
        (``"auto"`` | ``"compiled"`` | ``"interp"``); machines without an
        event simulator ignore it."""
        ...

    def cost_program_many(self, progs, *, fidelity: str = "analytic",
                          level: str | None = None,
                          backend: str = "auto") -> list[float]:
        """Batched :meth:`cost_program` — the planner-facing surface the
        sweep consumers call; simulated machines batch
        structurally-identical programs through one compiled replay."""
        ...


@dataclasses.dataclass(frozen=True)
class TpuMachine:
    """TPU v5e mesh: closed-form alpha-beta per axis (roofline/hw.py).

    There is no event simulator for the TPU target, so both fidelities are
    analytic; ``fidelity="sim"`` silently degrades (the planner treats the
    knob as a *maximum* fidelity).
    """
    #: per-collective launch/latency cost over ICI, seconds
    alpha_s: float = 2e-6
    #: cross-pod (DCN) alpha is orders of magnitude worse
    alpha_pod_s: float = 5e-5
    #: ICI per-link bandwidth, bytes/s
    ici_bw: float = V5E.ici_link_bw
    #: cross-pod per-chip bandwidth, bytes/s
    dcn_bw: float = V5E.dcn_bw
    #: HBM bandwidth, bytes/s (costs the quantize/dequantize passes of the
    #: compressed gradient-sync candidate)
    hbm_bw: float = V5E.hbm_bw

    name: ClassVar[str] = "tpu-v5e"
    levels: ClassVar[tuple[str, ...]] = (INTRA, INTER)
    #: rank-placement key for the synthesized-schedule winner cache
    #: (DESIGN.md §2.8): the mesh has one placement
    placement: ClassVar[str] = "mesh"

    def alpha_beta(self, level: str = INTRA) -> tuple[float, float]:
        if level == INTER:
            return self.alpha_pod_s, self.dcn_bw
        return self.alpha_s, self.ici_bw

    def supports(self, schedule: CollectiveSchedule, nranks: int,
                 nbytes: int) -> bool:
        if schedule.name == "allreduce_accel":
            return False  # no NI-resident accelerator on the TPU target
        return _schedule_feasible(schedule, nranks, nbytes)

    def cost_s(self, schedule: CollectiveSchedule, nranks: int, nbytes: int,
               *, fidelity: str = "analytic", level: str | None = None
               ) -> float:
        if nranks < 2:
            return 0.0
        alpha, bw = self.alpha_beta(level or INTRA)
        return alpha_beta_cost_s(schedule, nranks, nbytes,
                                 alpha_s=alpha, bw_bytes_per_s=bw)

    def cost_many(self, schedule: CollectiveSchedule, nranks: int, sizes,
                  *, fidelity: str = "analytic", level: str | None = None,
                  engine=None) -> list[float]:
        """Batched :meth:`cost_s` over a message-size grid.  Closed forms
        have no shared work to amortize, so this is the plain loop — the
        method exists so the planner can batch uniformly across machines
        (``engine``, a scan-backend choice for *simulated* machines, has
        nothing to select here)."""
        return [self.cost_s(schedule, nranks, s, fidelity=fidelity,
                            level=level) for s in sizes]

    def cost_population(self, population, nranks: int, *,
                        fidelity: str = "analytic",
                        level: str | None = None,
                        engine=None) -> list[float]:
        """Per-member cost of a
        :class:`~repro.core.exanet.schedule_algebra.SchedulePopulation`.
        Closed forms share no work across members, so this is the plain
        loop (the uniform search-facing surface; simulated machines
        batch it)."""
        return [self.cost_s(m, nranks, population.nbytes,
                            fidelity=fidelity, level=level)
                for m in population.members]

    def cost_program(self, prog, *, fidelity: str = "analytic",
                     level: str | None = None,
                     backend: str = "auto", engine=None) -> float:
        """Closed-form program time: the TPU target has no event
        simulator, so both fidelities are the contention-free alpha-beta
        walk of :func:`repro.core.program.analytic_program_us` (and
        ``backend`` — an executor choice for *simulated* programs — has
        nothing to select)."""
        from repro.core.program import analytic_program_us
        alpha, bw = self.alpha_beta(level or INTRA)
        res = analytic_program_us(
            prog, alpha_us=alpha * 1e6, bw_bytes_per_us=bw * 1e-6,
            coll_cost_us=_analytic_coll_us(prog.nranks, alpha, bw))
        return res.latency_us * 1e-6

    def cost_program_many(self, progs, *, fidelity: str = "analytic",
                          level: str | None = None,
                          backend: str = "auto",
                          engine=None) -> list[float]:
        """Batched :meth:`cost_program`: closed forms share no work, so
        this is the plain loop (uniform planner-facing surface)."""
        return [self.cost_program(p, fidelity=fidelity, level=level,
                                  backend=backend) for p in progs]

    def memory_pass_s(self, nbytes: int) -> float:
        """One streaming read+write pass over a buffer (HBM roundtrip)."""
        return 2.0 * nbytes / self.hbm_bw


class ExanetMachine:
    """The ExaNeSt prototype, seen through the event engine.

    * ``fidelity="sim"`` replays the schedule on the discrete-event engine
      (R5/DMA/packetizer/link contention included) — the calibrated model
      the paper-validation tests pin.
    * ``fidelity="analytic"`` linearizes a rendez-vous sendrecv step from
      the engine's :class:`PathMetrics` of a representative path per level
      (alpha = handshake + R5 startup + endpoint software, beta = the
      path's single-stream RDMA bandwidth).

    The §4.7 accelerator schedule is costed by its calibrated per-block
    closed form at either fidelity: the event executor models *software*
    endpoints, which is exactly what the NI offload removes.
    """

    name = "exanest-prototype"
    levels = (INTRA, INTER)

    def __init__(self, mpi=None, params=None, faults=None):
        from repro.core.exanet.mpi import ExanetMPI
        if mpi is None:
            from repro.core.exanet.params import DEFAULT
            mpi = ExanetMPI(params or DEFAULT, ranks_per_mpsoc=1,
                            faults=faults)
        self.mpi = mpi
        self.params = mpi.p
        self.faults = mpi.faults
        if self.faults is not None:
            # degraded machines are first-class MachineModel variants: the
            # fault signature scopes every name-keyed cache (synthesized-
            # schedule winners, DESIGN.md §2.8) to this degradation
            self.name = f"exanest-prototype+{self.faults.signature()}"
        self._ab_cache: dict[str, tuple[float, float]] = {}
        self._tiers: dict[int, object] = {}
        self._degraded: dict = {}

    def degraded(self, spec) -> "ExanetMachine":
        """The machine variant operating under ``spec`` (a
        :class:`~repro.core.exanet.faults.FaultSpec`), cached by fault
        signature: same params and placement, fault-aware routes, every
        latency constant carrying the static degradation.  The healthy
        spec returns ``self``."""
        if spec is None or spec.is_empty:
            return self
        cached = self._degraded.get(spec)
        if cached is None:
            from repro.core.exanet.mpi import ExanetMPI
            cached = self._degraded[spec] = ExanetMachine(
                ExanetMPI(self.params, ranks_per_mpsoc=self.mpi._rpm,
                          faults=spec))
        return cached

    @property
    def placement(self) -> str:
        """Rank-placement key for the synthesized-schedule winner cache:
        QFDB-major 1/MPSoC (the §4.7 placement) vs block-packed cores."""
        return "mpsoc" if self.mpi._rpm == 1 else "block"

    def _mpi_for(self, nranks: int):
        """The simulation instance that fits ``nranks``: the calibrated
        prototype when the ranks fit its 512 cores, else a scaled twin
        (same per-component constants, larger mezzanine torus) built once
        per size tier — what lets the planner answer paper-scale
        (1024/4096+) queries the base machine cannot even route."""
        mpi = self.mpi
        if nranks < 2:
            return mpi
        needed = mpi.rank_core(nranks - 1) + 1
        if needed <= mpi.p.n_cores:
            return mpi
        from repro.core.exanet.mpi import ExanetMPI
        from repro.core.exanet.params import scaled_params
        p2 = scaled_params(needed, mpi.p)
        tier = self._tiers.get(p2.n_cores)
        if tier is None:
            tier = self._tiers[p2.n_cores] = ExanetMPI(
                p2, ranks_per_mpsoc=mpi._rpm, faults=mpi.faults)
        return tier

    def _level_alpha_beta(self, level: str) -> tuple[float, float]:
        p = self.params
        # representative single-hop paths: next MPSoC in the QFDB (intra),
        # first MPSoC of the next QFDB across a mezzanine link (inter)
        dst = p.cores_per_mpsoc if level == INTRA else \
            p.cores_per_mpsoc * p.fpgas_per_qfdb
        m = self.mpi.net.path_metrics(0, dst)
        alpha_us = m.handshake_pp_us + p.rdma_startup_us + \
            p.sendrecv_sw_rdv_us
        bw_bytes_per_s = m.rdma_bw_gbps * 1e9 / 8.0
        return alpha_us * 1e-6, bw_bytes_per_s

    def alpha_beta(self, level: str = INTRA) -> tuple[float, float]:
        ab = self._ab_cache.get(level)
        if ab is None:
            ab = self._ab_cache[level] = self._level_alpha_beta(level)
        return ab

    def _default_level(self, nranks: int) -> str:
        """Ranks are 1/MPSoC on this machine: beyond one QFDB the schedule
        crosses the slower inter-QFDB links."""
        return INTRA if nranks <= self.params.fpgas_per_qfdb else INTER

    def supports(self, schedule: CollectiveSchedule, nranks: int,
                 nbytes: int) -> bool:
        if schedule.name == "allreduce_accel":
            # only the hardware (rank) envelope gates the candidate: the
            # historical 4 KB vector cap is the profitability fallback the
            # planner re-derives from cost (Fig. 19 crossover)
            from repro.core.exanet.allreduce_accel import \
                accel_rank_applicable
            return accel_rank_applicable(nranks, self.params)
        return _schedule_feasible(schedule, nranks, nbytes)

    def cost_s(self, schedule: CollectiveSchedule, nranks: int, nbytes: int,
               *, fidelity: str = "sim", level: str | None = None) -> float:
        if nranks < 2:
            return 0.0
        if schedule.name == "allreduce_accel":
            from repro.core.exanet.allreduce_accel import accel_cost_us
            return accel_cost_us(nbytes, nranks, self.params) * 1e-6
        if fidelity == "sim":
            return self._mpi_for(nranks).run_schedule(
                schedule, nbytes, nranks).latency_us * 1e-6
        alpha, bw = self.alpha_beta(level or self._default_level(nranks))
        return alpha_beta_cost_s(schedule, nranks, nbytes,
                                 alpha_s=alpha, bw_bytes_per_s=bw)

    def cost_many(self, schedule: CollectiveSchedule, nranks: int, sizes,
                  *, fidelity: str = "sim", level: str | None = None,
                  engine=None) -> list[float]:
        """Batched :meth:`cost_s` over a message-size grid.  At ``sim``
        fidelity one compiled round program (the schedule lowered once for
        this rank count) serves the whole grid in a single vectorized
        replay — this is what cuts the planner's cold-plan cost from
        per-size event simulation to one batched run.  Serial-chain
        schedules the array executor cannot amortize (see
        ``round_parallelism``) stay on the interpreter.  ``engine``
        selects the replay's scan backend (DESIGN.md §2.5)."""
        sizes = list(sizes)
        if nranks < 2 or not sizes:
            return [0.0] * len(sizes)
        if schedule.name == "allreduce_accel" or fidelity != "sim":
            return [self.cost_s(schedule, nranks, s, fidelity=fidelity,
                                level=level) for s in sizes]
        from repro.core.exanet.exec_compiled import ProgramStructureError
        mpi = self._mpi_for(nranks)
        try:
            if not mpi.compiled_profitable(schedule, nranks):
                raise ProgramStructureError("serial-chain schedule")
            res = mpi.run_schedule_many(schedule, sizes, nranks,
                                        engine=engine)
        except (ProgramStructureError, ValueError):
            # chain-bound, size-varying structure, or a tracing engine:
            # interpret per size
            return [self.cost_s(schedule, nranks, s, fidelity=fidelity,
                                level=level) for s in sizes]
        return [float(us) * 1e-6 for us in res.latency_us]

    def cost_population(self, population, nranks: int, *,
                        fidelity: str = "sim", level: str | None = None,
                        engine=None) -> list[float]:
        """Per-member simulated cost of a
        :class:`~repro.core.exanet.schedule_algebra.SchedulePopulation`
        in ONE batched compiled replay (one batch column per member, one
        lowered program per skeleton x rank count) — the synthesis
        search's fitness call.  Populations whose skeleton the array
        executor cannot amortize fall back to interpreting each member,
        same gate as :meth:`cost_many`."""
        n_members = len(population)
        if nranks < 2 or not n_members:
            return [0.0] * n_members
        if fidelity != "sim":
            alpha, bw = self.alpha_beta(level
                                        or self._default_level(nranks))
            return [alpha_beta_cost_s(m, nranks, population.nbytes,
                                      alpha_s=alpha, bw_bytes_per_s=bw)
                    for m in population.members]
        from repro.core.exanet.exec_compiled import ProgramStructureError
        mpi = self._mpi_for(nranks)
        try:
            if not mpi.compiled_profitable(population, nranks):
                raise ProgramStructureError("serial-chain population")
            res = mpi.run_schedule_population(population, nranks,
                                              engine=engine)
        except (ProgramStructureError, ValueError):
            return [mpi.run_schedule(m, population.nbytes,
                                     nranks).latency_us * 1e-6
                    for m in population.members]
        return [float(us) * 1e-6 for us in res.latency_us]

    def cost_program(self, prog, *, fidelity: str = "sim",
                     level: str | None = None,
                     backend: str = "auto", engine=None) -> float:
        """Program cost on the prototype.  ``fidelity="sim"`` executes the
        program on the event engine of the tier that fits its rank count
        (:meth:`ExanetMPI.run_program`: per-rank cores, contending
        point-to-point flows, embedded collectives at live occupancy) with
        the chosen executor ``backend`` — ``"auto"`` compiles paper-scale
        programs to vectorized level programs
        (:mod:`repro.core.exanet.program_compiled`), which is what makes
        1024-4096-rank weak-scaling queries answerable; ``"analytic"`` is
        the contention-free alpha-beta walk — their gap *is* the
        congestion the retired apps ``alpha`` used to paper over."""
        nranks = prog.nranks
        if nranks < 1:
            return 0.0
        if fidelity == "sim":
            mpi = self._mpi_for(nranks)
            return mpi.run_program(prog, backend=backend,
                                   engine=engine).latency_us * 1e-6
        alpha, bw = self.alpha_beta(level or self._default_level(nranks))
        from repro.core.program import analytic_program_us
        res = analytic_program_us(
            prog, alpha_us=alpha * 1e6, bw_bytes_per_us=bw * 1e-6,
            coll_cost_us=_analytic_coll_us(nranks, alpha, bw,
                                           accel_params=self.params))
        return res.latency_us * 1e-6

    def cost_program_many(self, progs, *, fidelity: str = "sim",
                          level: str | None = None,
                          backend: str = "auto",
                          engine=None) -> list[float]:
        """Batched :meth:`cost_program` over many programs.  At ``sim``
        fidelity, programs are grouped per machine tier and handed to
        :meth:`ExanetMPI.run_program_many`, where structurally-identical
        emissions (a weak/strong sweep at one rank count) become columns
        of a single compiled replay."""
        progs = list(progs)
        if fidelity != "sim":
            return [self.cost_program(p, fidelity=fidelity, level=level,
                                      backend=backend) for p in progs]
        out: list[float] = [0.0] * len(progs)
        tiers: dict[int, list[int]] = {}
        for i, p in enumerate(progs):
            if p.nranks < 1:
                continue
            tiers.setdefault(id(self._mpi_for(p.nranks)), []).append(i)
        for idxs in tiers.values():
            mpi = self._mpi_for(progs[idxs[0]].nranks)
            results = mpi.run_program_many([progs[i] for i in idxs],
                                           backend=backend, engine=engine)
            for i, r in zip(idxs, results):
                out[i] = r.latency_us * 1e-6
        return out

    def cost_program_scenarios(self, prog, *, compute_scale=None,
                               byte_scale=None, site_scale=None,
                               link_scale=None, link_latency_us=None,
                               t0=None, engine=None,
                               check: int = 0, rtol: float = 1e-9):
        """Batched scenario costing of ONE program: bind per-column
        compute skew / payload scale / collective payload scale / link
        degradation / entry clocks onto the compiled artifact of ``prog``
        and replay every column at once
        (:meth:`ExanetMPI.run_program_scenarios` on the tier that fits
        the rank count).  This is the machine-level fast lane the train
        co-sim's candidate populations, the serve step table and the
        Monte-Carlo fault sweeps ride; returns one
        :class:`~repro.core.program.ProgramResult` per column."""
        return self._mpi_for(prog.nranks).run_program_scenarios(
            prog, compute_scale=compute_scale, byte_scale=byte_scale,
            site_scale=site_scale, link_scale=link_scale,
            link_latency_us=link_latency_us,
            t0=t0, engine=engine, check=check, rtol=rtol)

    def memory_pass_s(self, nbytes: int) -> float:
        """One read+write pass on an A53 endpoint (single DDR4 channel is
        the §6.2 bottleneck)."""
        return 2.0 * nbytes / (self.params.a53_copy_bw_bytes_per_us * 1e6)
