"""Contribution-tracking semantic verification for collective schedules.

The synthesis search (:mod:`repro.core.synth.search`) optimizes simulated
*cost*; nothing in the fitness function knows whether a candidate still
computes an allreduce.  This module is the gate that does — the
ROADMAP item 2 equivalence check (Exo's role model): replay a schedule's
annotated data rounds (:class:`~repro.core.exanet.schedule_algebra.DataRound`)
through an exact multiset model and require that **every rank ends
holding every rank's contribution exactly once on every atom**.

State is an integer tensor ``counts[holder, atom, src]`` starting as the
identity (each rank holds its own contribution once).  Within a round
all reads come from the pre-round snapshot (sendrecv semantics); a
``reduce`` send adds the source's multiset into the destination, a
replace send overwrites it.  The final state must be all-ones: a
schedule that double-counts, drops, or misroutes any contribution fails
loudly with the first offending (holder, atom, src) triple.

Menu schedules lower to plain :class:`~repro.core.exanet.schedules.Round`
streams without atom annotations, so this module carries their semantic
twins (:data:`MENU_SEMANTICS`) — the documented dataflow of each
hand-written algorithm in annotated form.  Algebra terms are annotated
natively.
"""

from __future__ import annotations

import numpy as np

from ..exanet.schedule_algebra import (DataRound, DataSend, Split, Term,
                                       TermSchedule)


class SemanticCheckError(Exception):
    """A schedule's dataflow is not an exact-once allreduce."""


def contribution_check(data_rounds, nranks: int, n_atoms: int,
                       *, label: str = "schedule") -> None:
    """Raise :class:`SemanticCheckError` unless the rounds implement an
    exact-once allreduce over ``nranks`` ranks and ``n_atoms`` atoms."""
    counts = np.zeros((nranks, n_atoms, nranks), dtype=np.int32)
    idx = np.arange(nranks)
    counts[idx, :, idx] = 1
    for dr in data_rounds:
        snap = counts.copy()
        replaced = np.zeros((nranks, n_atoms), dtype=bool)
        for s in dr.sends:
            if not (0 <= s.src < nranks and 0 <= s.dst < nranks):
                raise SemanticCheckError(
                    f"{label}: send {s} outside rank range at step {dr.step}")
            if not (0 <= s.a_lo < s.a_hi <= n_atoms):
                raise SemanticCheckError(
                    f"{label}: send {s} outside atom range at step {dr.step}")
            sl = slice(s.a_lo, s.a_hi)
            if s.reduce:
                if replaced[s.dst, sl].any():
                    raise SemanticCheckError(
                        f"{label}: reduce and replace race on rank "
                        f"{s.dst} atoms [{s.a_lo},{s.a_hi}) at step "
                        f"{dr.step}")
                counts[s.dst, sl, :] += snap[s.src, sl, :]
            else:
                if replaced[s.dst, sl].any():
                    raise SemanticCheckError(
                        f"{label}: two replaces on rank {s.dst} atoms "
                        f"[{s.a_lo},{s.a_hi}) at step {dr.step}")
                counts[s.dst, sl, :] = snap[s.src, sl, :]
                replaced[s.dst, sl] = True
    bad = np.argwhere(counts != 1)
    if len(bad):
        h, a, src = (int(v) for v in bad[0])
        raise SemanticCheckError(
            f"{label}: rank {h} ends holding rank {src}'s contribution "
            f"{int(counts[h, a, src])} times on atom {a} "
            f"(expected exactly once); {len(bad)} violations total")


def check_term(term: Term, nranks: int) -> None:
    """Semantic gate for an algebra term at a rank count."""
    term.validate(nranks)
    contribution_check(term.data_rounds(nranks), nranks,
                       term.n_atoms(nranks),
                       label=f"term {term.spec()!r} @ {nranks} ranks")


# ------------------------------------------------ menu semantic twins
def _dr_recursive_doubling(nranks: int):
    rounds = []
    for step in range(nranks.bit_length() - 1):
        d = 1 << step
        rounds.append(DataRound(step, tuple(
            DataSend(r, r ^ d, 0, 1, True) for r in range(nranks)), True))
    return rounds, 1


def _dr_oneshot(nranks: int):
    sends = tuple(DataSend(r, (r + k) % nranks, 0, 1, True)
                  for r in range(nranks) for k in range(1, nranks))
    return [DataRound(0, sends, True)], 1


def _dr_rabenseifner(nranks: int):
    term = Split.balanced(nranks)
    return term.data_rounds(nranks), term.n_atoms(nranks)


def _dr_ring(nranks: int):
    # reduce-scatter: at step t rank r forwards chunk (r - t) mod n, so
    # after n-1 steps rank r holds chunk (r + 1) mod n fully reduced;
    # all-gather: at step t rank r forwards chunk (r + 1 - t) mod n (the
    # one it completed/received most recently), replace semantics
    n = nranks
    rounds = []
    for t in range(n - 1):
        rounds.append(DataRound(t, tuple(
            DataSend(r, (r + 1) % n, (r - t) % n, (r - t) % n + 1, True)
            for r in range(n)), True, "reduce_scatter"))
    for t in range(n - 1):
        rounds.append(DataRound(n - 1 + t, tuple(
            DataSend(r, (r + 1) % n, (r + 1 - t) % n, (r + 1 - t) % n + 1,
                     False)
            for r in range(n)), True, "all_gather"))
    return rounds, n


def _dr_accel(nranks: int):
    # semantic twin of schedules.HierarchicalAccelAllreduce, including
    # the MPICH-style fold/unfold pre/post steps for non-power-of-two
    # QFDB counts
    q = 4
    if nranks % q or nranks < q:
        raise ValueError(f"accel needs a multiple of {q} ranks")
    n_g = nranks // q
    servers = [i * q for i in range(n_g)]
    rounds = [DataRound(0, tuple(
        DataSend(s + c, s, 0, 1, True)
        for s in servers for c in range(1, q)), False, "client_reduce")]
    step = 1
    pow2 = 1 << (n_g.bit_length() - 1)
    if pow2 < n_g:
        rounds.append(DataRound(step, tuple(
            DataSend(servers[i], servers[i - pow2], 0, 1, True)
            for i in range(pow2, n_g)), False, "server_fold"))
        step += 1
    d = 1
    while d < pow2:
        rounds.append(DataRound(step, tuple(
            DataSend(servers[i], servers[i ^ d], 0, 1, True)
            for i in range(pow2)), True, "server_exchange"))
        step, d = step + 1, d * 2
    if pow2 < n_g:
        rounds.append(DataRound(step, tuple(
            DataSend(servers[i - pow2], servers[i], 0, 1, False)
            for i in range(pow2, n_g)), False, "server_unfold"))
        step += 1
    rounds.append(DataRound(step, tuple(
        DataSend(s, s + c, 0, 1, False)
        for s in servers for c in range(1, q)), False, "client_broadcast"))
    return rounds, 1


#: allreduce candidate name -> nranks -> (data rounds, n_atoms); every
#: name the planner can emit must appear here or be a synth term
MENU_SEMANTICS = {
    "recursive_doubling": _dr_recursive_doubling,
    "oneshot": _dr_oneshot,
    "rabenseifner": _dr_rabenseifner,
    "ring": _dr_ring,
    "accel": _dr_accel,
}


def check_allreduce(name_or_schedule, nranks: int) -> None:
    """Semantic gate for anything the planner can emit: a menu algorithm
    name, an ``"synth:..."`` name (resolved through the registry), or a
    :class:`TermSchedule` instance."""
    obj = name_or_schedule
    if isinstance(obj, str) and obj.startswith("synth:"):
        from .search import registered
        sched = registered(obj)
        if sched is None:
            raise SemanticCheckError(f"unknown synthesized schedule {obj!r}")
        obj = sched
    if isinstance(obj, TermSchedule):
        check_term(obj.term, nranks)
        return
    if isinstance(obj, str):
        emitter = MENU_SEMANTICS.get(obj)
        if emitter is None:
            raise SemanticCheckError(
                f"no semantic model for menu algorithm {obj!r}")
        rounds, n_atoms = emitter(nranks)
        contribution_check(rounds, nranks, n_atoms,
                           label=f"{obj} @ {nranks} ranks")
        return
    raise SemanticCheckError(
        f"cannot semantically check {type(obj).__name__}")
