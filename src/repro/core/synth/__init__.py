"""Collective-schedule synthesis: semantic verification
(:mod:`repro.core.synth.verify`), population search + winner cache
(:mod:`repro.core.synth.search`) over the round algebra of
:mod:`repro.core.exanet.schedule_algebra`."""
