"""GVAS (§4.3) -> jax.Array mapping.

The paper's 80-bit Global Virtual Address is (PDID | node | rank | VA):
any NI can read/write any process's memory through SMMU translation. The
TPU/JAX analog is a global ``jax.Array`` over the mesh:

  PDID  -> the Mesh itself (a protection/process-group boundary)
  node  -> mesh coordinates of a chip
  rank  -> the named-axis index along each mesh axis
  VA    -> index into the addressable global array; NamedSharding is the
           translation table ("SMMU") from global index to (chip, local)

``addr_of`` / ``shard_of`` make the mapping concrete; they are used by the
checkpoint re-sharder and tested in tests/test_gvas.py.
"""

from __future__ import annotations

import numpy as np
import jax
from jax.sharding import NamedSharding


def addr_of(arr: jax.Array, global_index: tuple[int, ...]) -> dict:
    """The GVAS 'address' of one element: owning device(s) + local index."""
    sharding = arr.sharding
    assert isinstance(sharding, NamedSharding)
    out = []
    for dev, idx in sharding.devices_indices_map(arr.shape).items():
        local = []
        inside = True
        for (gi, sl, dim) in zip(global_index, idx, arr.shape):
            start = sl.start or 0
            stop = sl.stop if sl.stop is not None else dim
            if not (start <= gi < stop):
                inside = False
                break
            local.append(gi - start)
        if inside:
            out.append({"device": dev.id, "local_index": tuple(local)})
    return {"global_index": tuple(global_index), "replicas": out}


def shard_of(arr: jax.Array, device_id: int) -> np.ndarray | None:
    """The local VA window of one device (None if it holds no shard)."""
    for s in arr.addressable_shards:
        if s.device.id == device_id:
            return np.asarray(s.data)
    return None


def global_bytes(arr: jax.Array) -> int:
    return int(np.prod(arr.shape)) * arr.dtype.itemsize
