"""Topology-aware collectives — the paper's Allreduce accelerator (§4.7)
re-thought for the TPU mesh (Layer B of DESIGN.md).

The accelerator's 3-phase structure maps axis-for-axis onto the mesh:

  paper                          TPU adaptation
  ─────────────────────────────  ─────────────────────────────────────────
  level 0: intra-QFDB clients    reduce-scatter along the *intra* (fast)
  send to the server FPGA        mesh axis — each chip ends up owning a
                                 1/k shard of the partially-reduced vector
  levels 1..log2(N)-1: servers   all-reduce of the 1/k-size shard along
  recursive-double inter-QFDB    the *inter* (slow/cross-pod) axis
  final level: servers           all-gather along the intra axis
  broadcast to clients

Cross-inter-axis traffic drops by the intra-axis size (the accelerator's
4x = QFDB size; here 16x = the intra axis), which is the entire point when
the inter axis is cross-pod DCN. The reduction arithmetic itself is the
``allreduce_combine`` Pallas kernel on TPU backends.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _flat_body(x, axes):
    return jax.lax.psum(x, axes)


def _hier_body(x, intra_axis, inter_axis):
    n = x.shape[0]
    k = jax.lax.axis_size(intra_axis)
    pad = (-n) % k
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    # phase 1: reduce-scatter along the fast axis (intra-QFDB reduce)
    shard = jax.lax.psum_scatter(x, intra_axis, scatter_dimension=0,
                                 tiled=True)
    # phase 2: recursive doubling across the slow axis (server exchange)
    shard = jax.lax.psum(shard, inter_axis)
    # phase 3: broadcast back along the fast axis
    full = jax.lax.all_gather(shard, intra_axis, axis=0, tiled=True)
    return full[:n] if pad else full


def hierarchical_allreduce(x: jnp.ndarray, mesh, *, intra_axis: str = "data",
                           inter_axis: str = "pod") -> jnp.ndarray:
    """All-reduce a replicated array over (intra x inter) mesh axes with the
    accelerator's hierarchical schedule. ``x`` is flattened over dim 0."""
    body = functools.partial(_hier_body, intra_axis=intra_axis,
                             inter_axis=inter_axis)
    fn = jax.shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                       check_vma=False)  # replicated-ness over unused axes
    return fn(x)


def flat_allreduce(x: jnp.ndarray, mesh, axes: tuple[str, ...]) -> jnp.ndarray:
    """Single-phase psum over all axes (the software-allreduce baseline)."""
    fn = jax.shard_map(functools.partial(_flat_body, axes=axes), mesh=mesh,
                       in_specs=P(), out_specs=P(), check_vma=False)
    return fn(x)


def hierarchical_collective_bytes(n_bytes: int, intra: int, inter: int
                                  ) -> dict:
    """Napkin model of wire bytes per chip for both schedules (used by the
    CommPolicy and the collectives benchmark).

    ring all-reduce over p chips moves 2(p-1)/p * n bytes per chip; the
    hierarchical schedule moves 2(k-1)/k * n on the intra axis and
    2(m-1)/m * n/k on the inter axis."""
    p = intra * inter
    flat = {"total": 2 * (p - 1) / p * n_bytes,
            "inter": 2 * (inter - 1) / inter * n_bytes}  # flat ring crosses
    hier = {"intra": 2 * (intra - 1) / intra * n_bytes,
            "inter": 2 * (inter - 1) / inter * n_bytes / intra}
    hier["total"] = hier["intra"] + hier["inter"]
    return {"flat": flat, "hier": hier,
            "inter_reduction": flat["inter"] / max(hier["inter"], 1e-12)}
