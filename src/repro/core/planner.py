"""CollectivePlanner: schedule selection by simulated cost (DESIGN.md §3.5).

The paper's headline result is that *choosing the communication mechanism per
message* is what makes the interconnect fast: eager vs rendez-vous at 32 B
(§5.2.1), software vs NI-accelerated allreduce with up to 88% latency
reduction below a crossover vector size (§6.2).  The repo used to hard-code
each of those choices in a different layer; the planner is the one place
they are all derived from machine cost.

Given (collective op, payload bytes, participants per mesh axis) the planner
enumerates candidate schedules from :mod:`repro.core.exanet.schedules`,
costs each on a :class:`repro.core.machine.MachineModel` at the requested
``fidelity`` (``"analytic"`` alpha-beta closed forms or ``"sim"`` full event
simulation where the machine has one), and returns a memoized :class:`Plan`
carrying the chosen executor key, its predicted cost, and every candidate's
cost for auditability.

Design rules (enforced by import structure, see DESIGN.md §3.5):

* the planner never sees jax — it works on byte counts and axis sizes, so
  it can run at trace time inside a jitted training step;
* machines never see schedules' internals — costs go through
  ``alpha_beta_cost_s`` or the event executor;
* plans are frozen value objects; repeated queries are cache hits.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

from repro.core.exanet.schedules import (HierarchicalAccelAllreduce,
                                         OneShotAllreduce,
                                         RabenseifnerAllreduce,
                                         RecursiveDoublingAllreduce,
                                         RingAllreduce)
from repro.core.machine import INTER, INTRA, MachineModel


# ----------------------------------------------------- closed-form anchors
def oneshot_cost_s(nbytes: int, p: int, bw: float, alpha: float) -> float:
    """All-gather everything + local reduce: 1 phase, alpha-cheap,
    bandwidth-expensive (the packetizer analog).  Identical to the
    alpha-beta cost of :class:`OneShotAllreduce` by construction."""
    if p <= 1:
        return 0.0
    return alpha + (p - 1) * nbytes / bw


def ring_cost_s(nbytes: int, p: int, bw: float, alpha: float) -> float:
    """Bandwidth-optimal ring: 2(p-1) rounds moving size/p chunks (the
    rendez-vous analog)."""
    if p <= 1:
        return 0.0
    return 2 * (p - 1) * alpha + 2 * (p - 1) / p * nbytes / bw


def crossover_bytes(cost_small: Callable[[int], float],
                    cost_large: Callable[[int], float],
                    *, hi: int = 1 << 32) -> int:
    """Smallest message size at which ``cost_small`` stops winning, found by
    bisection (assumes the sign of the difference flips at most once, which
    holds whenever ``cost_small`` has the steeper per-byte slope).  Returns
    ``hi`` when ``cost_small`` wins everywhere."""
    lo = 1
    while lo < hi:
        mid = (lo + hi) // 2
        if cost_small(mid) <= cost_large(mid):
            lo = mid + 1
        else:
            hi = mid
    return lo


# ------------------------------------------------------------------- plans
@dataclasses.dataclass(frozen=True)
class Plan:
    """Outcome of one planning query: the chosen executor key plus every
    candidate's predicted cost (seconds), for auditing and benchmarks."""
    op: str
    nbytes: int
    participants: tuple[int, ...]
    schedule: str                        # chosen executor key
    cost_s: float                        # predicted cost of the choice
    costs: tuple[tuple[str, float], ...]  # every feasible candidate
    fidelity: str
    machine: str
    #: candidate source of the winner: ``"menu"`` (hand-written schedule
    #: or the §4.7 accelerator) or ``"synthesized"`` (winner-cache term)
    provenance: str = "menu"
    #: fractional cost advantage of the winner over the best candidate
    #: from the *other* source (0.0 when only one source was feasible):
    #: how much the synthesis search actually buys (or forgoes) here
    margin: float = 0.0

    def cost_of(self, name: str) -> float | None:
        for k, v in self.costs:
            if k == name:
                return v
        return None


@dataclasses.dataclass(frozen=True)
class TrainSyncPlan:
    """Outcome of one train-sync planning run
    (:meth:`CollectivePlanner.plan_train_sync`): the simulated-best
    gradient-sync candidate next to the analytic-policy baseline, with
    the step-time margin that justifies (or refutes) a flip."""
    arch: str
    nranks: int
    chosen: object                  #: winning repro.train.cosim.SyncCandidate
    step_us: float                  #: its simulated step time
    baseline: object                #: the analytic CommPolicy candidate
    baseline_step_us: float         #: its simulated step time
    flipped: bool                   #: does the decision differ at all?
    flip_kinds: tuple[str, ...]     #: which knobs differ
    margin: float                   #: (baseline - chosen) / baseline
    evaluated: int                  #: candidates costed (batched)
    machine: str
    fidelity: str = "sim"


#: software allreduce candidates, in tie-breaking preference order
#: (latency-optimal first: ties at tiny sizes resolve to the eager path)
ALLREDUCE_CANDIDATES: tuple[tuple[str, type], ...] = (
    ("oneshot", OneShotAllreduce),
    ("recursive_doubling", RecursiveDoublingAllreduce),
    ("rabenseifner", RabenseifnerAllreduce),
    ("ring", RingAllreduce),
    ("accel", HierarchicalAccelAllreduce),
)

GRAD_SYNC_STRATEGIES = ("flat", "hierarchical", "compressed")


class CollectivePlanner:
    """Cost-driven collective schedule selection on one machine model."""

    def __init__(self, machine: MachineModel, *, fidelity: str = "analytic",
                 engine=None, synth_cache="default"):
        """``engine`` — scan backend forwarded to the machine's batched
        ``sim``-fidelity costing (:meth:`plan_many`; ``"numpy"`` default |
        ``"jax"``, DESIGN.md §2.5).  Plans are engine-independent (the
        engines agree to 1e-9), so the cache never keys on it.

        ``synth_cache`` — the synthesized-schedule candidate source
        (DESIGN.md §2.8): ``"default"`` loads the committed
        ``core/synth/winners.json`` artifact, ``None`` disables
        synthesized candidates, a path or
        :class:`repro.core.synth.search.WinnerCache` uses that cache.
        Cached winners whose ``(machine, op, nranks, size-bucket,
        placement)`` key matches a query are costed alongside the menu —
        never trusted blindly — so ``allreduce(algo="auto")`` and
        ``grad_sync(strategy="auto")`` pick them up only where they
        actually win at this machine's fidelity."""
        self.machine = machine
        self.fidelity = fidelity
        self.engine = engine
        self._synth_cache_arg = synth_cache
        self._synth_cache = None if synth_cache is None else "unresolved"
        self._cache: dict[tuple, Plan] = {}
        self._hits = 0
        self._misses = 0
        self._synth_candidates = 0
        self._synth_wins = 0

    # ------------------------------------------------------------- caching
    def cache_info(self) -> dict:
        total = self._hits + self._misses
        return {"hits": self._hits, "misses": self._misses,
                "size": len(self._cache),
                "hit_rate": self._hits / total if total else 0.0,
                "synth_candidates": self._synth_candidates,
                "synth_wins": self._synth_wins}

    # --------------------------------------------- synthesized candidates
    def _winner_cache(self):
        if self._synth_cache == "unresolved":
            from repro.core.synth.search import resolve_cache
            self._synth_cache = resolve_cache(self._synth_cache_arg)
        return self._synth_cache

    def _synth_candidate(self, op: str, p: int, nbytes: int):
        """(name, schedule) of the cached synthesized winner matching
        this query's cell, or None.  Counts lookups that produced a
        candidate (``cache_info()["synth_candidates"]``)."""
        cache = self._winner_cache()
        if cache is None:
            return None
        entry = cache.get(self.machine.name, op, p, nbytes,
                          getattr(self.machine, "placement", "default"))
        if entry is None:
            return None
        sched = cache.schedule(entry)
        if not self.machine.supports(sched, p, nbytes):
            return None
        self._synth_candidates += 1
        return sched.name, sched

    def resolve_schedule(self, plan: Plan):
        """The executable schedule object behind a plan's chosen key
        (menu class instance, accelerator schedule, or the registered
        synthesized term)."""
        if plan.schedule.startswith("synth:"):
            from repro.core.synth.search import registered
            sched = registered(plan.schedule)
            if sched is None:
                raise ValueError(f"synthesized schedule {plan.schedule!r} "
                                 "is not registered")
            return sched
        for name, factory in ALLREDUCE_CANDIDATES:
            if name == plan.schedule:
                return factory()
        raise ValueError(f"no schedule object for {plan.schedule!r}")

    # ------------------------------------------------------------ planning
    def plan(self, op: str, nbytes: int, participants: tuple[int, ...] | int,
             *, fidelity: str | None = None, allow_lossy: bool = False) -> Plan:
        """Memoized plan for one collective.

        ``op="allreduce"``: participants collapse to one rank count; the
        candidates are every schedule in :data:`ALLREDUCE_CANDIDATES` the
        machine supports (including the §4.7 accelerator where applicable).

        ``op="grad_sync"``: participants are ``(intra, inter)`` mesh-axis
        sizes; the candidates are the bucket strategies ``flat`` /
        ``hierarchical`` / ``compressed`` of
        :func:`repro.parallel.grad_sync.sync_gradients`.
        The int8-quantized candidate is only considered with
        ``allow_lossy=True`` — lossy compression must be an explicit caller
        decision, never a silent cost win (its error feedback lives in
        ``CompressedSync``).
        """
        if isinstance(participants, int):
            participants = (participants,)
        participants = tuple(int(p) for p in participants)
        nbytes = int(nbytes)
        fidelity = fidelity or self.fidelity
        key = (op, nbytes, participants, fidelity, allow_lossy)
        plan = self._cache.get(key)
        if plan is not None:
            self._hits += 1
            return plan
        self._misses += 1
        if op == "allreduce":
            plan = self._plan_allreduce(nbytes, participants, fidelity)
        elif op == "grad_sync":
            plan = self._plan_grad_sync(nbytes, participants, fidelity,
                                        allow_lossy)
        else:
            raise ValueError(f"unknown collective op {op!r}; "
                             f"options: ['allreduce', 'grad_sync']")
        self._cache[key] = plan
        return plan

    def plan_many(self, op: str, sizes, participants: tuple[int, ...] | int,
                  *, fidelity: str | None = None,
                  allow_lossy: bool = False) -> list[Plan]:
        """Memoized plans for a whole message-size grid.

        For ``op="allreduce"`` the uncached sizes are costed in batch: one
        :meth:`MachineModel.cost_many` call per candidate schedule, which
        at ``sim`` fidelity reuses one compiled round program across the
        grid (``exec_compiled``) instead of event-interpreting every
        (schedule, size) pair — the cold-plan path of a sweep drops from
        O(sizes) simulations per candidate to one.  Results land in the
        same plan cache :meth:`plan` uses, so single-size queries keep
        hitting them."""
        if isinstance(participants, int):
            participants = (participants,)
        participants = tuple(int(p) for p in participants)
        fidelity = fidelity or self.fidelity
        sizes = [int(s) for s in sizes]
        missing = [s for s in dict.fromkeys(sizes)
                   if (op, s, participants, fidelity, allow_lossy)
                   not in self._cache]
        if op == "allreduce" and missing:
            p = math.prod(participants)
            m = self.machine
            costs_by_size: dict[int, list] = {s: [] for s in missing}
            for name, factory in ALLREDUCE_CANDIDATES:
                sched = factory()
                # supports() is by-contract byte-dependent: gate per size
                # (exactly like plan()) and batch over the feasible subset
                feasible = [s for s in missing if m.supports(sched, p, s)]
                if not feasible:
                    continue
                for s, c in zip(feasible, m.cost_many(sched, p, feasible,
                                                      fidelity=fidelity,
                                                      engine=self.engine)):
                    costs_by_size[s].append((name, c))
            # synthesized candidates: one winner-cache entry per size
            # bucket, batched per distinct schedule like the menu
            by_sched: dict[str, tuple] = {}
            for s in missing:
                syn = self._synth_candidate("allreduce", p, s)
                if syn is not None:
                    by_sched.setdefault(syn[0], (syn[1], []))[1].append(s)
            for name, (sched, ss) in by_sched.items():
                for s, c in zip(ss, m.cost_many(sched, p, ss,
                                                fidelity=fidelity,
                                                engine=self.engine)):
                    costs_by_size[s].append((name, c))
            for s in missing:
                key = (op, s, participants, fidelity, allow_lossy)
                self._cache[key] = self._pick("allreduce", s, participants,
                                              costs_by_size[s], fidelity)
                self._misses += 1
        return [self.plan(op, s, participants, fidelity=fidelity,
                          allow_lossy=allow_lossy) for s in sizes]

    def plan_program(self, prog, *, fidelity: str | None = None,
                     allow_lossy: bool = False) -> dict:
        """Plan every ``Collective(algo="auto")`` site of a
        :class:`repro.core.program.Program` in one pass.

        Sites are grouped by op and planned through :meth:`plan_many`, so
        at ``sim`` fidelity all sizes of one candidate schedule share a
        single compiled round program instead of being event-interpreted
        per site.  Returns ``{(op, nbytes): Plan}`` — the mapping
        :meth:`repro.core.exanet.mpi.ExanetMPI.run_program` consumes on
        *both* executors: the interpreter resolves each site through
        ``ExanetMPI._resolve_collective_schedule`` at barrier time, and
        the compiled backend resolves through the same method at bind
        time to pick which compiled ``RoundProgram`` to splice — one
        resolution rule, two executors (DESIGN.md §2.5).  Only allreduce
        sites have multiple candidates today; other ops fall back to
        their single shipped schedule at execution time and need no plan.
        """
        sites: dict[str, set[int]] = {}
        for c in prog.collectives():
            if c.algo == "auto" and c.op == "allreduce":
                sites.setdefault(c.op, set()).add(int(c.nbytes))
        out: dict[tuple[str, int], Plan] = {}
        for op, sizes in sites.items():
            ordered = sorted(sizes)
            plans = self.plan_many(op, ordered, (prog.nranks,),
                                   fidelity=fidelity,
                                   allow_lossy=allow_lossy)
            out.update({(op, s): p for s, p in zip(ordered, plans)})
        return out

    def _pick(self, op: str, nbytes: int, participants: tuple[int, ...],
              costs: list[tuple[str, float]], fidelity: str) -> Plan:
        if not costs:
            raise ValueError(f"no feasible schedule for {op} at "
                             f"nbytes={nbytes} participants={participants} "
                             f"on {self.machine.name}")
        best, best_cost = costs[0]
        for name, c in costs[1:]:
            if c < best_cost:
                best, best_cost = name, c
        synth_won = best.startswith("synth:")
        other = [c for name, c in costs
                 if name.startswith("synth:") != synth_won]
        margin = (min(other) - best_cost) / min(other) if other else 0.0
        if synth_won:
            self._synth_wins += 1
        return Plan(op, nbytes, participants, best, best_cost,
                    tuple(costs), fidelity, self.machine.name,
                    provenance="synthesized" if synth_won else "menu",
                    margin=margin)

    def _plan_allreduce(self, nbytes: int, participants: tuple[int, ...],
                        fidelity: str) -> Plan:
        p = math.prod(participants)
        m = self.machine
        costs = []
        for name, factory in ALLREDUCE_CANDIDATES:
            sched = factory()
            if not m.supports(sched, p, nbytes):
                continue
            costs.append((name, m.cost_s(sched, p, nbytes,
                                         fidelity=fidelity)))
        syn = self._synth_candidate("allreduce", p, nbytes)
        if syn is not None:
            name, sched = syn
            costs.append((name, m.cost_s(sched, p, nbytes,
                                         fidelity=fidelity)))
        return self._pick("allreduce", nbytes, participants, costs, fidelity)

    # ------------------------------------------------- gradient-sync plans
    def _best_sw_allreduce_s(self, nbytes: int, p: int, level: str,
                             fidelity: str,
                             exclude: tuple[str, ...] = ("accel",)) -> float:
        """Cheapest feasible *software* allreduce at one level."""
        m = self.machine
        best = None
        for name, factory in ALLREDUCE_CANDIDATES:
            if name in exclude:
                continue
            sched = factory()
            if not m.supports(sched, p, nbytes):
                continue
            c = m.cost_s(sched, p, nbytes, fidelity=fidelity, level=level)
            if best is None or c < best:
                best = c
        syn = self._synth_candidate("allreduce", p, nbytes)
        if syn is not None and syn[0] not in exclude:
            # synthesized winners are software schedules: grad_sync's
            # strategy costing benefits from them transparently
            c = m.cost_s(syn[1], p, nbytes, fidelity=fidelity, level=level)
            if best is None or c < best:
                best = c
        if best is None:
            raise ValueError(f"no software allreduce feasible at p={p}")
        return best

    def _plan_grad_sync(self, nbytes: int, participants: tuple[int, ...],
                        fidelity: str, allow_lossy: bool) -> Plan:
        """Cost the flat / hierarchical / compressed bucket strategies.

        * flat — one allreduce over all k*m ranks; with an inter axis the
          flat schedule crosses the slow links, so it is costed at the
          ``inter`` level (the whole point of DESIGN.md §5's rule that
          cross-pod traffic must never be the flat ring).
        * hierarchical — ring reduce-scatter + all-gather on the intra axis
          (together exactly one ring-allreduce cost) plus an allreduce of
          the 1/k shard on the inter axis.
        * compressed — hierarchical with the inter payload quantized to
          int8 and accumulated in int16 on the wire (half the bytes while
          the inter axis is <=255 wide, matching ``_compressed_allreduce``;
          int32 — no wire saving — beyond that) plus two memory passes
          (quantize + dequantize) over the shard.
        """
        k = participants[0] if participants else 1
        m_axis = participants[1] if len(participants) > 1 else 1
        machine = self.machine
        flat_level = INTER if m_axis > 1 else INTRA
        costs = [("flat", self._best_sw_allreduce_s(
            nbytes, k * m_axis, flat_level, fidelity))]
        if k > 1 and m_axis > 1:
            intra = machine.cost_s(RingAllreduce(), k, nbytes,
                                   fidelity=fidelity, level=INTRA)
            shard = max(1, nbytes // k)
            inter = self._best_sw_allreduce_s(shard, m_axis, INTER, fidelity)
            costs.append(("hierarchical", intra + inter))
            if allow_lossy:
                mem_pass = getattr(machine, "memory_pass_s", lambda nb: 0.0)
                wire = shard // 2 if m_axis <= 255 else shard
                inter_q = self._best_sw_allreduce_s(max(1, wire), m_axis,
                                                    INTER, fidelity)
                costs.append(("compressed",
                              intra + inter_q + 2.0 * mem_pass(shard)))
        return self._pick("grad_sync", nbytes, participants, costs, fidelity)

    # ------------------------------------------------- train-sync planning
    def plan_train_sync(self, sim, *, generations: int = 2,
                        survivors: int = 4, children: int = 4,
                        candidates=None, engine=None, check: int = 0,
                        seed: int = 0) -> TrainSyncPlan:
        """Hillclimb gradient-sync configurations (bucket layout,
        schedule, overlap depth) against *simulated* train-step time
        (DESIGN.md §2.9).

        ``sim`` is the cost oracle and domain surface — anything with
        the :class:`repro.train.cosim.TrainSim` protocol
        (``candidate_grid`` / ``cost_candidates`` / ``mutate`` /
        ``analytic_candidate`` / ``spec`` / ``machine``); the planner
        contributes only the search policy, so it stays import-clean of
        the train layer.  Every generation is costed through the sim's
        batched scenario lane (one compiled replay per structure
        family), which is what makes population search affordable at
        512-4096 ranks.  The returned plan carries the analytic
        ``CommPolicy`` baseline and the step-time margin, i.e. whether
        simulated overlap *flips* the analytic decision."""
        import numpy as np
        rng = np.random.default_rng(seed)
        base_cand = sim.analytic_candidate()
        pop = list(candidates) if candidates is not None \
            else sim.candidate_grid()
        if base_cand not in pop:
            pop.append(base_cand)
        seen = dict(zip(pop, sim.cost_candidates(pop, engine=engine,
                                                 check=check)))
        for _ in range(generations):
            elite = sorted(seen, key=seen.get)[:survivors]
            kids = [sim.mutate(c, rng) for c in elite
                    for _ in range(children)]
            kids = [k for k in dict.fromkeys(kids) if k not in seen]
            if not kids:
                break
            seen.update(zip(kids, sim.cost_candidates(kids, engine=engine,
                                                      check=check)))
        best = min(seen, key=seen.get)
        best_us, base_us = float(seen[best]), float(seen[base_cand])
        kinds = tuple(
            k for k, differs in (
                ("n_buckets", best.n_buckets != base_cand.n_buckets),
                ("algo", best.algo != base_cand.algo),
                ("overlap_depth",
                 best.overlap_depth != base_cand.overlap_depth),
                ("split", best.split != base_cand.split),
            ) if differs)
        return TrainSyncPlan(
            arch=sim.spec.arch, nranks=sim.spec.nranks, chosen=best,
            step_us=best_us, baseline=base_cand, baseline_step_us=base_us,
            flipped=bool(kinds), flip_kinds=kinds,
            margin=(base_us - best_us) / base_us if base_us else 0.0,
            evaluated=len(seen), machine=sim.machine.name)

    # --------------------------------------------------------- thresholds
    def eager_threshold_bytes(self, p: int, *, level: str = INTRA) -> int:
        """Derived eager threshold: the message size below which the
        one-shot (single-alpha, eager-analog) schedule is the *plan* — i.e.
        it beats every other feasible software schedule.  The one-shot
        per-byte slope (p-1)/bw dominates all candidates', so the winner
        flips at most once and bisection applies."""
        if p < 2:
            return 1 << 32
        alpha, bw = self.machine.alpha_beta(level)

        def oneshot(n: int) -> float:
            return oneshot_cost_s(n, p, bw, alpha)

        def best_other(n: int) -> float:
            try:
                return self._best_sw_allreduce_s(
                    n, p, level, "analytic", exclude=("oneshot", "accel"))
            except ValueError:
                return float("inf")

        return crossover_bytes(oneshot, best_other)
