"""Multi-tenant interference: concurrent background Programs contending
on the shared event-engine resources (DESIGN.md §2.10).

The prototype has no inter-job traffic isolation: a neighbour tenant's
RDMA streams share mezzanine-level links and network-MPSoC crossbar ports
with the application.  This module builds *merged* Programs — one rank
set carrying the application's ops, another the background tenant's — so
congestion stays **emergent**: both tenants' sends run through the same
interpreter/compiled transports on the same resources, and slowdown falls
out of link occupancy, never out of a fitted contention model.

Placement matters: under dimension-ordered routing with a full
intra-QFDB crossbar, two tenants occupying *disjoint whole QFDBs* own
disjoint links and never interfere.  Real co-tenancy shares QFDBs — each
tenant gets some MPSoCs of each board, and both tenants' cross-QFDB
traffic funnels through the board's single network MPSoC onto the same
mezzanine links.  :func:`interleave_qfdb` builds that placement;
:func:`merge_tenants` accepts any explicit rank mapping.

The neighbour-load axis rides the batched substrate: background posts get
their own rows of a ``byte_scale`` (n_posts, N) array
(:func:`neighbor_load_byte_scale`), so an interference *curve* — app
efficiency vs. background load — costs one
:meth:`~repro.core.exanet.mpi.ExanetMPI.run_program_scenarios` replay.
The load-0 column is the in-placement baseline (the tenant still posts,
but carries ~0 bytes).

Constraint: embedded ``Collective`` sites span every rank of a Program,
so both tenants must be collective-free (pure point-to-point, e.g.
:func:`~repro.core.program.halo3d`); :func:`merge_tenants` rejects
programs with sites rather than silently simulating a collective that
straddles tenants.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.program import (Collective, Compute, Irecv, Isend, Program,
                                ProgramError, Wait)

#: tag base for background-tenant channels.  Channels are keyed
#: (src, dst, tag) with merged ranks, so collisions with app tags are
#: impossible; the distinct base just makes traces readable.
BG_TAG = 7000


@dataclasses.dataclass(frozen=True)
class TenantMix:
    """A merged two-tenant Program plus the bookkeeping the sweeps need."""
    program: Program
    app_ranks: tuple           # merged rank of each app rank
    bg_ranks: tuple            # merged rank of each background rank
    bg_post_mask: np.ndarray   # (n_posts,) bool, True on background posts

    def app_latency_us(self, result) -> float:
        """The application's finish time: max over *app* rank clocks (the
        merged program's global latency includes the background tenant,
        which deliberately outlives the app)."""
        return max(result.clocks[r] for r in self.app_ranks)


def background_stream(n_bg: int, iters: int, nbytes: int, *,
                      stride: int | None = None,
                      compute_us: float = 0.0) -> Program:
    """A background tenant: ``n_bg`` ranks in pairwise exchange at
    ``stride`` (rank ``r`` partners ``r + stride``; default ``n_bg // 2``
    — long-range traffic that crosses QFDBs and loads the mezzanine
    rings), ``iters`` rounds of Isend/Irecv/Wait of ``nbytes`` each,
    optionally separated by ``compute_us`` of local work (an idle-ish
    tenant).  Ranks without a partner sit out.  Sized by the caller so
    the stream outlives the app under every load column (see
    :func:`size_background`)."""
    if stride is None:
        stride = max(1, n_bg // 2)
    rank_ops = []
    for r in range(n_bg):
        lo = r if (r // stride) % 2 == 0 else r - stride
        partner = r + stride if r == lo else r - stride
        ops: list = []
        if not 0 <= partner < n_bg or partner == r:
            rank_ops.append(())
            continue
        for it in range(iters):
            if compute_us > 0.0:
                ops.append(Compute(us=compute_us))
            tag = BG_TAG + it
            ops.append(Isend(partner, nbytes, tag=tag))
            ops.append(Irecv(partner, nbytes, tag=tag))
            ops.append(Wait())
        rank_ops.append(tuple(ops))
    return Program(tuple(rank_ops))


def interleave_qfdb(n_app: int, n_bg: int,
                    cores_per_qfdb: int = 16) -> tuple[tuple, tuple]:
    """Co-tenant placement: walk QFDBs, giving the first half of each
    board's cores to the app and the second half to the background
    tenant, until both are placed.  Cross-QFDB traffic of *both* tenants
    then shares each board's network MPSoC and its mezzanine links — the
    physical medium of multi-tenant interference.  Returns
    (app_ranks, bg_ranks) merged-rank mappings."""
    half = cores_per_qfdb // 2
    app, bg = [], []
    core = 0
    while len(app) < n_app or len(bg) < n_bg:
        for i in range(half):
            if len(app) < n_app:
                app.append(core + i)
        for i in range(half):
            if len(bg) < n_bg:
                bg.append(core + half + i)
        core += cores_per_qfdb
    return tuple(app), tuple(bg)


def _check_p2p(prog: Program, who: str) -> None:
    for ops in prog.rank_ops:
        for op in ops:
            if isinstance(op, Collective):
                raise ProgramError(
                    f"merge_tenants: {who} program has a Collective "
                    "site; collectives span every rank of a Program, so "
                    "a merged tenant mix must be point-to-point only")


def merge_tenants(app: Program, bg: Program, app_ranks=None,
                  bg_ranks=None) -> TenantMix:
    """Merge two tenants into one Program over the union of their
    placements.  ``app_ranks`` / ``bg_ranks`` map tenant rank -> merged
    rank (default: app on [0, n_app), background appended after it — a
    whole-QFDB split; pass :func:`interleave_qfdb` mappings for shared
    boards).  Peers inside each tenant's ops are remapped; unassigned
    merged ranks idle.  The returned mask marks background posts in the
    merged program's static post order (rank-major, program order — the
    ``byte_scale`` row order of ``run_program_scenarios``)."""
    _check_p2p(app, "app")
    _check_p2p(bg, "background")
    if app_ranks is None:
        app_ranks = tuple(range(app.nranks))
    if bg_ranks is None:
        bg_ranks = tuple(range(app.nranks, app.nranks + bg.nranks))
    app_ranks, bg_ranks = tuple(app_ranks), tuple(bg_ranks)
    if len(app_ranks) != app.nranks or len(bg_ranks) != bg.nranks:
        raise ValueError(f"rank maps must cover both tenants: "
                         f"{len(app_ranks)} vs {app.nranks} app, "
                         f"{len(bg_ranks)} vs {bg.nranks} bg")
    overlap = set(app_ranks) & set(bg_ranks)
    if overlap:
        raise ValueError(f"tenants overlap on merged ranks {sorted(overlap)[:4]}")

    def remap(ops, m):
        row = []
        for op in ops:
            if isinstance(op, Isend):
                row.append(dataclasses.replace(op, dst=m[op.dst]))
            elif isinstance(op, Irecv):
                row.append(dataclasses.replace(op, src=m[op.src]))
            else:
                row.append(op)
        return tuple(row)

    total = max((*app_ranks, *bg_ranks)) + 1
    merged: list = [()] * total
    is_bg: list = [False] * total
    for i, r in enumerate(app_ranks):
        merged[r] = remap(app.rank_ops[i], app_ranks)
    for i, r in enumerate(bg_ranks):
        merged[r] = remap(bg.rank_ops[i], bg_ranks)
        is_bg[r] = True
    mask = np.array([is_bg[r] for r in range(total)
                     for op in merged[r]
                     if isinstance(op, (Isend, Irecv))], dtype=bool)
    return TenantMix(Program(tuple(merged)), app_ranks, bg_ranks, mask)


def neighbor_load_byte_scale(mix: TenantMix, loads) -> np.ndarray:
    """The neighbour-load axis: (n_posts, N) ``byte_scale`` columns that
    scale only the background tenant's payloads.  ``loads`` is the (N,)
    relative background intensity (0 silences the tenant — posts still
    fire but carry ~0 bytes; 1 is the nominal stream)."""
    loads = np.asarray(loads, dtype=np.float64)
    if loads.ndim != 1:
        raise ValueError(f"loads must be (N,); got shape {loads.shape}")
    if (loads < 0).any():
        raise ValueError("negative background load")
    bs = np.ones((len(mix.bg_post_mask), len(loads)))
    bs[mix.bg_post_mask] = loads
    return bs


def size_background(app_us: float, iters_hint: int,
                    round_us: float) -> int:
    """Iterations needed for the background stream to outlive the app:
    ceil(app_us / round_us) with a floor of ``iters_hint`` (round_us is
    the tenant's own per-iteration time, measured or estimated by the
    caller)."""
    if round_us <= 0:
        return iters_hint
    return max(iters_hint, int(np.ceil(app_us / round_us)))
