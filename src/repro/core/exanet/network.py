"""ExaNet message engine: closed-form latency/bandwidth + resource contention.

Implements the transports of §4.4-4.5:

* **eager** (packetizer -> mailbox): small messages (<=32 B MPI payload) in a
  single ExaNet packet, end-to-end acknowledged in hardware.
* **rendez-vous** (RTS/CTS over packetizer + RDMA engine data movement): the
  R5 transaction layer splits transfers into 16 KB blocks; the Send engine
  segments blocks into 256+32 B cells (store-and-forward read of each cell
  payload, cut-through in the network, §4.2).

The closed forms are calibrated from component measurements (see
``params.py``) and reproduce the paper's end-to-end numbers; the *event* API
adds resource contention (per-MPSoC R5 firmware, AXI/DMA wire, packetizer)
so that collective schedules exhibit the sharing effects of §6.1.4.  The
shared-resource bookkeeping itself lives in :mod:`repro.core.exanet.sim`;
``Network`` contributes the hardware math and drives the engine.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.exanet import sim
from repro.core.exanet.params import DEFAULT, HwParams
from repro.core.exanet.sim import Engine, PathMetrics, TraceEvent
from repro.core.exanet.topology import INTRA_QFDB, MEZZ, Path, Topology

EAGER = "eager"
RDV = "rendezvous"


def _gbps_to_bytes_per_us(gbps: float) -> float:
    return gbps * 1000.0 / 8.0  # 1 Gb/s = 125 B/us


@dataclasses.dataclass(slots=True)
class SendResult:
    t_depart: float      # when the send call was issued
    t_complete: float    # when the payload fully arrived at the receiver
    t_sender_free: float # when the sender returns from the blocking send


@dataclasses.dataclass(slots=True)
class P2PResult:
    """Outcome of one *nonblocking* matched point-to-point transfer."""
    t_send_done: float   # when MPI_Wait on the Isend request would return
    t_recv_done: float   # when MPI_Wait on the Irecv request would return
    transport: str       # "eager" | "rendezvous"


class Network:
    """Latency/bandwidth model with optional resource contention."""

    def __init__(self, topo: Topology | None = None, params: HwParams = DEFAULT,
                 *, engine: Engine | None = None, trace: bool = False):
        self.p = params
        self.topo = topo or Topology(params)
        self.engine = engine or Engine(trace=trace)
        # hot-loop scalars (send() runs hundreds of thousands of times in a
        # paper-scale sweep; one attribute hop instead of two)
        self._eager_max = params.mpi_eager_max_bytes
        self._pktz_occ = params.pktz_occupancy_us
        self._pktz_ret = params.pktz_occupancy_us + params.a53_call_overhead_us
        self._r5_occ = params.r5_occupancy_us
        self._rdma_startup = params.rdma_startup_us
        self.reset()

    # ---------------------------------------------------------------- state
    def reset(self) -> None:
        self.engine.reset()

    @property
    def trace(self) -> list[TraceEvent]:
        return self.engine.trace

    # ------------------------------------------------------------ wire math
    def link_rate_gbps(self, kind: str) -> float:
        return (self.p.rate_intra_qfdb_gbps if kind == INTRA_QFDB
                else self.p.rate_mezz_gbps)

    def link_wire_bw_gbps(self, kind: str) -> float:
        """Sustained payload bandwidth of a link class (§6.1.2)."""
        return (self.p.bw_wire_intra_qfdb_gbps if kind == INTRA_QFDB
                else self.p.bw_wire_mezz_gbps)

    # ------------------------------------------- static fault degradation
    # A FaultSpec on the topology (DESIGN.md §2.10) rescales individual
    # links: hot/lossy links divide the raw and sustained rates by the
    # combined slowdown, degraded serdes adds per-link latency.  The
    # healthy path is bit-identical (slow == 1.0, extra == 0.0).
    def _link_slow(self, l) -> float:
        f = self.topo.faults
        return 1.0 if f is None else f.link_slow(l.kind, l.src_mpsoc,
                                                 l.dst_mpsoc)

    def link_eff_rate_gbps(self, l) -> float:
        """Raw serialization rate of one routed link under the active
        fault set."""
        return self.link_rate_gbps(l.kind) / self._link_slow(l)

    def link_eff_wire_bw_gbps(self, l) -> float:
        """Sustained wire bandwidth of one routed link under the active
        fault set."""
        return self.link_wire_bw_gbps(l.kind) / self._link_slow(l)

    def path_wire_bw_gbps(self, path: Path) -> float:
        """Bottleneck sustained wire bandwidth along a path; intra-MPSoC
        transfers are bounded by the AXI read channel (19.2 Gb/s) times the
        measured DMA efficiency on 16G links (13/16 -> ~0.8)."""
        if not path.links:
            return self.p.axi_bw_gbps * (self.p.bw_wire_intra_qfdb_gbps
                                         / self.p.rate_intra_qfdb_gbps)
        return min(self.link_eff_wire_bw_gbps(l) for l in path.links)

    def rdma_single_stream_bw_gbps(self, path: Path) -> float:
        """Effective in-message RDMA bandwidth: wire bandwidth degraded by the
        per-16KB-block R5 handling gap (single 4MB message on a 16G link
        sustains 12.475 Gb/s, §6.1.1)."""
        wire = self.path_wire_bw_gbps(path)
        block_bits = self.p.rdma_block_bytes * 8.0
        t_block = block_bits / (wire * 1000.0) + self.p.rdma_block_gap_us
        return block_bits / t_block / 1000.0

    def _path_hop_latency(self, path: Path) -> float:
        """Pure network traversal: links + routers + local switches."""
        t = path.n_routers * self.p.router_latency_us
        t += len(path.links) * self.p.link_latency_us
        # local input-queued switch at every FPGA entry that is not an
        # ExaNet router traversal (intra-QFDB hops)
        t += path.n_intra_qfdb_links * self.p.local_switch_latency_us
        f = self.topo.faults
        if f is not None:
            t += sum(f.link_extra_us(l.kind, l.src_mpsoc, l.dst_mpsoc)
                     for l in path.links)
        return t

    # --------------------------------------------------- closed-form latency
    def eager_latency(self, size: int, path: Path, *, one_way: bool = False) -> float:
        """One-way latency of an eager (packetizer/mailbox) MPI message.

        ``one_way=False`` -> half ping-pong (osu_latency semantics);
        ``one_way=True``  -> blocking-send->recv pattern (osu_one_way_lat),
        which hides part of the endpoint software cost (§6.1.4).
        """
        base = self.p.sw_oneway_base_us if one_way else self.p.sw_pingpong_base_us
        # cut-through switching (§4.2): the 32B header/footer overlap with
        # routing, so only the payload contributes serialization time.
        wire_bytes = size
        t = base + self._path_hop_latency(path)
        for l in path.links:
            t += wire_bytes * 8.0 / (self.link_eff_rate_gbps(l) * 1000.0)
        return t

    def rdv_latency(self, size: int, path: Path, *, one_way: bool = False) -> float:
        """One-way latency of a rendez-vous (RTS/CTS + RDMA) transfer (§5.2.1).

        RTS and CTS are eager control messages over the same path; the R5
        startup follows (§4.5.2); data then streams at the single-message
        RDMA bandwidth; the completion notification travels with the data
        (§5.2.1: "data issuing and notification delivery take place
        concurrently").
        """
        ctrl = self.eager_latency(0, path, one_way=one_way)
        t = 2.0 * ctrl + self.p.rdma_startup_us
        t += self._path_hop_latency(path)
        bw = self.rdma_single_stream_bw_gbps(path)
        t += size * 8.0 / (bw * 1000.0)
        return t

    def mpi_latency(self, size: int, path: Path, *, one_way: bool = False) -> float:
        if size <= self.p.mpi_eager_max_bytes:
            return self.eager_latency(size, path, one_way=one_way)
        return self.rdv_latency(size, path, one_way=one_way)

    # ------------------------------------------------------------- bandwidth
    def osu_bw_gbps(self, size: int, path: Path) -> float:
        """Windowed streaming bandwidth (osu_bw): many messages in flight, so
        per-message R5/handshake overheads overlap across RDMA channels and
        throughput approaches the wire limit for large messages (§6.1.2)."""
        if size <= self.p.mpi_eager_max_bytes:
            per_msg = max(self.p.pktz_occupancy_us * 2,
                          self.p.osu_bw_eager_gap_floor_us)
            wire = (size + self.p.cell_overhead_bytes) * 8.0 / (
                self.path_wire_bw_gbps(path) * 1000.0)
            return size * 8.0 / (max(per_msg, wire) * 1000.0)
        wire_bw = self.path_wire_bw_gbps(path)
        wire = size * 8.0 / (wire_bw * 1000.0)
        per_msg = self.p.osu_bw_rdv_per_msg_us
        return size * 8.0 / (max(wire, per_msg) * 1000.0)

    def osu_bibw_gbps(self, size: int, path: Path) -> float:
        """Bidirectional bandwidth: 2x osu_bw minus the sharing deviation the
        paper reports (§6.1.2: ~40% small, 18.3% at 4K, 5.9% at 1M)."""
        return 2.0 * self.osu_bw_gbps(size, path) * (1.0 - self._bibw_dev(size))

    @staticmethod
    def _bibw_dev(size: int) -> float:
        pts = [(64, 0.40), (4096, 0.183), (65536, 0.10),
               (1 << 20, 0.059), (4 << 20, 0.03)]
        if size <= pts[0][0]:
            return pts[0][1]
        for (s0, d0), (s1, d1) in zip(pts, pts[1:]):
            if size <= s1:
                f = (math.log(size) - math.log(s0)) / (math.log(s1) - math.log(s0))
                return d0 + f * (d1 - d0)
        return pts[-1][1]

    # ------------------------------------------------------------ path table
    def path_metrics(self, src_core: int, dst_core: int) -> PathMetrics:
        """Route + per-path constants, computed once per (src, dst) pair and
        reused by every subsequent send through the engine."""
        m = self.engine.metrics(src_core, dst_core)
        if m is not None:
            return m
        p = self.p
        eng = self.engine
        path = self.topo.route(src_core, dst_core)
        sm = self.topo.core_to_mpsoc(src_core)
        dm = self.topo.core_to_mpsoc(dst_core)
        hop = self._path_hop_latency(path)
        per_byte = sum(8.0 / (self.link_eff_rate_gbps(l) * 1000.0)
                       for l in path.links)
        rdma_bw = self.rdma_single_stream_bw_gbps(path)
        m = PathMetrics(
            path=path,
            src_mpsoc=sm,
            dst_mpsoc=dm,
            hop_latency_us=hop,
            eager_wire_us_per_byte=per_byte,
            rdma_bw_gbps=rdma_bw,
            eager_pp_const_us=p.sw_pingpong_base_us + hop,
            eager_ow_const_us=p.sw_oneway_base_us + hop,
            handshake_pp_us=2.0 * (p.sw_pingpong_base_us + hop),
            handshake_ow_us=2.0 * (p.sw_oneway_base_us + hop),
            stream_us_per_byte=8.0 / (rdma_bw * 1000.0),
            pktz_src=eng.resource(sim.PKTZ, sm),
            r5_src=eng.resource(sim.R5, sm),
            dma_src=eng.resource(sim.DMA, sm),
            dma_dst=eng.resource(sim.DMA, dm) if dm != sm else None,
            link_res=tuple(eng.resource(sim.LINK, l.key) for l in path.links),
        )
        return self.engine.register_metrics(m)

    def path_metrics_arrays(self, pairs) -> dict:
        """Per-path constants of many (src_core, dst_core) pairs in array
        form — the compile-time half of the compiled executor (DESIGN.md
        §2.5).  Physical constants come from the same :class:`PathMetrics`
        table the interpreter uses; shared resources are named by
        :meth:`Engine.resource_id` so both backends serialize on the same
        units.  ``dma_dst_id`` is -1 for intra-MPSoC loopback; ``link_ids``
        is -1-padded to the longest path in the batch."""
        ms = [self.path_metrics(s, d) for (s, d) in pairs]
        n = len(ms)
        rid = self.engine.resource_id
        max_links = max((len(m.link_res) for m in ms), default=0)
        link_ids = np.full((n, max_links), -1, dtype=np.int64)
        # per-link effective rates (static faults applied), 0-padded like
        # link_ids: the batched link-degradation axes of the compiled
        # executor recompute per-column constants from these with the
        # exact per-path formulas above (exec_compiled.LinkDegrade)
        link_rate = np.zeros((n, max_links))
        link_wire = np.zeros((n, max_links))
        for i, m in enumerate(ms):
            for k, l in enumerate(m.path.links):
                link_ids[i, k] = rid(sim.LINK, l.key)
                link_rate[i, k] = self.link_eff_rate_gbps(l)
                link_wire[i, k] = self.link_eff_wire_bw_gbps(l)
        return {
            "hop_latency_us": np.array([m.hop_latency_us for m in ms]),
            "eager_wire_us_per_byte": np.array(
                [m.eager_wire_us_per_byte for m in ms]),
            "eager_pp_const_us": np.array([m.eager_pp_const_us for m in ms]),
            "eager_ow_const_us": np.array([m.eager_ow_const_us for m in ms]),
            "handshake_pp_us": np.array([m.handshake_pp_us for m in ms]),
            "handshake_ow_us": np.array([m.handshake_ow_us for m in ms]),
            "stream_us_per_byte": np.array(
                [m.stream_us_per_byte for m in ms]),
            "pktz_id": np.array([rid(sim.PKTZ, m.src_mpsoc) for m in ms]),
            "r5_id": np.array([rid(sim.R5, m.src_mpsoc) for m in ms]),
            "dma_src_id": np.array([rid(sim.DMA, m.src_mpsoc) for m in ms]),
            "dma_dst_id": np.array(
                [rid(sim.DMA, m.dst_mpsoc) if m.dma_dst is not None else -1
                 for m in ms]),
            "link_ids": link_ids,
            "link_rate_gbps": link_rate,
            "link_wire_gbps": link_wire,
            "n_links": np.array([len(m.link_res) for m in ms]),
        }

    # ----------------------------------------------------- event-based sends
    def send(self, src_core: int, dst_core: int, size: int, t: float,
             *, one_way: bool = False) -> SendResult:
        """Contention-aware send. Occupies the shared per-MPSoC resources:

        * packetizer (eager + RTS/CTS control),
        * R5 firmware (one invocation per RDMA op, §4.5.2),
        * DMA/AXI wire (source read + destination write streams),
        * links along the path (payload serialization).
        """
        complete, sender_free = self._send(src_core, dst_core, size, t,
                                           one_way)
        return SendResult(t, complete, sender_free)

    def _send(self, src_core: int, dst_core: int, size: int, t: float,
              one_way: bool) -> tuple[float, float]:
        """Allocation-free send core: (t_complete, t_sender_free).  The
        schedule executor calls this directly — at paper scale (256 ranks)
        it runs ~10^5 times per collective."""
        eng = self.engine
        m = eng.path_table.get((src_core, dst_core)) or \
            self.path_metrics(src_core, dst_core)
        if size <= self._eager_max:
            depart = m.pktz_src.acquire(t, self._pktz_occ)
            complete = depart + \
                (m.eager_ow_const_us if one_way else m.eager_pp_const_us) + \
                size * m.eager_wire_us_per_byte
            sender_free = depart + self._pktz_ret
            if eng.tracing:
                eng.record(TraceEvent(t, src_core, dst_core, size, EAGER,
                                      complete, sender_free))
            return complete, sender_free
        # rendez-vous: RTS+CTS control eager messages, then the R5 op
        t_handshake = t + (m.handshake_ow_us if one_way else m.handshake_pp_us)
        start = m.r5_src.acquire(t_handshake, self._r5_occ) + \
            self._rdma_startup
        # stream occupancy: source DMA, links, destination DMA
        stream_us = size * m.stream_us_per_byte
        start = m.dma_src.acquire(start, stream_us)
        occupied_until = start + stream_us
        for lr in m.link_res:
            start = lr.acquire(start, stream_us)
            occupied_until = start + stream_us
        if m.dma_dst is not None:  # loopback uses a single AXI/DMA stream
            occupied_until = m.dma_dst.acquire(start, stream_us) + stream_us
        complete = occupied_until + m.hop_latency_us
        if eng.tracing:
            eng.record(TraceEvent(t, src_core, dst_core, size, RDV,
                                  complete, complete))
        return complete, complete

    def isend(self, src_core: int, dst_core: int, size: int,
              t_send: float, t_recv: float, *,
              one_way: bool = True) -> P2PResult:
        """Nonblocking matched point-to-point transfer (program execution).

        Eager messages depart at ``t_send`` regardless of the receive post
        (the mailbox buffers them); the Irecv request completes when the
        payload has arrived *and* the receive is posted.  Rendez-vous
        transfers cannot start before both sides are ready — the RTS/CTS
        handshake needs the posted receive — so the stream is issued at
        ``max(t_send, t_recv)``; MPI_Wait on the Isend request returns at
        payload completion (the end-to-end ACK travels with the data,
        §5.2.1).  All shared resources (packetizer, R5, DMA, links) are
        acquired through the engine, so concurrent programs from every
        rank contend exactly like collective schedules do.
        """
        if size <= self._eager_max:
            complete, sender_free = self._send(src_core, dst_core, size,
                                               t_send, one_way)
            return P2PResult(sender_free, max(complete, t_recv), EAGER)
        t0 = max(t_send, t_recv)
        complete, _ = self._send(src_core, dst_core, size, t0, one_way)
        return P2PResult(complete, complete, RDV)

    def charge_r5(self, mpsoc: int, t: float) -> float:
        """Charge one R5-firmware invocation (e.g. end-to-end ACK handling,
        §4.5.2) on an MPSoC; returns its completion time."""
        return self.engine.resource(sim.R5, mpsoc).acquire(
            t, self._r5_occ) + self._r5_occ
