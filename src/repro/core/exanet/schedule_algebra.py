"""Composable round algebra for collective-schedule synthesis.

A schedule *term* is a small combinator tree —

* :class:`Split` — generalized-butterfly reduce-scatter + mirrored
  all-gather whose per-step split fractions ``sigma`` are *continuous*
  search parameters (``sigma = 1/2`` everywhere is exactly
  :class:`~repro.core.exanet.schedules.RabenseifnerAllreduce`);
* :class:`Dissemination` — radix-``r`` dissemination rounds (every rank
  pushes its full accumulator to ``r - 1`` modular neighbours per round;
  exact when the scope is a power of the radix);
* :class:`Hierarchical` — group-leader staging over a machine axis
  (QFDB = 4 ranks, mezzanine = 16 at one rank per MPSoC): clients fold
  into their leader, an *outer* term runs among leaders, leaders
  broadcast back — the software analog of the §4.7 accelerator's
  client/server split, built from ordinary sends;
* :class:`Pipeline` — chunk the payload into ``c`` equal pieces and
  software-pipeline the inner term's rounds with unit stagger, so chunk
  ``i``'s round ``t`` shares a wire round with chunk ``i+1``'s round
  ``t-1``.

Terms lower to the same :class:`~repro.core.exanet.schedules.Round`
stream every other schedule uses — the interpreter and the compiled
executor replay them unchanged — but through an *annotated* intermediate
form (:class:`DataRound`) that records which **atoms** (finest vector
intervals) each send carries and whether the receiver reduces or
replaces.  The annotations are what make synthesized schedules
checkable: :mod:`repro.core.synth.verify` replays them through a
contribution-tracking semantic check (every rank must end holding every
rank's contribution exactly once) before a term is ever allowed near the
planner.

Continuous parameters form the term's **genome** (a flat tuple of the
``sigma`` fractions, in pre-order); the combinator tree plus its
discrete parameters (chunks, radix, group) is the **skeleton**.  The
round *structure* — send graph, exchange flags, round count — depends
only on the skeleton, never the genome, which is exactly the
compiled-executor contract: a whole population of same-skeleton terms
binds as batch columns of ONE lowered
:class:`~repro.core.exanet.exec_compiled.RoundProgram` replay
(:class:`SchedulePopulation`), replacing the PR 6 hack that reinterpreted
the ``nbytes`` argument as a candidate index.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Iterator, Sequence

import numpy as np

from .schedules import Round, _CopyInOut

#: clamp range for sigma genes — keeps every atom non-degenerate so the
#: round structure (>=1 byte per send) never collapses a send away
SIGMA_LO = 0.02
SIGMA_HI = 0.98

#: machine axes usable by :class:`Hierarchical` at one rank per MPSoC
AXIS_GROUPS = {"qfdb": 4, "mezzanine": 16}


@dataclasses.dataclass(frozen=True)
class DataSend:
    """One annotated send: atoms ``[a_lo, a_hi)`` travel src -> dst;
    ``reduce=True`` means the receiver adds them into its accumulator,
    ``False`` means it replaces its copy."""
    src: int
    dst: int
    a_lo: int
    a_hi: int
    reduce: bool


@dataclasses.dataclass(frozen=True)
class DataRound:
    """One round of annotated sends (the semantic twin of :class:`Round`)."""
    step: int
    sends: tuple[DataSend, ...]
    exchange: bool
    label: str = ""


class Term:
    """Base combinator.  A term is immutable; ``with_genome`` returns a
    re-parameterized copy with the same skeleton."""

    kind = "term"

    def validate(self, nranks: int) -> None:
        """Raise ValueError if the term cannot run over ``nranks`` ranks."""
        raise NotImplementedError

    def n_atoms(self, nranks: int) -> int:
        raise NotImplementedError

    def atom_widths(self, nranks: int) -> np.ndarray:
        """Fractional widths of this term's atoms (sum to 1.0)."""
        raise NotImplementedError

    def lower(self, ranks: Sequence[int], a0: int, step0: int
              ) -> list[DataRound]:
        """Annotated rounds over the given scope ranks, with this scope's
        atoms starting at global atom index ``a0``."""
        raise NotImplementedError

    def genome(self) -> tuple[float, ...]:
        return ()

    def with_genome(self, genome: Sequence[float]) -> "Term":
        term, rest = self._consume(tuple(float(g) for g in genome))
        if rest:
            raise ValueError(f"genome has {len(rest)} unused genes")
        return term

    def _consume(self, genome: tuple[float, ...]):
        return self, genome

    def structure_key(self) -> tuple:
        """Genome-free skeleton key (same key == batchable together)."""
        raise NotImplementedError

    def spec(self):
        """JSON-serializable description including the genome."""
        raise NotImplementedError

    def data_rounds(self, nranks: int) -> list[DataRound]:
        self.validate(nranks)
        return self.lower(range(nranks), 0, 0)


@dataclasses.dataclass(frozen=True)
class Split(Term):
    """sigma-split butterfly: ``len(sigmas)`` recursive-halving
    reduce-scatter steps (the lower rank of each XOR pair keeps the
    ``1 - sigma`` lower interval, the upper rank the ``sigma`` upper
    interval) followed by the mirrored recursive-doubling all-gather.
    Scope must be exactly ``2 ** len(sigmas)`` ranks."""

    sigmas: tuple[float, ...]

    kind = "split"

    @classmethod
    def balanced(cls, nranks: int) -> "Split":
        k = nranks.bit_length() - 1
        if nranks != 1 << k or k < 1:
            raise ValueError(f"Split needs power-of-two ranks, got {nranks}")
        return cls((0.5,) * k)

    def validate(self, nranks: int) -> None:
        k = len(self.sigmas)
        if k < 1 or nranks != 1 << k:
            raise ValueError(
                f"Split({k} steps) needs exactly {1 << k} ranks, "
                f"got {nranks}")
        for s in self.sigmas:
            if not (0.0 < s < 1.0):
                raise ValueError(f"sigma out of (0, 1): {s}")

    def n_atoms(self, nranks: int) -> int:
        return 1 << len(self.sigmas)

    def atom_widths(self, nranks: int) -> np.ndarray:
        k = len(self.sigmas)
        w = np.ones(1)
        for s in self.sigmas:
            w = np.concatenate([w * (1.0 - s), w * s]) \
                if len(w) == 1 else np.stack(
                    [w * (1.0 - s), w * s], axis=1).reshape(-1)
        # the loop above interleaves: each existing interval splits in
        # place into (lower, upper), preserving position order
        assert len(w) == 1 << k
        return w

    def lower(self, ranks, a0, step0):
        ranks = list(ranks)
        p = len(ranks)
        k = len(self.sigmas)
        rounds = []
        # reduce-scatter: step i pairs ranks at XOR distance 2^(k-1-i);
        # partners share their first-i split path, so they hold the same
        # working interval and trade its two sigma-halves
        for i in range(k):
            hb = k - 1 - i          # bit index of this step's distance
            d = 1 << hb
            sends = []
            for j in range(p):
                jp = j ^ d
                prefix = j >> (hb + 1)
                lo = prefix << (hb + 1)
                mid = lo + d
                hi = lo + (d << 1)
                # j sends the sub-half its partner keeps (partner's bit)
                if jp & d:
                    s_lo, s_hi = mid, hi
                else:
                    s_lo, s_hi = lo, mid
                sends.append(DataSend(ranks[j], ranks[jp],
                                      a0 + s_lo, a0 + s_hi, True))
            rounds.append(DataRound(step0 + i, tuple(sends), True,
                                    "reduce_scatter"))
        # all-gather mirror: owned block doubles at distances 1, 2, ...
        for t in range(k):
            d = 1 << t
            sends = []
            for j in range(p):
                lo = (j >> t) << t
                sends.append(DataSend(ranks[j], ranks[j ^ d],
                                      a0 + lo, a0 + lo + d, False))
            rounds.append(DataRound(step0 + k + t, tuple(sends), True,
                                    "all_gather"))
        return rounds

    def genome(self):
        return self.sigmas

    def _consume(self, genome):
        k = len(self.sigmas)
        if len(genome) < k:
            raise ValueError("genome too short for Split")
        return Split(genome[:k]), genome[k:]

    def structure_key(self):
        return ("split", len(self.sigmas))

    def spec(self):
        return ["split", [round(s, 12) for s in self.sigmas]]


@dataclasses.dataclass(frozen=True)
class Dissemination(Term):
    """Radix-r dissemination allreduce: round ``k`` has every rank push
    its full accumulator to ranks ``(j + c * r**k) mod P`` for
    ``c = 1 .. r-1``; exactly-once when ``P == r**m`` (base-r digit
    uniqueness).  No continuous genes — a pure skeleton point."""

    radix: int

    kind = "dissem"

    def _steps(self, nranks: int) -> int:
        r, m, p = self.radix, 0, 1
        while p < nranks:
            p *= r
            m += 1
        if p != nranks:
            raise ValueError(
                f"Dissemination(radix={r}) needs a power of {r} ranks, "
                f"got {nranks}")
        return m

    def validate(self, nranks: int) -> None:
        if self.radix < 2:
            raise ValueError(f"radix must be >= 2, got {self.radix}")
        if nranks < 2:
            raise ValueError("need at least 2 ranks")
        self._steps(nranks)

    def n_atoms(self, nranks: int) -> int:
        return 1

    def atom_widths(self, nranks: int) -> np.ndarray:
        return np.ones(1)

    def lower(self, ranks, a0, step0):
        ranks = list(ranks)
        p = len(ranks)
        m = self._steps(p)
        rounds = []
        for k in range(m):
            d = self.radix ** k
            sends = tuple(
                DataSend(ranks[j], ranks[(j + c * d) % p], a0, a0 + 1, True)
                for j in range(p) for c in range(1, self.radix))
            rounds.append(DataRound(step0 + k, sends, True, "dissemination"))
        return rounds

    def structure_key(self):
        return ("dissem", self.radix)

    def spec(self):
        return ["dissem", self.radix]


@dataclasses.dataclass(frozen=True)
class Hierarchical(Term):
    """Group-leader staging over a machine axis: the ``group`` ranks of
    each group fold into their leader (rank ``g * group``), the outer
    term allreduces among leaders, leaders broadcast the result back.
    The software analog of the §4.7 accelerator's client/server split —
    but made of ordinary sends, so the search is never told about the
    NI-resident hardware."""

    group: int
    outer: Term

    kind = "hier"

    @classmethod
    def over_axis(cls, axis: str, outer: Term) -> "Hierarchical":
        return cls(AXIS_GROUPS[axis], outer)

    def validate(self, nranks: int) -> None:
        if self.group < 2:
            raise ValueError(f"group must be >= 2, got {self.group}")
        if nranks % self.group or nranks // self.group < 2:
            raise ValueError(
                f"Hierarchical(group={self.group}) needs nranks a "
                f"multiple of {self.group} with >= 2 groups, got {nranks}")
        self.outer.validate(nranks // self.group)

    def n_atoms(self, nranks: int) -> int:
        return self.outer.n_atoms(nranks // self.group)

    def atom_widths(self, nranks: int) -> np.ndarray:
        return self.outer.atom_widths(nranks // self.group)

    def lower(self, ranks, a0, step0):
        ranks = list(ranks)
        p = len(ranks)
        q = self.group
        na = self.n_atoms(p)
        leaders = [ranks[g * q] for g in range(p // q)]
        up = tuple(DataSend(ranks[g * q + c], ranks[g * q], a0, a0 + na, True)
                   for g in range(p // q) for c in range(1, q))
        rounds = [DataRound(step0, up, False, "hier_up")]
        inner = self.outer.lower(leaders, a0, step0 + 1)
        rounds.extend(inner)
        step = step0 + 1 + len(inner)
        down = tuple(
            DataSend(ranks[g * q], ranks[g * q + c], a0, a0 + na, False)
            for g in range(p // q) for c in range(1, q))
        rounds.append(DataRound(step, down, False, "hier_down"))
        return rounds

    def genome(self):
        return self.outer.genome()

    def _consume(self, genome):
        outer, rest = self.outer._consume(genome)
        return Hierarchical(self.group, outer), rest

    def structure_key(self):
        return ("hier", self.group, self.outer.structure_key())

    def spec(self):
        return ["hier", self.group, self.outer.spec()]


@dataclasses.dataclass(frozen=True)
class Pipeline(Term):
    """Software pipelining: the payload splits into ``chunks`` equal
    pieces, each running the inner term's round stream offset by its
    chunk index, so successive chunks overlap on the wire (round count
    grows by ``chunks - 1`` while per-round bytes shrink by ``chunks``).
    The inner term must lower to exchange-only rounds (merged rounds
    share one exchange flag)."""

    chunks: int
    inner: Term

    kind = "pipe"

    def validate(self, nranks: int) -> None:
        if self.chunks < 2:
            raise ValueError(f"chunks must be >= 2, got {self.chunks}")
        self.inner.validate(nranks)
        for rnd in self.inner.lower(range(nranks), 0, 0):
            if not rnd.exchange:
                raise ValueError(
                    "Pipeline inner term must lower to exchange-only "
                    f"rounds, got relay round {rnd.label!r}")

    def n_atoms(self, nranks: int) -> int:
        return self.chunks * self.inner.n_atoms(nranks)

    def atom_widths(self, nranks: int) -> np.ndarray:
        w = self.inner.atom_widths(nranks) / self.chunks
        return np.tile(w, self.chunks)

    def lower(self, ranks, a0, step0):
        na = self.inner.n_atoms(len(ranks))
        merged: dict[int, list[DataSend]] = {}
        for c in range(self.chunks):
            for rnd in self.inner.lower(ranks, a0 + c * na, c):
                merged.setdefault(rnd.step, []).extend(rnd.sends)
        return [DataRound(step0 + s, tuple(merged[s]), True, "pipeline")
                for s in sorted(merged)]

    def genome(self):
        return self.inner.genome()

    def _consume(self, genome):
        inner, rest = self.inner._consume(genome)
        return Pipeline(self.chunks, inner), rest

    def structure_key(self):
        return ("pipe", self.chunks, self.inner.structure_key())

    def spec(self):
        return ["pipe", self.chunks, self.inner.spec()]


def term_from_spec(spec) -> Term:
    """Inverse of :meth:`Term.spec` (the winner-cache wire format)."""
    kind = spec[0]
    if kind == "split":
        return Split(tuple(float(s) for s in spec[1]))
    if kind == "dissem":
        return Dissemination(int(spec[1]))
    if kind == "hier":
        return Hierarchical(int(spec[1]), term_from_spec(spec[2]))
    if kind == "pipe":
        return Pipeline(int(spec[1]), term_from_spec(spec[2]))
    raise ValueError(f"unknown term kind {kind!r}")


def _spec_json(term: Term) -> str:
    return json.dumps(term.spec(), separators=(",", ":"))


def term_digest(term: Term) -> str:
    return hashlib.sha1(_spec_json(term).encode()).hexdigest()[:10]


class TermSchedule(_CopyInOut):
    """Adapter lowering an algebra term to the ordinary
    :class:`~repro.core.exanet.schedules.CollectiveSchedule` protocol.

    Byte counts are floor-scaled atom fractions (``max(1,
    int(frac * nbytes))``) so the balanced Split reproduces
    ``RabenseifnerAllreduce``'s ``nbytes * d // nranks`` arithmetic
    bit-for-bit; each round's ``reduce_bytes`` is the largest payload any
    receiver reduces (one reduction charge per round, as everywhere
    else).  ``one_way`` stays False: relay phases inside Hierarchical
    terms are costed with the conservative ping-pong transport rather
    than the accelerator's one-way model.
    """

    one_way = False

    def __init__(self, term: Term):
        self.term = term
        self.name = f"synth:{term_digest(term)}"
        self._spec = _spec_json(term)
        self._cache: dict[int, tuple] = {}

    def _lowered(self, nranks: int):
        hit = self._cache.get(nranks)
        if hit is None:
            self.term.validate(nranks)
            widths = self.term.atom_widths(nranks)
            cw = np.concatenate([[0.0], np.cumsum(widths)])
            hit = (self.term.data_rounds(nranks), cw)
            self._cache[nranks] = hit
        return hit

    def rounds(self, nranks: int, nbytes: int) -> Iterator[Round]:
        data_rounds, cw = self._lowered(nranks)
        for dr in data_rounds:
            nb = [max(1, int((cw[s.a_hi] - cw[s.a_lo]) * nbytes))
                  for s in dr.sends]
            red = max((b for s, b in zip(dr.sends, nb) if s.reduce),
                      default=0)
            yield Round(dr.step,
                        tuple((s.src, s.dst, b)
                              for s, b in zip(dr.sends, nb)),
                        exchange=dr.exchange, reduce_bytes=red,
                        label=dr.label)

    def program_key(self):
        # genome-bearing: two same-skeleton terms with different sigmas
        # bind different byte grids, so they must not share a lowered
        # program's size cache
        return ("synth", self._spec)

    def structure_key(self):
        return ("synth-skel", self.term.structure_key())

    def __repr__(self):
        return f"TermSchedule({self._spec})"


class SchedulePopulation:
    """First-class population binding on the schedule/compile seam.

    Wraps N same-skeleton schedules at one payload size as a single
    schedule-protocol object whose *size token* is the member index: the
    compiled executor's ``bind(sched, sizes)`` treats sizes as opaque
    tokens passed back to ``rounds``, so binding ``range(len(pop))``
    makes each member one batch column of ONE
    :class:`~repro.core.exanet.exec_compiled.RoundProgram` replay.  This
    replaces the PR 6 ``ButterflyPopulation`` hack (which overloaded the
    ``nbytes`` argument of an ordinary schedule) with an explicit type.

    ``program_key`` is skeleton-only, so the lowered program is reused
    across search generations; callers must therefore bind with
    ``cache=False`` (``ExanetMPI.run_schedule_population`` does) because
    member payloads change under the same token between generations.
    """

    one_way = False

    def __init__(self, members: Sequence, nbytes: int):
        members = tuple(members)
        if not members:
            raise ValueError("population needs at least one member")
        keys = {self._member_key(m) for m in members}
        if len(keys) != 1:
            raise ValueError(
                f"population members must share one skeleton, got {keys}")
        self.members = members
        self.nbytes = int(nbytes)
        self.name = f"population[{members[0].name} x{len(members)}]"
        self.one_way = bool(getattr(members[0], "one_way", False))

    @staticmethod
    def _member_key(m):
        sk = getattr(m, "structure_key", None)
        return sk() if sk is not None else (type(m).__name__,)

    def __len__(self) -> int:
        return len(self.members)

    def tokens(self) -> range:
        """The size tokens to bind: one batch column per member."""
        return range(len(self.members))

    def _member(self, token) -> object:
        # modulo so the compiled executor's structure probe (an arbitrary
        # token) lands on a member; all members share the probe structure
        return self.members[int(token) % len(self.members)]

    def rounds(self, nranks: int, token) -> Iterator[Round]:
        return self._member(token).rounds(nranks, self.nbytes)

    def pre_copy_bytes(self, token) -> int:
        return self._member(token).pre_copy_bytes(self.nbytes)

    def post_copy_bytes(self, token) -> int:
        return self._member(token).post_copy_bytes(self.nbytes)

    def program_key(self):
        return ("population", self._member_key(self.members[0]))
