"""Faithful model of the ExaNeSt prototype's ExaNet interconnect (Layer A).

See DESIGN.md §2: this package reproduces the paper's measured communication
behaviour (Tables 1-3, Figs. 13-22) from component-level constants; the
TPU-native adaptation of the same ideas lives in :mod:`repro.core.collectives`
and :mod:`repro.parallel`.
"""

from repro.core.exanet.params import DEFAULT, HwParams, scaled_params
from repro.core.exanet.topology import Topology, Path
from repro.core.exanet.sim import Engine, Resource, TraceEvent
from repro.core.exanet.network import Network
from repro.core.exanet.schedules import (CollectiveSchedule, Round,
                                         alpha_beta_cost_s)
from repro.core.exanet.exec_compiled import (BatchScheduleResult,
                                             ProgramStructureError,
                                             RoundProgram)
from repro.core.exanet.program_compiled import (CompiledProgram,
                                                compile_program_ir)
from repro.core.exanet.mpi import ExanetMPI, BcastResult, ScheduleResult
from repro.core.exanet.allreduce_accel import (accel_allreduce_latency,
                                               accel_applicable)

__all__ = [
    "DEFAULT", "HwParams", "scaled_params", "Topology", "Path", "Engine",
    "Resource", "TraceEvent", "Network", "CollectiveSchedule", "Round",
    "alpha_beta_cost_s", "BatchScheduleResult", "ProgramStructureError",
    "RoundProgram", "CompiledProgram", "compile_program_ir",
    "ExanetMPI", "BcastResult", "ScheduleResult",
    "accel_allreduce_latency", "accel_applicable",
]
