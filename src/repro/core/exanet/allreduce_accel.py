"""Model of the NI-resident MPI_Allreduce accelerator (§4.7, §6.1.5).

Algorithm (Fig. 10): for N ranks (1 rank/MPSoC, whole QFDBs, N multiple of 4):

* Level 0: every *client* module (non-network FPGAs) DMA-fetches its vector
  and sends it to the QFDB's *server* module (network FPGA), which reduces
  the 4 local vectors.
* Levels 1..log2(N)-1: server modules pairwise exchange partial vectors over
  inter-QFDB links (recursive doubling over QFDBs: log2(N/4) levels) and
  reduce.
* Final level: servers broadcast to their clients; clients DMA the reduced
  vector to memory and notify software.

The engine is triggered once per 256 B block (the max ExaNet cell payload);
latency therefore scales ~linearly in ceil(size/256) (§6.1.5: 6.79 us ->
13.38 us -> 26.11 us for 256/512/1024 B at 16 ranks). Above 4 KB the
accelerator is not profitable and ExaNet-MPI falls back to software.
"""

from __future__ import annotations

import math

from repro.core.exanet.params import DEFAULT, HwParams
from repro.core.exanet.schedules import HierarchicalAccelAllreduce


def accel_rank_applicable(nranks: int, params: HwParams = DEFAULT) -> bool:
    """The *hardware* envelope of §4.7: <=1024 ranks, one rank per FPGA,
    whole QFDBs (multiples of 4).  The engine itself is per-256B-block, so
    vector size is not a hardware constraint — the historical 4 KB cap in
    :func:`accel_applicable` is the runtime's profitability fallback, which
    the CollectivePlanner re-derives from cost (DESIGN.md §3.5)."""
    return nranks % 4 == 0 and 4 <= nranks <= params.ar_accel_max_ranks


def accel_applicable(size: int, nranks: int, params: HwParams = DEFAULT) -> bool:
    """§4.7 constraints: sum/min/max over int/float/double, <=1024 ranks,
    one rank per FPGA, whole QFDBs (multiples of 4), plus the runtime's
    4 KB profitability fallback (see :func:`accel_rank_applicable`)."""
    return (accel_rank_applicable(nranks, params)
            and size <= params.ar_accel_max_vector_bytes)


def accel_server_levels(nranks: int) -> int:
    """Inter-QFDB server-exchange levels, counted from the first-class
    schedule (Fig. 10 structure) rather than a closed-form log."""
    sched = HierarchicalAccelAllreduce()
    return sum(1 for r in sched.rounds(nranks, 1)
               if r.label == "server_exchange")


def accel_cost_us(size: int, nranks: int, params: HwParams = DEFAULT) -> float:
    """Ungated per-block cost model of the accelerated allreduce (us).

    Per 256 B block: fixed cost (software programming of the modules +
    level-0 client fetch/send + final broadcast + completion notification +
    software poll-out, calibrated 4.91 us) + one inter-QFDB server-exchange
    level per recursive-doubling step over QFDBs (0.94 us/level, one per
    ``server_exchange`` round of the schedule).  Valid at any vector size
    within the rank envelope — the planner compares it against simulated
    software cost to place the Fig. 19 crossover.
    """
    if not accel_rank_applicable(nranks, params):
        raise ValueError(f"accelerator rank envelope violated: N={nranks}")
    blocks = max(1, math.ceil(size / params.ar_accel_block_bytes))
    per_block = params.ar_accel_fixed_us + \
        accel_server_levels(nranks) * params.ar_accel_level_us
    return blocks * per_block


def accel_allreduce_latency(size: int, nranks: int,
                            params: HwParams = DEFAULT) -> float:
    """Latency (us) of the accelerated allreduce, gated by the historical
    runtime applicability rule (see :func:`accel_cost_us` for the model)."""
    if not accel_applicable(size, nranks, params):
        raise ValueError(f"accelerator not applicable: size={size} N={nranks}")
    return accel_cost_us(size, nranks, params)
