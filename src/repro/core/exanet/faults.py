"""Fault & degradation model of the ExaNeSt machine (DESIGN.md §2.10).

The paper's reliability story is hardware-level: the transaction layer
replays faulting RDMA blocks end-to-end (§4.5.3) and the 3-D torus keeps
routes available when a ring direction dies (the APEnet+ lineage).  This
module makes those operating conditions *first-class simulation inputs*:

* :class:`FaultSpec` — a frozen, canonicalized description of one degraded
  machine: dead links (mezzanine-level or intra-QFDB), dead MPSoCs, slow
  "hot" links, per-link extra latency, lossy links (loss probability ``p``
  costs the expected ``1/(1-p)`` retransmissions of the block-replay
  protocol, §4.5.3), and slow ranks (compute stragglers).
* :func:`sample_fault_spec` — deterministic Monte-Carlo fault sets for
  batched sweeps (``benchmarks/faults_sweep.py``).
* :exc:`UnroutableError` — raised by fault-aware routing
  (:meth:`repro.core.exanet.topology.Topology._compute_route`) when a
  fault set cuts the network; carries the diagnosis.

Link identity is *undirected*: a physical link failure or degradation hits
both directions, so every key is normalized to ``(kind, lo, hi)`` with
``lo <= hi`` MPSoC ids.  Kind strings match
:data:`repro.core.exanet.topology.INTRA_QFDB` / ``MEZZ`` (this module keeps
plain literals to stay import-free of the topology).

Structural faults (dead links/MPSoCs) change *routes* and therefore program
structure; they select a distinct degraded machine
(:meth:`repro.core.machine.ExanetMachine.degraded`, cached by
:meth:`FaultSpec.signature`).  Non-structural degradation (slow/lossy
links, extra latency, slow ranks) preserves routes and rides the batched
scenario axes (``link_scale`` / ``link_latency_us`` / ``compute_scale`` of
:meth:`repro.core.exanet.mpi.ExanetMPI.run_program_scenarios`).
"""

from __future__ import annotations

import dataclasses
import hashlib

#: link-class literals (== topology.INTRA_QFDB / topology.MEZZ)
INTRA_QFDB = "intra_qfdb"
MEZZ = "mezz"


class UnroutableError(RuntimeError):
    """A fault set disconnects the requested (src, dst) pair.  The message
    names the cut: which ring dimension / intra-QFDB crossbar pair, and
    why both alternatives are unavailable."""


def link_key(kind: str, a: int, b: int) -> tuple[str, int, int]:
    """Normalized undirected link key ``(kind, lo, hi)``."""
    a, b = int(a), int(b)
    return (kind, a, b) if a <= b else (kind, b, a)


def _norm_links(links) -> tuple[tuple[str, int, int], ...]:
    return tuple(sorted({link_key(*k) for k in links}))


def _norm_weighted(items) -> tuple:
    """Canonicalize a mapping/iterable of (link key -> float)."""
    if hasattr(items, "items"):
        items = items.items()
    merged: dict[tuple, float] = {}
    for k, v in items:
        merged[link_key(*k)] = float(v)
    return tuple(sorted(merged.items()))


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One degraded machine, canonicalized and hashable.

    Construction accepts convenient inputs (sets/dicts/iterables, directed
    or undirected link tuples); ``__post_init__`` normalizes everything to
    sorted tuples so equal fault sets compare, hash and sign equal.
    """
    #: dead physical links, undirected ``(kind, mpsoc_a, mpsoc_b)``
    dead_links: tuple = ()
    #: dead MPSoCs (node failures): unroutable as endpoint, skipped as relay
    dead_mpsocs: tuple = ()
    #: hot/slow links: key -> bandwidth slowdown factor (>= 1)
    slow_links: tuple = ()
    #: per-link extra one-way latency in microseconds (degraded serdes,
    #: retimer retraining, firmware-level retries)
    link_extra_latency_us: tuple = ()
    #: lossy links: key -> block-loss probability in [0, 1); §4.5.3 replay
    #: makes the expected cost ``1/(1-p)`` transmissions per block
    lossy_links: tuple = ()
    #: compute stragglers: (rank, compute-time slowdown factor >= 1)
    slow_ranks: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "dead_links", _norm_links(self.dead_links))
        object.__setattr__(self, "dead_mpsocs",
                           tuple(sorted({int(m) for m in self.dead_mpsocs})))
        object.__setattr__(self, "slow_links",
                           _norm_weighted(self.slow_links))
        object.__setattr__(self, "link_extra_latency_us",
                           _norm_weighted(self.link_extra_latency_us))
        object.__setattr__(self, "lossy_links",
                           _norm_weighted(self.lossy_links))
        sr = self.slow_ranks
        if hasattr(sr, "items"):
            sr = sr.items()
        object.__setattr__(self, "slow_ranks", tuple(
            sorted((int(r), float(f)) for r, f in sr)))
        for k, f in self.slow_links:
            if f < 1.0:
                raise ValueError(f"slow_links[{k}] = {f} < 1 (a factor "
                                 "below 1 would be a speedup)")
        for k, p in self.lossy_links:
            if not 0.0 <= p < 1.0:
                raise ValueError(f"lossy_links[{k}] = {p} outside [0, 1)")
        # derived lookup structures (identity-level, excluded from eq/hash)
        object.__setattr__(self, "_dead_links", frozenset(self.dead_links))
        object.__setattr__(self, "_dead_mpsocs",
                           frozenset(self.dead_mpsocs))
        slow = {k: f for k, f in self.slow_links}
        for k, p in self.lossy_links:
            slow[k] = slow.get(k, 1.0) / (1.0 - p)
        object.__setattr__(self, "_slow", slow)
        object.__setattr__(self, "_extra",
                           dict(self.link_extra_latency_us))

    # ------------------------------------------------------------- queries
    @property
    def is_empty(self) -> bool:
        return not (self.dead_links or self.dead_mpsocs or self.slow_links
                    or self.link_extra_latency_us or self.lossy_links
                    or self.slow_ranks)

    @property
    def degrades_structure(self) -> bool:
        """Do routes change?  Dead links/MPSoCs reroute; everything else
        only rescales the existing paths."""
        return bool(self.dead_links or self.dead_mpsocs)

    def is_dead_link(self, kind: str, a: int, b: int) -> bool:
        return link_key(kind, a, b) in self._dead_links

    def is_dead_mpsoc(self, mpsoc: int) -> bool:
        return mpsoc in self._dead_mpsocs

    def link_slow(self, kind: str, a: int, b: int) -> float:
        """Combined bandwidth slowdown (hot-link factor x §4.5.3 replay
        expectation) of one link; 1.0 when undegraded."""
        return self._slow.get(link_key(kind, a, b), 1.0)

    def link_extra_us(self, kind: str, a: int, b: int) -> float:
        return self._extra.get(link_key(kind, a, b), 0.0)

    def degraded_link_keys(self) -> tuple:
        """Every link key carrying non-structural degradation."""
        return tuple(sorted(set(self._slow) | set(self._extra)))

    def rank_compute_scale(self, nranks: int):
        """(nranks,) per-rank compute-time multipliers for the slow-rank
        class — feeds the existing ``compute_scale`` scenario axis."""
        import numpy as np
        s = np.ones(nranks)
        for r, f in self.slow_ranks:
            if 0 <= r < nranks:
                s[r] = f
        return s

    # ----------------------------------------------------------- signature
    def signature(self) -> str:
        """Deterministic short id of this fault set — the cache key that
        scopes degraded machines, their compiled artifacts and planner
        winners (DESIGN.md §2.10).  ``"healthy"`` for the empty spec."""
        if self.is_empty:
            return "healthy"
        canon = repr((self.dead_links, self.dead_mpsocs, self.slow_links,
                      self.link_extra_latency_us, self.lossy_links,
                      self.slow_ranks)).encode()
        digest = hashlib.sha256(canon).hexdigest()[:10]
        return (f"f{len(self.dead_links)}l{len(self.dead_mpsocs)}m"
                f"{len(self.slow_links) + len(self.lossy_links)}s"
                f"{len(self.slow_ranks)}r-{digest}")


#: the healthy machine (empty spec)
HEALTHY = FaultSpec()


def batch_fault_axes(specs, prog=None) -> dict:
    """Fold N *non-structural* FaultSpecs into the scenario axes of one
    batched replay: column ``j`` carries ``specs[j]``'s degradation, every
    other column holds 1/0 on that link.  Returns kwargs for
    :meth:`~repro.core.exanet.mpi.ExanetMPI.run_program_scenarios`
    (``link_scale`` / ``link_latency_us`` / ``compute_scale``, omitting
    empty axes).  Specs with ``slow_ranks`` need ``prog`` — the
    ``compute_scale`` axis is per *Compute post* (rank-major program
    order), so each rank's multiplier repeats across its compute ops.
    Structural specs are rejected — dead links change routes and need a
    degraded machine per fault signature, not a column (DESIGN.md
    §2.10)."""
    import numpy as np
    specs = list(specs)
    N = len(specs)
    slow: dict = {}
    extra: dict = {}
    any_ranks = False
    for j, s in enumerate(specs):
        if s.degrades_structure:
            raise ValueError(
                f"specs[{j}] kills links/MPSoCs (signature "
                f"{s.signature()}): structural faults reroute and must "
                "run on a degraded machine, not a batch column")
        for k in s.degraded_link_keys():
            f = s.link_slow(*k)
            if f != 1.0:
                slow.setdefault(k, np.ones(N))[j] = f
            e = s.link_extra_us(*k)
            if e:
                extra.setdefault(k, np.zeros(N))[j] = e
        any_ranks = any_ranks or bool(s.slow_ranks)
    axes: dict = {}
    if slow:
        axes["link_scale"] = slow
    if extra:
        axes["link_latency_us"] = extra
    if any_ranks:
        if prog is None:
            raise ValueError("specs carry slow_ranks; pass the Program so "
                             "the per-compute-post compute_scale axis can "
                             "be shaped")
        from repro.core.program import Compute
        counts = [sum(isinstance(op, Compute) for op in ops)
                  for ops in prog.rank_ops]
        per_rank = np.stack([s.rank_compute_scale(prog.nranks)
                             for s in specs], axis=1)     # (nranks, N)
        axes["compute_scale"] = np.repeat(per_rank, counts, axis=0)
    return axes


# --------------------------------------------------------------- samplers
def all_link_keys(topo) -> list[tuple[str, int, int]]:
    """Every physical link of a topology as a normalized key: the full
    intra-QFDB crossbar plus the +1-neighbour mezzanine-level torus links
    (each undirected link listed once)."""
    keys: set = set()
    for q in range(topo.n_qfdbs):
        base = q * topo.fpgas_per_qfdb
        for i in range(topo.fpgas_per_qfdb):
            for j in range(i + 1, topo.fpgas_per_qfdb):
                keys.add(link_key(INTRA_QFDB, base + i, base + j))
        x, y, z = topo.qfdb_coords(q)
        here = topo.network_mpsoc(q)
        for nq in ((x + 1) % topo.qfdbs_per_mezz, y, z), \
                  (x, (y + 1) % topo.mezz_y, z), \
                  (x, y, (z + 1) % topo.mezz_z):
            other = topo.network_mpsoc(topo.coords_to_qfdb(*nq))
            if other != here:
                keys.add(link_key(MEZZ, here, other))
    return sorted(keys)


def sample_fault_spec(rng, topo, *, n_dead_links: int = 0,
                      n_dead_mpsocs: int = 0, n_slow_links: int = 0,
                      slow_factor: tuple[float, float] = (2.0, 8.0),
                      n_lossy_links: int = 0,
                      loss_prob: tuple[float, float] = (0.02, 0.3),
                      n_slow_ranks: int = 0, nranks: int | None = None,
                      rank_factor: tuple[float, float] = (2.0, 6.0),
                      extra_latency_us: float = 0.0) -> FaultSpec:
    """One Monte-Carlo fault set drawn from ``rng``
    (:class:`numpy.random.Generator`).  Dead MPSoCs avoid Network MPSoCs
    so a single sample rarely cuts a whole QFDB (a cut raises
    :exc:`UnroutableError` at route time, which the fuzz tests cover by
    sampling network MPSoCs explicitly)."""
    links = all_link_keys(topo)
    picked = [links[i] for i in rng.choice(
        len(links), size=min(n_dead_links + n_slow_links + n_lossy_links,
                             len(links)), replace=False)]
    dead = picked[:n_dead_links]
    hot = picked[n_dead_links:n_dead_links + n_slow_links]
    lossy = picked[n_dead_links + n_slow_links:]
    non_net = [m for m in range(topo.n_mpsocs)
               if m % topo.fpgas_per_qfdb != 0]
    dead_mpsocs = [non_net[i] for i in rng.choice(
        len(non_net), size=min(n_dead_mpsocs, len(non_net)),
        replace=False)] if n_dead_mpsocs else []
    slow_links = {k: float(rng.uniform(*slow_factor)) for k in hot}
    lossy_links = {k: float(rng.uniform(*loss_prob)) for k in lossy}
    extra = {k: extra_latency_us for k in hot} if extra_latency_us else {}
    n = nranks if nranks is not None else topo.n_cores
    ranks = rng.choice(n, size=min(n_slow_ranks, n), replace=False) \
        if n_slow_ranks else []
    slow_ranks = {int(r): float(rng.uniform(*rank_factor)) for r in ranks}
    return FaultSpec(dead_links=dead, dead_mpsocs=dead_mpsocs,
                     slow_links=slow_links, link_extra_latency_us=extra,
                     lossy_links=lossy_links, slow_ranks=slow_ranks)
