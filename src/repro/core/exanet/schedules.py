"""Collective communication schedules for the ExaNet engine.

A *schedule* is pure structure: it yields the communication :class:`Round`\\ s
of a collective — who sends how many bytes to whom at which step — without
knowing anything about link rates, R5 firmware or DMA engines.  The executor
(:meth:`repro.core.exanet.mpi.ExanetMPI.run_schedule`) replays the rounds on
the discrete-event engine, which supplies the hardware behaviour.  The split
is what makes new collectives ~10-line definitions (see
:class:`AllGather`, :class:`AllToAll`, :class:`Barrier`) instead of
hand-rolled event loops.

Provided schedules:

* :class:`BinomialBroadcast` — MPICH binomial tree (§5.2.1/§6.1.4).
* :class:`RecursiveDoublingAllreduce` — MPICH recursive doubling (§6.1.3).
* :class:`RingAllreduce` — bandwidth-optimal ring (reduce-scatter ring +
  all-gather ring, 2(N-1) rounds of size/N chunks).
* :class:`RabenseifnerAllreduce` — recursive-halving reduce-scatter +
  recursive-doubling all-gather (bandwidth-optimal in log N rounds).
* :class:`OneShotAllreduce` — single-round all-gather + local reduce (the
  "eager"/packetizer analog: one alpha, bandwidth-expensive).
* :class:`HierarchicalAccelAllreduce` — the §4.7 NI-accelerator schedule
  (intra-QFDB client gather, inter-QFDB server recursive doubling,
  intra-QFDB broadcast) as a first-class schedule.
* :class:`AllGather`, :class:`AllToAll`, :class:`Barrier`,
  :class:`ScatterBinomial`, :class:`GatherBinomial` — the collectives the
  schedule/executor split unlocks for free.

Schedules also admit a hardware-free **alpha-beta cost**
(:func:`alpha_beta_cost_s`), which is how :class:`repro.core.comm.CommPolicy`
derives its crossover sizes from the very same round structure.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Protocol, runtime_checkable

#: one send: (src_rank, dst_rank, nbytes)
SendOp = tuple[int, int, int]


@dataclasses.dataclass(frozen=True)
class Round:
    """One synchronization-free batch of sends.

    ``exchange=True`` gives MPI_Sendrecv semantics: every participant waits
    for both its outgoing send to return and its incoming payload to arrive
    (plus the rendez-vous end-to-end-ACK R5 charge) before the round's local
    reduction of ``reduce_bytes`` bytes.  ``exchange=False`` is a one-way
    relay (broadcast/scatter trees).  ``sync`` adds the per-step skew noise
    stand-in of §6.1.4 after the round.
    """
    step: int
    sends: tuple[SendOp, ...]
    exchange: bool = False
    reduce_bytes: int = 0
    sync: bool = False
    label: str = ""


@runtime_checkable
class CollectiveSchedule(Protocol):
    """Structure of a collective: rounds + endpoint copy costs."""
    name: str
    #: sends use the one-way (blocking-send -> recv) latency model
    one_way: bool

    def rounds(self, nranks: int, nbytes: int) -> Iterator[Round]: ...

    def pre_copy_bytes(self, nbytes: int) -> int: ...

    def post_copy_bytes(self, nbytes: int) -> int: ...


class Schedule:
    """Base: no endpoint copies, sendrecv (ping-pong) latency model."""
    name = "schedule"
    one_way = False

    def pre_copy_bytes(self, nbytes: int) -> int:
        return 0

    def post_copy_bytes(self, nbytes: int) -> int:
        return 0

    def rounds(self, nranks: int, nbytes: int) -> Iterator[Round]:
        raise NotImplementedError


def _pow2_check(nranks: int) -> None:
    if nranks < 2 or nranks & (nranks - 1):
        raise ValueError(f"schedule requires power-of-two ranks >= 2 "
                         f"(as in §6.1.4), got {nranks}")


class _CopyInOut(Schedule):
    """Allreduce-style endpoint behaviour: one memcpy in, one memcpy out."""

    def pre_copy_bytes(self, nbytes: int) -> int:
        return nbytes

    def post_copy_bytes(self, nbytes: int) -> int:
        return nbytes


# --------------------------------------------------------------- broadcast
class BinomialBroadcast(Schedule):
    """MPICH binomial tree: step distances N/2, N/4, ..., 1 (§6.1.4)."""
    name = "bcast_binomial"
    one_way = True

    def rounds(self, nranks: int, nbytes: int) -> Iterator[Round]:
        _pow2_check(nranks)
        step, d = 0, nranks // 2
        while d >= 1:
            sends = tuple((r, r + d, nbytes)
                          for r in range(0, nranks, 2 * d) if r + d < nranks)
            yield Round(step, sends, sync=True, label="bcast")
            step, d = step + 1, d // 2


# --------------------------------------------------------------- allreduce
class RecursiveDoublingAllreduce(_CopyInOut):
    """MPICH recursive doubling: log N full-size sendrecv+reduce rounds."""
    name = "allreduce_recursive_doubling"

    def rounds(self, nranks: int, nbytes: int) -> Iterator[Round]:
        _pow2_check(nranks)
        for step in range(nranks.bit_length() - 1):
            d = 1 << step
            sends = tuple((r, r ^ d, nbytes) for r in range(nranks))
            yield Round(step, sends, exchange=True, reduce_bytes=nbytes)


class RingAllreduce(_CopyInOut):
    """Bandwidth-optimal ring: N-1 reduce-scatter rounds + N-1 all-gather
    rounds, each moving a size/N chunk to the next rank."""
    name = "allreduce_ring"

    def rounds(self, nranks: int, nbytes: int) -> Iterator[Round]:
        assert nranks >= 2
        chunk = max(1, nbytes // nranks)
        sends = tuple((r, (r + 1) % nranks, chunk) for r in range(nranks))
        for step in range(nranks - 1):
            yield Round(step, sends, exchange=True, reduce_bytes=chunk,
                        label="reduce_scatter")
        for step in range(nranks - 1, 2 * (nranks - 1)):
            yield Round(step, sends, exchange=True, label="all_gather")


class RabenseifnerAllreduce(_CopyInOut):
    """Rabenseifner: recursive-halving reduce-scatter then recursive-doubling
    all-gather; bandwidth-optimal wire bytes in only 2 log N rounds."""
    name = "allreduce_rabenseifner"

    def rounds(self, nranks: int, nbytes: int) -> Iterator[Round]:
        _pow2_check(nranks)
        step, d = 0, nranks // 2
        while d >= 1:
            nb = max(1, nbytes * d // nranks)
            sends = tuple((r, r ^ d, nb) for r in range(nranks))
            yield Round(step, sends, exchange=True, reduce_bytes=nb,
                        label="reduce_scatter")
            step, d = step + 1, d // 2
        d = 1
        while d < nranks:
            nb = max(1, nbytes * d // nranks)
            sends = tuple((r, r ^ d, nb) for r in range(nranks))
            yield Round(step, sends, exchange=True, label="all_gather")
            step, d = step + 1, d * 2


class OneShotAllreduce(_CopyInOut):
    """One-shot allreduce: every rank sends its full vector to every other
    rank in a single round, then reduces the N-1 received vectors locally.
    Latency-optimal (one alpha), bandwidth-expensive ((N-1)x wire bytes per
    rank) — the collective analog of the paper's eager/packetizer transport
    (§5.2.1), and the schedule behind the derived eager threshold."""
    name = "allreduce_oneshot"

    def rounds(self, nranks: int, nbytes: int) -> Iterator[Round]:
        assert nranks >= 2
        sends = tuple((r, (r + k) % nranks, nbytes)
                      for r in range(nranks) for k in range(1, nranks))
        yield Round(0, sends, exchange=True,
                    reduce_bytes=(nranks - 1) * nbytes, label="oneshot")


class HierarchicalAccelAllreduce(Schedule):
    """The §4.7 NI-resident accelerator schedule (Fig. 10), per 256 B block:

    * level 0: the 3 client FPGAs of every QFDB push their vector to the
      QFDB's server FPGA (the Network MPSoC), which reduces the 4 inputs;
    * levels 1..log2(N/4): servers recursive-double over inter-QFDB links;
    * final level: servers broadcast the result back to their clients.

    Ranks are 1/MPSoC over whole QFDBs (``nranks`` a multiple of 4, §4.7).
    """
    name = "allreduce_accel"
    one_way = True
    ranks_per_qfdb = 4

    def rounds(self, nranks: int, nbytes: int) -> Iterator[Round]:
        q = self.ranks_per_qfdb
        assert nranks % q == 0 and nranks >= q
        n_qfdbs = nranks // q
        servers = [i * q for i in range(n_qfdbs)]
        up = tuple((s + c, s, nbytes) for s in servers for c in range(1, q))
        yield Round(0, up, reduce_bytes=nbytes, label="client_reduce")
        step = 1
        # recursive doubling runs over the largest power-of-two server
        # subset; surplus servers fold their partial in first and get the
        # result back with the final broadcast (MPICH-style pre-step).
        pow2 = 1 << (n_qfdbs.bit_length() - 1)
        if pow2 < n_qfdbs:
            fold = tuple((servers[i], servers[i - pow2], nbytes)
                         for i in range(pow2, n_qfdbs))
            yield Round(step, fold, reduce_bytes=nbytes, label="server_fold")
            step += 1
        d = 1
        while d < pow2:
            sends = tuple((servers[i], servers[i ^ d], nbytes)
                          for i in range(pow2))
            yield Round(step, sends, exchange=True, reduce_bytes=nbytes,
                        label="server_exchange")
            step, d = step + 1, d * 2
        if pow2 < n_qfdbs:
            unfold = tuple((servers[i - pow2], servers[i], nbytes)
                           for i in range(pow2, n_qfdbs))
            yield Round(step, unfold, label="server_unfold")
            step += 1
        down = tuple((s, s + c, nbytes) for s in servers for c in range(1, q))
        yield Round(step, down, label="client_broadcast")


# ------------------------------------------------- schedule-split dividends
class AllGather(Schedule):
    """Recursive doubling: at distance d every rank exchanges its
    accumulated d*nbytes block (nbytes = per-rank contribution)."""
    name = "allgather_recursive_doubling"

    def rounds(self, nranks: int, nbytes: int) -> Iterator[Round]:
        _pow2_check(nranks)
        step, d = 0, 1
        while d < nranks:
            sends = tuple((r, r ^ d, nbytes * d) for r in range(nranks))
            yield Round(step, sends, exchange=True)
            step, d = step + 1, d * 2


class AllToAll(Schedule):
    """XOR pairwise exchange: N-1 rounds, each rank trades its nbytes block
    with partner r^k."""
    name = "alltoall_pairwise"

    def rounds(self, nranks: int, nbytes: int) -> Iterator[Round]:
        _pow2_check(nranks)
        for k in range(1, nranks):
            sends = tuple((r, r ^ k, nbytes) for r in range(nranks))
            yield Round(k - 1, sends, exchange=True)


class Barrier(Schedule):
    """Dissemination barrier: ceil(log2 N) rounds of empty messages to
    (r + 2^i) mod N."""
    name = "barrier_dissemination"

    def rounds(self, nranks: int, nbytes: int = 0) -> Iterator[Round]:
        assert nranks >= 2
        step, d = 0, 1
        while d < nranks:
            sends = tuple((r, (r + d) % nranks, 0) for r in range(nranks))
            yield Round(step, sends, exchange=True)
            step, d = step + 1, d * 2


class ScatterBinomial(Schedule):
    """Binomial scatter from rank 0: holders forward the half of their block
    destined for the subtree at distance d (nbytes = per-rank payload)."""
    name = "scatter_binomial"
    one_way = True

    def rounds(self, nranks: int, nbytes: int) -> Iterator[Round]:
        _pow2_check(nranks)
        step, d = 0, nranks // 2
        while d >= 1:
            sends = tuple((r, r + d, nbytes * d)
                          for r in range(0, nranks, 2 * d))
            yield Round(step, sends, label="scatter")
            step, d = step + 1, d // 2


class GatherBinomial(Schedule):
    """Binomial gather to rank 0: mirror of scatter, distances 1, 2, ...,
    N/2 with growing blocks."""
    name = "gather_binomial"
    one_way = True

    def rounds(self, nranks: int, nbytes: int) -> Iterator[Round]:
        _pow2_check(nranks)
        step, d = 0, 1
        while d < nranks:
            sends = tuple((r + d, r, nbytes * d)
                          for r in range(0, nranks, 2 * d))
            yield Round(step, sends, label="gather")
            step, d = step + 1, d * 2


#: allreduce algorithm registry for the executor entry points
ALLREDUCE_SCHEDULES = {
    "recursive_doubling": RecursiveDoublingAllreduce,
    "ring": RingAllreduce,
    "rabenseifner": RabenseifnerAllreduce,
    "oneshot": OneShotAllreduce,
}

#: op -> {algo name -> schedule class} for embedded Program collectives
#: (first key of each op is its default algorithm; ``algo="auto"`` on a
#: non-allreduce op falls back to that default — the planner only ranks
#: allreduce candidates today)
COLLECTIVE_SCHEDULES: dict[str, dict[str, type]] = {
    "allreduce": ALLREDUCE_SCHEDULES,
    "bcast": {"binomial": BinomialBroadcast},
    "allgather": {"recursive_doubling": AllGather},
    "alltoall": {"pairwise": AllToAll},
    "barrier": {"dissemination": Barrier},
    "scatter": {"binomial": ScatterBinomial},
    "gather": {"binomial": GatherBinomial},
}


# --------------------------------------------------------- alpha-beta costs
def alpha_beta_cost_s(schedule: CollectiveSchedule, nranks: int, nbytes: int,
                      *, alpha_s: float, bw_bytes_per_s: float) -> float:
    """Hardware-free LogP-style cost of a schedule: every round costs one
    launch latency (alpha) plus the serialization of the busiest sender's
    outgoing bytes (beta * bytes).  For schedules where every rank sends at
    most once per round this is the classic max-single-send model; fan-out
    rounds (one-shot, the accelerator's client broadcast) charge the sum of
    each source's sends, since one NI serializes them.  This is the model
    :class:`repro.core.comm.CommPolicy` and the planner's ``analytic``
    fidelity use to place eager/rendez-vous-style crossovers, derived from
    the same round structure the event engine executes."""
    t = 0.0
    for rnd in schedule.rounds(nranks, nbytes):
        if not rnd.sends:
            continue
        per_src: dict[int, int] = {}
        for (src, _, nb) in rnd.sends:
            per_src[src] = per_src.get(src, 0) + nb
        t += alpha_s + max(per_src.values()) / bw_bytes_per_s
    return t
