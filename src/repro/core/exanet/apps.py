"""Application-level weak/strong scaling models (§6.2, Figs. 20-22, Table 3).

We model the three codes the paper runs — HPCG, LAMMPS (rhodopsin), miniFE —
as iterative bulk-synchronous kernels:

    T_iter(N) = T_comp(N) * f_mem(cores_active) + T_halo(N) + T_coll(N)

* ``T_comp``: per-rank per-iteration compute (weak: constant per rank;
  strong: global work / N), at a calibrated per-core rate.
* ``f_mem``: DDR4 single-channel contention when several A53 cores of an
  MPSoC are active (§6.2: LAMMPS weak efficiency 96%/89% at 2/4 ranks with
  negligible comm -> f_mem(2)=1.042, f_mem(4)=1.124).
* ``T_halo``: nearest-neighbour exchange (6 faces, 3-D decomposition) using
  the rendez-vous transport model between block-placed neighbour ranks.
* ``T_coll``: dot-product allreduces per iteration (recursive doubling,
  8 B) using the ExaNet-MPI collective model.

Per app we calibrate the per-core compute rate against ONE anchor — the
communication-time fraction the paper reports (LAMMPS strong 12% @512,
HPCG strong 22.4% @512, miniFE weak calibrated to its 69% efficiency) —
and then *predict* the remaining Table 3 efficiencies.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.exanet.mpi import ExanetMPI
from repro.core.exanet.params import DEFAULT, HwParams


def _grid3(n: int) -> tuple[int, int, int]:
    """Balanced 3-D process grid (largest factors last)."""
    best = (n, 1, 1)
    score = float("inf")
    for px in range(1, n + 1):
        if n % px:
            continue
        rem = n // px
        for py in range(1, rem + 1):
            if rem % py:
                continue
            pz = rem // py
            s = max(px, py, pz) / min(px, py, pz)
            if s < score:
                score, best = s, (px, py, pz)
    return best


def f_mem(active_cores: int, f4: float = 1.124) -> float:
    """Memory-channel contention multiplier for 1/2/4 active cores."""
    if active_cores <= 1:
        return 1.0
    if active_cores == 2:
        return 1.0 + (f4 - 1.0) * 0.375   # 1.042 at f4=1.124 (§6.2)
    return f4


@dataclasses.dataclass
class AppModel:
    name: str
    #: global problem points for the strong test / per-rank points for weak
    strong_points: float
    weak_points_per_rank: float
    #: flops per point per iteration
    flops_per_point: float
    #: bytes exchanged per halo face point
    halo_bytes_per_point: float
    #: dot-product style allreduces per iteration
    allreduce_per_iter: int
    #: calibrated per-core compute rate (flop/us)
    core_rate_flops_per_us: float
    #: DDR contention factor at 4 active cores
    f4: float = 1.124
    params: HwParams = dataclasses.field(default_factory=lambda: DEFAULT)

    # ------------------------------------------------------------------ comm
    def _halo_us(self, local_points: float, n: int, mpi: ExanetMPI) -> float:
        if n == 1:
            return 0.0
        side = local_points ** (1.0 / 3.0)
        face_bytes = int(side * side * self.halo_bytes_per_point)
        # block placement: the 3 face-neighbour distances in rank space
        px, py, pz = _grid3(n)
        dists = sorted({1 % n, px % n, (px * py) % n} - {0})
        t = 0.0
        for d in dists:
            # two faces per dimension, sends overlap pairwise -> 1 exchange
            t += mpi.osu_one_way(max(face_bytes, 1), 0, d)
        return t

    def _coll_us(self, n: int, mpi: ExanetMPI) -> float:
        if n == 1 or self.allreduce_per_iter == 0:
            return 0.0
        # 8 B dot products: recursive doubling, like the MPICH runtime the
        # paper ran (schedule-based executor, same numbers as allreduce_sw)
        return self.allreduce_per_iter * mpi.allreduce(8, n,
                                                       "recursive_doubling")

    # --------------------------------------------------------------- scaling
    #
    # The network model above is *contention-free per message*; the measured
    # application communication time additionally contains the full-machine
    # congestion of 512 simultaneous halo exchanges plus MPI stack effects.
    # We therefore calibrate ONE multiplicative constant alpha per
    # (app, mode) against the paper's measured 512-rank efficiency
    # (Table 3) and *predict* every other rank count; EXPERIMENTS.md marks
    # the 512-rank cells as calibrated and the rest as predictions.

    def _comm_model_us(self, local_points: float, n: int) -> float:
        mpi = ExanetMPI(self.params)
        return self._halo_us(local_points, n, mpi) + self._coll_us(n, mpi)

    def _comp_us(self, local_points: float, n: int) -> float:
        active = min(n, self.params.cores_per_mpsoc)
        comp = local_points * self.flops_per_point / self.core_rate_flops_per_us
        return comp * f_mem(active, self.f4)

    def _alpha(self, mode: str, target_eff_512: float) -> float:
        if mode == "weak":
            t1 = self._comp_us(self.weak_points_per_rank, 1)
            comp = self._comp_us(self.weak_points_per_rank, 512)
            comm = self._comm_model_us(self.weak_points_per_rank, 512)
            return max(0.0, (t1 / target_eff_512 - comp) / comm)
        t1 = self._comp_us(self.strong_points, 1)
        comp = self._comp_us(self.strong_points / 512, 512)
        comm = self._comm_model_us(self.strong_points / 512, 512)
        return max(0.0, (t1 / (512 * target_eff_512) - comp) / comm)

    def _eval(self, mode: str, n: int) -> dict:
        from repro.core.exanet.apps import PAPER_TABLE3  # anchor table
        target = PAPER_TABLE3[self.name][mode][512] / 100.0
        alpha = self._alpha(mode, target)
        if mode == "weak":
            pts, t1 = self.weak_points_per_rank, self._comp_us(
                self.weak_points_per_rank, 1)
            ideal = t1
        else:
            pts, t1 = self.strong_points / n, self._comp_us(self.strong_points, 1)
            ideal = t1 / n
        comm = alpha * self._comm_model_us(pts, n) if n > 1 else 0.0
        tn = self._comp_us(pts, n) + comm
        return {"n": n, "efficiency": ideal / tn, "comm_fraction": comm / tn,
                "t_iter_us": tn, "alpha": alpha,
                "calibrated": n == 512}

    def weak(self, n: int) -> dict:
        return self._eval("weak", n)

    def strong(self, n: int) -> dict:
        return self._eval("strong", n)


def hpcg(params: HwParams = DEFAULT) -> AppModel:
    """HPCG: 27-point stencil CG + multigrid; strong global 256x256x128,
    weak 104^3 per rank (§6.2). Rate calibrated to 22.4% comm @512 strong."""
    return AppModel(
        name="hpcg",
        strong_points=256 * 256 * 128,
        weak_points_per_rank=104 ** 3,
        flops_per_point=180.0,          # SpMV(54) + MG smoother sweeps
        halo_bytes_per_point=8.0 * 1.6,  # f64 faces + coarse MG levels
        allreduce_per_iter=2,
        core_rate_flops_per_us=330.0,   # ~0.33 GFLOP/s/core, memory bound
    )


def lammps(params: HwParams = DEFAULT) -> AppModel:
    """LAMMPS rhodopsin: 32k atoms/rank weak (§6.2); neighbour exchange
    dominates comm; few global reductions (thermo every ~10 steps)."""
    return AppModel(
        name="lammps",
        strong_points=32000.0 * 16,     # strong test base system
        weak_points_per_rank=32000.0,
        flops_per_point=900.0,          # pair forces + PPPM per atom-step
        halo_bytes_per_point=200.0,     # ghost-atom skins are fat vs faces
        allreduce_per_iter=1,
        core_rate_flops_per_us=2400.0,
    )


def minife(params: HwParams = DEFAULT) -> AppModel:
    """miniFE: FE assembly + CG solve; 264^3 strong, weak scaled to 512^3
    at 512 ranks (§6.2). The CG dominates: halo + 2 allreduce/iteration,
    with the highest comm share of the three codes."""
    return AppModel(
        name="minife",
        strong_points=264.0 ** 3,
        weak_points_per_rank=(512.0 ** 3) / 512.0,
        flops_per_point=60.0,           # 27-pt SpMV + AXPYs
        halo_bytes_per_point=8.0,
        allreduce_per_iter=2,
        core_rate_flops_per_us=480.0,
    )


ALL_APPS = {"hpcg": hpcg, "lammps": lammps, "minife": minife}

#: Table 3 of the paper (validation targets): efficiency in percent.
PAPER_TABLE3 = {
    "lammps": {"weak": {2: 96, 512: 69}, "strong": {2: 97, 512: 82}},
    "hpcg": {"weak": {2: 96, 512: 87}, "strong": {2: 92, 512: 70}},
    "minife": {"weak": {2: 86, 512: 69}, "strong": {2: 94, 512: 72}},
}


def table3(params: HwParams = DEFAULT) -> dict:
    out = {}
    for name, factory in ALL_APPS.items():
        m = factory(params)
        out[name] = {
            "weak": {n: round(100 * m.weak(n)["efficiency"], 1) for n in (2, 512)},
            "strong": {n: round(100 * m.strong(n)["efficiency"], 1) for n in (2, 512)},
        }
    return out
