"""Application workloads (§6.2, Figs. 20-22, Table 3) as *programs* on the
event engine.

HPCG, LAMMPS (rhodopsin) and miniFE are modeled as iterative bulk-
synchronous kernels.  Each :class:`AppModel` is a program **emitter**: per
(mode, rank count) it emits one iteration as a
:class:`repro.core.program.Program` — a per-rank op sequence of

* ``Compute`` — per-rank per-iteration work (weak: constant per rank;
  strong: global work / N) at a calibrated per-core rate, scaled by
  ``f_mem`` (DDR4 single-channel contention when several A53 cores of an
  MPSoC are active; §6.2: LAMMPS weak efficiency 96%/89% at 2/4 ranks);
* ``Isend``/``Irecv``/``Wait`` — the 6-face 3-D halo exchange
  (:func:`repro.core.program.cg_iteration`), tagged per face;
* ``Collective`` — the dot-product allreduces (8 B, recursive doubling —
  the MPICH 3.2.1 algorithm the paper ran, §5.2.1).

Iteration time comes out of **simulation**
(:meth:`ExanetMPI.run_program`): all N ranks' halo flows contend on the
shared R5/DMA/link resources concurrently, so the full-machine congestion
of 512 simultaneous exchanges — which the closed-form predecessor of this
module could not see — is *emergent*.

What remains calibrated (and what was retired):

* the per-app per-core compute rate and ``f_mem``, as before;
* one multiplicative constant ``beta`` per (app, mode) on the *simulated*
  communication time, calibrated against the paper's measured 512-rank
  efficiency (Table 3) — it absorbs MPI-stack effects (progress-engine
  polling, unexpected-message queues, noise) the engine does not model.
  ``beta`` replaces the retired ``alpha``, which multiplied a sum of
  *isolated* per-message costs and therefore had to absorb all of the
  congestion too: ``beta <= alpha`` by construction (the simulated base
  already contains the contention), typically by 1-2 orders of magnitude
  — see ``alpha_retired`` in the eval dicts and ``BENCH_apps.json``.
  EXPERIMENTS.md marks 512-rank cells as calibrated, the rest as
  predictions.
"""

from __future__ import annotations

import dataclasses

from repro.core.exanet.mpi import ExanetMPI
from repro.core.exanet.params import DEFAULT, HwParams
from repro.core.program import (Compute, Program, ProgramResult,
                                balanced_grid3, cg_iteration)


def f_mem(active_cores: int, f4: float = 1.124) -> float:
    """Memory-channel contention multiplier for 1/2/4 active cores."""
    if active_cores <= 1:
        return 1.0
    if active_cores == 2:
        return 1.0 + (f4 - 1.0) * 0.375   # 1.042 at f4=1.124 (§6.2)
    return f4


@dataclasses.dataclass
class AppModel:
    name: str
    #: global problem points for the strong test / per-rank points for weak
    strong_points: float
    weak_points_per_rank: float
    #: flops per point per iteration
    flops_per_point: float
    #: bytes exchanged per halo face point
    halo_bytes_per_point: float
    #: dot-product style allreduces per iteration
    allreduce_per_iter: int
    #: calibrated per-core compute rate (flop/us)
    core_rate_flops_per_us: float
    #: DDR contention factor at 4 active cores
    f4: float = 1.124
    params: HwParams = dataclasses.field(default_factory=lambda: DEFAULT)
    #: one simulation instance per model — the path table, route cache and
    #: schedule caches are rebuilt from params exactly once, not per eval
    _mpi: ExanetMPI | None = dataclasses.field(
        default=None, init=False, repr=False, compare=False)
    _sim_cache: dict = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False)
    _beta_cache: dict = dataclasses.field(
        default_factory=dict, init=False, repr=False, compare=False)
    _machine: object = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    @property
    def mpi(self) -> ExanetMPI:
        if self._mpi is None:
            self._mpi = ExanetMPI(self.params)
        return self._mpi

    def mpi_for(self, n: int) -> ExanetMPI:
        """The simulation instance that fits ``n`` ranks: the calibrated
        prototype up to its 512 cores, else a scaled twin per size tier
        (``params.scaled_params``: same component constants, larger
        mezzanine torus) — what lets the weak-scaling sweep predict
        1024-4096-rank iterations the base machine cannot even route.
        Tier construction is delegated to
        :meth:`repro.core.machine.ExanetMachine._mpi_for`, so benchmarks,
        planner and apps all agree on one twin per rank count."""
        if self._machine is None:
            from repro.core.machine import ExanetMachine
            self._machine = ExanetMachine(mpi=self.mpi)
        return self._machine._mpi_for(n)

    # ------------------------------------------------------------- emission
    def _local_points(self, mode: str, n: int) -> float:
        return self.weak_points_per_rank if mode == "weak" else \
            self.strong_points / n

    def _face_bytes(self, local_points: float) -> int:
        side = local_points ** (1.0 / 3.0)
        return max(1, int(side * side * self.halo_bytes_per_point))

    def emit_iteration(self, mode: str, n: int) -> Program:
        """One iteration of this app at ``n`` ranks as a Program: 6-face
        halo exchange + compute + the dot-product allreduces (recursive
        doubling, like the MPICH runtime the paper ran, §5.2.1)."""
        pts = self._local_points(mode, n)
        comp = self._comp_us(pts, n)
        if n == 1:
            return Program(((Compute(comp),),))
        return cg_iteration(n, self._face_bytes(pts), comp,
                            n_dots=self.allreduce_per_iter, dot_bytes=8,
                            coll_algo="recursive_doubling")

    # ----------------------------------------------------------- simulation
    def simulate_iteration(self, mode: str, n: int, *,
                           backend: str = "auto") -> ProgramResult:
        """Event-simulate one iteration on the tier that fits ``n``
        ranks.  ``backend="auto"`` compiles the program at paper scale
        (:data:`ExanetMPI.PROGRAM_COMPILED_AUTO_MIN_RANKS`) — beyond 512
        ranks the interpreted executor is impractical for sweeps, so the
        1024-4096-rank weak-scaling rows of ``BENCH_apps.json`` exist
        only because of this path."""
        return self.mpi_for(n).run_program(self.emit_iteration(mode, n),
                                           backend=backend)

    def _simulate(self, mode: str, n: int) -> ProgramResult:
        """Event-simulated iteration (cached): all ranks' halo flows and
        embedded collectives contend on one engine."""
        key = (mode, n)
        res = self._sim_cache.get(key)
        if res is None:
            res = self._sim_cache[key] = self.simulate_iteration(mode, n)
        return res

    def _comp_us(self, local_points: float, n: int) -> float:
        active = min(n, self.params.cores_per_mpsoc)
        comp = local_points * self.flops_per_point / self.core_rate_flops_per_us
        return comp * f_mem(active, self.f4)

    # ---------------------------------------------------------- calibration
    #
    # One multiplicative constant beta per (app, mode) scales the
    # *simulated* communication time to the paper's measured 512-rank
    # efficiency; every other rank count is a prediction.  beta absorbs
    # only the MPI-stack residue — congestion is already in the base.

    def _anchor_comm_us(self, mode: str) -> float:
        """Communication budget of the 512-rank Table 3 anchor: measured
        iteration time (from the paper's efficiency) minus modeled
        compute.  Numerator of both beta and the retired alpha."""
        target = PAPER_TABLE3[self.name][mode][512] / 100.0
        pts = self._local_points(mode, 512)
        comp = self._comp_us(pts, 512)
        if mode == "weak":
            tn_target = self._comp_us(self.weak_points_per_rank, 1) / target
        else:
            tn_target = self._comp_us(self.strong_points, 1) / (512 * target)
        return tn_target - comp

    def _beta(self, mode: str) -> float:
        beta = self._beta_cache.get(("beta", mode))
        if beta is None:
            beta = max(0.0, self._anchor_comm_us(mode)
                       / self._simulate(mode, 512).comm_us)
            self._beta_cache[("beta", mode)] = beta
        return beta

    def _retired_alpha(self, mode: str) -> float:
        """What the pre-IR closed-form model had to calibrate: the same
        512-rank anchor divided by a sum of *isolated* message costs (one
        contention-free one-way exchange per distinct neighbour distance +
        isolated allreduces).  Kept for the record: beta/alpha_retired is
        how much of the old fudge factor the simulation now explains."""
        alpha = self._beta_cache.get(("alpha", mode))
        if alpha is None:
            comm = self._comm_closed_us(self._local_points(mode, 512), 512)
            alpha = max(0.0, self._anchor_comm_us(mode) / comm)
            self._beta_cache[("alpha", mode)] = alpha
        return alpha

    def _comm_closed_us(self, local_points: float, n: int) -> float:
        """The retired per-message model: isolated one-way halo faces (one
        per distinct block-placement neighbour distance) + isolated
        allreduces, no cross-rank contention."""
        if n == 1:
            return 0.0
        mpi = self.mpi
        face = self._face_bytes(local_points)
        px, py, _ = balanced_grid3(n)
        dists = sorted({1 % n, px % n, (px * py) % n} - {0})
        t = sum(mpi.osu_one_way(face, 0, d) for d in dists)
        if self.allreduce_per_iter:
            t += self.allreduce_per_iter * mpi.allreduce(
                8, n, "recursive_doubling")
        return t

    # --------------------------------------------------------------- scaling
    def _eval(self, mode: str, n: int) -> dict:
        if mode == "weak":
            t1 = self._comp_us(self.weak_points_per_rank, 1)
            comp = self._comp_us(self.weak_points_per_rank, n)
            ideal = t1
        else:
            t1 = self._comp_us(self.strong_points, 1)
            comp = self._comp_us(self.strong_points / n, n)
            ideal = t1 / n
        beta = self._beta(mode)
        comm = beta * self._simulate(mode, n).comm_us if n > 1 else 0.0
        tn = comp + comm
        return {"n": n, "efficiency": ideal / tn, "comm_fraction": comm / tn,
                "t_iter_us": tn, "beta": beta,
                "alpha_retired": self._retired_alpha(mode),
                "calibrated": n == 512}

    def weak(self, n: int) -> dict:
        return self._eval("weak", n)

    def strong(self, n: int) -> dict:
        return self._eval("strong", n)


def hpcg(params: HwParams = DEFAULT) -> AppModel:
    """HPCG: 27-point stencil CG + multigrid; strong global 256x256x128,
    weak 104^3 per rank (§6.2). Rate calibrated to 22.4% comm @512 strong."""
    return AppModel(
        name="hpcg",
        strong_points=256 * 256 * 128,
        weak_points_per_rank=104 ** 3,
        flops_per_point=180.0,          # SpMV(54) + MG smoother sweeps
        halo_bytes_per_point=8.0 * 1.6,  # f64 faces + coarse MG levels
        allreduce_per_iter=2,
        core_rate_flops_per_us=330.0,   # ~0.33 GFLOP/s/core, memory bound
        params=params,
    )


def lammps(params: HwParams = DEFAULT) -> AppModel:
    """LAMMPS rhodopsin: 32k atoms/rank weak (§6.2); neighbour exchange
    dominates comm; few global reductions (thermo every ~10 steps)."""
    return AppModel(
        name="lammps",
        strong_points=32000.0 * 16,     # strong test base system
        weak_points_per_rank=32000.0,
        flops_per_point=900.0,          # pair forces + PPPM per atom-step
        halo_bytes_per_point=200.0,     # ghost-atom skins are fat vs faces
        allreduce_per_iter=1,
        core_rate_flops_per_us=2400.0,
        params=params,
    )


def minife(params: HwParams = DEFAULT) -> AppModel:
    """miniFE: FE assembly + CG solve; 264^3 strong, weak scaled to 512^3
    at 512 ranks (§6.2). The CG dominates: halo + 2 allreduce/iteration,
    with the highest comm share of the three codes.

    miniFE is the most DDR-bound of the three (streaming SpMV + AXPYs
    with no cache reuse), so its memory-contention factor is larger than
    the LAMMPS-derived default: f4 = 1.32, calibrated between the paper's
    two 2-rank anchors (weak 86% / strong 94%, §6.2) — the pre-IR model
    instead buried this on-node effect inside its alpha = 76x comm fudge.
    """
    return AppModel(
        name="minife",
        strong_points=264.0 ** 3,
        weak_points_per_rank=(512.0 ** 3) / 512.0,
        flops_per_point=60.0,           # 27-pt SpMV + AXPYs
        halo_bytes_per_point=8.0,
        allreduce_per_iter=2,
        core_rate_flops_per_us=480.0,
        f4=1.32,
        params=params,
    )


ALL_APPS = {"hpcg": hpcg, "lammps": lammps, "minife": minife}

#: Table 3 of the paper (validation targets): efficiency in percent.
PAPER_TABLE3 = {
    "lammps": {"weak": {2: 96, 512: 69}, "strong": {2: 97, 512: 82}},
    "hpcg": {"weak": {2: 96, 512: 87}, "strong": {2: 92, 512: 70}},
    "minife": {"weak": {2: 86, 512: 69}, "strong": {2: 94, 512: 72}},
}


def table3(params: HwParams = DEFAULT) -> dict:
    out = {}
    for name, factory in ALL_APPS.items():
        m = factory(params)
        out[name] = {
            "weak": {n: round(100 * m.weak(n)["efficiency"], 1) for n in (2, 512)},
            "strong": {n: round(100 * m.strong(n)["efficiency"], 1) for n in (2, 512)},
        }
    return out
