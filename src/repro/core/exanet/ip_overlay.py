"""IP-over-ExaNet converged-network service model (§5.3, Figs. 12-13).

A user-space program tunnels IP packets between a TUN device and the ExaNet
fabric; multiple packets are batched per RDMA transfer; RDMA notifications
synchronize transmitter/receiver. The baseline is the 10GbE management
network reached through the Network-MPSoC software bridge.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.exanet.network import Network
from repro.core.exanet.params import DEFAULT, HwParams
from repro.core.exanet.topology import Topology


@dataclasses.dataclass
class OverlayResult:
    throughput_gbps: float
    rtt_poll_us: float
    rtt_sleep_us: float


def overlay_throughput_gbps(pkt_bytes: int, params: HwParams = DEFAULT,
                            *, hops: int = 5, batch: int = 8) -> float:
    """Throughput of the overlay for a stream of IP packets.

    Per packet: one TUN read() + copy on an A53 core; per batch of packets:
    one RDMA transfer at the path's sustained bandwidth. The 5-hop path of
    the paper's experiment traverses 10 Gb/s links (wire 6.42 Gb/s); the CPU
    side (TUN syscalls) is the bottleneck for small packets, the fabric for
    large ones.
    """
    topo = Topology(params)
    net = Network(topo, params)
    # representative 5-hop path: 4 mezz-level links + 1 intra-QFDB
    src, dst = topo._inter_mezz_312()
    path = topo.route(src, dst)
    # transmit side: TUN reads into the RDMA ring overlap with transfers
    # (multiple packets per RDMA); the receive side's TUN write() + copy into
    # the kernel cannot overlap with the fabric and is additive.
    wire_bw = net.path_wire_bw_gbps(path)
    wire_us_per_pkt = pkt_bytes * 8.0 / (wire_bw * 1000.0)
    rx_copy_bw = 1.5 * params.a53_copy_bw_bytes_per_us  # write-combining copy
    rx_us_per_pkt = params.tun_syscall_us + pkt_bytes / rx_copy_bw
    rdma_fixed_per_batch = params.rdma_startup_us + params.rdma_block_gap_us
    per_pkt = wire_us_per_pkt + rx_us_per_pkt + rdma_fixed_per_batch / batch
    return pkt_bytes * 8.0 / (per_pkt * 1000.0)


def baseline_throughput_gbps(pkt_bytes: int, params: HwParams = DEFAULT,
                             *, mtu: int = 1500) -> float:
    """10GbE management path through the Network-MPSoC software bridge
    (§3.3): large datagrams fragment at the 1500B MTU and every fragment
    crosses the kernel stack plus the software bridge — CPU bound."""
    frags = max(1, math.ceil(pkt_bytes / mtu))
    cpu_us_per_frag = params.tun_syscall_us + \
        mtu / (1.5 * params.a53_copy_bw_bytes_per_us) * 2.0
    wire_us = pkt_bytes * 8.0 / (10.0 * 1000.0)
    per_pkt = max(frags * cpu_us_per_frag, wire_us)
    return pkt_bytes * 8.0 / (per_pkt * 1000.0)


def overlay_vs_native_gap(pkt_bytes: int = 65536,
                          params: HwParams = DEFAULT) -> dict:
    """The §5.3 throughput ladder on the paper's 5-hop path: native wire
    bandwidth (what RDMA sustains), the IP overlay (CPU-taxed tunnel),
    and the 10GbE software-bridge baseline — each in Gb/s, plus their
    ratios.  The faults sweep reads this as the *graceful-degradation
    floor*: a degraded fabric that still beats ``overlay_gbps`` keeps
    native transport worthwhile; below ``baseline_gbps`` the converged
    fabric has lost to the management network and the machine is
    effectively partitioned for HPC traffic."""
    topo = Topology(params)
    net = Network(topo, params)
    src, dst = topo._inter_mezz_312()
    native = net.path_wire_bw_gbps(topo.route(src, dst))
    overlay = overlay_throughput_gbps(pkt_bytes, params)
    baseline = baseline_throughput_gbps(pkt_bytes, params)
    return {
        "pkt_bytes": pkt_bytes,
        "native_wire_gbps": native,
        "overlay_gbps": overlay,
        "baseline_gbps": baseline,
        "overlay_vs_native": overlay / native,
        "overlay_vs_baseline": overlay / baseline,
    }


def overlay_rtt(params: HwParams = DEFAULT, *, mode: str = "poll") -> float:
    """RTT of a sporadic small message through the overlay. Polling keeps a
    core busy but reacts in ~1 TUN turnaround per direction; adaptive-sleep
    adds the sleep quantum (§5.3: 90 us poll / 2.2 ms sleep vs 72 us bare)."""
    topo = Topology(params)
    net = Network(topo, params)
    src, dst = topo._inter_mezz_312()
    path = topo.route(src, dst)
    one_way_fabric = net.rdv_latency(1500, path)
    tun = 2.0 * params.tun_syscall_us  # read + write per direction
    kernel_stack = 12.0                # IP stack traversal per direction
    rtt_poll = 2.0 * (one_way_fabric + tun + kernel_stack)
    if mode == "poll":
        return rtt_poll
    sleep_quantum = 1000.0  # adaptive sleep period ~1 ms average backoff
    return rtt_poll + 2.0 * sleep_quantum
