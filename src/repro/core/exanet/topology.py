"""ExaNeSt prototype topology (§3, §4.1).

Structure: ``mezzanine (blade) -> QFDB -> MPSoC (FPGA) -> A53 core``.

* 4 MPSoCs per QFDB, fully connected with 16 Gb/s GTH pairs; only FPGA 0
  (the "Network MPSoC", F1 in the paper's naming) has external links.
* QFDBs form a 3D torus over 10 Gb/s mezzanine-level links:
  X = 4 QFDBs inside a blade (ring), Y = 4 blades of a quad-blade group
  (ring), Z = 2 quad-blade groups.
* Routing is dimension-ordered X->Y->Z (§4.2, deadlock-free single path),
  with intra-QFDB first/last hops to reach the Network MPSoC.

Core ids are block-packed: consecutive ranks fill the cores of an MPSoC,
then the MPSoCs of a QFDB, then the QFDBs of a mezzanine (matches the
broadcast schedule decomposition of §6.1.4: step distance >=16 crosses a
QFDB boundary, >=4 crosses an MPSoC boundary).
"""

from __future__ import annotations

import dataclasses

from repro.core.exanet.faults import FaultSpec, UnroutableError
from repro.core.exanet.params import DEFAULT, HwParams

__all__ = ["Topology", "Link", "Path", "UnroutableError",
           "INTRA_QFDB", "MEZZ", "LOOPBACK"]

#: link classes
INTRA_QFDB = "intra_qfdb"  # 16 Gb/s GTH inside a QFDB
MEZZ = "mezz"              # 10 Gb/s mezzanine-level (intra- or inter-blade)
LOOPBACK = "loopback"      # same MPSoC / same FPGA


@dataclasses.dataclass(frozen=True)
class Link:
    kind: str          # INTRA_QFDB | MEZZ
    src_mpsoc: int
    dst_mpsoc: int

    @property
    def key(self) -> tuple:
        return (self.kind, self.src_mpsoc, self.dst_mpsoc)


@dataclasses.dataclass(frozen=True)
class Path:
    """A routed path between two cores."""
    src_core: int
    dst_core: int
    links: tuple[Link, ...]
    n_routers: int          # ExaNet (APEnet-class) router traversals
    same_mpsoc: bool

    @property
    def n_mezz_links(self) -> int:
        return sum(1 for l in self.links if l.kind == MEZZ)

    @property
    def n_intra_qfdb_links(self) -> int:
        return sum(1 for l in self.links if l.kind == INTRA_QFDB)

    @property
    def kind(self) -> str:
        """Classification matching Table 1 of the paper."""
        if self.same_mpsoc:
            return "intra_fpga"
        if not self.links:
            return "intra_fpga"
        m, k = self.n_mezz_links, self.n_intra_qfdb_links
        if m == 0:
            return "intra_qfdb_sh"
        # distinguishing intra- vs inter-mezzanine needs coordinates; the
        # latency model only depends on (m, k), mirroring Table 1 rows b-e.
        if m == 1 and k == 0:
            return "mezz_sh"
        if m == 1:
            return f"mezz_mh({1 + k})"
        return f"inter_mezz({m},{k})"


class Topology:
    def __init__(self, params: HwParams = DEFAULT, *,
                 route_cache_size: int = 1 << 16,
                 faults: FaultSpec | None = None):
        self.p = params
        self.cores_per_mpsoc = params.cores_per_mpsoc
        self.fpgas_per_qfdb = params.fpgas_per_qfdb
        self.qfdbs_per_mezz = params.qfdbs_per_mezzanine
        self.mezzanines = params.mezzanines
        #: mezzanine-level torus ring sizes (prototype: X=4 QFDBs/blade,
        #: Y=4 blades/group, Z=2 groups; paper-scale params grow Y/Z)
        self.mezz_y = params.mezz_torus_y
        self.mezz_z = params.mezz_torus_z
        if self.mezz_y * self.mezz_z != self.mezzanines:
            raise ValueError(
                f"mezzanines={self.mezzanines} is not mezz_torus_y="
                f"{self.mezz_y} x {self.mezz_z} torus rings")
        self.n_cores = params.n_cores
        self.n_mpsocs = params.n_mpsocs
        self.n_qfdbs = params.n_qfdbs
        #: LRU route cache: dimension-ordered routing is deterministic, so a
        #: (src, dst) pair always resolves to the same Path. Collectives hit
        #: the same few pairs thousands of times; ``route_cache_size=0``
        #: disables caching (the pre-refactor per-send behaviour).
        self._route_cache: dict[tuple[int, int], Path] = {}
        self._route_cache_size = route_cache_size
        self.route_hits = 0
        self.route_misses = 0
        #: active fault set (None == healthy); routes are computed against
        #: it, so the cache must never mix entries from different specs —
        #: :meth:`set_faults` bumps the epoch and clears the cache.
        self.faults: FaultSpec | None = \
            None if faults is None or faults.is_empty else faults
        self.fault_epoch = 0

    # --------------------------------------------------------- fault state
    def set_faults(self, faults: FaultSpec | None) -> None:
        """Install a new fault set: bumps :attr:`fault_epoch` and clears
        the route cache (cached paths belong to the previous epoch).
        Callers holding derived path state — the engine's
        ``path_table``, compiled round programs — must rebuild it; the
        supported pattern is a fresh degraded ``ExanetMPI``/machine per
        fault signature (DESIGN.md §2.10)."""
        self.faults = None if faults is None or faults.is_empty else faults
        self.fault_epoch += 1
        self.route_cache_clear(reset_counters=False)

    # ------------------------------------------------------- cache control
    def route_cache_info(self) -> dict:
        """Route-cache counters, mirroring ``sync_cost_cache_info`` and
        the planner's ``cache_info()``."""
        total = self.route_hits + self.route_misses
        return {"hits": self.route_hits, "misses": self.route_misses,
                "size": len(self._route_cache),
                "max_size": self._route_cache_size,
                "hit_rate": self.route_hits / total if total else 0.0,
                "fault_epoch": self.fault_epoch}

    def route_cache_clear(self, *, reset_counters: bool = True) -> None:
        self._route_cache.clear()
        if reset_counters:
            self.route_hits = 0
            self.route_misses = 0

    # ------------------------------------------------------------ id helpers
    def core_to_mpsoc(self, core: int) -> int:
        return core // self.cores_per_mpsoc

    def mpsoc_to_qfdb(self, mpsoc: int) -> int:
        return mpsoc // self.fpgas_per_qfdb

    def mpsoc_fpga_index(self, mpsoc: int) -> int:
        return mpsoc % self.fpgas_per_qfdb

    def qfdb_coords(self, qfdb: int) -> tuple[int, int, int]:
        """QFDB -> (x, y, z) torus coordinates."""
        mezz = qfdb // self.qfdbs_per_mezz
        x = qfdb % self.qfdbs_per_mezz
        y = mezz % self.mezz_y
        z = mezz // self.mezz_y
        return (x, y, z)

    def coords_to_qfdb(self, x: int, y: int, z: int) -> int:
        mezz = z * self.mezz_y + y
        return mezz * self.qfdbs_per_mezz + x

    def network_mpsoc(self, qfdb: int) -> int:
        """FPGA 0 of a QFDB is the Network MPSoC (§3.1)."""
        return qfdb * self.fpgas_per_qfdb

    # --------------------------------------------------------------- routing
    def route(self, src_core: int, dst_core: int) -> Path:
        """Cached dimension-ordered route (see :meth:`_compute_route`)."""
        if src_core >= self.n_cores or dst_core >= self.n_cores or \
                src_core < 0 or dst_core < 0:
            raise ValueError(
                f"core pair ({src_core}, {dst_core}) outside the "
                f"{self.n_cores}-core machine; paper-scale rank counts "
                f"need repro.core.exanet.params.scaled_params")
        if not self._route_cache_size:
            return self._compute_route(src_core, dst_core)
        key = (src_core, dst_core)
        cache = self._route_cache
        path = cache.get(key)
        if path is not None:
            self.route_hits += 1
            cache.pop(key)  # true LRU: refresh position on hit
            cache[key] = path
            return path
        self.route_misses += 1
        path = self._compute_route(src_core, dst_core)
        if len(self._route_cache) >= self._route_cache_size:
            # evict the oldest entry (dict preserves insertion order)
            self._route_cache.pop(next(iter(self._route_cache)))
        self._route_cache[key] = path
        return path

    def _intra_qfdb_hop(self, a: int, b: int) -> list[Link]:
        """Links from MPSoC ``a`` to ``b`` inside one QFDB: the direct
        crossbar pair, or — when that link is dead — a deterministic relay
        through the lowest-id alive MPSoC with two healthy legs."""
        f = self.faults
        if f is None or not f.degrades_structure \
                or not f.is_dead_link(INTRA_QFDB, a, b):
            return [Link(INTRA_QFDB, a, b)]
        base = self.mpsoc_to_qfdb(a) * self.fpgas_per_qfdb
        for m in range(base, base + self.fpgas_per_qfdb):
            if m in (a, b) or f.is_dead_mpsoc(m):
                continue
            if not f.is_dead_link(INTRA_QFDB, a, m) \
                    and not f.is_dead_link(INTRA_QFDB, m, b):
                return [Link(INTRA_QFDB, a, m), Link(INTRA_QFDB, m, b)]
        raise UnroutableError(
            f"intra-QFDB crossbar link ({a}, {b}) is dead in QFDB "
            f"{self.mpsoc_to_qfdb(a)} and no alive relay MPSoC has two "
            f"healthy legs — the pair is disconnected")

    def _ring_hops(self, cur: tuple[int, int, int], dim: int, target: int,
                   size: int) -> list[tuple[int, int, int]]:
        """Coordinate hops along one torus ring, fault-aware: the healthy
        (minimal, tie -> +1) direction is preferred; if it traverses a
        dead mezzanine link or a QFDB whose Network MPSoC is dead, the
        opposite direction is taken deterministically.  Both directions
        cut -> :exc:`UnroutableError` naming the dimension."""
        a = cur[dim]
        if a == target:
            return []
        fwd, bwd = (target - a) % size, (a - target) % size
        pref = 1 if fwd <= bwd else -1
        f = self.faults
        dirs = (pref,) if f is None or not f.degrades_structure \
            else (pref, -pref)
        for step in dirs:
            hops: list[tuple[int, int, int]] = []
            c = list(cur)
            prev_net = self.network_mpsoc(self.coords_to_qfdb(*cur))
            ok = True
            while c[dim] != target:
                c[dim] = (c[dim] + step) % size
                net = self.network_mpsoc(self.coords_to_qfdb(*c))
                if f is not None and (f.is_dead_mpsoc(net)
                                      or f.is_dead_link(MEZZ, prev_net,
                                                        net)):
                    ok = False
                    break
                hops.append(tuple(c))
                prev_net = net
            if ok:
                return hops
        raise UnroutableError(
            f"torus ring {'XYZ'[dim]} (size {size}) is cut between "
            f"coordinates {a} and {target}: both ring directions traverse "
            f"a dead mezzanine link or a dead Network MPSoC — the fault "
            f"set partitions the machine")

    def _compute_route(self, src_core: int, dst_core: int) -> Path:
        """Dimension-ordered route; returns the link sequence + router count.

        Router traversals: the message enters the source QFDB's Network-MPSoC
        router, then one router per intermediate/destination QFDB on the
        torus path — i.e. (#mezzanine-level links + 1) routers when it leaves
        the QFDB, matching the paper's N+1-switches rule (§6.1.1).

        With a fault set installed (:meth:`set_faults`) the route is
        *fault-aware but still deterministic and dimension-ordered*
        (X -> Y -> Z, each ring traversed monotonically in one direction,
        so the deadlock-freedom argument of §4.2 is preserved): dead
        crossbar links relay through an alive MPSoC
        (:meth:`_intra_qfdb_hop`), dead ring segments flip the ring
        direction (:meth:`_ring_hops`), and a cut partition raises
        :exc:`UnroutableError`.
        """
        sm, dm = self.core_to_mpsoc(src_core), self.core_to_mpsoc(dst_core)
        f = self.faults
        if f is not None:
            for m, role in ((sm, "source"), (dm, "destination")):
                if f.is_dead_mpsoc(m):
                    raise UnroutableError(
                        f"{role} MPSoC {m} (core "
                        f"{src_core if role == 'source' else dst_core}) "
                        f"is dead")
        if sm == dm:
            return Path(src_core, dst_core, (), 0, True)
        sq, dq = self.mpsoc_to_qfdb(sm), self.mpsoc_to_qfdb(dm)
        if sq == dq:
            # full crossbar inside the QFDB (§4.1)
            return Path(src_core, dst_core,
                        tuple(self._intra_qfdb_hop(sm, dm)), 0, False)
        links: list[Link] = []
        n_routers = 0
        # hop to the network MPSoC of the source QFDB if needed
        cur_mpsoc = sm
        for q, role in ((sq, "source"), (dq, "destination")):
            net = self.network_mpsoc(q)
            if f is not None and f.is_dead_mpsoc(net):
                raise UnroutableError(
                    f"Network MPSoC {net} of {role} QFDB {q} is dead — "
                    f"the QFDB has no external connectivity")
        net = self.network_mpsoc(sq)
        if cur_mpsoc != net:
            links.extend(self._intra_qfdb_hop(cur_mpsoc, net))
            cur_mpsoc = net
        n_routers += 1  # source QFDB router
        # torus X -> Y -> Z between QFDBs
        cur = self.qfdb_coords(sq)
        (dx, dy, dz) = self.qfdb_coords(dq)
        sizes = (self.qfdbs_per_mezz, self.mezz_y, self.mezz_z)
        for dim, target in enumerate((dx, dy, dz)):
            for h in self._ring_hops(cur, dim, target, sizes[dim]):
                nxt = self.network_mpsoc(self.coords_to_qfdb(*h))
                links.append(Link(MEZZ, cur_mpsoc, nxt))
                cur_mpsoc = nxt
                n_routers += 1  # router of every traversed QFDB
                cur = h
        # final intra-QFDB hop
        if cur_mpsoc != dm:
            links.extend(self._intra_qfdb_hop(cur_mpsoc, dm))
        return Path(src_core, dst_core, tuple(links), n_routers, False)

    # ----------------------------------------------------- named Table-1 paths
    def table1_paths(self) -> dict[str, tuple[int, int]]:
        """Representative (src_core, dst_core) pairs for Table 1/2 rows."""
        c = self.cores_per_mpsoc
        q = self.fpgas_per_qfdb * c  # cores per QFDB
        return {
            # (f) intra-FPGA: two ranks on the same MPSoC
            "intra_fpga": (0, 1),
            # (a) Intra-QFDB-sh: M1QAF1 - M1QAF2
            "intra_qfdb_sh": (0, c),
            # (b) Intra-mezz-sh: M1QAF1 - M1QBF1 (network FPGAs, adjacent QFDBs)
            "mezz_sh": (0, q),
            # (c) Intra-mezz-mh(2): M1QAF1 - M1QBF2
            "mezz_mh(2)": (0, q + c),
            # (d) Intra-mezz-mh(3): M1QAF2 - M1QBF3
            "mezz_mh(3)": (c, q + 2 * c),
            # (e) Inter-mezz(3,1,2): 3 inter-mezz + 1 intra-mezz + 2 intra-QFDB
            "inter_mezz(3,1,2)": self._inter_mezz_312(),
        }

    def _inter_mezz_312(self) -> tuple[int, int]:
        """A pair whose dimension-ordered route crosses 4 mezzanine-level
        links (1 X + 2 Y + 1 Z in our torus == the paper's 3 inter-mezz +
        1 intra-mezz) and 2 intra-QFDB links."""
        c = self.cores_per_mpsoc
        src_q = self.coords_to_qfdb(0, 0, 0)
        dst_q = self.coords_to_qfdb(1, 2, 1)
        src = src_q * self.fpgas_per_qfdb * c + c       # F2 of src QFDB
        dst = dst_q * self.fpgas_per_qfdb * c + 2 * c   # F3 of dst QFDB
        return (src, dst)
