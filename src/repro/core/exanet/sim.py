"""Discrete-event simulation substrate for the ExaNet model.

The paper's contention effects (§6.1.4: R5 firmware serialization, AXI/DMA
wire sharing, link occupancy) used to be tracked by ad-hoc ``*_free`` dicts
inside :class:`~repro.core.exanet.network.Network`.  This module extracts
that bookkeeping into a proper engine:

* :class:`Resource` — a serially-reusable unit (one R5 core, one AXI/DMA
  wire, one packetizer, one link direction) with occupancy accounting.
* :class:`Engine` — owns every resource of the simulated machine, an
  optional per-send :class:`TraceEvent` log, and the **path table**: routes
  and their derived per-path constants (:class:`PathMetrics`) are computed
  once per (src, dst) pair and reused across sends, which is what makes
  paper-scale sweeps (256+ ranks) fast.

The closed-form latency/bandwidth math stays in ``network.py``; the engine
is the substrate it runs on.

Two execution backends share this substrate (DESIGN.md §2.5):

* the **interpreter** (:meth:`ExanetMPI.run_schedule`) drives
  :class:`Resource` objects one ``acquire`` at a time — the reference
  semantics;
* the **compiled executor** (:mod:`repro.core.exanet.exec_compiled`)
  replays pre-lowered round programs against :class:`ResourceState` —
  array-backed ``free_at`` rows addressed by :meth:`Engine.resource_id` —
  using :func:`segmented_maxplus_scan` to serialize contending sends with
  ``maximum``-scan arithmetic instead of per-send Python calls.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.exanet.topology import Path

#: resource kinds (the shared units of §4.4-4.5)
R5 = "r5"        # per-MPSoC R5 transaction-layer firmware
DMA = "dma"      # per-MPSoC AXI/DMA wire
PKTZ = "pktz"    # per-MPSoC packetizer
LINK = "link"    # one physical link direction
CORE = "core"    # one A53 core (per-rank compute resource: program
                 # execution charges Compute ops on it, so compute and
                 # in-flight communication overlap is accounted per rank)


class Resource:
    """A serially-reusable resource with busy-time accounting."""

    __slots__ = ("key", "free_at", "busy_us", "n_acquires")

    def __init__(self, key: tuple):
        self.key = key
        self.free_at = 0.0
        self.busy_us = 0.0
        self.n_acquires = 0

    def acquire(self, t: float, duration_us: float) -> float:
        """Acquire from time ``t`` for ``duration_us``; returns the actual
        start time (``max(t, free_at)``)."""
        start = self.free_at if self.free_at > t else t
        self.free_at = start + duration_us
        self.busy_us += duration_us
        self.n_acquires += 1
        return start


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One send through the engine (recorded when tracing is enabled)."""
    t_issue: float
    src_core: int
    dst_core: int
    nbytes: int
    transport: str          # "eager" | "rendezvous"
    t_complete: float
    t_sender_free: float


@dataclasses.dataclass(frozen=True)
class PathMetrics:
    """Route + per-path constants derived once and reused on every send.

    Besides the physical quantities, the table pins the :class:`Resource`
    objects the path touches, so the send hot loop is pure arithmetic plus
    ``Resource.acquire`` calls — no dict lookups.
    """
    path: Path
    src_mpsoc: int
    dst_mpsoc: int
    hop_latency_us: float          # links + routers + local switches
    eager_wire_us_per_byte: float  # sum of 8/(rate*1000) over the links
    rdma_bw_gbps: float            # single-stream RDMA bandwidth
    eager_pp_const_us: float       # ping-pong base + hop latency
    eager_ow_const_us: float       # one-way base + hop latency
    handshake_pp_us: float         # 2x 0-byte eager control (RTS+CTS)
    handshake_ow_us: float
    stream_us_per_byte: float      # 8/(rdma_bw*1000)
    pktz_src: Resource
    r5_src: Resource
    dma_src: Resource
    dma_dst: Resource | None       # None for intra-MPSoC loopback
    link_res: tuple                # link Resources along the path


class Engine:
    """Owns the shared resources, the path table and the optional trace.

    ``reset()`` clears occupancy state between simulated collectives but
    keeps the path table — routes do not change with time.
    """

    def __init__(self, *, trace: bool = False, cache_paths: bool = True):
        self.tracing = trace
        self.cache_paths = cache_paths
        self._resources: dict[tuple, Resource] = {}
        self._resource_ids: dict[tuple, int] = {}
        self.path_table: dict[tuple[int, int], PathMetrics] = {}
        self.trace: list[TraceEvent] = []

    # ------------------------------------------------------------- resources
    def resource(self, kind: str, ident) -> Resource:
        key = (kind, ident)
        r = self._resources.get(key)
        if r is None:
            r = self._resources[key] = Resource(key)
        return r

    def resource_id(self, kind: str, ident) -> int:
        """Stable dense integer id of a resource.  Compiled round programs
        index :class:`ResourceState` rows by these ids; the interpreter's
        :class:`Resource` objects are untouched, so both backends can name
        the same physical unit."""
        key = (kind, ident)
        rid = self._resource_ids.get(key)
        if rid is None:
            rid = self._resource_ids[key] = len(self._resource_ids)
        return rid

    @property
    def n_resource_ids(self) -> int:
        return len(self._resource_ids)

    def resource_ids_of(self, kind: str) -> dict:
        """ident -> dense id for every registered resource of ``kind``
        (the degradation axes map undirected physical-link keys onto the
        directed LINK rows of the compiled executors)."""
        return {ident: rid for (k, ident), rid in self._resource_ids.items()
                if k == kind}

    def reset(self) -> None:
        # zero in place (don't clear): PathMetrics entries hold direct
        # references to these Resource objects across collectives
        for r in self._resources.values():
            r.free_at = 0.0
            r.busy_us = 0.0
            r.n_acquires = 0
        self.trace.clear()

    # ------------------------------------------------------------ path table
    def metrics(self, src_core: int, dst_core: int):
        """Cached :class:`PathMetrics` lookup; ``None`` on miss (the caller
        builds and registers it via :meth:`register_metrics`)."""
        if not self.cache_paths:
            return None
        return self.path_table.get((src_core, dst_core))

    def register_metrics(self, m: PathMetrics) -> PathMetrics:
        if self.cache_paths:
            self.path_table[(m.path.src_core, m.path.dst_core)] = m
        return m

    # ----------------------------------------------------------------- trace
    def record(self, ev: TraceEvent) -> None:
        if self.tracing:
            self.trace.append(ev)

    # ------------------------------------------------------------- reporting
    def utilization(self, t_end: float) -> dict[tuple, float]:
        """Busy fraction of every touched resource over [0, t_end]."""
        if t_end <= 0.0:
            return {}
        return {k: r.busy_us / t_end for k, r in self._resources.items()}

    def occupancy_stats(self) -> dict[tuple, dict]:
        return {k: {"busy_us": r.busy_us, "n_acquires": r.n_acquires,
                    "free_at": r.free_at}
                for k, r in self._resources.items()}


# ---------------------------------------------------------------------------
# Array-backed resource state (the compiled executor's substrate)
# ---------------------------------------------------------------------------
class ResourceState:
    """Vectorized ``free_at`` bookkeeping: one row per engine resource id
    (:meth:`Engine.resource_id`), one trailing *batch* axis per bound
    binding — a message size of a sweep grid, a perturbed scenario of a
    Monte-Carlo batch.  ``batch`` is an int (one flat column axis, the
    common case) or a tuple of trailing dims (``(N, B)`` nests scenario
    and size axes without reshaping the caller's data).

    The compiled executor replays a whole round program against one state;
    a run starts from all-zero occupancy, exactly like ``Engine.reset()``.
    """

    __slots__ = ("free",)

    def __init__(self, n_resources: int, batch):
        shape = (n_resources,) + (tuple(batch) if isinstance(batch, tuple)
                                  else (int(batch),))
        self.free = np.zeros(shape)

    def acquire_unique(self, rows: np.ndarray, t: np.ndarray,
                       dur) -> np.ndarray:
        """Acquire resources ``rows`` (no row repeated) from times ``t``
        for ``dur``; returns the start times (``maximum(t, free)``)."""
        free = self.free[rows]
        start = np.maximum(t, free)
        self.free[rows] = start + dur
        return start

    def acquire_unique_masked(self, rows: np.ndarray, t: np.ndarray, dur,
                              active: np.ndarray) -> np.ndarray:
        """Like :meth:`acquire_unique`, but only batch elements where
        ``active`` advance the resource (an eager send never touches the
        R5/DMA rows its rendez-vous twin would)."""
        free = self.free[rows]
        start = np.maximum(t, free)
        self.free[rows] = np.where(active, start + dur, free)
        return start


def scan_take_masks(first: np.ndarray, max_group: int) -> list:
    """Precomputed per-pass combine masks of a segmented Hillis-Steele
    scan.  The flag evolution is data-independent, so a compiled program
    pays for it once per (schedule, nranks) instead of per run."""
    F = np.array(first, copy=True)
    takes = []
    s = 1
    while s < max_group:
        takes.append((s, (~F[s:])[:, None]))
        F[s:] |= F[:-s]
        s *= 2
    return takes


def segmented_maxplus_scan(dur: np.ndarray, t_plus_dur: np.ndarray,
                           first: np.ndarray, max_group: int,
                           *, takes: list | None = None, copy: bool = True
                           ) -> tuple[np.ndarray, np.ndarray]:
    """Inclusive segmented scan of serially-reusable acquisitions.

    One acquire is the max-plus affine map ``g(f) = max(f + D, T)`` of the
    resource's free time ``f``, with ``D`` the busy duration and
    ``T = t + D`` (an inactive acquire is the identity: ``D=0, T=-inf``).
    Composition is associative — ``(D1,T1) then (D2,T2)`` is
    ``(D1+D2, max(T1+D2, T2))`` — so serialization of every contention
    group resolves in ``ceil(log2(max_group))`` Hillis-Steele passes of
    plain array arithmetic instead of a Python loop over sends.

    ``dur``/``t_plus_dur`` are ``(k, *batch)`` acquire arrays laid out so
    each resource's acquires are contiguous and in send order; ``first``
    is the (k,) segment-start mask.  The trailing batch may be any number
    of dims (``(k, B)`` size grids, ``(k, N, B)`` scenario x size
    batches); the precomputed (m, 1) combine masks broadcast over one
    trailing dim and are right-padded for deeper batches.  Returns
    ``(Dacc, Tacc)`` such that the resource is next free at
    ``maximum(F0 + Dacc_i, Tacc_i)`` after its i-th acquire, where ``F0``
    is the segment's initial free time.  ``takes`` (from
    :func:`scan_take_masks`) skips recomputing the flag evolution;
    ``copy=False`` lets the scan clobber its inputs.
    """
    D = np.array(dur, copy=True) if copy else dur
    T = np.array(t_plus_dur, copy=True) if copy else t_plus_dur
    if takes is None:
        takes = scan_take_masks(first, max_group)
    pad = T.ndim - 2
    for s, mask in takes:
        if pad > 0:
            mask = mask.reshape(mask.shape[0], *([1] * (T.ndim - 1)))
        # masked in-place ufuncs: numpy detects the self-overlap and
        # buffers internally, so this is the np.where form minus the
        # intermediate allocations (the scans are the replay hot loop)
        np.maximum(T[:-s] + D[s:], T[s:], out=T[s:], where=mask)
        np.add(D[:-s], D[s:], out=D[s:], where=mask)
    return D, T


def segmented_running_max(v: np.ndarray, takes: list) -> np.ndarray:
    """In-place segmented running maximum (the scalar-duration fast path:
    with a group-constant duration ``d``, the serialization recurrence
    collapses to ``f_after_i = (k_i+1) d + max(F0, max_j<=i (t_j - k_j d))``
    — one plain-max scan over ``v = t - k d`` instead of the (D, T)
    composition).  Like :func:`segmented_maxplus_scan`, ``v`` may carry
    any number of trailing batch dims."""
    pad = v.ndim - 2
    for s, mask in takes:
        if pad > 0:
            mask = mask.reshape(mask.shape[0], *([1] * (v.ndim - 1)))
        np.maximum(v[:-s], v[s:], out=v[s:], where=mask)
    return v
