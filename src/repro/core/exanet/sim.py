"""Discrete-event simulation substrate for the ExaNet model.

The paper's contention effects (§6.1.4: R5 firmware serialization, AXI/DMA
wire sharing, link occupancy) used to be tracked by ad-hoc ``*_free`` dicts
inside :class:`~repro.core.exanet.network.Network`.  This module extracts
that bookkeeping into a proper engine:

* :class:`Resource` — a serially-reusable unit (one R5 core, one AXI/DMA
  wire, one packetizer, one link direction) with occupancy accounting.
* :class:`Engine` — owns every resource of the simulated machine, an
  optional per-send :class:`TraceEvent` log, and the **path table**: routes
  and their derived per-path constants (:class:`PathMetrics`) are computed
  once per (src, dst) pair and reused across sends, which is what makes
  paper-scale sweeps (256+ ranks) fast.

The closed-form latency/bandwidth math stays in ``network.py``; the engine
is the substrate it runs on.
"""

from __future__ import annotations

import dataclasses

from repro.core.exanet.topology import Path

#: resource kinds (the shared units of §4.4-4.5)
R5 = "r5"        # per-MPSoC R5 transaction-layer firmware
DMA = "dma"      # per-MPSoC AXI/DMA wire
PKTZ = "pktz"    # per-MPSoC packetizer
LINK = "link"    # one physical link direction


class Resource:
    """A serially-reusable resource with busy-time accounting."""

    __slots__ = ("key", "free_at", "busy_us", "n_acquires")

    def __init__(self, key: tuple):
        self.key = key
        self.free_at = 0.0
        self.busy_us = 0.0
        self.n_acquires = 0

    def acquire(self, t: float, duration_us: float) -> float:
        """Acquire from time ``t`` for ``duration_us``; returns the actual
        start time (``max(t, free_at)``)."""
        start = self.free_at if self.free_at > t else t
        self.free_at = start + duration_us
        self.busy_us += duration_us
        self.n_acquires += 1
        return start


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One send through the engine (recorded when tracing is enabled)."""
    t_issue: float
    src_core: int
    dst_core: int
    nbytes: int
    transport: str          # "eager" | "rendezvous"
    t_complete: float
    t_sender_free: float


@dataclasses.dataclass(frozen=True)
class PathMetrics:
    """Route + per-path constants derived once and reused on every send.

    Besides the physical quantities, the table pins the :class:`Resource`
    objects the path touches, so the send hot loop is pure arithmetic plus
    ``Resource.acquire`` calls — no dict lookups.
    """
    path: Path
    src_mpsoc: int
    dst_mpsoc: int
    hop_latency_us: float          # links + routers + local switches
    eager_wire_us_per_byte: float  # sum of 8/(rate*1000) over the links
    rdma_bw_gbps: float            # single-stream RDMA bandwidth
    eager_pp_const_us: float       # ping-pong base + hop latency
    eager_ow_const_us: float       # one-way base + hop latency
    handshake_pp_us: float         # 2x 0-byte eager control (RTS+CTS)
    handshake_ow_us: float
    stream_us_per_byte: float      # 8/(rdma_bw*1000)
    pktz_src: Resource
    r5_src: Resource
    dma_src: Resource
    dma_dst: Resource | None       # None for intra-MPSoC loopback
    link_res: tuple                # link Resources along the path


class Engine:
    """Owns the shared resources, the path table and the optional trace.

    ``reset()`` clears occupancy state between simulated collectives but
    keeps the path table — routes do not change with time.
    """

    def __init__(self, *, trace: bool = False, cache_paths: bool = True):
        self.tracing = trace
        self.cache_paths = cache_paths
        self._resources: dict[tuple, Resource] = {}
        self.path_table: dict[tuple[int, int], PathMetrics] = {}
        self.trace: list[TraceEvent] = []

    # ------------------------------------------------------------- resources
    def resource(self, kind: str, ident) -> Resource:
        key = (kind, ident)
        r = self._resources.get(key)
        if r is None:
            r = self._resources[key] = Resource(key)
        return r

    def reset(self) -> None:
        # zero in place (don't clear): PathMetrics entries hold direct
        # references to these Resource objects across collectives
        for r in self._resources.values():
            r.free_at = 0.0
            r.busy_us = 0.0
            r.n_acquires = 0
        self.trace.clear()

    # ------------------------------------------------------------ path table
    def metrics(self, src_core: int, dst_core: int):
        """Cached :class:`PathMetrics` lookup; ``None`` on miss (the caller
        builds and registers it via :meth:`register_metrics`)."""
        if not self.cache_paths:
            return None
        return self.path_table.get((src_core, dst_core))

    def register_metrics(self, m: PathMetrics) -> PathMetrics:
        if self.cache_paths:
            self.path_table[(m.path.src_core, m.path.dst_core)] = m
        return m

    # ----------------------------------------------------------------- trace
    def record(self, ev: TraceEvent) -> None:
        if self.tracing:
            self.trace.append(ev)

    # ------------------------------------------------------------- reporting
    def utilization(self, t_end: float) -> dict[tuple, float]:
        """Busy fraction of every touched resource over [0, t_end]."""
        if t_end <= 0.0:
            return {}
        return {k: r.busy_us / t_end for k, r in self._resources.items()}

    def occupancy_stats(self) -> dict[tuple, dict]:
        return {k: {"busy_us": r.busy_us, "n_acquires": r.n_acquires,
                    "free_at": r.free_at}
                for k, r in self._resources.items()}
