"""Compiled execution backend: collective schedules as vectorized programs.

The interpreter (:meth:`repro.core.exanet.mpi.ExanetMPI.run_schedule`) walks
every send of a schedule through a Python call chain (``Network._send`` →
per-resource ``Resource.acquire``), which caps paper-scale sweeps at ~1M
simulated sends/sec.  This module lowers a schedule's rounds — for a fixed
(nranks, rank placement, topology) — into a cached :class:`RoundProgram` of
NumPy arrays, then replays them with array arithmetic:

* **compile** (once per schedule x nranks): per-send src/dst indices, the
  gathered :class:`PathMetrics` constants (hop latency, wire us/byte,
  handshake constants, stream us/byte) and the shared-resource rows each
  send touches (:meth:`Engine.resource_id`), plus the *level* decomposition
  described below;
* **bind** (once per message-size grid): per-round byte counts, reduce
  sizes and eager/rendez-vous transport flags for every size in the batch —
  the program structure is byte-size parameterized, so one compiled program
  serves a whole message-size sweep as one batched run;
* **execute**: per round, resource contention resolves through
  :func:`repro.core.exanet.sim.segmented_maxplus_scan` (grouped running
  maxima) against an array-backed :class:`ResourceState` instead of per-send
  Python calls.

Exactness
=========
The interpreter stays the reference semantics; the compiled executor must
match it to ~1e-9 relative (enforced by ``tests/test_exec_compiled.py`` and
the hypothesis property test).  Two constructions make that possible:

* Within one round, the interpreter acquires every resource in *send
  order*.  Acquires of one resource from the same pipeline stage (e.g. the
  four ranks of an MPSoC hitting its R5 in a rendez-vous round) compose
  associatively in max-plus arithmetic, so a whole contention group
  resolves in one segmented scan.
* Acquires of one resource from *different* stages (a DMA engine that is
  send A's source and send B's destination, a link crossed at hop 1 by one
  path and hop 3 by another) cannot be reordered stage-major.  At compile
  time each round is split into **levels**: send j lands one level after
  send i whenever a shared resource is touched at different stages (or, in
  one-way rounds, when j's issue clock reads a rank i just wrote).  Levels
  execute in order; within a level only same-stage sharing remains, which
  the scans serialize in send order — reproducing the interpreter's
  acquisition order exactly.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.exanet.scan_engine import NUMPY, resolve_engine
from repro.core.exanet.sim import ResourceState, scan_take_masks

NEG_INF = float("-inf")


class ProgramStructureError(ValueError):
    """A schedule's round structure changed with message size, so one
    compiled program cannot serve the requested size grid (the ``auto``
    backend falls back to the interpreter)."""


# ---------------------------------------------------------------------------
# compile-time pieces
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Stage:
    """One pipeline stage of a level: which sends acquire which resource
    rows, laid out contiguously per contention group in send order."""
    sperm: np.ndarray       # (m,) indices into the level's send arrays
    rows: np.ndarray        # (m,) resource row per acquire (sperm order)
    first: np.ndarray       # (m,) segment-start mask
    last: np.ndarray        # (m,) segment-end mask
    max_group: int
    takes: list             # precomputed Hillis-Steele combine masks
    kpos: np.ndarray        # (m, 1) within-group ordinal
    kpos1: np.ndarray       # (m, 1) ordinal + 1
    #: duration constant within every group (per batch column) — enables
    #: the running-max fast path when activity is also column-uniform
    pb_uniform: bool


def _make_stage(positions, rows, pb=None, span=None,
                force_grouped=False) -> _Stage | None:
    """Sort (level-position, resource-row) acquires into grouped layout;
    ``pb`` (per-byte duration factors) marks whether durations are
    group-constant for the scan fast path.  Contention-free stages skip
    the grouped layout entirely (acquire order is irrelevant when no row
    repeats); a full-cover contention-free stage (``span`` == stage size)
    keeps the level's own array order (``sperm`` None)."""
    m = len(positions)
    if m == 0:
        return None
    positions = np.asarray(positions, dtype=np.int64)
    rows = np.asarray(rows, dtype=np.int64)
    if not force_grouped and len(np.unique(rows)) == m:
        sperm = None if span == m else positions
        return _Stage(sperm, rows, None, None, 1, [], None, None, True)
    order = np.argsort(rows, kind="stable")   # stable: keeps send order
    sperm = positions[order]
    rows = rows[order]
    first = np.empty(m, dtype=bool)
    first[0] = True
    first[1:] = rows[1:] != rows[:-1]
    last = np.empty(m, dtype=bool)
    last[-1] = True
    last[:-1] = first[1:]
    seg = np.cumsum(first) - 1
    max_group = int(np.bincount(seg).max())
    idx = np.arange(m)
    kpos = idx - np.maximum.accumulate(np.where(first, idx, 0))
    if pb is None:
        pb_uniform = True
    else:
        ps = np.asarray(pb)[order]
        nf = np.flatnonzero(~first)
        pb_uniform = bool((ps[nf] == ps[nf - 1]).all())
    return _Stage(sperm, rows, first, last, max_group,
                  scan_take_masks(first, max_group),
                  kpos[:, None].astype(np.float64),
                  (kpos + 1)[:, None].astype(np.float64), pb_uniform)


def _dst_grouping(dst, positions=None):
    """(perm, reduceat starts, unique dst) for grouped running maxima;
    ``positions`` restricts (and renames) the contributing indices."""
    dst = np.asarray(dst, dtype=np.int64)
    if positions is None:
        positions = np.arange(len(dst), dtype=np.int64)
    if len(dst) == 0:
        return None, None, None
    perm = positions[np.argsort(dst, kind="stable")]
    sd = np.sort(dst, kind="stable")
    first = np.empty(len(sd), dtype=bool)
    first[0] = True
    first[1:] = sd[1:] != sd[:-1]
    return perm, np.flatnonzero(first), sd[first]


@dataclasses.dataclass
class _Level:
    sel: np.ndarray                  # (k,) round-send indices, ascending
    # per-send constants, shaped (k, 1) for broadcasting over the batch
    e_const: np.ndarray
    eager_pb: np.ndarray
    handshake: np.ndarray
    stream_pb: np.ndarray
    hop: np.ndarray
    pktz: _Stage
    r5: _Stage
    dsrc: _Stage
    links: list                      # list[_Stage] per link position
    ddst: _Stage | None
    # one-way epilogue (None for exchange rounds)
    src_ranks: np.ndarray | None
    dst_perm: np.ndarray | None
    dst_starts: np.ndarray | None
    udst: np.ndarray | None
    # raw per-send link data (k, L) / (k,), -1/0-padded: lets a batched
    # link-degradation axis (:class:`LinkDegrade`) recompute the derived
    # constants per column at run time (DESIGN.md §2.10)
    link_ids: np.ndarray | None = None
    link_rate: np.ndarray | None = None
    link_wire: np.ndarray | None = None
    n_links: np.ndarray | None = None


@dataclasses.dataclass
class _EagerRound:
    """Round-wide eager transport of an exchange round: the packetizer is
    only ever shared same-stage, so the eager branch never needs the level
    decomposition and runs once per round."""
    pktz: _Stage
    e_const: np.ndarray
    eager_pb: np.ndarray
    # raw link data for the batched degradation axis (eager sends pay the
    # per-link serialization + extra latency, but no stream/handshake)
    link_ids: np.ndarray | None = None
    link_rate: np.ndarray | None = None
    n_links: np.ndarray | None = None


class LinkDegrade:
    """Per-(link, batch-column) degradation: the new binding axes of the
    batched substrate (DESIGN.md §2.10).  ``slow``/``extra_us`` are
    ``(n_resource_rows, N)`` arrays indexed by :meth:`Engine.resource_id`
    LINK rows: ``slow`` divides a link's serialization rate and sustained
    wire bandwidth (bandwidth-scale axis), ``extra_us`` adds per-link
    one-way latency (latency axis).

    At run time every level's derived constants are recomputed per column
    with the *same formulas* ``Network.path_metrics`` uses — per-link
    eager serialization summed, bottleneck wire bandwidth through the
    §6.1.1 16KB-block RDMA formula, handshake/hop picking up the extra
    latency — so an all-ones column is bit-identical to the undegraded
    constants and the interpreter twin agrees to ~1e-9 under degradation.
    Loopback sends (no links) are AXI-bound and keep their base constants.
    """

    def __init__(self, slow, extra_us, p):
        self.slow = np.asarray(slow, dtype=np.float64)
        self.extra = np.asarray(extra_us, dtype=np.float64)
        if self.slow.shape != self.extra.shape:
            raise ValueError(f"slow {self.slow.shape} != extra "
                             f"{self.extra.shape}")
        self.ncols = self.slow.shape[1]
        self._block_bits = p.rdma_block_bytes * 8.0
        self._gap_us = p.rdma_block_gap_us
        self._cache: dict[int, dict] = {}

    def column(self, j: int) -> "LinkDegrade":
        """A one-column view (the per-binding reference lane)."""
        return LinkDegrade(self.slow[:, j:j + 1], self.extra[:, j:j + 1],
                           _ParamsView(self._block_bits, self._gap_us))

    def consts(self, lv) -> dict:
        """Recomputed per-column constants of one level (cached per level
        object: levels are compile-time artifacts that outlive runs)."""
        out = self._cache.get(id(lv))
        if out is not None:
            return out
        ids = lv.link_ids
        if ids is None or ids.size == 0:
            out = {"e_const": lv.e_const, "eager_pb": lv.eager_pb}
            if hasattr(lv, "handshake"):
                out.update(handshake=lv.handshake, stream_pb=lv.stream_pb,
                           hop=lv.hop)
            self._cache[id(lv)] = out
            return out
        mask = ids >= 0                                    # (k, L)
        idx = np.where(mask, ids, 0)
        s = self.slow[idx]                                 # (k, L, N)
        ex = np.where(mask[..., None], self.extra[idx], 0.0)
        exsum = ex.sum(axis=1)                             # (k, N)
        rate = np.where(mask[..., None], lv.link_rate[..., None], np.inf)
        pb = 8.0 / ((rate / s) * 1000.0)   # exactly 0.0 on padding
        has = (lv.n_links > 0)[:, None]
        out = {"e_const": lv.e_const + exsum,
               "eager_pb": np.where(has, pb.sum(axis=1), lv.eager_pb)}
        if hasattr(lv, "handshake"):                       # full _Level
            wire = np.where(mask[..., None],
                            lv.link_wire[..., None] / s, np.inf)
            wmin = wire.min(axis=1)                        # (k, N)
            t_block = self._block_bits / (wmin * 1000.0) + self._gap_us
            bw = self._block_bits / t_block / 1000.0
            out["handshake"] = lv.handshake + 2.0 * exsum
            out["stream_pb"] = np.where(has, 8.0 / (bw * 1000.0),
                                        lv.stream_pb)
            out["hop"] = lv.hop + exsum
        self._cache[id(lv)] = out
        return out


@dataclasses.dataclass
class _ParamsView:
    """The two HwParams fields :class:`LinkDegrade` needs, for views."""
    _block_bits: float
    _gap_us: float

    @property
    def rdma_block_bytes(self) -> float:
        return self._block_bits / 8.0

    @property
    def rdma_block_gap_us(self) -> float:
        return self._gap_us


def _deg_col(a: np.ndarray, cols) -> np.ndarray:
    """Column-subset a degraded (k, N) constant; (k, 1) arrays broadcast
    over any subset and pass through untouched."""
    return a if cols is None or a.shape[1] == 1 else a[:, cols]


@dataclasses.dataclass
class _LoweredRound:
    src: np.ndarray
    dst: np.ndarray
    exchange: bool
    sync: bool
    levels: list
    eager: _EagerRound | None = None
    # exchange epilogue
    src_perm: np.ndarray | None = None
    src_starts: np.ndarray | None = None
    usrc: np.ndarray | None = None
    dst_perm: np.ndarray | None = None
    dst_starts: np.ndarray | None = None
    udst: np.ndarray | None = None
    participants: np.ndarray | None = None
    ack: _Stage | None = None
    ack_first_of_sender: np.ndarray | None = None   # (m,) sperm-order mask
    ack_src: np.ndarray | None = None               # (m,) sender rank
    ack_last_pos: np.ndarray | None = None          # positions of last send
    ack_senders: np.ndarray | None = None           # rank per last position
    # one-way epilogue
    round_udst: np.ndarray | None = None


@dataclasses.dataclass
class _BoundRound:
    nb: np.ndarray          # (1, B) uniform-bytes round, else (n, B)
    t_red: np.ndarray       # (B,)
    penalty: np.ndarray     # (B,)
    rdv_round: np.ndarray   # (B,) bool — the interpreter's first-send rule
    is_rdv: np.ndarray      # per-send transport mask, same shape as nb
    col_uniform: bool       # transport uniform within each batch column
    any_e: bool
    any_r: bool
    cols_e: np.ndarray | None   # eager batch columns (mixed uniform rounds)
    cols_r: np.ndarray | None


@dataclasses.dataclass
class _BoundProgram:
    sizes: tuple
    rounds: list
    pre_copy_us: np.ndarray   # (B,)
    post_copy_us: np.ndarray  # (B,)


@dataclasses.dataclass
class BatchScheduleResult:
    """One compiled replay over a message-size grid."""
    sizes: tuple
    latency_us: np.ndarray     # (B,)
    clocks: np.ndarray         # (B, nranks) per-rank completion times
    round_heads: list          # first (src, dst) per non-empty round

    @property
    def n_rounds(self) -> int:
        return len(self.round_heads)


def _send_res_tags(pm, n):
    """Per-send (resource_row, stage_tag) pairs for the level analysis."""
    link_rows = pm["link_ids"]
    n_links = pm["n_links"]
    res_tags = []
    for i in range(n):
        tags = [(int(pm["pktz_id"][i]), "E"),
                (int(pm["r5_id"][i]), "R"),
                (int(pm["dma_src_id"][i]), "S")]
        for k in range(int(n_links[i])):
            tags.append((int(link_rows[i, k]), k))
        if pm["dma_dst_id"][i] >= 0:
            tags.append((int(pm["dma_dst_id"][i]), "D"))
        res_tags.append(tags)
    return res_tags


def _level_assignment(n, src, dst, res_tags, exchange):
    """Longest-path level per send (see module docstring).

    ``res_tags[i]`` lists ``(resource_row, stage_tag)`` pairs; same-tag
    sharing costs nothing extra (scans keep send order), cross-tag sharing
    forces a later level.  One-way rounds add the clock-coupling rules:
    a send reading a rank another send wrote must run in a later level.
    """
    row_tags: dict = {}
    src_lv: dict = {}
    dst_lv: dict = {}
    levels = np.zeros(n, dtype=np.int64)
    for i in range(n):
        lv = 0
        for (row, tag) in res_tags[i]:
            tags = row_tags.get(row)
            if tags:
                for t2, l2 in tags.items():
                    need = l2 if t2 == tag else l2 + 1
                    if need > lv:
                        lv = need
        if not exchange:
            a = src_lv.get(src[i])
            if a is not None and a + 1 > lv:
                lv = a + 1              # j assigned clocks[s] that i reads
            b = dst_lv.get(src[i])
            if b is not None and b + 1 > lv:
                lv = b + 1              # j max-wrote clocks[s] that i reads
            c = src_lv.get(dst[i])
            if c is not None and c > lv:
                lv = c                  # j read clocks[s_j] before i's write
        levels[i] = lv
        for (row, tag) in res_tags[i]:
            d = row_tags.setdefault(row, {})
            if d.get(tag, -1) < lv:
                d[tag] = lv
        if not exchange:
            if src_lv.get(src[i], -1) < lv:
                src_lv[src[i]] = lv
            if dst_lv.get(dst[i], -1) < lv:
                dst_lv[dst[i]] = lv
    return levels


# ---------------------------------------------------------------------------
# the vectorized transport substrate (shared by RoundProgram and the Program
# compiler in program_compiled.py)
# ---------------------------------------------------------------------------
class VecTransport:
    """The eager / rendez-vous transports over an array-backed
    :class:`ResourceState`: stage acquisition via the segmented max-plus
    scans, exactly mirroring ``Network._send``'s acquire chain.  Both
    compiled executors — collective :class:`RoundProgram`\\ s and whole
    Program-IR artifacts (:mod:`repro.core.exanet.program_compiled`) —
    run their sends through these three methods, so there is exactly one
    vectorized implementation of the interpreter's transport semantics."""

    def _init_transport(self, p):
        self._p = p
        self._eng = NUMPY     # scan engine; rebound per run (engine=)
        self._deg = None      # LinkDegrade axis; rebound per run (deg=)
        self._eager_max = p.mpi_eager_max_bytes
        self._pktz_occ = p.pktz_occupancy_us
        self._pktz_ret = p.pktz_occupancy_us + p.a53_call_overhead_us
        self._r5_occ = p.r5_occupancy_us
        self._rdma_startup = p.rdma_startup_us

    def _stage_acquire(self, state, st, t, dur, act, dur_const, cols):
        """Acquire one stage; ``t`` is the branch's (k, Bc) issue array,
        result is the start times in ``st.sperm`` order (level order when
        ``sperm`` is None — a contention-free full-cover stage).

        ``act`` is the per-send activity mask (None = all active; only
        non-column-uniform rounds mask).  ``cols`` restricts the acquire
        to a batch-column subset (the transport split of a mixed
        column-uniform round).  ``dur_const`` promises the duration is
        group-constant per column, unlocking the running-max fast path.
        """
        gather = st.sperm is not None
        ts = t[st.sperm] if gather else t
        scalar_dur = not isinstance(dur, np.ndarray)
        ds = dur if scalar_dur or not gather else dur[st.sperm]
        rows = st.rows
        if st.max_group == 1:
            if cols is not None:
                ix = (rows[:, None], cols[None, :])
                free = state.free[ix]
                start = np.maximum(ts, free)
                state.free[ix] = start + ds
                return start
            if act is None:
                return state.acquire_unique(rows, ts, ds)
            return state.acquire_unique_masked(
                rows, ts, ds, act[st.sperm] if gather else act)
        if cols is not None:
            ix = (rows[:, None], cols[None, :])
            F0 = state.free[ix]
        else:
            F0 = state.free[rows]
        if dur_const and act is None:
            # group-constant durations: one plain running-max scan
            v = self._eng.running_max(ts - st.kpos * ds, st.takes)
            f_after = np.maximum(v, F0) + st.kpos1 * ds
        else:
            if act is None:
                D, T = np.array(ds, copy=True), ts + ds
                if D.shape != T.shape:
                    D = np.broadcast_to(D, T.shape).copy()
            else:
                asub = act[st.sperm] if gather else act
                D = np.where(asub, ds, 0.0)
                T = np.where(asub, ts + ds, NEG_INF)
            Dacc, Tacc = self._eng.maxplus_scan(D, T, st.takes)
            f_after = np.maximum(F0 + Dacc, Tacc)
        if cols is not None:
            state.free[(rows[st.last][:, None], cols[None, :])] = \
                f_after[st.last]
        else:
            state.free[rows[st.last]] = f_after[st.last]
        return f_after - ds

    def _run_eager(self, state, lv, t_issue, nbl, act, cols):
        """The packetizer/mailbox transport: (complete, sender_free)."""
        if self._deg is None:
            e_const, eager_pb = lv.e_const, lv.eager_pb
        else:
            c = self._deg.consts(lv)
            e_const = _deg_col(c["e_const"], cols)
            eager_pb = _deg_col(c["eager_pb"], cols)
        st = lv.pktz
        r = self._stage_acquire(state, st, t_issue, self._pktz_occ, act,
                                True, cols)
        if st.sperm is None:
            dep = r
        else:
            dep = np.empty(t_issue.shape)
            dep[st.sperm] = r
        comp = dep + e_const + nbl * eager_pb
        return comp, dep + self._pktz_ret

    def _run_rdv(self, state, lv, t_issue, nbl, act, cols, uni):
        """The RTS/CTS + RDMA transport: (complete, complete)."""
        if self._deg is None:
            handshake, stream_pb, hop = lv.handshake, lv.stream_pb, lv.hop
        else:
            # per-column constants: the group-constant-duration fast path
            # no longer applies, force the exact max-plus general path
            c = self._deg.consts(lv)
            handshake = _deg_col(c["handshake"], cols)
            stream_pb = _deg_col(c["stream_pb"], cols)
            hop = _deg_col(c["hop"], cols)
            uni = False
        stream = nbl * stream_pb
        st = lv.r5
        r = self._stage_acquire(state, st, t_issue + handshake,
                                self._r5_occ, act, True, cols)
        if st.sperm is None:
            cur = r + self._rdma_startup
        else:
            cur = np.empty(t_issue.shape)
            cur[st.sperm] = r
            cur += self._rdma_startup
        st = lv.dsrc
        s0 = self._stage_acquire(state, st, cur, stream, act,
                                 uni and st.pb_uniform, cols)
        if st.sperm is None:
            cur = s0
        else:
            cur[st.sperm] = s0
        occupied = cur + stream
        for st in lv.links:
            s0 = self._stage_acquire(state, st, cur, stream, act,
                                     uni and st.pb_uniform, cols)
            cur[st.sperm] = s0
            occupied[st.sperm] = s0 + stream[st.sperm]
        st = lv.ddst
        if st is not None:
            s0 = self._stage_acquire(state, st, cur, stream, act,
                                     uni and st.pb_uniform, cols)
            occupied[st.sperm] = s0 + stream[st.sperm]
        comp = occupied + hop
        return comp, comp


# ---------------------------------------------------------------------------
# the program
# ---------------------------------------------------------------------------
class RoundProgram(VecTransport):
    """A schedule lowered for one (nranks, placement, topology)."""

    def __init__(self, net, sched, cores, nranks):
        self.schedule_name = getattr(sched, "name", type(sched).__name__)
        self.one_way = bool(sched.one_way)
        self.nranks = nranks
        self.cores = list(cores)
        self._init_transport(net.p)
        self.round_heads: list = []
        self.rounds: list = []
        self._bind_cache: dict = {}
        self._size_cache: dict = {}
        self._compile(net, sched)
        self.n_rows = net.engine.n_resource_ids

    # ----------------------------------------------------------- compilation
    def _compile(self, net, sched):
        one_way = self.one_way
        structure = []
        for rnd in sched.rounds(self.nranks, _STRUCT_SIZE):
            if not rnd.sends:
                continue
            structure.append(rnd)
        for rnd in structure:
            self.round_heads.append(rnd.sends[0][:2])
            self.rounds.append(self._lower_round(net, rnd, one_way))

    def _lower_round(self, net, rnd, one_way):
        src = np.array([s for (s, _, _) in rnd.sends], dtype=np.int64)
        dst = np.array([d for (_, d, _) in rnd.sends], dtype=np.int64)
        n = len(src)
        pairs = [(self.cores[s], self.cores[d]) for (s, d, _) in rnd.sends]
        pm = net.path_metrics_arrays(pairs)
        e_const = pm["eager_ow_const_us"] if one_way else \
            pm["eager_pp_const_us"]
        handshake = pm["handshake_ow_us"] if one_way else \
            pm["handshake_pp_us"]
        link_rows = pm["link_ids"]
        n_links = pm["n_links"]
        max_links = int(n_links.max()) if n else 0

        levels_of = _level_assignment(n, src, dst, _send_res_tags(pm, n),
                                      rnd.exchange)

        levels = []
        for lv in range(int(levels_of.max()) + 1 if n else 0):
            sel = np.flatnonzero(levels_of == lv)
            k = len(sel)
            pos = np.arange(k)
            link_stages = []
            for pos_k in range(max_links):
                sub = np.flatnonzero(n_links[sel] > pos_k)
                link_stages.append(_make_stage(
                    pos[sub], link_rows[sel[sub], pos_k],
                    pm["stream_us_per_byte"][sel[sub]]))
            ddst_sub = np.flatnonzero(pm["dma_dst_id"][sel] >= 0)
            if rnd.exchange:
                src_ranks = dperm = dstarts = udst = None
            else:
                src_ranks = src[sel]
                # a self-send's receive clock is overwritten by its own
                # sender-side assignment (the interpreter maxes clocks[d]
                # *before* assigning clocks[s]), so it never contributes
                keep = np.flatnonzero(src[sel] != dst[sel])
                dperm, dstarts, udst = _dst_grouping(dst[sel[keep]], keep)
            spb = pm["stream_us_per_byte"][sel]
            levels.append(_Level(
                sel=sel,
                e_const=e_const[sel][:, None],
                eager_pb=pm["eager_wire_us_per_byte"][sel][:, None],
                handshake=handshake[sel][:, None],
                stream_pb=spb[:, None],
                hop=pm["hop_latency_us"][sel][:, None],
                # exchange rounds run their eager branch round-wide
                pktz=None if rnd.exchange else
                _make_stage(pos, pm["pktz_id"][sel], span=k),
                r5=_make_stage(pos, pm["r5_id"][sel], span=k),
                dsrc=_make_stage(pos, pm["dma_src_id"][sel], spb, span=k),
                links=[st for st in link_stages if st is not None],
                ddst=_make_stage(ddst_sub, pm["dma_dst_id"][sel[ddst_sub]],
                                 spb[ddst_sub]),
                src_ranks=src_ranks, dst_perm=dperm, dst_starts=dstarts,
                udst=udst,
                link_ids=link_rows[sel],
                link_rate=pm["link_rate_gbps"][sel],
                link_wire=pm["link_wire_gbps"][sel],
                n_links=n_links[sel]))

        out = _LoweredRound(src=src, dst=dst, exchange=rnd.exchange,
                            sync=rnd.sync, levels=levels)
        if rnd.exchange:
            out.eager = _EagerRound(
                _make_stage(np.arange(n), pm["pktz_id"], span=n),
                e_const[:, None], pm["eager_wire_us_per_byte"][:, None],
                link_ids=link_rows, link_rate=pm["link_rate_gbps"],
                n_links=n_links)
            out.src_perm, out.src_starts, out.usrc = _dst_grouping(src)
            out.dst_perm, out.dst_starts, out.udst = _dst_grouping(dst)
            out.participants = np.unique(np.concatenate([src, dst]))
            # end-to-end-ACK phase: one R5 invocation per send, in send
            # order, serialized per MPSoC (§4.5.2)
            ack = _make_stage(np.arange(n), pm["r5_id"],
                              force_grouped=True)
            ack_src = src[ack.sperm]
            seen: set = set()
            first_of_sender = np.zeros(n, dtype=bool)
            last_pos: dict = {}
            for j in range(n):
                s = int(ack_src[j])
                if s not in seen:
                    seen.add(s)
                    first_of_sender[j] = True
                last_pos[s] = j
            out.ack = ack
            out.ack_src = ack_src
            out.ack_first_of_sender = first_of_sender
            out.ack_last_pos = np.array(sorted(last_pos.values()),
                                        dtype=np.int64)
            out.ack_senders = ack_src[out.ack_last_pos]
        else:
            out.round_udst = np.unique(dst)
        return out

    # ----------------------------------------------------------------- bind
    def _round_bytes(self, sched, size, cache=True):
        """Per-round byte data for one size, verifying the structure."""
        cached = self._size_cache.get(size) if cache else None
        if cached is not None:
            return cached
        per_round = []
        rid = 0
        for rnd in sched.rounds(self.nranks, size):
            if not rnd.sends:
                continue
            if rid >= len(self.rounds):
                raise ProgramStructureError(
                    f"{self.schedule_name}: round count varies with size")
            r = self.rounds[rid]
            nb = np.fromiter((b for (_, _, b) in rnd.sends),
                             dtype=np.float64, count=len(rnd.sends))
            if (len(nb) != len(r.src) or rnd.exchange != r.exchange
                    or rnd.sync != r.sync):
                raise ProgramStructureError(
                    f"{self.schedule_name}: round shape varies with size")
            s2 = np.fromiter((s for (s, _, _) in rnd.sends),
                             dtype=np.int64, count=len(nb))
            d2 = np.fromiter((d for (_, d, _) in rnd.sends),
                             dtype=np.int64, count=len(nb))
            if not (np.array_equal(s2, r.src) and np.array_equal(d2, r.dst)):
                raise ProgramStructureError(
                    f"{self.schedule_name}: send structure varies with size")
            uniform = bool((nb == nb[0]).all())
            per_round.append((nb[:1] if uniform else nb,
                              float(rnd.reduce_bytes), float(nb[0])))
            rid += 1
        if rid != len(self.rounds):
            raise ProgramStructureError(
                f"{self.schedule_name}: round count varies with size")
        data = (per_round, float(sched.pre_copy_bytes(size)),
                float(sched.post_copy_bytes(size)))
        if cache:
            self._size_cache[size] = data
        return data

    def _copy_us(self, nb):
        p = self._p
        return np.where(nb > 0,
                        nb / p.a53_copy_bw_bytes_per_us
                        + p.a53_call_overhead_us, 0.0)

    def _reduce_us(self, nb):
        p = self._p
        return np.where(nb > 0,
                        3.0 * nb / p.a53_copy_bw_bytes_per_us
                        + p.a53_call_overhead_us, 0.0)

    def bind(self, sched, sizes, cache=True) -> _BoundProgram:
        """Per-size byte counts, transport flags and endpoint copy costs
        for a size grid; cached, so a repeated sweep only pays once.

        ``cache=False`` bypasses both the bind and per-size byte caches:
        population binding (DESIGN.md §2.8) reuses one lowered program
        across search generations while the payload behind each member
        *token* changes, so cached byte grids would be stale."""
        key = tuple(int(s) for s in sizes)
        bound = self._bind_cache.get(key) if cache else None
        if bound is not None:
            return bound
        per_size = [self._round_bytes(sched, s, cache) for s in key]
        p = self._p
        rounds = []
        for rid in range(len(self.rounds)):
            cols = [ps[0][rid][0] for ps in per_size]
            uniform = all(c.shape[0] == 1 for c in cols)
            if uniform:
                nb = np.array([c[0] for c in cols])[None, :]
            else:
                n = len(self.rounds[rid].src)
                nb = np.stack([np.broadcast_to(c, (n,)) for c in cols],
                              axis=1)
            red = np.array([ps[0][rid][1] for ps in per_size])
            first_b = np.array([ps[0][rid][2] for ps in per_size])
            rdv = first_b > self._eager_max
            penalty = np.where(rdv, p.sendrecv_sw_rdv_us,
                               p.sendrecv_sw_eager_us)
            is_rdv = nb > self._eager_max
            col_uniform = is_rdv.shape[0] == 1
            any_e = bool((~is_rdv).any())
            any_r = bool(is_rdv.any())
            cols_e = cols_r = None
            if col_uniform and any_e and any_r:
                cols_e = np.flatnonzero(~is_rdv[0])
                cols_r = np.flatnonzero(is_rdv[0])
            rounds.append(_BoundRound(
                nb=nb, t_red=self._reduce_us(red), penalty=penalty,
                rdv_round=rdv, is_rdv=is_rdv, col_uniform=col_uniform,
                any_e=any_e, any_r=any_r, cols_e=cols_e, cols_r=cols_r))
        bound = _BoundProgram(
            sizes=key, rounds=rounds,
            pre_copy_us=self._copy_us(np.array([ps[1] for ps in per_size])),
            post_copy_us=self._copy_us(np.array([ps[2] for ps in per_size])))
        self._bind_cache[key] = bound
        return bound

    # ------------------------------------------------------------ execution
    def _exec_exchange_round(self, state, r, rb, t_issue, B):
        """All sends of an exchange round: the eager branch runs once
        round-wide (packetizer sharing is always same-stage), the
        rendez-vous branch walks the level decomposition."""
        n = len(r.src)
        complete = np.empty((n, B))
        sender_free = np.empty((n, B))
        if rb.col_uniform:
            if rb.any_e and rb.any_r:
                ce, cr = rb.cols_e, rb.cols_r
                comp_e, sfree_e = self._run_eager(
                    state, r.eager, t_issue[:, ce], rb.nb[:, ce], None, ce)
                complete[:, ce] = comp_e
                sender_free[:, ce] = sfree_e
                for lv in r.levels:
                    ix = (lv.sel[:, None], cr[None, :])
                    comp, sfree = self._run_rdv(state, lv, t_issue[ix],
                                                rb.nb[:, cr], None, cr,
                                                True)
                    complete[ix] = comp
                    sender_free[ix] = sfree
            elif rb.any_r:
                for lv in r.levels:
                    comp, sfree = self._run_rdv(state, lv,
                                                t_issue[lv.sel], rb.nb,
                                                None, None, True)
                    complete[lv.sel] = comp
                    sender_free[lv.sel] = sfree
            else:
                complete, sender_free = self._run_eager(
                    state, r.eager, t_issue, rb.nb, None, None)
            return complete, sender_free
        # per-send byte variation: masked dual execution + blend
        act_r = rb.is_rdv
        comp_e = sfree_e = None
        if rb.any_e:
            comp_e, sfree_e = self._run_eager(
                state, r.eager, t_issue, rb.nb,
                ~act_r if rb.any_r else None, None)
        if rb.any_r:
            for lv in r.levels:
                act = None if not rb.any_e else \
                    np.broadcast_to(act_r[lv.sel], (len(lv.sel), B))
                comp, sfree = self._run_rdv(state, lv, t_issue[lv.sel],
                                            rb.nb[lv.sel], act, None,
                                            False)
                complete[lv.sel] = comp
                sender_free[lv.sel] = sfree
            if rb.any_e:
                complete = np.where(act_r, complete, comp_e)
                sender_free = np.where(act_r, sender_free, sfree_e)
        else:
            complete, sender_free = comp_e, sfree_e
        return complete, sender_free

    def _exec_level(self, state, lv, t_issue, rb):
        """Run one level's sends through both transports; returns
        (complete, sender_free) in level order.  Mixed column-uniform
        rounds split the batch columns per transport (each branch runs
        unmasked on its own column subset); only rounds with per-send
        byte variation pay the masked dual-execution path."""
        if rb.col_uniform:
            nbl, rdvl = rb.nb, rb.is_rdv
            any_e, any_r = rb.any_e, rb.any_r
        else:
            nbl, rdvl = rb.nb[lv.sel], rb.is_rdv[lv.sel]
            any_e = bool((~rdvl).any())
            any_r = bool(rdvl.any())
        if not (any_e and any_r):
            if any_r:
                return self._run_rdv(state, lv, t_issue, nbl, None,
                                     None, rb.col_uniform)
            return self._run_eager(state, lv, t_issue, nbl, None, None)
        if rb.col_uniform:
            cols_e, cols_r = rb.cols_e, rb.cols_r
            comp_e, sfree_e = self._run_eager(
                state, lv, t_issue[:, cols_e], nbl[:, cols_e], None, cols_e)
            comp_r, sfree_r = self._run_rdv(
                state, lv, t_issue[:, cols_r], nbl[:, cols_r], None, cols_r,
                True)
            comp = np.empty(t_issue.shape)
            sfree = np.empty(t_issue.shape)
            comp[:, cols_e] = comp_e
            comp[:, cols_r] = comp_r
            sfree[:, cols_e] = sfree_e
            sfree[:, cols_r] = sfree_r
            return comp, sfree
        act = np.broadcast_to(rdvl, t_issue.shape)
        comp_e, sfree_e = self._run_eager(state, lv, t_issue, nbl, ~act,
                                          None)
        comp_r, sfree_r = self._run_rdv(state, lv, t_issue, nbl, act,
                                        None, False)
        return (np.where(rdvl, comp_r, comp_e),
                np.where(rdvl, sfree_r, sfree_e))

    def run(self, sched, sizes, *, state: ResourceState | None = None,
            t0: np.ndarray | None = None, engine=None,
            deg: LinkDegrade | None = None,
            cache_bind: bool = True) -> BatchScheduleResult:
        """Execute the program over a message-size grid in one batch.

        ``state``/``t0`` serve *embedded* execution inside a compiled
        Program-IR artifact (:mod:`repro.core.exanet.program_compiled`) —
        the array twin of the interpreter's ``run_schedule(t0=, reset=
        False)`` seam: ``t0`` gives per-rank per-column entry clocks
        (shape (nranks, B)), ``state`` the live occupancy the collective
        starts over (its rows must cover :attr:`n_rows`).  The level
        decomposition is start-state independent, so one lowered program
        serves both the cold standalone replay and every spliced entry.
        ``t0`` is also exact for standalone runs (fresh all-zero state):
        it batches per-rank arrival offsets — one scenario per column of
        a repeated-size grid.

        ``engine`` selects the scan backend (``"numpy"`` default,
        ``"jax"``, or an engine object; DESIGN.md §2.5).  ``deg`` binds
        the per-(link, column) degradation axes (:class:`LinkDegrade`) —
        one batched replay sweeps N fault/congestion scenarios.
        """
        self._eng = resolve_engine(engine)
        self._deg = deg
        bound = self.bind(sched, sizes, cache_bind)
        B = len(bound.sizes)
        if deg is not None and deg.ncols not in (1, B):
            raise ValueError(f"deg has {deg.ncols} columns, batch has {B}")
        p = self._p
        if state is None:
            state = ResourceState(self.n_rows, B)
        clocks = np.tile(bound.pre_copy_us, (self.nranks, 1))
        if t0 is not None:
            clocks = clocks + t0
        skew = 0.0
        for r, rb in zip(self.rounds, bound.rounds):
            if r.exchange:
                t_issue_all = clocks[r.src] + skew
                complete, sender_free = self._exec_exchange_round(
                    state, r, rb, t_issue_all, B)
                arrivals = np.zeros((self.nranks, B))
                arrivals[r.udst] = np.maximum.reduceat(
                    complete[r.dst_perm], r.dst_starts, axis=0)
                done = np.zeros((self.nranks, B))
                done[r.usrc] = np.maximum.reduceat(
                    sender_free[r.src_perm], r.src_starts, axis=0)
                if rb.rdv_round.any():
                    done = self._ack_phase(state, r, rb, done, B)
                base = np.maximum(done[r.participants],
                                  arrivals[r.participants])
                clocks[r.participants] = base + rb.penalty + rb.t_red - skew
            else:
                for lv in r.levels:
                    t_issue = clocks[lv.src_ranks] + skew
                    comp, sfree = self._exec_level(state, lv, t_issue, rb)
                    clocks[lv.src_ranks] = sfree - skew
                    if lv.udst is not None:
                        red = np.maximum.reduceat(comp[lv.dst_perm],
                                                  lv.dst_starts, axis=0)
                        clocks[lv.udst] = np.maximum(clocks[lv.udst],
                                                     red - skew)
                clocks[r.round_udst] += rb.t_red
            if r.sync:
                skew += p.step_sync_us
        latency = clocks.max(axis=0) + skew + bound.post_copy_us \
            + p.barrier_exit_us
        return BatchScheduleResult(bound.sizes, latency,
                                   (clocks + skew).T, list(self.round_heads))

    def _ack_phase(self, state, r, rb, done, B):
        """Rendez-vous end-to-end-ACK: a second R5 invocation per send on
        the sender's MPSoC, serialized in send order (§4.5.2).  Repeat
        sends of one sender chain through the previous acquire, which in
        max-plus terms is an unconditional (T = -inf) acquire."""
        st = r.ack
        occ = self._r5_occ
        act = rb.rdv_round[None, :]
        F0 = state.free[st.rows]
        # repeat sends chain (T = -inf); duration is the scalar occupancy,
        # activity column-uniform — the running-max fast path applies
        v = np.where(r.ack_first_of_sender[:, None],
                     done[r.ack_src] - st.kpos * occ, NEG_INF)
        v = self._eng.running_max(v, st.takes)
        f_after = np.maximum(v, F0) + st.kpos1 * occ
        if not rb.rdv_round.all():
            f_after = np.where(act, f_after, F0)
        state.free[st.rows[st.last]] = f_after[st.last]
        done = done.copy()
        done[r.ack_senders] = np.where(act, f_after[r.ack_last_pos],
                                       done[r.ack_senders])
        return done


#: structure-probe size used when lowering a schedule's rounds; bytes at
#: other sizes are bound per grid (and verified against this structure)
_STRUCT_SIZE = 4096


def compile_program(net, sched, cores, nranks) -> RoundProgram:
    """Lower ``sched`` for a fixed (nranks, placement, topology).  Raises
    whatever the schedule's own shape validation raises (like the
    interpreter does on its first round)."""
    return RoundProgram(net, sched, cores, nranks)


def round_parallelism(net, sched, cores, nranks) -> float:
    """Cheap pre-compile predictor of compiled-backend profitability:
    mean sends per dependency level over the schedule's first and last
    non-empty rounds.  Wide rounds (recursive doubling, broadcast trees,
    the accelerator's fan-in/out) vectorize; serial-chain rounds (the
    ring's ``r -> r+1`` pattern couples every DMA engine source-to-
    destination) degenerate to one send per level, where the interpreter
    is cheaper than replaying thousands of one-send array steps."""
    rounds = [r for r in sched.rounds(nranks, _STRUCT_SIZE) if r.sends]
    if not rounds:
        return float("inf")
    probe = [rounds[0]] if len(rounds) == 1 else [rounds[0], rounds[-1]]
    best = 0.0
    for rnd in probe:
        n = len(rnd.sends)
        src = np.fromiter((s for (s, _, _) in rnd.sends), np.int64, n)
        dst = np.fromiter((d for (_, d, _) in rnd.sends), np.int64, n)
        pm = net.path_metrics_arrays(
            [(cores[s], cores[d]) for (s, d, _) in rnd.sends])
        levels = _level_assignment(n, src, dst, _send_res_tags(pm, n),
                                   rnd.exchange)
        best = max(best, n / float(levels.max() + 1))
    return best
