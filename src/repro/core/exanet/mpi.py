"""ExaNet-MPI runtime model (§5.2.1) + OSU-style microbenchmarks (§6.1).

Point-to-point: eager (<=32 B) via packetizer/mailbox; rendez-vous otherwise
(RTS -> CTS -> RDMA write + concurrent completion notification).

Collectives are *schedules* (:mod:`repro.core.exanet.schedules`) replayed on
the discrete-event engine by :meth:`ExanetMPI.run_schedule`; the MPICH 3.2.1
algorithms the paper used (§5.2.1: binomial broadcast, recursive-doubling
allreduce) keep their historical entry points (:meth:`bcast`,
:meth:`allreduce_sw`) as thin wrappers, and the schedule split adds ring and
Rabenseifner allreduce, allgather, alltoall, barrier and scatter/gather at
no extra engine code.

Rank placement is block-packed (4 ranks/MPSoC fills cores first), matching
the §6.1.4 schedule decomposition: binomial step distance >=16 crosses a
QFDB ("mezzanine-class" step), >=4 crosses an MPSoC ("QFDB-class" step),
otherwise it is an intra-MPSoC step.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.exanet import sim
from repro.core.exanet.exec_compiled import (BatchScheduleResult,
                                             ProgramStructureError,
                                             compile_program,
                                             round_parallelism)
from repro.core.exanet.network import Network
from repro.core.exanet.params import DEFAULT, HwParams
from repro.core.exanet.schedules import (ALLREDUCE_SCHEDULES,
                                         COLLECTIVE_SCHEDULES, AllGather,
                                         AllToAll, Barrier, BinomialBroadcast,
                                         CollectiveSchedule, GatherBinomial,
                                         RecursiveDoublingAllreduce,
                                         ScatterBinomial)
from repro.core.exanet.topology import Path, Topology


@dataclasses.dataclass
class BcastResult:
    observed_us: float
    expected_us: float      # Eq. 1 analytic model
    steps: dict[str, int]   # Ns_MPSoC / Ns_QFDB / Ns_mezzanine

    @property
    def deviation(self) -> float:
        """(observed - expected)/observed, the paper's §6.1.4 metric."""
        return (self.observed_us - self.expected_us) / self.observed_us


@dataclasses.dataclass
class ScheduleResult:
    """Outcome of one schedule execution on the event engine."""
    latency_us: float
    clocks: list[float]                       # per-rank completion times
    round_heads: list[tuple[int, int]]        # first (src, dst) per round

    @property
    def n_rounds(self) -> int:
        return len(self.round_heads)


class ExanetMPI:
    def __init__(self, params: HwParams = DEFAULT, *,
                 ranks_per_mpsoc: int | None = None, trace: bool = False,
                 cache: bool = True, faults=None):
        """``cache=False`` disables both the route cache and the engine's
        path table — the pre-refactor per-send ``route()`` behaviour, kept
        for the collectives_sweep speedup benchmark.  ``faults`` takes a
        :class:`repro.core.exanet.faults.FaultSpec`: routes become
        fault-aware and every latency constant picks up the static
        degradation (DESIGN.md §2.10)."""
        self.p = params
        self.topo = Topology(params, faults=faults) if cache else \
            Topology(params, route_cache_size=0, faults=faults)
        self.net = Network(self.topo, params,
                           engine=sim.Engine(trace=trace, cache_paths=cache))
        self._rpm = ranks_per_mpsoc

    @property
    def faults(self):
        """The static :class:`FaultSpec` this machine instance carries
        (None when healthy)."""
        return self.topo.faults

    # --------------------------------------------------------- rank placement
    def rank_core(self, rank: int) -> int:
        """Block placement. With ranks_per_mpsoc=1 (accelerator comparisons,
        §6.1.5) each rank occupies core 0 of its own MPSoC."""
        if self._rpm == 1:
            return rank * self.p.cores_per_mpsoc
        return rank

    def _cores(self, nranks: int) -> list[int]:
        """Rank -> core map, cached per rank count."""
        cache = getattr(self, "_cores_cache", None)
        if cache is None:
            cache = self._cores_cache = {}
        cores = cache.get(nranks)
        if cores is None:
            cores = cache[nranks] = [self.rank_core(r) for r in range(nranks)]
        return cores

    def _r5s(self, nranks: int) -> list:
        """Rank -> R5 :class:`Resource` of its MPSoC, cached per rank count
        (rendez-vous exchange rounds charge the end-to-end ACK on it every
        collective; the engine zeroes occupancy in place on reset, so the
        objects stay valid across runs)."""
        cache = getattr(self, "_r5s_cache", None)
        if cache is None:
            cache = self._r5s_cache = {}
        r5s = cache.get(nranks)
        if r5s is None:
            engine = self.net.engine
            r5s = cache[nranks] = [
                engine.resource(sim.R5, self.topo.core_to_mpsoc(c))
                for c in self._cores(nranks)]
        return r5s

    def _rank_path(self, r0: int, r1: int | None) -> Path:
        """Route between two ranks; ``r1=None`` means the default
        intra-QFDB neighbour used by the OSU pair benchmarks."""
        if r1 is None:
            r1 = self.p.cores_per_mpsoc
        return self.topo.route(self.rank_core(r0), self.rank_core(r1))

    # ------------------------------------------------------- microbenchmarks
    def osu_latency(self, size: int, r0: int = 0, r1: int | None = None) -> float:
        """Half ping-pong latency (osu_latency)."""
        return self.net.mpi_latency(size, self._rank_path(r0, r1))

    def osu_one_way(self, size: int, r0: int, r1: int) -> float:
        return self.net.mpi_latency(size, self._rank_path(r0, r1),
                                    one_way=True)

    def osu_bw(self, size: int, r0: int = 0, r1: int | None = None) -> float:
        return self.net.osu_bw_gbps(size, self._rank_path(r0, r1))

    def osu_bibw(self, size: int, r0: int = 0, r1: int | None = None) -> float:
        return self.net.osu_bibw_gbps(size, self._rank_path(r0, r1))

    # ------------------------------------------------------ endpoint software
    def _copy_us(self, nbytes: int) -> float:
        """One A53 memcpy (buffer in / buffer out of the MPI runtime)."""
        if nbytes <= 0:
            return 0.0
        return nbytes / self.p.a53_copy_bw_bytes_per_us + \
            self.p.a53_call_overhead_us

    def _reduce_us(self, nbytes: int) -> float:
        """MPI_Reduce_local: read two operands + write one (3x traffic)."""
        if nbytes <= 0:
            return 0.0
        return 3.0 * nbytes / self.p.a53_copy_bw_bytes_per_us + \
            self.p.a53_call_overhead_us

    # --------------------------------------------------------- the executor
    #: ``backend="auto"`` compiles once the interpreter's per-send Python
    #: overhead dominates; below this rank count a single-size replay is
    #: cheaper interpreted (batched sweeps always compile).
    COMPILED_AUTO_MIN_RANKS = 512

    def run_schedule(self, sched: CollectiveSchedule, size: int,
                     nranks: int, *, backend: str = "auto",
                     t0: list[float] | None = None,
                     reset: bool = True) -> ScheduleResult:
        """Replay a schedule's rounds on the event engine.

        One-way rounds relay data down a tree (receiver clock = arrival,
        sender clock = send-engine return).  Exchange rounds have
        MPI_Sendrecv semantics: both directions must complete (plus the
        rendez-vous end-to-end-ACK R5 charge on each sender's MPSoC,
        §4.5.2) before the per-round software penalty and local reduction.

        ``backend`` selects the executor: ``"interp"`` (this method's
        per-send loop — the reference semantics), ``"compiled"`` (the
        vectorized round programs of
        :mod:`repro.core.exanet.exec_compiled`, equal to ~1e-9), or
        ``"auto"`` (compiled at paper scale / for batched sweeps, where
        the interpreter is Python-bound; interpreted otherwise, and always
        when tracing is on — the compiled path records no trace).

        ``t0``/``reset`` serve *embedded* execution inside a program
        (:meth:`run_program`): ``t0`` gives per-rank entry clocks (the
        collective starts skewed, like real ranks arriving late) and
        ``reset=False`` keeps the engine's occupancy from in-flight
        point-to-point traffic.  ``reset=False`` runs always interpret —
        the compiled executor assumes zero starting occupancy — but a
        skewed fresh start (``t0`` with ``reset=True``) is exact on both
        backends: compiled replay seeds its clocks from ``t0`` over an
        all-zero :class:`ResourceState`, just like the interpreter after
        ``net.reset()``.
        """
        if backend not in ("auto", "interp", "compiled"):
            raise ValueError(f"unknown backend {backend!r}; "
                             f"options: ['auto', 'compiled', 'interp']")
        embedded = not reset
        if embedded and backend == "compiled":
            raise ValueError("compiled backend cannot start from nonzero "
                             "occupancy; use backend='interp'")
        auto = backend == "auto"
        if auto:
            backend = "compiled" if (
                not embedded
                and not self.net.engine.tracing
                and nranks >= self.COMPILED_AUTO_MIN_RANKS
                and self.compiled_profitable(sched, nranks)) else "interp"
        if backend == "compiled":
            try:
                t0c = None if t0 is None else \
                    np.asarray(t0, dtype=np.float64)[:, None]
                batch = self.run_schedule_many(sched, (size,), nranks,
                                               t0=t0c)
            except ProgramStructureError:
                if not auto:
                    raise
            else:
                return ScheduleResult(float(batch.latency_us[0]),
                                      [float(c) for c in batch.clocks[0]],
                                      batch.round_heads)
        p = self.p
        net = self.net
        send = net._send
        one_way = sched.one_way
        eager_max = p.mpi_eager_max_bytes
        r5_occ = p.r5_occupancy_us
        if reset:
            net.reset()
        cores = self._cores(nranks)
        r5s = None  # per-rank R5 resources, bound on first rdv round
        pre = self._copy_us(sched.pre_copy_bytes(size))
        clocks = [pre] * nranks if t0 is None else [t + pre for t in t0]
        # per-step sync skew (§6.1.4 noise stand-in) hits every rank equally,
        # so it is tracked as one running offset instead of N list writes;
        # ``clocks`` stores times relative to -skew.
        skew = 0.0
        round_heads: list[tuple[int, int]] = []
        for rnd in sched.rounds(nranks, size):
            sends = rnd.sends
            if not sends:
                continue
            round_heads.append(sends[0][:2])
            if rnd.exchange:
                arrivals = [0.0] * nranks
                done = [0.0] * nranks
                rdv = sends[0][2] > eager_max
                for (s, d, nb) in sends:
                    complete, sender_free = send(cores[s], cores[d], nb,
                                                 clocks[s] + skew, one_way)
                    if complete > arrivals[d]:
                        arrivals[d] = complete
                    # a rank sending twice in one round waits for both
                    # sends (max, not last-write-wins)
                    if sender_free > done[s]:
                        done[s] = sender_free
                if rdv:
                    # end-to-end ACK processing is a second R5 invocation on
                    # the sender's MPSoC (§4.5.2) and serializes with other
                    # channels.
                    if r5s is None:
                        r5s = self._r5s(nranks)
                    for (s, _, _) in sends:
                        done[s] = r5s[s].acquire(done[s], r5_occ) + r5_occ
                penalty = p.sendrecv_sw_rdv_us if rdv else \
                    p.sendrecv_sw_eager_us
                t_red = self._reduce_us(rnd.reduce_bytes)
                participants = {s for (s, _, _) in sends} | \
                    {d for (_, d, _) in sends}
                for r in participants:
                    clocks[r] = max(done[r], arrivals[r]) + penalty + t_red \
                        - skew
            else:
                for (s, d, nb) in sends:
                    complete, sender_free = send(cores[s], cores[d], nb,
                                                 clocks[s] + skew, one_way)
                    complete -= skew
                    if complete > clocks[d]:
                        clocks[d] = complete
                    clocks[s] = sender_free - skew
                if rnd.reduce_bytes:
                    t_red = self._reduce_us(rnd.reduce_bytes)
                    for d in {d for (_, d, _) in sends}:
                        clocks[d] += t_red
            if rnd.sync:
                # deterministic stand-in for per-step late-arrival noise
                # (§6.1.4)
                skew += p.step_sync_us
        total = max(clocks) + skew + \
            self._copy_us(sched.post_copy_bytes(size)) + p.barrier_exit_us
        return ScheduleResult(total, [c + skew for c in clocks], round_heads)

    # ------------------------------------------------- compiled batch runs
    #: minimum mean sends-per-level before ``auto`` / ``cost_many`` pick
    #: the compiled backend: below this a schedule's rounds are serial
    #: chains the array executor cannot amortize (see round_parallelism)
    COMPILED_MIN_PARALLELISM = 8.0

    @staticmethod
    def _schedule_cache_key(sched: CollectiveSchedule, nranks: int):
        """Cache key of a (schedule, nranks) pair, or None when the
        schedule must not share cached artifacts: the key is the
        schedule's ``program_key()`` when it defines one, else its *type*
        — but only for instances without per-instance state (every
        shipped schedule: their structure depends only on nranks).  Two
        differently-parameterized instances of one stateful class must
        not share a lowered program or a profitability verdict."""
        key_fn = getattr(sched, "program_key", None)
        if key_fn is not None:
            return (key_fn(), nranks)
        if not getattr(sched, "__dict__", True):
            return (type(sched), nranks)
        return None

    def compiled_profitable(self, sched: CollectiveSchedule,
                            nranks: int) -> bool:
        """Would the compiled backend beat the interpreter on this
        schedule shape?  Cached under the same keying rule as
        :meth:`compiled_program`."""
        cache = getattr(self, "_parallelism_cache", None)
        if cache is None:
            cache = self._parallelism_cache = {}
        key = self._schedule_cache_key(sched, nranks)
        par = None if key is None else cache.get(key)
        if par is None:
            par = round_parallelism(self.net, sched, self._cores(nranks),
                                    nranks)
            if key is not None:
                cache[key] = par
        return par >= self.COMPILED_MIN_PARALLELISM

    def compiled_program(self, sched: CollectiveSchedule, nranks: int):
        """The cached :class:`RoundProgram` of a (schedule, nranks) pair
        (see :meth:`_schedule_cache_key`; stateful schedules without a
        ``program_key`` compile fresh each call)."""
        cache = getattr(self, "_program_cache", None)
        if cache is None:
            cache = self._program_cache = {}
        key = self._schedule_cache_key(sched, nranks)
        prog = None if key is None else cache.get(key)
        if prog is None:
            prog = compile_program(self.net, sched, self._cores(nranks),
                                   nranks)
            if key is not None:
                cache[key] = prog
        return prog

    def run_schedule_many(self, sched: CollectiveSchedule, sizes,
                          nranks: int, *, t0=None,
                          engine=None) -> BatchScheduleResult:
        """Replay one compiled program over a whole message-size grid in a
        single batched run — the sweep workload (algorithm x size x scale,
        Figs. 14-19) that makes the compiled backend >=10x faster than
        interpreting each size.  Raises :class:`ProgramStructureError` if
        the schedule's round structure varies with size (no shipped
        schedule does).

        ``t0`` — optional (nranks, len(sizes)) per-rank entry clocks, one
        column per binding: repeating one size across columns turns the
        batch axis into a Monte-Carlo *arrival-offset* scenario axis (the
        compiled twin of ``run_schedule(t0=...)``, still from fresh
        occupancy).  ``engine`` selects the scan backend (``"numpy"``
        default | ``"jax"``; DESIGN.md §2.5)."""
        if self.net.engine.tracing:
            raise ValueError("compiled backend records no per-send trace; "
                             "use backend='interp' (or trace=False)")
        prog = self.compiled_program(sched, nranks)
        return prog.run(sched, sizes, t0=t0, engine=engine)

    def run_schedule_population(self, population, nranks: int, *,
                                engine=None) -> BatchScheduleResult:
        """Cost every member of a
        :class:`~repro.core.exanet.schedule_algebra.SchedulePopulation`
        as one batched compiled replay — the synthesis-search fitness
        call (one column per candidate; DESIGN.md §2.8).

        The lowered program is cached by the population's *skeleton*
        (``program_key``), so successive generations of a search reuse
        one compilation; binding always bypasses the byte caches
        (``cache_bind=False``) because the member behind each batch
        token changes between generations."""
        if self.net.engine.tracing:
            raise ValueError("compiled backend records no per-send trace; "
                             "use backend='interp' (or trace=False)")
        prog = self.compiled_program(population, nranks)
        return prog.run(population, population.tokens(), engine=engine,
                        cache_bind=False)

    # ------------------------------------------------------ program execution
    #: ``run_program(backend="auto")`` compiles at and above this rank
    #: count: per-iteration replay of a lowered Program beats the
    #: interpreted heap scheduler once thousands of matches contend
    #: (below it, array dispatch overhead wins; the apps sweep records
    #: the crossover empirically in BENCH_apps.json)
    PROGRAM_COMPILED_AUTO_MIN_RANKS = 256

    def _resolve_collective_schedule(self, op: str, nbytes: int, algo: str,
                                     plans: dict) -> str:
        """The executor key an embedded ``Collective`` resolves to — one
        place, so the interpreter hook and the compiled splice
        (:mod:`repro.core.exanet.program_compiled`) can never drift."""
        algos = COLLECTIVE_SCHEDULES.get(op)
        if algos is None:
            raise ValueError(f"unknown collective op {op!r}; options: "
                             f"{sorted(COLLECTIVE_SCHEDULES)}")
        name = algo
        if algo == "auto":
            plan = plans.get((op, int(nbytes)))
            # non-allreduce ops have a single shipped schedule each
            name = plan.schedule if plan is not None else next(iter(algos))
        if name != "accel" and name not in algos:
            if name.startswith("synth:"):
                from repro.core.synth.search import registered
                if registered(name) is not None:
                    return name
            raise ValueError(f"unknown {op} algo {name!r}; options: "
                             f"{sorted(algos) + ['auto']}")
        return name

    def _schedule_instance(self, op: str, name: str) -> CollectiveSchedule:
        """Schedule object behind a resolved algorithm name: a menu class
        instantiation, or the synthesized-schedule registry for
        ``synth:<digest>`` names the planner's winner cache emits."""
        if name.startswith("synth:"):
            from repro.core.synth.search import registered
            sched = registered(name)
            if sched is None:
                raise ValueError(
                    f"synthesized schedule {name!r} is not registered "
                    "(load its winner cache first)")
            return sched
        return COLLECTIVE_SCHEDULES[op][name]()

    def _program_hooks(self, nranks: int, plans: dict,
                       recorder=None) -> dict:
        """The event-engine cost hooks of :class:`ProgramExecutor` —
        shared by the interpreted backend and the compiled backend's
        recording probe (``recorder`` logs the scheduler's match/barrier
        firing order without touching the semantics)."""
        net = self.net
        cores = self._cores(nranks)
        core_res = [net.engine.resource(sim.CORE, c) for c in cores]

        def compute(rank: int, us: float, t: float) -> float:
            return core_res[rank].acquire(t, us) + us

        def p2p(src: int, dst: int, nbytes: int, tag: int,
                t_send: float, t_recv: float) -> tuple[float, float]:
            if recorder is not None:
                recorder.p2p(src, dst, tag)
            res = net.isend(cores[src], cores[dst], nbytes, t_send, t_recv)
            return res.t_send_done, res.t_recv_done

        def collective(op: str, nbytes: int, algo: str,
                       enters: list[float]) -> list[float]:
            n = len(enters)
            if n < 2:
                if recorder is not None:
                    recorder.coll(None)
                return list(enters)
            name = self._resolve_collective_schedule(op, nbytes, algo,
                                                     plans)
            if recorder is not None:
                recorder.coll(name)
            if name == "accel":
                from repro.core.exanet.allreduce_accel import accel_cost_us
                t = max(enters) + accel_cost_us(nbytes, n, self.p)
                return [t] * n
            res = self.run_schedule(self._schedule_instance(op, name),
                                    nbytes, n, backend="interp",
                                    t0=list(enters), reset=False)
            shift = res.latency_us - max(res.clocks)
            return [c + shift for c in res.clocks]

        return {"compute": compute, "p2p": p2p, "collective": collective}

    def _plan_program_sites(self, prog, plans: dict | None) -> dict:
        if plans is None and prog.nranks >= 2 and any(
                c.algo == "auto" and c.op == "allreduce"
                for c in prog.collectives()):
            plans = self.planner.plan_program(prog)
        return plans or {}

    def _program_splices_profitable(self, prog, plans: dict) -> bool:
        """Would every embedded collective site's compiled splice beat
        interpreting it?  Serial-chain schedules (the ring's ``r -> r+1``
        DMA coupling) degenerate to one send per level, where replaying
        thousands of one-send array steps is an order of magnitude
        *slower* than the interpreter — the same
        :meth:`compiled_profitable` gate ``run_schedule``'s auto backend
        applies, lifted to whole programs so ``run_program(backend=
        "auto")`` can never pick a losing executor."""
        if prog.nranks < 2:
            return True
        for c in prog.collectives():
            name = self._resolve_collective_schedule(c.op, c.nbytes,
                                                     c.algo, plans)
            if name == "accel":
                continue
            if not self.compiled_profitable(
                    self._schedule_instance(c.op, name), prog.nranks):
                return False
        return True

    def _program_auto_compiles(self, prog, plans: dict) -> bool:
        """The consolidated ``backend="auto"`` gate of
        :meth:`run_program` / :meth:`run_program_many`: compiled only
        when (a) tracing is off (the compiled path records no trace),
        (b) the program is at or above the rank floor
        (:data:`PROGRAM_COMPILED_AUTO_MIN_RANKS` — BENCH_apps records
        forced-compiled at 0.87x the interpreter for nranks=2 hpcg/weak,
        so below the floor auto must interpret), and (c) every embedded
        collective splice clears the sends-per-level parallelism floor
        (:meth:`_program_splices_profitable`).  One method, so the two
        entry points can never gate differently."""
        return (not self.net.engine.tracing
                and prog.nranks >= self.PROGRAM_COMPILED_AUTO_MIN_RANKS
                and self._program_splices_profitable(prog, plans))

    def program_artifact(self, prog):
        """The cached compiled artifact of a Program *structure*
        (:meth:`repro.core.program.Program.structure_key`): payload data
        — byte sizes, compute microseconds — binds per column, so two
        differently-parameterized emissions of one builder (a weak/strong
        sweep at fixed rank count, every iteration of an app) share one
        lowering.  Structure mismatches at bind raise
        :class:`ProgramStructureError` — content-keyed caching is what
        makes builders that close over mutable state safe."""
        cache = getattr(self, "_app_program_cache", None)
        if cache is None:
            cache = self._app_program_cache = {}
        key = prog.structure_key()
        art = cache.get(key)
        if art is None:
            from repro.core.exanet.program_compiled import compile_program_ir
            art = cache[key] = compile_program_ir(self, prog)
        return art

    def run_program(self, prog, *, plans: dict | None = None,
                    backend: str = "auto", engine=None, t0=None):
        """Execute a :class:`repro.core.program.Program` on the event engine.

        Every rank's ops run concurrently: ``Compute`` occupies the rank's
        A53 core, nonblocking sends go through :meth:`Network.isend` (so
        simultaneous flows from *all* ranks contend on the shared
        R5/DMA/link resources — full-machine halo congestion is emergent,
        not modeled), and embedded ``Collective`` ops replay their
        schedule with the ranks' skewed entry clocks and the engine's
        live occupancy.

        ``backend`` selects the executor: ``"interp"`` (the
        :class:`ProgramExecutor` heap scheduler over per-send engine
        calls — the reference semantics), ``"compiled"`` (the program
        lowered to vectorized level programs by
        :mod:`repro.core.exanet.program_compiled`, equal to ~1e-9; embedded
        collectives splice their compiled
        :class:`~repro.core.exanet.exec_compiled.RoundProgram`\\ s), or
        ``"auto"`` (compiled at paper scale —
        :data:`PROGRAM_COMPILED_AUTO_MIN_RANKS` — when tracing is off,
        interpreted otherwise).

        ``Collective(algo="auto")`` sites are planned in one pass by the
        :class:`~repro.core.planner.CollectivePlanner` *before* execution
        starts (planning simulates candidate schedules on this same
        engine, which resets occupancy); ``plans`` can inject the mapping
        ``{(op, nbytes): Plan}`` directly, e.g. from
        :meth:`CollectivePlanner.plan_program`.

        Returns the executor's :class:`~repro.core.program.ProgramResult`
        (per-rank completion clocks, total compute, send/collective
        counts).

        ``engine`` selects the compiled path's scan backend (``"numpy"``
        default | ``"jax"``; DESIGN.md §2.5) and is ignored by the
        interpreter.

        ``t0`` skews per-rank start clocks: a scalar or an (nranks,)
        sequence of entry times in microseconds (request-arrival /
        dispatch jitter for serving Programs).  Both backends honor it;
        exactness of the compiled path under skew follows the same
        payload-invariant-firing-order contract as
        :meth:`run_program_scenarios` documents.
        """
        if backend not in ("auto", "interp", "compiled"):
            raise ValueError(f"unknown backend {backend!r}; "
                             f"options: ['auto', 'compiled', 'interp']")
        from repro.core.program import ProgramExecutor
        nranks = prog.nranks
        if t0 is not None:
            t0 = np.asarray(t0, dtype=np.float64)
            if t0.ndim == 0:
                t0 = np.full(nranks, float(t0))
            elif t0.shape != (nranks,):
                raise ValueError(f"t0 must be scalar or (nranks,); got "
                                 f"shape {t0.shape} for nranks={nranks}")
        default_plans = plans is None
        tracing = self.net.engine.tracing
        if backend == "compiled" and tracing:
            raise ValueError("compiled backend records no per-send trace; "
                             "use backend='interp' (or trace=False)")
        if backend == "compiled" or (
                backend == "auto" and not tracing
                and nranks >= self.PROGRAM_COMPILED_AUTO_MIN_RANKS):
            try:
                # memoized per program *identity*: iterating an app
                # replays the same (artifact, binding) without re-walking
                # the IR for plans, structure key or payload extraction.
                # Keyed by id() — hashing a frozen Program would deep-hash
                # every op tuple on every call — with a weakref guard so a
                # recycled id can never alias a dead program.
                import weakref
                memo = getattr(self, "_prog_run_memo", None)
                if memo is None:
                    memo = self._prog_run_memo = {}
                ent = memo.get(id(prog)) if default_plans else None
                if ent is None or ent[0]() is not prog:
                    plans = self._plan_program_sites(prog, plans)
                    if backend == "auto" and \
                            not self._program_auto_compiles(prog, plans):
                        raise ProgramStructureError(
                            "auto gate: compiled would lose here")
                    art = self.program_artifact(prog)
                    ent = (weakref.ref(
                        prog, lambda _, k=id(prog): memo.pop(k, None)),
                        art, art.bind((prog,), (plans,)))
                    if default_plans:
                        memo[id(prog)] = ent
                return ent[1].run(ent[2], engine=engine, t0=t0)[0]
            except ProgramStructureError:
                if backend == "compiled":
                    raise
        # `plans` is already the resolved dict when the compiled branch
        # fell back after planning — _plan_program_sites passes it through
        plans = self._plan_program_sites(prog, plans)
        hooks = self._program_hooks(nranks, plans)
        self.net.reset()
        return ProgramExecutor(
            prog, **hooks,
            post_overhead_us=self.p.a53_call_overhead_us).run(
                t0=0.0 if t0 is None else t0)

    def run_program_many(self, progs, *, plans=None,
                         backend: str = "auto", engine=None) -> list:
        """Execute many Programs, batching structurally-identical ones
        through one compiled artifact (columns of a single vectorized
        replay, grouped by probe tape via
        :meth:`CompiledProgram.bind_batch`) — the weak/strong sweep
        workload.  ``plans`` is an optional per-program list.  Results
        keep input order; programs below the auto threshold (or whose
        batch the compiler rejects) fall back per program.  ``engine``
        selects the compiled path's scan backend."""
        if backend not in ("auto", "interp", "compiled"):
            raise ValueError(f"unknown backend {backend!r}; "
                             f"options: ['auto', 'compiled', 'interp']")
        progs = list(progs)
        tracing = self.net.engine.tracing
        if backend == "compiled" and tracing:
            # validate before planning: the planner simulates candidates
            # on this engine (resetting occupancy, polluting the trace)
            raise ValueError("compiled backend records no per-send trace; "
                             "use backend='interp' (or trace=False)")
        if plans is None:
            plans_list = [None] * len(progs)
        else:
            plans_list = list(plans)
            if len(plans_list) != len(progs) or not all(
                    pl is None or isinstance(pl, dict)
                    for pl in plans_list):
                raise ValueError(
                    "plans must be a per-program sequence of plan dicts "
                    f"(or None) matching len(progs)={len(progs)}")
        resolved = [self._plan_program_sites(p, pl)
                    for p, pl in zip(progs, plans_list)]
        out: list = [None] * len(progs)
        groups: dict[tuple, list[int]] = {}
        for i, p in enumerate(progs):
            if backend == "interp" or (backend == "auto" and
                    not self._program_auto_compiles(p, resolved[i])):
                out[i] = self.run_program(p, plans=resolved[i],
                                          backend="interp")
            else:
                groups.setdefault(p.structure_key(), []).append(i)
        for idxs in groups.values():
            try:
                art = self.program_artifact(progs[idxs[0]])
                for cols, bound in art.bind_batch(
                        [progs[i] for i in idxs],
                        [resolved[i] for i in idxs]):
                    for j, r in zip(cols, art.run(bound, engine=engine)):
                        out[idxs[int(j)]] = r
            except ProgramStructureError:
                if backend == "compiled":
                    raise
                for i in idxs:  # retry singly (compiled, then interp)
                    out[i] = self.run_program(progs[i], plans=resolved[i],
                                              backend="auto",
                                              engine=engine)
        return out

    def _norm_link_axis(self, ax, name: str):
        """Normalize a per-link scenario axis to {undirected key: (N,)}.
        Accepts an (N,) array (applies to *every* physical link) or a
        mapping ``{(kind, a, b): (N,)}`` (directed tuples normalized)."""
        from repro.core.exanet import faults as _faults
        if ax is None:
            return None
        if hasattr(ax, "items"):
            out = {}
            for k, v in ax.items():
                v = np.asarray(v, dtype=np.float64)
                if v.ndim != 1:
                    raise ValueError(f"{name}[{k}] must be (N,); got "
                                     f"shape {v.shape}")
                out[_faults.link_key(*k)] = v
            return out or None
        arr = np.asarray(ax, dtype=np.float64)
        if arr.ndim != 1:
            raise ValueError(f"{name} must be (N,) or a per-link mapping; "
                             f"got shape {arr.shape}")
        return {k: arr for k in _faults.all_link_keys(self.topo)}

    def _link_degrade(self, slow_map, extra_map, N):
        """Build the :class:`LinkDegrade` run-time axis: (n_resource_rows,
        N) slowdown/extra-latency arrays indexed by the engine's directed
        LINK resource ids (an undirected fault key hits both directions).
        Must run *after* the artifact compiles so every routed link has
        its id registered."""
        from repro.core.exanet.exec_compiled import LinkDegrade
        from repro.core.exanet import faults as _faults
        R = self.net.engine.n_resource_ids
        slow = np.ones((R, N))
        extra = np.zeros((R, N))
        for ident, rid in self.net.engine.resource_ids_of(sim.LINK).items():
            key = _faults.link_key(*ident)
            if slow_map and key in slow_map:
                slow[rid] = slow_map[key]
            if extra_map and key in extra_map:
                extra[rid] = extra_map[key]
        return LinkDegrade(slow, extra, self.p)

    def _column_fault_spec(self, slow_map, extra_map, b: int):
        """The static FaultSpec equivalent of scenario column ``b`` of the
        link axes, merged over this machine's own faults — the
        interpreter-twin reference lane of the batched degradation."""
        from repro.core.exanet import faults as _faults
        base = self.topo.faults or _faults.HEALTHY
        slow = {k: base.link_slow(*k) for k in base.degraded_link_keys()}
        extra = {k: base.link_extra_us(*k)
                 for k in base.degraded_link_keys()}
        for k, v in (slow_map or {}).items():
            slow[k] = slow.get(k, 1.0) * float(v[b])
        for k, v in (extra_map or {}).items():
            extra[k] = extra.get(k, 0.0) + float(v[b])
        return _faults.FaultSpec(
            dead_links=base.dead_links, dead_mpsocs=base.dead_mpsocs,
            slow_links={k: f for k, f in slow.items() if f != 1.0},
            link_extra_latency_us={k: e for k, e in extra.items() if e},
            slow_ranks=base.slow_ranks)

    def run_program_scenarios(self, prog, *, compute_scale=None,
                              byte_scale=None, site_scale=None,
                              link_scale=None, link_latency_us=None,
                              t0=None, plans: dict | None = None,
                              engine=None, check: int = 0,
                              rtol: float = 1e-9) -> list:
        """Monte-Carlo scenario sweep of one Program as a single batched
        replay: N payload perturbations of ``prog`` bind as columns of
        its compiled artifact (:meth:`CompiledProgram.bind_arrays` — no
        N Program objects, no N probes) and execute in one pass.

        ``compute_scale`` — (N,) per-scenario, (nranks, N) per-rank, or
        (n_computes, N) per-compute-slot (slots in static-walk order;
        when ``nranks == n_computes`` the per-rank reading wins)
        multiplicative compute skew; ``byte_scale`` — (N,) per-scenario
        or (n_posts, N) per-post multiplier on point-to-point payloads
        (rounded to whole bytes); ``site_scale`` — (N,) per-scenario or
        (n_sites, N) per-collective-site multiplier on embedded
        collective payloads (rounded; every scaled size must resolve to
        the *same* schedule as the base site — single-schedule ops and
        explicit ``algo=`` are always safe, ``algo="auto"`` allreduce
        sites may cross a planner decision boundary and are rejected by
        ``bind_arrays``); ``t0`` — (nranks, N) per-rank per-scenario
        entry clocks in microseconds (the request-arrival-skew axis for
        serving Programs).  ``check`` > 0 cross-checks that many
        evenly-sampled columns against the interpreter
        (:func:`rebind_program` hands it the perturbed column, with the
        column's ``t0``) and raises if any latency disagrees beyond
        ``rtol`` relative — the guard for builders whose scheduling
        order is *not* payload-invariant.

        ``link_scale`` / ``link_latency_us`` are the degradation axes
        (DESIGN.md §2.10): an (N,) array applies to every physical link,
        a ``{(kind, a, b): (N,)}`` mapping degrades chosen links
        (undirected — both directions are hit).  ``link_scale`` divides
        per-link serialization rate and sustained wire bandwidth
        (factors >= 1; a §4.5.3 lossy link with block-loss probability
        ``p`` is the factor ``1/(1-p)``); ``link_latency_us`` adds
        per-link one-way latency.  N sampled fault sets x load points
        cost one replay; checked columns run against a statically
        degraded interpreter twin (:class:`FaultSpec` merged over this
        machine's own faults).

        Returns N :class:`~repro.core.program.ProgramResult`\\ s.
        """
        from repro.core.exanet.program_compiled import (extract_data,
                                                        rebind_program)
        base = extract_data(prog)
        slow_map = self._norm_link_axis(link_scale, "link_scale")
        extra_map = self._norm_link_axis(link_latency_us, "link_latency_us")
        if slow_map:
            for k, v in slow_map.items():
                if (v < 1.0).any():
                    raise ValueError(
                        f"link_scale[{k}] has factors < 1 (a speedup); "
                        "degradation factors must be >= 1")
        N = None
        for nm, a in (("compute_scale", compute_scale),
                      ("byte_scale", byte_scale),
                      ("site_scale", site_scale), ("t0", t0),
                      ("link_scale", slow_map),
                      ("link_latency_us", extra_map)):
            if a is not None:
                if isinstance(a, dict):
                    n = len(next(iter(a.values())))
                    bad = {k: len(v) for k, v in a.items() if len(v) != n}
                    if bad:
                        raise ValueError(f"{nm} values disagree on N: "
                                         f"{bad} vs {n}")
                else:
                    n = np.asarray(a).shape[-1]
                if N is None:
                    N = n
                elif n != N:
                    raise ValueError(f"{nm} disagrees on N ({n} vs {N})")
        if N is None:
            raise ValueError(
                "give at least one of compute_scale / byte_scale / "
                "site_scale / link_scale / link_latency_us / t0")
        comp_cols = post_cols = site_cols = t0_cols = None
        base_comp = np.array(base[0], dtype=np.float64)
        base_post = np.array(base[1], dtype=np.float64)
        base_site = np.array(base[2], dtype=np.float64)
        if compute_scale is not None:
            cs = np.asarray(compute_scale, dtype=np.float64)
            if cs.ndim == 1:
                comp_cols = base_comp[:, None] * cs[None, :]
            elif cs.shape[0] == prog.nranks:
                art0 = self.program_artifact(prog)
                comp_cols = base_comp[:, None] * \
                    cs[art0._static.compute_rank]
            elif cs.shape[0] == len(base_comp):
                # per-compute-slot skew: the train co-sim's bucket-layout
                # axis (candidates move backward compute between buckets,
                # not between ranks)
                comp_cols = base_comp[:, None] * cs
            else:
                raise ValueError(
                    f"compute_scale must be (N,), (nranks, N) or "
                    f"(n_computes, N); got {cs.shape} for "
                    f"nranks={prog.nranks}, n_computes={len(base_comp)}")
        if byte_scale is not None:
            bs = np.asarray(byte_scale, dtype=np.float64)
            if bs.ndim == 1:
                post_cols = np.rint(base_post[:, None] * bs[None, :])
            else:
                if bs.shape[0] != len(base_post):
                    raise ValueError(
                        f"byte_scale must be (N,) or (n_posts, N); got "
                        f"{bs.shape} for n_posts={len(base_post)}")
                post_cols = np.rint(base_post[:, None] * bs)
        if site_scale is not None:
            ss = np.asarray(site_scale, dtype=np.float64)
            if ss.ndim == 1:
                site_cols = np.rint(base_site[:, None] * ss[None, :]
                                    ).astype(np.int64)
            else:
                if ss.shape[0] != len(base_site):
                    raise ValueError(
                        f"site_scale must be (N,) or (n_sites, N); got "
                        f"{ss.shape} for n_sites={len(base_site)}")
                site_cols = np.rint(base_site[:, None] * ss
                                    ).astype(np.int64)
        if t0 is not None:
            t0_cols = np.asarray(t0, dtype=np.float64)
            if t0_cols.shape != (prog.nranks, N):
                raise ValueError(
                    f"t0 must be (nranks, N); got {t0_cols.shape} for "
                    f"nranks={prog.nranks}, N={N}")
        if (comp_cols is None and post_cols is None and site_cols is None
                and (t0_cols is not None or slow_map or extra_map)):
            # t0-/link-only sweep: bind_arrays infers N from payload
            # arrays, so hold one of them constant across the N columns
            if len(base_comp):
                comp_cols = np.broadcast_to(
                    base_comp[:, None], (len(base_comp), N))
            elif len(base_post):
                post_cols = np.broadcast_to(
                    base_post[:, None], (len(base_post), N))
            else:
                site_cols = np.broadcast_to(
                    np.array(base[2], dtype=np.int64)[:, None],
                    (len(base_site), N))
        plans = self._plan_program_sites(prog, plans)
        art = self.program_artifact(prog)
        bound = art.bind_arrays(prog, compute_us=comp_cols,
                                post_nbytes=post_cols,
                                site_nbytes=site_cols, plans=plans)
        # build the degradation AFTER binding: the bind's probe is what
        # allocates the engine's LINK resource ids on a cold artifact
        deg = self._link_degrade(slow_map, extra_map, N) \
            if (slow_map or extra_map) else None
        results = art.run(bound, engine=engine, t0=t0_cols, deg=deg)
        if check > 0:
            cols = np.unique(np.linspace(0, N - 1, min(int(check), N))
                             .astype(np.int64))
            twins: dict = {}
            for b in cols:
                ref_mpi = self
                if deg is not None:
                    # link degradation cannot be rebound into a Program:
                    # the reference lane is a statically degraded machine
                    spec = self._column_fault_spec(slow_map, extra_map,
                                                   int(b))
                    ref_mpi = twins.get(spec)
                    if ref_mpi is None:
                        ref_mpi = twins[spec] = ExanetMPI(
                            self.p, ranks_per_mpsoc=self._rpm, faults=spec)
                pb = rebind_program(
                    prog,
                    compute_us=None if comp_cols is None
                    else comp_cols[:, b],
                    post_nbytes=None if post_cols is None
                    else post_cols[:, b],
                    site_nbytes=None if site_cols is None
                    else site_cols[:, b])
                ref = ref_mpi.run_program(pb, plans=plans, backend="interp",
                                          t0=None if t0_cols is None
                                          else t0_cols[:, b])
                err = abs(results[b].latency_us - ref.latency_us) / \
                    max(abs(ref.latency_us), 1e-30)
                if err > rtol:
                    raise ProgramStructureError(
                        f"scenario column {int(b)} disagrees with the "
                        f"interpreter ({err:.2e} rel > {rtol:.0e}) — the "
                        f"scheduling order is payload-dependent; run "
                        f"these scenarios via run_program_many instead")
        return results

    def _step_class(self, src: int, dst: int) -> str:
        d = abs(dst - src) * (self.p.cores_per_mpsoc if self._rpm == 1 else 1)
        cpq = self.p.cores_per_mpsoc * self.p.fpgas_per_qfdb
        if d >= cpq:
            return "mezzanine"
        if d >= self.p.cores_per_mpsoc:
            return "qfdb"
        return "mpsoc"

    # ------------------------------------------------------------- broadcast
    def bcast(self, size: int, nranks: int) -> BcastResult:
        """Event-simulated binomial broadcast vs the Eq. 1 expectation."""
        sched = BinomialBroadcast()
        res = self.run_schedule(sched, size, nranks)
        counts = {"mpsoc": 0, "qfdb": 0, "mezzanine": 0}
        for (s, d) in res.round_heads:
            counts[self._step_class(s, d)] += 1
        expected = self.bcast_expected(size, counts)
        return BcastResult(res.latency_us, expected, counts)

    def bcast_expected(self, size: int, counts: dict[str, int]) -> float:
        """Eq. 1: L_exp = Ns_MPSoC*L_MPSoC + Ns_QFDB*L_QFDB + Ns_mezz*L_mezz,
        with one-way latencies from osu_one_way_lat over representative
        single-hop paths (§6.1.4)."""
        c = self.p.cores_per_mpsoc
        l_mpsoc = self.osu_one_way_core(size, 0, 1)
        l_qfdb = self.osu_one_way_core(size, 0, c)
        l_mezz = self.osu_one_way_core(size, 0, c * self.p.fpgas_per_qfdb)
        return (counts["mpsoc"] * l_mpsoc + counts["qfdb"] * l_qfdb
                + counts["mezzanine"] * l_mezz)

    def osu_one_way_core(self, size: int, c0: int, c1: int) -> float:
        path = self.topo.route(c0, c1)
        return self.net.mpi_latency(size, path, one_way=True)

    # ------------------------------------------------------------- planner
    @property
    def planner(self):
        """Cost-driven schedule selection over *this* instance (its rank
        placement and calibrated params), at full event-simulation fidelity.
        Built lazily: the planner layer is optional for plain wrapper use."""
        planner = getattr(self, "_planner", None)
        if planner is None:
            from repro.core.machine import ExanetMachine
            from repro.core.planner import CollectivePlanner
            planner = self._planner = CollectivePlanner(
                ExanetMachine(mpi=self), fidelity="sim")
        return planner

    # ------------------------------------------------------------- allreduce
    def allreduce(self, size: int, nranks: int,
                  algo: str = "recursive_doubling") -> float:
        """Event-simulated software allreduce with a pluggable schedule
        (``recursive_doubling`` | ``ring`` | ``rabenseifner`` |
        ``oneshot``), or ``algo="auto"``: the planner picks the cheapest
        schedule — including the §4.7 accelerator where applicable — by
        simulated cost, reproducing the paper's Fig. 19 sw/accel crossover
        from cost alone instead of a hand-coded threshold."""
        if algo == "auto":
            plan = self.planner.plan("allreduce", size, (nranks,))
            if plan.schedule == "accel":
                # ungated cost path: the planner (not the historical 4 KB
                # fallback) decided the accelerator is profitable here
                from repro.core.exanet.allreduce_accel import accel_cost_us
                return accel_cost_us(size, nranks, self.p)
            algo = plan.schedule
        if algo.startswith("synth:"):
            sched = self._schedule_instance("allreduce", algo)
        else:
            sched_cls = ALLREDUCE_SCHEDULES.get(algo)
            if sched_cls is None:
                raise ValueError(
                    f"unknown allreduce algo {algo!r}; options: "
                    f"{sorted(ALLREDUCE_SCHEDULES) + ['auto']}")
            sched = sched_cls()
        return self.run_schedule(sched, size, nranks).latency_us

    def allreduce_sw(self, size: int, nranks: int) -> float:
        """Recursive-doubling software allreduce (§6.1.3): per step an
        MPI_Sendrecv (full exchange) + MPI_Reduce_local; one memcpy in, one
        memcpy out. Event-simulated with R5/DMA contention."""
        return self.allreduce(size, nranks, "recursive_doubling")

    def allreduce_hw(self, size: int, nranks: int) -> float:
        from repro.core.exanet.allreduce_accel import accel_allreduce_latency
        return accel_allreduce_latency(size, nranks, self.p)

    # ------------------------------------------- schedule-split collectives
    def allgather(self, size: int, nranks: int) -> float:
        """All-gather ``size`` bytes per rank (recursive doubling)."""
        return self.run_schedule(AllGather(), size, nranks).latency_us

    def alltoall(self, size: int, nranks: int) -> float:
        """Pairwise-exchange all-to-all of ``size`` bytes per pair."""
        return self.run_schedule(AllToAll(), size, nranks).latency_us

    def barrier(self, nranks: int) -> float:
        """Dissemination barrier (empty eager messages)."""
        return self.run_schedule(Barrier(), 0, nranks).latency_us

    def scatter(self, size: int, nranks: int) -> float:
        """Binomial scatter of ``size`` bytes per rank from rank 0."""
        return self.run_schedule(ScatterBinomial(), size, nranks).latency_us

    def gather(self, size: int, nranks: int) -> float:
        """Binomial gather of ``size`` bytes per rank to rank 0."""
        return self.run_schedule(GatherBinomial(), size, nranks).latency_us
