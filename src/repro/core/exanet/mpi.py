"""ExaNet-MPI runtime model (§5.2.1) + OSU-style microbenchmarks (§6.1).

Point-to-point: eager (<=32 B) via packetizer/mailbox; rendez-vous otherwise
(RTS -> CTS -> RDMA write + concurrent completion notification).

Collectives use the MPICH 3.2.1 algorithms the paper used (§5.2.1):
binomial tree for broadcast, recursive doubling for allreduce.

Rank placement is block-packed (4 ranks/MPSoC fills cores first), matching
the §6.1.4 schedule decomposition: binomial step distance >=16 crosses a
QFDB ("mezzanine-class" step), >=4 crosses an MPSoC ("QFDB-class" step),
otherwise it is an intra-MPSoC step.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.exanet.network import Network
from repro.core.exanet.params import DEFAULT, HwParams
from repro.core.exanet.topology import Topology


@dataclasses.dataclass
class BcastResult:
    observed_us: float
    expected_us: float      # Eq. 1 analytic model
    steps: dict[str, int]   # Ns_MPSoC / Ns_QFDB / Ns_mezzanine

    @property
    def deviation(self) -> float:
        """(observed - expected)/observed, the paper's §6.1.4 metric."""
        return (self.observed_us - self.expected_us) / self.observed_us


class ExanetMPI:
    def __init__(self, params: HwParams = DEFAULT, *,
                 ranks_per_mpsoc: int | None = None):
        self.p = params
        self.topo = Topology(params)
        self.net = Network(self.topo, params)
        self._rpm = ranks_per_mpsoc

    # --------------------------------------------------------- rank placement
    def rank_core(self, rank: int) -> int:
        """Block placement. With ranks_per_mpsoc=1 (accelerator comparisons,
        §6.1.5) each rank occupies core 0 of its own MPSoC."""
        if self._rpm == 1:
            return rank * self.p.cores_per_mpsoc
        return rank

    # ------------------------------------------------------- microbenchmarks
    def osu_latency(self, size: int, r0: int = 0, r1: int | None = None) -> float:
        """Half ping-pong latency (osu_latency)."""
        if r1 is None:
            r1 = self.p.cores_per_mpsoc  # intra-QFDB neighbour by default
        path = self.topo.route(self.rank_core(r0), self.rank_core(r1))
        return self.net.mpi_latency(size, path)

    def osu_one_way(self, size: int, r0: int, r1: int) -> float:
        path = self.topo.route(self.rank_core(r0), self.rank_core(r1))
        return self.net.mpi_latency(size, path, one_way=True)

    def osu_bw(self, size: int, r0: int = 0, r1: int | None = None) -> float:
        if r1 is None:
            r1 = self.p.cores_per_mpsoc
        path = self.topo.route(self.rank_core(r0), self.rank_core(r1))
        return self.net.osu_bw_gbps(size, path)

    def osu_bibw(self, size: int, r0: int = 0, r1: int | None = None) -> float:
        if r1 is None:
            r1 = self.p.cores_per_mpsoc
        path = self.topo.route(self.rank_core(r0), self.rank_core(r1))
        return self.net.osu_bibw_gbps(size, path)

    # ------------------------------------------------------------- broadcast
    def _binomial_schedule(self, n: int) -> list[list[tuple[int, int]]]:
        """Binomial-tree (MPICH) broadcast schedule: list of steps, each a
        list of (src_rank, dst_rank) pairs. Step distances N/2, N/4, ..., 1."""
        steps = []
        d = n // 2
        while d >= 1:
            pairs = [(r, r + d) for r in range(0, n, 2 * d) if r + d < n]
            steps.append(pairs)
            d //= 2
        return steps

    def _step_class(self, pairs: list[tuple[int, int]]) -> str:
        src, dst = pairs[0]
        d = abs(dst - src) * (self.p.cores_per_mpsoc if self._rpm == 1 else 1)
        cpq = self.p.cores_per_mpsoc * self.p.fpgas_per_qfdb
        if d >= cpq:
            return "mezzanine"
        if d >= self.p.cores_per_mpsoc:
            return "qfdb"
        return "mpsoc"

    def bcast(self, size: int, nranks: int) -> BcastResult:
        """Event-simulated binomial broadcast vs the Eq. 1 expectation."""
        assert nranks & (nranks - 1) == 0, "power-of-two ranks as in §6.1.4"
        self.net.reset()
        clocks = [0.0] * nranks
        schedule = self._binomial_schedule(nranks)
        counts = {"mpsoc": 0, "qfdb": 0, "mezzanine": 0}
        for pairs in schedule:
            counts[self._step_class(pairs)] += 1
            for (s, d) in pairs:
                res = self.net.send(self.rank_core(s), self.rank_core(d), size,
                                    clocks[s], one_way=True)
                clocks[d] = max(clocks[d], res.t_complete)
                clocks[s] = res.t_sender_free
            # deterministic stand-in for per-step late-arrival noise (§6.1.4)
            clocks = [c + self.p.step_sync_us for c in clocks]
        observed = max(clocks) + self.p.barrier_exit_us
        expected = self.bcast_expected(size, counts)
        return BcastResult(observed, expected, dict(counts))

    def bcast_expected(self, size: int, counts: dict[str, int]) -> float:
        """Eq. 1: L_exp = Ns_MPSoC*L_MPSoC + Ns_QFDB*L_QFDB + Ns_mezz*L_mezz,
        with one-way latencies from osu_one_way_lat over representative
        single-hop paths (§6.1.4)."""
        c = self.p.cores_per_mpsoc
        l_mpsoc = self.osu_one_way_core(size, 0, 1)
        l_qfdb = self.osu_one_way_core(size, 0, c)
        l_mezz = self.osu_one_way_core(size, 0, c * self.p.fpgas_per_qfdb)
        return (counts["mpsoc"] * l_mpsoc + counts["qfdb"] * l_qfdb
                + counts["mezzanine"] * l_mezz)

    def osu_one_way_core(self, size: int, c0: int, c1: int) -> float:
        path = self.topo.route(c0, c1)
        return self.net.mpi_latency(size, path, one_way=True)

    # ------------------------------------------------------------- allreduce
    def allreduce_sw(self, size: int, nranks: int) -> float:
        """Recursive-doubling software allreduce (§6.1.3): per step an
        MPI_Sendrecv (full exchange) + MPI_Reduce_local; one memcpy in, one
        memcpy out. Event-simulated with R5/DMA contention."""
        assert nranks & (nranks - 1) == 0
        self.net.reset()
        p = self.p
        t_cpy = size / p.a53_copy_bw_bytes_per_us + p.a53_call_overhead_us
        t_red = 3.0 * size / p.a53_copy_bw_bytes_per_us + p.a53_call_overhead_us
        rdv = size > p.mpi_eager_max_bytes
        penalty = p.sendrecv_sw_rdv_us if rdv else p.sendrecv_sw_eager_us
        clocks = [t_cpy] * nranks
        for i in range(int(math.log2(nranks))):
            d = 1 << i
            arrivals = [0.0] * nranks
            done = [0.0] * nranks
            for r in range(nranks):
                partner = r ^ d
                res = self.net.send(self.rank_core(r), self.rank_core(partner),
                                    size, clocks[r])
                arrivals[partner] = max(arrivals[partner], res.t_complete)
                done[r] = res.t_sender_free
            if rdv:
                # end-to-end ACK processing is a second R5 invocation on the
                # sender's MPSoC (§4.5.2) and serializes with other channels.
                for r in range(nranks):
                    m = self.topo.core_to_mpsoc(self.rank_core(r))
                    done[r] = self.net.charge_r5(m, done[r])
            for r in range(nranks):
                # sendrecv returns when both directions complete; then reduce
                clocks[r] = max(done[r], arrivals[r]) + penalty + t_red
        return max(clocks) + t_cpy + p.barrier_exit_us

    def allreduce_hw(self, size: int, nranks: int) -> float:
        from repro.core.exanet.allreduce_accel import accel_allreduce_latency
        return accel_allreduce_latency(size, nranks, self.p)
