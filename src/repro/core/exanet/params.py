"""Calibrated hardware constants of the ExaNeSt prototype.

Every constant cites the paper section it was measured in (FORTH-ICS/TR-488,
July 2023).  These are *component-level* measurements; the end-to-end
microbenchmark numbers (Tables 1-2, Figs 14-19) are produced by the event
engine in :mod:`repro.core.exanet.network` and validated against the paper in
``tests/test_exanet_paper_validation.py``.

Units: time in microseconds (us), sizes in bytes, rates in Gb/s
(1 Gb/s == 1000 bits/us).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HwParams:
    # ------------------------------------------------------------------ links
    #: per-link propagation+serdes latency; derived in §6.1.1:
    #: 1.293us (intra-QFDB 1 hop) - 1.17us (intra-FPGA) ~= 120ns.
    link_latency_us: float = 0.120
    #: ExaNet router (APEnet-derived) per-hop latency; §6.1.1:
    #: (409ns single-hop communication latency - 120ns link)/2 ~= 145ns.
    router_latency_us: float = 0.145
    #: small input-queued switch in every FPGA: 2 cycles @ 150 MHz (§4.2).
    local_switch_latency_us: float = 2 / 150.0
    #: raw link rates per class (§3.1): intra-QFDB GTH pairs 16 Gb/s,
    #: mezzanine-level SFP+ links 10 Gb/s.
    rate_intra_qfdb_gbps: float = 16.0
    rate_mezz_gbps: float = 10.0
    #: sustained MPI wire bandwidth per link class, §6.1.2: 13 Gb/s on 16G
    #: links (81.9% of theoretical), 6.42 Gb/s on 10G links (64.3%; extra
    #: flow-control control data on inter-QFDB links).
    bw_wire_intra_qfdb_gbps: float = 13.0
    bw_wire_mezz_gbps: float = 6.42

    # ------------------------------------------------------------------ cells
    #: §4.2: cells carry up to 256B payload + 16B header + 16B footer.
    cell_payload_bytes: int = 256
    cell_overhead_bytes: int = 32

    # ------------------------------------------------------------- NI / AXI
    #: PS<->PL AXI read/write channel: 128 bit @ 150 MHz = 19.2 Gb/s (§4.2).
    axi_bw_gbps: float = 19.2
    #: base PS<->PL round-trip 100-150ns (§4.2); one-way copy packetizer /
    #: mailbox measured 100~150ns with Chipscope (§6.1.1).
    pktz_copy_us: float = 0.125
    #: raw user-space packetizer->mailbox one-way latency (§6.1.1): ~470ns.
    ni_raw_oneway_us: float = 0.470
    #: endpoint software+NI cost of an MPI eager message: intra-FPGA
    #: osu_latency(0B) = 1.17us (§6.1.1). Includes MPI processing on both
    #: slow in-order A53 endpoints + both NI copies.
    sw_pingpong_base_us: float = 1.17
    #: osu_one_way_lat small-message base (§6.1.4: "one way latency values
    #: can be as low as 750 ns").
    sw_oneway_base_us: float = 0.75
    #: packetizer occupancy per small message (engine serialization).
    pktz_occupancy_us: float = 0.15
    #: floor on the per-message issue gap of the *windowed* eager stream
    #: (osu_bw): mailbox doorbell + completion polling on the in-order A53
    #: cannot be pipelined below this, which sets the small-message
    #: bandwidth plateau of §6.1.2 (Fig. 15, <=32 B points).
    osu_bw_eager_gap_floor_us: float = 0.30
    #: non-overlappable per-message software cost in the windowed
    #: rendez-vous stream (descriptor writes + completion handling per
    #: message); calibrated so osu_bw approaches the 13 Gb/s wire limit
    #: only above ~4 KB messages (§6.1.2, Fig. 15).
    osu_bw_rdv_per_msg_us: float = 0.70

    # ------------------------------------------------------------------ RDMA
    #: R5-firmware transaction-layer invocation, §4.5.2: "2-4us every time it
    #: is invoked. This dominates the interconnect (and MPI) base latency."
    #: Calibrated inside that window against osu_latency(64B)=5.157us.
    rdma_startup_us: float = 2.40
    #: R5 occupancy per RDMA operation (serializes concurrent channels of one
    #: MPSoC); remainder of the 2-4us window is waiting, not occupancy.
    r5_occupancy_us: float = 1.4
    #: endpoint software serialization of an MPI_Sendrecv step (the single-
    #: threaded process interleaves its send with RTS/CTS handling of the
    #: incoming message); calibrated against Fig. 17 anchors.
    sendrecv_sw_rdv_us: float = 2.0
    sendrecv_sw_eager_us: float = 0.65
    #: RDMA transaction/block size, §4.5: 16 KB blocks.
    rdma_block_bytes: int = 16384
    #: per-block gap inside a single transfer (R5 block handling + e2e ack
    #: turnaround); calibrated so a single 4MB message sustains 12.475 Gb/s
    #: on a 16G link (§6.1.1) while windowed osu_bw reaches 13 Gb/s.
    rdma_block_gap_us: float = 0.43
    #: MPI eager->rendez-vous switch (§6.1.1: messages up to 32B are eager;
    #: packetizer payload cap is 64B, the rest is MPI control data).
    mpi_eager_max_bytes: int = 32
    pktz_max_payload_bytes: int = 64

    # ----------------------------------------------------- endpoint memory
    #: A53 effective single-core copy/reduce bandwidth (bytes/us) for the
    #: MPI_Reduce_local + memcpy terms of software allreduce; single DDR4
    #: channel per MPSoC (§6.2: memory channel is the bottleneck).
    a53_copy_bw_bytes_per_us: float = 2000.0
    a53_call_overhead_us: float = 0.10

    # ------------------------------------------------------------- "noise"
    #: deterministic stand-ins for the effects the paper attributes to
    #: system noise / barrier exit skew / late arrivals (§6.1.4).
    barrier_exit_us: float = 0.40
    step_sync_us: float = 0.05

    # ------------------------------------------ Allreduce accelerator (§4.7)
    #: fixed per-256B-block cost: init/programming + level-0 client fetch +
    #: final broadcast + completion notify + software poll-out. Calibrated
    #: against Fig. 19 (16 ranks / 256B = 6.79us).
    ar_accel_fixed_us: float = 4.91
    #: per server-exchange level (inter-QFDB sendrecv + reduce in PL logic);
    #: calibrated against Fig. 19 scaling (128 ranks / 256B = 9.61us).
    ar_accel_level_us: float = 0.94
    ar_accel_block_bytes: int = 256
    ar_accel_max_vector_bytes: int = 4096
    ar_accel_max_ranks: int = 1024

    # ------------------------------------------------------ IP overlay (§5.3)
    #: user-space TUN read()/write() syscall + copy per packet on the A53.
    tun_syscall_us: float = 8.0
    #: paper Fig. 13 measured throughputs (validation targets, 5-hop path).
    ip_overlay_udp_large_gbps: float = 4.7
    ip_baseline_udp_large_gbps: float = 1.3
    ip_overlay_rtt_poll_us: float = 90.0
    ip_baseline_rtt_us: float = 72.0
    ip_overlay_rtt_sleep_us: float = 2200.0

    # ------------------------------------------------- MatMul accelerator (§7)
    mm_tile: int = 128
    mm_clock_mhz: float = 300.0
    mm_flops_per_cycle: int = 1024  # 512 FP32 mul + 512 FP32 add
    mm_measured_gflops: float = 275.0
    mm_tile_exec_cycles: int = 4200
    mm_dynamic_watts: float = 16.2
    mm_gflops_per_watt: float = 17.0

    # ------------------------------------------------------------- structure
    cores_per_mpsoc: int = 4
    fpgas_per_qfdb: int = 4
    qfdbs_per_mezzanine: int = 4
    mezzanines: int = 8  # full-scale prototype: 8 blades = 512 cores (§4.1)
    #: Y-ring size of the mezzanine-level torus; the Z ring is
    #: ``mezzanines // mezz_torus_y`` (the prototype is 4 x 4 x 2, §4.1).
    #: Paper-scale sweeps ("tens of thousands of processors", §1) grow the
    #: torus via :func:`scaled_params` while keeping every calibrated
    #: per-component constant untouched.
    mezz_torus_y: int = 4

    @property
    def cell_efficiency(self) -> float:
        """16 words payload / 18 words on the wire (§4.2)."""
        p, o = self.cell_payload_bytes, self.cell_overhead_bytes
        return p / float(p + o)

    @property
    def mezz_torus_z(self) -> int:
        return self.mezzanines // self.mezz_torus_y

    @property
    def n_qfdbs(self) -> int:
        return self.qfdbs_per_mezzanine * self.mezzanines

    @property
    def n_mpsocs(self) -> int:
        return self.n_qfdbs * self.fpgas_per_qfdb

    @property
    def n_cores(self) -> int:
        return self.n_mpsocs * self.cores_per_mpsoc


DEFAULT = HwParams()


def scaled_params(min_cores: int, base: HwParams = DEFAULT) -> HwParams:
    """A machine with the prototype's calibrated constants but a mezzanine
    torus grown (Y/Z rings doubled alternately from the 4x4x2 baseline)
    until it holds at least ``min_cores`` A53 cores.  This is how the
    paper-scale sweeps (1024/4096+ ranks) get a consistent topology: the
    prototype's 8 blades cap out at 512 cores."""
    if min_cores <= base.n_cores:
        return base
    cores_per_mezz = (base.cores_per_mpsoc * base.fpgas_per_qfdb
                      * base.qfdbs_per_mezzanine)
    y, z = base.mezz_torus_y, base.mezz_torus_z
    while y * z * cores_per_mezz < min_cores:
        if y <= z:
            y *= 2
        else:
            z *= 2
    return dataclasses.replace(base, mezzanines=y * z, mezz_torus_y=y)
