"""Compiled Program-IR execution: whole applications as vectorized level
programs (DESIGN.md §2.5, the Program half).

The interpreted :class:`~repro.core.program.ProgramExecutor`
(:meth:`ExanetMPI.run_program`) walks every rank's op stream through a
Python heap scheduler and every matched point-to-point transfer through
``Network.isend`` → per-resource ``Resource.acquire`` — which caps the
apps workload simulator at ~10 simulated iterations/sec at 512 ranks.
This module lowers a :class:`~repro.core.program.Program` the same way PR 3
lowered collective schedules: compile the *structure* once, bind the
*data* (byte sizes, compute microseconds) per column, replay with array
arithmetic.

Pipeline
========
1. **Static analysis** (once per :meth:`Program.structure_key`): FIFO
   matching is purely structural — the k-th ``Isend`` on channel
   (src, dst, tag) matches the k-th ``Irecv`` on that channel regardless of
   timing — so the match table, the per-rank *segments* (op runs between
   ``Wait``/``Collective`` boundaries, within which a rank's clock advances
   by bindable constants only), the wait sets and the collective sites are
   all computed without simulating anything.
2. **Probe** (once per binding): one interpreted run with recording hooks
   pins the *order* in which the scheduler fires matches and collective
   barriers — the composition order of same-resource acquisitions, which
   is the one thing array replay cannot derive structurally (it depends on
   the per-rank clocks, i.e. on the bound data).  Bindings that produce
   the same tape share one lowered artifact; for wave-structured programs
   (every halo/CG/BSP builder in the repo: all ranks post in lockstep) the
   tape is provably size-invariant, so a whole weak/strong sweep lands on
   a single lowering.
3. **Level decomposition** (once per tape): matched transfers are layered
   exactly like ``exec_compiled`` rounds — same-stage resource sharing
   (four ranks of an MPSoC hitting its R5) stays within a level and
   resolves in one segmented max-plus scan in tape order; *cross-stage*
   sharing (a DMA that is transfer A's source and transfer B's
   destination) forces a later level; a transfer whose post clocks read a
   wait's output lands after that wait; a ``Collective`` is a full
   barrier level that splices the schedule's already-compiled
   :class:`~repro.core.exanet.exec_compiled.RoundProgram` at the ranks'
   skewed entry clocks over the live :class:`ResourceState` (the array
   twin of the interpreter's ``run_schedule(t0=..., reset=False)`` seam).
4. **Execute**: per-segment clock offsets are one segmented ``cumsum``;
   per level, the eager/rendez-vous transports run through the shared
   :class:`~repro.core.exanet.exec_compiled.VecTransport` kernels; waits
   are grouped ``maximum.reduceat`` reductions.  One run costs a few
   thousand array ops instead of hundreds of thousands of Python calls.

Exactness
=========
The interpreter stays the reference semantics; compiled execution must
match it to ~1e-9 relative (``tests/test_program_compiled.py``: the
60-seed deterministic fuzz and its hypothesis twin).  The probe *is* an
interpreted run, so the recorded acquisition order is the interpreter's
own order for that binding by construction; within a level the scans
compose same-stage acquires in tape order, and every cross-stage or
clock-coupled pair is level-separated — the same two constructions that
make ``RoundProgram`` exact.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.exanet.exec_compiled import (ProgramStructureError,
                                             VecTransport, _Level,
                                             _make_stage, _send_res_tags)
from repro.core.exanet.scan_engine import resolve_engine
from repro.core.exanet.sim import ResourceState
from repro.core.program import (Collective, Compute, Irecv, Isend, Program,
                                ProgramError, ProgramExecutor, ProgramResult,
                                Wait)


# ---------------------------------------------------------------------------
# static analysis (per structure)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Post:
    rank: int
    gid: int          # segment the post belongs to
    item: int         # global item index (for the offset cumsum)
    is_send: bool
    peer: int
    tag: int


@dataclasses.dataclass
class _WaitNode:
    idx: int
    rank: int
    prev_gid: int     # segment the wait ends (exit clock read)
    new_gid: int      # segment the wait produces
    consumed: tuple   # post indices whose completion the wait maxes over


@dataclasses.dataclass
class _CollSite:
    idx: int
    op: str
    algo: str
    handle: str | None  # None = blocking barrier; else nonblocking site
    entry_gid: list   # per rank: segment whose exit clock is the entry
    exit_gid: list    # per rank: segment the exits produce (blocking only)
    entry_item: list  # per rank: item index of the entry (nonblocking only)


class _Static:
    """Structure-only decomposition of a Program: segments, posts, FIFO
    match table, waits and collective sites (no hardware, no timing)."""

    def __init__(self, prog: Program):
        nranks = prog.nranks
        self.nranks = nranks
        self.first_gid: list[int] = []
        self.last_gid: list[int] = []
        self.seg_producer: dict[int, tuple] = {}
        self.items: list[tuple] = []      # ("c"|"p", data_idx, gid)
        self.posts: list[_Post] = []
        self.waits: list[_WaitNode] = []
        self.sites: list[_CollSite] = []
        self.n_computes = 0
        channels: dict[tuple, tuple[list, list]] = {}
        n_segs = 0
        for r in range(nranks):
            gid = n_segs
            n_segs += 1
            self.first_gid.append(gid)
            outstanding: list[int] = []
            named: dict[str, int] = {}
            coll_i = 0
            for op in prog.rank_ops[r]:
                if isinstance(op, Compute):
                    self.items.append(("c", self.n_computes, gid))
                    self.n_computes += 1
                elif isinstance(op, (Isend, Irecv)):
                    is_send = isinstance(op, Isend)
                    peer = op.dst if is_send else op.src
                    pi = len(self.posts)
                    self.posts.append(_Post(r, gid, len(self.items),
                                            is_send, peer, op.tag))
                    self.items.append(("p", pi, gid))
                    key = (r, peer, op.tag) if is_send else \
                        (peer, r, op.tag)
                    ch = channels.setdefault(key, ([], []))
                    ch[0 if is_send else 1].append(pi)
                    outstanding.append(pi)
                    if op.handle is not None:
                        named[op.handle] = pi
                elif isinstance(op, Wait):
                    if op.handles is None:
                        consumed = tuple(outstanding)
                    else:
                        try:
                            consumed = tuple(named[h] for h in op.handles)
                        except KeyError as e:
                            raise ProgramError(
                                f"rank {r}: Wait on unknown handle "
                                f"{e}") from e
                    widx = len(self.waits)
                    new_gid = n_segs
                    n_segs += 1
                    self.waits.append(_WaitNode(widx, r, gid, new_gid,
                                                consumed))
                    self.seg_producer[new_gid] = ("w", widx)
                    cset = set(consumed)
                    outstanding = [q for q in outstanding if q not in cset]
                    named = {h: q for h, q in named.items()
                             if q not in cset}
                    gid = new_gid
                elif isinstance(op, Collective):
                    if coll_i == len(self.sites):
                        self.sites.append(_CollSite(
                            coll_i, op.op, op.algo, op.handle,
                            [None] * nranks, [None] * nranks,
                            [None] * nranks))
                    site = self.sites[coll_i]
                    if (site.op, site.algo, site.handle) != \
                            (op.op, op.algo, op.handle):
                        # a handle mismatch changes the *structure* (a
                        # blocking rank cuts a segment, a nonblocking one
                        # does not), so it must be rejected here, not at
                        # probe time
                        raise ProgramError(
                            f"collective mismatch at site #{coll_i}: "
                            f"rank {r} calls ({op.op}, {op.algo}, "
                            f"{op.handle}), another rank called "
                            f"({site.op}, {site.algo}, {site.handle})")
                    site.entry_gid[r] = gid
                    if op.handle is not None:
                        # nonblocking: the entry is an in-segment item
                        # (costing one post overhead, like an Isend) and
                        # the completion a pseudo-request a later Wait
                        # consumes — the rank's segment is NOT cut
                        site.entry_item[r] = len(self.items)
                        self.items.append(("a", coll_i, gid))
                        token = ("x", coll_i)
                        outstanding.append(token)
                        if op.handle in named:
                            raise ProgramError(
                                f"rank {r}: handle {op.handle!r} reused "
                                f"while still outstanding")
                        named[op.handle] = token
                    else:
                        new_gid = n_segs
                        n_segs += 1
                        site.exit_gid[r] = new_gid
                        self.seg_producer[new_gid] = ("x", coll_i)
                        gid = new_gid
                    coll_i += 1
            self.last_gid.append(gid)
        self.n_segs = n_segs
        # FIFO matching: k-th send on a channel pairs with its k-th recv
        # (a channel's sends all come from one rank, in its program order,
        # so the pairing is timing-independent).  Length mismatches are
        # dangling requests — the probe run raises the interpreter's own
        # ProgramError for them.
        self.events: list[tuple[int, int]] = []
        self.event_of_post: dict[int, tuple[int, bool]] = {}
        self.chan_events: dict[tuple, list[int]] = {}
        for key, (s_list, r_list) in channels.items():
            ids = []
            for sp, rp in zip(s_list, r_list):
                e = len(self.events)
                self.events.append((sp, rp))
                self.event_of_post[sp] = (e, True)
                self.event_of_post[rp] = (e, False)
                ids.append(e)
            self.chan_events[key] = ids
        # item -> segment bookkeeping for the bind-time offset cumsum
        # (items of one segment are contiguous and gids increase in walk
        # order, so segmented prefixes come from plain cumsum + gathers)
        self.item_seg = np.array([g for (_, _, g) in self.items],
                                 dtype=np.int64)
        n_items = len(self.items)
        self.item_first = np.zeros(n_items, dtype=np.int64)
        seg_first: dict[int, int] = {}
        for i, g in enumerate(self.item_seg):
            seg_first.setdefault(int(g), i)
            self.item_first[i] = seg_first[int(g)]
        self.seg_item_start = np.array(sorted(seg_first.values()),
                                       dtype=np.int64)
        self.segs_with_items = np.array(
            sorted(seg_first, key=lambda g: seg_first[g]), dtype=np.int64)
        self.post_item = np.array([p.item for p in self.posts],
                                  dtype=np.int64)
        # posts AND nonblocking-collective entries both cost one post
        # overhead on the poster's clock
        self.item_is_post = np.array([k != "c" for (k, _, _) in self.items],
                                     dtype=bool)
        # nonblocking sites get virtual completion rows past the p2p
        # events: row n_events + async_ord[site]*nranks + rank
        self.async_ord = {s.idx: i for i, s in enumerate(
            s for s in self.sites if s.handle is not None)}
        self.n_async = len(self.async_ord)
        # compute slots are appended rank-major, so per-rank totals are a
        # reduceat over contiguous runs
        first_gids = np.array(self.first_gid, dtype=np.int64)
        comp_gids = np.array([g for (k, _, g) in self.items if k == "c"],
                             dtype=np.int64)
        self.compute_rank = (
            np.searchsorted(first_gids, comp_gids, side="right") - 1
            if self.n_computes else np.zeros(0, dtype=np.int64))
        self.last_gid_arr = np.array(self.last_gid, dtype=np.int64)
        self.first_gid_arr = first_gids


def extract_data(prog: Program) -> tuple:
    """The bindable payload of a program, in static-walk order:
    (compute us, post nbytes, per-site collective nbytes)."""
    comp: list[float] = []
    post_nb: list[int] = []
    site_nb: dict[int, int] = {}
    for ops in prog.rank_ops:
        coll_i = 0
        for op in ops:
            if isinstance(op, Compute):
                comp.append(float(op.us))
            elif isinstance(op, (Isend, Irecv)):
                post_nb.append(int(op.nbytes))
            elif isinstance(op, Collective):
                nb = int(op.nbytes)
                prev = site_nb.setdefault(coll_i, nb)
                if prev != nb:
                    # sizes are excluded from structure_key, so a
                    # rank-inconsistent site would otherwise alias a
                    # consistent binding in the cache; the interpreter
                    # rejects it at barrier time, we reject it at extract
                    raise ProgramError(
                        f"collective mismatch at site #{coll_i}: ranks "
                        f"disagree on nbytes ({prev} vs {nb})")
                coll_i += 1
    sites = tuple(site_nb[i] for i in range(len(site_nb)))
    return tuple(comp), tuple(post_nb), sites


def rebind_program(prog: Program, *, compute_us=None, post_nbytes=None,
                   site_nbytes=None) -> Program:
    """Rebuild ``prog`` with replaced payload data — the Program-object
    inverse of one :meth:`CompiledProgram.bind_arrays` column, in the
    same static-walk order :func:`extract_data` emits (computes and posts
    rank-major in program order, collectives by site index).  Used by the
    scenario cross-checks to hand a perturbed column to the
    interpreter."""
    ci = pi = 0
    new_ranks = []
    for ops in prog.rank_ops:
        coll_i = 0
        new_ops = []
        for op in ops:
            if isinstance(op, Compute):
                if compute_us is not None:
                    op = dataclasses.replace(op, us=float(compute_us[ci]))
                ci += 1
            elif isinstance(op, (Isend, Irecv)):
                if post_nbytes is not None:
                    op = dataclasses.replace(op,
                                             nbytes=int(post_nbytes[pi]))
                pi += 1
            elif isinstance(op, Collective):
                if site_nbytes is not None:
                    op = dataclasses.replace(
                        op, nbytes=int(site_nbytes[coll_i]))
                coll_i += 1
            new_ops.append(op)
        new_ranks.append(tuple(new_ops))
    return Program(tuple(new_ranks))


# ---------------------------------------------------------------------------
# probe recording
# ---------------------------------------------------------------------------
class _Recorder:
    """Maps the interpreter's hook invocations back to static event ids:
    the i-th p2p call on a channel is that channel's i-th match; collective
    barriers complete in site order (site s+1 needs every rank past s)."""

    def __init__(self, static: _Static):
        self._chan_events = static.chan_events
        self._count: dict[tuple, int] = {}
        self._coll_i = 0
        self.tape: list[tuple] = []

    def p2p(self, src: int, dst: int, tag: int) -> None:
        key = (src, dst, tag)
        i = self._count.get(key, 0)
        self._count[key] = i + 1
        self.tape.append(("p", self._chan_events[key][i]))

    def coll(self, name: str | None) -> None:
        self.tape.append(("x", self._coll_i, name))
        self._coll_i += 1


# ---------------------------------------------------------------------------
# lowered artifacts
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _PLevel:
    """One dependency level of matched point-to-point transfers."""
    lv: _Level                  # shared stage structures (VecTransport)
    ev: np.ndarray              # event ids, tape order
    send_post: np.ndarray
    recv_post: np.ndarray
    send_seg: np.ndarray
    recv_seg: np.ndarray


@dataclasses.dataclass
class _WaitPlan:
    """All waits of one level, grouped for one reduceat."""
    target: np.ndarray          # produced segment ids
    prev: np.ndarray            # ended segment ids (exit clock read)
    with_req: np.ndarray        # indices into target that have >=1 request
    req_ev: np.ndarray          # concatenated event ids
    req_side: np.ndarray        # True = send-side completion
    starts: np.ndarray          # reduceat starts into req_ev


@dataclasses.dataclass
class _CollSlot:
    site: _CollSite
    name: str | None            # resolved schedule ("accel", None = trivial)
    sched: object | None        # schedule instance (stateless)
    rp: object | None           # compiled RoundProgram
    entry: np.ndarray           # (nranks,) entry segment ids
    exit: np.ndarray | None     # (nranks,) produced segment ids (blocking)
    entry_item: np.ndarray | None  # (nranks,) entry items (nonblocking)
    virt_base: int              # first virtual done-row (nonblocking)


@dataclasses.dataclass
class _LevelPlan:
    p2p: _PLevel | None = None
    waits: _WaitPlan | None = None
    coll: _CollSlot | None = None


@dataclasses.dataclass
class _LoweredTape:
    levels: list
    n_rows: int


@dataclasses.dataclass
class _BoundLevel:
    nb: np.ndarray              # (k, B) send bytes per event
    is_rdv: np.ndarray          # (k, B)
    any_e: bool
    any_r: bool
    uni: bool                   # bytes uniform across the level's events


@dataclasses.dataclass
class _BoundIR:
    """One binding of a compiled program: per-column payload data laid out
    for array replay (plus the lowered tape the columns share)."""
    B: int
    lowered: _LoweredTape
    post_off: np.ndarray        # (n_posts, B) in-segment clock offsets
    seg_total: np.ndarray       # (n_segs, B)
    rank_compute: np.ndarray    # (nranks, B)
    levels: list                # _BoundLevel per _LevelPlan (None w/o p2p)
    site_sizes: list            # per site: tuple of per-column nbytes
    coll_entry_off: dict        # async site idx -> (nranks, B) item offsets


class CompiledProgram(VecTransport):
    """A Program structure lowered for one (machine, placement).

    Compile once per :meth:`Program.structure_key`; :meth:`bind` payload
    data per column (one probe per distinct binding pins the scheduling
    order — bindings with equal tapes share the lowered levels);``run``
    replays bound columns in one batched pass.  Collective sites splice
    their compiled :class:`RoundProgram` at the ranks' entry clocks over
    the shared live :class:`ResourceState`.
    """

    def __init__(self, mpi, prog: Program):
        prog.validate()
        self.key = prog.structure_key()
        self.nranks = prog.nranks
        self._mpi = mpi
        self._init_transport(mpi.p)
        self._static = _Static(prog)
        self._cores = mpi._cores(self.nranks)
        self._pm = None             # per-event path metrics (lazy)
        self._res_tags = None
        self._tape_cache: dict = {}
        self._bind_cache: dict = {}
        self._probe_cache: dict = {}

    # ---------------------------------------------------------------- probe
    def _probe(self, prog: Program, plans: dict) -> tuple:
        """One interpreted run with recording hooks: returns the tape (the
        scheduler's match/barrier firing order for this binding)."""
        mpi = self._mpi
        rec = _Recorder(self._static)
        hooks = mpi._program_hooks(self.nranks, plans, recorder=rec)
        mpi.net.reset()
        ProgramExecutor(prog, **hooks,
                        post_overhead_us=mpi.p.a53_call_overhead_us).run()
        return tuple(rec.tape)

    # ------------------------------------------------------------- lowering
    def _event_metrics(self):
        if self._pm is None:
            st = self._static
            pairs = [(self._cores[st.posts[sp].rank],
                      self._cores[st.posts[rp].rank])
                     for (sp, rp) in st.events]
            self._pm = self._mpi.net.path_metrics_arrays(pairs)
            self._res_tags = _send_res_tags(self._pm, len(st.events))
        return self._pm, self._res_tags

    def _lowered(self, tape: tuple) -> _LoweredTape:
        lt = self._tape_cache.get(tape)
        if lt is not None:
            return lt
        st = self._static
        pm, res_tags = self._event_metrics()
        avail: dict[int, int] = {g: 0 for g in st.first_gid}
        ev_level: dict[int, int] = {}
        wait_level: dict[int, int] = {}
        coll_level: dict[int, int] = {}

        def resolve_seg(gid: int) -> int:
            # iterative over the rank's Wait chain (which can be
            # arbitrarily deep — a recursion would overflow on long
            # phase-sequenced programs the interpreter handles fine)
            lv = avail.get(gid)
            if lv is not None:
                return lv
            stack = [gid]
            while stack:
                g = stack[-1]
                if g in avail:
                    stack.pop()
                    continue
                kind, idx = st.seg_producer[g]
                if kind != "w":     # collective exits set avail eagerly
                    raise ProgramStructureError(
                        "tape references a collective exit before the "
                        "site fired — scheduling order inconsistent "
                        "with structure")
                w = st.waits[idx]
                lv = avail.get(w.prev_gid)
                if lv is None:
                    stack.append(w.prev_gid)
                    continue
                for pi in w.consumed:
                    if isinstance(pi, tuple):   # nonblocking collective
                        if pi[1] not in coll_level:
                            raise ProgramStructureError(
                                "wait consumes a nonblocking collective "
                                "the probe never fired")
                        # the splice executes after the level's waits, so
                        # a consuming wait lands one level later
                        lv = max(lv, coll_level[pi[1]] + 1)
                        continue
                    rec = st.event_of_post.get(pi)
                    if rec is None or rec[0] not in ev_level:
                        raise ProgramStructureError(
                            "wait consumes a request the probe never "
                            "matched")
                    lv = max(lv, ev_level[rec[0]])
                wait_level[idx] = lv
                avail[g] = lv + 1
                stack.pop()
            return avail[gid]

        floor = 0
        amax = -1
        row_tags: dict = {}
        # Stage-major execution within a level runs R5 -> DMA src -> link
        # hops in path order -> DMA dst.  A later send touching a shared
        # row at a *later* pipeline stage is therefore acquired after the
        # earlier send even inside one level — only the reverse direction
        # (later send, earlier stage) forces a level split.  This is a
        # strictly tighter rule than ``exec_compiled._level_assignment``'s
        # symmetric one and roughly halves the level count of halo
        # programs (the dominant S->D DMA chains pair up).
        def stage_ord(tag):
            if isinstance(tag, int):      # link hop position
                return 2 + tag
            return {"E": -1, "R": 0, "S": 1, "D": 1 << 30}[tag]
        for item in tape:
            if item[0] == "p":
                e = item[1]
                sp, rp = st.events[e]
                lv = max(floor, resolve_seg(st.posts[sp].gid),
                         resolve_seg(st.posts[rp].gid))
                for (row, tag) in res_tags[e]:
                    tags = row_tags.get(row)
                    if tags:
                        o = stage_ord(tag)
                        for t2, l2 in tags.items():
                            need = l2 if t2 == tag or stage_ord(t2) < o \
                                else l2 + 1
                            if need > lv:
                                lv = need
                ev_level[e] = lv
                for (row, tag) in res_tags[e]:
                    d = row_tags.setdefault(row, {})
                    if d.get(tag, -1) < lv:
                        d[tag] = lv
                if lv > amax:
                    amax = lv
            else:
                _, s, _name = item
                site = st.sites[s]
                lv = floor
                for r in range(self.nranks):
                    lv = max(lv, resolve_seg(site.entry_gid[r]))
                # the interpreter fired every recorded event before the
                # last rank arrived, so the splice must follow everything
                # assigned so far (nonblocking sites keep the same
                # conservative ordering: levels only sequence resource
                # acquisitions, the entry clocks stay mid-segment)
                lv = max(lv, amax + 1)
                coll_level[s] = lv
                if site.handle is None:
                    for r in range(self.nranks):
                        avail[site.exit_gid[r]] = lv + 1
                floor = lv + 1
                amax = lv
                row_tags = {}
        for w in st.waits:
            if w.idx not in wait_level:
                resolve_seg(w.new_gid)

        n_levels = 1 + max(
            [lv for lv in ev_level.values()]
            + [lv for lv in wait_level.values()]
            + [lv for lv in coll_level.values()] + [-1])
        levels = [_LevelPlan() for _ in range(n_levels)]
        by_level: dict[int, list[int]] = {}
        for item in tape:                      # keep tape order per level
            if item[0] == "p":
                by_level.setdefault(ev_level[item[1]], []).append(item[1])
        for lv_i, evs in by_level.items():
            levels[lv_i].p2p = self._lower_p2p_level(evs, pm)
        waits_by_level: dict[int, list[_WaitNode]] = {}
        for w in st.waits:
            waits_by_level.setdefault(wait_level[w.idx], []).append(w)
        for lv_i, ws in waits_by_level.items():
            levels[lv_i].waits = self._lower_waits(ws)
        for item in tape:
            if item[0] == "x":
                _, s, name = item
                levels[coll_level[s]].coll = self._lower_coll(
                    st.sites[s], name)
        lt = _LoweredTape(levels, self._mpi.net.engine.n_resource_ids)
        self._tape_cache[tape] = lt
        return lt

    def _lower_p2p_level(self, evs: list[int], pm) -> _PLevel:
        st = self._static
        idx = np.array(evs, dtype=np.int64)
        k = len(idx)
        pos = np.arange(k)
        spb = pm["stream_us_per_byte"][idx]
        n_links = pm["n_links"][idx]
        max_links = int(n_links.max()) if k else 0
        link_stages = []
        for pos_k in range(max_links):
            sub = np.flatnonzero(n_links > pos_k)
            link_stages.append(_make_stage(
                pos[sub], pm["link_ids"][idx[sub], pos_k], spb[sub]))
        ddst_sub = np.flatnonzero(pm["dma_dst_id"][idx] >= 0)
        lv = _Level(
            sel=idx,
            e_const=pm["eager_ow_const_us"][idx][:, None],
            eager_pb=pm["eager_wire_us_per_byte"][idx][:, None],
            handshake=pm["handshake_ow_us"][idx][:, None],
            stream_pb=spb[:, None],
            hop=pm["hop_latency_us"][idx][:, None],
            pktz=_make_stage(pos, pm["pktz_id"][idx], span=k),
            r5=_make_stage(pos, pm["r5_id"][idx], span=k),
            dsrc=_make_stage(pos, pm["dma_src_id"][idx], spb, span=k),
            links=[s for s in link_stages if s is not None],
            ddst=_make_stage(ddst_sub, pm["dma_dst_id"][idx[ddst_sub]],
                             spb[ddst_sub]),
            src_ranks=None, dst_perm=None, dst_starts=None, udst=None,
            link_ids=pm["link_ids"][idx],
            link_rate=pm["link_rate_gbps"][idx],
            link_wire=pm["link_wire_gbps"][idx],
            n_links=n_links)
        send_post = np.array([st.events[e][0] for e in evs], dtype=np.int64)
        recv_post = np.array([st.events[e][1] for e in evs], dtype=np.int64)
        return _PLevel(
            lv=lv, ev=idx, send_post=send_post, recv_post=recv_post,
            send_seg=np.array([st.posts[p].gid for p in send_post],
                              dtype=np.int64),
            recv_seg=np.array([st.posts[p].gid for p in recv_post],
                              dtype=np.int64))

    def _lower_waits(self, ws: list[_WaitNode]) -> _WaitPlan:
        st = self._static
        n_events = len(st.events)
        req_ev, req_side, starts, with_req = [], [], [], []
        for i, w in enumerate(ws):
            if w.consumed:
                with_req.append(i)
                starts.append(len(req_ev))
                for pi in w.consumed:
                    if isinstance(pi, tuple):   # nonblocking collective:
                        # virtual completion row of (site, waiting rank)
                        req_ev.append(n_events
                                      + st.async_ord[pi[1]] * self.nranks
                                      + w.rank)
                        req_side.append(True)
                        continue
                    e, is_send = st.event_of_post[pi]
                    req_ev.append(e)
                    req_side.append(is_send)
        return _WaitPlan(
            target=np.array([w.new_gid for w in ws], dtype=np.int64),
            prev=np.array([w.prev_gid for w in ws], dtype=np.int64),
            with_req=np.array(with_req, dtype=np.int64),
            req_ev=np.array(req_ev, dtype=np.int64),
            req_side=np.array(req_side, dtype=bool),
            starts=np.array(starts, dtype=np.int64))

    def _lower_coll(self, site: _CollSite, name: str | None) -> _CollSlot:
        st = self._static
        entry = np.array(site.entry_gid, dtype=np.int64)
        if site.handle is None:
            exit_ = np.array(site.exit_gid, dtype=np.int64)
            entry_item = None
            virt_base = -1
        else:
            exit_ = None
            entry_item = np.array(site.entry_item, dtype=np.int64)
            virt_base = len(st.events) + st.async_ord[site.idx] * self.nranks
        sched = rp = None
        if name is not None and name != "accel":
            from repro.core.exanet.schedules import COLLECTIVE_SCHEDULES
            sched = COLLECTIVE_SCHEDULES[site.op][name]()
            rp = self._mpi.compiled_program(sched, self.nranks)
        return _CollSlot(site, name, sched, rp, entry, exit_, entry_item,
                         virt_base)

    # ----------------------------------------------------------------- bind
    def _tape_of(self, prog, plans, data, names) -> tuple:
        """Cached probe: one interpreted run per distinct (payload data,
        resolved schedule names) binding ever probed on this artifact."""
        key = (data, names)
        tape = self._probe_cache.get(key)
        if tape is None:
            tape = self._probe_cache[key] = self._probe(prog, plans or {})
        return tape

    def bind(self, progs, plans_list=None) -> _BoundIR:
        """Bind one or more structurally-identical programs as batch
        columns of a *single* replay.  Raises
        :class:`ProgramStructureError` when the scheduler's firing order
        differs between columns — :meth:`bind_batch` is the total version
        that groups divergent columns instead of raising."""
        groups = self.bind_batch(progs, plans_list)
        if len(groups) > 1:
            raise ProgramStructureError(
                "scheduling order varies across the bound columns; bind "
                "them separately")
        return groups[0][1]

    def bind_batch(self, progs, plans_list=None
                   ) -> list[tuple[np.ndarray, _BoundIR]]:
        """Bind structurally-identical programs as batch columns, grouped
        by probe tape: returns ``[(column_indices, bound), ...]`` where
        each bound replays its columns in one pass (one group — the
        common case for wave-structured builders — means the whole batch
        is a single array program).  Raises
        :class:`ProgramStructureError` when a program's structure does
        not match this artifact (the cache-poisoning guard:
        differently-*structured* programs must never share a lowering)."""
        progs = list(progs)
        plans_list = list(plans_list or [None] * len(progs))
        datas = []
        names_cols = []
        for i, (prog, plans) in enumerate(zip(progs, plans_list)):
            if prog.structure_key() != self.key:
                raise ProgramStructureError(
                    "program structure does not match the compiled "
                    "artifact (FIFO matching / waits / collective sites "
                    "differ) — compile it instead of re-binding")
            if plans is None:
                # same default as run_program: auto allreduce sites are
                # planner-chosen, so both backends resolve identically
                plans_list[i] = self._mpi._plan_program_sites(prog, None)
        for prog, plans in zip(progs, plans_list):
            data = extract_data(prog)
            datas.append(data)
            names_cols.append(tuple(
                None if self.nranks < 2 else
                self._mpi._resolve_collective_schedule(
                    s.op, data[2][s.idx], s.algo, plans or {})
                for s in self._static.sites))
        groups: dict[tuple, list[int]] = {}
        for i, (prog, plans) in enumerate(zip(progs, plans_list)):
            tape = self._tape_of(prog, plans, datas[i], names_cols[i])
            groups.setdefault(tape, []).append(i)
        out = []
        for tape, cols in groups.items():
            key = (tuple(datas[i] for i in cols),
                   tuple(names_cols[i] for i in cols))
            bound = self._bind_cache.get(key)
            if bound is None:
                lowered = self._lowered(tape)
                bound = self._bind_data(lowered, [datas[i] for i in cols])
                self._bind_cache[key] = bound
            out.append((np.array(cols, dtype=np.int64), bound))
        return out

    def bind_arrays(self, prog: Program, *, compute_us=None,
                    post_nbytes=None, site_nbytes=None,
                    plans=None) -> _BoundIR:
        """Scenario binding: N payload perturbations of one base program
        as batch columns, *without* materializing N Program objects or
        probing N times.

        ``compute_us`` is (n_computes, N) per-slot compute microseconds
        (slots in static-walk order: rank-major, program order — the
        order :func:`extract_data` emits), ``post_nbytes``
        (n_posts, N) per-post byte counts, ``site_nbytes`` (n_sites, N)
        per-collective-site byte counts; ``None`` holds the base
        program's value constant across columns.

        All columns share the *base binding's* probe tape.  That is exact
        whenever the scheduler's firing order is payload-invariant —
        which holds for the repo's wave-structured builders (all ranks
        post in lockstep; the heap's rank-id tie-break fixes the order)
        but is not checked per column here: perturbations that change
        which collective schedule a site resolves to are rejected, and
        :meth:`ExanetMPI.run_program_scenarios` offers sampled
        interpreter cross-checks for the rest.
        """
        if prog.structure_key() != self.key:
            raise ProgramStructureError(
                "program structure does not match the compiled artifact "
                "(FIFO matching / waits / collective sites differ) — "
                "compile it instead of re-binding")
        st = self._static
        if plans is None:
            plans = self._mpi._plan_program_sites(prog, None)
        base = extract_data(prog)
        N = None
        for nm, a, k in (("compute_us", compute_us, st.n_computes),
                         ("post_nbytes", post_nbytes, len(st.posts)),
                         ("site_nbytes", site_nbytes, len(st.sites))):
            if a is None:
                continue
            a = np.asarray(a)
            if a.ndim != 2 or a.shape[0] != k:
                raise ValueError(f"{nm} must have shape ({k}, N), "
                                 f"got {a.shape}")
            if N is None:
                N = a.shape[1]
            elif a.shape[1] != N:
                raise ValueError("scenario arrays disagree on N")
        if N is None:
            N = 1
        comp_cols = (np.asarray(compute_us, dtype=np.float64)
                     if compute_us is not None else np.broadcast_to(
                         np.array(base[0])[:, None], (st.n_computes, N)))
        post_nb = (np.asarray(post_nbytes, dtype=np.float64)
                   if post_nbytes is not None else np.broadcast_to(
                       np.array(base[1], dtype=np.float64)[:, None],
                       (len(st.posts), N)))
        if site_nbytes is not None:
            site_cols = np.asarray(site_nbytes, dtype=np.int64)
        else:
            site_cols = np.broadcast_to(
                np.array(base[2], dtype=np.int64)[:, None],
                (len(st.sites), N))
        names0 = tuple(
            None if self.nranks < 2 else
            self._mpi._resolve_collective_schedule(
                s.op, base[2][s.idx], s.algo, plans or {})
            for s in st.sites)
        for j, s in enumerate(st.sites):
            if self.nranks < 2:
                continue
            for sz in np.unique(site_cols[j]):
                name = self._mpi._resolve_collective_schedule(
                    s.op, int(sz), s.algo, plans or {})
                if name != names0[j]:
                    raise ProgramStructureError(
                        f"site #{j}: scenario size {int(sz)} resolves to "
                        f"schedule {name!r} but the base binding uses "
                        f"{names0[j]!r} — the tape differs; bind those "
                        f"scenarios separately")
        tape = self._tape_of(prog, plans, base, names0)
        lowered = self._lowered(tape)
        site_sizes = [tuple(int(x) for x in site_cols[j])
                      for j in range(len(st.sites))]
        return self._bind_cols(lowered, comp_cols, post_nb, site_sizes)

    def _bind_data(self, lowered: _LoweredTape, datas: list) -> _BoundIR:
        st = self._static
        B = len(datas)
        comp_cols = np.array([d[0] for d in datas]).T.reshape(
            st.n_computes, B)
        post_nb = np.array([d[1] for d in datas], dtype=np.float64).T \
            .reshape(len(st.posts), B)
        site_sizes = [tuple(int(d[2][s.idx]) for d in datas)
                      for s in st.sites]
        return self._bind_cols(lowered, comp_cols, post_nb, site_sizes)

    def _bind_cols(self, lowered: _LoweredTape, comp_cols: np.ndarray,
                   post_nb: np.ndarray, site_sizes: list) -> _BoundIR:
        """Column-stacked payload arrays -> a :class:`_BoundIR` (shared
        tail of :meth:`bind` and :meth:`bind_arrays`)."""
        st = self._static
        B = comp_cols.shape[1]
        po = self._p.a53_call_overhead_us
        n_items = len(st.items)
        item_cost = np.empty((n_items, B))
        item_cost[st.item_is_post] = po
        if st.n_computes:
            item_cost[~st.item_is_post] = comp_cols
        excl = np.cumsum(item_cost, axis=0) - item_cost if n_items else \
            np.zeros((0, B))
        item_off = excl - excl[st.item_first] if n_items else excl
        post_off = item_off[st.post_item] if len(st.posts) else \
            np.zeros((0, B))
        seg_total = np.zeros((st.n_segs, B))
        if n_items:
            seg_total[st.segs_with_items] = np.add.reduceat(
                item_cost, st.seg_item_start, axis=0)
        rank_compute = np.zeros((self.nranks, B))
        if st.n_computes:
            np.add.at(rank_compute, st.compute_rank, comp_cols)
        coll_entry_off = {
            s.idx: item_off[np.array(s.entry_item, dtype=np.int64)]
            for s in st.sites if s.handle is not None}
        b_levels = []
        for plan in lowered.levels:
            if plan.p2p is None:
                b_levels.append(None)
                continue
            nb = post_nb[plan.p2p.send_post]
            # the interpreter's _match rejects size-mismatched channels;
            # re-bound programs must fail the same way (the probe already
            # raised for the compiled columns, this guards the arrays)
            nb_r = post_nb[plan.p2p.recv_post]
            if not np.array_equal(nb, nb_r):
                raise ProgramError(
                    "size mismatch on a matched (src, dst, tag) channel")
            is_rdv = nb > self._eager_max
            b_levels.append(_BoundLevel(
                nb=nb, is_rdv=is_rdv, any_e=bool((~is_rdv).any()),
                any_r=bool(is_rdv.any()),
                uni=bool((nb == nb[:1]).all())))
        return _BoundIR(B, lowered, post_off, seg_total, rank_compute,
                        b_levels, site_sizes, coll_entry_off)

    # ------------------------------------------------------------ execution
    def run(self, bound: _BoundIR, *, engine=None, t0=None,
            deg=None) -> list[ProgramResult]:
        """Replay the bound columns; one :class:`ProgramResult` each.
        ``engine`` selects the scan backend (``"numpy"`` default,
        ``"jax"``, or an engine object; DESIGN.md §2.5) — collective
        splices inherit it.

        ``t0`` seeds per-rank entry clocks: ``(nranks,)`` applied to all
        columns, or ``(nranks, B)`` per column — the Program-IR twin of
        the schedule replay's arrival-offset axis (exact for the same
        reason: resources start at zero occupancy, so an offset start is
        just a shifted first segment).  Like payload perturbations, the
        columns share the base probe tape; skews large enough to reorder
        the scheduler's firing are the cross-check's (``check=``) job to
        catch.

        ``deg`` binds the per-(link, column) degradation axes
        (:class:`~repro.core.exanet.exec_compiled.LinkDegrade`): every
        p2p level and spliced collective recomputes its link-derived
        constants per column (DESIGN.md §2.10)."""
        self._eng = resolve_engine(engine)
        self._deg = deg
        st = self._static
        B = bound.B
        if deg is not None and deg.ncols not in (1, B):
            raise ValueError(f"deg has {deg.ncols} columns, batch has {B}")
        lowered = bound.lowered
        state = ResourceState(lowered.n_rows, B)
        C = np.zeros((st.n_segs, B))
        if t0 is not None:
            t0 = np.asarray(t0, dtype=np.float64)
            if t0.ndim == 1:
                t0 = t0[:, None]
            if t0.shape != (self.nranks, 1) and t0.shape != (self.nranks, B):
                raise ValueError(
                    f"t0 must have shape ({self.nranks},) or "
                    f"({self.nranks}, {B}), got {t0.shape}")
            C[st.first_gid_arr] = t0
        # virtual rows past the p2p events hold the per-(site, rank) exit
        # clocks of nonblocking collectives, consumed by waits like any
        # send-side completion
        n_rows = len(st.events) + st.n_async * self.nranks
        send_done = np.empty((n_rows, B))
        recv_done = np.empty((n_rows, B))
        for plan, bl in zip(lowered.levels, bound.levels):
            if plan.p2p is not None:
                self._exec_p2p_level(state, plan.p2p, bl, C, bound,
                                     send_done, recv_done)
            if plan.waits is not None:
                self._exec_waits(plan.waits, C, bound, send_done, recv_done)
            if plan.coll is not None:
                self._exec_coll(state, plan.coll, C, bound, send_done)
        final = C[st.last_gid_arr] + bound.seg_total[st.last_gid_arr]
        latency = final.max(axis=0) if self.nranks else np.zeros(B)
        return [ProgramResult(
            float(latency[b]),
            tuple(float(x) for x in final[:, b]),
            tuple(float(x) for x in bound.rank_compute[:, b]),
            len(st.events), len(st.sites)) for b in range(B)]

    def _exec_p2p_level(self, state, pl: _PLevel, bl: _BoundLevel, C,
                        bound, send_done, recv_done) -> None:
        t_send = C[pl.send_seg] + bound.post_off[pl.send_post]
        t_recv = C[pl.recv_seg] + bound.post_off[pl.recv_post]
        lv, nb = pl.lv, bl.nb
        if not bl.any_r:
            comp, sfree = self._run_eager(state, lv, t_send, nb, None, None)
            send_done[pl.ev] = sfree
            recv_done[pl.ev] = np.maximum(comp, t_recv)
            return
        if not bl.any_e:
            comp, _ = self._run_rdv(state, lv, np.maximum(t_send, t_recv),
                                    nb, None, None, bl.uni)
            send_done[pl.ev] = comp
            recv_done[pl.ev] = comp
            return
        act_r = np.broadcast_to(bl.is_rdv, t_send.shape)
        comp_e, sfree_e = self._run_eager(state, lv, t_send, nb, ~act_r,
                                          None)
        comp_r, _ = self._run_rdv(state, lv, np.maximum(t_send, t_recv),
                                  nb, act_r, None, False)
        send_done[pl.ev] = np.where(bl.is_rdv, comp_r, sfree_e)
        recv_done[pl.ev] = np.where(bl.is_rdv, comp_r,
                                    np.maximum(comp_e, t_recv))

    def _exec_waits(self, wp: _WaitPlan, C, bound, send_done,
                    recv_done) -> None:
        exit_ = C[wp.prev] + bound.seg_total[wp.prev]
        if wp.req_ev.size:
            vals = np.where(wp.req_side[:, None], send_done[wp.req_ev],
                            recv_done[wp.req_ev])
            gm = np.maximum.reduceat(vals, wp.starts, axis=0)
            exit_[wp.with_req] = np.maximum(exit_[wp.with_req], gm)
        C[wp.target] = exit_

    def _exec_coll(self, state, slot: _CollSlot, C, bound,
                   send_done=None) -> None:
        if slot.entry_item is None:         # blocking: entry ends a segment
            enters = C[slot.entry] + bound.seg_total[slot.entry]
        else:                               # nonblocking: mid-segment item
            enters = C[slot.entry] + bound.coll_entry_off[slot.site.idx]
        sizes = bound.site_sizes[slot.site.idx]
        if slot.name is None:               # nranks < 2: pass-through
            exits = enters
        elif slot.name == "accel":
            from repro.core.exanet.allreduce_accel import accel_cost_us
            cost = np.array([accel_cost_us(s, self.nranks, self._p)
                             for s in sizes])
            exits = np.broadcast_to(
                enters.max(axis=0)[None, :] + cost[None, :], enters.shape)
        else:
            rp, sched = slot.rp, slot.sched
            res = rp.run(sched, sizes, state=state, t0=enters,
                         engine=self._eng, deg=self._deg)
            b = rp.bind(sched, sizes)
            exits = res.clocks.T + b.post_copy_us[None, :] + \
                self._p.barrier_exit_us
        if slot.entry_item is None:
            C[slot.exit] = exits
        else:
            send_done[slot.virt_base:slot.virt_base + self.nranks] = exits


def compile_program_ir(mpi, prog: Program) -> CompiledProgram:
    """Lower a Program's structure for one (machine, placement).  Payload
    data (sizes, compute times) binds per column via
    :meth:`CompiledProgram.bind`."""
    return CompiledProgram(mpi, prog)
