"""Pluggable scan-engine seam for the compiled executors (DESIGN.md §2.5).

The two kernels every compiled replay spends its time in — the segmented
max-plus scan and the segmented running maximum of
:mod:`repro.core.exanet.sim` — are pure array programs over a
``(k, *batch)`` layout with data-independent combine masks.  That makes
them retargetable: this module defines the engine interface the
:class:`~repro.core.exanet.exec_compiled.VecTransport` kernels call
through, with two implementations:

* :class:`NumpyScanEngine` (``engine="numpy"``, the default) — delegates
  to the in-place masked-ufunc scans in ``sim.py``.  No dependencies
  beyond NumPy; the reference for the ≤1e-9 agreement tests.
* :class:`JaxScanEngine` (``engine="jax"``) — the same Hillis-Steele
  passes as ``jax.jit``-compiled kernels, ``jax.vmap``-batched over the
  trailing batch axis.  jax is an *optional* dependency
  (requirements-dev.txt): constructing the engine without it raises a
  clear error, and everything else in the simulator keeps working.
  Kernels run under a *scoped* ``jax.experimental.enable_x64`` context —
  the compiled executor is held to ≤1e-9 agreement with the interpreter,
  which float32 cannot meet — without flipping the process-global x64
  flag (other jax users in the same process, e.g. the Layer-B models,
  keep their own precision defaults).

Engines are stateless beyond caches, so one instance serves every
compiled program; executors resolve a per-call ``engine=`` argument
through :func:`resolve_engine` (``None`` → numpy).  The combine masks
arrive as the precomputed ``takes`` lists of
:func:`~repro.core.exanet.sim.scan_take_masks` — shift offsets are
static per stage (they key the jitted kernel cache), masks are traced
operands.
"""

from __future__ import annotations

import functools

import numpy as np

from repro.core.exanet.sim import (segmented_maxplus_scan,
                                   segmented_running_max)


class NumpyScanEngine:
    """The default engine: sim.py's in-place masked-ufunc scans."""

    name = "numpy"

    def maxplus_scan(self, D, T, takes):
        """Segmented max-plus scan; may clobber ``D``/``T`` (callers pass
        freshly-built per-stage arrays)."""
        return segmented_maxplus_scan(D, T, None, 0, takes=takes,
                                      copy=False)

    def running_max(self, v, takes):
        return segmented_running_max(v, takes)


_jax = None
_enable_x64 = None


def _load_jax():
    """Import jax lazily; raise a clear error when the optional
    dependency is absent (mirrors the hypothesis pattern in tests)."""
    global _jax, _enable_x64
    if _jax is None:
        try:
            import jax
            from jax.experimental import enable_x64
        except ImportError as e:
            raise RuntimeError(
                "scan engine 'jax' requires the optional jax dependency "
                "(pip install \"jax[cpu]\"; see requirements-dev.txt). "
                "The default engine='numpy' needs nothing extra."
            ) from e
        _jax, _enable_x64 = jax, enable_x64
    return _jax


@functools.lru_cache(maxsize=None)
def _maxplus_kernel(shifts: tuple):
    """jit+vmap max-plus kernel for one static shift sequence.  One
    Hillis-Steele pass composes ``(D1,T1) then (D2,T2)`` into
    ``(D1+D2, max(T1+D2, T2))`` where the take mask allows; vmap runs
    every batch column through the same (k,)-vector kernel."""
    jax = _load_jax()
    jnp = jax.numpy

    def one(D, T, masks):
        for s, m in zip(shifts, masks):
            T = T.at[s:].set(jnp.where(
                m, jnp.maximum(T[:-s] + D[s:], T[s:]), T[s:]))
            D = D.at[s:].set(jnp.where(m, D[:-s] + D[s:], D[s:]))
        return D, T

    return jax.jit(jax.vmap(one, in_axes=(1, 1, None), out_axes=1))


@functools.lru_cache(maxsize=None)
def _running_max_kernel(shifts: tuple):
    jax = _load_jax()
    jnp = jax.numpy

    def one(v, masks):
        for s, m in zip(shifts, masks):
            v = v.at[s:].set(jnp.where(m, jnp.maximum(v[:-s], v[s:]),
                                       v[s:]))
        return v

    return jax.jit(jax.vmap(one, in_axes=(1, None), out_axes=1))


class JaxScanEngine:
    """``jax.jit`` + ``jax.vmap`` lane of the same scan kernels.

    Jitted kernels are cached per shift sequence (the static part of a
    stage's ``takes``); the 1-D mask operands are cached per ``takes``
    list identity — the cache holds a reference to the list itself, so a
    recycled ``id()`` can never alias a dead stage.  Inputs and outputs
    are NumPy arrays: conversion happens at this boundary only, and the
    surrounding gather/scatter bookkeeping stays NumPy either way.
    """

    name = "jax"

    def __init__(self):
        _load_jax()
        self._takes_cache: dict = {}

    def _prep(self, takes):
        key = id(takes)
        ent = self._takes_cache.get(key)
        if ent is None or ent[0] is not takes:
            shifts = tuple(int(s) for s, _ in takes)
            masks = tuple(np.ascontiguousarray(m[:, 0]) for _, m in takes)
            ent = self._takes_cache[key] = (takes, shifts, masks)
        return ent[1], ent[2]

    def maxplus_scan(self, D, T, takes):
        shifts, masks = self._prep(takes)
        shape = T.shape
        if D.shape != shape:
            D = np.broadcast_to(D, shape)
        if T.ndim != 2:
            D = np.ascontiguousarray(D).reshape(shape[0], -1)
            T = np.ascontiguousarray(T).reshape(shape[0], -1)
        # scoped x64: the ≤1e-9 contract needs float64, but the flag must
        # not leak to other jax users in the process (the x64 state keys
        # the jit cache, so scoping is sound)
        with _enable_x64():
            Dj, Tj = _maxplus_kernel(shifts)(D, T, masks)
            return (np.asarray(Dj).reshape(shape),
                    np.asarray(Tj).reshape(shape))

    def running_max(self, v, takes):
        shifts, masks = self._prep(takes)
        shape = v.shape
        if v.ndim != 2:
            v = np.ascontiguousarray(v).reshape(shape[0], -1)
        with _enable_x64():
            out = _running_max_kernel(shifts)(v, masks)
            return np.asarray(out).reshape(shape)


#: the default engine instance (module-level: every compiled program
#: shares it, and ``resolve_engine(None)`` is an attribute read)
NUMPY = NumpyScanEngine()

_engines: dict = {"numpy": NUMPY}


def available_engines() -> list[str]:
    """Engine names usable in this environment (``jax`` only when the
    optional dependency imports)."""
    names = ["numpy"]
    try:
        _load_jax()
    except RuntimeError:
        pass
    else:
        names.append("jax")
    return names


def get_scan_engine(name: str = "numpy"):
    """The shared engine instance for ``name``.  Raises ``ValueError``
    for unknown names and ``RuntimeError`` when ``"jax"`` is requested
    without jax installed."""
    eng = _engines.get(name)
    if eng is None:
        if name != "jax":
            raise ValueError(f"unknown scan engine {name!r}; "
                             f"options: ['jax', 'numpy']")
        eng = _engines["jax"] = JaxScanEngine()
    return eng


def resolve_engine(engine):
    """Normalize a per-call ``engine=`` argument: ``None`` → the numpy
    default, a name → the shared instance, an engine object → itself."""
    if engine is None:
        return NUMPY
    if isinstance(engine, str):
        return get_scan_engine(engine)
    if hasattr(engine, "maxplus_scan") and hasattr(engine, "running_max"):
        return engine
    raise ValueError(f"not a scan engine: {engine!r} (pass 'numpy', "
                     f"'jax', or an object with maxplus_scan/running_max)")
