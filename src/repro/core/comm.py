"""CommPolicy: message-size-aware collective strategy selection.

The ExaNet-MPI runtime switches transports at 32 B: packetizer/mailbox
(latency-optimal, "eager") below, RDMA rendez-vous (bandwidth-optimal)
above (§5.2.1). The transferable idea is an alpha-beta crossover: pick the
algorithm by comparing startup-dominated vs wire-dominated cost.

On TPU the same split appears in gradient synchronization:
* tiny tensors (norm scales, biases) -> fuse into one bucket, single
  all-reduce (the "eager" path: pay alpha once);
* bulk tensors -> reduce-scatter + all-gather pipeline, hierarchical across
  pods (the "rendez-vous" path: pay bandwidth, hide alpha).

Constants are TPU v5e (roofline/hw.py); the policy exposes the predicted
cost of each choice so EXPERIMENTS.md can show the napkin math.
"""

from __future__ import annotations

import dataclasses

from repro.roofline.hw import V5E


@dataclasses.dataclass(frozen=True)
class CommPolicy:
    #: per-collective launch/latency cost (alpha) in seconds; ICI hop-scale
    alpha_s: float = 2e-6
    #: cross-pod (DCN) alpha is orders of magnitude worse
    alpha_pod_s: float = 5e-5
    #: ICI per-link bandwidth (beta), bytes/s
    ici_bw: float = V5E.ici_link_bw
    #: cross-pod per-chip bandwidth, bytes/s
    dcn_bw: float = V5E.dcn_bw
    #: bucket target: amortize alpha to <2% of wire time
    alpha_amortization: float = 0.02

    def ring_allreduce_s(self, n_bytes: int, p: int, bw: float,
                         alpha: float) -> float:
        if p <= 1:
            return 0.0
        return 2 * (p - 1) * alpha + 2 * (p - 1) / p * n_bytes / bw

    def schedule_allreduce_s(self, n_bytes: int, p: int, bw: float,
                             alpha: float, *, algo: str = "ring") -> float:
        """Alpha-beta cost of an allreduce derived from the *schedule* that
        the ExaNet event engine executes (repro.core.exanet.schedules), not
        from a hand-written closed form.  For ``algo="ring"`` this coincides
        with :meth:`ring_allreduce_s` whenever ``p`` divides ``n_bytes``;
        ``"rabenseifner"`` and ``"recursive_doubling"`` come for free but
        require power-of-two ``p`` (ValueError otherwise)."""
        from repro.core.exanet.schedules import (ALLREDUCE_SCHEDULES,
                                                 alpha_beta_cost_s)
        if p <= 1:
            return 0.0
        sched = ALLREDUCE_SCHEDULES[algo]()
        return alpha_beta_cost_s(sched, p, n_bytes, alpha_s=alpha,
                                 bw_bytes_per_s=bw)

    def oneshot_allreduce_s(self, n_bytes: int, p: int, bw: float,
                            alpha: float) -> float:
        """all-gather everything + local reduce: 1 phase, alpha-cheap,
        bandwidth-expensive (the packetizer analog)."""
        if p <= 1:
            return 0.0
        return alpha + (p - 1) * n_bytes / bw

    def eager_threshold_bytes(self, p: int, *, bw: float | None = None,
                              alpha: float | None = None) -> int:
        """Crossover size below which the one-shot schedule wins — the
        TPU re-derivation of the paper's 32 B eager threshold."""
        bw = bw or self.ici_bw
        alpha = alpha or self.alpha_s
        lo, hi = 1, 1 << 32
        while lo < hi:
            mid = (lo + hi) // 2
            if self.oneshot_allreduce_s(mid, p, bw, alpha) <= \
                    self.ring_allreduce_s(mid, p, bw, alpha):
                lo = mid + 1
            else:
                hi = mid
        return lo

    def bucket_bytes(self, p: int) -> int:
        """Gradient bucket size so the 2(p-1) alpha terms cost <=2% of wire
        time (the cell/bucket adaptation of §4.2's small-MTU trade-off)."""
        alpha_total = 2 * (p - 1) * self.alpha_s
        wire_per_byte = 2 * (p - 1) / p / self.ici_bw
        return int(alpha_total / self.alpha_amortization / wire_per_byte)

    def choose(self, n_bytes: int, p: int) -> str:
        return ("eager" if n_bytes <= self.eager_threshold_bytes(p)
                else "rendezvous")
