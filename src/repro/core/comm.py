"""CommPolicy: message-size-aware collective strategy selection.

The ExaNet-MPI runtime switches transports at 32 B: packetizer/mailbox
(latency-optimal, "eager") below, RDMA rendez-vous (bandwidth-optimal)
above (§5.2.1). The transferable idea is an alpha-beta crossover: pick the
algorithm by comparing startup-dominated vs wire-dominated cost.

On TPU the same split appears in gradient synchronization:
* tiny tensors (norm scales, biases) -> fuse into one bucket, single
  all-reduce (the "eager" path: pay alpha once);
* bulk tensors -> reduce-scatter + all-gather pipeline, hierarchical across
  pods (the "rendez-vous" path: pay bandwidth, hide alpha).

Since the MachineModel/CollectivePlanner split (DESIGN.md §3.5) this class
is a thin facade: its alpha/beta knobs instantiate a
:class:`repro.core.machine.TpuMachine`, its crossovers come from
:mod:`repro.core.planner` cost functions over that machine, and its
:attr:`planner` is what ``grad_sync``'s ``strategy="auto"`` consults per
bucket. The closed-form numbers are unchanged; they just live in one place.
"""

from __future__ import annotations

import dataclasses
import functools

from repro.core.machine import INTRA, TpuMachine
from repro.core.planner import (CollectivePlanner, Plan, crossover_bytes,
                                oneshot_cost_s, ring_cost_s)
from repro.roofline.hw import V5E


@dataclasses.dataclass(frozen=True)
class CommPolicy:
    #: per-collective launch/latency cost (alpha) in seconds; ICI hop-scale
    alpha_s: float = 2e-6
    #: cross-pod (DCN) alpha is orders of magnitude worse
    alpha_pod_s: float = 5e-5
    #: ICI per-link bandwidth (beta), bytes/s
    ici_bw: float = V5E.ici_link_bw
    #: cross-pod per-chip bandwidth, bytes/s
    dcn_bw: float = V5E.dcn_bw
    #: bucket target: amortize alpha to <2% of wire time
    alpha_amortization: float = 0.02

    @functools.cached_property
    def machine(self) -> TpuMachine:
        """The machine model these knobs describe (the planner's backend)."""
        return TpuMachine(alpha_s=self.alpha_s, alpha_pod_s=self.alpha_pod_s,
                          ici_bw=self.ici_bw, dcn_bw=self.dcn_bw)

    @functools.cached_property
    def planner(self) -> CollectivePlanner:
        """Cost-driven schedule selection over :attr:`machine`; consulted by
        ``grad_sync``'s ``strategy="auto"`` and ``benchmarks/planner_sweep``."""
        return CollectivePlanner(self.machine, fidelity="analytic")

    def ring_allreduce_s(self, n_bytes: int, p: int, bw: float,
                         alpha: float) -> float:
        return ring_cost_s(n_bytes, p, bw, alpha)

    def schedule_allreduce_s(self, n_bytes: int, p: int, bw: float,
                             alpha: float, *, algo: str = "ring") -> float:
        """Alpha-beta cost of an allreduce derived from the *schedule* that
        the ExaNet event engine executes (repro.core.exanet.schedules), not
        from a hand-written closed form.  For ``algo="ring"`` this coincides
        with :meth:`ring_allreduce_s` whenever ``p`` divides ``n_bytes``;
        ``"rabenseifner"`` and ``"recursive_doubling"`` come for free but
        require power-of-two ``p`` (ValueError otherwise)."""
        from repro.core.exanet.schedules import (ALLREDUCE_SCHEDULES,
                                                 alpha_beta_cost_s)
        if p <= 1:
            return 0.0
        sched = ALLREDUCE_SCHEDULES[algo]()
        return alpha_beta_cost_s(sched, p, n_bytes, alpha_s=alpha,
                                 bw_bytes_per_s=bw)

    def oneshot_allreduce_s(self, n_bytes: int, p: int, bw: float,
                            alpha: float) -> float:
        """all-gather everything + local reduce: 1 phase, alpha-cheap,
        bandwidth-expensive (the packetizer analog)."""
        return oneshot_cost_s(n_bytes, p, bw, alpha)

    def eager_threshold_bytes(self, p: int, *, bw: float | None = None,
                              alpha: float | None = None) -> int:
        """Crossover size below which the one-shot schedule wins — the
        TPU re-derivation of the paper's 32 B eager threshold (bisected by
        :func:`repro.core.planner.crossover_bytes` over the machine's
        one-shot/ring cost pair)."""
        bw = bw or self.ici_bw
        alpha = alpha or self.alpha_s
        return crossover_bytes(
            lambda n: oneshot_cost_s(n, p, bw, alpha),
            lambda n: ring_cost_s(n, p, bw, alpha))

    def bucket_bytes(self, p: int) -> int:
        """Gradient bucket size so the 2(p-1) alpha terms cost <=2% of wire
        time (the cell/bucket adaptation of §4.2's small-MTU trade-off)."""
        alpha, bw = self.machine.alpha_beta(INTRA)
        alpha_total = 2 * (p - 1) * alpha
        wire_per_byte = 2 * (p - 1) / p / bw
        return int(alpha_total / self.alpha_amortization / wire_per_byte)

    def choose(self, n_bytes: int, p: int) -> str:
        return ("eager" if n_bytes <= self.eager_threshold_bytes(p)
                else "rendezvous")

    def plan_bucket(self, n_bytes: int, intra: int, inter: int = 1,
                    *, allow_lossy: bool = False) -> Plan:
        """Planner-chosen gradient-sync strategy for one bucket (the
        ``strategy="auto"`` entry point of ``parallel/grad_sync``).
        ``allow_lossy=False`` restricts the candidates to exact syncs."""
        return self.planner.plan("grad_sync", n_bytes, (intra, inter),
                                 allow_lossy=allow_lossy)
