"""Program IR: per-rank communication/compute programs (DESIGN.md §2.6).

A :class:`Program` is what an application *does* per iteration, expressed as
one op sequence per rank:

* :class:`Compute` — local work in microseconds (occupies the rank's core);
* :class:`Isend` / :class:`Irecv` — nonblocking tagged point-to-point
  (matched FIFO per (src, dst, tag) channel, like MPI);
* :class:`Wait` — block until named requests (or all outstanding ones)
  complete;
* :class:`Collective` — an embedded collective over all program ranks,
  executed by schedule (``algo="auto"`` lets the
  :class:`repro.core.planner.CollectivePlanner` pick it by cost).

The IR is pure structure: no link rates, no engine, no jax — the same
split that keeps :mod:`repro.core.exanet.schedules` hardware-free.  Two
executors share it:

* :class:`ProgramExecutor` here is the *scheduler* (per-rank clocks, FIFO
  message matching, waits, collective barriers, deadlock detection) over
  pluggable cost hooks;
* :meth:`repro.core.exanet.mpi.ExanetMPI.run_program` binds the hooks to
  the discrete-event engine, so independent flows from every rank contend
  on the shared R5/DMA/link resources — full-machine halo congestion is
  *simulated*, not modeled;
* :func:`analytic_hooks` binds them to closed-form alpha-beta costs (no
  contention) — the reference the sim is compared against, and the TPU
  machine's only fidelity.

Builders for the common shapes live here too: :func:`halo3d` (nearest-
neighbour 3-D halo exchange), :func:`cg_iteration` (halo + SpMV compute +
dot-product allreduces), :func:`bsp_step` (compute + one collective).
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Callable, Iterator, Sequence, Union


class ProgramError(Exception):
    """Malformed program: bad rank ids, reused handles, size-mismatched
    matches, or unmatched sends/recvs at program exit."""


class ProgramDeadlockError(ProgramError):
    """No rank can make progress: a Wait on a request whose peer never
    posts (mismatched tag/peer), or a Collective some ranks never reach."""


# ------------------------------------------------------------------- the IR
@dataclasses.dataclass(frozen=True)
class Compute:
    """Local work for ``us`` microseconds on the rank's core."""
    us: float


@dataclasses.dataclass(frozen=True)
class Isend:
    """Nonblocking tagged send of ``nbytes`` to rank ``dst``."""
    dst: int
    nbytes: int
    tag: int = 0
    handle: str | None = None   # name for a selective Wait; None = anonymous


@dataclasses.dataclass(frozen=True)
class Irecv:
    """Nonblocking tagged receive of ``nbytes`` from rank ``src``."""
    src: int
    nbytes: int
    tag: int = 0
    handle: str | None = None


@dataclasses.dataclass(frozen=True)
class Wait:
    """Block until the named requests complete; ``handles=None`` waits on
    every outstanding request of the rank (MPI_Waitall)."""
    handles: tuple[str, ...] | None = None


@dataclasses.dataclass(frozen=True)
class Collective:
    """An embedded collective over all program ranks.  ``algo="auto"``
    defers schedule choice to the planner (allreduce only; other ops fall
    back to their single shipped schedule).

    With ``handle=None`` the collective is a full barrier: every rank's
    clock advances to its exit.  With a handle it is *nonblocking*
    (MPI_Iallreduce): each rank records its entry clock and keeps
    executing — the transfer progresses off the core (NI/DMA-driven) —
    and a later :class:`Wait` on the handle (or ``Wait()``) joins the
    rank's exit clock.  This is the seam that lets backward/sync overlap
    in a training step be *emergent* rather than assumed: the exit clocks
    still come from the full schedule replay on the shared engine
    resources, only the rank cores stop standing still."""
    op: str                 # "allreduce" | "bcast" | "allgather" | ...
    nbytes: int
    algo: str = "auto"
    handle: str | None = None   # None = blocking barrier semantics


Op = Union[Compute, Isend, Irecv, Wait, Collective]


@dataclasses.dataclass(frozen=True)
class Program:
    """One op sequence per rank (SPMD programs repeat the same shape)."""
    rank_ops: tuple[tuple[Op, ...], ...]

    @property
    def nranks(self) -> int:
        return len(self.rank_ops)

    def collectives(self) -> list[Collective]:
        """Unique Collective sites, in first-appearance order across ranks
        (what :meth:`CollectivePlanner.plan_program` plans in one pass)."""
        seen: dict[tuple, Collective] = {}
        for ops in self.rank_ops:
            for op in ops:
                if isinstance(op, Collective):
                    seen.setdefault((op.op, op.nbytes, op.algo), op)
        return list(seen.values())

    def compute_us(self, rank: int) -> float:
        """Total Compute microseconds of one rank (contention-free lower
        bound; per-rank cores never contend, so this is also the simulated
        compute time)."""
        return sum(op.us for op in self.rank_ops[rank]
                   if isinstance(op, Compute))

    def counts(self) -> dict[str, int]:
        c: dict[str, int] = {}
        for ops in self.rank_ops:
            for op in ops:
                k = type(op).__name__.lower()
                c[k] = c.get(k, 0) + 1
        return c

    def structure_key(self) -> tuple:
        """Hashable fingerprint of the program's *structure*: op kinds,
        peers, tags, handles and collective (op, algo) — everything except
        the bindable payload data (``Compute.us``, ``Isend``/``Irecv``/
        ``Collective.nbytes``).  Two programs with equal keys have
        identical FIFO channel matchings, wait sets and collective sites,
        so a compiled execution artifact
        (:mod:`repro.core.exanet.program_compiled`) lowered for one can be
        re-bound with the other's sizes — the Program analog of
        ``RoundProgram``'s per-(schedule, nranks) cache key."""
        sig = []
        for ops in self.rank_ops:
            row = []
            for op in ops:
                if isinstance(op, Compute):
                    row.append(("c",))
                elif isinstance(op, Isend):
                    row.append(("s", op.dst, op.tag, op.handle))
                elif isinstance(op, Irecv):
                    row.append(("r", op.src, op.tag, op.handle))
                elif isinstance(op, Wait):
                    row.append(("w", op.handles))
                elif isinstance(op, Collective):
                    row.append(("x", op.op, op.algo, op.handle))
                else:
                    row.append(("?", repr(op)))
            sig.append(tuple(row))
        return (self.nranks, tuple(sig))

    def validate(self) -> None:
        n = self.nranks
        for r, ops in enumerate(self.rank_ops):
            for op in ops:
                if isinstance(op, Isend) and not 0 <= op.dst < n:
                    raise ProgramError(f"rank {r}: Isend dst {op.dst} "
                                       f"outside [0, {n})")
                if isinstance(op, Irecv) and not 0 <= op.src < n:
                    raise ProgramError(f"rank {r}: Irecv src {op.src} "
                                       f"outside [0, {n})")
                if isinstance(op, (Isend, Irecv)) and op.nbytes < 0:
                    raise ProgramError(f"rank {r}: negative nbytes")


# ---------------------------------------------------------------- builders
def balanced_grid3(n: int) -> tuple[int, int, int]:
    """Balanced 3-D process grid of ``n`` ranks (largest factors last) —
    the block decomposition HPCG/miniFE/LAMMPS all use."""
    best = (n, 1, 1)
    score = float("inf")
    for px in range(1, n + 1):
        if n % px:
            continue
        rem = n // px
        for py in range(1, rem + 1):
            if rem % py:
                continue
            pz = rem // py
            s = max(px, py, pz) / min(px, py, pz)
            if s < score:
                score, best = s, (px, py, pz)
    return best


def _halo_neighbors(rank: int, grid: tuple[int, int, int]
                    ) -> list[tuple[int, int]]:
    """(neighbor_rank, face_index) of the up-to-6 periodic face neighbours.
    face_index = 2*dim + (0 for +, 1 for -), from the *sender's* view."""
    px, py, pz = grid
    x, y, z = rank % px, (rank // px) % py, rank // (px * py)
    out = []
    for dim, (c, extent) in enumerate(((x, px), (y, py), (z, pz))):
        if extent == 1:
            continue  # periodic self-neighbour: no message
        for face, step in ((0, 1), (1, -1)):
            cc = (c + step) % extent
            coords = [x, y, z]
            coords[dim] = cc
            nb = coords[0] + px * (coords[1] + py * coords[2])
            out.append((nb, 2 * dim + face))
    return out


def halo3d(nranks: int, face_bytes: int, compute_us: float = 0.0, *,
           grid: tuple[int, int, int] | None = None,
           overlap: bool = False) -> Program:
    """One BSP step of a 3-D halo exchange: every rank posts receives for
    its (up to) 6 faces, sends its 6 faces, then computes.  With
    ``overlap=True`` the compute is issued *between* the sends and the
    Wait, so it hides communication up to the critical path (the paper's
    codes under MPICH do not overlap; the option exists for the IR's
    overlap semantics and their tests).

    A face sent in direction +x carries tag 0; the receiver (our +x
    neighbour) posts tag 0 from us — tags pair the six faces even when two
    ranks exchange more than one face (e.g. 2-rank periodic grids).
    """
    grid = grid or balanced_grid3(nranks)
    if grid[0] * grid[1] * grid[2] != nranks:
        raise ProgramError(f"grid {grid} does not tile {nranks} ranks")
    ranks = []
    for r in range(nranks):
        ops: list[Op] = []
        for nb, face in _halo_neighbors(r, grid):
            # the message we receive from neighbour `nb` is the one *they*
            # sent toward us: their face index, which is ours with the
            # +/- bit flipped in the same dimension
            ops.append(Irecv(src=nb, nbytes=face_bytes, tag=face ^ 1))
        for nb, face in _halo_neighbors(r, grid):
            ops.append(Isend(dst=nb, nbytes=face_bytes, tag=face))
        if overlap and compute_us > 0.0:
            ops.append(Compute(compute_us))
        ops.append(Wait())
        if not overlap and compute_us > 0.0:
            ops.append(Compute(compute_us))
        ranks.append(tuple(ops))
    return Program(tuple(ranks))


def cg_iteration(nranks: int, face_bytes: int, compute_us: float, *,
                 n_dots: int = 2, dot_bytes: int = 8,
                 coll_algo: str = "auto",
                 grid: tuple[int, int, int] | None = None,
                 overlap: bool = False) -> Program:
    """One CG-style iteration: halo exchange + SpMV/smoother compute +
    ``n_dots`` dot-product allreduces of ``dot_bytes`` each — the
    iteration shape of HPCG and miniFE (§6.2)."""
    halo = halo3d(nranks, face_bytes, compute_us, grid=grid,
                  overlap=overlap)
    dots = tuple(Collective("allreduce", dot_bytes, coll_algo)
                 for _ in range(n_dots))
    return Program(tuple(ops + dots for ops in halo.rank_ops))


def bsp_step(nranks: int, compute_us: float, coll_op: str = "allreduce",
             coll_bytes: int = 0, *, coll_algo: str = "auto") -> Program:
    """Plain bulk-synchronous step: compute then one collective."""
    ops: tuple[Op, ...] = (Compute(compute_us),)
    if coll_bytes or coll_op == "barrier":
        ops += (Collective(coll_op, coll_bytes, coll_algo),)
    return Program(tuple(ops for _ in range(nranks)))


# -------------------------------------------------------------- the runner
@dataclasses.dataclass(frozen=True)
class ProgramResult:
    """Outcome of one program execution."""
    latency_us: float            # completion time of the slowest rank
    clocks: tuple[float, ...]    # per-rank completion times
    compute_us: tuple[float, ...]  # per-rank total Compute time
    n_sends: int
    n_collectives: int

    @property
    def comm_us(self) -> float:
        """Communication on the critical path: what the iteration pays on
        top of the slowest rank's pure compute.  This is the quantity the
        retired closed-form ``alpha`` used to multiply."""
        return self.latency_us - max(self.compute_us, default=0.0)


@dataclasses.dataclass(eq=False)  # identity semantics: two posts are two
class _Req:                       # requests even with identical fields
    rank: int
    peer: int
    nbytes: int
    tag: int
    is_send: bool
    t_post: float
    t_done: float | None = None


class ProgramExecutor:
    """Event-driven scheduler of a :class:`Program` over cost hooks.

    Hooks (all times in microseconds):

    * ``compute(rank, us, t) -> t_end`` — local work;
    * ``p2p(src, dst, nbytes, tag, t_send, t_recv) -> (t_send_done,
      t_recv_done)`` — one matched point-to-point transfer, called at
      match time (eager transfers depart at ``t_send``, rendez-vous ones
      cannot start before ``max(t_send, t_recv)`` — the hook decides);
    * ``collective(op, nbytes, algo, enters) -> exits`` — per-rank entry
      clocks to per-rank exit clocks.

    Ranks advance one op per scheduling step, always the rank with the
    smallest clock first, so shared-resource hooks see sends in near
    global-time order (exact time ordering is the hooks' concern; the
    engine's ``Resource.acquire`` serializes whatever order it is called
    in).  Execution is deterministic: ties break by rank id.
    """

    def __init__(self, prog: Program, *,
                 compute: Callable[[int, float, float], float],
                 p2p: Callable[..., tuple[float, float]],
                 collective: Callable[..., list[float]],
                 post_overhead_us: float = 0.0):
        prog.validate()
        self.prog = prog
        self._compute = compute
        self._p2p = p2p
        self._collective = collective
        #: local CPU cost of posting one Isend/Irecv (descriptor write /
        #: request setup) charged on the poster's clock
        self.post_overhead_us = post_overhead_us

    # ------------------------------------------------------------- matching
    def _match(self, send: _Req, recv: _Req) -> None:
        if send.nbytes != recv.nbytes:
            raise ProgramError(
                f"size mismatch on channel ({send.rank}->{send.peer}, "
                f"tag {send.tag}): Isend {send.nbytes} B vs Irecv "
                f"{recv.nbytes} B")
        send.t_done, recv.t_done = self._p2p(
            send.rank, recv.rank, send.nbytes, send.tag,
            send.t_post, recv.t_post)
        self._n_sends += 1

    def run(self, t0: float | Sequence[float] = 0.0) -> ProgramResult:
        prog = self.prog
        n = prog.nranks
        if hasattr(t0, "__len__"):
            t0s = [float(v) for v in t0]
            if len(t0s) != n:
                raise ProgramError(
                    f"t0 has {len(t0s)} entries for {n} ranks")
        else:
            t0s = [float(t0)] * n
        clock = list(t0s)
        pc = [0] * n
        compute_tot = [0.0] * n
        self._n_sends = 0
        n_coll = 0
        # FIFO channels of unmatched posts, keyed (src, dst, tag)
        sends: dict[tuple, deque] = {}
        recvs: dict[tuple, deque] = {}
        # per-rank outstanding requests; named handles point into it
        outstanding: dict[int, list[_Req]] = {r: [] for r in range(n)}
        named: dict[tuple[int, str], _Req] = {}
        # blocked ranks: rank -> ("wait", [reqs]) | ("coll", site_key)
        blocked: dict[int, tuple] = {}
        coll_idx = [0] * n
        barriers: dict[int, dict[int, float]] = {}
        # nonblocking collective sites: site -> {rank: pseudo-request}
        coll_reqs: dict[int, dict[int, _Req]] = {}
        ready = [(t0s[r], r) for r in range(n) if prog.rank_ops[r]]
        heapq.heapify(ready)

        def wake_waiters() -> None:
            for r in [r for r, b in blocked.items() if b[0] == "wait"]:
                reqs = blocked[r][1]
                if all(q.t_done is not None for q in reqs):
                    del blocked[r]
                    clock[r] = max([clock[r]] + [q.t_done for q in reqs])
                    heapq.heappush(ready, (clock[r], r))

        while ready:
            _, r = heapq.heappop(ready)
            if r in blocked or pc[r] >= len(prog.rank_ops[r]):
                continue
            op = prog.rank_ops[r][pc[r]]
            pc[r] += 1
            if isinstance(op, Compute):
                t_end = self._compute(r, op.us, clock[r])
                compute_tot[r] += op.us
                clock[r] = t_end
            elif isinstance(op, (Isend, Irecv)):
                is_send = isinstance(op, Isend)
                peer = op.dst if is_send else op.src
                key = (r, peer, op.tag) if is_send else (peer, r, op.tag)
                req = _Req(r, peer, op.nbytes, op.tag, is_send, clock[r])
                clock[r] += self.post_overhead_us
                outstanding[r].append(req)
                if op.handle is not None:
                    if (r, op.handle) in named:
                        raise ProgramError(
                            f"rank {r}: handle {op.handle!r} reused while "
                            f"still outstanding")
                    named[(r, op.handle)] = req
                mine, theirs = (sends, recvs) if is_send else (recvs, sends)
                q = theirs.get(key)
                if q:
                    other = q.popleft()
                    self._match(req if is_send else other,
                                other if is_send else req)
                    wake_waiters()
                else:
                    mine.setdefault(key, deque()).append(req)
            elif isinstance(op, Wait):
                if op.handles is None:
                    reqs = outstanding[r]
                else:
                    try:
                        reqs = [named[(r, h)] for h in op.handles]
                    except KeyError as e:
                        raise ProgramError(
                            f"rank {r}: Wait on unknown handle {e}") from e
                if all(q.t_done is not None for q in reqs):
                    clock[r] = max([clock[r]] + [q.t_done for q in reqs])
                else:
                    blocked[r] = ("wait", list(reqs))
                # consume: a waited request cannot be waited on again
                outstanding[r] = [q for q in outstanding[r] if q not in reqs]
                for q in reqs:
                    for h, v in list(named.items()):
                        if v is q:
                            del named[h]
            elif isinstance(op, Collective):
                site = coll_idx[r]
                coll_idx[r] += 1
                sig = (op.op, op.nbytes, op.algo, op.handle)
                bar, first = barriers.setdefault(site, ({}, sig))
                if sig != first:
                    raise ProgramError(
                        f"collective mismatch at site #{site}: rank {r} "
                        f"calls {sig}, another rank called {first} — "
                        f"ranks must reach matching collectives in the "
                        f"same order")
                bar[r] = clock[r]
                if op.handle is not None:
                    # nonblocking: register a pseudo-request per rank (the
                    # entry pays the same local post cost as an Isend) and
                    # keep executing; the hook fires on last arrival and
                    # completes the requests without touching clocks.
                    req = _Req(r, -1, op.nbytes, -1, False, clock[r])
                    clock[r] += self.post_overhead_us
                    outstanding[r].append(req)
                    if (r, op.handle) in named:
                        raise ProgramError(
                            f"rank {r}: handle {op.handle!r} reused while "
                            f"still outstanding")
                    named[(r, op.handle)] = req
                    coll_reqs.setdefault(site, {})[r] = req
                    if len(bar) == n:
                        enters = [bar[i] for i in range(n)]
                        exits = self._collective(op.op, op.nbytes, op.algo,
                                                 enters)
                        n_coll += 1
                        del barriers[site]
                        for i, q in coll_reqs.pop(site).items():
                            q.t_done = exits[i]
                        wake_waiters()
                elif len(bar) == n:
                    enters = [bar[i] for i in range(n)]
                    exits = self._collective(op.op, op.nbytes, op.algo,
                                             enters)
                    n_coll += 1
                    del barriers[site]
                    for i in range(n):
                        clock[i] = exits[i]
                        if i != r and blocked.get(i, (None,))[0] == "coll":
                            del blocked[i]
                            heapq.heappush(ready, (clock[i], i))
                else:
                    blocked[r] = ("coll", site)
            else:
                raise ProgramError(f"rank {r}: unknown op {op!r}")
            if r not in blocked and pc[r] < len(prog.rank_ops[r]):
                heapq.heappush(ready, (clock[r], r))

        unfinished = [r for r in range(n)
                      if r in blocked or pc[r] < len(prog.rank_ops[r])]
        if unfinished:
            raise ProgramDeadlockError(self._diagnose(blocked, unfinished,
                                                      sends, recvs))
        dangling = [q for qs in list(sends.values()) + list(recvs.values())
                    for q in qs]
        if dangling:
            d = dangling[0]
            kind = "Isend" if d.is_send else "Irecv"
            raise ProgramError(
                f"program completed with {len(dangling)} unmatched "
                f"request(s); first: rank {d.rank} {kind} peer={d.peer} "
                f"tag={d.tag} ({d.nbytes} B)")
        return ProgramResult(max(clock) if clock else max(t0s, default=0.0),
                             tuple(clock),
                             tuple(compute_tot), self._n_sends, n_coll)

    @staticmethod
    def _diagnose(blocked: dict, unfinished: list, sends: dict,
                  recvs: dict) -> str:
        parts = [f"deadlock: {len(unfinished)} rank(s) cannot progress"]
        for r in unfinished[:8]:
            b = blocked.get(r)
            if b is None:
                parts.append(f"  rank {r}: never scheduled")
            elif b[0] == "coll":
                parts.append(f"  rank {r}: in collective barrier #{b[1]} "
                             f"other ranks never reach")
            else:
                pend = [q for q in b[1] if q.t_done is None]
                what = ", ".join(
                    f"Collective({q.nbytes} B) some ranks never post"
                    if q.peer < 0 else
                    f"{'Isend' if q.is_send else 'Irecv'}(peer={q.peer}, "
                    f"tag={q.tag}, {q.nbytes} B)" for q in pend[:4])
                parts.append(f"  rank {r}: Wait on unmatched {what}")
        un = sum(len(q) for q in sends.values())
        ur = sum(len(q) for q in recvs.values())
        parts.append(f"  unmatched posts: {un} send(s), {ur} recv(s) — "
                     f"check (src, dst, tag) pairing")
        return "\n".join(parts)


# ------------------------------------------------------- closed-form hooks
def analytic_hooks(alpha_us: float, bw_bytes_per_us: float,
                   coll_cost_us: Callable[[str, int, str], float]) -> dict:
    """Contention-free alpha-beta hooks: a point-to-point message costs
    ``alpha + nbytes/bw`` after both sides are ready; compute is exact;
    collectives are barrier + ``coll_cost_us(op, nbytes, algo)``.  This is
    the closed-form reference the event-engine execution is validated
    against in the no-contention limit, and the only fidelity machines
    without an event simulator (the TPU target) have."""

    def compute(rank: int, us: float, t: float) -> float:
        return t + us

    def p2p(src: int, dst: int, nbytes: int, tag: int,
            t_send: float, t_recv: float) -> tuple[float, float]:
        done = max(t_send, t_recv) + alpha_us + nbytes / bw_bytes_per_us
        return t_send + alpha_us, done

    def collective(op: str, nbytes: int, algo: str,
                   enters: list[float]) -> list[float]:
        t = max(enters) + coll_cost_us(op, nbytes, algo)
        return [t] * len(enters)

    return {"compute": compute, "p2p": p2p, "collective": collective}


def analytic_program_us(prog: Program, *, alpha_us: float,
                        bw_bytes_per_us: float,
                        coll_cost_us: Callable[[str, int, str], float]
                        ) -> ProgramResult:
    """Closed-form program time (microseconds): the :func:`analytic_hooks`
    semantics run through the same scheduler as the event engine."""
    return ProgramExecutor(prog, **analytic_hooks(
        alpha_us, bw_bytes_per_us, coll_cost_us)).run()


def rounds_iter(prog: Program) -> Iterator[Op]:
    """Flat op iterator (debug/introspection helper)."""
    for ops in prog.rank_ops:
        yield from ops
