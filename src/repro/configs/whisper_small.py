"""Whisper-small [arXiv:2212.04356]: enc-dec, 12+12L, d_model 768, 12H,
d_ff 3072, vocab 51865; conv frontend is a STUB (precomputed frame
embeddings, 1500 frames)."""
from repro.config import ArchConfig, EncDecConfig

ARCH = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64,
    mlp_act="gelu", mlp_gated=False, norm="layernorm",
    pos_embedding="learned", attn_bias=True,
    encdec=EncDecConfig(n_encoder_layers=12, encoder_seq=1500),
)
