"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01]: 40L, d_model 8192,
64H (GQA kv=8... v01 uses MHA-like 64/64; assignment says kv=8), d_ff 22528,
no biases, 256k vocab."""
from repro.config import ArchConfig

ARCH = ArchConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22528, vocab_size=256000, head_dim=128,
    rope_theta=8e6, mlp_act="silu", mlp_gated=True,
    norm="layernorm", tie_embeddings=True,
)
