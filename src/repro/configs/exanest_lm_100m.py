"""The repo's own end-to-end example config: a ~100M-param dense LM sized
for the examples/train_lm.py driver (CPU-runnable training for a few
hundred steps)."""
from repro.config import ArchConfig

ARCH = ArchConfig(
    name="exanest-lm-100m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=2048, vocab_size=32000, head_dim=64,
    rope_theta=10000.0, mlp_act="silu", mlp_gated=True,
    q_chunk=256, kv_chunk=256,
)
