"""InternVL2-1B [arXiv:2404.16821]: InternViT-300M frontend (STUB: the
assignment provides precomputed patch embeddings) + Qwen2-0.5B LM backbone:
24L, d_model 896, 14H (GQA kv=2), d_ff 4864, vocab 151655."""
from repro.config import ArchConfig, VisionStubConfig

ARCH = ArchConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab_size=151655, head_dim=64,
    rope_theta=1e6, attn_bias=True,  # Qwen2 uses QKV bias
    mlp_act="silu", mlp_gated=True,
    vision=VisionStubConfig(n_patches=256),
)
