"""IBM Granite 3.0 1B-A400M base [hf:ibm-granite/granite-3.0-1b-a400m-base]:
24L, d_model 1024, 16H (GQA kv=8), 32 experts top-8, expert FFN 512."""
from repro.config import ArchConfig, MoEConfig

ARCH = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab_size=49155,
    moe=MoEConfig(n_experts=32, top_k=8, d_expert=512),
    n_dense_layers=0,
    rope_theta=10000.0, mlp_act="silu", mlp_gated=True,
)
