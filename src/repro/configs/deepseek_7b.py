"""DeepSeek LLM 7B [arXiv:2401.02954]: llama-arch, 30L, d_model 4096,
32H MHA (kv=32), d_ff 11008, vocab 102400."""
from repro.config import ArchConfig

ARCH = ArchConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab_size=102400, head_dim=128,
    rope_theta=10000.0, mlp_act="silu", mlp_gated=True,
)
