"""DeepSeek-V3 671B [arXiv:2412.19437]: MLA, 1 shared + 256 routed top-8
experts, MTP. 61 layers (first 3 dense, d_ff 18432), d_model 7168,
128 attention heads, expert FFN 2048, vocab 129280."""
from repro.config import ArchConfig, MLAConfig, MoEConfig

ARCH = ArchConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab_size=129280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048,
                  n_shared_experts=1, d_shared=2048,
                  router_softmax=False),  # V3 uses sigmoid routing
    n_dense_layers=3, mtp_depth=1,
    rope_theta=10000.0, mlp_act="silu", mlp_gated=True,
)
