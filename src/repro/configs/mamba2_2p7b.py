"""Mamba-2 2.7B [arXiv:2405.21060]: 64L, d_model 2560, attention-free SSD,
d_state 128, headdim 64 (80 heads at expand=2), vocab 50280."""
from repro.config import ArchConfig, SSMConfig

ARCH = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk=256),
    subquadratic=True, pos_embedding="none",
)
