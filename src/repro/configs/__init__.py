"""Assigned architecture configs (``--arch <id>``).

Each module defines ``ARCH``; ``get(name)`` resolves ids with dashes or
underscores. ``ALL_ARCHS`` lists the 10 assigned ids plus the repo's own
example config (``exanest-lm-100m``).
"""

from __future__ import annotations

import importlib

ALL_ARCHS = [
    "deepseek-v3-671b",
    "granite-moe-1b-a400m",
    "mamba2-2.7b",
    "starcoder2-7b",
    "command-r-35b",
    "deepseek-7b",
    "mistral-large-123b",
    "internvl2-1b",
    "whisper-small",
    "zamba2-2.7b",
]

EXTRA_ARCHS = ["exanest-lm-100m"]


def _modname(name: str) -> str:
    return name.replace("-", "_").replace(".", "p")


def get(name: str):
    mod = importlib.import_module(f"repro.configs.{_modname(name)}")
    return mod.ARCH


def all_configs() -> dict:
    return {n: get(n) for n in ALL_ARCHS + EXTRA_ARCHS}
