"""StarCoder2-7B [arXiv:2402.19173]: 32L, d_model 4608, 36H (GQA kv=4),
d_ff 18432, GQA + RoPE, gelu MLP with bias, LayerNorm."""
from repro.config import ArchConfig

ARCH = ArchConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab_size=49152, head_dim=128,
    rope_theta=1e5, attn_bias=True, mlp_bias=True,
    mlp_act="gelu", mlp_gated=False, norm="layernorm",
)
