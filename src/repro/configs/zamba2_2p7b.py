"""Zamba2-2.7B [arXiv:2411.15242]: 54 Mamba-2 layers (d_state 64) with a
shared attention(+MLP) block applied every 6 layers; d_model 2560, 32H,
d_ff 10240, vocab 32000. Simplifications vs the HF release (documented in
DESIGN.md): no concat-with-embedding input to the shared block and no
per-invocation LoRA on the shared weights."""
from repro.config import ArchConfig, SSMConfig

ARCH = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk=256),
    hybrid_attn_every=6, subquadratic=True,
    rope_theta=10000.0, mlp_act="gelu", mlp_gated=True,
)
