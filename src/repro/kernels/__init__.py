"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel package provides ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jitted wrapper with CPU fallback) and ``ref.py`` (pure-jnp
oracle). Kernels are validated on CPU in ``interpret=True`` mode; on TPU
backends the wrappers dispatch to the compiled kernels.

* ``matmul_tile``       — §7 MatMul accelerator -> 128x128xK MXU tiling
* ``allreduce_combine`` — §4.7 Allreduce accelerator reduction arithmetic
* ``flash_decode``      — decode attention over long KV (decode/long shapes)
* ``ssd_scan``          — Mamba-2 SSD chunk processor (mamba2/zamba2 archs)
"""

from repro.kernels.matmul_tile.ops import matmul
from repro.kernels.allreduce_combine.ops import combine_parts
from repro.kernels.flash_decode.ops import decode_attn
from repro.kernels.ssd_scan.ops import ssd

__all__ = ["matmul", "combine_parts", "decode_attn", "ssd"]
