"""Jitted wrapper for the SSD chunk-scan kernel."""

from __future__ import annotations

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


def ssd(x, dt, A, B, C, *, use_pallas: bool | None = None,
        interpret: bool = False, chunk: int = 256):
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu" or interpret
    if use_pallas:
        return ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)
    return ssd_ref(x, dt, A, B, C)
