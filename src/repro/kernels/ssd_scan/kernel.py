"""Pallas TPU kernel: Mamba-2 SSD chunk processor.

One grid step processes one (batch, head-block, chunk) cell entirely in
VMEM: the (chunk x chunk) decay-masked dual form (MXU matmuls) plus the
carried inter-chunk state, which lives in a VMEM scratch accumulator across
the chunk sweep — the sequential dependence is the innermost grid dim.

Head blocking keeps the working set in VMEM: per step it holds
x (c x hb*p), B/C (c x n), the (c x c) mask and the (hb, p, n) state.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_ref,
                *, chunk: int, n_chunks: int):
    z = pl.program_id(2)

    @pl.when(z == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, :, 0].astype(jnp.float32)   # (c, hb, p)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)  # (c, hb)
    A = a_ref[0].astype(jnp.float32)             # (hb,)
    Bm = b_ref[0, 0].astype(jnp.float32)         # (c, n)
    Cm = c_ref[0, 0].astype(jnp.float32)         # (c, n)

    dA = dt * A[None, :]                   # (c, hb)
    cs = jnp.cumsum(dA, axis=0)            # (c, hb)
    # within-chunk decay L[i,j] = exp(cs_i - cs_j) for i >= j
    seg = cs[:, None, :] - cs[None, :, :]  # (c, c, hb)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tri[..., None], jnp.exp(seg), 0.0)
    CB = jnp.dot(Cm, Bm.T)                 # (c, c)
    M = CB[..., None] * L                  # (c, c, hb)
    xdt = x * dt[..., None]                # (c, hb, p)
    y_diag = jnp.einsum("csh,shp->chp", M, xdt)

    # inter-chunk: contribution of the entering state
    state = state_ref[...]                 # (hb, p, n)
    decay_in = jnp.exp(cs)                 # (c, hb)
    y_off = jnp.einsum("cn,hpn,ch->chp", Cm, state, decay_in)
    y_ref[0, 0, :, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # update carried state
    decay_out = jnp.exp(cs[-1:, :] - cs)   # (c, hb)
    new_contrib = jnp.einsum("cn,ch,chp->hpn", Bm, decay_out, xdt)
    chunk_decay = jnp.exp(cs[-1, :])       # (hb,)
    state_ref[...] = state * chunk_decay[:, None, None] + new_contrib

    @pl.when(z == n_chunks - 1)
    def _emit():
        st_ref[0, 0] = state_ref[...].astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "head_block",
                                             "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 256, head_block: int = 8,
             interpret: bool = False):
    """x: (b,l,h,p); dt: (b,l,h) f32; A: (h,); B, C: (b,l,1,n) (n_groups=1).
    Returns (y (b,l,h,p) f32, final_state (b,h,p,n) f32)."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    assert B.shape[2] == 1, "kernel supports n_groups=1 (both assigned archs)"
    hb = min(head_block, h)
    assert h % hb == 0 and l % chunk == 0, (h, hb, l, chunk)
    n_chunks = l // chunk
    grid = (b, h // hb, n_chunks)
    xr = x.reshape(b, n_chunks, chunk, h // hb, hb, p)
    dtr = dt.reshape(b, n_chunks, chunk, h // hb, hb)
    Ar = A.reshape(h // hb, hb)
    Br = B[:, :, 0].reshape(b, n_chunks, chunk, n)
    Cr = C[:, :, 0].reshape(b, n_chunks, chunk, n)
    y, st = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, 1, hb, p),
                         lambda i, j, z: (i, z, 0, j, 0, 0)),
            pl.BlockSpec((1, 1, chunk, 1, hb),
                         lambda i, j, z: (i, z, 0, j, 0)),
            pl.BlockSpec((1, hb), lambda i, j, z: (j, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda i, j, z: (i, z, 0, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda i, j, z: (i, z, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, 1, hb, p),
                         lambda i, j, z: (i, z, 0, j, 0, 0)),
            pl.BlockSpec((1, 1, hb, p, n), lambda i, j, z: (i, j, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_chunks, chunk, h // hb, hb, p),
                                 jnp.float32),
            jax.ShapeDtypeStruct((b, h // hb, hb, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hb, p, n), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, Ar, Br, Cr)
    return (y.reshape(b, l, h, p), st.reshape(b, h, p, n))
