"""Oracle for the Mamba-2 SSD chunk scan: naive sequential recurrence."""

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C):
    """Sequential SSD recurrence (exact semantics of the chunked dual form).

    x: (b,l,h,p); dt: (b,l,h) f32 post-softplus; A: (h,) f32 (<0);
    B, C: (b,l,g,n) with h % g == 0.
    Returns (y: (b,l,h,p) f32, final_state: (b,h,p,n) f32).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=2)  # (b,l,h,n)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2)
    xf = x.astype(jnp.float32)

    def step(state, t):
        dA = jnp.exp(dt[:, t] * A)                       # (b,h)
        xdt = xf[:, t] * dt[:, t][..., None]             # (b,h,p)
        state = state * dA[..., None, None] + \
            jnp.einsum("bhn,bhp->bhpn", Bf[:, t], xdt)
        y = jnp.einsum("bhn,bhpn->bhp", Cf[:, t], state)
        return state, y

    state0 = jnp.zeros((b, h, p, n), jnp.float32)
    final, ys = jax.lax.scan(step, state0, jnp.arange(l))
    return jnp.moveaxis(ys, 0, 1), final
