"""Pallas TPU kernel: block-pipelined multi-operand reduction.

TPU adaptation of the paper's §4.7 Allreduce accelerator arithmetic: the NI
reduces incoming 256 B cells against a local partial vector as blocks
stream in. 256 B is far below VPU granularity, so the "cell" becomes a
(parts x block) VMEM tile: the grid walks the vector in ``block`` chunks
and each step reduces ``n_parts`` operands (f32 accumulation for sum —
matching the accelerator's int/float/double exactness guarantee).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(x_ref, o_ref, *, op: str):
    x = x_ref[...]
    if op == "sum":
        o_ref[...] = jnp.sum(x.astype(jnp.float32), axis=0).astype(o_ref.dtype)
    elif op == "max":
        o_ref[...] = jnp.max(x, axis=0)
    elif op == "min":
        o_ref[...] = jnp.min(x, axis=0)
    else:
        raise ValueError(op)


@functools.partial(jax.jit, static_argnames=("op", "block", "interpret"))
def combine(stacked: jnp.ndarray, *, op: str = "sum", block: int = 2048,
            interpret: bool = False) -> jnp.ndarray:
    """stacked: (n_parts, L) -> (L,). L is padded to a lane-aligned block."""
    P, L = stacked.shape
    block = min(block, L) if L % (min(block, L)) == 0 else L
    if L % block != 0:
        block = L
    grid = (L // block,)
    return pl.pallas_call(
        functools.partial(_combine_kernel, op=op),
        grid=grid,
        in_specs=[pl.BlockSpec((P, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((L,), stacked.dtype),
        interpret=interpret,
    )(stacked)
