"""Jitted wrapper: combine used by hierarchical-allreduce stages."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.allreduce_combine.kernel import combine
from repro.kernels.allreduce_combine.ref import combine_ref


def combine_parts(stacked: jnp.ndarray, *, op: str = "sum",
                  use_pallas: bool | None = None,
                  interpret: bool = False) -> jnp.ndarray:
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu" or interpret
    if use_pallas:
        return combine(stacked, op=op, interpret=interpret)
    return combine_ref(stacked, op=op)
