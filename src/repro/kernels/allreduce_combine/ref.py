"""Oracle for the multi-operand combine (allreduce reduction arithmetic)."""

import jax.numpy as jnp

OPS = {
    "sum": jnp.sum,
    "max": jnp.max,
    "min": jnp.min,
}


def combine_ref(stacked: jnp.ndarray, op: str = "sum") -> jnp.ndarray:
    """stacked: (n_parts, L) -> (L,) elementwise reduce with f32 accumulation
    (sum); min/max reduce in the native dtype."""
    if op == "sum":
        return jnp.sum(stacked.astype(jnp.float32), axis=0).astype(stacked.dtype)
    return OPS[op](stacked, axis=0)
