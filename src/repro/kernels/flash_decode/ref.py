"""Oracle for decode attention (one query token vs a long KV cache)."""

import jax.numpy as jnp


def decode_attention_ref(q, k, v, length=None):
    """q: (B,H,dk); k: (B,S,K,dk); v: (B,S,K,dv); H % K == 0.
    Attends to positions < length (default: all)."""
    B, H, dk = q.shape
    _, S, K, dv = v.shape
    rep = H // K
    qg = q.reshape(B, K, rep, dk).astype(jnp.float32)
    s = jnp.einsum("bgrh,bkgh->bgrk", qg, k.astype(jnp.float32)) * dk ** -0.5
    if length is not None:
        s = jnp.where(jnp.arange(S)[None, None, None] < length, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrk,bkgd->bgrd", p, v.astype(jnp.float32))
    return out.reshape(B, H, dv).astype(q.dtype)


import jax  # noqa: E402  (used above lazily)
