"""Jitted wrapper for flash-decode."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.kernel import flash_decode
from repro.kernels.flash_decode.ref import decode_attention_ref


def decode_attn(q, k, v, length=None, *, use_pallas: bool | None = None,
                interpret: bool = False, chunk: int = 1024):
    """q: (B,H,dk); caches (B,S,K,d*). Memory-bound decode attention."""
    S = k.shape[1]
    length = S if length is None else length
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu" or interpret
    if use_pallas:
        return flash_decode(q, k, v, length, chunk=chunk, interpret=interpret)
    return decode_attention_ref(q, k, v, length)


def hbm_bytes(batch: int, seq: int, kv_heads: int, head_dim: int,
              dtype_bytes: int = 2) -> int:
    """Roofline napkin math: decode attention streams the whole KV cache."""
    return 2 * batch * seq * kv_heads * head_dim * dtype_bytes
