"""Pallas TPU kernel: flash-decode — one query token vs a long KV cache.

Memory-bound by the KV stream (the ``decode_32k``/``long_500k`` shapes):
the grid walks KV chunks; online-softmax state (m, l, acc) lives in VMEM
scratch across the chunk sweep. Grid = (B, K_heads, S/chunk) with the chunk
dimension innermost — the TPU analog of split-K flash decoding, except the
"split" is the sequential VMEM-resident sweep (chips don't need CUDA-style
SM rebalancing; the ICI-sharded variant splits S across the mesh instead).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fd_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
               *, chunk: int, n_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (rep, dk)
    k = k_ref[0, 0].astype(jnp.float32)          # (chunk, dk)
    v = v_ref[0, 0].astype(jnp.float32)          # (chunk, dv)
    s = jnp.dot(q, k.T) * (q.shape[-1] ** -0.5)  # (rep, chunk)
    pos = c * chunk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(p, v)

    @pl.when(c == n_chunks - 1)
    def _emit():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def flash_decode(q, k, v, length, *, chunk: int = 1024,
                 interpret: bool = False):
    """q: (B,H,dk); k: (B,S,K,dk); v: (B,S,K,dv); length: scalar int32.
    Returns (B,H,dv)."""
    B, H, dk = q.shape
    _, S, K, dv = v.shape
    rep = H // K
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    qg = q.reshape(B, K, rep, dk)
    kk = jnp.moveaxis(k, 2, 1)  # (B,K,S,dk)
    vv = jnp.moveaxis(v, 2, 1)
    grid = (B, K, n_chunks)
    out = pl.pallas_call(
        functools.partial(_fd_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, rep, dk), lambda b, g, c: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, chunk, dk), lambda b, g, c: (b, g, c, 0)),
            pl.BlockSpec((1, 1, chunk, dv), lambda b, g, c: (b, g, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rep, dv), lambda b, g, c: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, rep, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, dv), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(length, jnp.int32).reshape(1), qg.reshape(B, K, rep, dk),
      kk.reshape(B, K, S, dk), vv.reshape(B, K, S, dv))
    return out.reshape(B, H, dv)
