"""Jitted public wrapper for the tiled matmul kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.matmul_tile.kernel import matmul_tile
from repro.kernels.matmul_tile.ref import matmul_ref


def matmul(a: jnp.ndarray, b: jnp.ndarray, *, use_pallas: bool | None = None,
           interpret: bool = False, **tile_kw) -> jnp.ndarray:
    """Tiled matmul. On TPU backends uses the Pallas kernel; elsewhere falls
    back to the jnp oracle unless ``interpret=True`` forces the kernel body
    to run interpreted (correctness validation on CPU)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu" or interpret
    if use_pallas:
        return matmul_tile(a, b, interpret=interpret, **tile_kw)
    return matmul_ref(a, b)


def flops_per_byte(m: int, n: int, k: int, dtype_bytes: int = 2) -> float:
    """Arithmetic intensity of the full problem (roofline napkin math)."""
    flops = 2.0 * m * n * k
    byts = dtype_bytes * (m * k + k * n + m * n)
    return flops / byts
