"""Pallas TPU kernel: 128x128xK tiled matmul with f32 VMEM accumulation.

This is the TPU-native adaptation of the paper's §7 HLS accelerator:
* the paper's 128x128 FP32 tile with the k-loop fully unrolled (512 mul +
  512 add per cycle) is exactly an MXU pass — we keep the 128x128 output
  tile (MXU-aligned) and the tiled-K accumulation loop;
* the paper streams tiles HBM(DDR)->BRAM over 3 AXI ports; here BlockSpecs
  stream HBM->VMEM tiles per grid step, with the accumulator held in a VMEM
  scratch buffer across the K grid dimension (revisiting semantics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    """Grid: (M/bm, N/bn, K/bk); K is the innermost (fastest) dimension so
    the accumulator lives in VMEM across the K sweep of one (i, j) tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_tile(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 128,
                bn: int = 128, bk: int = 512,
                interpret: bool = False) -> jnp.ndarray:
    """C[M,N] = A[M,K] @ B[K,N]; M % bm == K % bk == N % bn == 0."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
