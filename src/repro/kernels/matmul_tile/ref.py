"""Pure-jnp oracle for the tiled matmul kernel (paper §7)."""

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """C = A @ B with f32 accumulation, result in A's dtype."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)
