"""Sharded, integrity-checked, async checkpoint store.

Fault-tolerance substrate (DESIGN.md §5): per-leaf .npy files named by tree
path + a manifest with shapes/dtypes/sha256 + atomic rename commit. Restore
takes a *template* pytree (typically from jax.eval_shape) and an optional
target sharding tree — restoring onto a DIFFERENT mesh is the elastic
re-shard path (tested in tests/test_fault.py). Per-host sharded I/O at
scale: each host writes only the leaves it owns (single-host here, but the
layout is per-leaf so the extension is file-granular).
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading

import numpy as np
import jax


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return ".".join(parts) or "root"


def _sha256(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()


def save_checkpoint(path: str, step: int, tree, *, extra: dict | None = None
                    ) -> str:
    """Atomic checkpoint write; returns the committed directory."""
    tmp = os.path.join(path, f".tmp-{step}")
    final = os.path.join(path, f"step-{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for p, leaf in flat:
        name = _leaf_name(p)
        arr = np.asarray(jax.device_get(leaf))
        orig_dtype = str(arr.dtype)
        if arr.dtype.kind not in "fiub" or orig_dtype == "bfloat16":
            arr = arr.astype(np.float32)  # lossless widening for bf16 etc.
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"][name] = {
            "shape": list(arr.shape), "dtype": orig_dtype,
            "sha256": _sha256(arr),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [int(m.group(1)) for d in os.listdir(path)
             if (m := re.fullmatch(r"step-(\d+)", d))]
    return max(steps) if steps else None


def restore_checkpoint(path: str, step: int, template, *, shardings=None,
                       verify: bool = True):
    """Restore into the structure of ``template``; ``shardings`` (a matching
    pytree of jax.sharding.Sharding) re-shards onto a new mesh (elastic)."""
    d = os.path.join(path, f"step-{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (p, tmpl), shd in zip(flat, shard_flat):
        name = _leaf_name(p)
        arr = np.load(os.path.join(d, name + ".npy"))
        meta = manifest["leaves"][name]
        if verify and _sha256(arr) != meta["sha256"]:
            raise IOError(f"checkpoint corruption in leaf {name}")
        assert list(arr.shape) == list(tmpl.shape), (name, arr.shape,
                                                     tmpl.shape)
        leaves.append(jax.device_put(arr.astype(tmpl.dtype), shd)
                      if shd is not None else jax.numpy.asarray(
                          arr.astype(tmpl.dtype)))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


class CheckpointStore:
    """Async (background-thread) checkpointing with a bounded queue of one
    in-flight save — training never blocks on I/O longer than one save."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._pending: threading.Thread | None = None
        os.makedirs(path, exist_ok=True)

    def save_async(self, step: int, tree, extra=None):
        self.wait()
        # device_get NOW so training can mutate buffers after we return
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        self._pending = threading.Thread(
            target=self._save, args=(step, host_tree, extra), daemon=True)
        self._pending.start()

    def _save(self, step, tree, extra):
        save_checkpoint(self.path, step, tree, extra=extra)
        self._gc()

    def _gc(self):
        steps = sorted(int(d.split("-")[1]) for d in os.listdir(self.path)
                       if d.startswith("step-"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step-{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
