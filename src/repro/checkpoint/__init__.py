from repro.checkpoint.store import (CheckpointStore, save_checkpoint,
                                    restore_checkpoint, latest_step)

__all__ = ["CheckpointStore", "save_checkpoint", "restore_checkpoint",
           "latest_step"]
