"""Training loop: jitted step builder + a small Trainer for the examples.

``make_train_step`` is the single source of truth for the step graph —
the dry-run lowers exactly this function at full scale (launch/dryrun.py),
so what compiles there is what trains here.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update)


def make_train_step(model, opt_cfg: AdamWConfig, pctx=None,
                    microbatches: int = 1,
                    accum_dtype=jnp.float32,
                    sync_fn: Callable | None = None) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). With ``microbatches > 1``, gradients accumulate over
    sequential microbatch slices (pipeline-friendly; lowers activation
    memory by the same factor). ``accum_dtype=bfloat16`` halves the
    accumulator footprint for memory-floor configs (671B on one pod).
    ``sync_fn`` (grads -> grads) runs after accumulation, before the
    optimizer — the manual-DP gradient sync hook (see ``Trainer.make_step``
    and ``parallel/grad_sync.sync_gradients``)."""

    def loss_fn(params, batch):
        return model.loss_fn(params, batch, pctx)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def mb(i):
                return jax.tree_util.tree_map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // microbatches),
                        x.shape[0] // microbatches, axis=0), batch)

            def acc_fn(carry, i):
                loss_acc, grad_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb(i))
                return (loss_acc + l, jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), grad_acc, g)), None

            zero = (jnp.zeros(()), jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params))
            (loss, grads), _ = jax.lax.scan(acc_fn, zero,
                                            jnp.arange(microbatches))
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        if sync_fn is not None:
            grads = sync_fn(grads)
        new_params, new_opt, metrics = adamw_update(grads, opt_state, params,
                                                    opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


@dataclasses.dataclass
class Trainer:
    """Minimal driver used by examples/ and the fault-tolerance tests.

    With ``mesh`` set, gradients are synchronized across the DP axes each
    step via ``parallel/grad_sync.sync_gradients``; ``sync_strategy="auto"``
    lets the CollectivePlanner pick the cheapest exact sync per bucket by
    predicted cost (DESIGN.md §3.5). Lossy int8 compression is never chosen
    silently — opt in with ``allow_lossy=True`` (and consider
    ``CompressedSync`` for error feedback)."""
    model: Any
    opt_cfg: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    pctx: Any = None
    mesh: Any = None
    sync_strategy: str = "auto"
    allow_lossy: bool = False

    def init_state(self, key) -> dict:
        params = self.model.init(key)
        return {"params": params, "opt": adamw_init(params, self.opt_cfg)}

    def make_step(self, jit: bool = True) -> Callable:
        sync_fn = None
        if self.mesh is not None:
            import math

            from repro.parallel.grad_sync import sync_gradients
            dp_axes = [a for a in ("data", "pod")
                       if a in self.mesh.axis_names]
            if not dp_axes:
                raise ValueError(
                    "Trainer(mesh=...) synchronizes over DP axes named "
                    f"'data'/'pod' (DESIGN.md §5); mesh has "
                    f"{self.mesh.axis_names} — rename the axes or call "
                    "sync_gradients with explicit intra_axis/inter_axis")
            # psum sums per-replica gradients; divide by the DP world size
            # (the axes sync_gradients will reduce over) to get the mean
            world = math.prod(self.mesh.shape[a] for a in dp_axes
                              if self.mesh.shape[a] > 1)
            sync_fn = functools.partial(sync_gradients, mesh=self.mesh,
                                        strategy=self.sync_strategy,
                                        mean_over=max(world, 1),
                                        allow_lossy=self.allow_lossy)
        step = make_train_step(self.model, self.opt_cfg, self.pctx,
                               sync_fn=sync_fn)

        def fn(state, batch):
            p, o, m = step(state["params"], state["opt"], batch)
            return {"params": p, "opt": o}, m

        return jax.jit(fn) if jit else fn

    def fit(self, state, data_iter, n_steps: int, *, log_every: int = 10,
            callback=None) -> tuple[dict, list]:
        step_fn = self.make_step()
        history = []
        t0 = time.perf_counter()
        for i, batch in enumerate(data_iter):
            if i >= n_steps:
                break
            state, metrics = step_fn(state, batch)
            if i % log_every == 0 or i == n_steps - 1:
                loss = float(metrics["loss"])
                history.append({"step": i, "loss": loss,
                                "t": time.perf_counter() - t0})
                if callback:
                    callback(history[-1])
        return state, history
