"""AdamW with optional block-wise int8 state quantization.

Built in-repo (no optax): the framework owns its substrate. The int8 mode
(8-bit Adam, Dettmers et al., arXiv:2110.02861 — block-wise scales) is what
lets a 671B-parameter MoE train on a 256-chip v5e pod: m+v drop from 8 to
~2.03 bytes/param. It doubles as the gradient-compression analog of the
paper's bandwidth-saving tricks and is exercised in §Perf.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_states: bool = False   # int8 block-quantized m/v
    qblock: int = 256
    warmup_steps: int = 100
    decay_steps: int = 10000


# ------------------------------------------------------- int8 block quant
# Layout-preserving (8-bit Adam, arXiv:2110.02861): q keeps the PARAM shape
# in int8 and scales are per last-dim block — so a parameter's
# PartitionSpec applies verbatim to its quantized state (sharding-neutral).
def _quantizable(p, cfg: AdamWConfig) -> bool:
    return (cfg.quantize_states and p.ndim >= 1
            and p.shape[-1] % cfg.qblock == 0 and p.size >= 4 * cfg.qblock)


def _quant(x: jnp.ndarray, block: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    blocks = x.reshape(x.shape[:-1] + (x.shape[-1] // block, block))
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-20)[..., None])
    return q.reshape(x.shape).astype(jnp.int8), scale.astype(jnp.float32)


def _dequant(q: jnp.ndarray, scale: jnp.ndarray, block: int) -> jnp.ndarray:
    blocks = q.reshape(q.shape[:-1] + (q.shape[-1] // block, block))
    return (blocks.astype(jnp.float32) * scale[..., None]).reshape(q.shape)


def _state_for(p: jnp.ndarray, cfg: AdamWConfig):
    if _quantizable(p, cfg):
        return {"q": jnp.zeros(p.shape, jnp.int8),
                "scale": jnp.zeros(p.shape[:-1] +
                                   (p.shape[-1] // cfg.qblock,), jnp.float32)}
    return jnp.zeros(p.shape, jnp.float32)


def _read(state, shape, cfg: AdamWConfig):
    if isinstance(state, dict):
        return _dequant(state["q"], state["scale"], cfg.qblock)
    return state


def _write(val, state, cfg: AdamWConfig):
    if isinstance(state, dict):
        q, s = _quant(val, cfg.qblock)
        return {"q": q, "scale": s}
    return val


# ---------------------------------------------------------------- schedule
def lr_at(step, cfg: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


# --------------------------------------------------------------- optimizer
def adamw_init(params, cfg: AdamWConfig):
    return {
        "m": jax.tree_util.tree_map(lambda p: _state_for(p, cfg), params),
        "v": jax.tree_util.tree_map(lambda p: _state_for(p, cfg), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree_util.tree_leaves(tree)))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(step, cfg)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    is_state = lambda x: isinstance(x, dict) and set(x) == {"q", "scale"}

    def upd(p, g, m_st, v_st):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * _read(m_st, p.shape, cfg) + (1 - cfg.b1) * g
        v = cfg.b2 * _read(v_st, p.shape, cfg) + (1 - cfg.b2) * g * g
        upd_ = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        upd_ = upd_ + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd_).astype(p.dtype)
        return new_p, _write(m, m_st, cfg), _write(v, v_st, cfg)

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(opt_state["m"], is_leaf=is_state)[0]
    flat_v = jax.tree_util.tree_flatten(opt_state["v"], is_leaf=is_state)[0]
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics


def opt_state_bytes_per_param(cfg: AdamWConfig) -> float:
    if cfg.quantize_states:
        return 2 * (1 + 4.0 / cfg.qblock)
    return 8.0
