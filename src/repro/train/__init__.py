from repro.train.cosim import SyncCandidate, TrainSim, TrainStepSpec
from repro.train.loop import Trainer, make_train_step
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["Trainer", "make_train_step", "AdamWConfig", "adamw_init",
           "adamw_update", "SyncCandidate", "TrainSim", "TrainStepSpec"]
