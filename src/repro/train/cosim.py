"""Overlap-aware train-step co-simulation (ROADMAP item 5; DESIGN.md §2.9).

Closes the loop from model configs to simulated training step time: the
compute side comes from the roofline stack (synthetic train HLO through
the while-rollup cost model, cross-anchored by the closed form), the
communication side is *executed* — per-rank forward/backward ``Compute``
ops interleaved with bucketed nonblocking gradient ``Collective``\\ s, so
backward/sync overlap is emergent from the event engine
(``Collective(handle=...)`` + ``Wait``, the seam of
:class:`repro.core.program.ProgramExecutor`), never a closed-form
assumption.  The same emission runs on both machines:
:class:`~repro.core.machine.ExanetMachine` at sim fidelity (contention
included) and :class:`~repro.core.machine.TpuMachine` through the
analytic hooks of the shared scheduler.

The fast path mirrors :mod:`repro.serve.sim`: a *candidate family* —
same (bucket count, algorithm, overlap depth), hence the same Program
structure — binds every member's bucket layout as one batch column of
ONE compiled replay (per-site payload scale for the bucket bytes,
per-compute-slot scale for the backward slices that produce them), so
the planner's hillclimb (:meth:`repro.core.planner.CollectivePlanner.
plan_train_sync`) evaluates whole populations per
:meth:`~repro.core.exanet.mpi.ExanetMPI.run_program_scenarios` call
instead of re-binding per candidate.  Candidates always carry explicit
algorithms (never ``"auto"``): per-column payloads must not be able to
flip the probe tape's schedule resolution.

Step emission (per rank, identical across ranks)::

    Compute(fwd)
    for bucket i in 0..k-1:
        Compute(bwd * frac_i)
        Collective(allreduce, grad_bytes * frac_i, algo[, handle=g_i])
        Wait((g_{i-depth},))          # overlap_depth > 0 only
    Wait()                            # drain outstanding syncs
    Compute(opt)

``overlap_depth = 0`` emits blocking collectives — exactly the PR-4
``grad_sync.emit_sync_program`` pipeline the analytic ``CommPolicy``
baseline assumes.  Depth ``d`` lets ``d`` syncs ride behind backward
compute; the engine decides what actually overlaps.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.program import Collective, Compute, Program, Wait

#: software allreduce candidates the grid defaults to: log-p round counts
#: stay compilable at 4096 ranks (ring's O(p) rounds and oneshot's O(p^2)
#: flows are excluded by default, not by ability)
DEFAULT_ALGOS = ("rabenseifner", "recursive_doubling")


@dataclasses.dataclass(frozen=True)
class TrainStepSpec:
    """One simulated data-parallel training deployment."""
    arch: str = "exanest-lm-100m"
    nranks: int = 512
    seq_len: int = 2048
    batch_per_rank: int = 1        #: sequences per rank per step
    microbatches: int = 1
    #: wire dtype of the gradient sync (bf16 buckets by default)
    grad_dtype_bytes: int = 2
    #: per-rank sustained training compute, GFLOP/s.  The default is an
    #: A53+NEON-class MPSoC node (the prototype's compute tier), which
    #: puts backward compute and gradient wire time in the same decade —
    #: the regime where overlap decisions actually move step time.  This
    #: is the knob that moves the compute/comm crossover.
    rank_gflops: float = 50.0
    bwd_fwd_ratio: float = 2.0     #: backward flops per forward flop
    opt_frac: float = 0.15         #: optimizer+misc as fraction of forward


@dataclasses.dataclass(frozen=True)
class SyncCandidate:
    """One gradient-sync configuration the planner can pick: bucket
    layout x collective algorithm x overlap depth.  ``split`` is the
    per-bucket fraction tuple (None = equal); members sharing
    ``family()`` share a Program structure and batch together."""
    n_buckets: int
    algo: str
    overlap_depth: int = 0
    split: tuple[float, ...] | None = None

    def __post_init__(self):
        if self.algo == "auto":
            raise ValueError(
                "co-sim candidates need explicit algorithms: per-column "
                "payload bindings must not be able to flip an algo='auto' "
                "schedule resolution recorded on the probe tape")
        if self.split is not None and len(self.split) != self.n_buckets:
            raise ValueError(f"{self.n_buckets} buckets but "
                             f"{len(self.split)} split fractions")

    def fractions(self) -> tuple[float, ...]:
        if self.split is None:
            return (1.0 / self.n_buckets,) * self.n_buckets
        tot = sum(self.split)
        return tuple(f / tot for f in self.split)

    def family(self) -> tuple:
        """Structure key: candidates with equal families emit
        structurally-identical Programs (depth saturates at the bucket
        count — deeper never changes the op sequence)."""
        return (self.n_buckets, self.algo,
                min(self.overlap_depth, self.n_buckets)
                if self.overlap_depth else 0)


class TrainSim:
    """Emit + cost train-step Programs for one :class:`TrainStepSpec`.

    The simulation instance (base prototype or scaled-torus twin) is
    resolved per rank count through the same
    :meth:`~repro.core.machine.ExanetMachine._mpi_for` tier cache the
    planner and serve sweeps use.
    """

    def __init__(self, spec: TrainStepSpec, machine=None,
                 rank_compute_scale=None):
        from repro.configs import get
        from repro.roofline.analysis import lm_train_step_cost
        from repro.roofline.hlo_cost import analyze_hlo, synth_train_hlo
        if spec.nranks & (spec.nranks - 1):
            raise ValueError("nranks must be a power of two for the "
                             f"log-p allreduce schedules; got {spec.nranks}")
        self.spec = spec
        # per-rank compute-time multipliers (slow/"hot" ranks, DESIGN.md
        # §2.10): every batched costing lane sees the stragglers, so
        # plan_train_sync replans *for* the degraded machine; the
        # single-candidate lanes stay healthy references
        self.rank_compute_scale = None
        if rank_compute_scale is not None:
            rcs = np.asarray(rank_compute_scale, dtype=np.float64)
            if rcs.shape != (spec.nranks,):
                raise ValueError(f"rank_compute_scale must be "
                                 f"({spec.nranks},); got {rcs.shape}")
            if (rcs != 1.0).any():
                self.rank_compute_scale = rcs
        self.cfg = get(spec.arch)
        if machine is None:
            from repro.core.machine import ExanetMachine
            machine = ExanetMachine()
        self.machine = machine
        self.mpi = machine._mpi_for(spec.nranks)
        # compute side: the synthetic-HLO estimate is primary (same
        # while-rollup model real dry-run artifacts go through), the
        # closed form is the cross-anchor pinned by tests
        self.closed = lm_train_step_cost(
            self.cfg, seq_len=spec.seq_len, batch=spec.batch_per_rank,
            grad_dtype_bytes=spec.grad_dtype_bytes)
        self.hlo_cost = analyze_hlo(synth_train_hlo(
            self.cfg, seq_len=spec.seq_len, batch=spec.batch_per_rank,
            microbatches=spec.microbatches))
        rate = spec.rank_gflops * 1e3            # flops per microsecond
        self.fwd_us = self.hlo_cost["flops"] / rate
        self.bwd_us = spec.bwd_fwd_ratio * self.fwd_us
        self.opt_us = spec.opt_frac * self.fwd_us
        self.grad_bytes = int(self.closed["grad_bytes"])

    # ------------------------------------------------------------ emission
    def bucket_bytes(self, cand: SyncCandidate) -> tuple[int, ...]:
        return tuple(max(1, int(round(self.grad_bytes * f)))
                     for f in cand.fractions())

    def emit_step(self, cand: SyncCandidate) -> Program:
        """One training step as a Program (module docstring shape).
        Structure depends only on ``cand.family()``; payloads and
        backward slices move with the split, so same-family candidates
        bind as columns of one compiled artifact."""
        depth = cand.overlap_depth
        ops: list = [Compute(us=self.fwd_us)]
        fr = cand.fractions()
        for i, (f, nb) in enumerate(zip(fr, self.bucket_bytes(cand))):
            ops.append(Compute(us=self.bwd_us * f))
            if depth > 0:
                ops.append(Collective("allreduce", nb, cand.algo,
                                      handle=f"g{i}"))
                if i - depth >= 0:
                    ops.append(Wait((f"g{i - depth}",)))
            else:
                ops.append(Collective("allreduce", nb, cand.algo))
        if depth > 0:
            ops.append(Wait())
        ops.append(Compute(us=self.opt_us))
        return Program(tuple(tuple(ops) for _ in range(self.spec.nranks)))

    # ------------------------------------------------------------- costing
    def cost_candidates(self, cands, *, engine=None, check: int = 0,
                        rtol: float = 1e-9) -> np.ndarray:
        """Simulated step time (us) of every candidate: ONE batched
        scenario replay per structure family — per-site payload scale
        carries each member's bucket bytes, per-compute-slot scale the
        backward slice that produces each bucket.  ``check`` forwards to
        :meth:`~repro.core.exanet.mpi.ExanetMPI.run_program_scenarios`
        (sampled columns re-run on the interpreter, <=rtol or raise)."""
        cands = list(cands)
        out = np.empty(len(cands))
        fams: dict[tuple, list[int]] = {}
        for i, c in enumerate(cands):
            fams.setdefault(c.family(), []).append(i)
        for (nb, algo, depth), idxs in fams.items():
            base_cand = SyncCandidate(nb, algo, depth)
            base = self.emit_step(base_cand)
            base_bytes = np.array(self.bucket_bytes(base_cand),
                                  dtype=np.float64)
            base_fr = np.array(base_cand.fractions())
            N = len(idxs)
            ss = np.empty((nb, N))
            pat = np.ones((nb + 2, N))      # [fwd, bwd_0..bwd_k-1, opt]
            for j, i in enumerate(idxs):
                ss[:, j] = (np.array(self.bucket_bytes(cands[i]),
                                     dtype=np.float64) / base_bytes)
                pat[1:nb + 1, j] = (np.array(cands[i].fractions())
                                    / base_fr)
            # compute slots are rank-major in program order and every
            # rank emits the same pattern: tile it across ranks
            cs = np.tile(pat, (self.spec.nranks, 1))
            if self.rank_compute_scale is not None:
                cs = cs * np.repeat(self.rank_compute_scale,
                                    nb + 2)[:, None]
            res = self.machine.cost_program_scenarios(
                base, compute_scale=cs, site_scale=ss, engine=engine,
                check=min(check, N), rtol=rtol)
            for j, i in enumerate(idxs):
                out[i] = res[j].latency_us
        return out

    def step_time_single(self, cand: SyncCandidate, *,
                         backend: str = "auto", engine=None) -> float:
        """The naive lane: emit and run this one candidate alone — what
        a per-candidate search pays per evaluation.  Same payloads as
        the batched column, so lane agreement is executor agreement."""
        return self.mpi.run_program(self.emit_step(cand), backend=backend,
                                    engine=engine).latency_us

    def step_time_analytic(self, cand: SyncCandidate, machine=None) -> float:
        """The same emission through a machine's analytic program walk
        (default: the TPU target, which has no event engine) — overlap
        still emerges because the analytic hooks run on the shared
        nonblocking-collective scheduler, in microseconds."""
        if machine is None:
            from repro.core.machine import TpuMachine
            machine = TpuMachine()
        return machine.cost_program(self.emit_step(cand)) * 1e6

    def serialized_us(self, cand: SyncCandidate, *, engine=None) -> float:
        """No-overlap reference: the same buckets forced blocking."""
        return self.step_time_single(
            dataclasses.replace(cand, overlap_depth=0), engine=engine)

    def sync_tail_us(self, cand: SyncCandidate) -> float:
        """Simulated time of the LAST bucket's allreduce alone — with
        the compute totals, a critical-path lower bound: the final
        bucket cannot enter before every backward slice has run, and
        the optimizer cannot start before it exits."""
        nb = self.bucket_bytes(cand)[-1]
        prog = Program(tuple((Collective("allreduce", nb, cand.algo),)
                             for _ in range(self.spec.nranks)))
        return self.mpi.run_program(prog).latency_us

    def lower_bound_us(self, cand: SyncCandidate) -> float:
        return (self.fwd_us + self.bwd_us + self.opt_us
                + self.sync_tail_us(cand))

    # ------------------------------------------- planner candidate surface
    def feasible_algos(self, algos=DEFAULT_ALGOS) -> tuple[str, ...]:
        """Requested algos this machine supports at this rank count,
        plus the NI accelerator where the machine has one."""
        from repro.core.exanet.schedules import ALLREDUCE_SCHEDULES
        p = self.spec.nranks
        out = [a for a in algos if self.machine.supports(
            ALLREDUCE_SCHEDULES[a](), p, self.grad_bytes)]
        try:
            from repro.core.exanet.allreduce_accel import \
                accel_rank_applicable
            if accel_rank_applicable(p, getattr(self.machine, "params",
                                                None)):
                out.append("accel")
        except (ImportError, AttributeError, TypeError):
            pass
        return tuple(out)

    def candidate_grid(self, *, buckets=(1, 2, 4, 8, 16, 32),
                       algos=DEFAULT_ALGOS,
                       depths=(0, 1, 2)) -> list[SyncCandidate]:
        """Equal-split seed grid for the planner's hillclimb: bucket
        counts whose buckets stay at least one element per rank, every
        feasible algorithm, blocking plus small overlap depths."""
        nb_ok = [b for b in buckets
                 if self.grad_bytes // b >= self.spec.nranks]
        return [SyncCandidate(nb, a, d)
                for nb in (nb_ok or [1])
                for a in self.feasible_algos(algos)
                for d in depths if d <= nb]

    def mutate(self, cand: SyncCandidate, rng) -> SyncCandidate:
        """One hillclimb move: usually a same-family split perturbation
        (stays a batch column of the parent's artifact), sometimes a
        family hop (bucket count, algorithm, or overlap depth)."""
        r = rng.random()
        if r < 0.55:
            fr = np.array(cand.fractions())
            fr = fr * np.exp(rng.normal(0.0, 0.25, fr.shape))
            fr = np.maximum(fr / fr.sum(), 1e-3)
            return dataclasses.replace(
                cand, split=tuple(float(f) for f in fr / fr.sum()))
        if r < 0.75:
            nb = cand.n_buckets * 2 if rng.random() < 0.5 else \
                max(1, cand.n_buckets // 2)
            nb = min(nb, 64)
            if self.grad_bytes // nb < self.spec.nranks:
                nb = cand.n_buckets
            return SyncCandidate(nb, cand.algo,
                                 min(cand.overlap_depth, nb))
        if r < 0.9:
            algos = self.feasible_algos()
            return dataclasses.replace(
                cand, split=None,
                algo=algos[int(rng.integers(len(algos)))])
        return dataclasses.replace(
            cand, overlap_depth=int(rng.integers(0, 3)))

    def analytic_candidate(self) -> SyncCandidate:
        """What the pre-cosim stack picks: ``CommPolicy.bucket_bytes``
        sizes buckets by alpha amortization on THIS machine's
        (alpha, beta), the analytic planner picks the algorithm for
        that bucket size, and emission is the blocking PR-4 pipeline
        (``overlap_depth=0`` — the closed forms carry no overlap
        term).  This is the baseline the simulated plan flips against
        (``BENCH_train.json``)."""
        from repro.core.comm import CommPolicy
        from repro.core.machine import INTRA
        from repro.core.planner import CollectivePlanner
        alpha, bw = self.machine.alpha_beta(INTRA)
        pol = CommPolicy(alpha_s=alpha, ici_bw=bw)
        per_bucket = max(self.spec.nranks, pol.bucket_bytes(self.spec.nranks))
        nb = max(1, min(64, math.ceil(self.grad_bytes / per_bucket)))
        plan = CollectivePlanner(self.machine, fidelity="analytic").plan(
            "allreduce", max(1, self.grad_bytes // nb), self.spec.nranks)
        algo = plan.schedule
        if algo not in self.feasible_algos() or algo.startswith("synth:"):
            # the grid carries only structurally-stable explicit algos;
            # fall back to the best of those by the same analytic plan
            feas = self.feasible_algos()
            costs = [(plan.cost_of(a), a) for a in feas
                     if plan.cost_of(a) is not None]
            algo = min(costs)[1] if costs else feas[0]
        return SyncCandidate(nb, algo, 0)
