"""Fault tolerance: checkpoint/restart, failure replay, stragglers, elastic.

The paper's reliable transport replays faulting RDMA blocks instead of
pinning pages (§4.5.3); at framework scale the analogous unit of replay is
the *training step*: deterministic data (seed, step) + periodic checkpoints
make any step replayable after a node failure, bit-exactly.

* ``run_with_recovery`` — drives a step function with injected failures;
  recovery = restore latest checkpoint + replay. The invariant (tested):
  final state equals the failure-free run.
* ``StragglerMonitor``  — per-step deadline from a running median; slow
  steps trigger the mitigation hook (at scale: re-dispatch the microbatch
  to a hot spare; here: recorded + replayed).
* ``elastic_reshard``   — re-shard a checkpoint onto a different mesh
  (shrink/grow), enabled by pure-function-of-(seed,step) data.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable

import jax

from repro.checkpoint.store import (latest_step, restore_checkpoint,
                                    save_checkpoint)


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Deterministically fail at given steps (once each)."""
    fail_at: frozenset
    _hit: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at and step not in self._hit:
            self._hit.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


class StragglerMonitor:
    def __init__(self, deadline_factor: float = 3.0, window: int = 32,
                 on_straggle: Callable | None = None):
        """``on_straggle(step, dt, deadline)`` fires when a step exceeds
        its running-median deadline — the mitigation hook (at scale:
        re-dispatch the microbatch to a hot spare, or hand the planner a
        slow-rank FaultSpec to replan against; default: record only)."""
        self.factor = deadline_factor
        self.window = window
        self.on_straggle = on_straggle
        self.times: list[float] = []
        self.flagged: list[int] = []

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if the step straggled past the deadline."""
        hist = self.times[-self.window:]
        self.times.append(dt)
        if len(hist) >= 8:
            deadline = self.factor * statistics.median(hist)
            if dt > deadline:
                self.flagged.append(step)
                if self.on_straggle is not None:
                    self.on_straggle(step, dt, deadline)
                return True
        return False


def run_with_recovery(state, step_fn: Callable, n_steps: int, *,
                      ckpt_dir: str, ckpt_every: int = 10,
                      injector: FailureInjector | None = None,
                      straggler: StragglerMonitor | None = None,
                      delay_fn: Callable | None = None) -> tuple:
    """Run ``state = step_fn(state, step)`` for ``n_steps`` with periodic
    checkpoints; on SimulatedFailure, restore + replay. Returns
    (final_state, log)."""
    template = jax.tree_util.tree_map(lambda x: x, state)
    save_checkpoint(ckpt_dir, 0, state)
    log = {"failures": 0, "replayed_steps": 0, "straggles": 0}
    step = 0
    while step < n_steps:
        try:
            if injector is not None:
                injector.check(step)
            t0 = time.perf_counter()
            if delay_fn is not None:
                delay_fn(step)
            state = step_fn(state, step)
            dt = time.perf_counter() - t0
            if straggler is not None and straggler.observe(step, dt):
                log["straggles"] += 1
            step += 1
            if step % ckpt_every == 0:
                save_checkpoint(ckpt_dir, step, state)
        except SimulatedFailure:
            log["failures"] += 1
            last = latest_step(ckpt_dir)
            state, _ = restore_checkpoint(ckpt_dir, last, template)
            log["replayed_steps"] += step - last
            step = last
    return state, log


def elastic_reshard(ckpt_dir: str, step: int, template, new_shardings):
    """Restore a checkpoint onto a different mesh (elastic shrink/grow)."""
    return restore_checkpoint(ckpt_dir, step, template,
                              shardings=new_shardings)[0]
