from repro.runtime.fault import (FailureInjector, SimulatedFailure,
                                 StragglerMonitor, run_with_recovery,
                                 elastic_reshard)

__all__ = ["FailureInjector", "SimulatedFailure", "StragglerMonitor",
           "run_with_recovery", "elastic_reshard"]
