"""Benchmark: trace-driven application simulation (Table 3 on the engine).

One artifact (``BENCH_apps.json``) with one row per (app, mode, rank
count), 2-512 ranks:

* **predicted-vs-paper efficiency** — the Program-IR apps model
  (``apps.py``: per-rank halo/compute/allreduce programs executed on the
  discrete-event engine, congestion emergent) against the paper's Table 3
  anchors where they exist (2 and 512 ranks; 512 is the calibration
  point, 2 a prediction);
* **simulated app-iterations/sec** — wall-clock throughput of simulating
  one iteration (the workload-simulator cost of the IR executor:
  thousands of contending point-to-point flows + embedded collectives per
  iteration);
* **beta vs retired alpha** — the per-(app, mode) MPI-stack residual
  ``beta`` that replaced the old closed-form fudge factor, next to the
  ``alpha`` the old model would have needed (the ratio is how much of the
  fudge the simulation now explains).

Run: PYTHONPATH=src python benchmarks/apps_sweep.py [--smoke]

``--smoke`` (the CI benchmark step) drops the 64/512-rank rows and
shortens timed windows; per the BENCH schema rules (DESIGN.md §6), smoke
artifacts omit the acceptance keys (``table3_max_abs_error_pts_512``,
``prediction_max_abs_error_pts_2``, ``iters_per_sec_at_512``) so a smoke
run can never masquerade as the full sweep.
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.exanet.apps import ALL_APPS, PAPER_TABLE3  # noqa: E402

RANKS = (2, 8, 64, 512)
SMOKE_RANKS = (2, 8)
MODES = ("weak", "strong")


def _iterations_per_sec(model, mode: str, n: int, min_wall_s: float
                        ) -> tuple[float, int]:
    """Simulated app-iterations per wall second (cold caches excluded:
    the first run builds routes/paths, then we time steady-state runs)."""
    prog = model.emit_iteration(mode, n)
    mpi = model.mpi
    mpi.run_program(prog)  # warm the path table / route cache
    runs, wall = 0, 0.0
    t0 = time.perf_counter()
    while wall < min_wall_s:
        mpi.run_program(prog)
        runs += 1
        wall = time.perf_counter() - t0
    return runs / wall, runs


def sweep(ranks: tuple[int, ...], min_wall_s: float) -> list[dict]:
    rows = []
    for app, factory in ALL_APPS.items():
        model = factory()
        for mode in MODES:
            for n in ranks:
                ev = model._eval(mode, n)
                sim = model._simulate(mode, n)
                ips, runs = _iterations_per_sec(model, mode, n, min_wall_s)
                paper = PAPER_TABLE3[app][mode].get(n)
                eff_pct = round(100 * ev["efficiency"], 1)
                row = {
                    "app": app, "mode": mode, "nranks": n,
                    "efficiency_pct": eff_pct,
                    "paper_pct": paper,
                    "error_pts": (round(eff_pct - paper, 1)
                                  if paper is not None else None),
                    "calibrated": ev["calibrated"],
                    "comm_fraction": round(ev["comm_fraction"], 4),
                    "t_iter_us": round(ev["t_iter_us"], 1),
                    "sim_comm_us": round(sim.comm_us, 2),
                    "n_sends": sim.n_sends,
                    "beta": round(ev["beta"], 4),
                    "alpha_retired": round(ev["alpha_retired"], 3),
                    "sim_iterations_per_sec": round(ips, 1),
                    "timed_runs": runs,
                }
                rows.append(row)
                anchor = (f" paper={paper}"
                          f" err={row['error_pts']:+.1f}" if paper else "")
                print(f"{app:7s} {mode:6s} N={n:3d}  eff={eff_pct:5.1f}%"
                      f"{anchor}  beta={ev['beta']:.3f} "
                      f"(alpha was {ev['alpha_retired']:.2f})  "
                      f"{ips:8.1f} sim-iters/s ({sim.n_sends} sends)")
    return rows


def main(out_path: str = "BENCH_apps.json", smoke: bool = False) -> None:
    ranks = SMOKE_RANKS if smoke else RANKS
    rows = sweep(ranks, min_wall_s=0.05 if smoke else 0.2)
    out: dict = {"ranks": list(ranks), "results": rows}
    betas = {f"{r['app']}/{r['mode']}": {"beta": r["beta"],
                                         "alpha_retired": r["alpha_retired"]}
             for r in rows if r["nranks"] == max(ranks)}
    out["beta_vs_alpha_retired"] = betas
    if not smoke:
        # acceptance keys: full sweeps only (see module docstring)
        err512 = [abs(r["error_pts"]) for r in rows
                  if r["nranks"] == 512 and r["error_pts"] is not None]
        err2 = [abs(r["error_pts"]) for r in rows
                if r["nranks"] == 2 and r["error_pts"] is not None]
        ips512 = [r["sim_iterations_per_sec"] for r in rows
                  if r["nranks"] == 512]
        out["table3_max_abs_error_pts_512"] = max(err512)
        out["prediction_max_abs_error_pts_2"] = max(err2)
        out["iters_per_sec_at_512"] = {"min": min(ips512),
                                       "max": max(ips512)}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {out_path}")
    worst = max((r for r in rows), key=lambda r: r["beta"])
    print(f"largest residual: {worst['app']}/{worst['mode']} "
          f"beta={worst['beta']:.3f} vs retired alpha="
          f"{worst['alpha_retired']:.2f}")
    if not smoke:
        print(f"Table 3 max |error|: {out['table3_max_abs_error_pts_512']}"
              f" pts at 512 (calibrated), "
              f"{out['prediction_max_abs_error_pts_2']} pts at 2 "
              f"(predicted); {out['iters_per_sec_at_512']['min']:.0f}-"
              f"{out['iters_per_sec_at_512']['max']:.0f} sim-iters/s @512")
        assert out["table3_max_abs_error_pts_512"] <= 0.5, \
            "512-rank cells are calibrated and must match Table 3"
        assert out["prediction_max_abs_error_pts_2"] <= 7.0, \
            "2-rank predictions must stay in the DESIGN.md §7 band"
    # the IR's whole point: the residual must not exceed the retired fudge
    for k, v in betas.items():
        assert v["beta"] <= v["alpha_retired"] + 1e-9, \
            f"{k}: beta {v['beta']} exceeds retired alpha {v['alpha_retired']}"


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
