"""Benchmark: trace-driven application simulation (Table 3 on the engine).

One artifact (``BENCH_apps.json``) with one row per (app, mode, rank
count):

* **predicted-vs-paper efficiency** — the Program-IR apps model
  (``apps.py``: per-rank halo/compute/allreduce programs executed on the
  discrete-event engine, congestion emergent) against the paper's Table 3
  anchors where they exist (2 and 512 ranks; 512 is the calibration
  point, 2 a prediction);
* **compiled vs interpreted throughput** — simulated app-iterations per
  wall second on both executors of ``run_program`` (the interpreted heap
  scheduler vs the vectorized level programs of
  ``core/exanet/program_compiled.py``), with a ≤1e-9 agreement guard:
  every timed row first checks the two backends return the same latency
  and per-rank clocks;
* **paper-scale weak-scaling predictions** — 1024/2048/4096-rank rows
  (scaled-torus tiers) that only the compiled backend makes practical to
  sweep; the interpreter is timed only through 512 ranks (at 1024 it
  still runs once, for the agreement guard);
* **beta vs retired alpha** — the per-(app, mode) MPI-stack residual
  ``beta`` that replaced the old closed-form fudge factor;
* **Monte-Carlo scenario rows** (PR 6) — N perturbed scenarios of one
  iteration (per-scenario compute skew x byte jitter) executed as ONE
  array program (``run_program_scenarios`` -> ``bind_arrays``: no N
  Program objects, no N scheduler probes) vs the per-binding lane
  (``rebind_program`` + ``run_program_many``), fresh random draws every
  timed repetition so neither lane hits a warm bind cache; batched and
  per-binding results are cross-checked to <=1e-9 and ``batch_speedup``
  is recorded per row (schema: DESIGN.md §6).

Run: PYTHONPATH=src python benchmarks/apps_sweep.py [--smoke]
         [--min-runs N] [--engine numpy|jax]

Timing windows have a ``--min-runs`` floor (default 5): a 0.2 s budget
fits only ~2 interpreted runs at 512 ranks, and single-sample throughput
rows are noise.  Every row records its ``wall_s``.

``--smoke`` (the CI benchmark step) drops the 64/512-rank and prediction
rows and shortens timed windows, but still runs the compiled backend and
its agreement guard end to end; per the BENCH schema rules (DESIGN.md
§6), smoke artifacts omit the acceptance keys
(``table3_max_abs_error_pts_512``, ``prediction_max_abs_error_pts_2``,
``iters_per_sec_at_512``, ``compiled_speedup_at_512``) so a smoke run can
never masquerade as the full sweep.
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.exanet.apps import ALL_APPS, PAPER_TABLE3  # noqa: E402

RANKS = (2, 8, 64, 512)
SMOKE_RANKS = (2, 8)
#: weak-scaling predictions beyond the prototype (scaled-torus tiers);
#: compiled-backend only — interpreting a 4096-rank iteration takes tens
#: of seconds, sweeping it is impractical
PREDICT_RANKS = (1024, 2048, 4096)
MODES = ("weak", "strong")
AGREEMENT_RTOL = 1e-9


def _iterations_per_sec(model, mode: str, n: int, min_wall_s: float,
                        min_runs: int, backend: str,
                        engine: str = "numpy") -> tuple:
    """Simulated app-iterations per wall second (cold costs excluded: the
    first run builds routes/paths — and, for the compiled backend, the
    lowered artifact — then we time steady-state runs)."""
    prog = model.emit_iteration(mode, n)
    mpi = model.mpi_for(n)
    mpi.run_program(prog, backend=backend, engine=engine)  # warm / compile
    runs, wall = 0, 0.0
    t0 = time.perf_counter()
    while wall < min_wall_s or runs < min_runs:
        mpi.run_program(prog, backend=backend, engine=engine)
        runs += 1
        wall = time.perf_counter() - t0
    return runs / wall, runs, wall


def _agreement_rel(model, mode: str, n: int) -> float:
    """Max relative deviation (latency + per-rank clocks) between the
    compiled and interpreted executors on one iteration."""
    prog = model.emit_iteration(mode, n)
    mpi = model.mpi_for(n)
    a = mpi.run_program(prog, backend="interp")
    b = mpi.run_program(prog, backend="compiled")
    rel = abs(b.latency_us - a.latency_us) / max(abs(a.latency_us), 1e-12)
    for x, y in zip(a.clocks, b.clocks):
        rel = max(rel, abs(y - x) / max(abs(x), 1e-12))
    assert (a.n_sends, a.n_collectives) == (b.n_sends, b.n_collectives)
    return rel


def _row(model, app: str, mode: str, n: int, ev: dict, sim) -> dict:
    paper = PAPER_TABLE3[app][mode].get(n)
    eff_pct = round(100 * ev["efficiency"], 1)
    return {
        "app": app, "mode": mode, "nranks": n,
        "efficiency_pct": eff_pct,
        "paper_pct": paper,
        "error_pts": (round(eff_pct - paper, 1)
                      if paper is not None else None),
        "calibrated": ev["calibrated"],
        "comm_fraction": round(ev["comm_fraction"], 4),
        "t_iter_us": round(ev["t_iter_us"], 1),
        "sim_comm_us": round(sim.comm_us, 2),
        "n_sends": sim.n_sends,
        "beta": round(ev["beta"], 4),
        "alpha_retired": round(ev["alpha_retired"], 3),
    }


def sweep(ranks: tuple[int, ...], min_wall_s: float,
          min_runs: int, engine: str = "numpy") -> list[dict]:
    rows = []
    for app, factory in ALL_APPS.items():
        model = factory()
        for mode in MODES:
            for n in ranks:
                ev = model._eval(mode, n)
                sim = model._simulate(mode, n)
                rel = _agreement_rel(model, mode, n)
                assert rel <= AGREEMENT_RTOL, \
                    f"{app}/{mode}@{n}: compiled deviates {rel:.2e}"
                ips_i, runs_i, wall_i = _iterations_per_sec(
                    model, mode, n, min_wall_s, min_runs, "interp")
                ips_c, runs_c, wall_c = _iterations_per_sec(
                    model, mode, n, min_wall_s, min_runs, "compiled",
                    engine)
                row = _row(model, app, mode, n, ev, sim)
                row.update({
                    "engine": engine,
                    "agreement_rel": rel,
                    "interp": {"sim_iterations_per_sec": round(ips_i, 1),
                               "timed_runs": runs_i,
                               "wall_s": round(wall_i, 4)},
                    "compiled": {"sim_iterations_per_sec": round(ips_c, 1),
                                 "timed_runs": runs_c,
                                 "wall_s": round(wall_c, 4)},
                    "speedup_compiled": round(ips_c / ips_i, 2),
                })
                rows.append(row)
                anchor = (f" paper={row['paper_pct']}"
                          f" err={row['error_pts']:+.1f}"
                          if row["paper_pct"] else "")
                print(f"{app:7s} {mode:6s} N={n:4d}  "
                      f"eff={row['efficiency_pct']:5.1f}%{anchor}  "
                      f"interp {ips_i:7.1f} it/s  compiled {ips_c:7.1f} "
                      f"it/s  ({row['speedup_compiled']:.1f}x, "
                      f"agree {rel:.1e})")
    return rows


def predict_rows(min_wall_s: float, min_runs: int,
                 engine: str = "numpy") -> list[dict]:
    """Weak-scaling predictions at 1024-4096 ranks: compiled-only timing
    (one interpreted run at 1024 keeps the agreement guard honest at the
    first beyond-prototype tier)."""
    rows = []
    for app, factory in ALL_APPS.items():
        model = factory()
        for n in PREDICT_RANKS:
            ev = model._eval("weak", n)
            sim = model._simulate("weak", n)
            rel = None
            if n == PREDICT_RANKS[0]:
                rel = _agreement_rel(model, "weak", n)
                assert rel <= AGREEMENT_RTOL, \
                    f"{app}/weak@{n}: compiled deviates {rel:.2e}"
            ips_c, runs_c, wall_c = _iterations_per_sec(
                model, "weak", n, min_wall_s, min_runs, "compiled",
                engine)
            row = _row(model, app, "weak", n, ev, sim)
            row.update({
                "prediction": True,
                "engine": engine,
                "agreement_rel": rel,
                "compiled": {"sim_iterations_per_sec": round(ips_c, 1),
                             "timed_runs": runs_c,
                             "wall_s": round(wall_c, 4)},
            })
            rows.append(row)
            print(f"{app:7s} weak   N={n:4d}  "
                  f"eff={row['efficiency_pct']:5.1f}% (prediction)  "
                  f"compiled {ips_c:7.1f} it/s"
                  + (f"  (agree {rel:.1e})" if rel is not None else ""))
    return rows


def scenario_rows(ranks, n_scenarios: int, min_wall_s: float,
                  min_runs: int, engine: str = "numpy") -> list[dict]:
    """Monte-Carlo scenario sweeps (PR 6): N perturbed copies of one
    weak-scaling iteration — per-scenario compute skew (0.9-1.1x) and
    point-to-point byte jitter (0.8-1.2x) — as ONE array program
    (``run_program_scenarios``) vs the per-binding lane
    (``rebind_program`` + ``run_program_many``, which probes each
    distinct payload).  Every timed repetition draws fresh scales, so
    neither lane reuses a warm bind; the first draw cross-checks batched
    against per-binding results to <=1e-9 (and the batched lane against
    the interpreter via ``check=``)."""
    import numpy as np

    from repro.core.exanet.program_compiled import (extract_data,
                                                    rebind_program)
    rows = []
    for app, factory in ALL_APPS.items():
        model = factory()
        for n in ranks:
            prog = model.emit_iteration("weak", n)
            mpi = model.mpi_for(n)
            mpi.run_program(prog, backend="compiled")  # warm artifact
            comp, post, _ = extract_data(prog)
            base_c = np.array(comp, dtype=np.float64)
            base_p = np.array(post, dtype=np.float64)
            rng = np.random.default_rng(n)

            def draw():
                return (rng.uniform(0.9, 1.1, n_scenarios),
                        rng.uniform(0.8, 1.2, n_scenarios))

            def per_binding(cs, bs):
                progs = [rebind_program(prog,
                                        compute_us=base_c * c,
                                        post_nbytes=np.rint(base_p * b))
                         for c, b in zip(cs, bs)]
                return mpi.run_program_many(progs, backend="compiled",
                                            engine=engine)

            # agreement: batched vs per-binding on one draw, plus the
            # interpreter cross-check built into run_program_scenarios
            cs, bs = draw()
            got = mpi.run_program_scenarios(
                prog, compute_scale=cs, byte_scale=bs, engine=engine,
                check=3, rtol=AGREEMENT_RTOL)
            ref = per_binding(cs, bs)
            rel = max(abs(g.latency_us - r.latency_us)
                      / max(abs(r.latency_us), 1e-12)
                      for g, r in zip(got, ref))
            assert rel <= AGREEMENT_RTOL, \
                f"{app}@{n}: scenario batch deviates {rel:.2e}"

            lanes = {}
            for lane, fn in (("batched", lambda c, b:
                              mpi.run_program_scenarios(
                                  prog, compute_scale=c, byte_scale=b,
                                  engine=engine)),
                             ("per_binding", per_binding)):
                runs, wall = 0, 0.0
                t0 = time.perf_counter()
                while wall < min_wall_s or runs < min_runs:
                    c, b = draw()
                    fn(c, b)
                    runs += 1
                    wall = time.perf_counter() - t0
                lanes[lane] = {
                    "scenarios_per_sec": round(n_scenarios * runs / wall,
                                               1),
                    "timed_runs": runs, "wall_s": round(wall, 4)}
            row = {"app": app, "mode": "weak", "nranks": n,
                   "engine": engine, "n_scenarios": n_scenarios,
                   "agreement_rel": rel, **lanes,
                   "batch_speedup": round(
                       lanes["batched"]["scenarios_per_sec"]
                       / lanes["per_binding"]["scenarios_per_sec"], 2)}
            rows.append(row)
            print(f"{app:7s} scen   N={n:4d}  x{n_scenarios}  "
                  f"batched {lanes['batched']['scenarios_per_sec']:8.1f} "
                  f"scen/s  per-binding "
                  f"{lanes['per_binding']['scenarios_per_sec']:8.1f}  "
                  f"({row['batch_speedup']:.1f}x, agree {rel:.1e})")
    return rows


def main(out_path: str = "BENCH_apps.json", smoke: bool = False,
         min_runs: int = 5, engine: str = "numpy") -> None:
    ranks = SMOKE_RANKS if smoke else RANKS
    min_wall = 0.05 if smoke else 0.2
    rows = sweep(ranks, min_wall, min_runs, engine)
    preds = [] if smoke else predict_rows(min_wall, min_runs, engine)
    scen = scenario_rows((max(ranks),), 8 if smoke else 32,
                         min_wall, 2 if smoke else min(min_runs, 3),
                         engine)
    out: dict = {"ranks": list(ranks),
                 "prediction_ranks": [] if smoke else list(PREDICT_RANKS),
                 "min_runs": min_runs,
                 "engine": engine,
                 "agreement_rtol": AGREEMENT_RTOL,
                 "results": rows, "predictions": preds,
                 "scenario_results": scen}
    betas = {f"{r['app']}/{r['mode']}": {"beta": r["beta"],
                                         "alpha_retired": r["alpha_retired"]}
             for r in rows if r["nranks"] == max(ranks)}
    out["beta_vs_alpha_retired"] = betas
    if not smoke:
        # acceptance keys: full sweeps only (see module docstring)
        err512 = [abs(r["error_pts"]) for r in rows
                  if r["nranks"] == 512 and r["error_pts"] is not None]
        err2 = [abs(r["error_pts"]) for r in rows
                if r["nranks"] == 2 and r["error_pts"] is not None]
        at512 = [r for r in rows if r["nranks"] == 512]
        ips512 = [r["compiled"]["sim_iterations_per_sec"] for r in at512]
        spd512 = [r["speedup_compiled"] for r in at512]
        out["table3_max_abs_error_pts_512"] = max(err512)
        out["prediction_max_abs_error_pts_2"] = max(err2)
        out["iters_per_sec_at_512"] = {"min": min(ips512),
                                       "max": max(ips512)}
        out["interp_iters_per_sec_at_512"] = {
            "min": min(r["interp"]["sim_iterations_per_sec"]
                       for r in at512),
            "max": max(r["interp"]["sim_iterations_per_sec"]
                       for r in at512)}
        out["compiled_speedup_at_512"] = {"min": min(spd512),
                                          "max": max(spd512)}
        out["compiled_max_ranks"] = max(
            (r["nranks"] for r in preds), default=None)
        sb = [r["batch_speedup"] for r in scen]
        out["scenario_batch_speedup_at_512"] = {"min": min(sb),
                                                "max": max(sb)}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {out_path}")
    worst = max((r for r in rows), key=lambda r: r["beta"])
    print(f"largest residual: {worst['app']}/{worst['mode']} "
          f"beta={worst['beta']:.3f} vs retired alpha="
          f"{worst['alpha_retired']:.2f}")
    if not smoke:
        print(f"Table 3 max |error|: {out['table3_max_abs_error_pts_512']}"
              f" pts at 512 (calibrated), "
              f"{out['prediction_max_abs_error_pts_2']} pts at 2 "
              f"(predicted); compiled {out['iters_per_sec_at_512']['min']:.0f}"
              f"-{out['iters_per_sec_at_512']['max']:.0f} sim-iters/s @512 "
              f"({out['compiled_speedup_at_512']['min']:.1f}-"
              f"{out['compiled_speedup_at_512']['max']:.1f}x interp), "
              f"predictions to {out['compiled_max_ranks']} ranks")
        assert out["table3_max_abs_error_pts_512"] <= 0.5, \
            "512-rank cells are calibrated and must match Table 3"
        assert out["prediction_max_abs_error_pts_2"] <= 7.0, \
            "2-rank predictions must stay in the DESIGN.md §7 band"
        assert out["compiled_speedup_at_512"]["min"] >= 8.0, \
            "compiled run_program must be >=8x the interpreter at 512"
        assert out["scenario_batch_speedup_at_512"]["min"] >= 5.0, \
            "batched scenario sweep must be >=5x the per-binding lane " \
            "at 512 ranks"
    # the IR's whole point: the residual must not exceed the retired fudge
    for k, v in betas.items():
        assert v["beta"] <= v["alpha_retired"] + 1e-9, \
            f"{k}: beta {v['beta']} exceeds retired alpha {v['alpha_retired']}"


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--min-runs", type=int, default=5,
                    help="floor on timed runs per throughput row")
    ap.add_argument("--engine", default="numpy",
                    choices=("numpy", "jax"),
                    help="scan backend of the compiled lanes")
    args = ap.parse_args()
    main(smoke=args.smoke, min_runs=args.min_runs, engine=args.engine)
