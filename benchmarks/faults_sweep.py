"""Benchmark: fault & congestion scenario engine (DESIGN.md §2.10).

One artifact (``BENCH_faults.json``) with five blocks:

* **degradation classes** — paper-style weak-scaling efficiency for
  HPCG/LAMMPS/miniFE under one sampled fault of each class: a dead link
  (structural: routes change, the app runs on a *degraded machine*
  variant keyed by the fault signature), a hot link, a lossy link
  (§4.5.3 block-replay cost ``1/(1-p)``), extra per-link latency, and a
  slow "hot" rank.  Non-structural classes ride the batched scenario
  axes (``link_scale`` / ``link_latency_us`` / ``compute_scale``); every
  row carries a ≤1e-9 degraded compiled-vs-interpreted agreement guard.
* **Monte-Carlo fault sweep** — N sampled link-degradation sets
  (hot + lossy + retimer latency) × one app iteration, costed as ONE
  ``run_program_scenarios`` replay (``batch_fault_axes``: column ``j``
  carries fault set ``j``) vs the per-fault-set lane (one statically
  degraded ``ExanetMPI`` twin per set — fresh topology, routes and
  compiled artifact each time, which is what batching amortizes);
  fresh fault draws every timed repetition, first draw cross-checked
  lane-vs-lane and against the interpreter to ≤1e-9.
* **interference curves** — a halo-exchange app co-located with a
  background tenant on *shared* QFDBs (``interleave_qfdb``: both
  tenants' cross-board traffic funnels through each board's single
  network MPSoC), neighbour load swept as ``byte_scale`` columns on the
  background posts only; app efficiency vs neighbour load is emergent
  link contention, not a fitted model.
* **straggler replanning** — train-step time under a slow rank with and
  without replanning: the healthy winner of ``plan_train_sync`` costed
  on the straggler machine vs a fresh plan searched *against* it
  (``TrainSim(rank_compute_scale=...)``), reporting the recovered
  margin; the simulated step-time series is fed through
  ``StragglerMonitor`` to show the ``on_straggle`` hook firing.
* **§5.3 graceful-degradation floor** — the IP-overlay-vs-native ladder
  (native wire 6.42, overlay 4.7, baseline 1.3 Gb/s on the paper's
  5-hop path) as the floor degraded native transport is measured
  against: the fraction of sampled fault sets whose bottleneck still
  beats the overlay.

Run: PYTHONPATH=src python benchmarks/faults_sweep.py [--smoke]
         [--min-runs N] [--engine numpy|jax]

``--smoke`` (the CI benchmark step) shrinks rank counts and fault-set
counts but still runs every block end to end, including the degraded
agreement guards; per the BENCH schema rules (DESIGN.md §6), smoke
artifacts omit the acceptance keys (``mc_batch_speedup_at_512``,
``degraded_agreement_max``, ``interference_min_efficiency``,
``straggler_recovered_margin``) so a smoke run can never masquerade as
the full sweep.
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.exanet.apps import ALL_APPS  # noqa: E402
from repro.core.exanet.faults import (FaultSpec, UnroutableError,  # noqa: E402
                                      all_link_keys, batch_fault_axes,
                                      sample_fault_spec)
from repro.core.exanet.interference import (background_stream,  # noqa: E402
                                            interleave_qfdb, merge_tenants,
                                            neighbor_load_byte_scale)
from repro.core.exanet.ip_overlay import overlay_vs_native_gap  # noqa: E402
from repro.core.machine import ExanetMachine  # noqa: E402

RANKS = (8, 64, 512)
SMOKE_RANKS = (8,)
AGREEMENT_RTOL = 1e-9
#: one sampled fault per class per (app, rank count); structural classes
#: select a degraded machine, the rest ride the batched axes
CLASSES = ("dead_link", "hot_link", "lossy_link", "extra_latency",
           "slow_rank")
LOADS = (0.0, 0.5, 1.0, 2.0, 4.0)


def _occupied_links(mpi, nranks: int) -> list:
    """Links with both endpoints inside the rank-hosting MPSoC prefix
    (the machine places 1 rank/MPSoC, QFDB-major), so a sampled fault
    can actually sit on a route the program uses."""
    used = mpi.rank_core(nranks - 1) // mpi.p.cores_per_mpsoc + 1
    keys = [k for k in all_link_keys(mpi.topo)
            if k[1] < used and k[2] < used]
    return keys or all_link_keys(mpi.topo)[:1]


def _class_spec(cls: str, rng, mpi, nranks: int) -> FaultSpec:
    links = _occupied_links(mpi, nranks)
    pick = lambda k: [links[i] for i in  # noqa: E731
                      rng.choice(len(links), size=min(k, len(links)),
                                 replace=False)]
    if cls == "dead_link":
        return FaultSpec(dead_links=pick(1))
    if cls == "hot_link":
        return FaultSpec(slow_links={k: float(rng.uniform(2.0, 8.0))
                                     for k in pick(2)})
    if cls == "lossy_link":
        return FaultSpec(lossy_links={k: float(rng.uniform(0.05, 0.3))
                                      for k in pick(2)})
    if cls == "extra_latency":
        return FaultSpec(link_extra_latency_us={k: 10.0 for k in pick(2)})
    if cls == "slow_rank":
        return FaultSpec(slow_ranks={int(rng.integers(nranks)):
                                     float(rng.uniform(2.0, 6.0))})
    raise ValueError(cls)


def _deg_agreement(mpi, prog) -> float:
    """Max relative deviation (latency + per-rank clocks) between the
    executors on one *degraded* machine."""
    a = mpi.run_program(prog, backend="interp")
    b = mpi.run_program(prog, backend="compiled")
    rel = abs(b.latency_us - a.latency_us) / max(abs(a.latency_us), 1e-12)
    for x, y in zip(a.clocks, b.clocks):
        rel = max(rel, abs(y - x) / max(abs(x), 1e-12))
    return rel


def class_rows(machine, ranks, engine: str, guards: list) -> list[dict]:
    """Weak-scaling efficiency per (app, degradation class, rank count).
    Degraded efficiency = healthy efficiency x t_healthy / t_degraded —
    the paper's Table-3 metric with the iteration slowed by the fault."""
    rows = []
    for app, factory in ALL_APPS.items():
        model = factory()
        for n in ranks:
            prog = model.emit_iteration("weak", n)
            mpi = machine._mpi_for(n)
            eff_h = model._eval("weak", n)["efficiency"]
            t_h = mpi.run_program(prog, backend="compiled",
                                  engine=engine).latency_us
            for cls in CLASSES:
                rng = np.random.default_rng(
                    abs(hash((app, cls, n))) % (1 << 32))
                for attempt in range(20):
                    spec = _class_spec(cls, rng, mpi, n)
                    try:
                        if spec.degrades_structure:
                            dmpi = machine.degraded(spec)._mpi_for(n)
                            rel = _deg_agreement(dmpi, prog)
                            t_d = dmpi.run_program(
                                prog, backend="compiled",
                                engine=engine).latency_us
                        else:
                            axes = batch_fault_axes([spec], prog)
                            res = machine.cost_program_scenarios(
                                prog, **axes, engine=engine, check=1,
                                rtol=AGREEMENT_RTOL)
                            t_d = res[0].latency_us
                            rel = 0.0  # check=1 raised if > rtol
                        break
                    except UnroutableError:
                        continue  # this draw cut the network; redraw
                else:
                    raise RuntimeError(f"no routable {cls} draw at {n}")
                assert rel <= AGREEMENT_RTOL, \
                    f"{app}/{cls}@{n}: degraded compiled deviates {rel:.2e}"
                guards.append(rel)
                eff_d = eff_h * t_h / t_d
                row = {"app": app, "mode": "weak", "nranks": n,
                       "class": cls, "fault": spec.signature(),
                       "structural": spec.degrades_structure,
                       "t_healthy_us": round(t_h, 2),
                       "t_degraded_us": round(t_d, 2),
                       "slowdown": round(t_d / t_h, 4),
                       "efficiency_pct": round(100 * eff_h, 1),
                       "degraded_efficiency_pct": round(100 * eff_d, 1),
                       "agreement_rel": rel}
                rows.append(row)
                print(f"{app:7s} {cls:13s} N={n:4d}  "
                      f"eff {row['efficiency_pct']:5.1f}% -> "
                      f"{row['degraded_efficiency_pct']:5.1f}%  "
                      f"(x{row['slowdown']:.2f}, {spec.signature()})")
    return rows


def node_failure_block(machine, engine: str) -> dict:
    """Structural node failure at 8 ranks under *block* placement (rank
    = core: 8 ranks on MPSoCs 0-1, MPSoCs 2-3 rank-free): a dead
    intra-QFDB link forces the crossbar relay, a dead relay MPSoC forces
    the *next* relay, and killing every relay is a diagnosable cut
    (``UnroutableError``) — the reroute ladder of DESIGN.md §2.10 as
    data."""
    from repro.core.exanet.mpi import ExanetMPI
    model = ALL_APPS["hpcg"]()
    prog = model.emit_iteration("weak", 8)
    t_h = ExanetMPI().run_program(prog, backend="compiled",
                                  engine=engine).latency_us
    dead_link = FaultSpec(dead_links=[("intra_qfdb", 0, 1)])
    relay_down = FaultSpec(dead_links=[("intra_qfdb", 0, 1)],
                           dead_mpsocs=[2])
    cut = FaultSpec(dead_links=[("intra_qfdb", 0, 1)], dead_mpsocs=[2, 3])
    hmpi = ExanetMPI()
    p2p_h = hmpi.net.rdv_latency(65536, hmpi.topo.route(0, 4))
    out = {"nranks": 8, "placement": "block", "t_healthy_us": round(t_h, 2),
           "p2p_healthy_us": round(p2p_h, 2),
           "route_healthy": [f"{l.kind}({l.src_mpsoc},{l.dst_mpsoc})"
                             for l in hmpi.topo.route(0, 4).links]}
    for name, spec in (("dead_link", dead_link),
                       ("dead_link_and_relay", relay_down)):
        dmpi = ExanetMPI(faults=spec)
        t = dmpi.run_program(prog, backend="compiled",
                             engine=engine).latency_us
        path = dmpi.topo.route(0, 4)
        out[name] = {"fault": spec.signature(), "t_us": round(t, 2),
                     "slowdown": round(t / t_h, 4),
                     # the app hides the reroute behind compute; the raw
                     # point-to-point latency shows its true cost
                     "p2p_us": round(dmpi.net.rdv_latency(65536, path), 2),
                     "route": [f"{l.kind}({l.src_mpsoc},{l.dst_mpsoc})"
                               for l in path.links],
                     "route_cache": dmpi.topo.route_cache_info()}
    try:
        ExanetMPI(faults=cut).run_program(prog)
        raise AssertionError("cut partition must be unroutable")
    except UnroutableError as e:
        out["cut"] = {"fault": cut.signature(), "diagnosis": str(e)}
    print(f"node_failure: p2p {out['p2p_healthy_us']}us -> "
          f"{out['dead_link']['p2p_us']}us (dead link) -> "
          f"{out['dead_link_and_relay']['p2p_us']}us (+dead relay), "
          f"cut -> UnroutableError")
    return out


def mc_rows(machine, n: int, n_sets: int, n_per: int, min_wall_s: float,
            min_runs: int, engine: str, guards: list) -> dict:
    """Monte-Carlo link-degradation sweep at ``n`` ranks: ``n_sets``
    sampled fault sets as ONE batched replay (``batch_fault_axes``) vs
    one statically degraded ``ExanetMPI`` twin per set.  Fresh draws per
    timed repetition; the lanes are cross-checked on the first draw."""
    from repro.core.exanet.mpi import ExanetMPI
    model = ALL_APPS["hpcg"]()
    prog = model.emit_iteration("weak", n)
    mpi = machine._mpi_for(n)
    mpi.run_program(prog, backend="compiled")  # warm artifact + routes
    rng = np.random.default_rng(n)

    def draw(k: int) -> list[FaultSpec]:
        return [sample_fault_spec(rng, mpi.topo, n_slow_links=2,
                                  n_lossy_links=1, extra_latency_us=5.0)
                for _ in range(k)]

    def batched(specs):
        return machine.cost_program_scenarios(
            prog, **batch_fault_axes(specs, prog), engine=engine)

    def per_fault_set(specs):
        out = []
        for s in specs:
            twin = ExanetMPI(mpi.p, ranks_per_mpsoc=mpi._rpm, faults=s,
                             cache=False)
            out.append(twin.run_program(prog, backend="compiled",
                                        engine=engine))
        return out

    # cross-check: batched columns == statically-degraded twins, plus
    # the interpreter twin check built into run_program_scenarios
    specs0 = draw(n_per)
    got = machine.cost_program_scenarios(
        prog, **batch_fault_axes(specs0, prog), engine=engine,
        check=min(2, n_per), rtol=AGREEMENT_RTOL)
    ref = per_fault_set(specs0)
    rel = max(abs(g.latency_us - r.latency_us)
              / max(abs(r.latency_us), 1e-12)
              for g, r in zip(got, ref))
    assert rel <= AGREEMENT_RTOL, \
        f"mc@{n}: batched fault lane deviates {rel:.2e}"
    guards.append(rel)

    lanes = {}
    for lane, fn, k in (("batched", batched, n_sets),
                        ("per_fault_set", per_fault_set, n_per)):
        runs, wall = 0, 0.0
        t0 = time.perf_counter()
        while wall < min_wall_s or runs < min_runs:
            fn(draw(k))
            runs += 1
            wall = time.perf_counter() - t0
        lanes[lane] = {"fault_sets_per_sec": round(k * runs / wall, 2),
                       "n_fault_sets": k, "timed_runs": runs,
                       "wall_s": round(wall, 4)}
    speedup = (lanes["batched"]["fault_sets_per_sec"]
               / lanes["per_fault_set"]["fault_sets_per_sec"])
    lat = [r.latency_us for r in batched(draw(n_sets))]
    out = {"app": "hpcg", "nranks": n, "n_fault_sets": n_sets,
           "engine": engine, "agreement_rel": rel, **lanes,
           "batch_speedup": round(speedup, 2),
           "latency_us": {"p50": round(float(np.median(lat)), 2),
                          "p95": round(float(np.percentile(lat, 95)), 2),
                          "max": round(float(np.max(lat)), 2)}}
    print(f"mc      N={n:4d}  x{n_sets}  batched "
          f"{lanes['batched']['fault_sets_per_sec']:8.2f} sets/s  "
          f"per-fault-set "
          f"{lanes['per_fault_set']['fault_sets_per_sec']:8.2f}  "
          f"({speedup:.1f}x, agree {rel:.1e})")
    return out


def interference_block(n_app: int, n_bg: int, engine: str,
                       guards: list) -> dict:
    """App efficiency vs neighbour load on shared QFDBs: the whole curve
    is one ``byte_scale`` replay over the merged two-tenant Program.
    Runs under *block* placement (rank = core) — ``interleave_qfdb``
    splits each board's cores between the tenants, so both tenants'
    cross-board traffic funnels through the board's single network
    MPSoC onto shared mezzanine links."""
    from repro.core.exanet.mpi import ExanetMPI
    from repro.core.program import halo3d
    app = halo3d(n_app, 65536, compute_us=50.0)
    bg = background_stream(n_bg, iters=12, nbytes=131072)
    a_ranks, b_ranks = interleave_qfdb(n_app, n_bg)
    mix = merge_tenants(app, bg, a_ranks, b_ranks)
    bs = neighbor_load_byte_scale(mix, LOADS)
    res = ExanetMPI().run_program_scenarios(
        mix.program, byte_scale=bs, engine=engine, check=2,
        rtol=AGREEMENT_RTOL)
    guards.append(0.0)  # check=2 raised if > rtol
    app_us = [mix.app_latency_us(r) for r in res]
    eff = [app_us[0] / t for t in app_us]
    out = {"n_app": n_app, "n_bg": n_bg, "engine": engine,
           "placement": "interleave_qfdb",
           "loads": list(LOADS),
           "app_us": [round(t, 2) for t in app_us],
           "efficiency": [round(e, 4) for e in eff]}
    print("interf  " + "  ".join(f"load {ld:g}: {e:.3f}"
                                 for ld, e in zip(LOADS, eff)))
    assert all(b <= a + 1e-9 for a, b in zip(eff, eff[1:])), \
        f"interference must be monotone in neighbour load: {eff}"
    return out


def straggler_block(machine, nranks: int, smoke: bool,
                    engine: str) -> dict:
    """Train-step time under one hot rank, with and without replanning,
    plus the ``StragglerMonitor.on_straggle`` hook firing on the
    simulated step-time series."""
    from repro.core.planner import CollectivePlanner
    from repro.runtime.fault import StragglerMonitor
    from repro.train.cosim import TrainSim, TrainStepSpec
    spec = TrainStepSpec(nranks=nranks)
    rank, factor = 5 % nranks, 4.0
    rcs = np.ones(nranks)
    rcs[rank] = factor
    healthy = TrainSim(spec, machine)
    slow = TrainSim(spec, machine, rank_compute_scale=rcs)
    planner = CollectivePlanner(machine, fidelity="sim", engine=engine)
    gens = 1 if smoke else 2
    h_plan = planner.plan_train_sync(healthy, generations=gens,
                                     engine=engine, check=1)
    t_noreplan = float(slow.cost_candidates([h_plan.chosen],
                                            engine=engine, check=1)[0])
    s_plan = planner.plan_train_sync(slow, generations=gens,
                                     engine=engine, check=1)
    recovered = (t_noreplan - s_plan.step_us) / t_noreplan

    # the runtime hook: healthy cadence, then the straggler appears
    events: list[dict] = []
    mon = StragglerMonitor(
        deadline_factor=1.5,
        on_straggle=lambda step, dt, deadline: events.append(
            {"step": step, "dt_us": round(dt, 2),
             "deadline_us": round(deadline, 2)}))
    for step, dt in enumerate([h_plan.step_us] * 12 + [t_noreplan] * 4):
        mon.observe(step, dt)

    out = {"nranks": nranks, "straggler_rank": rank,
           "compute_factor": factor, "engine": engine,
           "t_healthy_us": round(h_plan.step_us, 2),
           "t_straggler_no_replan_us": round(t_noreplan, 2),
           "t_straggler_replanned_us": round(s_plan.step_us, 2),
           "recovered_margin": round(recovered, 4),
           "healthy_plan": repr(h_plan.chosen),
           "replanned": repr(s_plan.chosen),
           "plan_flipped": h_plan.chosen != s_plan.chosen,
           "machines": {"healthy": h_plan.machine,
                        "degraded_search_space": s_plan.evaluated},
           "monitor": {"deadline_factor": mon.factor,
                       "flagged_steps": mon.flagged,
                       "on_straggle_events": events}}
    assert s_plan.step_us <= t_noreplan * (1 + 1e-9), \
        "replanning must not lose to the stale plan"
    assert mon.flagged and events, \
        "the straggler steps must trip the monitor hook"
    print(f"straggl N={nranks:4d}  healthy {h_plan.step_us:.0f}us  "
          f"stale plan {t_noreplan:.0f}us  replanned "
          f"{s_plan.step_us:.0f}us  (recovered {100 * recovered:.1f}%, "
          f"{len(events)} on_straggle events)")
    return out


def planner_replan_block(machine, engine: str) -> dict:
    """Collective planning against a structurally degraded machine: the
    winner cache is keyed by the machine *name*, which carries the fault
    signature, so healthy winners never leak onto broken fabrics."""
    from repro.core.planner import CollectivePlanner
    spec = FaultSpec(dead_links=[("mezz", 0, 4)])
    nbytes, p = 262144, 64
    h = CollectivePlanner(machine, fidelity="sim",
                          engine=engine).plan("allreduce", nbytes, p)
    d = CollectivePlanner(machine.degraded(spec), fidelity="sim",
                          engine=engine).plan("allreduce", nbytes, p)
    out = {"op": "allreduce", "nbytes": nbytes, "nranks": p,
           "fault": spec.signature(),
           "healthy": {"schedule": h.schedule, "machine": h.machine,
                       "cost_us": round(h.cost_s * 1e6, 2)},
           "degraded": {"schedule": d.schedule, "machine": d.machine,
                        "cost_us": round(d.cost_s * 1e6, 2)},
           "plan_flipped": h.schedule != d.schedule,
           "degradation_cost": round(d.cost_s / h.cost_s, 4)}
    assert h.machine != d.machine, \
        "degraded machine must carry the fault signature in its name"
    print(f"replan  allreduce {nbytes}B@{p}: {h.schedule} "
          f"{out['healthy']['cost_us']}us -> {d.schedule} "
          f"{out['degraded']['cost_us']}us on {d.machine}")
    return out


def overlay_block(mc_specs: list[FaultSpec]) -> dict:
    """§5.3 ladder + the graceful-degradation floor: which sampled fault
    sets leave native transport still worth more than the IP overlay."""
    gap = overlay_vs_native_gap()
    paper = {"native_wire_gbps": 6.42, "overlay_gbps": 4.7,
             "baseline_gbps": 1.3}
    floors = []
    for s in mc_specs:
        worst = max([s.link_slow(*k) for k in s.degraded_link_keys()],
                    default=1.0)
        floors.append(gap["native_wire_gbps"] / worst)
    above = [f for f in floors if f > gap["overlay_gbps"]]
    out = {**gap, "paper": paper,
           "rel_err": {k: round(abs(gap[k] - v) / v, 4)
                       for k, v in paper.items()},
           "degraded_native_floor_gbps": {
               "min": round(min(floors), 3) if floors else None,
               "p50": round(float(np.median(floors)), 3)
               if floors else None},
           "native_beats_overlay_fraction":
               round(len(above) / len(floors), 4) if floors else None}
    assert gap["baseline_gbps"] < gap["overlay_gbps"] \
        < gap["native_wire_gbps"], "§5.3 ladder ordering"
    print(f"overlay native {gap['native_wire_gbps']:.2f}  overlay "
          f"{gap['overlay_gbps']:.2f}  baseline "
          f"{gap['baseline_gbps']:.2f} Gb/s; degraded native floor "
          f"p50 {out['degraded_native_floor_gbps']['p50']} Gb/s")
    return out


def main(out_path: str = "BENCH_faults.json", smoke: bool = False,
         min_runs: int = 3, engine: str = "numpy") -> None:
    machine = ExanetMachine()
    ranks = SMOKE_RANKS if smoke else RANKS
    min_wall = 0.05 if smoke else 0.2
    guards: list[float] = []
    rows = class_rows(machine, ranks, engine, guards)
    node = node_failure_block(machine, engine)
    n_mc = max(ranks)
    mc = mc_rows(machine, n_mc, 8 if smoke else 32, 2 if smoke else 3,
                 min_wall, 1 if smoke else min(min_runs, 2), engine,
                 guards)
    # tenants must span QFDBs (>=16 ranks each at 8 cores/tenant/board)
    # for their cross-board traffic to meet on the mezzanine links
    interf = interference_block(16 if smoke else 32,
                                16 if smoke else 32, engine, guards)
    strag = straggler_block(machine, 16 if smoke else 64, smoke, engine)
    replan = planner_replan_block(machine, engine)
    rng = np.random.default_rng(7)
    topo = machine._mpi_for(n_mc).topo
    overlay = overlay_block([sample_fault_spec(
        rng, topo, n_slow_links=2, n_lossy_links=1)
        for _ in range(4 if smoke else 32)])
    out: dict = {"ranks": list(ranks), "engine": engine,
                 "min_runs": min_runs,
                 "agreement_rtol": AGREEMENT_RTOL,
                 "classes": list(CLASSES),
                 "class_results": rows,
                 "node_failure": node,
                 "monte_carlo": mc,
                 "interference": interf,
                 "straggler_replanning": strag,
                 "planner_replanning": replan,
                 "ip_overlay_floor": overlay}
    if not smoke:
        # acceptance keys: full sweeps only (see module docstring)
        out["mc_batch_speedup_at_512"] = mc["batch_speedup"]
        out["degraded_agreement_max"] = max(guards)
        out["interference_min_efficiency"] = min(interf["efficiency"])
        out["straggler_recovered_margin"] = strag["recovered_margin"]
        assert mc["nranks"] == 512
        assert out["mc_batch_speedup_at_512"] >= 10.0, \
            "batched fault sweep must be >=10x the per-fault-set lane " \
            "at 512 ranks"
        assert out["degraded_agreement_max"] <= AGREEMENT_RTOL
        assert out["interference_min_efficiency"] < 0.9, \
            "the neighbour-load sweep must show real contention"
        assert out["straggler_recovered_margin"] >= 0.0
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {out_path}")
    if not smoke:
        print(f"batched fault sweep {mc['batch_speedup']:.1f}x @512; "
              f"worst degraded agreement {out['degraded_agreement_max']:.1e}; "
              f"interference floor "
              f"{out['interference_min_efficiency']:.3f}; straggler "
              f"margin {100 * strag['recovered_margin']:.1f}%")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--min-runs", type=int, default=3,
                    help="floor on timed runs per throughput row")
    ap.add_argument("--engine", default="numpy",
                    choices=("numpy", "jax"),
                    help="scan backend of the compiled/batched lanes")
    args = ap.parse_args()
    main(smoke=args.smoke, min_runs=args.min_runs, engine=args.engine)
