"""Collective-schedule synthesis sweep over the OSU (nbytes x nranks)
grid -> BENCH_synth.json (schema: DESIGN.md §6).

Per cell, :func:`repro.core.synth.search.search_cell` runs the full
skeleton search (Split / Pipeline / Hierarchical / Dissemination
combinators of the round algebra) with batched compiled fitness, and
each row reports the best hand-written menu cost vs the synthesized
winner, the search throughput (candidates/s), and both synthesis gates
(semantic contribution check + interpreter agreement <=1e-9).

Two acceptance checks ride on top (ISSUE 8):

* **win cells** — the synthesized schedule must beat the best
  hand-written menu schedule (accelerator included) on >= 3 grid cells;
* **Fig. 19 crossover** — the search family contains no accelerator,
  yet bisecting accel-vs-synthesized cost with the planner's
  :func:`~repro.core.planner.crossover_bytes` must re-derive the
  paper's sw/accel crossover: accel wins below, synthesized software
  wins above.  The menu-derived crossover (what the planner would
  compute from hand-written schedules alone) is reported next to it.

``--write-cache default`` regenerates the committed winner-cache
artifact ``src/repro/core/synth/winners.json`` the planner loads as its
``synthesized`` candidate source; only winners that beat the software
menu are cached.

Run:
  PYTHONPATH=src python benchmarks/synth_sweep.py [--smoke] [--engine jax]
      [--pop 24] [--gens 6] [--write-cache default]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.machine import ExanetMachine  # noqa: E402
from repro.core.planner import crossover_bytes  # noqa: E402
from repro.core.synth.search import (WinnerCache, registered,  # noqa: E402
                                     search_cell)

#: OSU-style grid: latency-bound, crossover-adjacent, bandwidth-bound
GRID_NRANKS = (16, 64, 256)
GRID_NBYTES = (64, 4096, 65536, 262144)
SMOKE_NRANKS = (64,)
SMOKE_NBYTES = (4096, 65536)


def derive_crossover(machine: ExanetMachine, nranks: int,
                     winners: list, *, hi: int = 1 << 22) -> dict:
    """Re-derive the Fig. 19 sw/accel crossover by bisection.

    ``winners`` are the synthesized schedules found at this rank count —
    none of them saw the accelerator during search.  The same
    :func:`crossover_bytes` the planner uses for its eager threshold
    bisects accel cost against (a) the synthesized family and (b) the
    hand-written software menu, so the two crossovers are directly
    comparable."""
    from repro.core.synth.search import _menu_costs

    def accel(n: int) -> float:
        from repro.core.exanet.schedules import HierarchicalAccelAllreduce
        return machine.cost_s(HierarchicalAccelAllreduce(), nranks, n,
                              fidelity="sim")

    def best_synth(n: int) -> float:
        return min(machine.cost_s(w, nranks, n, fidelity="sim")
                   for w in winners)

    def best_sw_menu(n: int) -> float:
        sw, _ = _menu_costs(machine, nranks, n, "sim")
        return sw[0][1]

    x_synth = crossover_bytes(accel, best_synth, hi=hi)
    x_menu = crossover_bytes(accel, best_sw_menu, hi=hi)

    # spot-check the Fig. 19 shape on both sides of the derived point
    below, above = max(1, x_synth // 4), min(hi, x_synth * 4)
    fig19 = {
        "probe_below": below, "accel_s_below": accel(below),
        "synth_s_below": best_synth(below),
        "probe_above": above, "accel_s_above": accel(above),
        "synth_s_above": best_synth(above),
    }
    fig19["ok"] = (fig19["accel_s_below"] < fig19["synth_s_below"]
                   and fig19["synth_s_above"] < fig19["accel_s_above"])
    return {
        "nranks": nranks,
        "accel_vs_synth_bytes": x_synth,
        "accel_vs_menu_bytes": x_menu,
        "ratio_synth_vs_menu": x_synth / x_menu if x_menu else None,
        "method": "repro.core.planner.crossover_bytes bisection, sim "
                  "fidelity; accel absent from the search family",
        "fig19": fig19,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="2-cell grid, tiny population, for CI")
    ap.add_argument("--engine", default="numpy", choices=("numpy", "jax"),
                    help="scan backend of the batched fitness replays")
    ap.add_argument("--pop", type=int, default=24)
    ap.add_argument("--gens", type=int, default=6)
    ap.add_argument("--refine", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_synth.json")
    ap.add_argument("--write-cache", default=None, metavar="PATH",
                    help="persist menu-beating winners ('default' = the "
                         "committed src/repro/core/synth/winners.json)")
    args = ap.parse_args()
    if args.smoke:
        nranks_grid, nbytes_grid = SMOKE_NRANKS, SMOKE_NBYTES
        pop, gens, refine = 8, 3, 1
    else:
        nranks_grid, nbytes_grid = GRID_NRANKS, GRID_NBYTES
        pop, gens, refine = args.pop, args.gens, args.refine

    cache = None
    if args.write_cache is not None:
        path = (WinnerCache.DEFAULT_PATH if args.write_cache == "default"
                else args.write_cache)
        cache = WinnerCache(path=path)

    machine = ExanetMachine()
    rows = []
    winners_by_nranks: dict[int, list] = {}
    t0 = time.perf_counter()
    i = 0
    for nranks in nranks_grid:
        for nbytes in nbytes_grid:
            res = search_cell(machine, nbytes, nranks, pop=pop, gens=gens,
                              refine=refine, seed=args.seed + i,
                              engine=args.engine)
            i += 1
            rows.append(res.to_row())
            winners_by_nranks.setdefault(nranks, []).append(
                registered(res.winner_name))
            if cache is not None and res.winner_s < res.best_sw_menu_s:
                cache.put(machine.name, res.op, nranks, nbytes,
                          res.placement, spec=res.winner_spec,
                          cost_s=res.winner_s,
                          best_menu_s=res.best_sw_menu_s,
                          menu_name=res.best_sw_menu)
            beats = "WIN " if res.winner_s < res.best_menu_s else "    "
            print(f"{beats}N={nranks:4d} nbytes={nbytes:7d}  "
                  f"synth={res.winner_s:.3e}s  menu={res.best_menu_s:.3e}s"
                  f" ({res.best_menu})  x{res.best_menu_s / res.winner_s:.3f}"
                  f"  {res.candidates_per_s:.0f} cand/s  "
                  f"agree {res.agreement_rel:.1e}")

    win_cells = [r for r in rows if r["winner_s"] < r["best_menu_s"]]
    sw_win_cells = [r for r in rows if r["winner_s"] < r["best_sw_menu_s"]]

    # Fig. 19 crossover at the grid's center rank count
    x_nranks = 64 if 64 in winners_by_nranks else nranks_grid[0]
    crossover = derive_crossover(machine, x_nranks,
                                 winners_by_nranks[x_nranks],
                                 hi=(1 << 18 if args.smoke else 1 << 22))

    out = {
        "smoke": args.smoke, "engine": args.engine, "fidelity": "sim",
        "machine": machine.name, "population": pop, "generations": gens,
        "refine": refine,
        "grid": {"nranks": list(nranks_grid), "nbytes": list(nbytes_grid)},
        "results": rows,
        "n_cells": len(rows),
        "n_win_cells": len(win_cells),
        "n_sw_win_cells": len(sw_win_cells),
        "all_semantic_ok": all(r["semantic_ok"] for r in rows),
        "max_agreement_rel": max(r["agreement_rel"] for r in rows),
        "crossover": crossover,
        "wall_s": round(time.perf_counter() - t0, 2),
    }
    if cache is not None and len(cache):
        out["cache_path"] = cache.save()
        print(f"wrote {len(cache)} winners -> {out['cache_path']}")
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"win cells {len(win_cells)}/{len(rows)} (vs full menu), "
          f"{len(sw_win_cells)} vs sw menu; crossover synth="
          f"{crossover['accel_vs_synth_bytes']}B menu="
          f"{crossover['accel_vs_menu_bytes']}B fig19_ok="
          f"{crossover['fig19']['ok']}")
    print(f"wrote {args.out} ({out['wall_s']}s)")


if __name__ == "__main__":
    main()
