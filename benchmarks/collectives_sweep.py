"""Micro-benchmark: simulated collective throughput across rank counts.

Times event-simulated broadcast + allreduce at 16-256 ranks, with the route
cache / engine path table ON (the refactored default) and OFF (the
pre-refactor per-send ``route()`` recomputation), and writes
``BENCH_collectives.json`` with sends/sec and wall time so the speedup is
tracked in the perf trajectory.

Run: PYTHONPATH=src python benchmarks/collectives_sweep.py [--smoke]

``--smoke`` (used by the CI benchmark step) drops the 256-rank sweep and
shortens the timed windows so perf artifacts stay fresh without slowing CI.
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.exanet import ExanetMPI  # noqa: E402

RANKS = (16, 64, 256)
#: (collective, payload bytes, sends per run at n ranks)
CASES = (
    ("bcast", 1, lambda n: n - 1),
    ("bcast", 4096, lambda n: n - 1),
    ("allreduce", 4096, lambda n: n * (n.bit_length() - 1)),
)


def _time_runs(mpi: ExanetMPI, coll: str, size: int, nranks: int,
               min_wall_s: float) -> tuple[float, int]:
    """(wall seconds, number of runs) for repeated simulations."""
    fn = (lambda: mpi.bcast(size, nranks)) if coll == "bcast" else \
        (lambda: mpi.allreduce(size, nranks, "recursive_doubling"))
    fn()  # warm the caches (when enabled) outside the timed region
    runs, wall = 0, 0.0
    t0 = time.perf_counter()
    while wall < min_wall_s:
        fn()
        runs += 1
        wall = time.perf_counter() - t0
    return wall, runs


def sweep(ranks: tuple[int, ...], min_wall_s: float) -> dict:
    results = []
    for coll, size, sends_per_run in CASES:
        for n in ranks:
            row = {"collective": coll, "size_bytes": size, "nranks": n}
            for mode, cached in (("cached", True), ("uncached", False)):
                mpi = ExanetMPI(cache=cached)
                wall, runs = _time_runs(mpi, coll, size, n, min_wall_s)
                sends = sends_per_run(n) * runs
                row[mode] = {"wall_s": round(wall, 4), "runs": runs,
                             "sends_per_sec": round(sends / wall, 1)}
            row["speedup"] = round(row["cached"]["sends_per_sec"]
                                   / row["uncached"]["sends_per_sec"], 2)
            results.append(row)
            print(f"{coll:9s} {size:5d}B N={n:3d}  "
                  f"cached={row['cached']['sends_per_sec']:>10.0f} sends/s  "
                  f"uncached={row['uncached']['sends_per_sec']:>9.0f}  "
                  f"speedup={row['speedup']:.2f}x")
    top = max(ranks)
    at_top = [r["speedup"] for r in results if r["nranks"] == top]
    out = {"results": results, "top_ranks": top,
           "speedup_at_top_ranks": {"min": min(at_top), "max": max(at_top)}}
    if top == max(RANKS):
        # stable key for the PR-1 acceptance metric (full sweeps only; a
        # --smoke artifact must not masquerade as the 256-rank number)
        out["speedup_at_256_ranks"] = out["speedup_at_top_ranks"]
    return out


def main(out_path: str = "BENCH_collectives.json", smoke: bool = False) -> None:
    ranks = RANKS[:-1] if smoke else RANKS
    out = sweep(ranks, min_wall_s=0.05 if smoke else 0.2)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    s = out["speedup_at_top_ranks"]
    print(f"\nwrote {out_path}; route-cache speedup at {out['top_ranks']} "
          f"ranks: {s['min']:.2f}x-{s['max']:.2f}x")


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
