"""Micro-benchmark: simulated collective throughput across rank counts.

Two perf trajectories in one artifact (``BENCH_collectives.json``):

* **route-cache rows** (PR 1 metric, unchanged): event-simulated broadcast
  + allreduce at 16-256 ranks with the route cache / engine path table ON
  vs OFF, on the default (4 ranks/MPSoC) placement.

* **sweep rows** (PR 3 metric): the paper-style sweep workload — one
  collective replayed over the full OSU message-size grid (1 B..4 MB,
  powers of two, Figs. 14-19) — interpreted per size vs replayed as ONE
  compiled round program (``run_schedule_many``), with 1 rank/MPSoC
  placement (§6.1.4/6.1.5) on a torus scaled to fit
  (``scaled_params``).  Includes 512- (paper prototype scale), 1024- and
  4096-rank rows that were impractical to sweep before the compiled
  backend (the interpreter is sampled on a size subgrid there and
  compared by sends/sec rate).
  Each sweep row also times the **per-binding** compiled lane (the same
  grid as one single-size replay per call, B=1) against the batched
  replay and records ``batch_speedup`` — what the batch-binding axis
  buys over looping the compiled executor (DESIGN.md §6).

* **engine rows**: when jax is importable, the batched grid is replayed
  on both scan engines (``numpy`` and ``jax``, DESIGN.md §2.5) at the
  largest swept rank count, cross-checked to <=1e-9, and both rates are
  recorded.

Run: PYTHONPATH=src python benchmarks/collectives_sweep.py [--smoke]
         [--engine numpy|jax]

``--smoke`` (used by the CI benchmark step) drops the 256+-rank sweeps and
shortens the timed windows so perf artifacts stay fresh without slowing
CI; it still exercises the compiled backend end to end and fails loudly
if compiled and interpreted latencies ever disagree.  ``--engine``
selects the scan backend of every batched replay (default numpy); the
artifact records it per row.
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.exanet import ExanetMPI  # noqa: E402
from repro.core.exanet.params import DEFAULT, scaled_params  # noqa: E402
from repro.core.exanet.schedules import (BinomialBroadcast,  # noqa: E402
                                         RecursiveDoublingAllreduce)

RANKS = (16, 64, 256)
#: (collective, payload bytes, sends per run at n ranks) — route-cache rows
CASES = (
    ("bcast", 1, lambda n: n - 1),
    ("bcast", 4096, lambda n: n - 1),
    ("allreduce", 4096, lambda n: n * (n.bit_length() - 1)),
)

#: the OSU-style message-size grid (Figs. 14-19): 1 B .. 4 MB powers of 2
SWEEP_SIZES = tuple(1 << i for i in range(23))
#: interpreter subgrid for the paper-scale rows (full-grid interpretation
#: at 4096 ranks takes minutes; sends/sec is compared as a rate)
BIG_RANK_INTERP_SIZES = (1, 32, 1024, 32768, 1 << 20, 4 << 20)
SWEEP_RANKS = (16, 64, 256)
BIG_SWEEP_RANKS = (512, 1024, 4096)
SWEEP_SCHEDULES = (
    ("bcast", BinomialBroadcast, lambda n: n - 1),
    ("allreduce", RecursiveDoublingAllreduce,
     lambda n: n * (n.bit_length() - 1)),
)


def _time_runs(mpi: ExanetMPI, coll: str, size: int, nranks: int,
               min_wall_s: float) -> tuple[float, int]:
    """(wall seconds, number of runs) for repeated simulations."""
    fn = (lambda: mpi.bcast(size, nranks)) if coll == "bcast" else \
        (lambda: mpi.allreduce(size, nranks, "recursive_doubling"))
    fn()  # warm the caches (when enabled) outside the timed region
    runs, wall = 0, 0.0
    t0 = time.perf_counter()
    while wall < min_wall_s:
        fn()
        runs += 1
        wall = time.perf_counter() - t0
    return wall, runs


def sweep(ranks: tuple[int, ...], min_wall_s: float) -> dict:
    """PR-1 route-cache rows (cached vs uncached interpreter)."""
    results = []
    for coll, size, sends_per_run in CASES:
        for n in ranks:
            row = {"collective": coll, "size_bytes": size, "nranks": n}
            for mode, cached in (("cached", True), ("uncached", False)):
                mpi = ExanetMPI(cache=cached)
                wall, runs = _time_runs(mpi, coll, size, n, min_wall_s)
                sends = sends_per_run(n) * runs
                row[mode] = {"wall_s": round(wall, 4), "runs": runs,
                             "sends_per_sec": round(sends / wall, 1)}
            row["speedup"] = round(row["cached"]["sends_per_sec"]
                                   / row["uncached"]["sends_per_sec"], 2)
            results.append(row)
            print(f"{coll:9s} {size:5d}B N={n:3d}  "
                  f"cached={row['cached']['sends_per_sec']:>10.0f} sends/s  "
                  f"uncached={row['uncached']['sends_per_sec']:>9.0f}  "
                  f"speedup={row['speedup']:.2f}x")
    top = max(ranks)
    at_top = [r["speedup"] for r in results if r["nranks"] == top]
    out = {"results": results, "top_ranks": top,
           "speedup_at_top_ranks": {"min": min(at_top), "max": max(at_top)}}
    if top == max(RANKS):
        # stable key for the PR-1 acceptance metric (full sweeps only; a
        # --smoke artifact must not masquerade as the 256-rank number)
        out["speedup_at_256_ranks"] = out["speedup_at_top_ranks"]
    return out


def _interp_grid(mpi, sched, sizes, nranks, min_wall_s):
    """sends/sec interpreting one collective per size over a grid."""
    for s in sizes[:1]:
        mpi.run_schedule(sched, s, nranks, backend="interp")  # warm routes
    runs, wall = 0, 0.0
    t0 = time.perf_counter()
    while wall < min_wall_s:
        for s in sizes:
            mpi.run_schedule(sched, s, nranks, backend="interp")
        runs += 1
        wall = time.perf_counter() - t0
    return wall, runs


def _compiled_grid(mpi, sched, sizes, nranks, min_wall_s, engine=None):
    """sends/sec replaying one compiled program over the whole grid."""
    mpi.run_schedule_many(sched, sizes, nranks, engine=engine)  # compile+bind
    runs, wall = 0, 0.0
    t0 = time.perf_counter()
    while wall < min_wall_s:
        mpi.run_schedule_many(sched, sizes, nranks, engine=engine)
        runs += 1
        wall = time.perf_counter() - t0
    return wall, runs


def _per_binding_grid(mpi, sched, sizes, nranks, min_wall_s, engine=None):
    """sends/sec looping the compiled executor one size at a time (B=1
    columns) — the pre-batch-axis way to cover a grid."""
    for s in sizes:
        mpi.run_schedule_many(sched, (s,), nranks, engine=engine)  # bind
    runs, wall = 0, 0.0
    t0 = time.perf_counter()
    while wall < min_wall_s:
        for s in sizes:
            mpi.run_schedule_many(sched, (s,), nranks, engine=engine)
        runs += 1
        wall = time.perf_counter() - t0
    return wall, runs


def compiled_sweep(ranks, big_ranks, min_wall_s, engine=None) -> list[dict]:
    """PR-3 rows: compiled vs interpreted over the message-size sweep,
    plus the batched-vs-per-binding lane (PR 6)."""
    rows = []
    for coll, sched_cls, sends_per_run in SWEEP_SCHEDULES:
        for n in tuple(ranks) + tuple(big_ranks):
            sched = sched_cls()
            # 1 rank/MPSoC (§6.1.4/6.1.5 placement): rank r sits on core
            # r * cores_per_mpsoc; scale the torus when the 512-core
            # prototype cannot hold the ranks
            p = scaled_params((n - 1) * DEFAULT.cores_per_mpsoc + 1)
            mpi = ExanetMPI(p, ranks_per_mpsoc=1)
            interp_sizes = SWEEP_SIZES if n in ranks else \
                BIG_RANK_INTERP_SIZES
            iw, ir = _interp_grid(mpi, sched, interp_sizes, n,
                                  min_wall_s)
            cw, cr = _compiled_grid(mpi, sched, SWEEP_SIZES, n,
                                    min_wall_s, engine)
            pw, pr = _per_binding_grid(mpi, sched, SWEEP_SIZES, n,
                                       min_wall_s, engine)
            # equal-latency guard: the two backends must agree (~1e-9)
            batch = mpi.run_schedule_many(sched, SWEEP_SIZES, n,
                                          engine=engine)
            probe = [SWEEP_SIZES[0], SWEEP_SIZES[len(SWEEP_SIZES) // 2],
                     SWEEP_SIZES[-1]]
            for s in probe:
                a = mpi.run_schedule(sched, s, n, backend="interp")
                b = float(batch.latency_us[SWEEP_SIZES.index(s)])
                if abs(a.latency_us - b) > 1e-9 * a.latency_us:
                    raise AssertionError(
                        f"backend disagreement: {coll} N={n} size={s}: "
                        f"interp {a.latency_us} vs compiled {b}")
            i_rate = sends_per_run(n) * len(interp_sizes) * ir / iw
            c_rate = sends_per_run(n) * len(SWEEP_SIZES) * cr / cw
            p_rate = sends_per_run(n) * len(SWEEP_SIZES) * pr / pw
            row = {"collective": coll, "nranks": n,
                   "grid_sizes": len(SWEEP_SIZES),
                   "engine": engine or "numpy",
                   "interp": {"wall_s": round(iw, 4), "runs": ir,
                              "grid_sizes": len(interp_sizes),
                              "sends_per_sec": round(i_rate, 1)},
                   "compiled": {"wall_s": round(cw, 4), "runs": cr,
                                "sends_per_sec": round(c_rate, 1)},
                   "per_binding": {"wall_s": round(pw, 4), "runs": pr,
                                   "sends_per_sec": round(p_rate, 1)},
                   "speedup_compiled": round(c_rate / i_rate, 2),
                   "batch_speedup": round(c_rate / p_rate, 2)}
            rows.append(row)
            print(f"{coll:9s} sweep N={n:4d}  "
                  f"interp={i_rate:>11.0f} sends/s  "
                  f"compiled={c_rate:>12.0f}  "
                  f"speedup={row['speedup_compiled']:.2f}x  "
                  f"batch={row['batch_speedup']:.2f}x")
    return rows


def engine_rows(nranks: int, min_wall_s: float) -> list[dict]:
    """numpy-vs-jax scan-engine comparison on the batched grid (skipped
    when jax is not importable; DESIGN.md §2.5)."""
    from repro.core.exanet.scan_engine import available_engines
    if "jax" not in available_engines():
        print("engine rows: jax not importable, skipping")
        return []
    p = scaled_params((nranks - 1) * DEFAULT.cores_per_mpsoc + 1)
    mpi = ExanetMPI(p, ranks_per_mpsoc=1)
    rows = []
    for coll, sched_cls, sends_per_run in SWEEP_SCHEDULES:
        sched = sched_cls()
        lat = {}
        row = {"collective": coll, "nranks": nranks,
               "grid_sizes": len(SWEEP_SIZES)}
        for eng in ("numpy", "jax"):
            w, r = _compiled_grid(mpi, sched, SWEEP_SIZES, nranks,
                                  min_wall_s, eng)
            rate = sends_per_run(nranks) * len(SWEEP_SIZES) * r / w
            row[eng] = {"wall_s": round(w, 4), "runs": r,
                        "sends_per_sec": round(rate, 1)}
            lat[eng] = mpi.run_schedule_many(sched, SWEEP_SIZES, nranks,
                                             engine=eng).latency_us
        rel = float(max(abs(lat["jax"] - lat["numpy"])
                        / abs(lat["numpy"])))
        if rel > 1e-9:
            raise AssertionError(f"engine disagreement {coll} N={nranks}: "
                                 f"{rel:.2e} rel")
        row["agreement_rel"] = rel
        row["jax_vs_numpy"] = round(row["jax"]["sends_per_sec"]
                                    / row["numpy"]["sends_per_sec"], 3)
        rows.append(row)
        print(f"{coll:9s} engine N={nranks:4d}  "
              f"numpy={row['numpy']['sends_per_sec']:>12.0f} sends/s  "
              f"jax={row['jax']['sends_per_sec']:>12.0f}  "
              f"jax/numpy={row['jax_vs_numpy']:.3f}x  agree {rel:.1e}")
    return rows


def main(out_path: str = "BENCH_collectives.json", smoke: bool = False,
         engine: str = "numpy") -> None:
    ranks = RANKS[:-1] if smoke else RANKS
    out = sweep(ranks, min_wall_s=0.05 if smoke else 0.2)
    sweep_ranks = SWEEP_RANKS[:-1] if smoke else SWEEP_RANKS
    big_ranks = () if smoke else BIG_SWEEP_RANKS
    rows = compiled_sweep(sweep_ranks, big_ranks,
                          min_wall_s=0.05 if smoke else 0.5,
                          engine=engine)
    out["engine"] = engine
    out["sweep_sizes"] = [int(s) for s in SWEEP_SIZES]
    out["sweep_results"] = rows
    out["engine_results"] = engine_rows(
        max(tuple(sweep_ranks) + tuple(big_ranks)),
        min_wall_s=0.05 if smoke else 0.5)
    if not smoke:
        at_256 = [r["speedup_compiled"] for r in rows if r["nranks"] == 256]
        out["compiled_speedup_at_256_ranks"] = {"min": min(at_256),
                                                "max": max(at_256)}
        out["compiled_max_ranks"] = max(r["nranks"] for r in rows)
        big = [r["batch_speedup"] for r in rows if r["nranks"] >= 512]
        out["batch_speedup_at_big_ranks"] = {"min": min(big),
                                             "max": max(big)}
        assert out["batch_speedup_at_big_ranks"]["max"] >= 5.0, \
            "batched replay must be >=5x the per-binding compiled loop " \
            "on at least one >=512-rank size grid"
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    s = out["speedup_at_top_ranks"]
    print(f"\nwrote {out_path}; route-cache speedup at {out['top_ranks']} "
          f"ranks: {s['min']:.2f}x-{s['max']:.2f}x")
    if not smoke:
        c = out["compiled_speedup_at_256_ranks"]
        b = out["batch_speedup_at_big_ranks"]
        print(f"compiled-vs-interp sweep speedup at 256 ranks: "
              f"{c['min']:.2f}x-{c['max']:.2f}x "
              f"(max swept ranks: {out['compiled_max_ranks']}); "
              f"batched-vs-per-binding at >=512 ranks: "
              f"{b['min']:.2f}x-{b['max']:.2f}x")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default="numpy",
                    choices=("numpy", "jax"),
                    help="scan backend of the batched replays")
    args = ap.parse_args()
    main(smoke=args.smoke, engine=args.engine)
