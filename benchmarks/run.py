"""Benchmark driver (deliverable d): one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. The ExaNet-model benchmarks run
everywhere; the dry-run/roofline section is included when results/dryrun
JSONs exist (see scripts/run_dryrun_all.sh).
"""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import paper_tables  # noqa: E402


def dryrun_rows(out_dir: str = "results/dryrun"):
    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        d = json.load(open(f))
        tag = os.path.basename(f)[:-5]
        if "error" in d:
            rows.append((f"dryrun/{tag}", 0.0, "ERROR " + d["error"][:60]))
        elif "skipped" in d:
            rows.append((f"dryrun/{tag}", 0.0, d["skipped"][:60]))
        else:
            r = d["roofline"]
            rows.append((f"dryrun/{tag}", r["step_bound_s"] * 1e6,
                         f"bottleneck={r['bottleneck']} "
                         f"roofline_frac={r['roofline_fraction']:.3f} "
                         f"peak={d['memory']['peak_gb']:.1f}GB"))
    return rows


SECTIONS = [
    ("Fig14/Table2 osu_latency", paper_tables.osu_latency_rows),
    ("Fig15 osu_bw", paper_tables.osu_bw_rows),
    ("Fig16/18 osu_bcast", paper_tables.osu_bcast_rows),
    ("Fig17 osu_allreduce", paper_tables.osu_allreduce_rows),
    ("Pluggable allreduce schedules", paper_tables.allreduce_schedule_rows),
    ("Collective zoo (schedule split)", paper_tables.collective_zoo_rows),
    ("Fig19 allreduce accelerator", paper_tables.allreduce_accel_rows),
    ("Fig13 IP-over-ExaNet", paper_tables.ip_overlay_rows),
    ("Fig20-22/Table3 app scaling", paper_tables.apps_scaling_rows),
    ("S7 matmul accelerator", paper_tables.matmul_accel_rows),
    ("LayerB TPU collectives", paper_tables.collectives_tpu_rows),
    ("Dry-run roofline", dryrun_rows),
]


def main() -> None:
    print("name,us_per_call,derived")
    for title, fn in SECTIONS:
        print(f"# --- {title} ---")
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.3f},{derived}")
        except Exception as e:  # noqa: BLE001
            print(f"{title},nan,ERROR {type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
