"""Benchmarks reproducing the paper's tables/figures from the ExaNet model.

Each function returns rows of (name, us_per_call, derived) where `derived`
is the paper-comparison column (paper value / deviation / rate).
"""

from __future__ import annotations

from repro.core.exanet import ExanetMPI, Topology, DEFAULT
from repro.core.exanet.allreduce_accel import accel_allreduce_latency
from repro.core.exanet.apps import ALL_APPS, PAPER_TABLE3
from repro.core.exanet.ip_overlay import (baseline_throughput_gbps,
                                          overlay_rtt,
                                          overlay_throughput_gbps)

PAPER_TABLE2 = {"intra_fpga": 1.17, "intra_qfdb_sh": 1.293, "mezz_sh": 1.579,
                "mezz_mh(2)": 2.0, "mezz_mh(3)": 2.111,
                "inter_mezz(3,1,2)": 2.555}


def osu_latency_rows():
    """Fig. 14 / Tables 1-2: 0B one-way latency over the named paths."""
    topo = Topology()
    mpi = ExanetMPI()
    rows = []
    for name, (s, d) in topo.table1_paths().items():
        lat = mpi.net.mpi_latency(0, topo.route(s, d))
        paper = PAPER_TABLE2[name]
        rows.append((f"osu_latency/{name}", lat,
                     f"paper={paper}us dev={100*(lat-paper)/paper:+.1f}%"))
    # size sweep on the intra-QFDB path (Fig. 14 shape)
    for size in (0, 8, 32, 64, 4096, 1 << 20, 4 << 20):
        lat = mpi.osu_latency(size)
        rows.append((f"osu_latency/intra_qfdb/{size}B", lat,
                     "eager" if size <= 32 else "rendezvous"))
    return rows


def osu_bw_rows():
    """Fig. 15: uni/bidirectional bandwidth."""
    mpi = ExanetMPI()
    c = DEFAULT.cores_per_mpsoc
    rows = []
    for size in (64, 4096, 65536, 1 << 20, 4 << 20):
        bw16 = mpi.osu_bw(size, 0, c)
        bw10 = mpi.osu_bw(size, 0, c * DEFAULT.fpgas_per_qfdb)
        bi = mpi.osu_bibw(size, 0, c)
        t16 = size * 8.0 / (bw16 * 1000.0)
        rows.append((f"osu_bw/16G/{size}B", t16,
                     f"{bw16:.2f}Gbps (paper@4MB: 13.0, util 81.9%)"))
        rows.append((f"osu_bw/10G/{size}B", size * 8 / (bw10 * 1000),
                     f"{bw10:.2f}Gbps (paper@4MB: 6.42, util 64.3%)"))
        rows.append((f"osu_bibw/16G/{size}B", size * 8 / (bi * 1000),
                     f"{bi:.2f}Gbps (~2x bw - sharing dev)"))
    return rows


def osu_bcast_rows():
    """Figs. 16+18: broadcast observed vs Eq.1 expectation."""
    mpi = ExanetMPI()
    rows = []
    for n in (4, 16, 64, 256, 512):
        for size in (1, 4096, 1 << 20):
            r = mpi.bcast(size, n)
            rows.append((f"osu_bcast/N{n}/{size}B", r.observed_us,
                         f"expected(Eq1)={r.expected_us:.2f}us "
                         f"dev={100*r.deviation:+.1f}%"))
    return rows


def osu_allreduce_rows():
    """Fig. 17: software allreduce (recursive doubling)."""
    mpi = ExanetMPI()
    rows = []
    for n in (4, 16, 64, 512):
        for size in (4, 64, 1024):
            rows.append((f"osu_allreduce/N{n}/{size}B",
                         mpi.allreduce_sw(size, n), "recursive-doubling"))
    return rows


def allreduce_schedule_rows():
    """Beyond the paper: pluggable allreduce schedules (ring, Rabenseifner)
    against the MPICH recursive doubling the prototype shipped with."""
    mpi = ExanetMPI()
    rows = []
    for n in (8, 64):
        for size in (1024, 65536, 1 << 20):
            rd = mpi.allreduce(size, n, "recursive_doubling")
            for algo in ("ring", "rabenseifner"):
                t = mpi.allreduce(size, n, algo)
                rows.append((f"allreduce_sched/{algo}/N{n}/{size}B", t,
                             f"vs recursive_doubling {rd:.1f}us "
                             f"({rd/t:.2f}x)"))
    return rows


def collective_zoo_rows():
    """Collectives unlocked by the schedule/executor split: allgather,
    alltoall, barrier, scatter/gather as ~10-line schedule definitions."""
    mpi = ExanetMPI()
    rows = []
    for n in (16, 64):
        rows.append((f"coll_zoo/allgather/N{n}/1KB", mpi.allgather(1024, n),
                     "recursive doubling"))
        rows.append((f"coll_zoo/alltoall/N{n}/1KB", mpi.alltoall(1024, n),
                     "pairwise exchange"))
        rows.append((f"coll_zoo/barrier/N{n}", mpi.barrier(n),
                     "dissemination"))
        rows.append((f"coll_zoo/scatter/N{n}/1KB", mpi.scatter(1024, n),
                     "binomial"))
        rows.append((f"coll_zoo/gather/N{n}/1KB", mpi.gather(1024, n),
                     "binomial"))
    return rows


def allreduce_accel_rows():
    """Fig. 19: NI Allreduce accelerator vs software, 1 rank/MPSoC."""
    mpi1 = ExanetMPI(ranks_per_mpsoc=1)
    rows = []
    paper_best = {16: 83.4, 32: 86.2, 64: 87.1, 128: 87.9}
    for n in (16, 32, 64, 128):
        best = 0.0
        for size in (4, 64, 256, 1024, 4096):
            hw = accel_allreduce_latency(size, n)
            sw = mpi1.allreduce_sw(size, n)
            best = max(best, 1 - hw / sw)
            rows.append((f"allreduce_accel/N{n}/{size}B", hw,
                         f"sw={sw:.2f}us improvement={100*(1-hw/sw):.1f}%"))
        rows.append((f"allreduce_accel/N{n}/best", 0.0,
                     f"max_improvement={100*best:.1f}% "
                     f"(paper {paper_best[n]}%)"))
    return rows


def ip_overlay_rows():
    """Fig. 13 + §5.3 RTTs."""
    rows = []
    ov = overlay_throughput_gbps(65507)
    base = baseline_throughput_gbps(65507)
    rows.append(("ip_overlay/udp_large", 65507 * 8 / (ov * 1000),
                 f"{ov:.2f}Gbps (paper 4.7)"))
    rows.append(("ip_overlay/baseline_udp_large", 65507 * 8 / (base * 1000),
                 f"{base:.2f}Gbps (paper 1.3)"))
    rows.append(("ip_overlay/rtt_poll", overlay_rtt(mode="poll"),
                 "paper ~90us"))
    rows.append(("ip_overlay/rtt_sleep", overlay_rtt(mode="sleep"),
                 "paper ~2.2ms"))
    return rows


def apps_scaling_rows():
    """Figs. 20-22 / Table 3: weak+strong scaling efficiencies."""
    rows = []
    for name, factory in ALL_APPS.items():
        m = factory()
        for mode in ("weak", "strong"):
            for n in (2, 8, 64, 512):
                r = getattr(m, mode)(n)
                paper = PAPER_TABLE3[name][mode].get(n)
                note = (f"paper={paper}%" if paper is not None else
                        "prediction")
                tag = " [calibrated]" if r.get("calibrated") else ""
                rows.append((f"apps/{name}/{mode}/N{n}", r["t_iter_us"],
                             f"eff={100*r['efficiency']:.1f}% {note}{tag}"))
    return rows


def matmul_accel_rows():
    """§7: the MatMul accelerator -> Pallas MXU tile. Reports the paper's
    HLS numbers + the kernel's roofline napkin math for v5e."""
    from repro.kernels.matmul_tile.ops import flops_per_byte
    from repro.roofline.hw import V5E
    p = DEFAULT
    peak = p.mm_clock_mhz * 1e6 * p.mm_flops_per_cycle / 1e9
    rows = [
        ("matmul_accel/tile_exec", p.mm_tile_exec_cycles / p.mm_clock_mhz,
         f"128x128 tile, {p.mm_flops_per_cycle} flop/cycle"),
        ("matmul_accel/fpga_gflops", 0.0,
         f"paper 275 GFLOP/s = {100*p.mm_measured_gflops/peak:.1f}% of "
         f"{peak:.0f} peak; 17 GFLOPS/W"),
    ]
    for mnk in ((1024, 1024, 1024), (4096, 4096, 4096), (8192, 8192, 8192)):
        ai = flops_per_byte(*mnk)
        ridge = V5E.peak_bf16_flops / V5E.hbm_bw
        bound = "compute" if ai > ridge else "memory"
        t_us = 2.0 * mnk[0] * mnk[1] * mnk[2] / V5E.peak_bf16_flops * 1e6
        rows.append((f"matmul_accel/v5e/{mnk[0]}^3", t_us,
                     f"AI={ai:.0f} flops/B ridge={ridge:.0f} -> {bound}-bound"))
    return rows


def collectives_tpu_rows():
    """Layer B: flat vs hierarchical allreduce wire bytes (napkin model) —
    the TPU analog of Fig. 19's accelerated-vs-software comparison."""
    from repro.core.collectives import hierarchical_collective_bytes
    from repro.core.comm import CommPolicy
    rows = []
    pol = CommPolicy()
    for n_mb in (1, 64, 1024):
        n = n_mb << 20
        hb = hierarchical_collective_bytes(n, intra=16, inter=2)
        t_flat = pol.ring_allreduce_s(n, 512, pol.dcn_bw, pol.alpha_pod_s)
        t_hier = (pol.ring_allreduce_s(n, 16, pol.ici_bw, pol.alpha_s)
                  + pol.ring_allreduce_s(n // 16, 2, pol.dcn_bw,
                                         pol.alpha_pod_s))
        rows.append((f"collectives/hier_allreduce/{n_mb}MB", t_hier * 1e6,
                     f"flat={t_flat*1e6:.0f}us speedup={t_flat/t_hier:.1f}x "
                     f"xpod_bytes/chip {hb['flat']['inter']/1e6:.1f}->"
                     f"{hb['hier']['inter']/1e6:.2f}MB "
                     f"({hb['inter_reduction']:.0f}x less)"))
    thr = pol.eager_threshold_bytes(256)
    rows.append(("collectives/eager_threshold/256chips", 0.0,
                 f"{thr}B crossover (paper's eager/rendezvous analog)"))
    rows.append(("collectives/bucket_bytes/256chips", 0.0,
                 f"{pol.bucket_bytes(256)>>20}MB bucket (cell/MTU analog)"))
    return rows
