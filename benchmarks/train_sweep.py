"""Benchmark: overlap-aware train-step co-simulation
(``BENCH_train.json``, ROADMAP item 5; DESIGN.md §2.9).

Per (model, rank count, scaling mode) the train co-sim emits one data-
parallel training step — roofline compute slices interleaved with
bucketed gradient allreduces — and *executes* it on the ExaNeSt machine
at sim fidelity (and through the TPU machine's analytic walk of the
same emission), so backward/sync overlap is an emergent quantity, not a
closed form.  Reported: weak-scaling (fixed per-rank batch) and
strong-scaling (fixed global batch) step-time curves with blocking vs
overlapped sync, global token throughput, and the critical-path lower
bound each overlapped step is checked against.

The ``speedup`` section measures the candidate-population fast path: a
64-member family of split-perturbed sync candidates costed as batch
columns of ONE compiled replay (per-site payload scale + per-compute-
slot scale) against the naive lane — one emit + ``run_program`` per
candidate, identical payloads, lane agreement <=1e-9 asserted.  The
``planner`` section records ``CollectivePlanner.plan_train_sync``
hillclimbs against the analytic ``CommPolicy`` baseline; the full sweep
asserts at least one decision flips with margin.

Per-rank GFLOP/s is set per model to land compute and gradient wire
time in the same decade — the regime where sync scheduling moves step
time; see MODELS.

Run: PYTHONPATH=src python benchmarks/train_sweep.py [--smoke]
         [--engine numpy|jax]

``--smoke`` (the CI lane) runs 16 ranks with the same 1e-9 agreement
guards and the emergent-overlap bound check, and per the BENCH schema
rules (DESIGN.md §6) omits the acceptance keys
(``scenario_speedup_at_512``, ``planner_flip``) so a smoke artifact can
never masquerade as the full sweep.
"""

from __future__ import annotations

import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core.machine import ExanetMachine, TpuMachine  # noqa: E402
from repro.core.planner import CollectivePlanner  # noqa: E402
from repro.train.cosim import (SyncCandidate, TrainSim,  # noqa: E402
                               TrainStepSpec)

AGREEMENT_RTOL = 1e-9
RANKS = (512, 1024)
PREDICT_RANKS = (2048, 4096)
GLOBAL_BATCH = 4096           #: sequences, strong-scaling numerator
#: (arch, per-rank GFLOP/s, seq_len).  The GFLOP/s knob places each
#: model's backward compute and gradient wire time in the same decade:
#: the 100m config on bare A53+NEON nodes, the 123b dense model on
#: accelerator-equipped nodes, DeepSeek-V3 back on modest nodes (its
#: sparse active-param compute meets a full-param gradient sync).
MODELS = (("exanest-lm-100m", 50.0, 2048),
          ("mistral-large-123b", 1000.0, 2048),
          ("deepseek-v3-671b", 50.0, 2048))


def cand_dict(c: SyncCandidate) -> dict:
    return {"n_buckets": c.n_buckets, "algo": c.algo,
            "overlap_depth": c.overlap_depth,
            "split": list(c.split) if c.split else None}


def make_sim(machine, arch: str, gflops: float, seq: int, nranks: int,
             bpr: int) -> TrainSim:
    return TrainSim(TrainStepSpec(arch=arch, nranks=nranks, seq_len=seq,
                                  batch_per_rank=bpr, rank_gflops=gflops),
                    machine)


def scaling_row(sim: TrainSim, mode: str, over: SyncCandidate, *,
                engine: str, check: int, bounds: bool = True) -> dict:
    """One (arch, nranks, mode) point: blocking vs overlapped step time
    on the sim machine plus the same pair through the TPU analytic walk,
    with the emergent-overlap bound check."""
    spec = sim.spec
    block = dataclasses.replace(over, overlap_depth=0)
    t0 = time.perf_counter()
    bl, ov = sim.cost_candidates([block, over], engine=engine, check=check,
                                 rtol=AGREEMENT_RTOL)
    wall = time.perf_counter() - t0
    lb = sim.lower_bound_us(over) if bounds else None
    # bound check: overlapped in [critical path, blocking].  Equality
    # with blocking is legitimate in comm-saturated regimes (the engine
    # already pipelines compute into comm slack without handles); strict
    # gain is asserted separately over the sweep (see main).
    emergent = bool(bounds and lb * (1 - AGREEMENT_RTOL) <= ov <= bl)
    tokens = spec.nranks * spec.batch_per_rank * spec.seq_len
    row = {
        "arch": spec.arch, "nranks": spec.nranks, "mode": mode,
        "machine": "exanet-sim", "engine": engine,
        "batch_per_rank": spec.batch_per_rank, "seq_len": spec.seq_len,
        "rank_gflops": spec.rank_gflops,
        "candidate": cand_dict(over),
        "blocking_step_us": float(bl), "overlapped_step_us": float(ov),
        "overlap_gain": round(float((bl - ov) / bl), 4),
        "lower_bound_us": float(lb) if lb is not None else None,
        "overlap_emergent": emergent,
        "tokens_per_sec_global": round(tokens / (float(ov) / 1e6), 1),
        "wall_s": round(wall, 3),
    }
    print(f"{spec.arch:20s} N={spec.nranks:5d} {mode:6s} "
          f"bpr={spec.batch_per_rank:2d}  "
          f"block={bl/1e6:9.2f}s over={ov/1e6:9.2f}s "
          f"gain={row['overlap_gain']:6.1%} "
          f"tok/s={row['tokens_per_sec_global']:12.1f}  [{wall:5.1f}s]")
    return row


def analytic_row(sim: TrainSim, mode: str, over: SyncCandidate) -> dict:
    """The same emission through the TPU machine's analytic hooks —
    overlap still emerges because analytic costing runs on the shared
    nonblocking-collective scheduler."""
    spec = sim.spec
    block = dataclasses.replace(over, overlap_depth=0)
    tpu = TpuMachine()
    bl = sim.step_time_analytic(block, tpu)
    ov = sim.step_time_analytic(over, tpu)
    return {"arch": spec.arch, "nranks": spec.nranks, "mode": mode,
            "machine": "tpu-analytic",
            "batch_per_rank": spec.batch_per_rank,
            "candidate": cand_dict(over),
            "blocking_step_us": float(bl), "overlapped_step_us": float(ov),
            "overlap_gain": round(float((bl - ov) / bl), 4)}


def speedup_row(sim: TrainSim, *, n_candidates: int, n_single: int,
                engine: str, check: int, seed: int = 7) -> dict:
    """Batched-vs-per-candidate lane comparison on identical candidates:
    one family of split-perturbed members costed as columns of one
    compiled replay vs one emit+run_program per member."""
    base = SyncCandidate(8, sim.feasible_algos()[0], 1)
    rng = np.random.default_rng(seed)
    fam = [base]
    while len(fam) < n_candidates:
        m = sim.mutate(dataclasses.replace(base), rng)
        if m.family() == base.family() and m not in fam:
            fam.append(m)
    sim.cost_candidates([base], engine=engine)       # warm schedule caches
    t0 = time.perf_counter()
    us = sim.cost_candidates(fam, engine=engine, check=check,
                             rtol=AGREEMENT_RTOL)
    batched_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    singles = np.array([sim.step_time_single(c, engine=engine)
                        for c in fam[:n_single]])
    single_wall = time.perf_counter() - t0
    lane_rel = float(np.max(np.abs(us[:n_single] - singles) / singles))
    assert lane_rel <= AGREEMENT_RTOL, \
        f"batched lane deviates from per-candidate lane: {lane_rel:.2e}"
    batched_rate = len(fam) / batched_wall
    single_rate = n_single / single_wall
    speedup = batched_rate / single_rate
    print(f"speedup @N={sim.spec.nranks}: batched {batched_rate:7.1f} "
          f"cand/s ({len(fam)} columns) vs per-candidate "
          f"{single_rate:6.2f} cand/s ({n_single} runs) -> "
          f"{speedup:.1f}x  (lane agree {lane_rel:.1e})")
    return {
        "arch": sim.spec.arch, "nranks": sim.spec.nranks, "engine": engine,
        "family": {"n_buckets": base.n_buckets, "algo": base.algo,
                   "overlap_depth": base.overlap_depth},
        "batched": {"candidates": len(fam),
                    "wall_s": round(batched_wall, 4),
                    "cand_per_sec": round(batched_rate, 2),
                    "interp_checked_columns": check},
        "per_candidate": {"candidates": n_single,
                          "wall_s": round(single_wall, 4),
                          "cand_per_sec": round(single_rate, 3)},
        "scenario_speedup": round(speedup, 1),
        "lane_agreement_rel": lane_rel,
    }


def planner_row(sim: TrainSim, *, engine: str, check: int,
                generations: int = 2, survivors: int = 4,
                children: int = 4) -> dict:
    t0 = time.perf_counter()
    plan = CollectivePlanner(sim.machine).plan_train_sync(
        sim, generations=generations, survivors=survivors,
        children=children, engine=engine, check=check)
    wall = time.perf_counter() - t0
    print(f"planner @{plan.arch} N={plan.nranks}: "
          f"{cand_dict(plan.baseline)} ({plan.baseline_step_us/1e6:.2f}s) "
          f"-> {cand_dict(plan.chosen)} ({plan.step_us/1e6:.2f}s)  "
          f"flip={plan.flipped} {plan.flip_kinds} "
          f"margin={plan.margin:.1%} [{plan.evaluated} evals, {wall:.1f}s]")
    return {"arch": plan.arch, "nranks": plan.nranks,
            "baseline": cand_dict(plan.baseline),
            "baseline_step_us": plan.baseline_step_us,
            "chosen": cand_dict(plan.chosen), "step_us": plan.step_us,
            "flipped": plan.flipped, "flip_kinds": list(plan.flip_kinds),
            "margin": round(plan.margin, 4), "evaluated": plan.evaluated,
            "wall_s": round(wall, 2)}


def main(out_path: str = "BENCH_train.json", smoke: bool = False,
         engine: str = "numpy") -> None:
    machine = ExanetMachine()
    out: dict = {"engine": engine, "agreement_rtol": AGREEMENT_RTOL,
                 "results": [], "speedup": [], "planner": []}
    if smoke:
        out["smoke"] = True
        out["ranks"] = [16]
        sim = make_sim(machine, "exanest-lm-100m", 50.0, 256, 16, 1)
        over = SyncCandidate(4, sim.feasible_algos()[0], 2)
        row = scaling_row(sim, "weak", over, engine=engine, check=2)
        assert row["overlap_emergent"] and row["overlap_gain"] > 0, \
            "smoke: overlapped step must sit in [lower bound, blocking)"
        out["results"].append(row)
        out["results"].append(analytic_row(sim, "weak", over))
        out["speedup"].append(speedup_row(sim, n_candidates=8, n_single=3,
                                          engine=engine, check=2))
        out["planner"].append(planner_row(
            sim, engine=engine, check=1, generations=1, survivors=2,
            children=2))
    else:
        out["ranks"] = list(RANKS)
        out["prediction_ranks"] = list(PREDICT_RANKS)
        out["models"] = [m[0] for m in MODELS]
        out["global_batch_strong"] = GLOBAL_BATCH
        for arch, gflops, seq in MODELS:
            for n in RANKS:
                for mode, bpr in (("weak", 1),
                                  ("strong", max(1, GLOBAL_BATCH // n))):
                    sim = make_sim(machine, arch, gflops, seq, n, bpr)
                    over = SyncCandidate(8, sim.feasible_algos()[0], 2)
                    out["results"].append(scaling_row(
                        sim, mode, over, engine=engine, check=1))
                    if mode == "weak":
                        out["results"].append(analytic_row(sim, mode, over))
            sim512 = make_sim(machine, arch, gflops, seq, 512, 1)
            out["planner"].append(planner_row(sim512, engine=engine,
                                              check=1))
        # the fast-path headline on the repo's own config
        arch0, gflops0, seq0 = MODELS[0]
        sim512 = make_sim(machine, arch0, gflops0, seq0, 512, 1)
        out["speedup"].append(speedup_row(sim512, n_candidates=64,
                                          n_single=6, engine=engine,
                                          check=2))
        # predicted tiers: carry the 512-rank overlapped plan upward
        # (weak scaling, the repo's own config)
        for n in PREDICT_RANKS:
            sim = make_sim(machine, MODELS[0][0], MODELS[0][1],
                           MODELS[0][2], n, 1)
            over = SyncCandidate(8, sim.feasible_algos()[0], 2)
            row = scaling_row(sim, "weak", over, engine=engine, check=1)
            row["prediction"] = True
            out["results"].append(row)
        # acceptance keys: full sweeps only (see module docstring)
        out["scenario_speedup_at_512"] = min(
            s["scenario_speedup"] for s in out["speedup"])
        assert out["scenario_speedup_at_512"] >= 10.0, \
            "batched candidate lane must be >=10x per-candidate at 512"
        flips = [p for p in out["planner"] if p["flipped"]]
        assert flips, "no planner decision flipped vs the analytic baseline"
        out["planner_flip"] = {"count": len(flips),
                               "max_margin": max(p["margin"]
                                                 for p in flips)}
        sim_rows = [r for r in out["results"]
                    if r["machine"] == "exanet-sim"]
        assert all(r["overlap_emergent"] for r in sim_rows), \
            "an overlapped step left [lower bound, blocking]"
        out["overlap_emergent_all_rows"] = True
        gained = [r for r in sim_rows if r["overlap_gain"] > 0.01]
        assert gained, "no row shows strict overlap gain"
        out["rows_with_overlap_gain"] = len(gained)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {out_path}")
    if not smoke:
        print(f"scenario_speedup @512: {out['scenario_speedup_at_512']}x; "
              f"planner flips: {out['planner_flip']['count']} "
              f"(max margin {out['planner_flip']['max_margin']:.1%})")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default="numpy", choices=("numpy", "jax"),
                    help="scan backend of the batched compiled lane")
    args = ap.parse_args()
    main(smoke=args.smoke, engine=args.engine)
