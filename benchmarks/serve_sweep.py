"""Benchmark: simulated LM serving under open-loop traffic
(``BENCH_serve.json``, ROADMAP item 1).

Whole load sweeps run through the batched compiled substrate: every step
state a continuous-batching server can occupy — (decoding slots,
prefilling slots, KV bucket) x Monte-Carlo draw, with per-column compute
skew, per-column collective payloads (``site_scale``) and per-rank
arrival jitter (the ``t0`` axis) — binds as one column of ONE
``run_program_scenarios`` call per rank count, and the open-loop replay
(Poisson and bursty-trace arrivals, continuous batching over slots) then
walks every load point as table lookups.  Reported per (arch, nranks,
load point): per-request latency CDFs and p50/p99/p99.9, TTFT and
queueing quantiles, goodput — with the goodput-vs-load knee per rank
count (largest offered load still served at >= 95% of the offered rate;
past it the open-loop queue diverges).

The ``speedup`` section measures the fast path against the naive lane —
one ``rebind_program`` + ``run_program`` per simulated step, the exact
same column payloads — on identical truncated workloads; the two lanes'
per-request latencies must agree to <=1e-9 (they share every line of
queueing logic, so lane agreement is executor agreement), and every step
table is built with sampled interpreter cross-checks (``check=``,
<=1e-9) on top.

Run: PYTHONPATH=src python benchmarks/serve_sweep.py [--smoke]
         [--engine numpy|jax] [--arch <id>]

``--smoke`` (the CI lane) runs a tiny Poisson sweep at 16 ranks — small
enough that the pairwise alltoall KV exchange is active — with the same
agreement guards, and per the BENCH schema rules (DESIGN.md §6) omits
the acceptance keys (``scenario_speedup``, ``knees``-derived capacity
claims) so a smoke artifact can never masquerade as the full sweep.
"""

from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.serve import traffic  # noqa: E402
from repro.serve.sim import ServeSim, ServeSimSpec  # noqa: E402

AGREEMENT_RTOL = 1e-9
#: full-sweep rank counts (512 = the prototype's cores; beyond = scaled
#: torus tiers) and the reduced grid for the biggest tiers
RANKS = (512, 1024)
PREDICT_RANKS = (2048, 4096)
LOAD_FRACS = (0.3, 0.5, 0.7, 0.85, 1.0, 1.2)
PREDICT_LOAD_FRACS = (0.5, 0.85, 1.2)
KNEE_FRAC = 0.95


def capacity_estimate_rps(sim: ServeSim, tab, prompt_mean: int,
                          out_mean: int, n: int = 0) -> float:
    """Measured saturation throughput that anchors the load grid: replay
    a backlog (every request arrives at t=0) through the step table and
    take n/makespan.  Accurate by construction for any compute/comm
    balance point (an analytic slots/step estimate misprices the
    prefill-heavy steps badly on compute-bound configs)."""
    sp = sim.spec
    n = n or 8 * sp.slots
    wl = traffic.trace_workload(np.zeros(n),
                                np.full(n, prompt_mean, dtype=np.int64),
                                np.full(n, out_mean, dtype=np.int64))
    res = traffic.replay(wl, slots=sp.slots, prefill_chunk=sp.prefill_chunk,
                         window=sp.window, kv_bucket=sp.kv_bucket,
                         step_time=tab.lookup)
    return n / float(res.done_us.max()) * 1e6


def run_load_point(sim: ServeSim, tab, offered_rps: float, n_requests: int,
                   reps: int, prompt_mean: int, out_mean: int,
                   seed: int, arrivals: str = "poisson") -> dict:
    sp = sim.spec
    lat, ttft, queue = [], [], []
    steps = 0
    sim_us = 0.0
    tokens = 0
    t0 = time.perf_counter()
    for rep in range(reps):
        if arrivals == "poisson":
            wl = traffic.poisson_workload(offered_rps, n_requests,
                                          seed + rep,
                                          prompt_tokens=prompt_mean,
                                          out_tokens=out_mean)
        else:  # bursty trace: groups of slots-size bursts, same mean rate
            burst = max(2, sp.slots)
            n_bursts = int(np.ceil(n_requests / burst))
            times = np.repeat(np.arange(n_bursts)
                              * (burst / offered_rps * 1e6),
                              burst)[:n_requests]
            rng = np.random.default_rng(seed + rep)
            wl = traffic.trace_workload(
                times, rng.integers(max(1, prompt_mean // 2),
                                    prompt_mean * 3 // 2 + 1, n_requests),
                rng.integers(max(1, out_mean // 2),
                             out_mean * 3 // 2 + 1, n_requests))
        res = traffic.replay(wl, slots=sp.slots,
                             prefill_chunk=sp.prefill_chunk,
                             window=sp.window, kv_bucket=sp.kv_bucket,
                             step_time=tab.lookup)
        lat.append(res.latency_us)
        ttft.append(res.ttft_us)
        queue.append(res.queue_us)
        steps += res.n_steps
        tokens += res.tokens_out
        span = res.done_us.max() - res.arrive_us.min()
        sim_us += span
    lat = np.concatenate(lat)
    n_total = lat.size
    goodput_rps = n_total / max(sim_us, 1e-30) * 1e6
    return {
        "arrivals": arrivals,
        "offered_rps": round(offered_rps, 3),
        "n_requests": n_total, "mc_reps": reps,
        "latency_us": traffic.quantiles(lat),
        "ttft_us": traffic.quantiles(np.concatenate(ttft)),
        "queue_mean_us": float(np.mean(np.concatenate(queue))),
        "goodput_rps": round(goodput_rps, 3),
        "goodput_tok_s": round(tokens / max(sim_us, 1e-30) * 1e6, 1),
        "steps": steps,
        "latency_cdf": traffic.cdf_points(lat, 32),
        "wall_s": round(time.perf_counter() - t0, 4),
    }


def sweep_rank(arch: str, nranks: int, *, load_fracs, n_requests: int,
               reps: int, mc: int, check: int, engine: str,
               prompt_mean: int = 256, out_mean: int = 24,
               slots: int = 8, smoke: bool = False) -> dict:
    spec = ServeSimSpec(arch=arch, nranks=nranks, slots=slots,
                        window=1024 if not smoke else 256,
                        prefill_chunk=256 if not smoke else 64,
                        kv_buckets=4 if not smoke else 2)
    sim = ServeSim(spec)
    t0 = time.perf_counter()
    tab = sim.build_table(mc=mc, rng=nranks, engine=engine, check=check,
                          rtol=AGREEMENT_RTOL)
    table_wall = time.perf_counter() - t0
    cap = capacity_estimate_rps(sim, tab, prompt_mean, out_mean)
    rows = []
    for f in load_fracs:
        row = run_load_point(sim, tab, f * cap, n_requests, reps,
                             prompt_mean, out_mean, seed=nranks * 1000)
        row.update({"arch": arch, "nranks": nranks, "load_frac": f,
                    "engine": engine})
        rows.append(row)
        q = row["latency_us"]
        print(f"{arch:16s} N={nranks:5d} load={f:4.2f} "
              f"({row['offered_rps']:8.2f} rps)  "
              f"p50={q['p50']/1e3:9.1f}ms p99={q['p99']/1e3:9.1f}ms "
              f"p99.9={q['p999']/1e3:9.1f}ms  "
              f"goodput={row['goodput_rps']:8.2f} rps")
    # one bursty-trace point at the middle load (trace-arrival lane)
    mid = load_fracs[len(load_fracs) // 2]
    trow = run_load_point(sim, tab, mid * cap, n_requests, max(1, reps - 1),
                          prompt_mean, out_mean, seed=nranks * 1000 + 77,
                          arrivals="trace_bursty")
    trow.update({"arch": arch, "nranks": nranks, "load_frac": mid,
                 "engine": engine})
    rows.append(trow)
    knee = traffic.knee_point(
        [r["offered_rps"] for r in rows if r["arrivals"] == "poisson"],
        [r["goodput_rps"] for r in rows if r["arrivals"] == "poisson"],
        KNEE_FRAC)
    print(f"{arch:16s} N={nranks:5d} knee={knee} rps "
          f"(capacity est {cap:.2f} rps, table {table_wall:.2f}s, "
          f"{len(tab.states)}x{mc} columns)")
    return {"rows": rows, "knee_offered_rps": knee,
            "capacity_est_rps": round(cap, 3),
            "table": {"n_states": len(tab.states), "mc": mc,
                      "n_columns": len(tab.states) * mc,
                      "wall_s": round(table_wall, 4),
                      "interp_checked_columns": check},
            "_sim": sim, "_tab": tab,
            "_workload": (prompt_mean, out_mean)}


def speedup_row(swept: dict, *, engine: str, per_step_steps: int,
                offered_frac: float = 0.85) -> dict:
    """Batched-vs-per-step lane comparison on identical workloads.

    Batched rate counts the table build + every replayed step of the
    full load sweep; the per-step lane replays a truncated workload with
    one rebind + run_program per step (the same column payloads — lane
    agreement <=1e-9 is asserted on the per-request latencies)."""
    sim: ServeSim = swept["_sim"]
    tab = swept["_tab"]
    prompt_mean, out_mean = swept["_workload"]
    sp = sim.spec
    total_steps = sum(r["steps"] for r in swept["rows"])
    total_wall = (swept["table"]["wall_s"]
                  + sum(r["wall_s"] for r in swept["rows"]))
    batched_rate = total_steps / total_wall

    # truncated workload sized to ~per_step_steps steps
    slot_steps = int(np.ceil(prompt_mean / sp.prefill_chunk)) + out_mean
    n_req = max(2, int(per_step_steps * sp.slots / slot_steps))
    cap = swept["capacity_est_rps"]
    wl = traffic.poisson_workload(offered_frac * cap, n_req, 12345,
                                  prompt_tokens=prompt_mean,
                                  out_tokens=out_mean)
    kw = dict(slots=sp.slots, prefill_chunk=sp.prefill_chunk,
              window=sp.window, kv_bucket=sp.kv_bucket)
    ref = traffic.replay(wl, step_time=tab.lookup, **kw)

    calls = [0]

    def per_step(nd, npf, kvb, i):
        calls[0] += 1
        return sim.step_time_single(tab, (nd, npf, kvb), i % tab.mc,
                                    backend="auto", engine=engine)

    t0 = time.perf_counter()
    naive = traffic.replay(wl, step_time=per_step, **kw)
    per_step_wall = time.perf_counter() - t0
    per_step_rate = calls[0] / per_step_wall

    lane_rel = float(np.max(np.abs(naive.done_us - ref.done_us)
                            / np.maximum(np.abs(ref.done_us), 1e-12)))
    assert lane_rel <= AGREEMENT_RTOL, \
        f"per-step lane deviates from the batched table: {lane_rel:.2e}"
    speedup = batched_rate / per_step_rate
    print(f"speedup @N={sp.nranks}: batched {batched_rate:9.1f} steps/s "
          f"(table+replay, {total_steps} steps) vs per-step "
          f"{per_step_rate:7.2f} steps/s ({calls[0]} steps) -> "
          f"{speedup:.1f}x  (lane agree {lane_rel:.1e})")
    return {
        "nranks": sp.nranks, "arch": sp.arch, "engine": engine,
        "batched": {"steps": total_steps,
                    "wall_s": round(total_wall, 4),
                    "steps_per_sec": round(batched_rate, 1),
                    "includes_table_build": True},
        "per_step": {"steps": calls[0],
                     "wall_s": round(per_step_wall, 4),
                     "steps_per_sec": round(per_step_rate, 2)},
        "scenario_speedup": round(speedup, 1),
        "lane_agreement_rel": lane_rel,
    }


def strip_private(swept: dict) -> dict:
    return {k: v for k, v in swept.items() if not k.startswith("_")}


#: nominal host execution rates for the calibration row's roofline
#: prediction — the *ratio* is the anchor, not an absolute claim
HOST_RATE_GFLOPS = 10.0
HOST_BW_GBPS = 10.0


def calibration_row(arch: str = "exanest-lm-100m", *, requests: int = 4,
                    slots: int = 2, window: int = 64, max_new: int = 4,
                    prompt_len: int = 5) -> dict:
    """Measured-vs-predicted anchor (DESIGN.md §6): run the REAL
    slot-based engine (the ``launch/serve.py`` path — jax forward passes,
    wall clock) on a reduced config, take measured wall time per engine
    step, and fold it back onto :func:`repro.roofline.analysis.
    lm_serve_step_cost` via ``serve_step_calibration``.  The recorded
    ``measured_over_predicted`` ratio is the single constant that maps
    the closed form onto this host.  Skipped (with reason) where jax or
    a model backend is unavailable — the simulated sweep above never
    depends on it."""
    try:
        import jax
        from repro.config import reduced
        from repro.configs import get
        from repro.models import build_model
        from repro.roofline.analysis import serve_step_calibration
        from repro.serve.engine import ServeEngine
    except ImportError as e:                        # pragma: no cover
        return {"arch": arch, "skipped": f"import: {e}"}
    cfg = reduced(get(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, slots=slots, window=window)
    rng = np.random.default_rng(0)
    rids = [eng.submit(list(rng.integers(0, cfg.vocab_size,
                                         size=prompt_len)),
                       max_new_tokens=max_new) for _ in range(requests)]
    eng.step()                     # compile outside the measured window
    t0 = time.perf_counter()
    steps = 1 + eng.run_until_idle(max_steps=2000)
    dt = time.perf_counter() - t0
    stats = eng.request_steps()
    # mean occupied decode slots over the run: request-steps / engine steps
    busy = sum(d - s for s, d in stats.values())
    n_decode = max(1.0, busy / max(steps, 1))
    cal = serve_step_calibration(
        cfg, measured_step_us=dt / max(steps, 1) * 1e6,
        n_decode=n_decode, decode_kv=prompt_len + max_new / 2,
        rate_flops_per_us=HOST_RATE_GFLOPS * 1e3,
        bw_bytes_per_us=HOST_BW_GBPS * 1e3)
    cal.update({"arch": arch, "reduced": True, "engine_steps": steps,
                "requests": len(rids), "wall_s": round(dt, 4),
                "mean_decode_slots": round(n_decode, 3),
                "host_rate_gflops": HOST_RATE_GFLOPS,
                "host_bw_gbps": HOST_BW_GBPS})
    print(f"calibration {arch} (reduced): measured "
          f"{cal['measured_step_us']:.0f} us/step vs predicted "
          f"{cal['predicted_step_us']:.0f} us/step -> "
          f"ratio {cal['measured_over_predicted']:.2f} "
          f"({steps} steps, {dt:.2f}s)")
    return cal


def main(out_path: str = "BENCH_serve.json", smoke: bool = False,
         engine: str = "numpy", arch: str = "deepseek-7b") -> None:
    out: dict = {"engine": engine, "agreement_rtol": AGREEMENT_RTOL,
                 "knee_criterion":
                     f"largest offered load with goodput >= "
                     f"{KNEE_FRAC} * offered (open loop)",
                 "results": [], "knees": {}, "tables": {}}
    if smoke:
        out["smoke"] = True
        out["ranks"] = [16]
        sw = sweep_rank(arch, 16, load_fracs=(0.5, 1.0), n_requests=48,
                        reps=1, mc=2, check=3, engine=engine, slots=4,
                        prompt_mean=64, out_mean=8, smoke=True)
        out["results"] += sw["rows"]
        out["knees"]["16"] = {"knee_offered_rps": sw["knee_offered_rps"],
                              "capacity_est_rps": sw["capacity_est_rps"]}
        out["tables"]["16"] = sw["table"]
        out["speedup"] = [speedup_row(sw, engine=engine,
                                      per_step_steps=10)]
        out["calibration"] = calibration_row()
    else:
        out["ranks"] = list(RANKS)
        out["prediction_ranks"] = list(PREDICT_RANKS)
        out["arch"] = arch
        speedups = []
        for n in RANKS:
            sw = sweep_rank(arch, n, load_fracs=LOAD_FRACS,
                            n_requests=320, reps=3, mc=3, check=4,
                            engine=engine)
            out["results"] += sw["rows"]
            out["knees"][str(n)] = {
                "knee_offered_rps": sw["knee_offered_rps"],
                "capacity_est_rps": sw["capacity_est_rps"]}
            out["tables"][str(n)] = sw["table"]
            if n == 512:
                speedups.append(speedup_row(sw, engine=engine,
                                            per_step_steps=24))
        # the repo's own config at the prototype's 512 cores (second
        # lane: a model small enough that communication dominates)
        sw = sweep_rank("exanest-lm-100m", 512,
                        load_fracs=(0.5, 0.85, 1.2), n_requests=240,
                        reps=2, mc=3, check=4, engine=engine)
        out["results"] += sw["rows"]
        out["knees"]["exanest-lm-100m/512"] = {
            "knee_offered_rps": sw["knee_offered_rps"],
            "capacity_est_rps": sw["capacity_est_rps"]}
        for n in PREDICT_RANKS:
            sw = sweep_rank(arch, n, load_fracs=PREDICT_LOAD_FRACS,
                            n_requests=160, reps=2, mc=2, check=1,
                            engine=engine)
            for r in sw["rows"]:
                r["prediction"] = True
            out["results"] += sw["rows"]
            out["knees"][str(n)] = {
                "knee_offered_rps": sw["knee_offered_rps"],
                "capacity_est_rps": sw["capacity_est_rps"],
                "prediction": True}
            out["tables"][str(n)] = sw["table"]
        out["speedup"] = speedups
        out["calibration"] = calibration_row()
        # acceptance keys: full sweeps only (see module docstring)
        out["scenario_speedup_at_512"] = min(
            s["scenario_speedup"] for s in speedups)
        assert out["scenario_speedup_at_512"] >= 10.0, \
            "batched serving sweep must be >=10x the per-step lane at 512"
        for n in RANKS:
            rows = [r for r in out["results"]
                    if r["nranks"] == n and r["arch"] == arch
                    and r["arrivals"] == "poisson"]
            assert len(rows) == len(LOAD_FRACS), f"missing rows at {n}"
            assert all(np.isfinite(r["latency_us"]["p999"])
                       for r in rows), f"non-finite tail at {n}"
            assert out["knees"][str(n)]["knee_offered_rps"] is not None, \
                f"no knee found at {n}: widen the load grid"
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {out_path}")
    if not smoke:
        print(f"scenario_speedup @512: {out['scenario_speedup_at_512']}x; "
              f"knees: " + ", ".join(
                  f"{k}={v['knee_offered_rps']}"
                  for k, v in out["knees"].items()))


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default="numpy", choices=("numpy", "jax"),
                    help="scan backend of the batched compiled lane")
    ap.add_argument("--arch", default="deepseek-7b",
                    help="serving config for the full sweep")
    args = ap.parse_args()
    main(smoke=args.smoke, engine=args.engine, arch=args.arch)
