"""Population hillclimb over the sigma-split butterfly family, on the
first-class population-binding seam.

This is the original ROADMAP item 2 search seam (PR 6), rebuilt on the
round algebra: candidates are :class:`Split` terms from
``core/exanet/schedule_algebra.py`` and a whole generation is costed as
ONE batched compiled replay through
:class:`~repro.core.exanet.schedule_algebra.SchedulePopulation` +
:meth:`ExanetMachine.cost_population` — one batch column per genome,
one lowered program per skeleton reused across generations.  (The old
``ButterflyPopulation`` class that reinterpreted the ``nbytes`` protocol
argument as a candidate index is gone; population binding is now an
explicit type on the schedule/compile seam.)

``sigma_i = 1/2`` everywhere *is* Rabenseifner's recursive halving; the
hillclimb re-derives that balance point at bandwidth-bound sizes without
being told, and skews splits at latency-bound sizes where rounding and
the 32 B eager boundary distort the trade.

Every winner passes the two synthesis gates (``core/synth``): the
contribution-tracking semantic check and the <=1e-9 interpreter
agreement.  The *full* skeleton search (hierarchical/pipeline/
dissemination combinators, winner cache, planner wiring) lives in
``benchmarks/synth_sweep.py``; this benchmark keeps the single-family
climb as the regression point for search throughput.

Run:
  PYTHONPATH=src python benchmarks/hillclimb.py [--smoke] [--engine jax]
      [--nranks 64] [--pop 48] [--gens 10]

Writes ``BENCH_hillclimb.json`` (schema: DESIGN.md §6).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.exanet.schedule_algebra import (SIGMA_HI,  # noqa: E402
                                                SIGMA_LO, SchedulePopulation,
                                                Split, TermSchedule)
from repro.core.machine import ExanetMachine  # noqa: E402
from repro.core.synth.search import AGREEMENT_RTOL  # noqa: E402
from repro.core.synth.verify import check_term  # noqa: E402

#: latency-bound, crossover, and bandwidth-bound points of the OSU grid
NBYTES = (64, 4096, 262144)


def evaluate(machine: ExanetMachine, nbytes: int, nranks: int,
             population: np.ndarray, engine: str) -> np.ndarray:
    """Fitness (simulated seconds) of every candidate genome — ONE
    batched cost_population call, candidates as columns."""
    pop = SchedulePopulation(
        [TermSchedule(Split(tuple(g))) for g in population], nbytes)
    return np.asarray(machine.cost_population(pop, nranks, engine=engine))


def hillclimb(machine: ExanetMachine, nbytes: int, nranks: int, *,
              pop: int, gens: int, engine: str,
              rng: np.random.Generator) -> dict:
    steps = nranks.bit_length() - 1
    # seed half the population at the balanced (Rabenseifner) point,
    # half uniform — the climb should rediscover balance on its own
    population = np.clip(np.concatenate([
        0.5 + 0.08 * rng.standard_normal((pop // 2, steps)),
        rng.uniform(0.05, 0.95, (pop - pop // 2, steps))]),
        SIGMA_LO, SIGMA_HI)
    best_g, best_s = None, np.inf
    evals = 0
    t0 = time.perf_counter()
    for gen in range(gens):
        cost = evaluate(machine, nbytes, nranks, population, engine)
        evals += len(population)
        order = np.argsort(cost)
        if cost[order[0]] < best_s:
            best_s, best_g = float(cost[order[0]]), \
                population[order[0]].copy()
        # elite quarter survives; the rest are mutated elite clones
        elite = population[order[:max(1, pop // 4)]]
        scale = 0.15 * (1.0 - gen / gens) + 0.02
        children = elite[rng.integers(0, len(elite), pop - len(elite))] \
            + scale * rng.standard_normal((pop - len(elite), steps))
        population = np.clip(np.concatenate([elite, children]),
                             SIGMA_LO, SIGMA_HI)
    wall = time.perf_counter() - t0

    # synthesis gates: semantic contribution check + the equivalence
    # check (winner's batched latency == interpreter replay <=1e-9)
    winner = Split(tuple(best_g))
    check_term(winner, nranks)
    sched = TermSchedule(winner)
    mpi = machine._mpi_for(nranks)
    interp_s = mpi.run_schedule(sched, nbytes, nranks,
                                backend="interp").latency_us * 1e-6
    rel = abs(best_s - interp_s) / max(abs(interp_s), 1e-30)
    assert rel <= AGREEMENT_RTOL, \
        f"winner disagrees with interpreter: {rel:.2e} rel"

    from repro.core.exanet.schedules import (RabenseifnerAllreduce,
                                             RecursiveDoublingAllreduce,
                                             RingAllreduce)
    menu = {}
    for cls in (RecursiveDoublingAllreduce, RabenseifnerAllreduce,
                RingAllreduce):
        msched = cls()
        menu[msched.name] = machine.cost_many(msched, nranks, [nbytes],
                                              engine=engine)[0]
    best_menu = min(menu.values())
    return {
        "nbytes": nbytes, "nranks": nranks, "engine": engine,
        "population": pop, "generations": gens, "evals": evals,
        "wall_s": round(wall, 4),
        "candidates_per_sec": round(evals / wall, 1),
        "batched_calls": gens,
        "best_genome": [round(float(x), 4) for x in best_g],
        "winner_name": sched.name,
        "best_s": best_s, "interp_agreement_rel": rel,
        "semantic_ok": True,
        "menu_s": {k: round(v, 9) for k, v in menu.items()},
        "vs_best_menu": round(best_s / best_menu, 4),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny population/generations for CI")
    ap.add_argument("--engine", default="numpy",
                    choices=("numpy", "jax"),
                    help="scan backend of the batched replays")
    ap.add_argument("--nranks", type=int, default=64)
    ap.add_argument("--pop", type=int, default=48)
    ap.add_argument("--gens", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_hillclimb.json")
    args = ap.parse_args()
    pop, gens = (12, 3) if args.smoke else (args.pop, args.gens)
    machine = ExanetMachine()
    rng = np.random.default_rng(args.seed)
    rows = []
    for nbytes in NBYTES:
        row = hillclimb(machine, nbytes, args.nranks, pop=pop, gens=gens,
                        engine=args.engine, rng=rng)
        rows.append(row)
        print(f"nbytes={nbytes:7d} N={args.nranks}  best={row['best_s']:.3e}s"
              f"  vs-menu={row['vs_best_menu']:.3f}x  "
              f"({row['candidates_per_sec']:.0f} cand/s, "
              f"{row['batched_calls']} batched calls, agree "
              f"{row['interp_agreement_rel']:.1e})")
    out = {"smoke": args.smoke, "engine": args.engine,
           "nranks": args.nranks, "results": rows}
    if not args.smoke:
        out["max_vs_best_menu"] = max(r["vs_best_menu"] for r in rows)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
