"""Population hillclimb over a parametric allreduce-schedule family,
fitness-evaluated on the batched compiled substrate.

This is the search seam ROADMAP item 2 (schedule synthesis) drives: the
simulator as a fitness function.  The inner loop evaluates a *whole
candidate population per call* — one genome-indexed schedule binds every
candidate as a batch column of a single compiled replay
(``ExanetMachine.cost_many``), so a generation costs one vectorized run
instead of P interpreted simulations.

The searched family is a generalized xor-butterfly allreduce: at
reduce-scatter step ``i`` (distance ``d = n/2^{i+1}``) each pair splits
its working set by a genome fraction ``sigma_i`` — the lower rank keeps
``(1-sigma_i)`` and receives its partner's copy of that part, the upper
rank keeps ``sigma_i``.  The all-gather phase mirrors the splits back.
``sigma_i = 1/2`` everywhere *is* Rabenseifner's recursive halving; the
hillclimb re-derives that balance point at bandwidth-bound sizes without
being told, and is free to skew splits at latency-bound sizes where
rounding and the 32 B eager boundary distort the trade.

Every generation's best candidate is cross-checked against the
interpreter to <=1e-9 relative — the agreement harness is the
equivalence check that keeps synthesized schedules honest (the Exo
pattern, see ROADMAP item 2).

Run:
  PYTHONPATH=src python benchmarks/hillclimb.py [--smoke] [--engine jax]
      [--nranks 64] [--pop 48] [--gens 10]

Writes ``BENCH_hillclimb.json`` (schema: DESIGN.md §6).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.exanet.schedules import (RabenseifnerAllreduce,  # noqa: E402
                                         RecursiveDoublingAllreduce,
                                         RingAllreduce, Round, Schedule)
from repro.core.machine import ExanetMachine  # noqa: E402

#: latency-bound, crossover, and bandwidth-bound points of the OSU grid
NBYTES = (64, 4096, 262144)
AGREEMENT_RTOL = 1e-9


class ButterflyPopulation(Schedule):
    """Genome-indexed butterfly-allreduce family.

    The ``nbytes`` argument of the :class:`CollectiveSchedule` protocol
    is reinterpreted as a *candidate index* into ``population`` — the
    compiled executor then binds the whole population as columns of one
    replay (``cost_many(sched, nranks, range(P))``), because the round
    structure (xor pairs, exchange flags) is genome-invariant while the
    per-send byte counts vary per column.

    ``population`` is a (P, log2(nranks)) array of split fractions in
    (0, 1); the payload is the constructor's ``nbytes``.
    """

    name = "allreduce_butterfly_population"

    def __init__(self, nbytes: int, population: np.ndarray):
        self.nbytes = int(nbytes)
        self.population = np.asarray(population, dtype=np.float64)

    # full-vector endpoint copies, like every software allreduce here
    def pre_copy_bytes(self, idx: int) -> int:
        return self.nbytes

    def post_copy_bytes(self, idx: int) -> int:
        return self.nbytes

    def rounds(self, nranks: int, idx: int):
        if nranks < 4 or nranks & (nranks - 1):
            raise ValueError(f"butterfly family needs power-of-two "
                             f"ranks >= 4, got {nranks}")
        # modulo: structure probes (round_parallelism's _STRUCT_SIZE)
        # may pass any index, and the structure is genome-invariant
        g = self.population[int(idx) % len(self.population)]
        steps = nranks.bit_length() - 1
        if g.shape[0] != steps:
            raise ValueError(f"genome length {g.shape[0]} != log2(nranks)"
                             f"={steps}")
        # per-rank working-set bytes; r and r^d share an identical split
        # history (they differ only in bit log2(d)), so pair sets agree
        w = np.full(nranks, float(self.nbytes))
        step, d = 0, nranks // 2
        for sigma in g:
            sends, kept = [], np.empty(nranks)
            for r in range(nranks):
                p = r ^ d
                # lower rank keeps (1-sigma): it sends its copy of the
                # partner's sigma-share and receives the (1-sigma)-share
                mine = (1.0 - sigma) if r < p else sigma
                sends.append((r, p, max(1, int(round(w[r] * (1.0 - mine))))))
                kept[r] = w[r] * mine
            # the reduction each rank performs covers its kept share;
            # Round carries one reduce_bytes, so charge the larger share
            red = max(1, int(round(w.max() * max(sigma, 1.0 - sigma))))
            yield Round(step, tuple(sends), exchange=True,
                        reduce_bytes=red, label="reduce_scatter")
            w = kept
            step, d = step + 1, d // 2
        d = 1
        while d < nranks:
            # all-gather mirror: everyone ships its whole owned segment
            sends = tuple((r, r ^ d, max(1, int(round(w[r]))))
                          for r in range(nranks))
            yield Round(step, sends, exchange=True, label="all_gather")
            w = w + w[np.arange(nranks) ^ d]
            step, d = step + 1, d * 2


def evaluate(machine: ExanetMachine, nbytes: int, nranks: int,
             population: np.ndarray, engine: str) -> np.ndarray:
    """Fitness (simulated seconds) of every candidate — ONE batched
    cost_many call, candidates as columns."""
    fam = ButterflyPopulation(nbytes, population)
    return np.asarray(machine.cost_many(fam, nranks,
                                        range(len(population)),
                                        engine=engine))


def hillclimb(machine: ExanetMachine, nbytes: int, nranks: int, *,
              pop: int, gens: int, engine: str,
              rng: np.random.Generator) -> dict:
    steps = nranks.bit_length() - 1
    # seed half the population at the balanced (Rabenseifner) point,
    # half uniform — the climb should rediscover balance on its own
    population = np.clip(np.concatenate([
        0.5 + 0.08 * rng.standard_normal((pop // 2, steps)),
        rng.uniform(0.05, 0.95, (pop - pop // 2, steps))]), 0.02, 0.98)
    best_g, best_s = None, np.inf
    evals = 0
    t0 = time.perf_counter()
    for gen in range(gens):
        cost = evaluate(machine, nbytes, nranks, population, engine)
        evals += len(population)
        order = np.argsort(cost)
        if cost[order[0]] < best_s:
            best_s, best_g = float(cost[order[0]]), \
                population[order[0]].copy()
        # elite quarter survives; the rest are mutated elite clones
        elite = population[order[:max(1, pop // 4)]]
        scale = 0.15 * (1.0 - gen / gens) + 0.02
        children = elite[rng.integers(0, len(elite), pop - len(elite))] \
            + scale * rng.standard_normal((pop - len(elite), steps))
        population = np.clip(np.concatenate([elite, children]),
                             0.02, 0.98)
    wall = time.perf_counter() - t0

    # equivalence check: the winning genome's batched latency must match
    # the interpreter replaying the same schedule (<=1e-9 relative)
    fam = ButterflyPopulation(nbytes, best_g[None, :])
    mpi = machine._mpi_for(nranks)
    interp_s = mpi.run_schedule(fam, 0, nranks,
                                backend="interp").latency_us * 1e-6
    rel = abs(best_s - interp_s) / max(abs(interp_s), 1e-30)
    assert rel <= AGREEMENT_RTOL, \
        f"winner disagrees with interpreter: {rel:.2e} rel"

    menu = {}
    for cls in (RecursiveDoublingAllreduce, RabenseifnerAllreduce,
                RingAllreduce):
        sched = cls()
        menu[sched.name] = machine.cost_many(sched, nranks, [nbytes],
                                             engine=engine)[0]
    best_menu = min(menu.values())
    return {
        "nbytes": nbytes, "nranks": nranks, "engine": engine,
        "population": pop, "generations": gens, "evals": evals,
        "wall_s": round(wall, 4),
        "candidates_per_sec": round(evals / wall, 1),
        "batched_calls": gens,
        "best_genome": [round(float(x), 4) for x in best_g],
        "best_s": best_s, "interp_agreement_rel": rel,
        "menu_s": {k: round(v, 9) for k, v in menu.items()},
        "vs_best_menu": round(best_s / best_menu, 4),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny population/generations for CI")
    ap.add_argument("--engine", default="numpy",
                    choices=("numpy", "jax"),
                    help="scan backend of the batched replays")
    ap.add_argument("--nranks", type=int, default=64)
    ap.add_argument("--pop", type=int, default=48)
    ap.add_argument("--gens", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_hillclimb.json")
    args = ap.parse_args()
    pop, gens = (12, 3) if args.smoke else (args.pop, args.gens)
    machine = ExanetMachine()
    rng = np.random.default_rng(args.seed)
    rows = []
    for nbytes in NBYTES:
        row = hillclimb(machine, nbytes, args.nranks, pop=pop, gens=gens,
                        engine=args.engine, rng=rng)
        rows.append(row)
        print(f"nbytes={nbytes:7d} N={args.nranks}  best={row['best_s']:.3e}s"
              f"  vs-menu={row['vs_best_menu']:.3f}x  "
              f"({row['candidates_per_sec']:.0f} cand/s, "
              f"{row['batched_calls']} batched calls, agree "
              f"{row['interp_agreement_rel']:.1e})")
    out = {"smoke": args.smoke, "engine": args.engine,
           "nranks": args.nranks, "results": rows}
    if not args.smoke:
        out["max_vs_best_menu"] = max(r["vs_best_menu"] for r in rows)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
