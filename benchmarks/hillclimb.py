"""§Perf hillclimb driver: lower a cell with named variants (extra_flags),
record the roofline deltas vs the baseline.

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb --cell <name> --out results/perf

Cells and their iteration ladders are defined in VARIANTS — each entry is
(variant_name, hypothesis, extra_flags). Results append to
results/perf/<cell>.json for the EXPERIMENTS.md §Perf log.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# (arch, shape, multi_pod) per hillclimb cell
CELLS = {
    "dsv3-train": ("deepseek-v3-671b", "train_4k", True),
    "starcoder2-prefill": ("starcoder2-7b", "prefill_32k", False),
    "mistral-train": ("mistral-large-123b", "train_4k", False),
}

VARIANTS = {
    "starcoder2-prefill": [
        ("baseline", "36 heads don't divide TP=16 -> attention fully "
         "replicated per chip; expect attention to dominate flops+bytes", {}),
        ("pad48", "pad q heads 36->48 (exact math, zero-grad padding): "
         "attention shards 16-way -> ~12x less attn flops/bytes per chip",
         {"cfg_overrides": {"pad_heads_to": 48}}),
        ("pad48_chunk2k", "double flash chunks 1024->2048: halves the "
         "number of block boundaries -> ~2x less score-tensor HBM traffic",
         {"cfg_overrides": {"pad_heads_to": 48, "q_chunk": 2048,
                            "kv_chunk": 2048}}),
        ("pad48_chunk4k", "4096 chunks: further boundary reduction, but "
         "block buffers (B*H_loc*qc*kc) start to stress VMEM-scale reuse",
         {"cfg_overrides": {"pad_heads_to": 48, "q_chunk": 4096,
                            "kv_chunk": 4096}}),
    ],
    "mistral-train": [
        ("baseline", "88-layer FSDP dense model: expect weight all-gathers "
         "(x3 passes x microbatches) to dominate collectives", {}),
        ("mb1", "microbatches 4->1: weight gathers shrink 4x at the cost "
         "of 4x activation memory (43GB — over budget, for measurement)",
         {"train_policy": {"microbatches": 1}}),
        ("quantized-opt", "int8 m/v states: -5.7GB optimizer memory, "
         "bf16 accum -1.9GB; expect no change in roofline terms",
         {"train_policy": {"opt_cfg": "QUANT"}}),
        ("chunk2k", "flash chunks 2048: less attention HBM traffic",
         {"cfg_overrides": {"q_chunk": 2048, "kv_chunk": 2048}}),
        ("gather-weights", "force ZeRO-3 weight all-gathers instead of "
         "GSPMD's activation psums over 'data' (see dsv3 diagnosis)",
         {"gather_weights": True}),
    ],
    "dsv3-train": [
        ("baseline", "MoE + MLA + MTP on 2 pods: expect collectives "
         "(expert a2a + FSDP gathers + cross-pod grad AR) to dominate", {}),
        ("cap1.0", "capacity factor 1.25->1.0: 20% less a2a payload and "
         "20% less expert compute (drops ~2% more tokens)",
         {"cfg_overrides": {"moe": "CAP1"}}),
        ("gather-weights", "measured 1.9TB/dev of activation all-reduce: "
         "GSPMD psums activations over 'data' instead of gathering the "
         "0.36GB/layer dense FSDP shards -> force ZeRO-3 weight gathers",
         {"gather_weights": True}),
        ("a2a-int8", "int8 a2a dispatch via custom-VJP quantized "
         "all_to_all (DeepSeek-V3's own fp8-dispatch trick; the naive "
         "round() variant silently ZEROED the dispatch gradient): ~2x "
         "less EP wire bytes both directions, off the 3.3TB/dev a2a",
         {"cfg_overrides": {"moe": "QUANT"}}),
        ("a2a-int8+cap1.0", "combine the two confirmed wins",
         {"cfg_overrides": {"moe": "QUANT_CAP1"}}),
    ],
}


def _resolve(flags, arch):
    import dataclasses
    from repro.configs import get
    from repro.train.optimizer import AdamWConfig
    out = json.loads(json.dumps({k: v for k, v in flags.items()
                                 if k != "train_policy"}))
    out = dict(flags)
    co = dict(out.get("cfg_overrides", {}))
    if co.get("moe") == "CAP1":
        co["moe"] = dataclasses.replace(get(arch).moe, capacity_factor=1.0)
    if co.get("moe") == "QUANT":
        co["moe"] = dataclasses.replace(get(arch).moe, a2a_quant=True)
    if co.get("moe") == "QUANT_CAP1":
        co["moe"] = dataclasses.replace(get(arch).moe, a2a_quant=True,
                                        capacity_factor=1.0)
    if co:
        out["cfg_overrides"] = co
    tp = dict(out.get("train_policy", {}))
    if tp.get("opt_cfg") == "QUANT":
        tp["opt_cfg"] = AdamWConfig(quantize_states=True)
    if tp:
        out["train_policy"] = tp
    return out


def run(cell: str, out_dir: str, only: str | None = None):
    from repro.launch.dryrun import analyze, lower_cell
    arch, shape, multi = CELLS[cell]
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, cell + ".json")
    results = json.load(open(path)) if os.path.exists(path) else []
    done = {r["variant"] for r in results}
    for name, hypothesis, flags in VARIANTS[cell]:
        if name in done or (only and name != only):
            continue
        print(f"[{cell}] variant={name}: {hypothesis}", flush=True)
        try:
            lowered, meta = lower_cell(arch, shape, multi,
                                       extra_flags=_resolve(flags, arch))
            compiled_txt_holder = {}
            meta = analyze(lowered, meta,
                           hlo_sink=compiled_txt_holder)
            rec = {"variant": name, "hypothesis": hypothesis, **meta}
            # projected effect of the fused Pallas attention kernels:
            # score/probability tensors stay in VMEM (see hlo_cost)
            if "hlo" in compiled_txt_holder:
                from repro.roofline.hlo_cost import flash_block_report
                from repro.roofline.analysis import roofline_terms
                fr = flash_block_report(compiled_txt_holder["hlo"])
                new_bytes = (meta["hlo_cost"]["bytes"]
                             - fr["savings_bytes"])
                proj = roofline_terms(meta["hlo_cost"]["flops"], new_bytes,
                                      meta["collectives"]["total"])
                rec["pallas_attention_projection"] = {
                    "attn_block_gb": fr["block_bytes"] / 1e9,
                    "fused_gb": fr["fused_bytes"] / 1e9,
                    "memory_s": proj["memory_s"],
                    "roofline_fraction": proj["roofline_fraction"],
                    "bottleneck": proj["bottleneck"],
                }
        except Exception as e:  # noqa: BLE001
            rec = {"variant": name, "hypothesis": hypothesis,
                   "error": f"{type(e).__name__}: {e}"}
        results.append(rec)
        with open(path, "w") as f:
            json.dump(results, f, indent=1, default=str)
        if "roofline" in rec:
            r = rec["roofline"]
            print(f"  -> comp={r['compute_s']:.3f} mem={r['memory_s']:.3f} "
                  f"coll={r['collective_s']:.3f} rf={r['roofline_fraction']:.3f} "
                  f"peak={rec['memory']['peak_gb']:.1f}GB", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS), required=True)
    ap.add_argument("--variant", default=None)
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()
    run(args.cell, args.out, args.variant)


if __name__ == "__main__":
    main()
