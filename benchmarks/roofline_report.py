"""Roofline report generator (deliverable g): reads results/dryrun JSONs and
emits the EXPERIMENTS.md §Roofline table + per-cell commentary."""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def load(out_dir="results/dryrun"):
    cells = {}
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        d = json.load(open(f))
        tag = os.path.basename(f)[:-5]
        cells[tag] = d
    return cells


def _advice(d):
    r = d["roofline"]
    bot = r["bottleneck"]
    uf = d.get("useful_flops_ratio", 0)
    if bot == "collective_s":
        return ("reduce collective volume: hierarchical/cross-pod schedule, "
                "bigger buckets, EP all-to-all capacity factor")
    if bot == "memory_s":
        if uf < 0.2:
            return ("fuse attention score traffic (Pallas kernel path) / "
                    "TP-shard the replicated attention (pad heads)")
        return "raise arithmetic intensity: bigger flash chunks, bf16 accum"
    return "compute-bound: near roofline; overlap remaining collectives"


def markdown_table(cells) -> str:
    lines = ["| arch | shape | mesh | compute_s | memory_s | collective_s |"
             " bottleneck | MODEL/HLO flops | roofline frac | peak GB |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for tag, d in cells.items():
        arch, shape, mesh = tag.rsplit("__", 2)
        if "skipped" in d:
            lines.append(f"| {arch} | {shape} | {mesh} | — | — | — | "
                         f"skipped | — | — | — |")
            continue
        if "error" in d:
            lines.append(f"| {arch} | {shape} | {mesh} | ERROR | | | | | | |")
            continue
        r = d["roofline"]
        lines.append(
            f"| {arch} | {shape} | {mesh} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['bottleneck'].replace('_s','')} | "
            f"{d['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {d['memory']['peak_gb']:.1f} |")
    return "\n".join(lines)


def commentary(cells) -> str:
    out = []
    for tag, d in cells.items():
        if "roofline" not in d:
            continue
        out.append(f"* `{tag}`: {_advice(d)}")
    return "\n".join(out)


def main():
    cells = load()
    print(markdown_table(cells))
    print()
    print("### What would move the dominant term")
    print(commentary(cells))


if __name__ == "__main__":
    main()
