"""Micro-benchmark: CollectivePlanner quality and overhead.

Two sections, written to ``BENCH_planner.json``:

* **tpu_grad_sync** — a mixed message-size workload (trace-like: many tiny
  norm/bias buckets, few huge weight buckets) planned on the TpuMachine
  over a (intra x inter) mesh.  Reports total predicted cost of the chosen
  plans vs the always-flat baseline (the headline: planned >= 2x cheaper),
  plus plan-cache hit rate and plans/sec (the planner must be cheap enough
  to run at trace time).
* **exanet_fig19** — the sw/accel crossover on the ExanetMachine at full
  event-simulation fidelity: per vector size, the planner's choice and the
  cost-derived crossover size (the paper's Fig. 19 reproduced from cost
  alone, no hand-coded 4 KB threshold).
* **exanet_plan_many** — sim-fidelity cold planning over a message-size
  grid: ``plan_many`` (one compiled round program per candidate schedule
  serves the whole grid, PR 3) vs scalar ``plan`` per size, plus the warm
  (plan-cache) rate.

Run: PYTHONPATH=src python benchmarks/planner_sweep.py [--smoke]
"""

from __future__ import annotations

import json
import os
import random
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.comm import CommPolicy                      # noqa: E402
from repro.core.exanet.mpi import ExanetMPI                 # noqa: E402
from repro.core.machine import ExanetMachine                # noqa: E402
from repro.core.planner import CollectivePlanner            # noqa: E402

#: (bucket bytes, count) — lognormal-ish gradient-bucket mix: most messages
#: are tiny fused scalars/norms, most *bytes* are in a few huge buckets
WORKLOAD = (
    (256, 400), (1 << 10, 300), (8 << 10, 150), (64 << 10, 80),
    (512 << 10, 40), (4 << 20, 20), (32 << 20, 8), (128 << 20, 2),
)
MESH = (16, 4)  # (intra=ICI, inter=cross-pod DCN) axis sizes


def tpu_grad_sync_section(repeats: int) -> dict:
    policy = CommPolicy()
    planner = policy.planner
    msgs = [size for size, cnt in WORKLOAD for _ in range(cnt)]
    random.Random(0).shuffle(msgs)

    # cold planning cost: what a jit trace over fresh bucket sizes pays
    # (every query a cache miss, measured on a fresh planner)
    cold_sizes = sorted({s for s, _ in WORKLOAD} |
                        {s + 128 for s, _ in WORKLOAD})
    cold_planner = CommPolicy().planner
    t0 = time.perf_counter()
    for s in cold_sizes:
        cold_planner.plan("grad_sync", s, MESH, allow_lossy=True)
    cold_pps = len(cold_sizes) / (time.perf_counter() - t0)

    planned = flat = 0.0
    chosen: dict[str, int] = {}
    t0 = time.perf_counter()
    for _ in range(repeats):
        for size in msgs:
            # explicit lossy opt-in: the sweep benchmarks the planner's
            # full candidate set, including the int8 cross-pod sync
            plan = planner.plan("grad_sync", size, MESH, allow_lossy=True)
            planned += plan.cost_s
            flat += plan.cost_of("flat")
            chosen[plan.schedule] = chosen.get(plan.schedule, 0) + 1
    wall = time.perf_counter() - t0
    n_plans = len(msgs) * repeats
    planned /= repeats
    flat /= repeats
    return {
        "mesh": {"intra": MESH[0], "inter": MESH[1]},
        "workload": [{"bytes": s, "count": c} for s, c in WORKLOAD],
        "planned_cost_s": planned,
        "always_flat_cost_s": flat,
        "cost_reduction_x": round(flat / planned, 2),
        "chosen": chosen,
        "cold_plans_per_sec": round(cold_pps, 1),
        "warm_plans_per_sec": round(n_plans / wall, 1),
        "plan_cache": planner.cache_info(),
    }


def exanet_fig19_section(nranks_list: tuple[int, ...]) -> dict:
    out = {}
    for nranks in nranks_list:
        mpi = ExanetMPI(ranks_per_mpsoc=1)
        planner = CollectivePlanner(ExanetMachine(mpi=mpi), fidelity="sim")
        sizes = [256 << i for i in range(9)]  # 256 B .. 64 KB
        rows = []
        crossover = None
        for size in sizes:
            plan = planner.plan("allreduce", size, (nranks,))
            accel = plan.cost_of("accel")
            best_sw = min(c for k, c in plan.costs if k != "accel")
            if crossover is None and plan.schedule != "accel":
                crossover = size
            rows.append({"bytes": size, "choice": plan.schedule,
                         "accel_us": round(accel * 1e6, 2),
                         "best_sw_us": round(best_sw * 1e6, 2)})
        out[str(nranks)] = {"rows": rows,
                            "crossover_bytes_cost_derived": crossover}
    return out


def exanet_plan_many_section(nranks: int, n_sizes: int) -> dict:
    """Cold sim-fidelity planning over a size grid, batched vs scalar."""
    sizes = [1 << i for i in range(n_sizes)]
    batched = CollectivePlanner(ExanetMachine(), fidelity="sim")
    t0 = time.perf_counter()
    plans = batched.plan_many("allreduce", sizes, (nranks,))
    t_batch = time.perf_counter() - t0
    scalar = CollectivePlanner(ExanetMachine(), fidelity="sim")
    t0 = time.perf_counter()
    for s in sizes:
        scalar.plan("allreduce", s, (nranks,))
    t_scalar = time.perf_counter() - t0
    t0 = time.perf_counter()
    batched.plan_many("allreduce", sizes, (nranks,))
    t_warm = time.perf_counter() - t0
    return {
        "nranks": nranks, "grid_sizes": len(sizes),
        "cold_batched_plans_per_sec": round(len(sizes) / t_batch, 1),
        "cold_scalar_plans_per_sec": round(len(sizes) / t_scalar, 1),
        "warm_batched_plans_per_sec": round(len(sizes) / t_warm, 1),
        "cold_speedup_x": round(t_scalar / t_batch, 2),
        "chosen": {str(s): p.schedule for s, p in zip(sizes, plans)},
    }


def main(out_path: str = "BENCH_planner.json", smoke: bool = False) -> None:
    repeats = 2 if smoke else 5
    nranks = (16, 64) if smoke else (16, 64, 128)
    out = {"tpu_grad_sync": tpu_grad_sync_section(repeats),
           "exanet_fig19": exanet_fig19_section(nranks),
           "exanet_plan_many": exanet_plan_many_section(
               16 if smoke else 64, 12 if smoke else 21)}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    g = out["tpu_grad_sync"]
    print(f"planned vs always-flat: {g['cost_reduction_x']:.2f}x cheaper "
          f"({g['planned_cost_s']*1e3:.2f} ms vs "
          f"{g['always_flat_cost_s']*1e3:.2f} ms per step), "
          f"chosen={g['chosen']}")
    print(f"planner overhead: {g['cold_plans_per_sec']:.0f} cold / "
          f"{g['warm_plans_per_sec']:.0f} warm plans/s, "
          f"cache hit rate {g['plan_cache']['hit_rate']:.3f}")
    for n, sec in out["exanet_fig19"].items():
        print(f"exanet N={n}: cost-derived sw/accel crossover at "
              f"{sec['crossover_bytes_cost_derived']} B")
    pm = out["exanet_plan_many"]
    print(f"plan_many N={pm['nranks']} over {pm['grid_sizes']} sizes: "
          f"{pm['cold_batched_plans_per_sec']:.0f} cold-batched vs "
          f"{pm['cold_scalar_plans_per_sec']:.0f} cold-scalar plans/s "
          f"({pm['cold_speedup_x']:.2f}x), "
          f"{pm['warm_batched_plans_per_sec']:.0f} warm")
    print(f"wrote {out_path}")
    assert g["cost_reduction_x"] >= 2.0, "planner must beat always-flat 2x"


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
