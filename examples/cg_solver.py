"""miniFE/HPCG analog (deliverable b): distributed conjugate-gradient solve
of a 3-D 7-point Poisson problem in JAX — the workload class the paper
scales to 512 ranks (§6.2) — with halo exchanges via collective-permute and
dot-product allreduces, the two communication patterns whose costs the
ExaNet model predicts.

Run: PYTHONPATH=src python examples/cg_solver.py [--n 48] [--iters 100]
On multiple devices the domain is slab-decomposed over the 'data' axis via
shard_map (halo exchange = jax.lax.ppermute, dots = psum).
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def apply_stencil(u, halo_lo, halo_hi, h2):
    """7-point Laplacian with Dirichlet boundaries; u: (nz_local, ny, nx).
    halo_lo/hi: (ny, nx) neighbour slabs (zeros at the global boundary)."""
    up = jnp.concatenate([halo_lo[None], u, halo_hi[None]], axis=0)
    lap = (6.0 * u
           - up[:-2] - up[2:]
           - jnp.pad(u[:, :-1], ((0, 0), (1, 0), (0, 0)))
           - jnp.pad(u[:, 1:], ((0, 0), (0, 1), (0, 0)))
           - jnp.pad(u[:, :, :-1], ((0, 0), (0, 0), (1, 0)))
           - jnp.pad(u[:, :, 1:], ((0, 0), (0, 0), (0, 1))))
    return lap / h2


def make_cg(mesh, n, iters):
    axis = "data"
    nshards = mesh.shape[axis] if mesh is not None else 1
    h2 = (1.0 / (n + 1)) ** 2

    def halo_exchange(u):
        if nshards == 1:
            z = jnp.zeros_like(u[0])
            return z, z
        idx = jax.lax.axis_index(axis)
        lo = jax.lax.ppermute(u[-1], axis,
                              [(i, (i + 1) % nshards) for i in range(nshards)])
        hi = jax.lax.ppermute(u[0], axis,
                              [(i, (i - 1) % nshards) for i in range(nshards)])
        lo = jnp.where(idx == 0, 0.0, lo)            # global boundary
        hi = jnp.where(idx == nshards - 1, 0.0, hi)
        return lo, hi

    def pdot(a, b):
        d = jnp.vdot(a, b)
        return jax.lax.psum(d, axis) if nshards > 1 else d

    def A(u):
        lo, hi = halo_exchange(u)
        return apply_stencil(u, lo, hi, h2)

    def cg(b):
        x = jnp.zeros_like(b)
        r = b - A(x)
        p = r
        rs = pdot(r, r)

        def body(i, carry):
            x, r, p, rs = carry
            Ap = A(p)
            alpha = rs / jnp.maximum(pdot(p, Ap), 1e-30)
            x = x + alpha * p
            r = r - alpha * Ap
            rs_new = pdot(r, r)
            p = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
            return (x, r, p, rs_new)

        x, r, p, rs = jax.lax.fori_loop(0, iters, body, (x, r, p, rs))
        return x, jnp.sqrt(rs)

    if mesh is None:
        return jax.jit(cg)
    return jax.jit(jax.shard_map(
        cg, mesh=mesh, in_specs=P(axis, None, None),
        out_specs=(P(axis, None, None), P()), check_vma=False))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32, help="grid points per dim")
    ap.add_argument("--iters", type=int, default=120)
    args = ap.parse_args()
    n = args.n

    ndev = jax.device_count()
    mesh = None
    if ndev > 1 and n % ndev == 0:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((ndev,), ("data",))
        print(f"slab decomposition over {ndev} devices")

    # RHS: the discrete Laplacian's lowest eigenfunction on the interior
    # grid x_i = i*h, i = 1..n, h = 1/(n+1)
    h = 1.0 / (n + 1)
    pts = (jnp.arange(n) + 1) * h
    zz, yy, xx = jnp.meshgrid(pts, pts, pts, indexing="ij")
    b = jnp.sin(np.pi * xx) * jnp.sin(np.pi * yy) * jnp.sin(np.pi * zz)

    cg = make_cg(mesh, n, args.iters)
    if mesh is not None:
        from jax.sharding import NamedSharding
        b = jax.device_put(b, NamedSharding(mesh, P("data", None, None)))
    x, res = cg(b)
    x.block_until_ready()

    # exact discrete eigenvalue of the 7-point operator for this mode
    lam = 3 * (2 - 2 * np.cos(np.pi * h)) / h ** 2
    expected = b / lam
    err = float(jnp.max(jnp.abs(x - expected)) / jnp.max(jnp.abs(expected)))
    print(f"n={n}^3 iters={args.iters} residual={float(res):.3e} "
          f"rel_err_vs_analytic={err:.3e}")
    assert err < 5e-2, "CG failed to converge to the analytic solution"
    print("cg_solver OK")


if __name__ == "__main__":
    main()
