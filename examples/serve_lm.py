"""Batched serving demo (deliverable b): continuous batching with slot-based
KV cache over a small LM — requests arrive while others are mid-generation.

Run: PYTHONPATH=src python examples/serve_lm.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.config import reduced
from repro.configs import get
from repro.models import build_model
from repro.serve.engine import ServeEngine


def main():
    cfg = reduced(get("exanest-lm-100m"), n_layers=2, d_model=64,
                  vocab_size=512, n_heads=4, n_kv_heads=2, d_ff=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, slots=4, window=64)

    # staggered arrivals: 6 requests over time into 4 slots
    rids = []
    for i in range(3):
        rids.append(eng.submit([1 + i, 2 + i, 3 + i], max_new_tokens=8))
    for step in range(50):
        eng.step()
        if step == 2:
            for i in range(3):
                rids.append(eng.submit([10 + i] * 5, max_new_tokens=6))
        if all(eng.result(r) is not None for r in rids):
            break
    for r in rids:
        out = eng.result(r)
        print(f"request {r}: {out}")
        assert out is not None
    print("serve_lm OK")


if __name__ == "__main__":
    main()
