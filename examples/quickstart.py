"""Quickstart: the three layers of the framework in one minute.

1. Layer A — ExaNet model: reproduce a paper number (accelerated allreduce).
2. Layer B — TPU adaptation: hierarchical allreduce on a local mesh.
3. Train a tiny LM for a few steps with the full substrate.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp


def layer_a():
    from repro.core.exanet import ExanetMPI
    from repro.core.exanet.allreduce_accel import accel_allreduce_latency
    mpi = ExanetMPI(ranks_per_mpsoc=1)
    sw = mpi.allreduce_sw(256, 128)
    hw = accel_allreduce_latency(256, 128)
    print(f"[exanet] 256B allreduce @128 ranks: software {sw:.1f}us, "
          f"NI accelerator {hw:.2f}us -> {100*(1-hw/sw):.1f}% faster "
          f"(paper: 87.9%)")


def layer_b():
    from repro.core.collectives import (flat_allreduce,
                                        hierarchical_allreduce)
    from repro.launch.mesh import make_mesh
    n = jax.device_count()
    if n < 2:
        print(f"[tpu-adapt] single device ({n}) — skipping mesh demo "
              "(see tests/test_distributed.py for the 8-device run)")
        return
    mesh = make_mesh((2, n // 2), ("pod", "data"))
    x = jnp.arange(8.0)
    a = hierarchical_allreduce(x, mesh, intra_axis="data", inter_axis="pod")
    b = flat_allreduce(x, mesh, ("data", "pod"))
    print(f"[tpu-adapt] hierarchical == flat allreduce: "
          f"{bool(jnp.allclose(a, b))}")


def tiny_training():
    from repro.config import reduced
    from repro.configs import get
    from repro.data.pipeline import SyntheticTokens
    from repro.models import build_model
    from repro.train.loop import Trainer
    from repro.train.optimizer import AdamWConfig
    cfg = reduced(get("exanest-lm-100m"))
    model = build_model(cfg)
    trainer = Trainer(model, AdamWConfig(lr=1e-2, warmup_steps=5))
    state = trainer.init_state(jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg, batch=4, seq=64)
    state, hist = trainer.fit(state, iter(data), n_steps=20, log_every=5)
    print(f"[train] tiny LM loss: {hist[0]['loss']:.3f} -> "
          f"{hist[-1]['loss']:.3f} over 20 steps")


if __name__ == "__main__":
    layer_a()
    layer_b()
    tiny_training()
    print("quickstart OK")
