"""End-to-end driver (deliverable b): train a ~100M-parameter LM for a few
hundred steps on CPU, with checkpointing, failure injection + recovery, and
straggler monitoring — the full production loop at laptop scale.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200] [--small]
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.config import reduced
from repro.configs import get
from repro.data.pipeline import Prefetcher, SyntheticTokens
from repro.models import build_model
from repro.runtime.fault import (FailureInjector, StragglerMonitor,
                                 run_with_recovery)
from repro.train.loop import Trainer
from repro.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true",
                    help="reduced config (fast CI run)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--inject-failures", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get("exanest-lm-100m")
    if args.small:
        cfg = reduced(cfg)
    model = build_model(cfg)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq} steps={args.steps}")

    trainer = Trainer(model, AdamWConfig(lr=3e-3, warmup_steps=20,
                                         decay_steps=args.steps))
    state = trainer.init_state(jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg, batch=args.batch, seq=args.seq)
    step_fn = trainer.make_step()

    losses = []

    def one_step(st, i):
        batch = data.batch_at(i)
        st, metrics = step_fn(st, batch)
        if i % 20 == 0 or i == args.steps - 1:
            losses.append((i, float(metrics["loss"])))
            print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.2f}")
        return st

    injector = FailureInjector(frozenset({args.steps // 3})) \
        if args.inject_failures else None
    mon = StragglerMonitor()
    with tempfile.TemporaryDirectory() as ckpt_dir:
        state, log = run_with_recovery(
            state, one_step, args.steps, ckpt_dir=ckpt_dir, ckpt_every=50,
            injector=injector, straggler=mon)
    print(f"done. failures={log['failures']} "
          f"replayed={log['replayed_steps']} straggles={log['straggles']}")
    assert losses[-1][1] < losses[0][1], "loss must decrease"
    print(f"loss {losses[0][1]:.3f} -> {losses[-1][1]:.3f}  OK")


if __name__ == "__main__":
    main()
