"""The paper's Allreduce accelerator (§4.7), all three incarnations:

1. the latency MODEL (Layer A) reproducing Fig. 19;
2. the Pallas combine KERNEL (NI reduction arithmetic -> VMEM tiles),
   validated in interpret mode against the jnp oracle;
3. the hierarchical collective SCHEDULE (Layer B) with its cross-pod
   traffic reduction napkin math.

Run: PYTHONPATH=src python examples/allreduce_accel_demo.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def model_fig19():
    from repro.core.exanet import ExanetMPI
    from repro.core.exanet.allreduce_accel import accel_allreduce_latency
    mpi = ExanetMPI(ranks_per_mpsoc=1)
    print("ranks  size   software(us)  accelerator(us)  improvement")
    for n in (16, 32, 64, 128):
        sw = mpi.allreduce_sw(256, n)
        hw = accel_allreduce_latency(256, n)
        print(f"{n:5d}  256B  {sw:11.2f}  {hw:14.2f}  {100*(1-hw/sw):9.1f}%")


def schedule_structure():
    """The §4.7 accelerator as a first-class schedule (Fig. 10 rounds)."""
    from collections import Counter
    from repro.core.exanet.schedules import HierarchicalAccelAllreduce
    sched = HierarchicalAccelAllreduce()
    counts = Counter(r.label for r in sched.rounds(64, 256))
    print(f"[schedule] 64-rank accel rounds: {dict(counts)} "
          f"(1 client gather + log2(16 QFDBs) server levels + 1 broadcast)")


def schedule_alternatives():
    """Ring / Rabenseifner vs the MPICH recursive doubling the paper ran."""
    from repro.core.exanet import ExanetMPI
    mpi = ExanetMPI()
    size, n = 1 << 20, 64
    rd = mpi.allreduce(size, n, "recursive_doubling")
    print(f"[schedules] 1MB/64-rank allreduce: recursive_doubling={rd:.0f}us"
          + "".join(f", {a}={mpi.allreduce(size, n, a):.0f}us"
                    for a in ("ring", "rabenseifner")))


def kernel_combine():
    from repro.kernels.allreduce_combine.kernel import combine
    from repro.kernels.allreduce_combine.ref import combine_ref
    parts = jax.random.normal(jax.random.PRNGKey(0), (4, 8192), jnp.float32)
    out = combine(parts, op="sum", interpret=True)  # Pallas body on CPU
    ref = combine_ref(parts, op="sum")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)
    print("[kernel] Pallas combine (4 parts x 8192) == oracle  OK")


def schedule_napkin():
    from repro.core.collectives import hierarchical_collective_bytes
    hb = hierarchical_collective_bytes(64 << 20, intra=16, inter=2)
    print(f"[schedule] 64MB gradient, 2 pods x 16: cross-pod bytes/chip "
          f"{hb['flat']['inter']/2**20:.1f}MB (flat) -> "
          f"{hb['hier']['inter']/2**20:.2f}MB (hierarchical), "
          f"{hb['inter_reduction']:.0f}x less — the QFDB-accelerator "
          f"decomposition at pod scale")


if __name__ == "__main__":
    model_fig19()
    schedule_structure()
    schedule_alternatives()
    kernel_combine()
    schedule_napkin()
    print("allreduce_accel_demo OK")
