"""Fault-tolerance runtime: failure replay exactness, straggler detection,
async checkpointing, corruption detection."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (CheckpointStore, latest_step,
                                    restore_checkpoint, save_checkpoint)
from repro.runtime.fault import (FailureInjector, SimulatedFailure,
                                 StragglerMonitor, elastic_reshard,
                                 run_with_recovery)


def _toy_step(state, step):
    # deterministic function of (state, step) — like our data pipeline
    return {"w": state["w"] * 0.9 + jnp.float32(step)}


def test_failure_replay_is_exact(tmp_path):
    """Recovery must reproduce the failure-free result bit-exactly (the
    paper's replay-faulting-blocks invariant, lifted to training steps)."""
    s0 = {"w": jnp.ones((4,), jnp.float32)}
    ref, _ = run_with_recovery(s0, _toy_step, 25, ckpt_dir=str(tmp_path / "a"),
                               ckpt_every=5)
    inj = FailureInjector(frozenset({7, 13, 22}))
    out, log = run_with_recovery(s0, _toy_step, 25,
                                 ckpt_dir=str(tmp_path / "b"), ckpt_every=5,
                                 injector=inj)
    np.testing.assert_array_equal(np.asarray(ref["w"]), np.asarray(out["w"]))
    assert log["failures"] == 3
    assert log["replayed_steps"] > 0


def test_straggler_detection():
    mon = StragglerMonitor(deadline_factor=3.0)
    for i in range(20):
        mon.observe(i, 0.01)
    assert not mon.flagged
    assert mon.observe(20, 0.2)   # 20x median -> straggler
    assert mon.flagged == [20]


def test_straggler_in_recovery_loop(tmp_path):
    mon = StragglerMonitor(deadline_factor=5.0)

    def delay(step):
        if step == 15:
            time.sleep(0.05)

    s0 = {"w": jnp.zeros((2,))}
    _, log = run_with_recovery(s0, _toy_step, 20,
                               ckpt_dir=str(tmp_path), ckpt_every=100,
                               straggler=mon, delay_fn=delay)
    assert log["straggles"] >= 1


def _mesh_shardings(mesh_shape, axes, spec):
    from jax.sharding import NamedSharding, PartitionSpec
    mesh = jax.make_mesh(mesh_shape, axes)
    return {"w": NamedSharding(mesh, PartitionSpec(*spec))}


def test_elastic_reshard_onto_shrunk_and_grown_mesh(tmp_path):
    """Mid-run reshard: checkpoint under one mesh, restore onto a mesh
    with fewer/more axes, finish the run — final state must equal the
    failure-free single-mesh run exactly (pure function of (seed, step)
    data is what makes elastic shrink/grow safe)."""
    s0 = {"w": jnp.ones((8, 4), jnp.float32)}
    ref, _ = run_with_recovery(s0, _toy_step, 12,
                               ckpt_dir=str(tmp_path / "ref"),
                               ckpt_every=4)
    d = str(tmp_path / "elastic")
    run_with_recovery(s0, _toy_step, 8, ckpt_dir=d, ckpt_every=4)
    tmpl = {"w": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
    for label, shardings in (
            ("shrunk", _mesh_shardings((1,), ("data",), ("data",))),
            ("grown", _mesh_shardings((1, 1), ("data", "model"),
                                      ("data", "model")))):
        state = elastic_reshard(d, 8, tmpl, shardings)
        assert state["w"].sharding == shardings["w"], label
        for step in range(8, 12):
            state = _toy_step(state, step)
        np.testing.assert_array_equal(np.asarray(ref["w"]),
                                      np.asarray(state["w"]), label)


def test_checkpoint_corruption_detected(tmp_path):
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    d = save_checkpoint(str(tmp_path), 1, tree)
    # corrupt the leaf
    import numpy as _np
    _np.save(f"{d}/w.npy", _np.zeros(8, _np.float32))
    with pytest.raises(IOError, match="corruption"):
        restore_checkpoint(str(tmp_path), 1, tree)


def test_async_store_and_gc(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        store.save_async(s, {"w": jnp.full((3,), float(s))})
    store.wait()
    assert latest_step(str(tmp_path)) == 4
    restored, _ = restore_checkpoint(str(tmp_path), 4,
                                     {"w": jnp.zeros((3,))})
    np.testing.assert_allclose(np.asarray(restored["w"]), 4.0)
    # gc kept only the last 2
    assert latest_step(str(tmp_path)) == 4
    import os
    steps = [d for d in os.listdir(tmp_path) if d.startswith("step-")]
    assert len(steps) <= 2


def test_checkpoint_roundtrip_nested_tree(tmp_path):
    tree = {"a": {"b": jnp.ones((2, 3), jnp.bfloat16)},
            "c": [jnp.zeros((4,), jnp.int32), jnp.full((1,), 7.0)],
            "step": jnp.asarray(9, jnp.int32)}
    save_checkpoint(str(tmp_path), 0, tree)
    out, manifest = restore_checkpoint(str(tmp_path), 0, tree)
    assert manifest["step"] == 0
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
