"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
one train-grad step on CPU, asserting output shapes and finiteness.
(The full configs are exercised only via the dry-run.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import reduced
from repro.configs import ALL_ARCHS, EXTRA_ARCHS, get
from repro.models import build_model

B, S = 2, 64


def make_batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.vision is not None:
        batch["patches"] = jax.random.normal(
            ks[2], (B, cfg.vision.n_patches, cfg.d_model), jnp.float32)
    if cfg.encdec is not None:
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.encdec.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("name", ALL_ARCHS + EXTRA_ARCHS)
def test_smoke_loss_and_grad(name):
    cfg = reduced(get(name))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    loss, grads = jax.jit(jax.value_and_grad(model.loss_fn))(params, batch)
    assert np.isfinite(float(loss)), name
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree_util.tree_leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, name


@pytest.mark.parametrize("name", ALL_ARCHS + EXTRA_ARCHS)
def test_smoke_prefill_decode(name):
    cfg = reduced(get(name))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    lg, cache = jax.jit(model.prefill)(params, batch)
    assert lg.shape[0] == B and lg.shape[-1] == cfg.vocab_size
    assert np.all(np.isfinite(np.asarray(lg, dtype=np.float32))), name

    # decode one token continuing from a zero-initialized full-window cache
    # (the assigned decode shapes use a pre-existing cache of seq_len)
    cache0 = model.init_cache(B, S)
    dbatch = {"token": jnp.zeros((B,), jnp.int32),
              "pos": jnp.array(S - 1, jnp.int32)}
    lg2, cache2 = jax.jit(model.decode_step)(params, cache0, dbatch)
    assert lg2.shape == (B, 1, cfg.vocab_size), name
    assert np.all(np.isfinite(np.asarray(lg2, dtype=np.float32))), name
    # cache pytree structure is preserved
    jax.tree_util.tree_structure(cache2)


def test_decode_matches_prefill_gqa():
    """Decode with a cache built by prefill reproduces the prefill logits
    for the next position (teacher-forced continuation), dense GQA arch."""
    cfg = reduced(get("deepseek-7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)

    # full forward logits at every position
    full = model.prefill(params, {"tokens": tokens})[0]  # (B,1,V) at last pos

    # prefill on the S-1 prefix, then decode token S-1
    prefix = tokens[:, :S - 1]
    _, cache = model.prefill(params, {"tokens": prefix})
    # pad cache from S-1 to S slots
    cache = jax.tree_util.tree_map(
        lambda c: jnp.pad(c, [(0, 0), (0, 0), (0, 1)] + [(0, 0)] *
                          (c.ndim - 3)), cache)
    lg, _ = model.decode_step(params, cache,
                              {"token": tokens[:, S - 1],
                               "pos": jnp.array(S - 1, jnp.int32)})
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, 0]),
                               rtol=2e-2, atol=2e-2)


def test_ssm_decode_matches_prefill():
    """Mamba2: stepwise decode continues exactly from the prefill state."""
    cfg = reduced(get("mamba2-2.7b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    # logits from the full sequence at the last position
    full_last = model.prefill(params, {"tokens": tokens})[0]
    # prefill prefix, then decode the final token recurrently
    _, state = model.prefill(params, {"tokens": tokens[:, :S - 1]})
    lg, _ = model.decode_step(params, state,
                              {"token": tokens[:, S - 1],
                               "pos": jnp.array(S - 1, jnp.int32)})
    np.testing.assert_allclose(np.asarray(lg[:, 0]),
                               np.asarray(full_last[:, 0]),
                               rtol=3e-2, atol=3e-2)


def test_mla_absorbed_decode_matches_prefill():
    """DeepSeek MLA: the absorbed-form decode (compressed c_kv cache,
    W_UK/W_UV folded into the attention) must reproduce the expanded-form
    prefill logits at the next position."""
    cfg = reduced(get("deepseek-v3-671b"), n_layers=2, mtp_depth=0,
                  n_dense_layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    full = model.prefill(params, {"tokens": tokens})[0]
    _, cache = model.prefill(params, {"tokens": tokens[:, :S - 1]})
    cache = jax.tree_util.tree_map(
        lambda c: jnp.pad(c, [(0, 0), (0, 0), (0, 1)] + [(0, 0)] *
                          (c.ndim - 3)), cache)
    lg, _ = model.decode_step(params, cache,
                              {"token": tokens[:, S - 1],
                               "pos": jnp.array(S - 1, jnp.int32)})
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, 0]),
                               rtol=3e-2, atol=3e-2)
