"""Round algebra + synthesis tests (ISSUE 8).

Three layers, matching the acceptance criteria:

* **sampled properties** — randomly generated algebra terms lower to a
  semantically correct allreduce (contribution-tracking check) and
  agree interp-vs-compiled to <=1e-9; deterministic seeded sampling so
  the gates always run (the hypothesis twins — unbounded generators —
  live in ``test_schedule_algebra_property.py`` behind the repo's usual
  ``importorskip``);
* **regression** — the balanced ``sigma = 1/2`` Split reproduces
  ``RabenseifnerAllreduce``'s round stream *exactly*, byte for byte;
* **wiring** — population binding, the winner cache, and the planner's
  ``synthesized`` candidate source (provenance, margin, counters,
  ``algo="synth:..."`` dispatch), plus the semantic gate over every
  hand-written menu schedule the planner can emit.
"""

import json

import numpy as np
import pytest

from repro.core.exanet import ExanetMPI
from repro.core.exanet.schedule_algebra import (AXIS_GROUPS, SIGMA_HI,
                                                SIGMA_LO, Dissemination,
                                                Hierarchical, Pipeline,
                                                SchedulePopulation, Split,
                                                TermSchedule, term_from_spec)
from repro.core.exanet.schedules import RabenseifnerAllreduce
from repro.core.machine import ExanetMachine
from repro.core.planner import ALLREDUCE_CANDIDATES, CollectivePlanner
from repro.core.synth.search import (AGREEMENT_RTOL, WinnerCache, register_term,
                                     search_cell, size_bucket, skeletons)
from repro.core.synth.verify import (SemanticCheckError, check_allreduce,
                                     check_term, contribution_check)

# ------------------------------------------------------- term sampling
def sample_term(rng, max_ranks=64):
    """(term, nranks): a random combinator tree over a feasible scope —
    the seeded twin of the hypothesis strategy in
    ``test_schedule_algebra_property.py``."""
    if rng.random() < 0.5:
        k = int(rng.integers(1, 5))
        term, n = Split(tuple(np.clip(rng.uniform(0.05, 0.95, k),
                                      SIGMA_LO, SIGMA_HI))), 1 << k
    else:
        radix = int(rng.integers(2, 4))
        m = int(rng.integers(1, 4))
        term, n = Dissemination(radix), radix ** m
    if rng.random() < 0.5:
        term = Pipeline(int(rng.integers(2, 4)), term)
    if rng.random() < 0.5:
        group = int(rng.choice([2, 4]))
        if n >= 2 and n * group <= max_ranks:
            term, n = Hierarchical(group, term), n * group
    if n > max_ranks:
        return sample_term(rng, max_ranks)
    return term, n


SAMPLED_TERMS = [sample_term(np.random.default_rng(seed))
                 for seed in range(25)]


# ------------------------------------------------------------- properties
@pytest.mark.parametrize("tn", SAMPLED_TERMS,
                         ids=lambda tn: f"{tn[0].spec()[0]}-p{tn[1]}")
def test_random_terms_are_semantically_correct(tn):
    """Gate 1 for the whole search space: any term the generator can
    produce implements an exact-once allreduce."""
    term, nranks = tn
    check_term(term, nranks)
    widths = term.atom_widths(nranks)
    assert widths.shape == (term.n_atoms(nranks),)
    assert np.isclose(widths.sum(), 1.0)
    assert (widths > 0).all()


@pytest.mark.parametrize("tn", SAMPLED_TERMS[:12],
                         ids=lambda tn: f"{tn[0].spec()[0]}-p{tn[1]}")
def test_genome_and_spec_roundtrip(tn):
    term, nranks = tn
    g = term.genome()
    again = term.with_genome(g)
    assert again.spec() == term.spec()
    assert again.structure_key() == term.structure_key()
    rebuilt = term_from_spec(json.loads(json.dumps(term.spec())))
    assert rebuilt.structure_key() == term.structure_key()
    assert TermSchedule(rebuilt).name == TermSchedule(term).name


@pytest.mark.parametrize("tn", [t for t in SAMPLED_TERMS if t[1] <= 32][:10],
                         ids=lambda tn: f"{tn[0].spec()[0]}-p{tn[1]}")
@pytest.mark.parametrize("nbytes", [64, 65536])
def test_random_terms_agree_interp_vs_compiled(tn, nbytes):
    """Gate 2 for the whole search space: the compiled replay of any
    term matches the event interpreter to <=1e-9."""
    term, nranks = tn
    sched = TermSchedule(term)
    mpi = ExanetMPI()
    interp = mpi.run_schedule(sched, nbytes, nranks,
                              backend="interp").latency_us
    compiled = mpi.run_schedule(sched, nbytes, nranks,
                                backend="compiled").latency_us
    rel = abs(interp - compiled) / max(abs(interp), 1e-30)
    assert rel <= AGREEMENT_RTOL


def test_contribution_check_catches_broken_schedules():
    """Negative control: dropping one send from a correct lowering must
    fail the gate (the checker is not vacuously green)."""
    term = Split.balanced(8)
    rounds = term.data_rounds(8)
    broken = list(rounds)
    broken[-1] = broken[-1].__class__(
        broken[-1].step, broken[-1].sends[1:], broken[-1].exchange,
        broken[-1].label)
    with pytest.raises(SemanticCheckError):
        contribution_check(broken, 8, term.n_atoms(8), label="broken")


# ------------------------------------------------------------- regression
@pytest.mark.parametrize("nranks", [4, 8, 16, 64])
@pytest.mark.parametrize("nbytes", [64, 1000, 4096, 262144])
def test_balanced_split_is_rabenseifner_exactly(nranks, nbytes):
    """sigma = 1/2 everywhere IS Rabenseifner: identical Round streams,
    byte for byte, including the integer-floor chunk arithmetic."""
    synth = list(TermSchedule(Split.balanced(nranks)).rounds(nranks, nbytes))
    menu = list(RabenseifnerAllreduce().rounds(nranks, nbytes))
    assert len(synth) == len(menu)
    for s, m in zip(synth, menu):
        assert s.sends == m.sends
        assert s.exchange == m.exchange
        assert s.reduce_bytes == m.reduce_bytes


@pytest.mark.parametrize("nranks", [8, 12, 16, 64])
@pytest.mark.parametrize("name", [n for n, _ in ALLREDUCE_CANDIDATES])
def test_every_menu_schedule_passes_semantic_check(nranks, name):
    """Acceptance: every schedule the planner can emit has a verified
    exact-once dataflow (menu twins live in verify.MENU_SEMANTICS)."""
    machine = ExanetMachine()
    factory = dict(ALLREDUCE_CANDIDATES)[name]
    if not machine.supports(factory(), nranks, 4096):
        pytest.skip(f"{name} infeasible at {nranks} ranks")
    check_allreduce(name, nranks)


def test_skeletons_are_feasible_and_unique():
    for nranks in (8, 16, 64, 256):
        terms_ = skeletons(nranks)
        assert terms_, f"no skeletons at {nranks}"
        keys = [t.structure_key() for t in terms_]
        assert len(keys) == len(set(keys))
        for t in terms_:
            check_term(t, nranks)


# ------------------------------------------------------ population binding
def test_population_batched_costs_match_interp():
    """One batched compiled replay per generation == per-member event
    interpretation, to 1e-9 (the first-class ButterflyPopulation
    replacement)."""
    nranks, nbytes = 16, 4096
    rng = np.random.default_rng(0)
    members = [TermSchedule(Split(tuple(g)))
               for g in np.clip(rng.uniform(0.2, 0.8, (6, 4)),
                                SIGMA_LO, SIGMA_HI)]
    pop = SchedulePopulation(members, nbytes)
    machine = ExanetMachine()
    batched = np.asarray(machine.cost_population(pop, nranks))
    mpi = machine._mpi_for(nranks)
    for m, c in zip(members, batched):
        interp = mpi.run_schedule(m, nbytes, nranks,
                                  backend="interp").latency_us * 1e-6
        assert abs(c - interp) / interp <= AGREEMENT_RTOL


def test_population_rejects_mixed_skeletons():
    with pytest.raises(ValueError, match="skeleton"):
        SchedulePopulation([TermSchedule(Split.balanced(8)),
                            TermSchedule(Dissemination(2))], 4096)


# ---------------------------------------------------------- search + gates
def test_search_cell_winner_passes_both_gates():
    """The CI-sized search: the returned winner has already cleared the
    semantic check and the 1e-9 interpreter-agreement gate (search_cell
    raises otherwise), and beats or ties the best software menu."""
    machine = ExanetMachine()
    res = search_cell(machine, 65536, 16, pop=6, gens=2, refine=1, seed=0)
    assert res.semantic_ok
    assert res.agreement_rel <= AGREEMENT_RTOL
    assert res.winner_s <= res.best_sw_menu_s * 1.001
    check_term(term_from_spec(res.winner_spec), 16)


# ------------------------------------------------------------ winner cache
def _seed_cache(machine, nranks=16, nbytes=65536):
    """A cache holding one genuine searched winner for the machine."""
    res = search_cell(machine, nbytes, nranks, pop=6, gens=2, refine=1,
                      seed=0)
    cache = WinnerCache()
    cache.put(machine.name, "allreduce", nranks, nbytes, res.placement,
              spec=res.winner_spec, cost_s=res.winner_s,
              best_menu_s=res.best_sw_menu_s, menu_name=res.best_sw_menu)
    return cache, res


def test_winner_cache_roundtrip(tmp_path):
    machine = ExanetMachine()
    cache, res = _seed_cache(machine)
    path = str(tmp_path / "winners.json")
    cache.save(path)
    again = WinnerCache.load(path)
    assert len(again) == len(cache) == 1
    entry = again.get(machine.name, "allreduce", 16, 65536, res.placement)
    assert entry is not None and entry["spec"] == res.winner_spec
    # bucket key: any size in [bucket, 2*bucket) resolves the same entry
    b = size_bucket(65536)
    assert again.get(machine.name, "allreduce", 16, 2 * b - 1,
                     res.placement) == entry
    assert again.get(machine.name, "allreduce", 16, 2 * b,
                     res.placement) is None
    sched = again.schedule(entry)
    assert sched.name == res.winner_name


# ---------------------------------------------------------- planner wiring
def test_planner_synth_candidate_provenance_margin_counters():
    machine = ExanetMachine()
    cache, res = _seed_cache(machine)
    planner = CollectivePlanner(machine, fidelity="sim", synth_cache=cache)
    plan = planner.plan("allreduce", 65536, 16)
    # the searched winner beat the sw menu; at 64 KiB accel has lost the
    # Fig. 19 crossover too, so the synthesized schedule must be chosen
    assert plan.schedule == res.winner_name
    assert plan.provenance == "synthesized"
    best_menu = min(c for n, c in plan.costs if not n.startswith("synth:"))
    assert plan.margin == pytest.approx(
        (best_menu - plan.cost_s) / best_menu)
    assert plan.margin > 0
    info = planner.cache_info()
    assert info["synth_candidates"] == 1 and info["synth_wins"] == 1
    # menu-only query: provenance stays "menu", no synth counters move
    plan2 = planner.plan("allreduce", 64, 16)
    assert plan2.provenance == "menu"
    assert planner.cache_info()["synth_candidates"] == 1
    # resolve_schedule returns the registered executable term
    sched = planner.resolve_schedule(plan)
    assert sched.name == plan.schedule
    # plan_many batch path lands on the identical plan
    planner2 = CollectivePlanner(machine, fidelity="sim", synth_cache=cache)
    [plan_b] = planner2.plan_many("allreduce", [65536], 16)
    assert (plan_b.schedule, plan_b.cost_s) == (plan.schedule, plan.cost_s)


def test_planner_disabled_synth_cache_is_menu_only():
    machine = ExanetMachine()
    planner = CollectivePlanner(machine, fidelity="sim", synth_cache=None)
    plan = planner.plan("allreduce", 65536, 16)
    assert plan.provenance == "menu"
    assert not any(n.startswith("synth:") for n, _ in plan.costs)


def test_mpi_allreduce_dispatches_synthesized_name():
    """``allreduce(algo="synth:<digest>")`` executes the registered term
    end to end — the transparent-benefit seam for serve/app simulators."""
    machine = ExanetMachine()
    sched = register_term(Split.balanced(16))
    mpi = machine._mpi_for(16)
    got = mpi.allreduce(4096, 16, algo=sched.name)
    via_menu = mpi.allreduce(4096, 16, algo="rabenseifner")
    assert got == pytest.approx(via_menu, rel=1e-9)
    with pytest.raises(Exception):
        mpi.allreduce(4096, 16, algo="synth:0000000000")
