"""Hypothesis property tests on system invariants (deliverable c)."""

import math

import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.exanet import ExanetMPI, Topology, DEFAULT
from repro.core.exanet.allreduce_accel import (accel_allreduce_latency,
                                               accel_applicable)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

topo = Topology()
mpi = ExanetMPI()


# --------------------------------------------------------------- topology
@given(st.integers(0, DEFAULT.n_cores - 1), st.integers(0, DEFAULT.n_cores - 1))
def test_route_properties(a, b):
    """Routes are well-formed: contiguous, bounded hop count, symmetric
    class; same MPSoC -> no links."""
    p = topo.route(a, b)
    if topo.core_to_mpsoc(a) == topo.core_to_mpsoc(b):
        assert p.links == () and p.same_mpsoc
        return
    # link chain is contiguous
    cur = topo.core_to_mpsoc(a)
    for l in p.links:
        assert l.src_mpsoc == cur or cur == l.src_mpsoc
        cur = l.dst_mpsoc
    assert cur == topo.core_to_mpsoc(b)
    # dimension-ordered torus: at most 2+2+1 mezz hops + 2 intra-QFDB
    assert p.n_mezz_links <= 5
    assert p.n_intra_qfdb_links <= 2
    rev = topo.route(b, a)
    assert rev.n_mezz_links == p.n_mezz_links  # symmetric distance classes


@given(st.integers(0, DEFAULT.n_cores - 1), st.integers(0, DEFAULT.n_cores - 1),
       st.integers(0, 20))
def test_latency_monotone_in_size(a, b, size_exp):
    """One-way latency is monotone non-decreasing in message size."""
    if a == b:
        return
    s1 = 1 << size_exp
    s2 = s1 * 2
    path = topo.route(a, b)
    assert mpi.net.mpi_latency(s2, path) >= mpi.net.mpi_latency(s1, path) - 1e-9


@given(st.integers(2, 9))
def test_bcast_grows_with_ranks(log_n):
    n = 1 << log_n
    r = mpi.bcast(1, n)
    assert r.observed_us > 0 and r.expected_us > 0
    # binomial depth: steps sum == log2(n)
    assert sum(r.steps.values()) == log_n


@given(st.integers(1, 256), st.integers(2, 8))
def test_accel_applicability_and_monotonicity(size_words, log_n):
    n = 1 << log_n
    size = size_words * 4
    if not accel_applicable(size, n):
        return
    lat = accel_allreduce_latency(size, n)
    assert lat > 0
    # monotone in ranks (more server levels) and in blocks
    if accel_applicable(size, n * 2) and n * 2 >= 8:
        assert accel_allreduce_latency(size, n * 2) >= lat
    if accel_applicable(size * 2, n):
        assert accel_allreduce_latency(size * 2, n) >= lat


# ---------------------------------------------------------- comm policy
@given(st.integers(2, 4096))
def test_commpolicy_crossover_consistent(p):
    from repro.core.comm import CommPolicy
    pol = CommPolicy()
    thr = pol.eager_threshold_bytes(p)
    small = max(1, thr // 4)
    assert pol.oneshot_allreduce_s(small, p, pol.ici_bw, pol.alpha_s) <= \
        pol.ring_allreduce_s(small, p, pol.ici_bw, pol.alpha_s) + 1e-12
    if thr < 1 << 31:  # p=2,3: one-shot wins at every size (no crossover)
        big = thr * 4
        assert pol.ring_allreduce_s(big, p, pol.ici_bw, pol.alpha_s) <= \
            pol.oneshot_allreduce_s(big, p, pol.ici_bw, pol.alpha_s) + 1e-12


@given(st.integers(2, 4096))
def test_eager_threshold_is_exact_crossover(p):
    """The threshold is the *first* size where one-shot strictly loses to
    ring — so it is the true crossover, and choose() is consistent with it
    at both boundary sides."""
    from repro.core.comm import CommPolicy
    pol = CommPolicy()
    thr = pol.eager_threshold_bytes(p)
    if thr >= 1 << 31:  # one-shot wins at every size (p=2,3: no crossover)
        return
    one = lambda n: pol.oneshot_allreduce_s(n, p, pol.ici_bw, pol.alpha_s)
    ring = lambda n: pol.ring_allreduce_s(n, p, pol.ici_bw, pol.alpha_s)
    assert one(thr) > ring(thr)
    if thr > 1:
        assert one(thr - 1) <= ring(thr - 1)
    assert pol.choose(thr, p) == "eager"
    assert pol.choose(thr + 1, p) == "rendezvous"


@given(st.integers(4, 1024),
       st.floats(1e-7, 5e-5), st.floats(1.0, 50.0))
def test_eager_threshold_monotone_in_alpha(p, alpha, factor):
    """Raising alpha raises the threshold: ring pays 2(p-1) alphas vs the
    one-shot's single alpha, so a slower launch path extends the eager
    regime (the paper's reasoning for keeping the packetizer, §5.2.1)."""
    from repro.core.comm import CommPolicy
    lo = CommPolicy(alpha_s=alpha)
    hi = CommPolicy(alpha_s=alpha * factor)
    assert hi.eager_threshold_bytes(p) >= lo.eager_threshold_bytes(p)


@given(st.integers(1, 1 << 24), st.integers(2, 64), st.integers(1, 8))
def test_planner_cache_deterministic(nbytes, intra, inter):
    """Plan-cache lookups are deterministic: repeated queries return the
    memoized object, and an independent planner derives the same plan."""
    from repro.core.comm import CommPolicy
    a_pol, b_pol = CommPolicy(), CommPolicy()
    a = a_pol.planner.plan("grad_sync", nbytes, (intra, inter))
    b = b_pol.planner.plan("grad_sync", nbytes, (intra, inter))
    assert (a.schedule, a.cost_s, a.costs) == (b.schedule, b.cost_s, b.costs)
    again = a_pol.planner.plan("grad_sync", nbytes, (intra, inter))
    assert again is a
    assert a_pol.planner.cache_info()["hits"] >= 1


# ------------------------------------------------------------- grad sync
@given(st.lists(st.integers(1, 300), min_size=1, max_size=5),
       st.integers(10, 10_000))
def test_bucket_roundtrip(sizes, bucket_bytes):
    """flatten_to_buckets/unflatten is exact for any tree and bucket size."""
    from repro.parallel.grad_sync import (flatten_to_buckets,
                                          unflatten_from_buckets)
    key = jax.random.PRNGKey(sum(sizes))
    tree = {f"p{i}": jax.random.normal(jax.random.fold_in(key, i), (n,))
            for i, n in enumerate(sizes)}
    buckets, spec = flatten_to_buckets(tree, bucket_bytes)
    out = unflatten_from_buckets(buckets, spec)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(tree[k]),
                                   rtol=1e-6)


# -------------------------------------------------------------- optimizer
@given(st.integers(1, 6), st.integers(1, 4))
def test_int8_quant_bounded_error(rows_exp, blocks):
    from repro.train.optimizer import _dequant, _quant
    n = 128 * blocks
    x = jax.random.normal(jax.random.PRNGKey(rows_exp), (1 << rows_exp, n))
    q, s = _quant(x, 128)
    y = _dequant(q, s, 128)
    # error bounded by scale/2 per block
    max_err = np.asarray(jnp.max(jnp.abs(x - y)))
    max_scale = np.asarray(jnp.max(s))
    assert max_err <= max_scale * 0.5 + 1e-7


# ------------------------------------------------------------- ssd model
@given(st.integers(1, 3), st.integers(1, 4))
def test_ssd_chunk_invariance(b, nc):
    """The chunked SSD dual form is invariant to chunk size (same math)."""
    from repro.models.ssm import ssd_chunked
    l, h, p, n = nc * 16, 2, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(b * 7 + nc), 4)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
    B = jax.random.normal(ks[3], (b, l, 1, n))
    C = jax.random.normal(jax.random.PRNGKey(0), (b, l, 1, n))
    y1, s1 = ssd_chunked(x, dt, A, B, C, chunk=8)
    y2, s2 = ssd_chunked(x, dt, A, B, C, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4,
                               atol=2e-4)


# ---------------------------------------------------- compiled executor
from repro.core.exanet.schedules import (RabenseifnerAllreduce,  # noqa: E402
                                         RecursiveDoublingAllreduce, Round,
                                         Schedule)


class _HypSchedule(Schedule):
    name = "hyp"

    def __init__(self, rounds, one_way):
        self._rounds = tuple(rounds)
        self.one_way = one_way

    def rounds(self, nranks, nbytes):
        return iter(self._rounds)


_exec_mpis = {rpm: ExanetMPI(ranks_per_mpsoc=rpm) for rpm in (None, 1)}
#: straddles mpi_eager_max_bytes (32 B) and reaches rendez-vous streaming
_send_bytes = st.sampled_from([0, 1, 31, 32, 33, 4096, 65536, 300000])


@st.composite
def _random_schedules(draw):
    """Random round structures: duplicate/self sends, mixed per-send
    transports, exchange and one-way rounds, reductions, sync skew."""
    n = draw(st.sampled_from([2, 4, 8, 16]))
    rounds = []
    for step in range(draw(st.integers(1, 3))):
        uniform = draw(st.booleans())
        nb0 = draw(_send_bytes)
        sends = tuple(
            (draw(st.integers(0, n - 1)), draw(st.integers(0, n - 1)),
             nb0 if uniform else draw(_send_bytes))
            for _ in range(draw(st.integers(1, 10))))
        rounds.append(Round(step, sends, exchange=draw(st.booleans()),
                            reduce_bytes=draw(st.sampled_from([0, 64,
                                                               4096])),
                            sync=draw(st.booleans())))
    return n, _HypSchedule(rounds, draw(st.booleans()))


def _assert_backends_agree(mpi, sched, size, n):
    a = mpi.run_schedule(sched, size, n, backend="interp")
    b = mpi.run_schedule(sched, size, n, backend="compiled")
    assert b.latency_us == pytest.approx(a.latency_us, rel=1e-9)
    for x, y in zip(a.clocks, b.clocks):
        assert y == pytest.approx(x, rel=1e-9, abs=1e-12)


@given(_random_schedules(), st.sampled_from([None, 1]))
def test_compiled_executor_matches_interpreter(n_sched, rpm):
    """The compiled (vectorized) executor reproduces the interpreter's
    latency and per-rank clocks to 1e-9 on arbitrary schedules at both
    rank placements (ranks_per_mpsoc in {1, 4})."""
    n, sched = n_sched
    _assert_backends_agree(_exec_mpis[rpm], sched, 0, n)


@given(st.sampled_from([RecursiveDoublingAllreduce, RabenseifnerAllreduce]),
       st.one_of(st.integers(1, 96), st.just(1 << 20)),
       st.sampled_from([4, 8, 16]), st.sampled_from([None, 1]))
def test_compiled_matches_interp_shipped_schedules(sched_cls, size, n, rpm):
    """Shipped allreduce schedules agree across the eager/rendez-vous
    boundary and into streaming sizes."""
    _assert_backends_agree(_exec_mpis[rpm], sched_cls(), size, n)


# ------------------------------------------------------------- flash attn
@given(st.integers(1, 2), st.sampled_from([32, 48, 96]),
       st.sampled_from([16, 32]))
def test_flash_chunk_invariance(b, s, chunk):
    from repro.models.attention import flash_attention
    H, K, hd = 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(s + chunk), 3)
    q = jax.random.normal(ks[0], (b, s, H, hd))
    k = jax.random.normal(ks[1], (b, s, K, hd))
    v = jax.random.normal(ks[2], (b, s, K, hd))
    y1 = flash_attention(q, k, v, q_chunk=chunk, kv_chunk=chunk)
    y2 = flash_attention(q, k, v, q_chunk=s, kv_chunk=s)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)


# ----------------------------------------------------- compiled programs
def _program_mpis():
    m = getattr(_program_mpis, "_cache", None)
    if m is None:
        m = _program_mpis._cache = {None: ExanetMPI(),
                                    1: ExanetMPI(ranks_per_mpsoc=1)}
    return m


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10 ** 6), st.sampled_from(["numpy", "jax"]))
def test_batched_programs_equal_per_binding_loop(seed, engine):
    """The hypothesis twin of test_batch_engine.py's batch fuzz: a batch
    of payload variants of one random program, replayed as columns of a
    single compiled artifact (run_program_many -> bind_batch), equals
    the per-program interpreter loop to 1e-9 — random structures, tag
    permutations, eager/rendez-vous payloads, compute skew, both scan
    engines."""
    import random as _random

    from repro.core.exanet.program_compiled import (extract_data,
                                                    rebind_program)
    from test_program_compiled import _assert_equal, _fuzz_program
    rng = _random.Random(seed)
    base = _fuzz_program(rng, rng.choice([2, 4, 8]))
    comp, post, _ = extract_data(base)
    f, g = rng.uniform(0.0, 8.0), rng.uniform(0.25, 4.0)
    progs = [base, rebind_program(
        base, compute_us=[c * g for c in comp],
        post_nbytes=[int(round(x * f)) for x in post])]
    m = _program_mpis()[None]
    got = m.run_program_many(progs, backend="compiled", engine=engine)
    for p, r in zip(progs, got):
        _assert_equal(m.run_program(p, backend="interp"), r,
                      ("batch-hyp", seed, engine))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10 ** 6), st.sampled_from([2, 4, 8, 12, 16]),
       st.sampled_from([None, 1]))
def test_compiled_program_matches_interp(seed, nranks, rpm):
    """The compiled Program-IR executor reproduces the interpreted
    run_program (latency, per-rank clocks, send/collective counts) to
    1e-9 on random halo programs — random grids, tag permutations, mixed
    eager/rendez-vous sizes, per-rank compute skew, with and without
    embedded collectives — at both rank placements (the hypothesis twin
    of test_program_compiled.py's 60-seed harness)."""
    import random as _random

    from test_program_compiled import _assert_equal, _fuzz_program
    prog = _fuzz_program(_random.Random(seed), nranks)
    m = _program_mpis()[rpm]
    a = m.run_program(prog, backend="interp")
    b = m.run_program(prog, backend="compiled")
    _assert_equal(a, b, ("hyp", seed, nranks, rpm))
