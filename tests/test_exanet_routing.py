"""Routing invariants of the dimension-ordered 3-D torus (§4.2).

Deterministic sweep over core pairs (no hypothesis dependency): hop-count
bounds, forward/backward symmetry, Table-1 classification, and the route
cache added for paper-scale sweeps.
"""

import pytest

from repro.core.exanet import DEFAULT, Topology


@pytest.fixture(scope="module")
def topo():
    return Topology()


def _sample_pairs(topo, stride=37):
    """A deterministic spread of (src, dst) core pairs across the machine."""
    n = topo.n_cores
    pairs = []
    for i, a in enumerate(range(0, n, stride)):
        b = (a * 7 + i * 113 + 5) % n
        pairs.append((a, b))
    # make sure every Table-1 class is represented
    pairs.extend(topo.table1_paths().values())
    return pairs


def test_mezz_hops_bounded_by_half_ring_sums(topo):
    """Dimension-ordered minimal routing: at most X/2 + Y/2 + Z/2 mezzanine
    hops (4/2 + 4/2 + 2/2 = 5 on the prototype torus)."""
    x, y, z = topo.qfdbs_per_mezz, 4, 2
    bound = x // 2 + y // 2 + z // 2
    for a, b in _sample_pairs(topo):
        p = topo.route(a, b)
        assert p.n_mezz_links <= bound, (a, b, p.n_mezz_links)


def test_route_reverse_symmetry(topo):
    """route(a,b) and route(b,a) traverse the same number of links of each
    class and the same number of routers (minimal rings are symmetric)."""
    for a, b in _sample_pairs(topo):
        fwd, rev = topo.route(a, b), topo.route(b, a)
        assert fwd.n_mezz_links == rev.n_mezz_links, (a, b)
        assert fwd.n_intra_qfdb_links == rev.n_intra_qfdb_links, (a, b)
        assert fwd.n_routers == rev.n_routers, (a, b)
        assert len(fwd.links) == len(rev.links), (a, b)


def test_route_link_chain_contiguous(topo):
    """The link sequence forms a contiguous MPSoC chain src -> dst."""
    for a, b in _sample_pairs(topo):
        p = topo.route(a, b)
        cur = topo.core_to_mpsoc(a)
        for l in p.links:
            assert l.src_mpsoc == cur, (a, b, l)
            cur = l.dst_mpsoc
        assert cur == topo.core_to_mpsoc(b), (a, b)


def test_table1_pairs_classify_to_named_kind(topo):
    """Every named Table-1 pair routes to a path of the advertised class."""
    expected_kind = {
        "intra_fpga": "intra_fpga",
        "intra_qfdb_sh": "intra_qfdb_sh",
        "mezz_sh": "mezz_sh",
        "mezz_mh(2)": "mezz_mh(2)",
        "mezz_mh(3)": "mezz_mh(3)",
        # the paper's (3 inter-mezz + 1 intra-mezz, 2 intra-QFDB) row is
        # 4 mezzanine-level + 2 intra-QFDB links in our torus coordinates
        "inter_mezz(3,1,2)": "inter_mezz(4,2)",
    }
    for name, (src, dst) in topo.table1_paths().items():
        assert topo.route(src, dst).kind == expected_kind[name], name


def test_route_cache_consistency():
    """Cached and uncached routing agree; repeat lookups hit the cache."""
    cached = Topology()
    uncached = Topology(route_cache_size=0)
    pairs = _sample_pairs(cached)
    for a, b in pairs:
        assert cached.route(a, b) == uncached.route(a, b), (a, b)
    misses = cached.route_misses
    for a, b in pairs:
        cached.route(a, b)
    assert cached.route_misses == misses  # second pass is all hits
    assert cached.route_hits >= len(pairs)
    assert uncached.route_hits == 0 and uncached.route_misses == 0


def test_route_cache_eviction_bounded():
    """A tiny cache never grows beyond its configured size."""
    topo = Topology(route_cache_size=8)
    for a in range(0, 64, 4):
        for b in range(1, 65, 4):
            topo.route(a % topo.n_cores, b % topo.n_cores)
    assert len(topo._route_cache) <= 8
