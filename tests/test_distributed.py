"""Multi-device tests (8 host devices via subprocess): collectives,
grad sync, MoE EP, elastic re-shard. Each test runs a short script in a
subprocess so the main pytest session keeps seeing 1 device (the dry-run
device-count flag must never leak into smoke tests)."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run8(body: str, devices: int = 8) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
    """) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_hierarchical_allreduce_equals_flat():
    """The accelerator-style hierarchical schedule must be numerically
    identical to a flat psum (paper: accelerated vs software allreduce
    produce the same reduction, only latency differs)."""
    run8("""
        from repro.launch.mesh import make_mesh
        from repro.core.collectives import hierarchical_allreduce, flat_allreduce
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        x = jax.random.normal(jax.random.PRNGKey(0), (1027, 3))
        a = hierarchical_allreduce(x, mesh, intra_axis="data", inter_axis="pod")
        b = flat_allreduce(x, mesh, ("data", "pod"))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
        # with all devices holding the same x, psum over (data,pod) = 4x
        np.testing.assert_allclose(np.asarray(a), 4 * np.asarray(x), rtol=1e-6)
        print("OK")
    """)


def test_grad_sync_strategies_agree():
    run8("""
        from repro.launch.mesh import make_mesh
        from repro.parallel.grad_sync import sync_gradients
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        tree = {"a": jax.random.normal(jax.random.PRNGKey(1), (513,)),
                "b": {"c": jnp.ones((7, 3), jnp.bfloat16)}}
        flat = sync_gradients(tree, mesh, strategy="flat", mean_over=4)
        hier = sync_gradients(tree, mesh, strategy="hierarchical", mean_over=4)
        for k in ("a",):
            np.testing.assert_allclose(np.asarray(flat[k]), np.asarray(hier[k]),
                                       rtol=1e-5)
        # replicated input summed over 4 dp shards / mean 4 == identity
        np.testing.assert_allclose(np.asarray(hier["a"]),
                                   np.asarray(tree["a"]), rtol=1e-5)
        print("OK")
    """)


def test_compressed_sync_error_feedback():
    """int8-compressed sync approximates the exact sum; error feedback keeps
    the accumulated bias bounded over steps."""
    run8("""
        from repro.launch.mesh import make_mesh
        from repro.parallel.grad_sync import sync_gradients, CompressedSync
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        g = {"w": jax.random.normal(jax.random.PRNGKey(2), (2048,))}
        exact = sync_gradients(g, mesh, strategy="hierarchical")
        comp = sync_gradients(g, mesh, strategy="compressed")
        err = np.abs(np.asarray(comp["w"]) - np.asarray(exact["w"])).max()
        scale = np.abs(np.asarray(exact["w"])).max()
        assert err <= 0.05 * scale, (err, scale)
        # error feedback: accumulated mean error over steps shrinks
        sync = CompressedSync(mesh)
        tot = np.zeros(2048); tot_exact = np.zeros(2048)
        for i in range(8):
            gi = {"w": jax.random.normal(jax.random.PRNGKey(10 + i), (2048,))}
            tot += np.asarray(sync(gi)["w"])
            tot_exact += np.asarray(sync_gradients(gi, mesh,
                                    strategy="hierarchical")["w"])
        drift = np.abs(tot - tot_exact).max()
        assert drift <= 0.08 * np.abs(tot_exact).max() + 0.05, drift
        print("OK")
    """)


def test_moe_ep_matches_local():
    """Expert-parallel MoE (all_to_all over data + TP psum over model) must
    match the single-shard computation."""
    run8("""
        from repro.launch.mesh import make_mesh, make_parallel_ctx
        from repro.config import reduced
        from repro.configs import get
        from repro.models.moe import apply_moe, init_moe
        cfg = reduced(get("granite-moe-1b-a400m"))
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        pctx = make_parallel_ctx(mesh)
        p = init_moe(jax.random.PRNGKey(0), cfg, cfg.d_model)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model),
                              jnp.float32).astype(jnp.bfloat16)
        with mesh:
            y_ep = jax.jit(lambda p, x: apply_moe(p, x, cfg, pctx))(p, x)
        y_local = apply_moe(p, x, cfg, None)
        np.testing.assert_allclose(np.asarray(y_ep, np.float32),
                                   np.asarray(y_local, np.float32),
                                   rtol=6e-2, atol=6e-2)
        print("OK")
    """)


def test_sharded_train_step_matches_single_device():
    """One train step on the (2,2,2) mesh must match the unsharded step
    (same loss, same updated params) — SPMD correctness end to end."""
    run8("""
        from repro.launch.mesh import make_mesh, make_parallel_ctx
        from repro.config import reduced
        from repro.configs import get
        from repro.models import build_model
        from repro.train.loop import make_train_step
        from repro.train.optimizer import AdamWConfig, adamw_init
        from repro.parallel.sharding import param_specs
        cfg = reduced(get("deepseek-7b"), n_layers=2)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt_cfg = AdamWConfig()
        opt = adamw_init(params, opt_cfg)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 64),
                                              0, cfg.vocab_size),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 64),
                                              0, cfg.vocab_size)}
        # single device reference
        step0 = make_train_step(model, opt_cfg, None)
        p_ref, o_ref, m_ref = jax.jit(step0)(params, opt, batch)
        # sharded
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        pctx = make_parallel_ctx(mesh)
        step1 = make_train_step(model, opt_cfg, pctx)
        specs = param_specs(params, cfg, pctx)
        shard = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda s: isinstance(s, P))
        with mesh:
            p_sh = jax.device_put(params, shard)
            fn = jax.jit(step1, in_shardings=(shard, None, None))
            p_new, o_new, m_new = fn(p_sh, opt, batch)
        assert abs(float(m_ref["loss"]) - float(m_new["loss"])) < 2e-2, \
            (float(m_ref["loss"]), float(m_new["loss"]))
        l1 = jax.tree_util.tree_leaves(p_ref)
        l2 = jax.tree_util.tree_leaves(p_new)
        for a, b in zip(l1, l2):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-2, atol=2e-2)
        print("OK")
    """)


def test_elastic_reshard_roundtrip():
    """Save on a (2,4)-mesh sharding, restore onto (4,2) — values intact."""
    run8("""
        import tempfile
        from repro.launch.mesh import make_mesh
        from repro.checkpoint.store import save_checkpoint, restore_checkpoint
        m1 = make_mesh((2, 4), ("data", "model"))
        m2 = make_mesh((4, 2), ("data", "model"))
        x = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
        tree = {"w": jax.device_put(x, NamedSharding(m1, P("data", "model")))}
        d = tempfile.mkdtemp()
        save_checkpoint(d, 3, tree)
        tmpl = {"w": jax.ShapeDtypeStruct((16, 8), x.dtype)}
        new_sh = {"w": NamedSharding(m2, P("data", "model"))}
        restored, _ = restore_checkpoint(d, 3, tmpl, shardings=new_sh)
        np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(x))
        assert restored["w"].sharding.mesh.shape["data"] == 4
        print("OK")
    """)


def test_gvas_addressing():
    run8("""
        from repro.launch.mesh import make_mesh
        from repro.core.gvas import addr_of, shard_of
        mesh = make_mesh((2, 4), ("data", "model"))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        arr = jax.device_put(x, NamedSharding(mesh, P("data", "model")))
        a = addr_of(arr, (5, 3))
        assert len(a["replicas"]) == 1
        dev = a["replicas"][0]["device"]
        local = shard_of(arr, dev)
        li = a["replicas"][0]["local_index"]
        assert local[li] == x[5, 3]
        print("OK")
    """)
