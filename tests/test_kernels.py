"""Per-kernel validation: shape/dtype sweeps, interpret-mode kernels vs the
pure-jnp oracles (assert_allclose), per the deliverable-(c) requirement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.allreduce_combine.kernel import combine
from repro.kernels.allreduce_combine.ref import combine_ref
from repro.kernels.flash_decode.kernel import flash_decode
from repro.kernels.flash_decode.ref import decode_attention_ref
from repro.kernels.matmul_tile.kernel import matmul_tile
from repro.kernels.matmul_tile.ref import matmul_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


# ------------------------------------------------------------------- matmul
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mnk", [(128, 128, 128), (256, 128, 512),
                                 (384, 256, 256), (128, 384, 640)])
def test_matmul_tile(mnk, dtype):
    m, n, k = mnk
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(ka, (m, k), jnp.float32).astype(dtype)
    b = jax.random.normal(kb, (k, n), jnp.float32).astype(dtype)
    out = matmul_tile(a, b, bm=128, bn=128, bk=128, interpret=True)
    ref = matmul_ref(a, b)
    # split-K changes the f32 accumulation order vs XLA's dot -> 1e-3 rel
    tol = 1e-3 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol * 8)


def test_matmul_tile_kblocks_accumulate():
    """K-grid accumulation must be exact across many K blocks."""
    a = jnp.ones((128, 2048), jnp.float32)
    b = jnp.ones((2048, 128), jnp.float32)
    out = matmul_tile(a, b, bm=128, bn=128, bk=256, interpret=True)
    np.testing.assert_allclose(np.asarray(out), 2048.0)


# ------------------------------------------------------------------ combine
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("op", ["sum", "max", "min"])
@pytest.mark.parametrize("shape", [(4, 1024), (3, 4096), (8, 8192)])
def test_combine(shape, op, dtype):
    x = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32) * 8
    x = x.astype(dtype)
    out = combine(x, op=op, interpret=True)
    ref = combine_ref(x, op=op)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=1e-2,
                               atol=1e-2)


# -------------------------------------------------------------- flash decode
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("cfg", [
    # (B, H, K, dk, dv, S, chunk)
    (2, 8, 2, 64, 64, 512, 128),
    (1, 4, 4, 128, 128, 1024, 256),
    (2, 8, 1, 64, 128, 256, 256),
])
def test_flash_decode(cfg, dtype):
    B, H, K, dk, dv, S, chunk = cfg
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, dk), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, K, dk), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, K, dv), jnp.float32).astype(dtype)
    out = flash_decode(q, k, v, S, chunk=chunk, interpret=True)
    ref = decode_attention_ref(q, k, v, S)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_flash_decode_respects_length():
    """Entries past `length` must not contribute (paged/partial caches)."""
    B, H, K, dk, S = 1, 4, 2, 32, 256
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, dk))
    k = jax.random.normal(ks[1], (B, S, K, dk))
    v = jax.random.normal(ks[2], (B, S, K, dk))
    length = 100
    out = flash_decode(q, k, v, length, chunk=64, interpret=True)
    # poison the tail; result must be identical
    k2 = k.at[:, length:].set(1e4)
    v2 = v.at[:, length:].set(-1e4)
    out2 = flash_decode(q, k2, v2, length, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=1e-5)


# ----------------------------------------------------------------- ssd scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("cfg", [
    # (b, l, h, p, n, chunk, hb)
    (2, 128, 8, 16, 16, 32, 4),
    (1, 256, 4, 32, 64, 64, 4),
    (2, 64, 16, 16, 32, 64, 8),
])
def test_ssd_scan(cfg, dtype):
    b, l, h, p, n, chunk, hb = cfg
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.3)
    B = jax.random.normal(ks[3], (b, l, 1, n), jnp.float32).astype(dtype)
    C = jax.random.normal(jax.random.PRNGKey(5), (b, l, 1, n),
                          jnp.float32).astype(dtype)
    y, st = ssd_scan(x, dt, A, B, C, chunk=chunk, head_block=hb,
                     interpret=True)
    y_ref, st_ref = ssd_ref(x, dt, A, B, C)
    tol = 1e-4 if dtype == jnp.float32 else 6e-2
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=tol,
                               atol=tol * 10)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), rtol=tol,
                               atol=tol * 10)


def test_ssd_scan_matches_model_chunked_form():
    """The Pallas kernel, the jnp chunked dual form used by the models, and
    the sequential oracle all agree."""
    from repro.models.ssm import ssd_chunked
    b, l, h, p, n = 1, 128, 4, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, 1, n))
    C = jax.random.normal(jax.random.PRNGKey(7), (b, l, 1, n))
    y_m, st_m = ssd_chunked(x, dt, A, B, C, chunk=32)
    y_r, st_r = ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y_m), np.asarray(y_r), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_m), np.asarray(st_r), rtol=1e-4,
                               atol=1e-4)
    y_k, st_k = ssd_scan(x, dt, A, B, C, chunk=32, head_block=4,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-4,
                               atol=1e-4)


# ------------------------------------------------- model flash vs naive attn
@pytest.mark.parametrize("causal", [True, False])
def test_model_flash_attention_oracle(causal):
    from repro.models.attention import flash_attention
    B, S, H, K, hd = 2, 96, 8, 2, 32  # ragged: 96 not divisible by 64
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    out = flash_attention(q, k, v, causal=causal, q_chunk=64, kv_chunk=64)
    # naive reference
    kk = jnp.repeat(k, H // K, axis=2)
    vv = jnp.repeat(v, H // K, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) * hd ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, axis=-1), vv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3,
                               atol=2e-3)
