"""Fault & congestion scenario engine (DESIGN.md §2.10): FaultSpec
canonicalization, deterministic fault-aware rerouting (no dead element on
any returned path, dimension order preserved, diagnosable cuts), route
cache epochs, degraded compiled==interp agreement, batched degradation
axes vs statically degraded twins, degraded machine variants, tenant
interference, and the straggler-aware train co-sim."""

import numpy as np
import pytest

from repro.core.exanet.faults import (HEALTHY, FaultSpec, UnroutableError,
                                      all_link_keys, batch_fault_axes,
                                      link_key, sample_fault_spec)
from repro.core.exanet.mpi import ExanetMPI
from repro.core.exanet.params import DEFAULT
from repro.core.exanet.topology import Topology
from repro.core.program import Program, cg_iteration, halo3d

RTOL = 1e-9


def _rel(a, b) -> float:
    rel = abs(b.latency_us - a.latency_us) / max(abs(a.latency_us), 1e-12)
    for x, y in zip(a.clocks, b.clocks):
        rel = max(rel, abs(y - x) / max(abs(x), 1e-12))
    return rel


# ---------------------------------------------------------------- FaultSpec
def test_fault_spec_canonicalization():
    a = FaultSpec(dead_links=[("mezz", 4, 0)],
                  slow_links={("intra_qfdb", 2, 1): 3.0})
    b = FaultSpec(dead_links=[("mezz", 0, 4)],
                  slow_links={("intra_qfdb", 1, 2): 3.0})
    assert a == b and hash(a) == hash(b)
    assert a.signature() == b.signature()
    assert a.is_dead_link("mezz", 4, 0) and a.is_dead_link("mezz", 0, 4)
    assert a.link_slow("intra_qfdb", 2, 1) == 3.0
    assert HEALTHY.is_empty and HEALTHY.signature() == "healthy"
    assert a.degrades_structure and not a.is_empty


def test_fault_spec_lossy_replay_cost():
    """§4.5.3: loss probability p costs 1/(1-p) expected transmissions,
    compounding with a hot-link factor on the same link."""
    s = FaultSpec(slow_links={("mezz", 0, 4): 2.0},
                  lossy_links={("mezz", 0, 4): 0.5})
    assert s.link_slow("mezz", 0, 4) == pytest.approx(4.0)
    assert not s.degrades_structure


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(slow_links={("mezz", 0, 4): 0.5})
    with pytest.raises(ValueError):
        FaultSpec(lossy_links={("mezz", 0, 4): 1.0})


# ----------------------------------------------------------------- reroute
def test_reroute_avoids_dead_mezz_link_deterministically():
    spec = FaultSpec(dead_links=[("mezz", 0, 4)])
    t1 = Topology(DEFAULT, faults=spec)
    t2 = Topology(DEFAULT, faults=spec)
    # QFDB0 -> QFDB1 normally crosses mezz(0,4)
    p1, p2 = t1.route(0, 16), t2.route(0, 16)
    assert p1.links == p2.links, "reroute must be deterministic"
    for l in p1.links:
        assert not spec.is_dead_link(l.kind, l.src_mpsoc, l.dst_mpsoc)
    healthy = Topology(DEFAULT).route(0, 16)
    assert p1.links != healthy.links


def test_intra_qfdb_relay_ladder():
    """Dead direct link -> lowest-id alive relay; dead relay -> next."""
    dead = FaultSpec(dead_links=[("intra_qfdb", 0, 1)])
    path = Topology(DEFAULT, faults=dead).route(0, 4)
    hops = [(l.src_mpsoc, l.dst_mpsoc) for l in path.links]
    assert hops == [(0, 2), (2, 1)]
    relay_down = FaultSpec(dead_links=[("intra_qfdb", 0, 1)],
                           dead_mpsocs=[2])
    path = Topology(DEFAULT, faults=relay_down).route(0, 4)
    hops = [(l.src_mpsoc, l.dst_mpsoc) for l in path.links]
    assert hops == [(0, 3), (3, 1)]


def test_unroutable_cut_is_diagnosed():
    cut = FaultSpec(dead_links=[("intra_qfdb", 0, 1)], dead_mpsocs=[2, 3])
    topo = Topology(DEFAULT, faults=cut)
    with pytest.raises(UnroutableError):
        topo.route(0, 4)
    # cutting both ring directions out of a torus node partitions it
    ring_cut = FaultSpec(dead_links=[("mezz", 0, 4), ("mezz", 0, 12)])
    topo = Topology(DEFAULT, faults=ring_cut)
    with pytest.raises(UnroutableError) as e:
        topo.route(0, 32)   # QFDB0 -> QFDB2 needs the x ring
    assert str(e.value)


def test_dead_endpoint_is_unroutable():
    spec = FaultSpec(dead_mpsocs=[1])
    topo = Topology(DEFAULT, faults=spec)
    with pytest.raises(UnroutableError, match="dead"):
        topo.route(0, 4)


def _mezz_dim_sequence(topo, path):
    dims = []
    for l in path.links:
        if l.kind != "mezz":
            continue
        a = topo.qfdb_coords(l.src_mpsoc // topo.fpgas_per_qfdb)
        b = topo.qfdb_coords(l.dst_mpsoc // topo.fpgas_per_qfdb)
        dims.append(next(i for i in range(3) if a[i] != b[i]))
    return dims


def test_fuzz_random_fault_sets_at_512_ranks():
    """Random fault sets on the full 512-core prototype: every returned
    route is fault-free, deterministic, and dimension-ordered (X->Y->Z
    never interleaves — the deadlock-freedom invariant of DOR)."""
    for seed in range(6):
        rng = np.random.default_rng(seed)
        spec = sample_fault_spec(rng, Topology(DEFAULT),
                                 n_dead_links=3, n_dead_mpsocs=2,
                                 n_slow_links=2)
        topo = Topology(DEFAULT, faults=spec)
        alive = [c for c in range(256, 512)
                 if not spec.is_dead_mpsoc(c // DEFAULT.cores_per_mpsoc)]
        pairs = rng.choice(len(alive), size=(60, 2))
        cuts = 0
        for i, j in pairs:
            src, dst = alive[i], alive[j]
            if src == dst:
                continue
            try:
                path = topo.route(src, dst)
            except UnroutableError as e:
                assert str(e)
                cuts += 1
                continue
            assert path.links == topo.route(src, dst).links
            for l in path.links:
                assert not spec.is_dead_link(l.kind, l.src_mpsoc,
                                             l.dst_mpsoc), (seed, l)
                assert not spec.is_dead_mpsoc(l.src_mpsoc)
                assert not spec.is_dead_mpsoc(l.dst_mpsoc)
            dims = _mezz_dim_sequence(topo, path)
            assert dims == sorted(dims), \
                f"dimension order violated: {dims} (seed {seed})"
        assert cuts < len(pairs), "every pair cut: degenerate sample"


def test_route_cache_epoch_and_clear():
    topo = Topology(DEFAULT)
    topo.route(0, 16)
    info = topo.route_cache_info()
    assert info["size"] >= 1 and info["fault_epoch"] == 0
    topo.set_faults(FaultSpec(dead_links=[("mezz", 0, 4)]))
    info = topo.route_cache_info()
    assert info["size"] == 0 and info["fault_epoch"] == 1
    path = topo.route(0, 16)
    for l in path.links:
        assert (l.kind, *sorted((l.src_mpsoc, l.dst_mpsoc))) != \
            ("mezz", 0, 4)
    topo.route_cache_clear()
    assert topo.route_cache_info()["size"] == 0


# ------------------------------------------------- executors under faults
def test_degraded_compiled_matches_interp():
    """Static degradation (structural + hot + lossy + latency) must keep
    the two executors within 1e-9 — same PathMetrics, same answers."""
    spec = FaultSpec(dead_links=[("intra_qfdb", 0, 1)],
                     slow_links={("mezz", 0, 4): 3.0},
                     lossy_links={("mezz", 4, 8): 0.2},
                     link_extra_latency_us={("intra_qfdb", 8, 9): 10.0})
    mpi = ExanetMPI(faults=spec)
    prog = cg_iteration(64, 32768, 120.0, coll_algo="recursive_doubling")
    a = mpi.run_program(prog, backend="interp")
    b = mpi.run_program(prog, backend="compiled")
    assert _rel(a, b) <= RTOL
    healthy = ExanetMPI().run_program(prog, backend="compiled")
    assert b.latency_us > healthy.latency_us


def test_batched_link_axes_match_static_twins():
    """N non-structural fault sets as batch columns == N statically
    degraded machines, column by column (and the built-in interpreter
    check lane)."""
    base = ExanetMPI()
    rng = np.random.default_rng(3)
    specs = [sample_fault_spec(rng, base.topo, n_slow_links=2,
                               n_lossy_links=1, extra_latency_us=4.0)
             for _ in range(4)]
    prog = halo3d(32, 65536, compute_us=40.0)
    axes = batch_fault_axes(specs, prog)
    got = base.run_program_scenarios(prog, **axes, check=4, rtol=RTOL)
    for j, s in enumerate(specs):
        twin = ExanetMPI(faults=s, cache=False)
        ref = twin.run_program(prog, backend="compiled")
        assert _rel(ref, got[j]) <= RTOL, (j, s.signature())


def test_batched_link_axes_jax_engine_agrees():
    base = ExanetMPI()
    rng = np.random.default_rng(5)
    specs = [sample_fault_spec(rng, base.topo, n_slow_links=2)
             for _ in range(3)]
    prog = halo3d(16, 32768, compute_us=25.0)
    axes = batch_fault_axes(specs, prog)
    a = base.run_program_scenarios(prog, **axes, engine="numpy")
    b = base.run_program_scenarios(prog, **axes, engine="jax")
    for x, y in zip(a, b):
        assert _rel(x, y) <= RTOL


def test_batch_fault_axes_validation():
    with pytest.raises(ValueError, match="structural"):
        batch_fault_axes([FaultSpec(dead_links=[("mezz", 0, 4)])])
    slow = FaultSpec(slow_ranks={1: 2.0})
    with pytest.raises(ValueError, match="slow_ranks"):
        batch_fault_axes([slow])
    prog = Program((
        tuple([__import__("repro.core.program",
                          fromlist=["Compute"]).Compute(us=1.0)] * 3),
        (),
    ))
    axes = batch_fault_axes([slow, HEALTHY], prog)
    assert axes["compute_scale"].shape == (3, 2)
    assert np.all(axes["compute_scale"][:, 1] == 1.0)


def test_slow_rank_axis_slows_only_that_rank():
    mpi = ExanetMPI()
    prog = halo3d(16, 16384, compute_us=200.0)
    spec = FaultSpec(slow_ranks={3: 4.0})
    axes = batch_fault_axes([HEALTHY, spec], prog)
    res = mpi.run_program_scenarios(prog, **axes, check=2, rtol=RTOL)
    assert res[1].latency_us > res[0].latency_us
    assert res[1].clocks[3] > res[0].clocks[3] * 2.0


# --------------------------------------------------------------- machine
def test_machine_degraded_variants():
    from repro.core.machine import ExanetMachine
    m = ExanetMachine()
    assert m.degraded(HEALTHY) is m and m.degraded(None) is m
    spec = FaultSpec(dead_links=[("mezz", 0, 4)])
    d = m.degraded(spec)
    assert d is m.degraded(spec), "degraded variants are cached"
    assert spec.signature() in d.name and d.name != m.name
    assert d.placement == m.placement
    assert d.mpi.faults == spec
    # scaled tiers inherit the fault spec
    assert d._mpi_for(1024).faults == spec


def test_network_static_degradation_slows_path():
    slow = FaultSpec(slow_links={("intra_qfdb", 0, 1): 3.0},
                     link_extra_latency_us={("intra_qfdb", 0, 1): 5.0})
    h = ExanetMPI()
    d = ExanetMPI(faults=slow, cache=False)
    path_h = h.topo.route(0, 4)
    path_d = d.topo.route(0, 4)
    assert [l.kind for l in path_h.links] == \
        [l.kind for l in path_d.links], "non-structural: same route"
    assert d.net.rdv_latency(65536, path_d) > \
        h.net.rdv_latency(65536, path_h) + 10.0


# ---------------------------------------------------------- interference
def test_interference_is_emergent_and_monotone():
    from repro.core.exanet.interference import (
        background_stream, interleave_qfdb, merge_tenants,
        neighbor_load_byte_scale)
    app = halo3d(16, 65536, compute_us=50.0)
    bg = background_stream(16, iters=8, nbytes=131072)
    a_ranks, b_ranks = interleave_qfdb(16, 16)
    mix = merge_tenants(app, bg, a_ranks, b_ranks)
    assert set(a_ranks).isdisjoint(b_ranks)
    n_posts = sum(1 for ops in mix.program.rank_ops for op in ops
                  if type(op).__name__ in ("Isend", "Irecv"))
    assert mix.bg_post_mask.shape == (n_posts,)
    loads = (0.0, 1.0, 4.0)
    bs = neighbor_load_byte_scale(mix, loads)
    res = ExanetMPI().run_program_scenarios(mix.program, byte_scale=bs,
                                            check=2, rtol=RTOL)
    app_us = [mix.app_latency_us(r) for r in res]
    assert app_us[0] < app_us[1] < app_us[2], \
        f"neighbour load must slow the app: {app_us}"


def test_merge_tenants_rejects_collectives_and_overlap():
    from repro.core.exanet.interference import merge_tenants
    from repro.core.program import ProgramError
    coll = cg_iteration(4, 1024, 1.0)
    p2p = halo3d(4, 1024)
    with pytest.raises(ProgramError, match="Collective"):
        merge_tenants(coll, p2p)
    with pytest.raises(ValueError, match="overlap"):
        merge_tenants(p2p, p2p, app_ranks=(0, 1, 2, 3),
                      bg_ranks=(3, 4, 5, 6))


# ------------------------------------------------------- train co-sim
def test_trainsim_rank_compute_scale():
    from repro.train.cosim import TrainSim, TrainStepSpec
    spec = TrainStepSpec(nranks=4)
    with pytest.raises(ValueError, match="rank_compute_scale"):
        TrainSim(spec, rank_compute_scale=np.ones(3))
    healthy = TrainSim(spec)
    rcs = np.ones(4)
    rcs[2] = 3.0
    slow = TrainSim(spec, rank_compute_scale=rcs)
    cand = healthy.analytic_candidate()
    t_h = float(healthy.cost_candidates([cand])[0])
    t_s = float(slow.cost_candidates([cand])[0])
    assert t_s > t_h * 1.5, (t_h, t_s)
    # all-ones scale is a no-op (stays on the fast path)
    assert TrainSim(spec, rank_compute_scale=np.ones(4)) \
        .rank_compute_scale is None


def test_on_straggle_callback():
    from repro.runtime.fault import StragglerMonitor
    events = []
    mon = StragglerMonitor(deadline_factor=2.0,
                           on_straggle=lambda s, dt, dl:
                           events.append((s, dt, dl)))
    for i in range(10):
        mon.observe(i, 1.0)
    assert not events
    assert mon.observe(10, 5.0)
    (step, dt, deadline), = events
    assert step == 10 and dt == 5.0 and deadline == pytest.approx(2.0)


# ------------------------------------------------------------ ip overlay
def test_overlay_vs_native_gap():
    import math
    from repro.core.exanet import ip_overlay
    assert ip_overlay.math is math  # module-scope import (no local shadow)
    gap = ip_overlay.overlay_vs_native_gap()
    assert gap["baseline_gbps"] < gap["overlay_gbps"] \
        < gap["native_wire_gbps"]
    assert gap["native_wire_gbps"] == pytest.approx(6.42, rel=0.05)
    assert gap["overlay_gbps"] == pytest.approx(4.7, rel=0.1)
    assert gap["baseline_gbps"] == pytest.approx(1.3, rel=0.1)
