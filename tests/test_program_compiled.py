"""Compiled Program-IR executor: equivalence with the interpreter +
backend plumbing (DESIGN.md §2.5, the Program half).

The compiled backend (``program_compiled``) must reproduce the
interpreted ``run_program`` — latencies, per-rank clocks, send and
collective counts — to ~1e-9 relative across random halo grids, tag
permutations, mixed eager/rendez-vous sizes, per-rank compute skew and
embedded collectives, for both rank placements.  Mirrors
``test_exec_compiled.py``'s 60-seed determinism harness; the hypothesis
twin lives at the bottom of this file.
"""

import random

import pytest

from repro.core.exanet import ExanetMPI
from repro.core.exanet.exec_compiled import ProgramStructureError
from repro.core.program import (Collective, Compute, Irecv, Isend, Program,
                                Wait, bsp_step, cg_iteration, halo3d)

#: straddles mpi_eager_max_bytes (32) and the 16 KB RDMA block size
BYTES = (0, 1, 31, 32, 33, 100, 4096, 65536, 300000)


def _assert_equal(a, b, tag, rel=1e-9):
    assert b.latency_us == pytest.approx(a.latency_us, rel=rel), tag
    assert a.n_sends == b.n_sends, tag
    assert a.n_collectives == b.n_collectives, tag
    for x, y in zip(a.clocks, b.clocks):
        assert y == pytest.approx(x, rel=rel, abs=1e-12), tag
    for x, y in zip(a.compute_us, b.compute_us):
        assert y == pytest.approx(x, rel=rel, abs=1e-12), tag


@pytest.fixture(scope="module", params=[None, 1], ids=["rpm4", "rpm1"])
def mpi(request):
    return ExanetMPI(ranks_per_mpsoc=request.param)


def _check(mpi, prog, tag):
    a = mpi.run_program(prog, backend="interp")
    b = mpi.run_program(prog, backend="compiled")
    _assert_equal(a, b, tag)
    return a, b


# ------------------------------------------------------------- builders
@pytest.mark.parametrize("face", [1, 31, 33, 4096, 300000])
def test_halo3d_compiled_matches_interp(mpi, face):
    for nranks, grid in ((8, None), (12, None), (16, (4, 2, 2)),
                         (2, None)):
        _check(mpi, halo3d(nranks, face, 12.5, grid=grid),
               ("halo", nranks, face))


def test_overlap_and_selective_waits(mpi):
    _check(mpi, halo3d(8, 4096, 40.0, overlap=True), "overlap")
    ops0 = (Isend(1, 300000, tag=1, handle="a"),
            Isend(1, 8, tag=2, handle="b"), Wait(("a",)), Compute(5.0),
            Wait(("b",)))
    ops1 = (Irecv(0, 300000, tag=1), Irecv(0, 8, tag=2), Wait(),
            Compute(2.0))
    _check(mpi, Program((ops0, ops1)), "named-handles")


@pytest.mark.parametrize("algo", ["recursive_doubling", "oneshot",
                                  "rabenseifner", "auto"])
def test_cg_iteration_with_embedded_collectives(mpi, algo):
    _check(mpi, cg_iteration(8, 70000, 30.0, coll_algo=algo),
           ("cg", algo))


def test_bsp_and_non_allreduce_collectives(mpi):
    _check(mpi, bsp_step(8, 10.0, "allreduce", 4096), "bsp")
    for op in ("bcast", "allgather", "barrier", "alltoall"):
        ops = tuple((Compute(3.0), Collective(op, 512, "auto"))
                    for _ in range(8))
        _check(mpi, Program(ops), ("coll", op))


def test_single_rank_program(mpi):
    prog = Program(((Compute(7.0), Collective("allreduce", 64, "auto")),))
    _check(mpi, prog, "single-rank")


def test_deep_wait_chain_compiles_iteratively():
    """A rank with hundreds of sequential post/Wait phases must lower
    without recursion limits (the Wait-chain resolution is iterative)."""
    phases = 1200
    ops0 = tuple(x for _ in range(phases)
                 for x in (Isend(1, 64, 0), Wait()))
    ops1 = tuple(x for _ in range(phases)
                 for x in (Irecv(0, 64, 0), Wait()))
    _check(ExanetMPI(), Program((ops0, ops1)), "deep-chain")


def test_degenerate_programs(mpi):
    """No posts at all (collective-only, the emit_sync_program shape with
    zero compute) and pure-compute programs lower to empty item/event
    tables — regression for the empty boolean-mask dtype."""
    colls_only = Program(tuple((Collective("allreduce", 4096, "auto"),)
                               for _ in range(8)))
    _check(mpi, colls_only, "colls-only")
    pure = Program(tuple((Compute(5.0),) for _ in range(4)))
    a, b = _check(mpi, pure, "pure-compute")
    assert b.latency_us == 5.0


# ------------------------------------------------------------------ fuzz
def _fuzz_program(rng, nranks):
    """Random halo-shaped program: random grid/tag bijection, per-channel
    random sizes (mixed eager/rendez-vous), random per-rank compute skew,
    optional overlap and trailing embedded collectives."""
    template = halo3d(nranks, 1, 0.0)
    tagmap = list(range(6))
    rng.shuffle(tagmap)
    sizes: dict = {}

    def size_of(src, dst, tag):
        return sizes.setdefault((src, dst, tag), rng.choice(BYTES))

    colls = []
    if nranks & (nranks - 1) == 0 and rng.random() < 0.6:
        for _ in range(rng.randint(1, 2)):
            colls.append(Collective(
                "allreduce", rng.choice([8, 64, 4096, 70000]),
                rng.choice(["recursive_doubling", "oneshot", "auto"])))
    overlap = rng.random() < 0.4
    ranks = []
    for r in range(nranks):
        ops = []
        if rng.random() < 0.5:
            ops.append(Compute(rng.uniform(0.0, 30.0)))
        for op in template.rank_ops[r]:
            if isinstance(op, Irecv):
                t = tagmap[op.tag]
                ops.append(Irecv(op.src, size_of(op.src, r, t), t))
            elif isinstance(op, Isend):
                t = tagmap[op.tag]
                ops.append(Isend(op.dst, size_of(r, op.dst, t), t))
            else:   # the Wait
                if overlap:
                    ops.append(Compute(rng.uniform(0.0, 20.0)))
                ops.append(op)
        if rng.random() < 0.4:
            ops.append(Compute(rng.uniform(0.0, 10.0)))
        ops.extend(colls)
        ranks.append(tuple(ops))
    return Program(tuple(ranks))


def test_seeded_fuzz_compiled_equals_interp():
    """Deterministic fuzz across random programs (the hypothesis twin is
    below): per-rank clock skew makes the scheduler's firing order
    data-dependent, which is exactly what the probe-recorded tape must
    capture."""
    mpis = {rpm: ExanetMPI(ranks_per_mpsoc=rpm) for rpm in (None, 1)}
    for seed in range(60):
        rng = random.Random(seed)
        nranks = rng.choice([2, 4, 6, 8, 12, 16])
        prog = _fuzz_program(rng, nranks)
        m = mpis[rng.choice([None, 1])]
        a = m.run_program(prog, backend="interp")
        b = m.run_program(prog, backend="compiled")
        _assert_equal(a, b, ("fuzz", seed))


# ------------------------------------------------- rebinding / the cache
def test_one_artifact_serves_a_size_sweep(mpi):
    """One compiled structure, many bindings (the weak/strong sweep
    workload): every binding must match its own interpreted run, and the
    wave-structured halo tape must be shared across them."""
    progs = [halo3d(16, nb, us) for nb, us in
             ((16, 5.0), (1024, 50.0), (65536, 0.25), (300000, 11.0))]
    art = mpi.program_artifact(progs[0])
    for p in progs[1:]:
        assert mpi.program_artifact(p) is art
    outs = art.run(art.bind(progs))
    for p, b in zip(progs, outs):
        _assert_equal(mpi.run_program(p, backend="interp"), b, "rebind")
    assert len(art._tape_cache) == 1  # halo tapes are size-invariant


def test_differently_parameterized_halo3d_share_nranks():
    """Regression (satellite): two differently-parameterized emissions of
    one builder at the same rank count share a structure — the cache must
    serve both *correctly* (content-keyed binding), never replay the
    first program's numbers for the second."""
    mpi = ExanetMPI()
    p1 = halo3d(8, 1024, 10.0)
    p2 = halo3d(8, 300000, 3.0)
    assert p1.structure_key() == p2.structure_key()
    a1, b1 = _check(mpi, p1, "p1")
    a2, b2 = _check(mpi, p2, "p2")
    # the stale-cache failure mode: identical outputs for distinct inputs
    assert abs(a1.latency_us - a2.latency_us) > 1e-6
    assert mpi.program_artifact(p1) is mpi.program_artifact(p2)


def test_bind_rejects_structure_mismatch(mpi):
    art = mpi.program_artifact(halo3d(8, 1024, 10.0))
    with pytest.raises(ProgramStructureError):
        art.bind([halo3d(16, 1024, 10.0)])
    with pytest.raises(ProgramStructureError):
        art.bind([halo3d(8, 1024, 10.0, grid=(8, 1, 1))])


def test_rank_inconsistent_collective_rejected_even_cache_warm():
    """Regression: collective nbytes are excluded from structure_key, so
    a rank-inconsistent site must be rejected at extract time — never
    aliased onto a warm consistent binding (the interpreter rejects the
    same program at barrier time)."""
    from repro.core.program import ProgramError
    mpi = ExanetMPI()
    good = Program(tuple(
        (Compute(1.0), Collective("allreduce", 1024, "recursive_doubling"))
        for _ in range(2)))
    bad = Program((
        (Compute(1.0), Collective("allreduce", 1024,
                                  "recursive_doubling")),
        (Compute(1.0), Collective("allreduce", 2048,
                                  "recursive_doubling"))))
    assert good.structure_key() == bad.structure_key()
    mpi.run_program(good, backend="compiled")   # warm the bind cache
    for be in ("interp", "compiled"):
        with pytest.raises(ProgramError, match="collective mismatch"):
            mpi.run_program(bad, backend=be)


def test_auto_avoids_serial_chain_collective_splices(monkeypatch):
    """Regression: a program whose collective sites resolve to a
    serial-chain schedule (ring) must stay interpreted under auto — the
    one-send-per-level splice replay is slower than the interpreter (the
    run_schedule auto gate, lifted to programs)."""
    mpi = ExanetMPI()
    monkeypatch.setattr(ExanetMPI, "PROGRAM_COMPILED_AUTO_MIN_RANKS", 2)
    ring = Program(tuple((Collective("allreduce", 12288, "ring"),)
                         for _ in range(8)))
    wide = Program(tuple((Collective("allreduce", 12288,
                                     "recursive_doubling"),)
                         for _ in range(8)))
    assert not mpi._program_splices_profitable(ring, {})
    assert mpi._program_splices_profitable(wide, {})
    a = mpi.run_program(ring, backend="auto")
    b = mpi.run_program(ring, backend="interp")
    _assert_equal(a, b, "ring-auto")
    assert ring.structure_key() not in getattr(mpi, "_app_program_cache",
                                               {})


# ----------------------------------------------------- backend selection
def test_unknown_backend_rejected(mpi):
    with pytest.raises(ValueError, match="backend"):
        mpi.run_program(halo3d(4, 64, 1.0), backend="jit")


def test_compiled_rejects_tracing_engine():
    mpi = ExanetMPI(trace=True)
    prog = halo3d(4, 64, 1.0)
    with pytest.raises(ValueError, match="trace"):
        mpi.run_program(prog, backend="compiled")
    # auto silently stays on the interpreter (and records the trace)
    res = mpi.run_program(prog)
    assert res.latency_us > 0 and len(mpi.net.trace) > 0


def test_auto_compiles_at_threshold(monkeypatch):
    mpi = ExanetMPI()
    monkeypatch.setattr(ExanetMPI, "PROGRAM_COMPILED_AUTO_MIN_RANKS", 2)
    prog = halo3d(8, 4096, 10.0)
    a = mpi.run_program(prog, backend="interp")
    b = mpi.run_program(prog, backend="auto")
    _assert_equal(a, b, "auto")
    assert prog.structure_key() in mpi._app_program_cache


def test_run_program_many_batches_and_orders(monkeypatch):
    monkeypatch.setattr(ExanetMPI, "PROGRAM_COMPILED_AUTO_MIN_RANKS", 2)
    mpi = ExanetMPI()
    progs = [halo3d(8, 1024, 5.0), cg_iteration(8, 4096, 10.0),
             halo3d(8, 65536, 7.0), halo3d(6, 512, 3.0)]
    outs = mpi.run_program_many(progs)
    refs = [mpi.run_program(p, backend="interp") for p in progs]
    for i, (a, b) in enumerate(zip(refs, outs)):
        _assert_equal(a, b, ("many", i))


# --------------------------------------------------------- machine layer
def test_cost_program_backends_agree():
    from repro.core.machine import ExanetMachine
    m = ExanetMachine()
    prog = cg_iteration(16, 70000, 25.0)
    ci = m.cost_program(prog, backend="interp")
    cc = m.cost_program(prog, backend="compiled")
    assert cc == pytest.approx(ci, rel=1e-9)
    batch = m.cost_program_many([prog, halo3d(16, 1024, 5.0)],
                                backend="compiled")
    assert batch[0] == pytest.approx(ci, rel=1e-9)


def test_grad_sync_program_cost_compiled():
    from repro.core.machine import ExanetMachine, TpuMachine
    from repro.parallel.grad_sync import cost_sync_program_s
    m = ExanetMachine()
    buckets = [1 << 20, 1 << 20, 4096]
    a = cost_sync_program_s(m, 16, buckets, compute_us_per_bucket=50.0,
                            backend="interp")
    b = cost_sync_program_s(m, 16, buckets, compute_us_per_bucket=50.0,
                            backend="compiled")
    assert b == pytest.approx(a, rel=1e-9)
    assert cost_sync_program_s(TpuMachine(), 16, buckets) > 0


def test_apps_compiled_backend_agrees_at_512():
    """The Table 3 pipeline runs on backend="auto" (compiled at 512):
    its numbers must be interpreter numbers to well below the 0.1-point
    rounding of the published table."""
    from repro.core.exanet.apps import hpcg
    m = hpcg()
    a = m.simulate_iteration("weak", 512, backend="interp")
    b = m.simulate_iteration("weak", 512, backend="compiled")
    _assert_equal(a, b, "hpcg-512")
    assert b.comm_us == pytest.approx(a.comm_us, rel=1e-9)


# The hypothesis twin of the seeded fuzz lives in tests/test_property.py
# (the hypothesis-gated module), reusing _fuzz_program/_assert_equal from
# here.
