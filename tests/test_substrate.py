"""Substrate: optimizer (incl. int8 states), data pipeline, serve engine,
comm policy, config invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SHAPES, cell_runnable, reduced
from repro.configs import ALL_ARCHS, get
from repro.core.comm import CommPolicy
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   opt_state_bytes_per_param, _quant,
                                   _dequant)


# ---------------------------------------------------------------- optimizer
def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, decay_steps=1000,
                      weight_decay=0.0, grad_clip=10.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params, cfg)
    for _ in range(200):
        g = {"w": 2 * params["w"]}  # d/dw w^2
        params, opt, _ = adamw_update(g, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_int8_state_quantization_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 512)) * 3
    q, s = _quant(x, 256)
    y = _dequant(q, s, 256)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=3 * 2 / 127)


def test_quantized_adamw_tracks_fp32():
    cfg32 = AdamWConfig(lr=0.05, warmup_steps=1, weight_decay=0.0)
    cfg8 = AdamWConfig(lr=0.05, warmup_steps=1, weight_decay=0.0,
                       quantize_states=True, qblock=128)
    p32 = {"w": jnp.ones((4, 256)) * 2.0}
    p8 = {"w": jnp.ones((4, 256)) * 2.0}
    o32, o8 = adamw_init(p32, cfg32), adamw_init(p8, cfg8)
    assert isinstance(o8["m"]["w"], dict)          # int8 state engaged
    assert o8["m"]["w"]["q"].shape == (4, 256)     # layout preserving
    key = jax.random.PRNGKey(1)
    for i in range(30):
        key, k = jax.random.split(key)
        g = {"w": p32["w"] + 0.1 * jax.random.normal(k, (4, 256))}
        p32, o32, _ = adamw_update(g, o32, p32, cfg32)
        g8 = {"w": p8["w"] + 0.1 * jax.random.normal(k, (4, 256))}
        p8, o8, _ = adamw_update(g8, o8, p8, cfg8)
    err = float(jnp.abs(p32["w"] - p8["w"]).mean())
    assert err < 0.05, err


def test_opt_bytes_per_param():
    assert opt_state_bytes_per_param(AdamWConfig()) == 8.0
    assert opt_state_bytes_per_param(AdamWConfig(quantize_states=True)) < 2.1


# --------------------------------------------------------------------- data
def test_data_determinism_and_structure():
    from repro.data.pipeline import SyntheticTokens
    cfg = reduced(get("deepseek-7b"))
    ds = SyntheticTokens(cfg, batch=4, seq=32, seed=7)
    a = ds.batch_at(3)
    b = ds.batch_at(3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = ds.batch_at(4)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    # learnable structure: next token is predictable most of the time
    t = np.asarray(a["tokens"])
    hits = np.mean((t[:, 1:] - t[:, :-1]) % cfg.vocab_size == 31)
    assert hits > 0.6


def test_prefetcher():
    from repro.data.pipeline import Prefetcher
    out = list(Prefetcher(iter(range(10)), depth=3))
    assert out == list(range(10))


# -------------------------------------------------------------------- serve
def test_serve_engine_continuous_batching():
    from repro.models import build_model
    from repro.serve.engine import ServeEngine
    cfg = reduced(get("exanest-lm-100m"), n_layers=1, d_model=32,
                  vocab_size=64, n_heads=2, n_kv_heads=1, d_ff=64, head_dim=16)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, slots=2, window=32)
    rids = [eng.submit([1, 2, 3], max_new_tokens=4) for _ in range(3)]
    steps = eng.run_until_idle(max_steps=100)
    assert steps < 100
    for rid in rids:
        out = eng.result(rid)
        assert out is not None and len(out) == 4
        assert all(0 <= t < cfg.vocab_size for t in out)


def test_serve_greedy_matches_model_decode():
    """The engine's greedy continuation equals manual prefill+decode."""
    from repro.models import build_model
    from repro.serve.engine import ServeEngine
    cfg = reduced(get("exanest-lm-100m"), n_layers=1, d_model=32,
                  vocab_size=64, n_heads=2, n_kv_heads=1, d_ff=64, head_dim=16)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = [5, 9, 2]
    eng = ServeEngine(model, params, slots=1, window=32)
    rid = eng.submit(prompt, max_new_tokens=3)
    eng.run_until_idle()
    got = eng.result(rid)
    # manual greedy decode
    cache = model.init_cache(1, 32)
    toks = list(prompt)
    for pos, t in enumerate(toks):
        lg, cache = model.decode_step(
            params, cache, {"token": jnp.array([t]),
                            "pos": jnp.array(pos, jnp.int32)})
    manual = []
    for i in range(3):
        nxt = int(jnp.argmax(lg[0, 0]))
        manual.append(nxt)
        lg, cache = model.decode_step(
            params, cache, {"token": jnp.array([nxt]),
                            "pos": jnp.array(len(prompt) + i, jnp.int32)})
    assert got == manual, (got, manual)


# ------------------------------------------------------------------- policy
def test_comm_policy_crossover():
    pol = CommPolicy()
    thr = pol.eager_threshold_bytes(256)
    assert 1024 < thr < 1 << 30
    assert pol.choose(64, 256) == "eager"
    assert pol.choose(64 << 20, 256) == "rendezvous"
    # bucket size amortizes alpha to 2%
    b = pol.bucket_bytes(256)
    ring = pol.ring_allreduce_s(b, 256, pol.ici_bw, pol.alpha_s)
    alpha_part = 2 * 255 * pol.alpha_s
    assert alpha_part / ring < 0.03


# ------------------------------------------------------------------- config
def test_cell_runnable_rules():
    for name in ALL_ARCHS:
        cfg = get(name)
        ok, why = cell_runnable(cfg, SHAPES["long_500k"])
        if name in ("mamba2-2.7b", "zamba2-2.7b"):
            assert ok, name
        else:
            assert not ok and "sub-quadratic" in why, name
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert cell_runnable(cfg, SHAPES[s])[0]


def test_param_counts_match_published():
    """Analytic parameter counts land near the published sizes."""
    expect = {"deepseek-v3-671b": (650e9, 700e9),
              "mistral-large-123b": (118e9, 126e9),
              "starcoder2-7b": (7.0e9, 7.8e9),
              "mamba2-2.7b": (2.6e9, 2.9e9),
              "zamba2-2.7b": (2.3e9, 2.9e9),
              "deepseek-7b": (6.5e9, 7.2e9),
              "whisper-small": (0.22e9, 0.31e9)}
    for name, (lo, hi) in expect.items():
        n = get(name).param_count()
        assert lo <= n <= hi, (name, n)
    # DeepSeek-V3 active params ~37B
    a = get("deepseek-v3-671b").active_param_count()
    assert 34e9 <= a <= 40e9, a
