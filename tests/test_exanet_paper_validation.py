"""Validation of the ExaNet model against the paper's measured numbers.

Every assertion cites the paper value (FORTH-ICS/TR-488). Tolerances follow
DESIGN.md §7: <=5-10% where the paper explains the number mechanistically,
and the paper's own model-vs-measurement envelope (15-25%) for the
noise-dominated small-message collective points.
"""

import math

import pytest

from repro.core.exanet import ExanetMPI, Topology, DEFAULT
from repro.core.exanet.allreduce_accel import (accel_allreduce_latency,
                                               accel_applicable)


@pytest.fixture(scope="module")
def mpi():
    return ExanetMPI()


@pytest.fixture(scope="module")
def mpi1():  # one rank per MPSoC (§6.1.5 accelerator comparisons)
    return ExanetMPI(ranks_per_mpsoc=1)


@pytest.fixture(scope="module")
def topo():
    return Topology()


# ------------------------------------------------------------------ Table 2
TABLE2 = {  # path name -> (paper us, tolerance)
    "intra_fpga": (1.17, 0.02),
    "intra_qfdb_sh": (1.293, 0.05),
    "mezz_sh": (1.579, 0.05),
    "mezz_mh(2)": (2.0, 0.20),       # the paper's own Eq.1-style model is
    "mezz_mh(3)": (2.111, 0.20),     # ~15% below measurement on these rows
    "inter_mezz(3,1,2)": (2.555, 0.08),
}


@pytest.mark.parametrize("name", sorted(TABLE2))
def test_osu_latency_0B_paths(name, mpi, topo):
    src, dst = topo.table1_paths()[name]
    model = mpi.net.mpi_latency(0, topo.route(src, dst))
    paper, tol = TABLE2[name]
    assert abs(model - paper) / paper <= tol, (name, model, paper)


def test_path_structure_table1(topo):
    """Table 1: hop structure of the named paths."""
    paths = topo.table1_paths()
    p = topo.route(*paths["intra_qfdb_sh"])
    assert p.n_intra_qfdb_links == 1 and p.n_mezz_links == 0
    p = topo.route(*paths["mezz_sh"])
    assert p.n_mezz_links == 1 and p.n_intra_qfdb_links == 0
    p = topo.route(*paths["mezz_mh(3)"])
    assert p.n_mezz_links == 1 and p.n_intra_qfdb_links == 2
    p = topo.route(*paths["inter_mezz(3,1,2)"])
    # 3 inter-mezz + 1 intra-mezz 10G links, 2 intra-QFDB 16G links,
    # 5 router traversals (paper: expected 2.615us via 1.17+5*L_ER+6*L_l)
    assert p.n_mezz_links == 4 and p.n_intra_qfdb_links == 2
    assert p.n_routers == 5


def test_router_and_link_latency_derivation(mpi, topo):
    """§6.1.1: single-hop inter-mezz communication latency ~409ns =
    2*L_ER + L_l."""
    p = DEFAULT
    comm = 2 * p.router_latency_us + p.link_latency_us
    assert abs(comm - 0.409) / 0.409 < 0.02


def test_rendezvous_64B(mpi, topo):
    """§6.1.1: 64B intra-QFDB = 5.157us (RDMA path, R5 startup dominates)."""
    path = topo.route(0, DEFAULT.cores_per_mpsoc)
    model = mpi.net.mpi_latency(64, path)
    assert abs(model - 5.157) / 5.157 < 0.05


def test_latency_4MB_matches_dma_rate(mpi, topo):
    """§6.1.1: 4MB intra-QFDB latency 2689.4us -> DMA engine ~12.475 Gb/s."""
    path = topo.route(0, DEFAULT.cores_per_mpsoc)
    model = mpi.net.mpi_latency(4 << 20, path)
    assert abs(model - 2689.4) / 2689.4 < 0.02
    bw = mpi.net.rdma_single_stream_bw_gbps(path)
    assert abs(bw - 12.475) / 12.475 < 0.02


def test_osu_bw_link_utilization(mpi):
    """§6.1.2: 13 Gb/s on a 16G link (81.9%); 6.42 Gb/s on a 10G link
    (64.3%)."""
    bw16 = mpi.osu_bw(4 << 20, 0, DEFAULT.cores_per_mpsoc)
    assert abs(bw16 - 13.0) < 0.1
    assert abs(bw16 / 16.0 - 0.819) < 0.01
    bw10 = mpi.osu_bw(4 << 20, 0, DEFAULT.cores_per_mpsoc *
                      DEFAULT.fpgas_per_qfdb)
    assert abs(bw10 - 6.42) < 0.1
    assert abs(bw10 / 10.0 - 0.643) < 0.01


def test_osu_bibw_deviations(mpi):
    """§6.1.2: bibw ~2x bw with deviations: -5.9% at 1MB, -18.3% at 4KB,
    up to ~40% for small messages."""
    for size, dev in [(1 << 20, 0.059), (4096, 0.183)]:
        bw = mpi.osu_bw(size, 0, 4)
        bibw = mpi.osu_bibw(size, 0, 4)
        measured_dev = 1.0 - bibw / (2 * bw)
        assert abs(measured_dev - dev) < 0.02, size
    small = 1.0 - mpi.osu_bibw(64, 0, 4) / (2 * mpi.osu_bw(64, 0, 4))
    assert 0.3 <= small <= 0.45


def test_ni_raw_latency_constant():
    """§6.1.1: raw packetizer->mailbox one-way ~470ns, and the MPI runtime
    adds ~800ns (conclusion: 'the minimal MPI runtime adds almost 800 ns')."""
    assert abs(DEFAULT.ni_raw_oneway_us - 0.47) < 1e-9
    mpi_overhead = DEFAULT.sw_pingpong_base_us - DEFAULT.ni_raw_oneway_us
    assert 0.6 <= mpi_overhead <= 0.8


def test_cell_efficiency():
    """§4.2: 2 overhead words per 16 payload words -> 16/18."""
    assert abs(DEFAULT.cell_efficiency - 16.0 / 18.0) < 1e-12


# ------------------------------------------------------------- §6.1.4 bcast
def test_bcast_4ranks_1B(mpi):
    """Fig 16/18a: 1B/4 ranks ~1.93us observed; Eq.1 underestimates ~24%."""
    r = mpi.bcast(1, 4)
    assert abs(r.observed_us - 1.93) / 1.93 < 0.10
    assert 0.15 <= r.deviation <= 0.30


def test_bcast_512_schedule(mpi):
    """§6.1.4: 512-rank schedule = 5 mezzanine-class + 2 QFDB-class +
    2 MPSoC-class steps."""
    r = mpi.bcast(1, 512)
    assert r.steps == {"mpsoc": 2, "qfdb": 2, "mezzanine": 5}


def test_bcast_scales_as_expected(mpi):
    """§6.1.4: deviation shrinks with rank count (9.3% at 512/1B; <=12%
    for most large-message points); latency grows monotonically."""
    r512 = mpi.bcast(1, 512)
    assert r512.deviation <= 0.15
    r4k = mpi.bcast(4096, 512)
    assert abs(r4k.deviation) <= 0.12
    lat = [mpi.bcast(1, n).observed_us for n in (4, 16, 64, 256, 512)]
    assert all(b > a for a, b in zip(lat, lat[1:]))


def test_bcast_rdma_sharing_deviation_large_msgs(mpi):
    """§6.1.4: 512KB/4 ranks deviation ~32.4% (RDMA bandwidth sharing)."""
    r = mpi.bcast(512 * 1024, 4)
    assert 0.2 <= r.deviation <= 0.4


def test_bcast_latency_doubles_for_large_messages(mpi):
    """§6.1.3: 'For larger messages though, doubling message sizes also
    resulted in doubling broadcast latency.'"""
    a = mpi.bcast(1 << 20, 64).observed_us
    b = mpi.bcast(2 << 20, 64).observed_us
    assert 1.8 <= b / a <= 2.2


# --------------------------------------------------------- §6.1.3/5 allreduce
def test_allreduce_sw_4ranks(mpi):
    """Fig 17: 4B/4 ranks = 5.34us; 64B/4 ranks = 33.62us (rendez-vous +
    engine sharing)."""
    assert abs(mpi.allreduce_sw(4, 4) - 5.34) / 5.34 < 0.15
    assert abs(mpi.allreduce_sw(64, 4) - 33.62) / 33.62 < 0.20


def test_allreduce_sw_scaling(mpi1):
    """§6.1.5: software allreduce 256B: 39.7us @16 ranks, 76.9us @128 ranks
    (nearly doubles); hardware stays nearly flat."""
    s16 = mpi1.allreduce_sw(256, 16)
    s128 = mpi1.allreduce_sw(256, 128)
    assert abs(s16 - 39.7) / 39.7 < 0.20
    assert abs(s128 - 76.9) / 76.9 < 0.15
    assert 1.6 <= s128 / s16 <= 2.2


def test_accel_allreduce_anchors():
    """Fig 19: 16 ranks: 256B=6.79us, 512B=13.38us, 1KB=26.11us;
    128 ranks/256B=9.61us."""
    assert abs(accel_allreduce_latency(256, 16) - 6.79) < 0.01
    assert abs(accel_allreduce_latency(512, 16) - 13.38) / 13.38 < 0.05
    assert abs(accel_allreduce_latency(1024, 16) - 26.11) / 26.11 < 0.05
    assert abs(accel_allreduce_latency(256, 128) - 9.61) < 0.01


def test_accel_allreduce_improvement(mpi1):
    """§6.1.5 / abstract: accelerator reduces allreduce latency by up to
    83.4/86.2/87.1/87.9% for 16/32/64/128 ranks ('up to 88%')."""
    paper = {16: 0.834, 32: 0.862, 64: 0.871, 128: 0.879}
    for n, target in paper.items():
        best = max(1 - accel_allreduce_latency(s, n) / mpi1.allreduce_sw(s, n)
                   for s in (4, 64, 256, 1024, 4096))
        assert abs(best - target) < 0.04, (n, best, target)
    # monotone in rank count, and the hw latency is nearly flat vs ranks
    hw16, hw128 = accel_allreduce_latency(256, 16), accel_allreduce_latency(256, 128)
    assert hw128 / hw16 < 1.5


def test_accel_applicability_rules():
    """§4.7 constraints."""
    assert accel_applicable(256, 16)
    assert not accel_applicable(256, 6)        # not multiple of 4
    assert not accel_applicable(8192, 16)      # > 4KB vector
    assert not accel_applicable(256, 2048)     # > 1024 ranks
    with pytest.raises(ValueError):
        accel_allreduce_latency(256, 6)


def test_accel_latency_blocks_linear():
    """§6.1.5: engine triggered once per 256B block -> linear in blocks."""
    base = accel_allreduce_latency(256, 64)
    for k in (2, 4, 8, 16):
        assert abs(accel_allreduce_latency(256 * k, 64) / base - k) < 1e-9


# ------------------------------------------------------------------- Table 3
def test_apps_table3():
    """Table 3 parallel efficiencies: 512-rank cells are calibrated (==),
    2-rank cells are predictions (+-7 points)."""
    from repro.core.exanet.apps import table3, PAPER_TABLE3
    model = table3()
    for app, modes in PAPER_TABLE3.items():
        for mode, pts in modes.items():
            assert abs(model[app][mode][512] - pts[512]) <= 0.5, (app, mode)
            assert abs(model[app][mode][2] - pts[2]) <= 7.0, (app, mode)


def test_apps_efficiency_at_least_69pct():
    """Abstract: 'for all these tests, parallelization efficiency is at
    least 69%'."""
    from repro.core.exanet.apps import ALL_APPS
    for name, factory in ALL_APPS.items():
        m = factory()
        for n in (2, 8, 64, 512):
            assert m.weak(n)["efficiency"] >= 0.685, (name, "weak", n)
            assert m.strong(n)["efficiency"] >= 0.685, (name, "strong", n)


def test_hpcg_comm_fraction():
    """§6.2: HPCG comm share 0.7% @2 ranks -> 22.4% @512 ranks (strong)."""
    from repro.core.exanet.apps import hpcg
    m = hpcg()
    assert m.strong(512)["comm_fraction"] == pytest.approx(0.224, abs=0.03)
    assert m.strong(2)["comm_fraction"] < 0.02


def test_memory_contention_lammps_weak():
    """§6.2: LAMMPS weak efficiency 96% at 2 ranks and 89% at 4 ranks —
    the single DDR channel is the bottleneck once all 4 cores are active."""
    from repro.core.exanet.apps import f_mem
    assert 1 / f_mem(2) == pytest.approx(0.96, abs=0.01)
    assert 1 / f_mem(4) == pytest.approx(0.89, abs=0.01)


def test_apps_halo_congestion_is_simulated_not_calibrated():
    """The Program-IR acceptance bar: Table 3 comes from *executing* 512
    concurrent per-rank halo programs on the event engine, and the
    per-(app, mode) MPI-stack residual beta that replaced the retired
    closed-form alpha never exceeds it (the simulation, not the constant,
    now carries the congestion)."""
    from repro.core.exanet.apps import ALL_APPS
    for name, factory in ALL_APPS.items():
        m = factory()
        for mode in ("weak", "strong"):
            sim = m._simulate(mode, 512)
            # every rank's 6 halo faces really executed on the engine
            assert sim.n_sends == 512 * 6, (name, mode)
            assert sim.n_collectives == m.allreduce_per_iter, (name, mode)
            # simulated concurrent comm dwarfs the isolated-message sum
            closed = m._comm_closed_us(m._local_points(mode, 512), 512)
            assert sim.comm_us > closed, (name, mode)
            e = m._eval(mode, 512)
            assert 0.0 <= e["beta"] <= e["alpha_retired"], (name, mode, e)


# -------------------------------------------------------------- §5.3 overlay
def test_ip_overlay_throughput():
    """Fig 13: large UDP 4.7 Gb/s over the overlay vs 1.3 Gb/s baseline."""
    from repro.core.exanet.ip_overlay import (baseline_throughput_gbps,
                                              overlay_throughput_gbps)
    ov = overlay_throughput_gbps(65507)   # max UDP datagram
    base = baseline_throughput_gbps(65507)
    assert abs(ov - 4.7) / 4.7 < 0.15
    assert abs(base - 1.3) / 1.3 < 0.25
    assert ov > 3 * base


def test_ip_overlay_rtt():
    """§5.3: RTT ~90us polling; ~2.2ms with adaptive sleep."""
    from repro.core.exanet.ip_overlay import overlay_rtt
    assert abs(overlay_rtt(mode="poll") - 90.0) / 90.0 < 0.25
    assert overlay_rtt(mode="sleep") > 1500.0


# ------------------------------------------------------------------ §7 matmul
def test_matmul_accel_constants():
    """§7: 128x128 tile @300MHz, 1024 flop/cycle -> 307 GFLOP/s peak;
    measured 275 GFLOP/s (89.5% of peak); 17 GFLOPS/W."""
    p = DEFAULT
    peak = p.mm_clock_mhz * 1e6 * p.mm_flops_per_cycle / 1e9
    assert abs(peak - 307.2) < 0.1
    assert 0.85 <= p.mm_measured_gflops / peak <= 0.92
    assert abs(p.mm_measured_gflops / p.mm_dynamic_watts - p.mm_gflops_per_watt) < 0.5
