"""Test-session guards.

The dry-run's 512-device flag must NEVER leak into the test session: smoke
tests and benches see the real single device (multi-device tests spawn
subprocesses with their own XLA_FLAGS).
"""

import os


def pytest_configure(config):
    flags = os.environ.get("XLA_FLAGS", "")
    assert "xla_force_host_platform_device_count" not in flags, (
        "tests must run without the dry-run device-count flag; "
        "launch/dryrun.py is the only entry point that sets it")
