"""Test-session guards + CI known-failure handling.

The dry-run's 512-device flag must NEVER leak into the test session: smoke
tests and benches see the real single device (multi-device tests spawn
subprocesses with their own XLA_FLAGS).

With REPRO_CI_XFAIL=1 (set by .github/workflows/ci.yml), the seed's known
failures listed in tests/known_failures.txt are marked xfail(strict=False)
so the CI job is green while new regressions stay visible. Local runs are
unaffected.
"""

import os

import pytest


def pytest_configure(config):
    flags = os.environ.get("XLA_FLAGS", "")
    assert "xla_force_host_platform_device_count" not in flags, (
        "tests must run without the dry-run device-count flag; "
        "launch/dryrun.py is the only entry point that sets it")


def _known_failures():
    path = os.path.join(os.path.dirname(__file__), "known_failures.txt")
    if not os.path.exists(path):
        return set()
    with open(path) as f:
        return {line.strip() for line in f
                if line.strip() and not line.startswith("#")}


def pytest_collection_modifyitems(config, items):
    if os.environ.get("REPRO_CI_XFAIL") != "1":
        return
    known = _known_failures()
    if not known:
        return
    mark = pytest.mark.xfail(strict=False,
                             reason="known seed failure (known_failures.txt)")
    for item in items:
        # nodeid is tests/<file>::<test>[param]; match on the unparametrized id
        base = item.nodeid.split("[", 1)[0]
        if base in known:
            item.add_marker(mark)
