"""Head padding (§Perf optimization): bit-exact vs the unpadded arch, with
zero gradients into the padding — so the optimized sharding preserves the
published architecture exactly."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import reduced
from repro.configs import get
from repro.models import build_model


def _graft(a, b):
    if a.shape == b.shape:
        return a
    out = jnp.zeros_like(b)
    sl = tuple(slice(0, s) for s in a.shape)
    return out.at[sl].set(a)


def test_pad_heads_exact_loss_and_zero_pad_grads():
    cfg = reduced(get("starcoder2-7b"), n_heads=3, n_kv_heads=1,
                  head_dim=16, d_model=48)
    cfgp = dataclasses.replace(cfg, pad_heads_to=4)
    m0, m1 = build_model(cfg), build_model(cfgp)
    p0 = m0.init(jax.random.PRNGKey(0))
    p1 = jax.tree_util.tree_map(_graft, p0, m1.init(jax.random.PRNGKey(0)))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                          cfg.vocab_size)}
    l0, l1 = m0.loss_fn(p0, batch), m1.loss_fn(p1, batch)
    assert abs(float(l0) - float(l1)) < 1e-4
    g1 = jax.grad(m1.loss_fn)(p1, batch)
    wq = g1["dense_stack"]["attn"]["wq"]
    wo = g1["dense_stack"]["attn"]["wo"]
    assert float(jnp.abs(wq[:, :, 3:, :]).max()) == 0.0
    assert float(jnp.abs(wo[:, 3:]).max()) == 0.0


def test_pad_heads_decode_exact():
    cfg = reduced(get("starcoder2-7b"), n_heads=3, n_kv_heads=1,
                  head_dim=16, d_model=48)
    cfgp = dataclasses.replace(cfg, pad_heads_to=4)
    m0, m1 = build_model(cfg), build_model(cfgp)
    p0 = m0.init(jax.random.PRNGKey(0))
    p1 = jax.tree_util.tree_map(_graft, p0, m1.init(jax.random.PRNGKey(0)))
    c0, c1 = m0.init_cache(2, 16), m1.init_cache(2, 16)
    b = {"token": jnp.array([3, 5]), "pos": jnp.array(4, jnp.int32)}
    lg0, _ = m0.decode_step(p0, c0, b)
    lg1, _ = m1.decode_step(p1, c1, b)
    np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1), rtol=1e-4,
                               atol=1e-4)
