"""MachineModel + CollectivePlanner: schedule selection by simulated cost.

Pins the acceptance criteria of the planner layer (DESIGN.md §3.5):

* the planner reproduces the paper's crossovers *from cost alone* — on the
  ExanetMachine it picks the §4.7 accelerator below the Fig. 19 sw/accel
  crossover and software allreduce above it, with no hard-coded crossover
  size anywhere in model or test;
* it picks the one-shot (eager-analog) schedule below the derived eager
  threshold;
* plans are memoized and deterministic;
* the layering rules hold (planner/machines never import jax);
* the CommPolicy facade keeps its historical numbers.
"""

import inspect
import math

import pytest

from repro.core.comm import CommPolicy
from repro.core.exanet import ExanetMPI
from repro.core.exanet.allreduce_accel import (accel_allreduce_latency,
                                               accel_cost_us)
from repro.core.machine import ExanetMachine, MachineModel, TpuMachine
from repro.core.planner import CollectivePlanner

SW_ALGOS = ("recursive_doubling", "ring", "rabenseifner", "oneshot")


@pytest.fixture(scope="module")
def mpi():
    return ExanetMPI(ranks_per_mpsoc=1)


@pytest.fixture(scope="module")
def exa_planner(mpi):
    return mpi.planner


# ----------------------------------------------------------------- protocol
def test_machines_satisfy_protocol(mpi):
    assert isinstance(TpuMachine(), MachineModel)
    assert isinstance(ExanetMachine(mpi=mpi), MachineModel)


def test_planner_and_machines_never_import_jax():
    """Layer rule (DESIGN.md §3.5): the planning layer is pure Python so it
    can run at trace time; only the executors (grad_sync, collectives)
    touch jax."""
    import repro.core.machine as machine
    import repro.core.planner as planner
    for mod in (machine, planner):
        assert "import jax" not in inspect.getsource(mod), mod.__name__


# ------------------------------------------------- Fig. 19 sw/accel crossover
def _true_costs_us(mpi, size, nranks):
    """Ground truth straight from the wrappers the paper tests pin."""
    sw = min(mpi.allreduce(size, nranks, a) for a in SW_ALGOS)
    hw = accel_cost_us(size, nranks, mpi.p)
    return sw, hw


def _synth_truth_us(mpi, planner, size, nranks):
    """Simulated truth of the cached synthesized candidate (DESIGN.md
    §2.8), through the same allreduce wrapper — None when the committed
    winner cache has no entry for this cell."""
    from repro.core.synth.search import WinnerCache
    machine = planner.machine
    entry = WinnerCache.default().get(machine.name, "allreduce", nranks,
                                      size, machine.placement)
    if entry is None:
        return None
    return mpi.allreduce(size, nranks, WinnerCache.default()
                         .schedule(entry).name)


@pytest.mark.parametrize("nranks", [64, 128])
def test_planner_choice_matches_simulated_truth(mpi, exa_planner, nranks):
    """At every size the plan is the argmin of the event-simulated software
    cost vs the calibrated accelerator cost (vs the cached synthesized
    term where one exists) — selection is cost, not a threshold."""
    for size in (256, 1024, 4096, 8192, 16384, 65536):
        plan = exa_planner.plan("allreduce", size, (nranks,))
        sw, hw = _true_costs_us(mpi, size, nranks)
        syn = _synth_truth_us(mpi, exa_planner, size, nranks)
        truth = min(sw, hw) if syn is None else min(sw, hw, syn)
        accel_wins = hw < sw and (syn is None or hw < syn)
        assert (plan.schedule == "accel") == accel_wins, \
            (size, plan, sw, hw, syn)
        synth_wins = syn is not None and syn < min(sw, hw)
        assert plan.provenance == ("synthesized" if synth_wins else "menu")
        assert plan.cost_s * 1e6 == pytest.approx(truth, rel=1e-9)


@pytest.mark.parametrize("nranks", [64, 128])
def test_fig19_crossover_reproduced_from_cost(mpi, exa_planner, nranks):
    """Scanning vector sizes, the plan flips from accelerator to software
    exactly once, and both regimes occur — the paper's Fig. 19 crossover
    (and the runtime's 4 KB fallback rule) re-derived from cost alone."""
    sizes = [256 << i for i in range(9)]  # 256 B .. 64 KB
    choices = [exa_planner.plan("allreduce", s, (nranks,)).schedule
               for s in sizes]
    is_accel = [c == "accel" for c in choices]
    assert is_accel[0], "accelerator must win at the smallest vectors (§6.2)"
    assert not is_accel[-1], "software must win at large vectors (§6.1.5)"
    flips = sum(1 for a, b in zip(is_accel, is_accel[1:]) if a != b)
    assert flips == 1, f"choice must flip exactly once: {choices}"
    # the accelerator regime delivers the paper's headline latency win at
    # the smallest size (§6.2: up to 88% below the crossover)
    sw, hw = _true_costs_us(mpi, 256, nranks)
    assert hw < 0.25 * sw


def test_auto_allreduce_dispatches_on_plan(mpi, exa_planner):
    """algo="auto" returns exactly the number of whichever executor the
    plan chose (accelerator closed form or simulated software schedule)."""
    for size, nranks in ((256, 64), (1024, 128), (16384, 128), (65536, 64)):
        got = mpi.allreduce(size, nranks, "auto")
        plan = exa_planner.plan("allreduce", size, (nranks,))
        if plan.schedule == "accel":
            assert got == accel_cost_us(size, nranks, mpi.p)
            if size <= mpi.p.ar_accel_max_vector_bytes:
                assert got == accel_allreduce_latency(size, nranks, mpi.p)
        else:
            assert got == mpi.allreduce(size, nranks, plan.schedule)


# -------------------------------------------------------- eager threshold
@pytest.mark.parametrize("p", [8, 64])
def test_plan_is_oneshot_below_derived_eager_threshold(p):
    """Below the derived eager threshold the planner picks the one-shot
    (single-alpha) schedule; above it, a bandwidth-optimal schedule — the
    32 B eager/rendez-vous switch of §5.2.1 re-derived, with the threshold
    itself coming from the cost model, not a constant."""
    planner = CommPolicy().planner
    thr = planner.eager_threshold_bytes(p)
    assert 1 < thr < 1 << 31
    below = planner.plan("allreduce", max(1, thr // 2), (p,))
    above = planner.plan("allreduce", 4 * thr, (p,))
    assert below.schedule == "oneshot", below
    assert above.schedule != "oneshot", above


# ----------------------------------------------------------- plan caching
def test_plan_cache_hits_and_determinism():
    pol = CommPolicy()
    a = pol.planner.plan("grad_sync", 1 << 20, (16, 4))
    misses = pol.planner.cache_info()["misses"]
    b = pol.planner.plan("grad_sync", 1 << 20, (16, 4))
    assert b is a                                      # memoized value object
    assert pol.planner.cache_info()["misses"] == misses
    assert pol.planner.cache_info()["hits"] >= 1
    # a fresh planner derives the identical plan (pure function of inputs)
    c = CommPolicy().planner.plan("grad_sync", 1 << 20, (16, 4))
    assert (c.schedule, c.cost_s, c.costs) == (a.schedule, a.cost_s, a.costs)


# ------------------------------------------------------- grad-sync planning
def test_grad_sync_plan_regimes():
    """Tiny buckets stay flat (alpha-dominated); huge buckets go
    hierarchical/compressed (the cross-pod hop must carry 1/k of the
    bytes, DESIGN.md §5); lossy compression only when explicitly allowed."""
    from repro.parallel.grad_sync import plan_bucket_strategy
    pol = CommPolicy()
    assert plan_bucket_strategy(pol, 256, (16, 4)) == "flat"
    # exact-only planning (the default) never sees the int8 candidate
    assert plan_bucket_strategy(pol, 64 << 20, (16, 4)) == "hierarchical"
    assert "compressed" not in [k for k, _ in pol.plan_bucket(
        64 << 20, 16, 4).costs]
    # lossy is opt-in, and then wins the big cross-pod buckets
    lossy = plan_bucket_strategy(pol, 64 << 20, (16, 4), allow_lossy=True)
    assert lossy == "compressed"
    # single DP axis: nothing to stack, flat is the only candidate
    assert plan_bucket_strategy(pol, 64 << 20, (16,)) == "flat"
    # the chosen plan must actually be predicted cheaper than always-flat
    plan = pol.plan_bucket(64 << 20, 16, 4)
    assert plan.cost_s < plan.cost_of("flat") / 2
    # the compressed cost model mirrors the executor: int16 wire (2x) only
    # while the inter axis is narrow enough for exact accumulation
    wide = CommPolicy().planner.plan("grad_sync", 64 << 20, (2, 512),
                                     allow_lossy=True)
    assert wide.cost_of("compressed") >= wide.cost_of("hierarchical")


# ------------------------------------------------------ CommPolicy facade
def test_commpolicy_facade_numbers_unchanged():
    """The facade derives its numbers from the machine, but they must be
    bit-identical to the historical closed forms."""
    pol = CommPolicy()
    for p in (2, 4, 16, 256):
        # independent re-derivation of the historical bisection
        lo, hi = 1, 1 << 32
        while lo < hi:
            mid = (lo + hi) // 2
            oneshot = pol.alpha_s + (p - 1) * mid / pol.ici_bw
            ring = 2 * (p - 1) * pol.alpha_s + \
                2 * (p - 1) / p * mid / pol.ici_bw
            if oneshot <= ring:
                lo = mid + 1
            else:
                hi = mid
        assert pol.eager_threshold_bytes(p) == lo
        if p > 1:
            alpha_total = 2 * (p - 1) * pol.alpha_s
            wire_per_byte = 2 * (p - 1) / p / pol.ici_bw
            assert pol.bucket_bytes(p) == \
                int(alpha_total / pol.alpha_amortization / wire_per_byte)


def test_exanet_machine_analytic_vs_sim_fidelity(mpi):
    """Both fidelities rank one-shot vs ring the same way at the extremes,
    and sim fidelity equals the wrapper numbers exactly."""
    machine = ExanetMachine(mpi=mpi)
    from repro.core.exanet.schedules import (OneShotAllreduce,
                                             RecursiveDoublingAllreduce)
    sched = RecursiveDoublingAllreduce()
    sim = machine.cost_s(sched, 16, 4096, fidelity="sim")
    assert sim * 1e6 == pytest.approx(
        mpi.allreduce(4096, 16, "recursive_doubling"), rel=1e-12)
    for fidelity in ("analytic", "sim"):
        tiny_one = machine.cost_s(OneShotAllreduce(), 8, 1, fidelity=fidelity)
        tiny_rd = machine.cost_s(sched, 8, 1, fidelity=fidelity)
        assert tiny_one <= tiny_rd * 1.5  # one alpha vs log2(8) alphas
