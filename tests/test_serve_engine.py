"""ServeEngine unit tests (seed-untouched until PR 7) on a deterministic
fake model: greedy next token == (last fed token + 1) mod vocab, so every
request's output is a predictable counting sequence and slot bookkeeping
bugs (stale caches, unreset positions, clobbered results) surface as
wrong tokens rather than flaky statistics."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.engine import ServeEngine

VOCAB = 23


class EchoModel:
    """decode_step contract double: cache records fed tokens per (slot,
    pos); logits put all mass on (token + 1) % VOCAB."""

    def init(self, key):
        return {}

    def init_cache(self, slots, window):
        return {"toks": jnp.full((slots, window), -1, jnp.int32)}

    def decode_step(self, params, cache, batch):
        tok = batch["token"].astype(jnp.int32)
        pos = batch["pos"].astype(jnp.int32)
        slots = tok.shape[0]
        toks = cache["toks"].at[jnp.arange(slots), pos].set(tok)
        logits = jax.nn.one_hot((tok + 1) % VOCAB, VOCAB,
                                dtype=jnp.float32)[:, None, :]
        return logits, {"toks": toks}


def make_engine(slots=2, window=32) -> ServeEngine:
    model = EchoModel()
    return ServeEngine(model, model.init(None), slots=slots, window=window)


def expect(prompt, max_new, eos_id=None):
    out, tok = [], prompt[-1]
    for _ in range(max_new):
        tok = (tok + 1) % VOCAB
        out.append(tok)
        if eos_id is not None and tok == eos_id:
            break
    return out


def test_generation_is_deterministic_counting():
    eng = make_engine()
    rid = eng.submit([3, 4, 5], max_new_tokens=4)
    eng.run_until_idle()
    assert eng.result(rid) == [6, 7, 8, 9]


def test_eos_stops_early_and_recycles_slot():
    eng = make_engine(slots=1)
    rid = eng.submit([7], max_new_tokens=10, eos_id=9)
    eng.run_until_idle()
    assert eng.result(rid) == [8, 9]
    # the freed slot serves a new request with a clean cache row
    rid2 = eng.submit([1], max_new_tokens=3)
    eng.run_until_idle()
    assert eng.result(rid2) == [2, 3, 4]
    assert eng.active == [None]


def test_pos_resets_on_recycle():
    eng = make_engine(slots=1, window=16)
    eng.submit([5, 6], max_new_tokens=2)
    eng.run_until_idle()
    assert eng.pos[0] == 0
    rid = eng.submit([10, 11, 12], max_new_tokens=1)
    eng.run_until_idle()
    assert eng.result(rid) == [13]
    # the recycled slot's cache rows were rewritten from position 0:
    # prompt tokens land at pos 0..2 (the generated token is fed only if
    # the request continues, which a 1-token request does not)
    row = np.asarray(eng.cache["toks"][0][:3])
    assert row.tolist() == [10, 11, 12]


def test_queue_admission_is_fifo():
    eng = make_engine(slots=1)
    rids = [eng.submit([i], max_new_tokens=2) for i in range(4)]
    eng.run_until_idle()
    steps = eng.request_steps()
    done_order = sorted(rids, key=lambda r: steps[r][1])
    assert done_order == rids          # 1 slot => strictly FIFO service
    for i, rid in enumerate(rids):
        assert eng.result(rid) == expect([i], 2)


def test_results_survive_slot_reuse():
    eng = make_engine(slots=2)
    rids = [eng.submit([i], max_new_tokens=3) for i in range(7)]
    eng.run_until_idle()
    for i, rid in enumerate(rids):
        assert eng.result(rid) == expect([i], 3), f"request {i} clobbered"


def test_batched_prefill_handles_mixed_prompt_lengths():
    # two slots admitted in the same step with different prompt lengths:
    # the shorter slot must not advance during the longer slot's tail
    eng = make_engine(slots=2)
    ra = eng.submit([1, 2, 3, 4, 5], max_new_tokens=2)
    rb = eng.submit([9], max_new_tokens=2)
    eng.run_until_idle()
    assert eng.result(ra) == [6, 7]
    assert eng.result(rb) == [10, 11]


def test_concurrent_slots_do_not_cross_talk():
    eng = make_engine(slots=3)
    rids = [eng.submit([p], max_new_tokens=5) for p in (0, 10, 20)]
    eng.run_until_idle()
    assert eng.result(rids[0]) == [1, 2, 3, 4, 5]
    assert eng.result(rids[1]) == [11, 12, 13, 14, 15]
    assert eng.result(rids[2]) == [21, 22, 0, 1, 2]  # wraps mod VOCAB


def test_empty_prompt_rejected():
    eng = make_engine()
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([], max_new_tokens=2)


def test_request_steps_monotone():
    eng = make_engine(slots=2)
    rids = [eng.submit([i], max_new_tokens=2) for i in range(3)]
    eng.run_until_idle()
    for rid in rids:
        s, d = eng.request_steps()[rid]
        assert d > s >= 0
