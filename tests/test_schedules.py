"""The schedule/executor split: pluggable collectives on the event engine.

Covers (a) wrapper equivalence — the historical entry points are thin
wrappers over schedules and keep their numbers; (b) the latency ordering of
ring/Rabenseifner vs recursive doubling at large messages; (c) wire-byte
structure of each schedule; (d) the §4.7 accelerator as a first-class
schedule; (e) the engine's occupancy/trace bookkeeping; (f) CommPolicy's
alpha-beta costs derived from the same schedules.
"""

import pytest

from repro.core.exanet import ExanetMPI, alpha_beta_cost_s
from repro.core.exanet.schedules import (AllGather, AllToAll, Barrier,
                                         BinomialBroadcast, GatherBinomial,
                                         HierarchicalAccelAllreduce,
                                         RabenseifnerAllreduce,
                                         RecursiveDoublingAllreduce,
                                         RingAllreduce, ScatterBinomial)


@pytest.fixture(scope="module")
def mpi():
    return ExanetMPI()


# ------------------------------------------------------ wrapper equivalence
def test_allreduce_sw_is_recursive_doubling_schedule(mpi):
    for size, n in [(4, 4), (256, 16), (4096, 8)]:
        assert mpi.allreduce_sw(size, n) == \
            mpi.allreduce(size, n, "recursive_doubling")


def test_bcast_wrapper_runs_binomial_schedule(mpi):
    r = mpi.bcast(1, 512)
    # §6.1.4 schedule decomposition survives the executor refactor
    assert r.steps == {"mpsoc": 2, "qfdb": 2, "mezzanine": 5}
    res = mpi.run_schedule(BinomialBroadcast(), 1, 512)
    assert res.latency_us == pytest.approx(r.observed_us, rel=1e-12)
    assert res.n_rounds == 9


# --------------------------------------------------------- latency ordering
@pytest.mark.parametrize("nranks", [8, 64])
def test_ring_beats_recursive_doubling_at_large_messages(mpi, nranks):
    """Ring moves 2(N-1)/N * size per rank vs recursive doubling's
    log2(N) * size — at large sizes bandwidth wins over round count."""
    size = 1 << 20
    rd = mpi.allreduce(size, nranks, "recursive_doubling")
    ring = mpi.allreduce(size, nranks, "ring")
    assert ring < rd, (ring, rd)


@pytest.mark.parametrize("nranks", [8, 16, 64])
def test_rabenseifner_beats_recursive_doubling_at_large_messages(mpi, nranks):
    """Rabenseifner has the same wire bytes as ring but only 2 log2(N)
    rounds of fixed costs — it dominates recursive doubling at large sizes."""
    size = 1 << 20
    rd = mpi.allreduce(size, nranks, "recursive_doubling")
    rab = mpi.allreduce(size, nranks, "rabenseifner")
    assert rab < rd, (rab, rd)


def test_recursive_doubling_wins_small_messages(mpi):
    """At latency-dominated sizes the ring's 2(N-1) rounds lose to the
    log2(N) rounds of recursive doubling."""
    assert mpi.allreduce(4, 64, "recursive_doubling") < \
        mpi.allreduce(4, 64, "ring")


# --------------------------------------------------------- round structure
def _per_rank_send_bytes(sched, n, size, rank=0):
    return sum(op[2] for rnd in sched.rounds(n, size)
               for op in rnd.sends if op[0] == rank)


def test_schedule_wire_bytes(mpi):
    n, size = 16, 1 << 16
    # recursive doubling: log2(n) full-size sends per rank
    assert _per_rank_send_bytes(RecursiveDoublingAllreduce(), n, size) == \
        4 * size
    # ring + Rabenseifner: bandwidth-optimal 2(n-1)/n * size per rank
    opt = 2 * (n - 1) * size // n
    assert _per_rank_send_bytes(RingAllreduce(), n, size) == opt
    assert _per_rank_send_bytes(RabenseifnerAllreduce(), n, size) == opt


def test_round_counts():
    n = 16
    assert sum(1 for _ in RecursiveDoublingAllreduce().rounds(n, 64)) == 4
    assert sum(1 for _ in RingAllreduce().rounds(n, 64)) == 2 * (n - 1)
    assert sum(1 for _ in RabenseifnerAllreduce().rounds(n, 64)) == 8
    assert sum(1 for _ in BinomialBroadcast().rounds(n, 64)) == 4
    assert sum(1 for _ in Barrier().rounds(n, 0)) == 4
    assert sum(1 for _ in AllToAll().rounds(n, 64)) == n - 1


def test_binomial_reaches_every_rank():
    """After the broadcast rounds every rank has received exactly once."""
    reached = {0}
    for rnd in BinomialBroadcast().rounds(64, 1):
        for (s, d, _) in rnd.sends:
            assert s in reached and d not in reached, (s, d)
            reached.add(d)
    assert reached == set(range(64))


def test_scatter_gather_mirror():
    """Gather is the arrow-reversed scatter with the same block sizes."""
    sc = [(s, d, nb) for r in ScatterBinomial().rounds(16, 8)
          for (s, d, nb) in r.sends]
    ga = [(d, s, nb) for r in GatherBinomial().rounds(16, 8)
          for (s, d, nb) in r.sends]
    assert sorted(sc) == sorted(ga)


# ---------------------------------------------------- new collectives run
def test_collective_zoo_executes(mpi):
    for n in (4, 16):
        assert mpi.allgather(256, n) > 0
        assert mpi.alltoall(256, n) > 0
        assert mpi.barrier(n) > 0
        assert mpi.scatter(256, n) > 0
        assert mpi.gather(256, n) > 0
    # more ranks -> more rounds -> more time
    assert mpi.barrier(64) > mpi.barrier(4)
    assert mpi.allgather(256, 64) > mpi.allgather(256, 4)


# --------------------------------------------------------- accel schedule
def test_accel_schedule_structure():
    sched = HierarchicalAccelAllreduce()
    labels = [r.label for r in sched.rounds(64, 256)]
    assert labels[0] == "client_reduce"
    assert labels[-1] == "client_broadcast"
    assert labels.count("server_exchange") == 4  # log2(16 QFDBs)
    # level 0 fans 3 clients into each of the 16 servers
    first = next(iter(sched.rounds(64, 256)))
    assert len(first.sends) == 48
    assert all(d % 4 == 0 for (_, d, _) in first.sends)


def test_accel_schedule_nonpow2_qfdbs_matches_closed_form():
    """12 ranks = 3 QFDBs: fold-in pre-step + floor(log2(3)) = 1 exchange
    level, matching the historical int(log2(n_qfdbs)) closed form."""
    from repro.core.exanet.allreduce_accel import (accel_allreduce_latency,
                                                   accel_server_levels)
    assert accel_server_levels(12) == 1
    p = ExanetMPI().p
    assert accel_allreduce_latency(256, 12) == pytest.approx(
        p.ar_accel_fixed_us + p.ar_accel_level_us)


# ------------------------------------------------------- engine bookkeeping
def test_engine_trace_and_utilization():
    mpi = ExanetMPI(trace=True)
    r = mpi.bcast(1, 16)
    trace = mpi.net.trace
    assert len(trace) == 15  # binomial tree: n-1 sends
    assert all(ev.transport == "eager" for ev in trace)
    assert all(ev.t_complete >= ev.t_issue for ev in trace)
    util = mpi.net.engine.utilization(r.observed_us)
    assert util and all(0.0 <= u <= 1.0 for u in util.values())


def test_engine_path_table_reused():
    mpi = ExanetMPI()
    mpi.allreduce_sw(256, 16)
    n_paths = len(mpi.net.engine.path_table)
    assert n_paths > 0
    mpi.allreduce_sw(256, 16)  # reset() keeps the path table
    assert len(mpi.net.engine.path_table) == n_paths


# ------------------------------------------------- CommPolicy derivation
def test_commpolicy_ring_cost_derived_from_schedule():
    from repro.core.comm import CommPolicy
    pol = CommPolicy()
    for p in (4, 16, 64):
        n = p * 4096  # divisible so chunking is exact
        closed = pol.ring_allreduce_s(n, p, pol.ici_bw, pol.alpha_s)
        derived = pol.schedule_allreduce_s(n, p, pol.ici_bw, pol.alpha_s,
                                           algo="ring")
        assert derived == pytest.approx(closed, rel=1e-12)


def test_alpha_beta_cost_orders_algorithms():
    """The hardware-free cost model reproduces the large-message ordering:
    bandwidth-optimal schedules beat recursive doubling."""
    alpha, bw = 2e-6, 1e9
    n, p = 64 << 20, 16
    rd = alpha_beta_cost_s(RecursiveDoublingAllreduce(), p, n,
                           alpha_s=alpha, bw_bytes_per_s=bw)
    ring = alpha_beta_cost_s(RingAllreduce(), p, n,
                             alpha_s=alpha, bw_bytes_per_s=bw)
    rab = alpha_beta_cost_s(RabenseifnerAllreduce(), p, n,
                            alpha_s=alpha, bw_bytes_per_s=bw)
    assert ring < rd and rab < rd
