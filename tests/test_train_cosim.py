"""Overlap-aware train-step co-simulation (DESIGN.md §2.9).

Pins the ISSUE-9 contract: overlap is *emergent* from the event engine
(strictly below the serialized sum, at or above the critical-path lower
bound), the batched candidate-population lane agrees with the
per-candidate lane to 1e-9, the same emission shows overlap through the
TPU machine's analytic walk, and the simulated hillclimb flips at least
one decision against the analytic ``CommPolicy`` baseline.  Also covers
the nonblocking-collective seam's error paths and the memoized
``grad_sync.cost_sync_program_s``.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.machine import ExanetMachine, TpuMachine
from repro.core.planner import CollectivePlanner
from repro.core.program import (Collective, Compute, Program, ProgramError,
                                Wait)
from repro.train.cosim import SyncCandidate, TrainSim, TrainStepSpec

SPEC = TrainStepSpec(arch="exanest-lm-100m", nranks=8, seq_len=256,
                     rank_gflops=50.0)


@pytest.fixture(scope="module")
def sim():
    return TrainSim(SPEC)


# ----------------------------------------------------------- candidates
def test_candidate_rejects_auto_algo():
    with pytest.raises(ValueError, match="explicit algorithms"):
        SyncCandidate(4, "auto")


def test_candidate_rejects_split_mismatch():
    with pytest.raises(ValueError, match="split fractions"):
        SyncCandidate(4, "rabenseifner", split=(0.5, 0.5))


def test_candidate_fractions_normalize():
    c = SyncCandidate(2, "rabenseifner", split=(3.0, 1.0))
    assert c.fractions() == (0.75, 0.25)
    assert sum(SyncCandidate(5, "rabenseifner").fractions()) == \
        pytest.approx(1.0)


def test_family_saturates_depth_and_ignores_split():
    a = SyncCandidate(4, "rabenseifner", 2)
    b = SyncCandidate(4, "rabenseifner", 2, split=(0.4, 0.3, 0.2, 0.1))
    assert a.family() == b.family()
    # depth beyond the bucket count cannot change the op sequence
    assert SyncCandidate(2, "rabenseifner", 7).family() == \
        SyncCandidate(2, "rabenseifner", 2).family()
    assert SyncCandidate(2, "rabenseifner", 0).family() != \
        SyncCandidate(2, "rabenseifner", 1).family()


# ------------------------------------------------------------- emission
def test_emit_structure(sim):
    blocking = sim.emit_step(SyncCandidate(3, "rabenseifner", 0))
    row = blocking.rank_ops[0]
    assert all(r == row for r in blocking.rank_ops)
    colls = [op for op in row if isinstance(op, Collective)]
    assert len(colls) == 3 and all(c.handle is None for c in colls)
    assert not any(isinstance(op, Wait) for op in row)
    assert sum(c.nbytes for c in colls) == pytest.approx(
        sim.grad_bytes, rel=1e-6)

    over = sim.emit_step(SyncCandidate(3, "rabenseifner", 2))
    row = over.rank_ops[0]
    colls = [op for op in row if isinstance(op, Collective)]
    assert [c.handle for c in colls] == ["g0", "g1", "g2"]
    waits = [op for op in row if isinstance(op, Wait)]
    # bucket 2 drains bucket 0 in-line; the final Wait() drains the rest
    assert [w.handles for w in waits] == [("g0",), None]
    # [fwd, bwd_0..bwd_2, opt] compute slots per rank
    assert sum(isinstance(op, Compute) for op in row) == 5


def test_split_moves_payloads_not_structure(sim):
    a = SyncCandidate(4, "rabenseifner", 1)
    b = dataclasses.replace(a, split=(0.4, 0.3, 0.2, 0.1))
    sa, sb = sim.emit_step(a), sim.emit_step(b)
    ka = [type(op).__name__ for op in sa.rank_ops[0]]
    kb = [type(op).__name__ for op in sb.rank_ops[0]]
    assert ka == kb
    assert sim.bucket_bytes(b)[0] > sim.bucket_bytes(a)[0]


# ------------------------------------------------ batched fast path
def test_batched_lane_matches_single_lane(sim):
    cands = [SyncCandidate(4, "rabenseifner", 2),
             SyncCandidate(4, "rabenseifner", 2,
                           split=(0.4, 0.3, 0.2, 0.1)),
             SyncCandidate(4, "rabenseifner", 0),
             SyncCandidate(4, "recursive_doubling", 1)]
    # check=2 re-runs sampled columns on the interpreter inside the
    # scenario substrate (compiled==interp <= 1e-9 or raise)
    us = sim.cost_candidates(cands, check=2, rtol=1e-9)
    singles = np.array([sim.step_time_single(c) for c in cands])
    rel = np.abs(us - singles) / singles
    assert rel.max() <= 1e-9, rel
    # the uneven split must actually change the step time
    assert us[1] != us[0]


def test_batched_lane_engine_numpy(sim):
    cands = [SyncCandidate(2, "rabenseifner", 1),
             SyncCandidate(2, "rabenseifner", 1, split=(0.7, 0.3))]
    us = sim.cost_candidates(cands, engine="numpy", check=1)
    assert np.all(us > 0) and us[0] != us[1]


# ---------------------------------------------------- emergent overlap
def test_overlap_emergent_between_bounds(sim):
    cand = SyncCandidate(4, "rabenseifner", 2)
    ov = sim.step_time_single(cand)
    bl = sim.serialized_us(cand)
    lb = sim.lower_bound_us(cand)
    # strictly below the serialized sum, at/above the critical path
    assert ov < bl
    assert ov >= lb * (1 - 1e-9)


def test_overlap_emerges_through_analytic_hooks(sim):
    over = sim.step_time_analytic(SyncCandidate(4, "rabenseifner", 2))
    block = sim.step_time_analytic(SyncCandidate(4, "rabenseifner", 0))
    assert 0 < over < block


# -------------------------------------------------------- planner flip
def test_plan_train_sync_flips_analytic_baseline(sim):
    plan = CollectivePlanner(sim.machine).plan_train_sync(
        sim, generations=1, survivors=3, children=3, seed=0, check=1)
    assert plan.baseline.overlap_depth == 0           # CommPolicy lane
    assert plan.step_us <= plan.baseline_step_us
    assert plan.flipped and plan.flip_kinds
    assert plan.margin > 0.05
    assert plan.evaluated >= len(sim.candidate_grid())
    assert plan.arch == SPEC.arch and plan.nranks == SPEC.nranks


def test_analytic_candidate_is_blocking_and_feasible(sim):
    base = sim.analytic_candidate()
    assert base.overlap_depth == 0 and base.split is None
    assert base.algo in sim.feasible_algos()
    assert 1 <= base.n_buckets <= 64


# ------------------------------------------- async seam error paths
def test_async_handle_reuse_raises(sim):
    ops = (Collective("allreduce", 1024, "rabenseifner", handle="h"),
           Collective("allreduce", 1024, "rabenseifner", handle="h"),
           Wait())
    prog = Program(tuple(ops for _ in range(4)))
    with pytest.raises(ProgramError, match="reused"):
        sim.machine._mpi_for(4).run_program(prog, backend="interp")


def test_wait_unknown_handle_raises(sim):
    ops = (Collective("allreduce", 1024, "rabenseifner", handle="h"),
           Wait(("nope",)))
    prog = Program(tuple(ops for _ in range(4)))
    with pytest.raises(ProgramError, match="unknown handle"):
        sim.machine._mpi_for(4).run_program(prog, backend="interp")


def test_async_compiled_matches_interp(sim):
    mpi = sim.machine._mpi_for(8)
    ops = (Compute(us=50.0),
           Collective("allreduce", 1 << 16, "recursive_doubling",
                      handle="a"),
           Compute(us=200.0),
           Collective("allreduce", 1 << 14, "recursive_doubling",
                      handle="b"),
           Wait(("a",)),
           Compute(us=25.0),
           Wait())
    prog = Program(tuple(ops for _ in range(8)))
    ci = mpi.run_program(prog, backend="interp").latency_us
    cc = mpi.run_program(prog, backend="compiled").latency_us
    assert ci == pytest.approx(cc, rel=1e-9)


# --------------------------------------- satellite: grad_sync memoization
def test_cost_sync_program_memoized():
    from repro.parallel import grad_sync as gs
    gs.clear_sync_cost_cache()
    m = ExanetMachine()
    buckets = [1 << 20, 1 << 19]
    a = gs.cost_sync_program_s(m, 8, buckets, compute_us_per_bucket=25.0)
    info = gs.sync_cost_cache_info()
    assert info["misses"] == 1 and info["hits"] == 0
    b = gs.cost_sync_program_s(m, 8, buckets, compute_us_per_bucket=25.0)
    info = gs.sync_cost_cache_info()
    assert info["hits"] == 1 and info["misses"] == 1 and a == b
    # any key component change is a distinct entry
    gs.cost_sync_program_s(m, 8, buckets, compute_us_per_bucket=25.0,
                           overlap_depth=1)
    gs.cost_sync_program_s(m, 8, buckets, compute_us_per_bucket=25.0,
                           algo="recursive_doubling")
    gs.cost_sync_program_s(TpuMachine(), 8, buckets,
                           compute_us_per_bucket=25.0)
    info = gs.sync_cost_cache_info()
    assert info["misses"] == 4 and info["hits"] == 1
    # cached values survive a re-query and match a cold recompute
    gs.clear_sync_cost_cache()
    assert gs.cost_sync_program_s(m, 8, buckets,
                                  compute_us_per_bucket=25.0) == a
    assert gs.sync_cost_cache_info()["size"] == 1


def test_emit_sync_program_overlap_depth():
    from repro.parallel.grad_sync import emit_sync_program
    prog = emit_sync_program(4, [1 << 20] * 3, compute_us_per_bucket=10.0,
                             overlap_depth=1)
    row = prog.rank_ops[0]
    handles = [op.handle for op in row if isinstance(op, Collective)]
    assert handles == ["g0", "g1", "g2"]
    assert any(isinstance(op, Wait) and op.handles == ("g0",)
               for op in row)
    assert isinstance(row[-1], Wait) and row[-1].handles is None
