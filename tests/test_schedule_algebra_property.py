"""Hypothesis property tests for the round algebra (ISSUE 8).

Unbounded-generator twins of the seeded sampling tests in
``test_schedule_algebra.py``: *any* term the strategy can produce must
(a) implement an exact-once allreduce under the contribution-tracking
check and (b) agree interp-vs-compiled to <=1e-9.  Skipped (like
``test_property.py``) when hypothesis is not installed; the seeded
versions keep the gates enforced regardless.
"""

import json

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.exanet import ExanetMPI
from repro.core.exanet.schedule_algebra import (SIGMA_HI, SIGMA_LO,
                                                Dissemination, Hierarchical,
                                                Pipeline, Split, TermSchedule,
                                                term_from_spec)
from repro.core.synth.search import AGREEMENT_RTOL
from repro.core.synth.verify import check_term

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def sigmas(k):
    return st.tuples(*[st.floats(SIGMA_LO, SIGMA_HI, allow_nan=False)
                       for _ in range(k)])


@st.composite
def terms(draw, max_ranks=64):
    """(term, nranks): a random combinator tree over a feasible scope."""
    base_kind = draw(st.sampled_from(["split", "dissem"]))
    if base_kind == "split":
        k = draw(st.integers(1, 4))
        term, n = Split(draw(sigmas(k))), 1 << k
    else:
        radix = draw(st.integers(2, 3))
        m = draw(st.integers(1, 3))
        term, n = Dissemination(radix), radix ** m
    if draw(st.booleans()):
        term = Pipeline(draw(st.integers(2, 3)), term)
    if draw(st.booleans()):
        group = draw(st.sampled_from([2, 4]))
        if n >= 2 and n * group <= max_ranks:
            term, n = Hierarchical(group, term), n * group
    hypothesis.assume(n <= max_ranks)
    return term, n


@given(terms())
def test_random_terms_are_semantically_correct(tn):
    term, nranks = tn
    check_term(term, nranks)
    widths = term.atom_widths(nranks)
    assert widths.shape == (term.n_atoms(nranks),)
    assert np.isclose(widths.sum(), 1.0)
    assert (widths > 0).all()


@given(terms())
def test_genome_and_spec_roundtrip(tn):
    term, nranks = tn
    again = term.with_genome(term.genome())
    assert again.spec() == term.spec()
    rebuilt = term_from_spec(json.loads(json.dumps(term.spec())))
    assert rebuilt.structure_key() == term.structure_key()
    assert TermSchedule(rebuilt).name == TermSchedule(term).name


@given(terms(max_ranks=32), st.sampled_from([64, 4096, 65536]))
@settings(max_examples=15, deadline=None)
def test_random_terms_agree_interp_vs_compiled(tn, nbytes):
    term, nranks = tn
    sched = TermSchedule(term)
    mpi = ExanetMPI()
    interp = mpi.run_schedule(sched, nbytes, nranks,
                              backend="interp").latency_us
    compiled = mpi.run_schedule(sched, nbytes, nranks,
                                backend="compiled").latency_us
    assert abs(interp - compiled) / max(abs(interp), 1e-30) <= AGREEMENT_RTOL
